"""Quickstart: the paper's pipeline end to end on one function.

    PYTHONPATH=src python examples/quickstart.py [--bits 12] [--kind recip]

1. Open an ``Explorer`` session (the single public entry point, repro.api).
2. Find the minimum feasible number of lookup bits (Eqns 9-10).
3. Sweep LUT heights, run the §III decision procedure per R.
4. Pick best area-delay, verify exhaustively (every input code, int64).
5. Evaluate through the Pallas kernel (interpret mode on CPU) and compare
   against the Remez (FloPoCo-style) baseline's LUT widths.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.api import ExploreConfig, Explorer
from repro.core import area as area_model
from repro.core.remez import generate_remez_table
from repro.kernels.interp.ops import table_eval


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", default="recip",
                    choices=["recip", "log2", "exp2", "exp2neg", "rsqrt",
                             "sigmoid", "silu", "softplus", "gelu"])
    ap.add_argument("--bits", type=int, default=12)
    args = ap.parse_args()

    ex = Explorer(ExploreConfig(kind=args.kind, bits=args.bits))
    spec = ex.config.spec()
    print(f"target: {spec.name}  ({spec.in_bits} -> {spec.out_bits} bits, "
          f"±{spec.ulp} ULP)")

    r_min = ex.min_regions(spec)
    print(f"minimum feasible lookup bits (Eqns 9-10 over all regions): R = {r_min}")

    res = ex.explore(spec)
    print(f"\nLUB sweep ({len(res)} feasible heights):")
    for g in res:
        d = g.design
        print(f"  R={d.lookup_bits}  {'lin ' if d.degree == 1 else 'quad'}"
              f"  k={d.k}  widths={d.lut_widths}  area={g.area:7.0f}"
              f"  delay={g.delay:5.2f}  AxD={g.area_delay:9.0f}"
              f"  gen={g.runtime_s:6.2f}s")

    best = res.best
    d = best.design
    ok, worst = d.verify(spec)
    print(f"\nbest area-delay: R={d.lookup_bits}, exhaustively verified over "
          f"2^{spec.in_bits} inputs: {'PASS' if ok else 'FAIL'}")

    codes = np.arange(1 << spec.in_bits, dtype=np.int32)
    out_kernel = np.asarray(table_eval(jax.numpy.asarray(codes), d))
    lo, hi = spec.bound_arrays()
    inside = np.all((lo <= out_kernel) & (out_kernel <= hi))
    print(f"Pallas kernel (interpret) output within bounds: {inside}")

    try:
        rz = generate_remez_table(spec, d.lookup_bits, degree=d.degree)
        if rz is None:
            raise ValueError("remez infeasible at this height")
        wa, wb, wc = d.lut_widths
        ra, rb, rc = rz.widths
        ad = area_model.estimate(rz.design)
        print(f"\nvs Remez baseline @ R={d.lookup_bits}:")
        print(f"  proposed LUT [{wa},{wb},{wc}] = {wa+wb+wc} bits/row,"
              f"  AxD = {best.area_delay:.0f}")
        print(f"  Remez    LUT [{ra},{rb},{rc}] = {ra+rb+rc} bits/row,"
              f"  AxD = {ad.product:.0f}")
    except ValueError as e:
        print(f"\nRemez baseline failed at this height: {e}")


if __name__ == "__main__":
    main()
