"""End-to-end training driver: train Mamba2-130M (the ~100M-class assigned
arch) on the synthetic pipeline with checkpointing and table-backed numerics.

    PYTHONPATH=src python examples/train_lm.py                 # full 130M run
    PYTHONPATH=src python examples/train_lm.py --smoke --steps 20   # tiny CPU run

Defaults train the real 130M-parameter config for a few hundred steps — on
CPU budget that's hours; pass ``--steps``/``--seq-len``/``--global-batch`` to
scale. ``--numerics interp`` routes every softplus/exp/SiLU/rsqrt in the SSD
recurrence through the paper's certified tables.
"""
from __future__ import annotations

import argparse

from repro.configs.base import get_config, get_smoke_config
from repro.train.step import StepConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2_130m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--numerics", choices=["exact", "interp"], default="exact")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke else get_config(args.arch))
    cfg = cfg.replace(numerics=args.numerics)
    tc = TrainerConfig(
        steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=50,
        log_every=5, seq_len=args.seq_len, global_batch=args.global_batch,
        step=StepConfig(microbatches=args.microbatches, peak_lr=6e-4,
                        warmup=min(50, args.steps // 5 + 1),
                        total_steps=args.steps),
    )
    trainer = Trainer(cfg, tc)
    if trainer.start_step:
        print(f"resuming from step {trainer.start_step}")
    hist = trainer.run()
    first, last = hist[0]["loss"], hist[-1]["loss"]
    med = sorted(t["wall_s"] for t in hist)[len(hist) // 2]
    print(f"\nloss {first:.3f} -> {last:.3f} over {len(hist)} steps "
          f"({med*1e3:.0f} ms/step median, numerics={args.numerics})")
    if trainer.stragglers:
        print(f"straggler steps: {trainer.stragglers}")


if __name__ == "__main__":
    main()
