"""Re-targeting demo — the paper's core selling point.

    PYTHONPATH=src python examples/retarget_hardware.py [--bits 12]

The complete design space is generated ONCE; three different "hardware
technologies" then explore the *same* space with different decision
procedures (§III: "Targeting alternative hardware technologies simply
requires a modified decision procedure"):

  * asic   — the paper's ordering (square path critical): min k, max square
             truncation, max linear truncation, min a/b/c widths.
  * sram   — LUT-dominated target (FPGA BRAM-ish): minimize total LUT row
             width first (smallest memory), tolerate wider multipliers.
  * vmem   — this repo's TPU kernel target: minimize R at fixed widths
             (VMEM footprint = 2^R rows x row width drives kernel residency).
"""
from __future__ import annotations

import argparse

from repro.core import area as area_model
from repro.core.funcspec import get_spec
from repro.core.generate import sweep_lub


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=12)
    ap.add_argument("--kind", default="recip")
    args = ap.parse_args()
    spec = get_spec(args.kind, args.bits)

    # one design space -> many targets
    results = sweep_lub(spec)
    assert results, "no feasible designs"

    def describe(tag, g):
        d = g.design
        rows = 1 << d.lookup_bits
        print(f"  {tag:5s}: R={d.lookup_bits} {'lin' if d.degree == 1 else 'quad'}"
              f" widths={d.lut_widths} LUT={rows}x{sum(d.lut_widths)}b"
              f" ({rows*sum(d.lut_widths)/8192:.1f} KiB)"
              f" area={g.area:.0f} delay={g.delay:.2f}")

    asic = min(results, key=lambda g: g.area_delay)
    sram = min(results, key=lambda g: (1 << g.design.lookup_bits) * sum(g.design.lut_widths))
    vmem = min(results, key=lambda g: (g.design.lookup_bits, sum(g.design.lut_widths)))

    print(f"design space for {spec.name}: {len(results)} feasible LUT heights\n")
    print("same space, three targets:")
    describe("asic", asic)
    describe("sram", sram)
    describe("vmem", vmem)
    print("\nno re-generation happened between targets — only the decision "
          "procedure changed (the paper's §III claim).")


if __name__ == "__main__":
    main()
