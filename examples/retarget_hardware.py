"""Re-targeting demo — the paper's core selling point, now one API call.

    PYTHONPATH=src python examples/retarget_hardware.py [--bits 12]

The region envelopes (the expensive, target-independent part of the design
space) are computed ONCE inside an ``Explorer`` session; each registered
``Target`` then explores the *same* cached space with its own decision
procedure and cost model (§III: "Targeting alternative hardware technologies
simply requires a modified decision procedure"):

  * asic       — the paper's ordering (square path critical): min k, max
                 truncations, min a/b/c widths; ranked by area x delay.
  * fpga-lut   — everything is 6-LUTs; ranked by total LUT count.
  * pallas-tpu — this repo's TPU kernels: truncation steps skipped (lane
                 width is fixed), ranked by VMEM footprint + product width.

Registering a fourth technology is `@register_target("name")` + ~20 lines —
try it below with --custom.
"""
from __future__ import annotations

import argparse

from repro.api import (DecisionPolicy, ExploreConfig, Explorer, list_targets,
                       register_target)
from repro.core import area as area_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=12)
    ap.add_argument("--kind", default="recip")
    ap.add_argument("--custom", action="store_true",
                    help="also register + run a custom low-power target")
    args = ap.parse_args()

    if args.custom:
        @register_target("low-power")
        class LowPower:
            """Leakage-dominated node: LUT bits are nearly free, switching in
            the multipliers is not — rank by multiplier area only."""
            policy = DecisionPolicy(prefer_linear=True)

            def estimate(self, design):
                ad = area_model.estimate(design)
                lut_bits = (1 << design.lookup_bits) * sum(design.lut_widths)
                return area_model.AreaDelay(ad.area - 0.25 * lut_bits, ad.delay)

            def objective(self, design, ad):
                return ad.area

    with Explorer(ExploreConfig(kind=args.kind, bits=args.bits)) as ex:
        spec = ex.config.spec()
        print(f"one session, one design space ({spec.name}), "
              f"{len(list_targets())} targets:\n")
        for tname in list_targets():
            res = ex.explore(spec, target=tname)
            assert res, f"no feasible designs for target {tname}"
            d = res.best.design
            rows = 1 << d.lookup_bits
            front = ",".join(f"R{e.lookup_bits}" for e in res.pareto())
            print(f"  {tname:10s}: R={d.lookup_bits} "
                  f"{'lin' if d.degree == 1 else 'quad'}"
                  f" widths={d.lut_widths} LUT={rows}x{sum(d.lut_widths)}b"
                  f" ({rows * sum(d.lut_widths) / 8192:.1f} KiB)"
                  f" area={res.best.area:.0f} delay={res.best.delay:.2f}"
                  f"  pareto=[{front}]")
        stats = ex.envelope_stats
        print(f"\nenvelope computations: {stats['computed']} "
              f"(cache hits: {stats['hits']}) — the space was generated once; "
              f"only the decision procedure changed between targets "
              f"(the paper's §III claim).")


if __name__ == "__main__":
    main()
