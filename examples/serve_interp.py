"""Serving with certified table numerics: compile -> save -> load -> serve.

    PYTHONPATH=src python examples/serve_interp.py [--arch yi_6b]

The deployment flow the library artifact enables:

  1. ``Explorer.compile()`` packs every table the interp numerics touch
     into one ``InterpLibrary`` (generating + verifying on a cold cache);
  2. ``library.save(path)`` persists it as npz + json manifest;
  3. a serving process ``InterpLibrary.load``s the artifact and constructs
     its ``ServeEngine`` from it — *zero* exploration calls at serve time.

The same batched request stream is then served with XLA transcendentals and
with the loaded library in every softmax/SiLU/rsqrt, reporting token
agreement plus the certified worst-case softmax error bound.
"""
from __future__ import annotations

import argparse
import pathlib
import tempfile

import jax
import numpy as np

from repro.api import Explorer, InterpLibrary
from repro.configs.base import get_smoke_config
from repro.models import transformer as tf
from repro.numerics.ops import softmax_ulp_bound
from repro.serve import ServeEngine
from repro.serve.engine import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--library", default=None,
                    help="library artifact path: loaded if it exists "
                         "(matching repro.launch.serve --library), compiled "
                         "+ saved there otherwise (default: a temp dir)")
    args = ap.parse_args()

    base = get_smoke_config(args.arch)
    params = tf.init_params(jax.random.key(0), base)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, base.vocab_size, args.prompt_len).astype(np.int32)
               for _ in range(args.requests)]

    path = pathlib.Path(args.library or
                        tempfile.mkdtemp(prefix="interp_lib_")) / "library"
    manifest = path.with_suffix(".json")
    if not manifest.exists():
        # compile once: one Explorer session generates + verifies every
        # table of the manifest and packs them into a single pytree artifact
        with Explorer() as ex:
            manifest = ex.compile().save(path)
        print(f"compiled library -> {manifest}")

    # the serving side only ever loads — no Explorer, no generation, just
    # the packed coefficients riding through the jitted decode as a pytree
    library = InterpLibrary.load(manifest)
    print(f"loaded {manifest}: {library}")

    outs = {}
    for numerics in ("exact", "interp"):
        cfg = base.replace(numerics=numerics)
        eng = ServeEngine(cfg, params, slots=args.slots, cache_len=128,
                          library=library if numerics == "interp" else None)
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, args.max_new))
        done = sorted(eng.run(), key=lambda r: r.rid)
        outs[numerics] = [r.out for r in done]
        total = sum(len(r.out) for r in done)
        print(f"{numerics:7s}: served {len(done)} requests, {total} tokens")

    agree = [
        np.mean([a == b for a, b in zip(ea, ia)])
        for ea, ia in zip(outs["exact"], outs["interp"])
    ]
    print(f"\nper-request greedy token agreement exact-vs-interp: "
          f"{[f'{a:.2f}' for a in agree]}")
    # the bound is a function of the served tables' widths — read them from
    # the loaded artifact's metadata, not a second exploration session
    bound = softmax_ulp_bound(library.meta("exp2neg"), library.meta("recip"))
    print(f"certified softmax relative error bound of the tables: "
          f"{bound:.2e}")
    print("(tokens can differ only where the argmax margin is inside that "
          "bound — the approximation is *certified*, not heuristic)")


if __name__ == "__main__":
    main()
