"""Serving with certified table numerics: continuous batching, exact-vs-interp.

    PYTHONPATH=src python examples/serve_interp.py [--arch yi_6b]

Loads a (smoke-size) model twice — once with XLA transcendentals, once with
the paper's piecewise-polynomial tables in every softmax/SiLU/rsqrt — serves
the same batched request stream through the continuous-batching engine, and
reports token agreement plus the certified worst-case softmax error bound
carried by the tables.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.api import Explorer, set_default_explorer
from repro.configs.base import get_smoke_config
from repro.models import transformer as tf
from repro.numerics.ops import softmax_ulp_bound
from repro.serve import ServeEngine
from repro.serve.engine import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    base = get_smoke_config(args.arch)
    params = tf.init_params(jax.random.key(0), base)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, base.vocab_size, args.prompt_len).astype(np.int32)
               for _ in range(args.requests)]

    # one Explorer session supplies (and, on first run, generates + verifies)
    # every table the interp numerics touch; the engines and the jitted
    # decode paths all resolve through it once it is the process default
    set_default_explorer(Explorer())
    outs = {}
    for numerics in ("exact", "interp"):
        cfg = base.replace(numerics=numerics)
        eng = ServeEngine(cfg, params, slots=args.slots, cache_len=128)
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, args.max_new))
        done = sorted(eng.run(), key=lambda r: r.rid)
        outs[numerics] = [r.out for r in done]
        total = sum(len(r.out) for r in done)
        print(f"{numerics:7s}: served {len(done)} requests, {total} tokens")

    agree = [
        np.mean([a == b for a, b in zip(ea, ia)])
        for ea, ia in zip(outs["exact"], outs["interp"])
    ]
    print(f"\nper-request greedy token agreement exact-vs-interp: "
          f"{[f'{a:.2f}' for a in agree]}")
    print(f"certified softmax relative error bound of the tables: "
          f"{softmax_ulp_bound():.2e}")
    print("(tokens can differ only where the argmax margin is inside that "
          "bound — the approximation is *certified*, not heuristic)")


if __name__ == "__main__":
    main()
