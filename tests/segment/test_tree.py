"""Segmentation (dyadic prefix tree) combinatorics: construction guards,
splitting, the seg-index table, and the ROM-v2 packing of it."""
from __future__ import annotations

import numpy as np
import pytest

from repro.segment import Segmentation


def test_uniform_constructor_is_equal_depth_tiling():
    seg = Segmentation.uniform(8, 3)
    assert seg.n_leaves == 8 and seg.max_depth == 3 and seg.is_uniform
    assert np.array_equal(seg.leaf_widths(), np.full(8, 32))
    assert np.array_equal(seg.seg_table(), np.arange(8))


def test_invalid_tilings_rejected():
    with pytest.raises(ValueError, match="cover"):
        Segmentation(4, (1,))  # half the domain
    with pytest.raises(ValueError, match="cover"):
        Segmentation(4, (1, 1, 1))  # 150% of the domain
    with pytest.raises(ValueError, match="aligned"):
        Segmentation(4, (2, 1, 2, 2))  # depth-1 leaf starting at 1/4
    with pytest.raises(ValueError, match="depth"):
        Segmentation(4, (0, 5))  # depth past in_bits
    with pytest.raises(ValueError, match="at least one leaf"):
        Segmentation(4, ())
    with pytest.raises(ValueError, match="positive"):
        Segmentation(0, (0,))


def test_split_refines_one_leaf():
    seg = Segmentation.uniform(6, 2)  # 4 leaves of width 16
    s2 = seg.split(1)
    assert s2.depths == (2, 3, 3, 2, 2)
    assert np.array_equal(s2.leaf_starts(), [0, 16, 24, 32, 48])
    with pytest.raises(ValueError, match="max depth"):
        Segmentation(4, (0,)).split(0).split(0).split(0).split(0).split(0)


def test_split_many_matches_sequential_splits():
    seg = Segmentation.uniform(6, 2)
    assert seg.split_many([0, 2]).depths == seg.split(2).split(0).depths
    # duplicate indices collapse (a leaf splits once per call)
    assert seg.split_many([3, 3]).depths == seg.split(3).depths


def test_seg_table_assigns_cells_by_depth():
    # depths (1, 2, 2): leaf 0 owns the left half of the 2^2 address space
    seg = Segmentation(4, (1, 2, 2))
    assert np.array_equal(seg.seg_table(), [0, 0, 1, 2])
    assert seg.depth_groups() == {1: [0], 2: [1, 2]}


def test_packed_table_pads_to_rom_rows():
    seg = Segmentation(4, (1, 2, 2))  # 4 cells -> 2 rows of 3
    packed = seg.packed_table()
    assert packed.shape == (2, 3) and packed.dtype == np.int32
    assert np.array_equal(packed.reshape(-1)[:4], seg.seg_table())
    assert np.all(packed.reshape(-1)[4:] == 0)  # zero padding, never junk
