"""The ISSUE 8 proof obligation: a degenerate (all-equal-depth)
segmentation reproduces the uniform §III pipeline BITWISE — same
coefficients, same datapath constants, same evaluation on every input
code — and the per-group decisions agree across region engines."""
from __future__ import annotations

import numpy as np
import pytest

from repro.api.config import spec_for
from repro.core.decision import run_decision
from repro.segment import Segmentation, decide_segmentation
from repro.segment.segmenter import min_uniform_depth

KINDS = ("tanh", "sigmoid", "gelu", "silu")
BITS = 10


def _min_r(spec):
    return min_uniform_depth(spec, engine="batched")


@pytest.mark.parametrize("kind", KINDS)
def test_degenerate_equals_uniform_bitwise(kind):
    spec = spec_for(kind, BITS)
    r = _min_r(spec)
    uni, _report = run_decision(spec, r, engine="batched")
    seg = Segmentation.uniform(spec.in_bits, r)
    sd = decide_segmentation(spec, seg, engine="batched")
    assert sd is not None, f"{kind}: degenerate decision infeasible at R={r}"

    # identical coefficient ROM, row for row
    np.testing.assert_array_equal(sd.a, uni.a)
    np.testing.assert_array_equal(sd.b, uni.b)
    np.testing.assert_array_equal(sd.c, uni.c)
    # identical datapath constants on every leaf
    w = spec.in_bits - r
    for m in sd.leaf_meta:
        assert m == (w, uni.k, uni.sq_trunc, uni.lin_trunc, uni.degree)
    # identical storage formats
    assert (sd.a_meta, sd.b_meta, sd.c_meta) == \
        (uni.a_meta, uni.b_meta, uni.c_meta)

    # and the oracles agree on EVERY input code (exhaustive)
    codes = np.arange(1 << spec.in_bits, dtype=np.int64)
    np.testing.assert_array_equal(sd.eval_int(codes), uni.eval_int(codes))
    ok, worst = sd.verify(spec)
    assert ok and worst == 0


def test_group_decisions_engine_invariant():
    """batched vs pooled region engines produce the same segmented design —
    the same invariance the uniform pipeline guarantees (ISSUE 3)."""
    spec = spec_for("tanh", BITS)
    r = _min_r(spec)
    seg = Segmentation.uniform(spec.in_bits, r).split(0).split(0)
    a = decide_segmentation(spec, seg, engine="batched")
    b = decide_segmentation(spec, seg, engine="pooled")
    assert (a is None) == (b is None)
    if a is not None:
        np.testing.assert_array_equal(a.a, b.a)
        np.testing.assert_array_equal(a.b, b.b)
        np.testing.assert_array_equal(a.c, b.c)
        assert a.leaf_meta == b.leaf_meta


def test_nonuniform_refinement_still_verifies():
    """Splitting leaves of a feasible tree never breaks the certificate:
    each child's bounds are a subset of its parent's rows."""
    spec = spec_for("sigmoid", BITS)
    r = _min_r(spec)
    seg = Segmentation.uniform(spec.in_bits, r)
    for leaf in (0, 2, 5):
        seg = seg.split(leaf)
    sd = decide_segmentation(spec, seg, engine="batched")
    assert sd is not None
    ok, worst = sd.verify(spec)
    assert ok and worst == 0
    assert sd.n_leaves == (1 << r) + 3
    assert sd.seg_depth == r + 1
