"""Segment-index datapath goldens: the in-kernel ``_lut_seg`` one-hot
path (via the ``rom_eval_2d`` harness) and the gather-semantics reference
``interp_eval_seg_ref`` are bit-identical to ``SegmentedDesign.eval_int``
on every input code."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import InterpLibrary
from repro.api.config import spec_for
from repro.kernels.interp.kernel import BLOCK_ROWS, LANES, rom_eval_2d
from repro.kernels.interp.ref import interp_eval_seg_ref
from repro.segment import explore_segmented, min_uniform_depth


@pytest.fixture(scope="module")
def lib():
    spec = spec_for("tanh", 8)
    sd = explore_segmented(spec, max_depth=min_uniform_depth(
        spec, engine="batched"), engine="batched")
    assert sd is not None and sd.seg_depth > 0
    return InterpLibrary.from_designs([sd], ["tanh"]), sd


def test_seg_ref_matches_oracle(lib):
    library, sd = lib
    m = library.meta("tanh")
    slot = library.coeffs[library.func_id("tanh")]
    codes = jnp.arange(1 << sd.in_bits, dtype=jnp.int32)
    got = np.asarray(interp_eval_seg_ref(codes, slot, seg=m.seg_spec()),
                     np.int64)
    np.testing.assert_array_equal(got, sd.eval_int(np.arange(1 << sd.in_bits)))


def test_lut_seg_kernel_matches_oracle(lib):
    library, sd = lib
    m = library.meta("tanh")
    n = 1 << sd.in_bits
    rows = max(BLOCK_ROWS, n // LANES)
    assert rows * LANES >= n and rows % BLOCK_ROWS == 0
    codes = jnp.resize(jnp.arange(n, dtype=jnp.int32), (rows, LANES))
    rom = jnp.reshape(library.coeffs, (-1, 3))
    out = rom_eval_2d(codes, rom, fid=library.func_id("tanh"),
                      r_max=library.r_max, eval_bits=m.eval_bits, k=m.k,
                      sq_trunc=m.sq_trunc, lin_trunc=m.lin_trunc,
                      degree=m.degree, seg=m.seg_spec(), interpret=True)
    want = sd.eval_int(np.resize(np.arange(n), (rows, LANES)))
    np.testing.assert_array_equal(np.asarray(out, np.int64), want)


def test_fused_numerics_serve_segmented_activation(lib):
    """FusedInterpNumerics' pointwise entry points transparently route a
    segmented slot — identical to the plain library glue, which is the
    same bitwise contract the uniform slots already satisfy."""
    from repro.numerics.ops import FusedInterpNumerics, InterpNumerics

    library, _sd = lib
    x = jnp.linspace(-6.0, 6.0, 257, dtype=jnp.float32)
    plain = np.asarray(InterpNumerics(library).tanh(x), np.float32)
    fused = np.asarray(FusedInterpNumerics(library).tanh(x), np.float32)
    np.testing.assert_array_equal(plain, fused)
    assert np.all(np.isfinite(plain))
    # the approximation is actually tanh-like, not just finite
    assert np.abs(plain - np.tanh(np.asarray(x))).max() < 0.05
