"""InterpLibrary ROM v2: segmented slots in the library artifact.

Contract under test (ISSUE 8): a library with any segmented slot saves as
manifest version 2 and round-trips; an all-uniform library still saves as
version 1 with a byte-identical manifest and checksum-identical ROM to the
pre-segment code path; the fused multi-function ROM walk (ISSUE 9) serves
any mix of uniform and segmented slots bit-exactly against the per-kind
segment-index oracle."""
from __future__ import annotations

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import InterpLibrary, default_explorer, load_library
from repro.api.config import spec_for
from repro.segment import explore_segmented, min_uniform_depth
from repro.segment.segmenter import explore_segmented as _explore


@pytest.fixture(scope="module")
def seg_design():
    spec = spec_for("tanh", 8)
    sd = explore_segmented(spec, max_depth=min_uniform_depth(
        spec, engine="batched"), engine="batched")
    assert sd is not None
    return sd


@pytest.fixture(scope="module")
def mixed_lib(seg_design):
    ex = default_explorer()
    uni = ex.get_table("sigmoid")
    return InterpLibrary.from_designs([seg_design, uni],
                                      ["tanh", "sigmoid"])


def test_segmented_slot_evaluates_bitwise(mixed_lib, seg_design):
    codes = jnp.arange(1 << seg_design.in_bits, dtype=jnp.int32)
    got = np.asarray(mixed_lib.eval_int(codes, "tanh"), np.int64)
    want = seg_design.eval_int(np.arange(1 << seg_design.in_bits))
    np.testing.assert_array_equal(got, want)


def test_mixed_library_saves_as_v2_and_round_trips(mixed_lib, tmp_path):
    assert mixed_lib.manifest()["version"] == 2
    assert mixed_lib.segmented_kinds == ("tanh",)
    path = mixed_lib.save(tmp_path / "lib")
    back = load_library(path)
    assert back.metas == mixed_lib.metas
    np.testing.assert_array_equal(np.asarray(back.coeffs),
                                  np.asarray(mixed_lib.coeffs))
    codes = jnp.arange(1 << back.meta("tanh").in_bits, dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(back.eval_int(codes, "tanh")),
                                  np.asarray(mixed_lib.eval_int(codes, "tanh")))
    # the uniform co-resident slot is untouched by its segmented neighbour
    codes = jnp.arange(1 << back.meta("sigmoid").in_bits, dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(back.eval_int(codes, "sigmoid")),
        np.asarray(mixed_lib.eval_int(codes, "sigmoid")))


def test_uniform_library_still_saves_v1_checksum_identical(tmp_path):
    """ROM v2 must not perturb v1 artifacts: an all-uniform library's
    manifest stays version 1 and its content-addressed ROM file name (the
    sealed hash) is reproducible across saves."""
    lib = default_explorer().compile()
    assert lib.segmented_kinds == ()
    man = lib.manifest()
    assert man["version"] == 1
    for entry in man["funcs"]:
        assert "seg_depth" not in entry and "seg_meta" not in entry
    p1 = lib.save(tmp_path / "a")
    p2 = lib.save(tmp_path / "b")
    m1, m2 = json.loads(p1.read_text()), json.loads(p2.read_text())
    # the ROM file is content-addressed <stem>.<hash>.npz: equal content
    # hash across saves proves the sealed bytes are reproducible
    assert m1["coeffs_file"].split(".")[1] == m2["coeffs_file"].split(".")[1]
    assert {k: v for k, v in m1.items() if k != "coeffs_file"} == \
        {k: v for k, v in m2.items() if k != "coeffs_file"}
    back = load_library(p1)
    np.testing.assert_array_equal(np.asarray(back.coeffs),
                                  np.asarray(lib.coeffs))


def test_eval_fused_serves_segmented_slots(mixed_lib, seg_design):
    """The unified ROM walk replaced the PR-8 loud refusal: one fused call
    over mixed uniform+segmented fids matches the per-kind entry points
    bit-exactly on both the ref and interpreted-kernel paths."""
    tanh_bits = mixed_lib.meta("tanh").in_bits
    sig_bits = mixed_lib.meta("sigmoid").in_bits
    codes_t = jnp.arange(1 << tanh_bits, dtype=jnp.int32)
    codes_s = jnp.arange(1 << sig_bits, dtype=jnp.int32)
    codes = jnp.concatenate([codes_t, codes_s])
    fid_t = mixed_lib.kinds.index("tanh")
    fid_s = mixed_lib.kinds.index("sigmoid")
    fids = jnp.concatenate([jnp.full_like(codes_t, fid_t),
                            jnp.full_like(codes_s, fid_s)])
    want = np.concatenate([
        np.asarray(mixed_lib.eval_int(codes_t, "tanh"), np.int64),
        np.asarray(mixed_lib.eval_int(codes_s, "sigmoid"), np.int64)])
    for use_kernel in (False, True):
        got = np.asarray(mixed_lib.eval_fused(
            codes, fids, use_kernel=use_kernel, interpret=True), np.int64)
        np.testing.assert_array_equal(got, want)
    # and against the int64 ground truth directly
    np.testing.assert_array_equal(
        np.asarray(mixed_lib.eval_fused(codes_t, jnp.full_like(
            codes_t, fid_t), use_kernel=False), np.int64),
        seg_design.eval_int(np.arange(1 << tanh_bits)))


def test_compile_segmented_swaps_only_improving_slots():
    ex = default_explorer()
    lib_u = ex.compile()
    lib_s = ex.compile_segmented()
    assert set(lib_s.kinds) == set(lib_u.kinds)
    total_u = sum(m.rows_used for m in lib_u.metas)
    total_s = sum(m.rows_used for m in lib_s.metas)
    assert total_s < total_u  # at least one slot improved, none regressed
    for kind in lib_s.kinds:
        mu, ms = lib_u.meta(kind), lib_s.meta(kind)
        if ms.seg_depth:
            assert ms.rows_used < mu.rows_used
        else:
            assert ms == mu


def test_explore_segmented_reexported_identity():
    # the package-level name and the segmenter module resolve to one object
    assert explore_segmented is _explore
