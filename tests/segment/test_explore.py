"""Greedy segmenter + target cost model: the segmentation must buy ROM
rows at matched accuracy, and every Target must price the address
decoder it now needs."""
from __future__ import annotations

import pytest

from repro.api.config import spec_for
from repro.api.target import get_target
from repro.segment import (estimate_segmented, explore_segmented,
                           min_uniform_depth)


def test_explore_saves_rows_at_matched_accuracy():
    """The BENCH_8 headline as a test: sigmoid at the registry width meets
    the same faithful-rounding spec with strictly fewer ROM rows than the
    minimal uniform design (rows INCLUDING the packed seg-index table)."""
    spec = spec_for("sigmoid", None)
    r = min_uniform_depth(spec, engine="batched")
    sd = explore_segmented(spec, max_depth=r, engine="batched")
    assert sd is not None
    ok, worst = sd.verify(spec)
    assert ok and worst == 0  # same certificate as the uniform design
    assert sd.rows_used < (1 << r)
    assert sd.seg_depth <= r  # never a deeper index than uniform's R


def test_explore_respects_max_depth():
    spec = spec_for("tanh", 10)
    sd = explore_segmented(spec, max_depth=4, engine="batched")
    if sd is not None:
        assert sd.seg_depth <= 4
        assert max(seg_d for seg_d in sd.seg.depths) <= 4


@pytest.mark.parametrize("target", ("asic", "fpga-lut", "pallas-tpu"))
def test_every_target_prices_the_decoder(target):
    spec = spec_for("sigmoid", None)
    r = min_uniform_depth(spec, engine="batched")
    sd = explore_segmented(spec, max_depth=r, engine="batched")
    assert sd is not None
    t = get_target(target)
    ad = estimate_segmented(sd, t)
    assert ad.area >= 0 and ad.delay > 0
    # the decoder itself is monotone in table size and leaf count
    d_small = t.decoder_estimate(2, 1)
    d_big = t.decoder_estimate(sd.n_leaves, sd.seg_depth)
    assert d_big.area >= d_small.area and d_big.delay >= d_small.delay


def test_min_uniform_depth_matches_uniform_feasibility():
    from repro.core.designspace import regions_feasible

    spec = spec_for("tanh", 10)
    r = min_uniform_depth(spec, engine="batched")
    assert regions_feasible(spec, r, None, engine="batched")[0]
    if r > 1:
        assert not regions_feasible(spec, r - 1, None, engine="batched")[0]
