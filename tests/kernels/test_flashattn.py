"""Flash-attention kernel: interpret-mode vs ref oracle vs exact softmax,
with a hypothesis shape/dtype sweep per the kernel-testing contract."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flashattn.ops import attention_fused
from repro.kernels.flashattn.ref import flash_attention_ref
from repro.numerics.registry import get_table


def _qkv(key, b, s, h, d, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, h, d), dtype)
    v = jax.random.normal(ks[2], (b, s, h, d), dtype)
    return q, k, v


def _exact(q, k, v, causal):
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(q.shape[-1])
    if causal:
        qp = jnp.arange(q.shape[1])[:, None]
        kp = jnp.arange(k.shape[1])[None, :]
        s = jnp.where(qp >= kp, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_ref(causal, dtype):
    q, k, v = _qkv(jax.random.key(0), 2, 256, 2, 128, dtype)
    got = attention_fused(q, k, v, causal=causal, use_kernel=True, interpret=True)
    ref = attention_fused(q, k, v, causal=causal, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-3)


def test_kernel_close_to_exact_softmax():
    q, k, v = _qkv(jax.random.key(1), 1, 256, 2, 128, jnp.float32)
    got = attention_fused(q, k, v, causal=True, use_kernel=True, interpret=True)
    exact = _exact(q, k, v, True)
    err = np.max(np.abs(np.asarray(got) - np.asarray(exact)))
    # certified-table error budget: exp+recip bounds propagated through the
    # convex combination of |v| <= ~4 sigma values
    assert err < 2.5e-2, err


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([128, 256, 384]),
       st.sampled_from([1, 2]), st.booleans())
def test_kernel_shape_sweep(seed, s, h, causal):
    q, k, v = _qkv(jax.random.key(seed), 1, s, h, 128, jnp.float32)
    got = attention_fused(q, k, v, causal=causal, use_kernel=True, interpret=True)
    ref = attention_fused(q, k, v, causal=causal, use_kernel=False)
    assert got.shape == q.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=5e-2, atol=5e-3)


def test_dead_chunk_skip_equals_full():
    """Causal chunk-skipping must not change results (first row attends only
    to itself; last row to everything)."""
    q, k, v = _qkv(jax.random.key(2), 1, 512, 1, 128, jnp.float32)
    got = attention_fused(q, k, v, causal=True, use_kernel=True, interpret=True)
    ref = flash_attention_ref(
        q.transpose(0, 2, 1, 3).reshape(1, 512, 128),
        k.transpose(0, 2, 1, 3).reshape(1, 512, 128),
        v.transpose(0, 2, 1, 3).reshape(1, 512, 128),
        get_table("exp2neg"), get_table("recip"), causal=True)
    np.testing.assert_allclose(np.asarray(got)[0, :, 0], np.asarray(ref)[0],
                               rtol=5e-2, atol=5e-3)
