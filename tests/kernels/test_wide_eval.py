"""Emulated-int64 ("wide") evaluation: the DESIGN.md §7.5 fallback for
designs whose coefficients exceed int32.

Regression (ROADMAP, flagged by the PR-4 review): the non-kernel fallback
fed ``device_coeffs()`` — a hard int32 cast — to ``interp_eval_ref``, so a
wide-output reciprocal silently evaluated with wrapped coefficients instead
of taking the promised int64 path. ``test_wide_recip_exact_vs_numpy_oracle``
fails on the pre-fix code.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.table import CoeffMeta, TableDesign
from repro.kernels.interp.ops import table_eval
from repro.kernels.interp.ref import (_add64, _shra64, _u32, _umul32,
                                      interp_eval_wide)
from repro.numerics.ops import table_eval_int


def _wide_recip_design(in_bits: int = 12, R: int = 4) -> TableDesign:
    """A wide-output-reciprocal-shaped design: linear fits of
    V = 2^(2b+1) / (2^b + Z) per region with b = 17, whose c column (~36
    bits) and b column (>31 bits when scaled by 2^k) exceed int32."""
    b_out = 17
    k = 18
    n = 1 << R
    w = in_bits - R
    z0 = (np.arange(n, dtype=np.float64) * (1 << w))  # region left edges
    z1 = z0 + (1 << w)
    f0 = 2.0 ** (2 * b_out + 1) / (2.0 ** b_out + z0)
    f1 = 2.0 ** (2 * b_out + 1) / (2.0 ** b_out + z1)
    slope = np.round((f1 - f0) / (1 << w) * (1 << k)).astype(np.int64)
    c = np.round(f0 * (1 << k)).astype(np.int64)
    assert np.abs(c).max() >= 2**31, "test premise: c exceeds int32"
    return TableDesign(
        name="recip_wide_test", in_bits=in_bits, out_bits=b_out + 1,
        lookup_bits=R, k=k, degree=1, sq_trunc=0, lin_trunc=0,
        a=np.zeros(n, np.int64), b=slope, c=c,
        a_meta=CoeffMeta(1, 0, False),
        b_meta=CoeffMeta(int(np.abs(slope).max()).bit_length(), 0, True),
        c_meta=CoeffMeta(int(c.max()).bit_length(), 0, False))


def test_wide_recip_exact_vs_numpy_oracle():
    """table_eval on an oversized design must equal the exhaustive numpy
    int64 oracle — on BOTH the use_kernel paths (the int32 ROM can't hold
    the coefficients, so both route to the wide jnp path)."""
    d = _wide_recip_design()
    assert not d.fits_int32
    codes = np.arange(1 << d.in_bits, dtype=np.int64)
    ref = d.eval_int(codes)
    assert np.abs(ref).max() < 2**31  # outputs fit int32: contract holds
    got = np.asarray(table_eval(jnp.asarray(codes, jnp.int32), d,
                                use_kernel=False)).astype(np.int64)
    np.testing.assert_array_equal(got, ref)
    got_k = np.asarray(table_eval(jnp.asarray(codes, jnp.int32), d,
                                  use_kernel=True)).astype(np.int64)
    np.testing.assert_array_equal(got_k, ref)
    # the numerics-layer gather path routes wide too
    got_n = np.asarray(table_eval_int(jnp.asarray(codes, jnp.int32), d)
                       ).astype(np.int64)
    np.testing.assert_array_equal(got_n, ref)
    # proof the test has teeth: the pre-fix path (int32 device cache fed to
    # interp_eval_ref) silently wraps and disagrees with the oracle
    from repro.kernels.interp.ref import interp_eval_ref

    wrapped = np.asarray(interp_eval_ref(
        jnp.asarray(codes, jnp.int32), d.device_coeffs(),
        eval_bits=d.eval_bits, k=d.k, sq_trunc=d.sq_trunc,
        lin_trunc=d.lin_trunc, degree=d.degree)).astype(np.int64)
    assert not np.array_equal(wrapped, ref)


def test_wide_quadratic_and_large_k():
    """Quadratic wide design (a*sq^2 crossing 32 bits) and a k >= 32 shift."""
    rng = np.random.default_rng(0)
    in_bits, R = 12, 4
    n = 1 << R
    a = rng.integers(-(1 << 21), 1 << 21, n).astype(np.int64)
    b = -rng.integers(1 << 32, 1 << 33, n).astype(np.int64)
    c = rng.integers(1 << 36, 1 << 37, n).astype(np.int64)
    codes = np.arange(1 << in_bits, dtype=np.int64)
    for k, degree in [(14, 2), (33, 1), (32, 2)]:
        d = TableDesign(
            name=f"wide_k{k}", in_bits=in_bits, out_bits=8, lookup_bits=R,
            k=k, degree=degree, sq_trunc=1, lin_trunc=0,
            a=a if degree == 2 else np.zeros(n, np.int64), b=b, c=c,
            a_meta=CoeffMeta(22, 0, True), b_meta=CoeffMeta(33, 0, True),
            c_meta=CoeffMeta(37, 0, False))
        got = np.asarray(table_eval(jnp.asarray(codes, jnp.int32), d,
                                    use_kernel=False)).astype(np.int64)
        np.testing.assert_array_equal(got, d.eval_int(codes), err_msg=f"k={k}")


def test_wide_eval_is_jittable():
    d = _wide_recip_design()
    codes = jnp.arange(1 << d.in_bits, dtype=jnp.int32)
    wide = d.device_coeffs_wide()
    f = jax.jit(lambda c: interp_eval_wide(
        c, wide, eval_bits=d.eval_bits, k=d.k, sq_trunc=d.sq_trunc,
        lin_trunc=d.lin_trunc, degree=d.degree))
    np.testing.assert_array_equal(np.asarray(f(codes)).astype(np.int64),
                                  d.eval_int(np.asarray(codes, np.int64)))


def test_doubleword_primitives_vs_numpy_int64():
    """Property check of the word-level ops against numpy int64/uint64."""
    rng = np.random.default_rng(1)
    a = rng.integers(-(2**31), 2**31, 4096).astype(np.int64)
    b = rng.integers(-(2**31), 2**31, 4096).astype(np.int64)
    au, bu = (x.astype(np.uint64) & 0xFFFFFFFF for x in (a, b))
    hi, lo = _umul32(_u32(jnp.asarray(a, jnp.int32)),
                     _u32(jnp.asarray(b, jnp.int32)))
    prod = au * bu  # unsigned 64-bit product of the 32-bit patterns
    np.testing.assert_array_equal(np.asarray(lo).astype(np.uint64),
                                  prod & 0xFFFFFFFF)
    np.testing.assert_array_equal(np.asarray(hi).astype(np.uint64),
                                  prod >> np.uint64(32))
    # add with carry: random u64 pairs, wrapped sum
    def words(v):
        return (jnp.asarray((v >> np.uint64(32)).astype(np.uint32)),
                jnp.asarray((v & np.uint64(0xFFFFFFFF)).astype(np.uint32)))

    x = rng.integers(0, 2**64, 4096, dtype=np.uint64)
    y = rng.integers(0, 2**64, 4096, dtype=np.uint64)
    hh, ll = _add64(*words(x), *words(y))
    s = x + y  # numpy wraps mod 2^64
    np.testing.assert_array_equal(np.asarray(ll).astype(np.uint64),
                                  s & np.uint64(0xFFFFFFFF))
    np.testing.assert_array_equal(np.asarray(hh).astype(np.uint64),
                                  s >> np.uint64(32))
    # arithmetic shift of signed 64-bit values
    v = rng.integers(-(2**62), 2**62, 4096)
    vh = jnp.asarray((v >> 32).astype(np.int64).astype(np.uint32).view(np.int32))
    vl = jnp.asarray((v & 0xFFFFFFFF).astype(np.uint32).view(np.int32))
    for k in (0, 1, 13, 31, 32, 40, 63):
        got = np.asarray(_shra64(_u32(vh), _u32(vl), k)).astype(np.int64)
        want = v >> k
        # _shra64 returns the low word: compare modulo 2^32, sign-extended
        np.testing.assert_array_equal(got, ((want + 2**31) % 2**32) - 2**31,
                                      err_msg=f"k={k}")
