"""Generalized multi-function ROM walk: golden bit-exactness over mixed
uniform + segmented libraries (ISSUE 9).

Contract: ``library_walk`` — the kernel behind ``eval_fused`` whenever any
slot is segmented — is bit-identical per element to the per-kind int64
oracles (``TableDesign.eval_int`` for uniform slots,
``SegmentedDesign.eval_int`` for segmented ones), on both the jnp-ref and
interpreted-Pallas paths, and collapses to ``library_eval`` bit-for-bit on
an all-uniform library (the v1 fast path is a special case of the walk).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import InterpLibrary, default_explorer
from repro.api.config import spec_for
from repro.kernels.interp.ops import library_eval, library_walk
from repro.segment import explore_segmented, min_uniform_depth

SEG_KINDS = ("tanh", "gelu")
UNI_KINDS = ("sigmoid", "exp2neg")


@pytest.fixture(scope="module")
def designs():
    """Two dyadic prefix-tree slots interleaved with two uniform ones —
    the walk must decode each element by its own slot's layout."""
    out = {}
    ex = default_explorer()
    for kind in SEG_KINDS:
        spec = spec_for(kind, 8)
        sd = explore_segmented(spec, max_depth=min_uniform_depth(
            spec, engine="batched"), engine="batched")
        assert sd is not None
        out[kind] = sd
    for kind in UNI_KINDS:
        out[kind] = ex.get_table(kind)
    return out


@pytest.fixture(scope="module")
def seg_lib(designs):
    kinds = ("tanh", "sigmoid", "gelu", "exp2neg")  # interleaved layouts
    lib = InterpLibrary.from_designs([designs[k] for k in kinds], list(kinds))
    assert set(lib.segmented_kinds) == set(SEG_KINDS)
    return lib


@pytest.fixture(scope="module")
def uni_lib():
    return default_explorer().compile()


def test_walk_matches_int64_oracle_every_kind(seg_lib, designs):
    """Exhaustive per-kind sweep: one fused walk call over every code of
    every slot == the per-design int64 oracle, ref and kernel paths."""
    parts, fid_parts, want = [], [], []
    for kind in seg_lib.kinds:
        m = seg_lib.meta(kind)
        codes = np.arange(1 << m.in_bits, dtype=np.int64)
        parts.append(codes.astype(np.int32))
        fid_parts.append(np.full(codes.size, seg_lib.func_id(kind), np.int32))
        want.append(designs[kind].eval_int(codes))
    codes = jnp.asarray(np.concatenate(parts))
    fids = jnp.asarray(np.concatenate(fid_parts))
    want = np.concatenate(want)
    walk, dp = seg_lib.walk_rows()
    ref = np.asarray(library_walk(codes, fids, seg_lib.coeffs, walk, dp,
                                  use_kernel=False), np.int64)
    np.testing.assert_array_equal(ref, want)
    kern = np.asarray(library_walk(codes, fids, seg_lib.coeffs, walk, dp,
                                   use_kernel=True, interpret=True), np.int64)
    np.testing.assert_array_equal(kern, want)


def test_walk_collapses_to_library_eval_on_uniform(uni_lib):
    """On an all-uniform library the walk's answer is bitwise the v1 fused
    kernel's — the special case eval_fused still fast-paths."""
    rng = np.random.default_rng(11)
    n_funcs = len(uni_lib.kinds)
    fids_np = rng.integers(0, n_funcs, 4096).astype(np.int32)
    codes_np = np.array([rng.integers(0, 1 << uni_lib.metas[f].in_bits)
                         for f in fids_np], np.int32)
    codes, fids = jnp.asarray(codes_np), jnp.asarray(fids_np)
    walk, dp = uni_lib.walk_rows()
    for use_kernel in (False, True):
        a = np.asarray(library_walk(codes, fids, uni_lib.coeffs, walk, dp,
                                    use_kernel=use_kernel, interpret=True))
        b = np.asarray(library_eval(codes, fids, uni_lib.coeffs,
                                    uni_lib.meta_rows(),
                                    use_kernel=use_kernel, interpret=True))
        np.testing.assert_array_equal(a, b)


def test_eval_fused_routes_mixed_library_through_walk(seg_lib):
    """The public entry point serves segmented fids without the PR-8
    refusal; per-kind answers equal eval_int's segment-index path."""
    for kind in seg_lib.segmented_kinds:
        m = seg_lib.meta(kind)
        codes = jnp.arange(1 << m.in_bits, dtype=jnp.int32)
        fids = jnp.full(codes.shape, seg_lib.func_id(kind), jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(seg_lib.eval_fused(codes, fids, use_kernel=False)),
            np.asarray(seg_lib.eval_int(codes, kind, use_kernel=False)))


def test_walk_rows_shapes(seg_lib, uni_lib):
    walk, dp = seg_lib.walk_rows()
    assert walk.shape == (len(seg_lib.kinds), 5)
    n_leaves = sum(len(m.seg_meta) if m.seg_depth else 1
                   for m in seg_lib.metas)
    assert dp.shape == (n_leaves, 5)
    walk_u, dp_u = uni_lib.walk_rows()
    assert dp_u.shape == (len(uni_lib.kinds), 5)
    assert int(walk_u[:, 2].sum()) == 0  # no seg flags on a v1 library
