"""Per-kernel interpret-mode validation: shape/dtype sweeps vs jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import designspace as dsp
from repro.core.funcspec import get_spec
from repro.kernels.dspace.ops import envelopes_pallas, envelopes_ref_jnp
from repro.kernels.interp.ops import table_eval
from repro.kernels.rmsnorm.ops import approx_rmsnorm_fused
from repro.kernels.softmax.ops import approx_softmax_fused
from repro.numerics import approx_rmsnorm, approx_softmax, get_table, softmax_ulp_bound


# ------------------------------------------------------------------- interp

@pytest.mark.parametrize("kind", ["exp2neg", "recip", "silu", "sigmoid"])
@pytest.mark.parametrize("shape", [(17,), (128,), (8, 200), (3, 5, 64)])
def test_interp_kernel_matches_ref_and_table(kind, shape):
    design = get_table(kind)
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 1 << design.in_bits, size=shape).astype(np.int32)
    out_kernel = np.asarray(table_eval(jnp.asarray(codes), design, use_kernel=True))
    out_ref = np.asarray(table_eval(jnp.asarray(codes), design, use_kernel=False))
    out_exact = design.eval_int(codes.astype(np.int64))
    np.testing.assert_array_equal(out_kernel, out_ref)
    np.testing.assert_array_equal(out_kernel.astype(np.int64), out_exact)


def test_interp_kernel_all_codes_exhaustive():
    design = get_table("recip")
    codes = np.arange(1 << design.in_bits, dtype=np.int32)
    out = np.asarray(table_eval(jnp.asarray(codes), design)).astype(np.int64)
    np.testing.assert_array_equal(out, design.eval_int(codes.astype(np.int64)))


# ------------------------------------------------------------------- dspace

@pytest.mark.parametrize("n", [128, 256, 384])
def test_envelope_kernel_matches_numpy_core(n):
    rng = np.random.default_rng(n)
    L = np.cumsum(rng.integers(0, 3, n)).astype(np.int64)
    U = L + rng.integers(0, 4, n)
    m_core, s_core = dsp.envelopes(L, U)
    m_pal, s_pal = envelopes_pallas(L, U)
    np.testing.assert_allclose(m_pal[1:], m_core[1:], rtol=1e-5)
    np.testing.assert_allclose(s_pal[1:], s_core[1:], rtol=1e-5)


def test_envelope_kernel_handles_padding():
    rng = np.random.default_rng(7)
    n = 200  # not a TILE multiple -> exercises sentinel padding
    L = np.cumsum(rng.integers(0, 3, n)).astype(np.int64)
    U = L + rng.integers(0, 4, n)
    m_core, s_core = dsp.envelopes(L, U)
    m_pal, s_pal = envelopes_pallas(L, U)
    assert m_pal.shape == m_core.shape
    np.testing.assert_allclose(m_pal[1:], m_core[1:], rtol=1e-5)
    np.testing.assert_allclose(s_pal[1:], s_core[1:], rtol=1e-5)


def test_envelope_kernel_batched_grid_matches_ref():
    """One pallas_call with a grid over regions == per-region dense oracle."""
    import jax.numpy as jnp

    from repro.kernels.dspace.kernel import envelopes_parity_batched
    from repro.kernels.dspace.ref import envelopes_parity_ref_batched

    rng = np.random.default_rng(11)
    b, n = 4, 128
    L = np.cumsum(rng.integers(0, 3, (b, n)), axis=1).astype(np.int64)
    U = L + rng.integers(0, 4, (b, n))
    got = envelopes_parity_batched(jnp.asarray(L, jnp.float32),
                                   jnp.asarray(U, jnp.float32))
    ref = envelopes_parity_ref_batched(jnp.asarray(L), jnp.asarray(U))
    for g, r in zip(got, ref):
        assert g.shape == (b, n)
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), rtol=1e-5)


def test_region_envelopes_device_matches_core():
    """Batched-engine device program == core numpy envelopes + a-intervals."""
    from repro.core import batched as bt
    from repro.kernels.dspace.ops import region_envelopes_device

    spec = get_spec("recip", 8)
    L, U = spec.region_bounds(3)
    big, m, a_lo, a_hi, feas9 = region_envelopes_device(L, U, interpret=True)
    big_ref, m_ref = bt.batched_envelopes(L, U)
    np.testing.assert_allclose(big[:, 1:], big_ref[:, 1:], rtol=2e-5)
    np.testing.assert_allclose(m[:, 1:], m_ref[:, 1:], rtol=2e-5)
    mask = bt.regions_feasible_mask(L, U)
    np.testing.assert_array_equal(np.asarray(feas9) & (a_lo < a_hi), mask)


def test_envelope_ref_jnp_matches_numpy():
    rng = np.random.default_rng(3)
    n = 64
    L = np.cumsum(rng.integers(0, 3, n)).astype(np.int64)
    U = L + rng.integers(0, 4, n)
    m_core, s_core = dsp.envelopes(L, U)
    m_ref, s_ref = envelopes_ref_jnp(L, U)
    np.testing.assert_allclose(m_ref[1:], m_core[1:], rtol=1e-5)
    np.testing.assert_allclose(s_ref[1:], s_core[1:], rtol=1e-5)


def test_envelope_kernel_drives_real_generation():
    """The kernel's envelopes reproduce the same feasibility verdicts."""
    spec = get_spec("recip", 8)
    L, U = spec.region_bounds(2)
    for r in range(4):
        m_core, s_core = dsp.envelopes(L[r], U[r])
        m_pal, s_pal = envelopes_pallas(L[r], U[r])
        assert np.all((m_pal[1:] < s_pal[1:]) == (m_core[1:] < s_core[1:]))


# ------------------------------------------------------------------ softmax

@pytest.mark.parametrize("shape", [(8, 128), (32, 256), (4, 8, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_softmax_accuracy(shape, dtype):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 4, shape), dtype)
    out = approx_softmax_fused(x)
    ref = jax.nn.softmax(x.astype(jnp.float32), axis=-1)
    tol = max(softmax_ulp_bound(), 1e-3 if dtype == jnp.float32 else 1e-2)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=tol)
    sums = np.asarray(out, np.float32).sum(-1)
    np.testing.assert_allclose(sums, 1.0, atol=5e-3)


def test_fused_softmax_matches_its_ref():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(0, 3, (16, 128)), jnp.float32)
    out_k = approx_softmax_fused(x, use_kernel=True)
    out_r = approx_softmax_fused(x, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-6, atol=1e-7)


def test_fused_softmax_close_to_jnp_numerics_path():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(0, 3, (8, 128)), jnp.float32)
    fused = approx_softmax_fused(x)
    unfused = approx_softmax(x)
    # frexp vs bit-twiddle rounding may differ by 1 table ulp
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused), atol=2e-3)


# ------------------------------------------------------------------ rmsnorm

@pytest.mark.parametrize("shape", [(8, 128), (2, 16, 256)])
def test_fused_rmsnorm_accuracy(shape):
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(0, 2, shape), jnp.float32)
    gamma = jnp.asarray(rng.normal(1, 0.1, shape[-1]), jnp.float32)
    out = approx_rmsnorm_fused(x, gamma)
    xf = np.asarray(x, np.float32)
    rs = 1.0 / np.sqrt((xf**2).mean(-1, keepdims=True) + 1e-6)
    ref = xf * rs * np.asarray(gamma)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-3, atol=2e-3)


def test_fused_rmsnorm_matches_its_ref_exactly():
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(0, 2, (8, 128)), jnp.float32)
    gamma = jnp.ones(128, jnp.float32)
    out_k = approx_rmsnorm_fused(x, gamma, use_kernel=True)
    out_r = approx_rmsnorm_fused(x, gamma, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-6)


def test_fused_rmsnorm_close_to_jnp_numerics_path():
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.normal(0, 2, (8, 128)), jnp.float32)
    gamma = jnp.ones(128, jnp.float32)
    fused = approx_rmsnorm_fused(x, gamma)
    unfused = approx_rmsnorm(x, gamma)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=3e-3, atol=3e-3)
