"""Fused library-bound datapath: golden bit-exactness of the in-kernel ROM
reads against the per-table ``table_eval_int`` oracle (every library kind),
bitwise equivalence of the library softmax/rmsnorm variants with the
per-table kernels, and the position-masked flash variant vs its oracle.

Bit-identity contract (ISSUE 5): the *integer* datapath of every fused
variant — ROM row select, coefficient gather, truncations, Horner, final
shift — is bit-identical to ``table_eval_int``; the composed float kernels
share one glue implementation with their per-table twins, so those pairs
are bitwise equal end to end.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import DEFAULT_LIBRARY_KINDS, default_explorer
from repro.kernels.flashattn.ops import attention_fused, attention_fused_library
from repro.kernels.interp.kernel import rom_eval_2d
from repro.kernels.rmsnorm.ops import approx_rmsnorm_fused, approx_rmsnorm_library
from repro.kernels.softmax.ops import (approx_softmax_fused,
                                       approx_softmax_library, lib_meta)
from repro.numerics.ops import get_numerics, table_eval_int


@pytest.fixture(scope="module")
def lib():
    return default_explorer().compile()


# ---------------------------------------------------------------------------
# per-kind golden: the fused consumers' in-kernel ROM datapath (_lut_rom)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", DEFAULT_LIBRARY_KINDS)
def test_rom_lut_golden_vs_table_eval_int(lib, kind):
    """Exhaustive per-kind sweep of `_lut_rom` — the exact datapath the
    fused softmax/rmsnorm/flashattn kernels evaluate in-registers — against
    the per-table oracle."""
    m = lib_meta(lib, kind)
    codes = np.arange(1 << m["in_bits"], dtype=np.int32)
    pad = (-codes.size) % (8 * 128)
    tiled = jnp.asarray(np.pad(codes, (0, pad)).reshape(-1, 128))
    out = rom_eval_2d(tiled, lib.coeffs.reshape(-1, 3), fid=m["fid"],
                      r_max=lib.coeffs.shape[1], **m["eval"], interpret=True)
    got = np.asarray(out).reshape(-1)[: codes.size]
    ref = np.asarray(table_eval_int(jnp.asarray(codes),
                                    default_explorer().get_table(kind)))
    np.testing.assert_array_equal(got, ref)


# ---------------------------------------------------------------------------
# fused softmax / rmsnorm: library variant == per-table variant, bit for bit
# ---------------------------------------------------------------------------

def test_library_softmax_bitwise_equals_per_table(lib):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3, (16, 128)).astype(np.float32))
    per_table = np.asarray(approx_softmax_fused(x, use_kernel=True,
                                                interpret=True))
    lib_kernel = np.asarray(approx_softmax_library(x, lib, use_kernel=True,
                                                   interpret=True))
    lib_ref = np.asarray(approx_softmax_library(x, lib, use_kernel=False))
    np.testing.assert_array_equal(lib_kernel, per_table)
    np.testing.assert_array_equal(lib_kernel, lib_ref)


def test_library_rmsnorm_bitwise_equals_per_table(lib):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 2, (16, 128)).astype(np.float32))
    gamma = jnp.asarray(rng.normal(1, 0.1, 128).astype(np.float32))
    per_table = np.asarray(approx_rmsnorm_fused(x, gamma, use_kernel=True,
                                                interpret=True))
    lib_kernel = np.asarray(approx_rmsnorm_library(x, gamma, lib,
                                                   use_kernel=True,
                                                   interpret=True))
    lib_ref = np.asarray(approx_rmsnorm_library(x, gamma, lib,
                                                use_kernel=False))
    np.testing.assert_array_equal(lib_kernel, per_table)
    np.testing.assert_array_equal(lib_kernel, lib_ref)


def test_library_softmax_unaligned_shapes(lib):
    """Off the 128-lane grid the wrapper runs the jnp ROM oracle — any
    trailing dim, any leading shape."""
    rng = np.random.default_rng(2)
    for shape in [(5,), (3, 33), (2, 4, 17)]:
        x = jnp.asarray(rng.normal(0, 3, shape).astype(np.float32))
        out = np.asarray(approx_softmax_library(x, lib))
        assert out.shape == shape
        np.testing.assert_allclose(out.sum(-1), 1.0, atol=5e-3)


# ---------------------------------------------------------------------------
# flash attention: library variant vs per-table kernel and vs the oracle
# ---------------------------------------------------------------------------

def test_library_flash_bitwise_equals_per_table_kernel(lib):
    """On the training layout (arange positions) the library kernel runs the
    same chunk math as the per-table kernel over the same ROM rows — bitwise
    equal."""
    rng = np.random.default_rng(3)
    q, k, v = (jnp.asarray(rng.normal(0, 1, (2, 256, 2, 128))
                           .astype(np.float32)) for _ in range(3))
    for causal in (True, False):
        a = np.asarray(attention_fused(q, k, v, causal=causal,
                                       use_kernel=True, interpret=True))
        b = np.asarray(attention_fused_library(q, k, v, lib, causal=causal,
                                               use_kernel=True,
                                               interpret=True))
        np.testing.assert_array_equal(a, b)


def test_library_flash_grouped_kv_matches_expanded(lib):
    """GQA: unexpanded (kvh < h) K/V through the kernel's index-mapped kv
    stripes == caller-expanded heads, bitwise (same programs per row)."""
    rng = np.random.default_rng(9)
    b, s, h, kvh, d = 2, 64, 4, 2, 64
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (b, s, kvh, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (b, s, kvh, d)).astype(np.float32))
    kx = jnp.repeat(k, h // kvh, axis=2)
    vx = jnp.repeat(v, h // kvh, axis=2)
    for use_kernel in (True, False):
        grouped = np.asarray(attention_fused_library(
            q, k, v, lib, causal=True, use_kernel=use_kernel, interpret=True))
        expanded = np.asarray(attention_fused_library(
            q, kx, vx, lib, causal=True, use_kernel=use_kernel,
            interpret=True))
        np.testing.assert_array_equal(grouped, expanded)


def test_library_flash_decode_masking_matches_ref_and_glue(lib):
    """Decode shape: Sq=1 against a partially-filled cache with per-row
    positions and dead slots. Kernel vs unchunked lib oracle vs the chunked
    attention_core glue path (table error budget only)."""
    from repro.models.attention import attention_core

    rng = np.random.default_rng(4)
    b, h, d, sk = 2, 2, 64, 48
    q = jnp.asarray(rng.normal(0, 1, (b, 1, h, d)).astype(np.float32))
    kc = jnp.asarray(rng.normal(0, 1, (b, sk, h, d)).astype(np.float32))
    vc = jnp.asarray(rng.normal(0, 1, (b, sk, h, d)).astype(np.float32))
    kv_pos = np.full((b, sk), -1, np.int32)
    kv_pos[0, :10] = np.arange(10)
    kv_pos[1, :20] = np.arange(20)
    q_pos = np.array([[9], [19]], np.int32)
    kw = dict(causal=True, q_pos=jnp.asarray(q_pos), kv_pos=jnp.asarray(kv_pos))
    kern = np.asarray(attention_fused_library(q, kc, vc, lib,
                                              use_kernel=True,
                                              interpret=True, **kw))
    ref = np.asarray(attention_fused_library(q, kc, vc, lib,
                                             use_kernel=False, **kw))
    np.testing.assert_allclose(kern, ref, rtol=5e-2, atol=5e-3)
    glue = np.asarray(attention_core(q, kc, vc, jnp.asarray(q_pos),
                                     jnp.asarray(kv_pos),
                                     get_numerics("interp"), causal=True))
    np.testing.assert_allclose(kern, glue, rtol=5e-2, atol=5e-3)


def test_library_flash_sliding_window(lib):
    """The window mask drops exactly the out-of-window positions (vs the
    oracle with the same mask semantics as models.attention._mask)."""
    rng = np.random.default_rng(5)
    b, s, h, d, w = 1, 64, 1, 64, 16
    q, k, v = (jnp.asarray(rng.normal(0, 1, (b, s, h, d)).astype(np.float32))
               for _ in range(3))
    kern = np.asarray(attention_fused_library(q, k, v, lib, causal=True,
                                              window=w, use_kernel=True,
                                              interpret=True))
    ref = np.asarray(attention_fused_library(q, k, v, lib, causal=True,
                                             window=w, use_kernel=False))
    np.testing.assert_allclose(kern, ref, rtol=5e-2, atol=5e-3)
    # windowed result must differ from unwindowed (the mask is live)
    full = np.asarray(attention_fused_library(q, k, v, lib, causal=True,
                                              use_kernel=False))
    assert np.abs(ref - full).max() > 1e-3


# ---------------------------------------------------------------------------
# fused numerics backend: routing + model-stack integration
# ---------------------------------------------------------------------------

def test_fused_numerics_requires_library():
    with pytest.raises(ValueError, match="needs a compiled InterpLibrary"):
        get_numerics("interp", None, fused=True)
    with pytest.raises(ValueError, match="needs a compiled InterpLibrary"):
        get_numerics("interp-fused")


def test_fused_numerics_softmax_matches_library_kernel(lib):
    num = get_numerics("interp", lib, fused=True)
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(0, 3, (8, 128)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(num.softmax(x)),
        np.asarray(approx_softmax_library(x, lib)))
    # non-last-axis softmax falls back to the glue path (still normalized)
    y = np.asarray(num.softmax(x, axis=0))
    np.testing.assert_allclose(y.sum(0), 1.0, atol=5e-3)
    gamma = jnp.ones(128, jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(num.rmsnorm(x, gamma)),
        np.asarray(approx_rmsnorm_library(x, gamma, lib)))


def test_fused_numerics_close_to_glue_numerics(lib):
    """Same certified tables, different code derivation for the reciprocal
    (bit-twiddle vs frexp): composite outputs agree within a table ulp."""
    fused = get_numerics("interp", lib, fused=True)
    glue = get_numerics("interp", lib)
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(0, 3, (8, 128)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(fused.softmax(x)),
                               np.asarray(glue.softmax(x)), atol=2e-3)
    gamma = jnp.ones(128, jnp.float32)
    np.testing.assert_allclose(np.asarray(fused.rmsnorm(x, gamma)),
                               np.asarray(glue.rmsnorm(x, gamma)),
                               rtol=3e-3, atol=3e-3)
