"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward/train step on CPU, asserting output shapes and no NaNs — for all
ten assigned architectures, under both numerics backends where it matters."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.data import make_batch
from repro.models import transformer as tf
from repro.numerics.ops import get_numerics

SEQ, BATCH = 64, 2


def _batch(cfg):
    b = make_batch(cfg, SEQ, BATCH)
    return {k: jnp.asarray(v) for k, v in b.items()}


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    return request.param


def test_loss_and_grad(arch):
    cfg = get_smoke_config(arch)
    numerics = get_numerics("exact")
    params = tf.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg)

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda q: tf.loss_fn(q, b, cfg, numerics), has_aux=True)(p)
        return loss, metrics, grads

    loss, metrics, grads = step(params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert float(loss) > 0
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, arch


def test_forward_logits_shape(arch):
    cfg = get_smoke_config(arch)
    numerics = get_numerics("exact")
    params = tf.init_params(jax.random.key(1), cfg)
    batch = _batch(cfg)
    logits = jax.jit(lambda p: tf.forward(
        p, batch["tokens"], cfg, numerics,
        frontend_emb=batch.get("frontend_emb"),
        enc_frames=batch.get("enc_frames")))(params)
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_prefill_decode_consistency(arch):
    """Greedy decode continuation must match teacher-forced forward argmax."""
    cfg = get_smoke_config(arch)
    numerics = get_numerics("exact")
    params = tf.init_params(jax.random.key(2), cfg)
    batch = _batch(cfg)
    toks = batch["tokens"]
    cache_len = SEQ + 8

    logits_tf = tf.forward(params, toks, cfg, numerics,
                           frontend_emb=batch.get("frontend_emb"),
                           enc_frames=batch.get("enc_frames"))
    last, caches, cross = tf.prefill(params, toks, cfg, numerics, cache_len,
                                     frontend_emb=batch.get("frontend_emb"),
                                     enc_frames=batch.get("enc_frames"))
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(logits_tf[:, -1]),
                               rtol=2e-2, atol=2e-2)
    # a few decode steps stay finite and shape-correct
    tok = jnp.argmax(last, -1).astype(jnp.int32)
    for i in range(3):
        logits, caches = tf.decode_step(params, tok, jnp.asarray(SEQ + i, jnp.int32),
                                        caches, cfg, numerics, cross=cross)
        assert logits.shape == (BATCH, 1, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch_i", ["yi_6b", "mamba2_130m", "deepseek_moe_16b"])
def test_interp_numerics_close_to_exact(arch_i):
    """The paper's table-backed numerics tracks exact numerics closely."""
    cfg = get_smoke_config(arch_i)
    params = tf.init_params(jax.random.key(3), cfg)
    batch = _batch(cfg)
    exact = tf.loss_fn(params, batch, cfg, get_numerics("exact"))[0]
    interp = tf.loss_fn(params, batch, cfg, get_numerics("interp"))[0]
    assert np.isfinite(float(interp))
    assert abs(float(exact) - float(interp)) < 0.15 * max(1.0, abs(float(exact)))
