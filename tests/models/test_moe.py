"""MoE block unit tests: routing semantics, capacity behaviour, aux loss."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig, get_smoke_config
from repro.models import moe as moe_mod
from repro.models.layers import init_tree
from repro.numerics.ops import get_numerics


def _setup(n_experts=4, top_k=2, cap_factor=1.25, d=32, d_e=48):
    cfg = get_smoke_config("mixtral_8x22b").replace(
        d_model=d,
        moe=MoEConfig(n_experts=n_experts, top_k=top_k, d_expert=d_e,
                      capacity_factor=cap_factor),
    )
    p = init_tree(jax.random.key(0), moe_mod.moe_shapes(cfg))
    return cfg, p


def test_moe_output_shape_and_finite():
    cfg, p = _setup()
    x = jax.random.normal(jax.random.key(1), (3, 16, cfg.d_model))
    y, probs = moe_mod.moe_block(p, x, cfg, get_numerics("exact"),
                                 return_probs=True)
    assert y.shape == x.shape
    assert probs.shape == (3, 16, 4)
    assert bool(jnp.all(jnp.isfinite(y)))
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, atol=1e-5)


def test_moe_batch_independence():
    """Per-example dispatch: example i's output must not depend on example j
    (the property that lets the batch axis stay DP-sharded)."""
    cfg, p = _setup()
    num = get_numerics("exact")
    xa = jax.random.normal(jax.random.key(2), (2, 16, cfg.d_model))
    xb = jax.random.normal(jax.random.key(3), (2, 16, cfg.d_model))
    both = jnp.concatenate([xa, xb], axis=0)
    y_both = moe_mod.moe_block(p, both, cfg, num)
    y_a = moe_mod.moe_block(p, xa, cfg, num)
    np.testing.assert_allclose(np.asarray(y_both[:2]), np.asarray(y_a),
                               rtol=1e-5, atol=1e-6)


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1 most token copies overflow; the block must
    still be finite and near zero for dropped tokens (residual fallthrough)."""
    cfg, p = _setup(cap_factor=0.1)
    x = jax.random.normal(jax.random.key(4), (1, 64, cfg.d_model))
    y = moe_mod.moe_block(p, x, cfg, get_numerics("exact"))
    assert bool(jnp.all(jnp.isfinite(y)))
    # tight capacity => strictly smaller output norm than generous capacity
    cfg2, _ = _setup(cap_factor=4.0)
    y2 = moe_mod.moe_block(p, x, cfg2, get_numerics("exact"))
    assert float(jnp.linalg.norm(y)) < float(jnp.linalg.norm(y2))


def test_moe_capacity_ample_uses_all_topk():
    """With ample capacity, output == dense mixture of the top-k experts."""
    cfg, p = _setup(cap_factor=8.0)
    num = get_numerics("exact")
    x = jax.random.normal(jax.random.key(5), (1, 8, cfg.d_model))
    y = moe_mod.moe_block(p, x, cfg, num)

    # dense reference: run every expert on every token, mix by renorm'd gates
    xt = x.reshape(-1, cfg.d_model)
    logits = xt.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.moe.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    h = jnp.einsum("td,edf->tef", xt, p["wi"])
    g, u = jnp.split(h, 2, -1)
    eo = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u, p["wo"])
    ref = jnp.einsum("tk,tkd->td", gate,
                     jnp.take_along_axis(eo, idx[..., None], 1))
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(ref), rtol=2e-2, atol=2e-3)


def test_shared_experts_added():
    cfg, p = _setup()
    cfg_sh = cfg.replace(moe=cfg.moe.__class__(
        n_experts=4, top_k=2, d_expert=48, n_shared=1))
    p_sh = init_tree(jax.random.key(0), moe_mod.moe_shapes(cfg_sh))
    x = jax.random.normal(jax.random.key(6), (1, 8, cfg.d_model))
    y0 = moe_mod.moe_block(p_sh, x, cfg, get_numerics("exact"))
    y1 = moe_mod.moe_block(p_sh, x, cfg_sh, get_numerics("exact"))
    assert float(jnp.max(jnp.abs(y1 - y0))) > 1e-4  # shared path contributes


def test_load_balance_loss_range():
    cfg, p = _setup()
    x = jax.random.normal(jax.random.key(7), (2, 32, cfg.d_model))
    _, probs = moe_mod.moe_block(p, x, cfg, get_numerics("exact"),
                                 return_probs=True)
    aux = moe_mod.load_balance_loss_from_probs(probs, cfg)
    # perfectly balanced -> top_k; pathological -> up to E * top_k
    assert cfg.moe.top_k * 0.5 <= float(aux) <= cfg.moe.n_experts * cfg.moe.top_k
