"""Test-suite bootstrap: run without optional dependencies.

``hypothesis`` powers the property-based tests but is an optional extra
(``pip install -e .[test]``). When it is absent we install a stub module
into ``sys.modules`` *before* test collection so the property tests are
skipped cleanly while every example-based test in the same files still
runs. The stub mirrors the handful of entry points the suite uses:
``given`` (returns a skip-marking decorator), ``settings`` (identity
decorator), and ``strategies`` (an absorbing object, since strategy
construction only happens at decoration time).
"""
from __future__ import annotations

import sys
import types

import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    class _Absorb:
        """Callable/attribute sink standing in for the strategies module."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    def _given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -e .[test])")(fn)
        return deco

    def _settings(*args, **kwargs):
        return lambda fn: fn

    _st = _Absorb()
    stub = types.ModuleType("hypothesis")
    stub.given = _given
    stub.settings = _settings
    stub.strategies = _st
    stub.__is_repro_stub__ = True
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.__getattr__ = lambda name: _st  # PEP 562 module-level fallback
    sys.modules["hypothesis"] = stub
    sys.modules["hypothesis.strategies"] = st_mod
