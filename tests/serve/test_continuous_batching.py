"""Mixed-length continuous batching: the per-slot position contract.

The engine decodes every live slot at its *own* next position. The pre-fix
engine decoded the whole pool at the single global ``max(pos)`` and then set
every slot's ``pos`` to ``pos + 1`` — a freshly admitted short-prompt request
got its KV/state rows written past its prefill position, leaving a garbage
gap and a wrong RoPE phase for the rest of its decode. The oracle is the
same engine serving one request at a time (slots=1): batching must not
change any request's greedy decode.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models import transformer as tf
from repro.serve.engine import Request, ServeEngine

MAX_NEW = 5


def _solo_decode(cfg, params, rid, prompt, cache_len):
    eng = ServeEngine(cfg, params, slots=1, cache_len=cache_len)
    eng.submit(Request(rid, prompt, max_new=MAX_NEW))
    (done,) = eng.run()
    return done.out


@pytest.mark.parametrize("arch", ["yi_6b", "minicpm3_4b"])
def test_mixed_length_batching_matches_one_at_a_time(arch):
    """Two slots, three requests of different prompt lengths: admission at
    staggered positions (the third request lands in a freed slot while the
    other slot is mid-decode at a higher position)."""
    cfg = get_smoke_config(arch)
    params = tf.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 11, 3)]
    eng = ServeEngine(cfg, params, slots=2, cache_len=48)
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new=MAX_NEW))
    done = {r.rid: r.out for r in eng.run()}
    assert set(done) == {0, 1, 2}
    for i, p in enumerate(prompts):
        ref = _solo_decode(cfg, params, i, p, cache_len=48)
        assert done[i] == ref, f"request {i} (len {len(p)}) diverged"


def test_per_slot_positions_advance_independently():
    cfg = get_smoke_config("yi_6b")
    params = tf.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(1)
    eng = ServeEngine(cfg, params, slots=2, cache_len=48)
    eng.submit(Request(0, rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                       max_new=4))
    eng.submit(Request(1, rng.integers(0, cfg.vocab_size, 9).astype(np.int32),
                       max_new=4))
    eng.step()
    # after admission + one decode, each slot sits at its own position
    assert list(eng.pos) == [4 + 1, 9 + 1]


# ---------------------------------------------------------------- admission

def test_submit_rejects_prompt_longer_than_cache():
    cfg = get_smoke_config("yi_6b")
    params = tf.init_params(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, slots=1, cache_len=16)
    long_prompt = np.zeros(17, np.int32)
    with pytest.raises(ValueError, match="exceeds cache_len"):
        eng.submit(Request(0, long_prompt, max_new=1))


def test_submit_rejects_decode_overflow():
    cfg = get_smoke_config("yi_6b")
    params = tf.init_params(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, slots=1, cache_len=16)
    with pytest.raises(ValueError, match="overflows cache_len"):
        eng.submit(Request(0, np.zeros(12, np.int32), max_new=8))
    # exactly filling the cache is fine: positions stop at cache_len - 1
    eng.submit(Request(1, np.zeros(12, np.int32), max_new=5))


def test_sliding_window_engine_accepts_long_prompts():
    """Sliding-window caches wrap; prompts beyond the window are legitimate
    (prefill stores the clipped tail position-aligned to the wrap slots).
    A cache smaller than the window is rejected at construction: every
    wrap would overwrite KV rows still inside the attention window."""
    cfg = get_smoke_config("mixtral_8x22b")
    params = tf.init_params(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, slots=1, cache_len=cfg.sliding_window)
    rng = np.random.default_rng(2)
    eng.submit(Request(0, rng.integers(0, cfg.vocab_size,
                                       cfg.sliding_window + 8).astype(np.int32),
                       max_new=3))
    (done,) = eng.run()
    assert len(done.out) >= 3
    with pytest.raises(ValueError, match="retain the full attention window"):
        ServeEngine(cfg, params, slots=1, cache_len=cfg.sliding_window - 1)


def test_windowed_wrap_decode_matches_refill_oracle():
    """Regression for the wrap-slot alignment: decoding past a clipped
    windowed prefill must match re-prefilling the grown sequence (which
    masks by window with no cache wrap at all). Pre-fix, the compacted
    prefill rows were misaligned with decode's ``pos % cache`` slots, so
    the first wrapped write clobbered live in-window KV."""
    import jax.numpy as jnp

    from repro.numerics.ops import get_numerics

    # dense model + window: MoE top-k routing would amplify float noise
    # between the two computation orders into discrete expert flips
    cfg = get_smoke_config("yi_6b").replace(sliding_window=16)
    params = tf.init_params(jax.random.key(1), cfg)
    num = get_numerics("exact")
    w = cfg.sliding_window
    s = w + 5  # prompt length not a multiple of w: nonzero rotation
    rng = np.random.default_rng(4)
    seq = rng.integers(0, cfg.vocab_size, s).astype(np.int32)
    logits, caches, _ = tf.prefill(params, jnp.asarray(seq)[None], cfg, num, w)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(3):
        logits, caches = tf.decode_step(params, tok, jnp.asarray(s + i, jnp.int32),
                                        caches, cfg, num)
        # oracle: the same sequence grown by the consumed token, re-prefilled
        seq = np.concatenate([seq, [int(tok[0, 0])]]).astype(np.int32)
        ref, _, _ = tf.prefill(params, jnp.asarray(seq)[None], cfg, num,
                               s + i + 1)
        np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                                   np.asarray(ref[:, 0], np.float32),
                                   rtol=2e-2, atol=2e-2)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


# ------------------------------------------------------------- construction

def test_library_with_exact_numerics_raises():
    """A user-passed library must not be silently discarded."""
    from repro.api import InterpLibrary
    from repro.core.table import CoeffMeta, TableDesign

    d = TableDesign(name="recip_stub", in_bits=4, out_bits=5, lookup_bits=2,
                    k=0, degree=1, sq_trunc=0, lin_trunc=0,
                    a=np.zeros(4, np.int64), b=np.zeros(4, np.int64),
                    c=np.zeros(4, np.int64),
                    a_meta=CoeffMeta(1, 0, False), b_meta=CoeffMeta(1, 0, False),
                    c_meta=CoeffMeta(1, 0, False))
    lib = InterpLibrary.from_designs([d], ["recip"])
    cfg = get_smoke_config("yi_6b")  # exact numerics
    params = tf.init_params(jax.random.key(0), cfg)
    with pytest.raises(ValueError, match="never consults"):
        ServeEngine(cfg, params, slots=1, cache_len=16, library=lib)
