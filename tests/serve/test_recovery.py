"""Crash-recoverable serve state (DESIGN.md §14): the admission/token
journal and ``ServeEngine.resume``.

The recovery contract, driven by simulated kill-9s (:class:`Crashed`, a
``BaseException`` raised at named crash points between durability events):

  * after a crash at ANY marker, resuming from the journal and running to
    completion yields, for every request, exactly the token stream an
    uninterrupted run produces (greedy decode is deterministic, and the
    teacher-forced rebuild re-runs the same numerics datapath);
  * completed work is never replayed — requests journaled ``done`` before
    the crash are skipped (counted, not recomputed), and already-emitted
    tokens are only teacher-forced (cache rebuild), never re-emitted or
    re-journaled;
  * a torn final journal line (the append that died mid-crash) is dropped:
    its tokens were never durable and are regenerated identically.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.faults import Crashed, arm_crashpoint, reset_crashpoints
from repro.models import transformer as tf
from repro.serve.engine import Request, ServeEngine
from repro.serve.journal import load_requests

MAX_NEW = 7
LENGTHS = (5, 11, 3)


@pytest.fixture(autouse=True)
def _clean_crashpoints():
    reset_crashpoints()
    yield
    reset_crashpoints()


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("yi_6b")
    params = tf.init_params(jax.random.key(0), cfg)
    return cfg, params


def _prompts(cfg, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in LENGTHS]


def _reference(cfg, params, **kw):
    eng = ServeEngine(cfg, params, slots=2, cache_len=48, **kw)
    for i, p in enumerate(_prompts(cfg)):
        eng.submit(Request(i, p, max_new=MAX_NEW))
    return {r.rid: r.out for r in eng.run()}


def _crash_and_resume(cfg, params, journal, point, after, **kw):
    """Run journaled until ``point`` fires, then resume and finish."""
    eng = ServeEngine(cfg, params, slots=2, cache_len=48, journal=journal,
                      **kw)
    arm_crashpoint(point, after=after)
    with pytest.raises(Crashed):
        for i, p in enumerate(_prompts(cfg)):
            eng.submit(Request(i, p, max_new=MAX_NEW))
        eng.run()
    reset_crashpoints()
    pre = load_requests(journal)  # durable state at the instant of death
    res = ServeEngine.resume(str(journal), cfg, params, slots=2,
                             cache_len=48, **kw)
    res.run()
    return pre, res


def test_journaled_run_reaches_done_states(model, tmp_path):
    cfg, params = model
    jp = tmp_path / "serve.jsonl"
    eng = ServeEngine(cfg, params, slots=2, cache_len=48, journal=str(jp))
    for i, p in enumerate(_prompts(cfg)):
        eng.submit(Request(i, p, max_new=MAX_NEW))
    done = {r.rid: r.out for r in eng.run()}
    states = load_requests(jp)
    assert set(states) == set(done)
    for rid, st in states.items():
        assert st.done and st.error is None
        assert st.out == done[rid]  # the journal IS the token stream


@pytest.mark.parametrize("point,after", [
    ("serve.submit.journaled", 1),
    ("serve.admit.emitted", 1),
    ("serve.tick.emitted", 1),
    ("serve.retire.journaled", 0),
])
def test_crash_anywhere_resumes_to_identical_streams(model, tmp_path, point,
                                                     after):
    """Kill-9 between any two durability events: the resumed run's final
    journal holds bitwise the streams of an uninterrupted run."""
    cfg, params = model
    want = _reference(cfg, params)
    jp = tmp_path / "serve.jsonl"
    pre, res = _crash_and_resume(cfg, params, jp, point, after)
    final = load_requests(jp)
    # every journaled request finishes with the uninterrupted run's exact
    # stream (a crash during submit loses the not-yet-journaled tail of
    # the submit batch — those clients never got an ack and retry)
    assert set(final) == set(pre)
    assert {rid: st.out for rid, st in final.items()} == {
        rid: want[rid] for rid in final}
    assert all(st.done for st in final.values())
    # completed-before-crash work was skipped, not replayed
    n_done_pre = sum(1 for st in pre.values() if not st.in_flight
                     or len(st.out) >= st.max_new)
    assert res.stats["resume_skipped_done"] == n_done_pre
    # teacher-forcing replays exactly the durable prefix of in-flight work
    want_replay = sum(max(0, len(st.out) - 1) for st in pre.values()
                     if st.in_flight and len(st.out) < st.max_new)
    assert res.stats["resume_replay_steps"] == want_replay


def test_mid_stream_crash_suffix_is_bitwise(model, tmp_path):
    """The headline oracle: crash mid-stream with partial emits, resume,
    and assert the regenerated *suffix* is exactly what the uninterrupted
    run emits after the same prefix — not just the same final length."""
    cfg, params = model
    want = _reference(cfg, params)  # tokens are horizon-invariant
    jp = tmp_path / "serve.jsonl"
    # horizon=1 → one decode step per tick, so the crash lands with a
    # genuinely partial stream (a few tokens durable, the rest pending)
    pre, res = _crash_and_resume(cfg, params, jp, "serve.tick.emitted", 2,
                                 horizon=1)
    partial = {rid: st for rid, st in pre.items() if st.in_flight
               and 0 < len(st.out) < st.max_new}
    assert partial, "crash landed at a stream boundary; tune `after`"
    for rid, st in partial.items():
        assert want[rid][:len(st.out)] == st.out  # durable prefix matches
    final = load_requests(jp)
    for rid, st in partial.items():
        assert final[rid].out == want[rid]
        # the suffix came from live decode on the resumed engine
        assert len(final[rid].out) > len(st.out)
    assert res.stats["resumed"] == len(partial)


def test_resume_replays_nothing_when_all_done(model, tmp_path):
    cfg, params = model
    jp = tmp_path / "serve.jsonl"
    eng = ServeEngine(cfg, params, slots=2, cache_len=48, journal=str(jp))
    for i, p in enumerate(_prompts(cfg)):
        eng.submit(Request(i, p, max_new=MAX_NEW))
    eng.run()
    res = ServeEngine.resume(str(jp), cfg, params, slots=2, cache_len=48)
    assert res.stats["resume_skipped_done"] == len(LENGTHS)
    assert res.stats["resume_replay_steps"] == 0
    res.run()
    assert res.stats["decode_steps"] == 0  # nothing left to do


def test_resume_drops_torn_tail_and_regenerates(model, tmp_path):
    cfg, params = model
    want = _reference(cfg, params)
    jp = tmp_path / "serve.jsonl"
    _crash_and_resume(cfg, params, jp, "serve.tick.emitted", 1)
    # tear the tail: a half-written emit that was never fsync'd durable
    with open(jp, "a") as f:
        f.write('{"ev": "emit", "rid": 0, "to')
    res = ServeEngine.resume(str(jp), cfg, params, slots=2, cache_len=48)
    res.run()
    final = load_requests(jp)
    assert {rid: st.out for rid, st in final.items()} == want


def test_fused_interp_engine_recovers_bitwise(tmp_path):
    """Resume replay must run the *fused-numerics* float path the fused
    interp engine decoded with pre-crash — a rebuild through the plain
    per-op glue could diverge by a table ulp and fork the suffix."""
    cfg = get_smoke_config("yi_6b").replace(numerics="interp")
    params = tf.init_params(jax.random.key(0), cfg)
    want = _reference(cfg, params, fused=True)
    jp = tmp_path / "serve.jsonl"
    pre, res = _crash_and_resume(cfg, params, jp, "serve.tick.emitted", 1,
                                 fused=True)
    final = load_requests(jp)
    assert {rid: st.out for rid, st in final.items()} == want
    assert all(st.done for st in final.values())
