"""AOT bucket-table edge cases (DESIGN.md §17, satellite of the sharded
serving tier): exact-boundary prompts, prompts past the largest bucket
(exact-length fallback, counted), and mixed-bucket admission ordering vs
the one-request-at-a-time oracle."""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models import transformer as tf
from repro.serve.aot import BucketTable, pack_sizes, tick_chunk_sizes
from repro.serve.engine import Request, ServeEngine

MAX_NEW = 5
CACHE = 48


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("yi_6b")
    params = tf.init_params(jax.random.key(0), cfg)
    return cfg, params


def _serve(cfg, params, prompts, **kw):
    eng = ServeEngine(cfg, params, cache_len=CACHE, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new=MAX_NEW))
    return {r.rid: r.out for r in eng.run()}, eng


# -- BucketTable semantics -------------------------------------------------

def test_bucket_table_validation():
    with pytest.raises(ValueError):
        BucketTable(())
    with pytest.raises(ValueError):
        BucketTable((16, 8))  # not ascending
    with pytest.raises(ValueError):
        BucketTable((8, 8))  # duplicate
    with pytest.raises(ValueError):
        BucketTable((0, 8))  # non-positive


def test_bucket_for_boundary_and_overflow():
    bt = BucketTable((8, 16, 32))
    assert bt.bucket_for(1) == 8
    assert bt.bucket_for(8) == 8  # exact boundary stays in its bucket
    assert bt.bucket_for(9) == 16
    assert bt.bucket_for(32) == 32
    assert bt.bucket_for(33) is None  # past the largest: fallback


def test_for_cache_clips_and_degenerates():
    assert BucketTable.for_cache(20, (8, 16, 32)).buckets == (8, 16)
    # nothing fits -> one full-cache bucket, never an empty table
    assert BucketTable.for_cache(4, (8, 16)).buckets == (4,)


def test_pack_and_chunk_sizes():
    assert pack_sizes(4, 8) == (1, 2, 4)
    assert pack_sizes(8, 3) == (1, 2)  # capped by the slot pool
    assert tick_chunk_sizes(8) == (1, 2, 4, 8)
    assert tick_chunk_sizes(6) == (1, 2, 4)


# -- engine behavior on the edges ------------------------------------------

def test_prompt_exactly_at_bucket_boundary(setup):
    cfg, params = setup
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (8, 16)]  # both exactly on a bucket edge
    got, eng = _serve(cfg, params, prompts, slots=2, aot_buckets=(8, 16))
    ref, _ = _serve(cfg, params, prompts, slots=2)
    assert got == ref
    assert eng.stats["aot_fallbacks"] == 0
    assert eng.stats["aot_misses"] == 0


def test_prompt_longer_than_largest_bucket_falls_back(setup):
    cfg, params = setup
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (20, 5)]  # 20 > largest bucket 16
    got, eng = _serve(cfg, params, prompts, slots=2, aot_buckets=(8, 16))
    ref, _ = _serve(cfg, params, prompts, slots=2)
    assert got == ref
    assert eng.stats["aot_fallbacks"] == 1  # the oversized prompt, counted
    assert len(got[0]) == MAX_NEW  # and still fully served


def test_mixed_bucket_admission_matches_solo_oracle(setup):
    """Requests landing in different buckets, more requests than slots:
    admission order (queue order -> ascending free slots) must reproduce
    the one-request-at-a-time oracle exactly, packed dispatch or not."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    lens = (5, 16, 3, 9, 30, 8, 2, 11)  # mixes buckets + one fallback
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    got, eng = _serve(cfg, params, prompts, slots=3,
                      aot_buckets=(8, 16), max_pack=4)
    assert eng.stats["packed_requests"] > 0
    for i, p in enumerate(prompts):
        solo, _ = _serve(cfg, params, [p], slots=1)
        assert got[i] == solo[0], f"request {i} (len {len(p)}) diverged"


def test_warm_engine_steady_state_has_zero_misses(setup):
    cfg, params = setup
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (4, 7, 12, 15)]
    got, eng = _serve(cfg, params, prompts, slots=4, aot_buckets=(8, 16))
    assert eng.stats["aot_misses"] == 0
    assert eng.stats["aot_hits"] > 0
    assert sum(len(v) for v in got.values()) == MAX_NEW * len(prompts)
