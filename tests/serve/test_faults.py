"""Chaos suite: the serving-robustness layer under injected faults
(DESIGN.md §14).

Every fault is deterministic (seeded injectors from :mod:`repro.faults`),
so each scenario is a reproducible experiment with an exact expected
outcome:

  * admission control — bounded-queue backpressure, out-of-vocab prompt
    rejection, and the regression for unbounded queue growth under
    sustained over-admission;
  * deadlines — queued and in-flight expiry against an injectable clock;
  * the tick watchdog — NaN'd, dropped and stalled ticks retire poisoned
    slots with structured errors and walk the degradation ladder;
  * ROM integrity — a seeded single-bit flip of the resident coefficient
    ROM is caught by ``verify_resident()`` and degrades the engine to
    exact numerics, whose tokens must be identical to an uncorrupted
    exact-numerics run (the ISSUE-7 acceptance oracle).
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.api import LibraryIntegrityError, default_explorer
from repro.configs.base import get_smoke_config
from repro.faults import (FaultClock, TickFaultInjector, flip_rom_bit,
                          poison_prompt, reset_crashpoints)
from repro.models import transformer as tf
from repro.serve.engine import Rejected, Request, ServeEngine

MAX_NEW = 5


@pytest.fixture(autouse=True)
def _clean_crashpoints():
    reset_crashpoints()
    yield
    reset_crashpoints()


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("yi_6b")
    params = tf.init_params(jax.random.key(0), cfg)
    return cfg, params


def _prompts(cfg, lengths, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in lengths]


# ------------------------------------------------------------ admission

def test_queue_full_rejection(model):
    cfg, params = model
    eng = ServeEngine(cfg, params, slots=1, cache_len=32, max_queue=2)
    for i, p in enumerate(_prompts(cfg, (4, 4))):
        eng.submit(Request(i, p, max_new=2))
    with pytest.raises(Rejected, match="queue full") as ei:
        eng.submit(Request(2, _prompts(cfg, (4,))[0], max_new=2))
    assert ei.value.reason == "queue_full"
    assert isinstance(ei.value, ValueError)  # pre-ISSUE-7 callers survive
    assert eng.stats["rejected"] == 1


def test_queue_stays_bounded_under_sustained_over_admission(model):
    """Regression (ISSUE 7 satellite): with backpressure on, sustained
    over-admission cannot grow the queue past ``max_queue`` — every
    overflow is a typed rejection, not silent unbounded growth."""
    cfg, params = model
    eng = ServeEngine(cfg, params, slots=1, cache_len=32, max_queue=3)
    prompt = _prompts(cfg, (4,))[0]
    rejected = 0
    for i in range(50):
        try:
            eng.submit(Request(i, prompt, max_new=2))
        except Rejected as e:
            assert e.reason == "queue_full"
            rejected += 1
        assert len(eng.queue) <= 3
    assert rejected == 50 - 3
    assert eng.stats["rejected"] == rejected
    # the engine still drains the admitted work
    done = eng.run()
    assert len(done) == 3


def test_poisoned_prompt_rejected(model):
    cfg, params = model
    eng = ServeEngine(cfg, params, slots=1, cache_len=32)
    bad = poison_prompt(_prompts(cfg, (6,))[0], cfg.vocab_size, seed=3)
    with pytest.raises(Rejected, match="outside vocab") as ei:
        eng.submit(Request(0, bad, max_new=2))
    assert ei.value.reason == "bad_prompt"
    with pytest.raises(Rejected):
        eng.submit(Request(1, np.zeros(0, np.int32), max_new=2))


def test_overflow_rejections_are_typed(model):
    cfg, params = model
    eng = ServeEngine(cfg, params, slots=1, cache_len=16)
    with pytest.raises(Rejected) as ei:
        eng.submit(Request(0, np.zeros(17, np.int32), max_new=1))
    assert ei.value.reason == "prompt_overflow"
    with pytest.raises(Rejected) as ei:
        eng.submit(Request(1, np.zeros(12, np.int32), max_new=8))
    assert ei.value.reason == "decode_overflow"


# ------------------------------------------------------------- deadlines

def test_deadline_expires_queued_request(model):
    cfg, params = model
    clk = FaultClock()
    eng = ServeEngine(cfg, params, slots=1, cache_len=32, clock=clk,
                      deadline_s=10.0)
    p0, p1 = _prompts(cfg, (4, 4))
    eng.submit(Request(0, p0, max_new=2))
    eng.submit(Request(1, p1, max_new=2))  # waits behind request 0
    clk.advance(11.0)  # both deadlines pass before any decode
    eng.run()
    # queued work past its deadline fails structurally, never decodes
    assert all(r.error == "deadline_exceeded" for r in eng.failed)
    assert eng.stats["expired"] == len(eng.failed) > 0


def test_deadline_expires_in_flight_request(model):
    cfg, params = model
    clk = FaultClock()
    eng = ServeEngine(cfg, params, slots=1, cache_len=64, clock=clk)
    (p,) = _prompts(cfg, (4,))
    eng.submit(Request(0, p, max_new=30, deadline=5.0))
    eng.step()  # admitted and decoding
    assert eng.req[0] is not None
    clk.advance(6.0)
    eng.step()
    assert eng.req[0] is None  # slot freed
    (failed,) = eng.failed
    assert failed.error == "deadline_exceeded"
    assert eng.stats["expired"] == 1


def test_submit_past_deadline_rejected(model):
    cfg, params = model
    clk = FaultClock(start=100.0)
    eng = ServeEngine(cfg, params, slots=1, cache_len=32, clock=clk)
    with pytest.raises(Rejected) as ei:
        eng.submit(Request(0, _prompts(cfg, (4,))[0], max_new=2,
                           deadline=99.0))
    assert ei.value.reason == "deadline"


# ---------------------------------------------------------- tick watchdog

def test_nan_tick_retires_slot_with_structured_error(model):
    """A poisoned fused tick (sentinel tripped) must retire the slot with a
    structured error — its garbage chunk is never appended to the stream —
    and count a watchdog trip."""
    cfg, params = model
    eng = ServeEngine(cfg, params, slots=2, cache_len=48, fused=True,
                      watchdog_limit=100)  # don't degrade in this test
    inj = TickFaultInjector("nan", every_n=1, limit=1).install(eng)
    for i, p in enumerate(_prompts(cfg, (5, 7))):
        eng.submit(Request(i, p, max_new=MAX_NEW))
    eng.run()
    assert inj.injected == 1
    assert eng.stats["watchdog_trips"] == 1
    assert len(eng.failed) == 2  # both live slots were in the poisoned tick
    for r in eng.failed:
        assert r.error == "non_finite_output"
        assert len(r.out) == 1  # only the admission token, no garbage chunk


def test_repeated_nan_ticks_degrade_fused_to_serial(model):
    cfg, params = model
    eng = ServeEngine(cfg, params, slots=1, cache_len=64, fused=True,
                      watchdog_limit=2)
    TickFaultInjector("nan", every_n=1, limit=2).install(eng)
    rng = np.random.default_rng(0)
    for i in range(4):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 4).astype(
            np.int32), max_new=3))
    eng.run()
    assert eng.stats["watchdog_trips"] == 2
    assert eng.stats["degradations"] == 1
    assert eng.fused is False  # fused -> serial rung
    assert any(f["action"] == "fused->serial" for f in eng.faults)
    # post-degradation the engine still completes the remaining requests
    assert len(eng.finished) == 2
    assert all(len(r.out) == 3 for r in eng.finished)


def test_degraded_interp_engine_uses_guarded_numerics():
    cfg = get_smoke_config("yi_6b").replace(numerics="interp")
    params = tf.init_params(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, slots=1, cache_len=48, fused=True,
                      watchdog_limit=1)
    TickFaultInjector("nan", every_n=1, limit=1).install(eng)
    rng = np.random.default_rng(1)
    for i in range(2):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 4).astype(
            np.int32), max_new=3))
    eng.run()
    # the serial rung of an interp engine serves through the domain guard
    assert eng.cfg.numerics == "interp-guarded"
    assert eng.numerics.__class__.__name__ == "GuardedNumerics"
    assert len(eng.finished) == 1


def test_dropped_tick_makes_no_silent_progress(model):
    cfg, params = model
    eng = ServeEngine(cfg, params, slots=1, cache_len=48, fused=True,
                      watchdog_limit=100)
    inj = TickFaultInjector("drop", every_n=1, limit=1).install(eng)
    (p,) = _prompts(cfg, (5,))
    eng.submit(Request(0, p, max_new=MAX_NEW))
    eng.run()
    assert inj.injected == 1
    # the dropped tick's zero tokens were never streamed as real output
    (failed,) = eng.failed
    assert failed.error == "non_finite_output"
    assert len(failed.out) == 1


def test_stalled_tick_trips_watchdog(model):
    cfg, params = model
    clk = FaultClock()
    eng = ServeEngine(cfg, params, slots=1, cache_len=48, fused=True,
                      clock=clk, max_tick_s=0.5, watchdog_limit=100)
    TickFaultInjector("delay", every_n=1, delay_s=2.0, limit=1).install(eng)
    (p,) = _prompts(cfg, (5,))
    eng.submit(Request(0, p, max_new=MAX_NEW))
    eng.run()
    assert eng.stats["watchdog_trips"] == 1
    assert any(f["reason"] == "stalled_tick" for f in eng.faults)
    # a stall poisons no data: the request still completed
    (done,) = eng.finished
    assert len(done.out) == MAX_NEW


# ------------------------------------------------------------ ROM integrity

def test_flipped_rom_bit_detected_by_verify_resident():
    lib = default_explorer().compile()
    lib.verify_resident()  # healthy baseline passes
    flipped = flip_rom_bit(lib, seed=11)
    with pytest.raises(LibraryIntegrityError, match="checksum"):
        flipped.verify_resident()
    # a different seed flips a different bit; still caught
    with pytest.raises(LibraryIntegrityError):
        flip_rom_bit(lib, seed=12).verify_resident()


def test_corrupt_rom_degrades_to_exact_with_identical_tokens():
    """The ISSUE-7 acceptance oracle: an engine handed a silently corrupted
    library detects it at construction, degrades straight to exact
    numerics, and its token streams are bitwise identical to an engine
    built with exact numerics and no library."""
    cfg = get_smoke_config("yi_6b").replace(numerics="interp")
    params = tf.init_params(jax.random.key(0), cfg)
    flipped = flip_rom_bit(default_explorer().compile(), seed=5)
    eng = ServeEngine(cfg, params, slots=2, cache_len=48, fused=True,
                      library=flipped)
    assert eng.stats["rom_faults"] == 1
    assert eng.cfg.numerics == "exact" and eng.library is None
    assert any(f["reason"] == "rom_integrity" for f in eng.faults)

    ref = ServeEngine(get_smoke_config("yi_6b"), params, slots=2,
                      cache_len=48, fused=True)
    prompts = _prompts(get_smoke_config("yi_6b"), (5, 11, 3))
    for e in (eng, ref):
        for i, p in enumerate(prompts):
            e.submit(Request(i, p, max_new=MAX_NEW))
    got = {r.rid: r.out for r in eng.run()}
    want = {r.rid: r.out for r in ref.run()}
    assert got == want


def test_periodic_rom_verify_catches_runtime_corruption():
    cfg = get_smoke_config("yi_6b").replace(numerics="interp")
    params = tf.init_params(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, slots=1, cache_len=64, fused=True,
                      verify_rom_every=1)
    rng = np.random.default_rng(2)
    eng.submit(Request(0, rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                       max_new=12))
    eng.step(2)
    # the resident ROM goes bad mid-serve
    eng.library = flip_rom_bit(eng.library, seed=9)
    eng.step(2)
    assert eng.stats["rom_faults"] == 1
    assert eng.cfg.numerics == "exact" and eng.library is None
    eng.run()  # finishes on the exact rung
    (done,) = eng.finished
    assert len(done.out) == 12
