"""The fused serve tick (ISSUE 5): one donated-buffer dispatch per chunk of
decode steps, greedy argmax inside the program, library-bound fused kernels
for interp numerics.

Oracles: (1) the fused engine against the serial per-op path — bitwise
token equality with exact numerics (same decode program, only the dispatch
granularity changes); (2) mixed-length continuous batching through the
fused engine against the PR-4 one-request-at-a-time oracle, interp
numerics end to end; (3) buffer identity across ticks — donation means the
KV-cache pool is updated in place, not copied.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models import transformer as tf
from repro.serve.engine import Request, ServeEngine

MAX_NEW = 6


def _mk(cfg, params, *, fused, slots=2, cache_len=48, horizon=8, lib=None):
    return ServeEngine(cfg, params, slots=slots, cache_len=cache_len,
                       library=lib, fused=fused, horizon=horizon)


def _prompts(cfg, lengths, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in lengths]


def test_fused_tokens_bitwise_equal_serial_exact_numerics():
    """Exact numerics: the fused tick runs the same decode program as the
    serial path (scan granularity only) — token streams are identical."""
    cfg = get_smoke_config("yi_6b")
    params = tf.init_params(jax.random.key(0), cfg)
    outs = {}
    for fused in (False, True):
        eng = _mk(cfg, params, fused=fused)
        for i, p in enumerate(_prompts(cfg, (5, 11, 3))):
            eng.submit(Request(i, p, max_new=MAX_NEW))
        outs[fused] = {r.rid: r.out for r in eng.run()}
    assert outs[True] == outs[False]


@pytest.mark.parametrize("arch", ["yi_6b", "minicpm3_4b"])
def test_fused_mixed_length_batching_matches_solo_oracle(arch):
    """The PR-4 oracle through the fused engine with interp numerics: the
    full fused datapath (library kernels + chunked tick) must make batching
    invisible — every request decodes exactly as if served alone."""
    cfg = get_smoke_config(arch).replace(numerics="interp")
    params = tf.init_params(jax.random.key(0), cfg)
    prompts = _prompts(cfg, (5, 11, 3))
    eng = _mk(cfg, params, fused=True)
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new=MAX_NEW))
    done = {r.rid: r.out for r in eng.run()}
    assert set(done) == {0, 1, 2}
    for i, p in enumerate(prompts):
        solo = _mk(cfg, params, fused=True, slots=1)
        solo.submit(Request(i, p, max_new=MAX_NEW))
        (ref,) = solo.run()
        assert done[i] == ref.out, f"request {i} (len {len(p)}) diverged"


def test_fused_horizon_chunking_is_invisible():
    """Tokens are independent of the chunk size (horizon 1 vs 8) and of
    stepping manually one decode at a time."""
    cfg = get_smoke_config("yi_6b").replace(numerics="interp")
    params = tf.init_params(jax.random.key(0), cfg)
    prompts = _prompts(cfg, (4, 9))
    outs = []
    for horizon in (1, 3, 8):
        eng = _mk(cfg, params, fused=True, horizon=horizon)
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, max_new=MAX_NEW))
        outs.append({r.rid: r.out for r in eng.run()})
    assert outs[0] == outs[1] == outs[2]


def test_fused_tick_donates_cache_buffers():
    """Donation contract (satellite): across ticks the KV-cache pool leaves
    are updated in place — the output arrays reuse the input buffers, so a
    decode tick never copies the pool."""
    cfg = get_smoke_config("yi_6b")
    params = tf.init_params(jax.random.key(0), cfg)
    eng = _mk(cfg, params, fused=True, slots=2, cache_len=64)
    eng.submit(Request(0, _prompts(cfg, (5,))[0], max_new=24))
    eng.step(4)  # admission + first chunk (fresh buffers land here)
    ptrs = [leaf.unsafe_buffer_pointer() for leaf in jax.tree.leaves(eng.caches)]
    eng.step(4)
    after = [leaf.unsafe_buffer_pointer() for leaf in jax.tree.leaves(eng.caches)]
    assert ptrs == after, "cache pool was copied despite donation"
    # slot-state buffers (token / position vectors) are donated too
    tok_ptr = eng._tok_dev.unsafe_buffer_pointer()
    pos_ptr = eng._pos_dev.unsafe_buffer_pointer()
    eng.step(4)
    assert eng._tok_dev.unsafe_buffer_pointer() == tok_ptr
    assert eng._pos_dev.unsafe_buffer_pointer() == pos_ptr


def test_fused_dispatch_counts_collapse():
    """The serve-tick contract: the serial path pays >= 2 program dispatches
    per decoded token; the fused path amortizes 1 dispatch + 1 transfer
    over the whole chunk."""
    cfg = get_smoke_config("yi_6b")
    params = tf.init_params(jax.random.key(0), cfg)
    stats = {}
    for fused in (False, True):
        eng = _mk(cfg, params, fused=fused, slots=2, horizon=8)
        for i, p in enumerate(_prompts(cfg, (5, 9))):
            eng.submit(Request(i, p, max_new=9))
        eng.run()
        stats[fused] = dict(eng.stats)
    serial, fused_s = stats[False], stats[True]
    assert serial["dispatches"] == 2 * serial["decode_steps"]
    assert fused_s["dispatches"] == fused_s["ticks"]
    assert fused_s["decode_steps"] > 2 * fused_s["ticks"]  # real amortization
    assert fused_s["dispatches"] < serial["dispatches"] / 4


def test_interp_fused_backend_name_serves():
    """The explicit "interp-fused" cfg backend name drives the engine like
    "interp": library auto-compiled, admission/tick usable."""
    cfg = get_smoke_config("yi_6b").replace(numerics="interp-fused")
    params = tf.init_params(jax.random.key(0), cfg)
    eng = _mk(cfg, params, fused=True)
    eng.submit(Request(0, _prompts(cfg, (5,))[0], max_new=4))
    (done,) = eng.run()
    assert len(done.out) >= 4
    # and it decodes identically to numerics="interp" on a fused engine
    eng2 = _mk(get_smoke_config("yi_6b").replace(numerics="interp"), params,
               fused=True)
    eng2.submit(Request(0, _prompts(cfg, (5,))[0], max_new=4))
    (ref,) = eng2.run()
    assert done.out == ref.out


def test_fused_engine_windowed_wrap():
    """Sliding-window engine through the fused tick: long prompt, wrapped
    decode — equality with the solo oracle still holds."""
    cfg = get_smoke_config("mixtral_8x22b")
    params = tf.init_params(jax.random.key(0), cfg)
    w = cfg.sliding_window
    prompts = _prompts(cfg, (w + 8, 3), seed=2)
    eng = _mk(cfg, params, fused=True, cache_len=w)
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new=4))
    done = {r.rid: r.out for r in eng.run()}
    for i, p in enumerate(prompts):
        solo = _mk(cfg, params, fused=True, slots=1, cache_len=w)
        solo.submit(Request(i, p, max_new=4))
        (ref,) = solo.run()
        assert done[i] == ref.out
