"""Async host pipeline (DESIGN.md §17): tokens and journal bitwise equal
to the synchronous engine, ordered fsync'd writes, watchdog semantics
preserved, clean shutdown, and worker-error surfacing."""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models import transformer as tf
from repro.serve.engine import Request, ServeEngine
from repro.serve.journal import load_requests
from repro.serve.pipeline import HostPipeline

MAX_NEW = 6
CACHE = 48


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("yi_6b")
    params = tf.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 11, 3, 16, 9, 2)]
    return cfg, params, prompts


def _serve(cfg, params, prompts, **kw):
    eng = ServeEngine(cfg, params, slots=3, cache_len=CACHE, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new=MAX_NEW))
    out = {r.rid: r.out for r in eng.run()}
    eng.close()
    return out, eng


def test_async_tokens_match_sync(setup):
    cfg, params, prompts = setup
    ref, _ = _serve(cfg, params, prompts)
    got, eng = _serve(cfg, params, prompts, async_host=True)
    assert got == ref
    assert eng.stats["async_tokens"] > 0
    assert eng.pipeline is None  # close() tore it down


def test_async_with_buckets_matches_sync(setup):
    cfg, params, prompts = setup
    ref, _ = _serve(cfg, params, prompts)
    got, eng = _serve(cfg, params, prompts, async_host=True,
                      aot_buckets=(8, 16))
    assert got == ref
    assert eng.stats["aot_misses"] == 0


def test_async_journal_replays_like_sync(setup, tmp_path):
    """The worker thread carries every journal write in queue order, so an
    async engine's journal is byte-for-byte replayable by the same resume
    path the sync engine uses — and holds the same durable streams."""
    cfg, params, prompts = setup
    sj, aj = str(tmp_path / "sync.jnl"), str(tmp_path / "async.jnl")
    ref, _ = _serve(cfg, params, prompts, journal=sj)
    got, _ = _serve(cfg, params, prompts, journal=aj, async_host=True)
    assert got == ref
    sync_states, async_states = load_requests(sj), load_requests(aj)
    assert set(sync_states) == set(async_states)
    for rid, st in sync_states.items():
        ast = async_states[rid]
        assert ast.out == st.out, f"request {rid} journal diverged"
        assert ast.in_flight == st.in_flight  # all done-marked
    res = ServeEngine.resume(aj, cfg, params, slots=3, cache_len=CACHE)
    assert res.stats["resume_skipped_done"] == len(prompts)


def test_async_requires_fused(setup):
    cfg, params, _ = setup
    with pytest.raises(ValueError, match="fused"):
        ServeEngine(cfg, params, slots=2, cache_len=CACHE, fused=False,
                    async_host=True)


def test_async_watchdog_still_fails_poisoned_slots(setup):
    """The ok-sentinel download stays synchronous on the tick path: a NaN
    fault trips the per-slot watchdog with async bookkeeping on, and the
    poisoned chunk is never handed to the worker (no garbage tokens)."""
    from repro.faults import TickFaultInjector

    cfg, params, prompts = setup
    eng = ServeEngine(cfg, params, slots=2, cache_len=CACHE,
                      async_host=True, watchdog_limit=100)
    inj = TickFaultInjector("nan", every_n=1, limit=1).install(eng)
    for i, p in enumerate(prompts[:2]):
        eng.submit(Request(i, p, max_new=MAX_NEW))
    eng.run()
    eng.close()
    assert inj.injected == 1
    assert eng.stats["watchdog_trips"] == 1
    assert len(eng.failed) == 2
    for r in eng.failed:
        assert r.error == "non_finite_output"
        assert len(r.out) == 1  # admission token only, no garbage chunk


def test_degrade_to_serial_closes_pipeline(setup):
    """The serial rung has no fused tick for the worker to trail — the
    ladder drains and drops the pipeline before flipping, and the engine
    finishes the surviving requests synchronously."""
    from repro.faults import TickFaultInjector

    cfg, params, prompts = setup
    eng = ServeEngine(cfg, params, slots=1, cache_len=CACHE,
                      async_host=True, watchdog_limit=2)
    TickFaultInjector("nan", every_n=1, limit=2).install(eng)
    for i, p in enumerate(prompts[:4]):
        eng.submit(Request(i, p, max_new=3))
    eng.run()
    assert eng.stats["degradations"] == 1
    assert eng.fused is False
    assert eng.pipeline is None  # closed before the serial rung took over
    assert len(eng.finished) == 2
    assert all(len(r.out) == 3 for r in eng.finished)


def test_pipeline_surfaces_worker_errors():
    class BoomJournal:
        def emit(self, rid, toks):
            raise RuntimeError("disk full")

    pipe = HostPipeline(journal=BoomJournal())
    req = Request(0, np.zeros(1, np.int32), max_new=4)
    pipe.emit_admit(((0, req),), np.asarray([7], np.int32))
    with pytest.raises(RuntimeError, match="disk full"):
        pipe.flush()
    pipe.close()


def test_pipeline_close_is_idempotent_and_rejects_after():
    pipe = HostPipeline()
    pipe.close()
    pipe.close()
    req = Request(0, np.zeros(1, np.int32), max_new=1)
    with pytest.raises(RuntimeError, match="closed"):
        pipe.emit_admit(((0, req),), np.asarray([1], np.int32))


def test_pipeline_backpressure_bounded_queue():
    pipe = HostPipeline(depth=2)
    req = Request(0, np.zeros(1, np.int32), max_new=64)
    for _ in range(16):  # far past depth: put() blocks, never grows
        pipe.emit_admit(((0, req),), np.asarray([1], np.int32))
    pipe.flush()
    assert len(req.out) == 16
    assert pipe.drain_stats()["tokens"] == 16
    pipe.close()
