"""Plan-carrying serve engines (ISSUE 9): uniform bitwise identity, mixed
per-layer slots through the fused tick, and the per-layer degradation rung.

Oracles:

  * a uniform ``NumericsPlan`` must reproduce the homogeneous engine
    *token-bitwise* — the plan machinery (grouped scan, interned backends,
    slot-keyed libraries) is pure plumbing in the degenerate case;
  * a genuinely mixed plan (different slots on different layers) serves
    through the same fused tick, compiling one library per slot;
  * a poisoned slot library downgrades exactly the layers reading it —
    the engine stays fused, unaffected layers keep their interp backends,
    and ``stats["degradations"]`` / the fault log attribute the layer.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.faults import TickFaultInjector, flip_rom_bit
from repro.models import transformer as tf
from repro.plan import LayerAssign, NumericsPlan, SiteAssign, SlotSpec
from repro.serve.engine import Request, ServeEngine

MAX_NEW = 6


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("yi_6b")
    return cfg, tf.init_params(jax.random.key(0), cfg)


def _prompts(cfg, lengths, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in lengths]


def _mk(cfg, params, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("cache_len", 48)
    kw.setdefault("fused", True)
    return ServeEngine(cfg, params, **kw)


def _run(eng, cfg, params=None, lengths=(5, 11, 3)):
    for i, p in enumerate(_prompts(cfg, lengths)):
        eng.submit(Request(i, p, max_new=MAX_NEW))
    return {r.rid: r.out for r in eng.run()}


def _two_slot_plan(n_layers):
    """Layer 0 interp-fused on its own R5 slot; every other layer and
    ``rest`` interp-fused on the default slot."""
    r5 = SlotSpec(lookup_bits=5)
    first = LayerAssign(SiteAssign("interp-fused", r5),
                        SiteAssign("interp-fused", r5),
                        SiteAssign("interp-fused", r5))
    rest = LayerAssign(SiteAssign("interp-fused"), SiteAssign("interp-fused"),
                       SiteAssign("interp-fused"))
    return NumericsPlan(layers=(first,) + (rest,) * (n_layers - 1), rest=rest)


def test_uniform_plan_bitwise_identical_to_homogeneous_engine(model):
    """The ISSUE-9 acceptance oracle: serving under the degenerate uniform
    plan produces token streams exactly equal to the homogeneous fused
    interp engine — same libraries, same traces, zero numerics drift."""
    cfg, params = model
    plan_cfg = cfg.replace(
        plan=NumericsPlan.uniform("interp-fused", cfg.n_layers))
    interp_cfg = cfg.replace(numerics="interp")
    got = _run(_mk(plan_cfg, params), cfg)
    want = _run(_mk(interp_cfg, params), cfg)
    assert got == want


def test_uniform_exact_plan_matches_exact_engine(model):
    cfg, params = model
    plan_cfg = cfg.replace(plan=NumericsPlan.uniform("exact", cfg.n_layers))
    got = _run(_mk(plan_cfg, params), cfg)
    want = _run(_mk(cfg, params), cfg)
    assert got == want


def test_mixed_plan_serves_with_one_library_per_slot(model):
    cfg, params = model
    plan = _two_slot_plan(cfg.n_layers)
    eng = _mk(cfg.replace(plan=plan), params)
    assert sorted(eng.library) == ["R5", "default"]
    done = _run(eng, cfg)
    assert set(done) == {0, 1, 2}
    assert all(len(out) == MAX_NEW for out in done.values())
    assert eng.stats["degradations"] == {}


def test_mixed_plan_slots_are_live(model):
    """The per-layer slots are real: R5 tables on layer 0 change the
    prefill logits relative to the all-default uniform plan (coarser
    tables, coarser softmax) — if these matched bitwise, the slot
    threading would be dead code. (Greedy argmax tokens may still agree —
    interpolation error rarely crosses a decision boundary on the smoke
    model — so the oracle is the logits, not the token stream.)"""
    from repro.numerics.ops import get_numerics

    cfg, params = model
    tokens = np.asarray([_prompts(cfg, (8,))[0]])
    logits = {}
    for name, plan in (("mixed", _two_slot_plan(cfg.n_layers)),
                       ("uniform",
                        NumericsPlan.uniform("interp-fused", cfg.n_layers))):
        pcfg = cfg.replace(plan=plan)
        out, _, _ = tf.prefill(params, tokens, pcfg, get_numerics(pcfg), 16)
        logits[name] = np.asarray(out)
    assert not np.array_equal(logits["mixed"], logits["uniform"])


def test_poisoned_slot_downgrades_only_its_layers(model):
    """The per-layer degradation rung: a flipped bit in the R5 slot ROM
    (read only by layer 0) plus one poisoned tick retires layer 0's sites
    to exact; layer 1+ keep their fused interp backends, the engine stays
    fused, and the fault log + degradation stats name the layer."""
    cfg, params = model
    plan = _two_slot_plan(cfg.n_layers)
    eng = _mk(cfg.replace(plan=plan), params)
    eng.library["R5"] = flip_rom_bit(eng.library["R5"], seed=3)
    TickFaultInjector("nan", every_n=1, limit=1).install(eng)
    for i, p in enumerate(_prompts(cfg, (5, 7))):
        eng.submit(Request(i, p, max_new=MAX_NEW))
    eng.run()
    # the poisoned tick failed the in-flight requests (sentinel tripped)...
    assert len(eng.failed) == 2
    assert all(r.error == "non_finite_output" for r in eng.failed)
    # ...and the integrity sweep pinned the corruption on the R5 slot
    assert eng.stats["rom_faults"] == 1
    assert eng.stats["degradations"] == {"0": 1}
    fault = next(f for f in eng.faults if f["reason"] == "rom_integrity")
    assert fault["action"] == "slots:R5->exact"
    assert fault["layers"] == ("0",)
    new_plan = eng.cfg.plan
    assert new_plan.layers[0].uniform_backend == "exact"
    assert new_plan.layers[1].uniform_backend == "interp-fused"
    assert new_plan.rest.uniform_backend == "interp-fused"
    assert eng.fused is True
    assert sorted(eng.library) == ["default"]
    # the degraded engine still serves fresh work end to end
    for i, p in enumerate(_prompts(cfg, (4, 6), seed=9)):
        eng.submit(Request(10 + i, p, max_new=3))
    done = {r.rid: r.out for r in eng.run()}
    assert set(done) == {10, 11}
    assert all(len(out) == 3 for out in done.values())


def test_plan_engine_serial_rung_guards_interp_sites(model):
    """Repeated watchdog trips walk the plan-level fused -> serial rung:
    every interp site drops to the guarded datapath, exact sites stay."""
    cfg, params = model
    plan = _two_slot_plan(cfg.n_layers)
    eng = _mk(cfg.replace(plan=plan), params, slots=1, cache_len=64,
              watchdog_limit=2)
    TickFaultInjector("nan", every_n=1, limit=2).install(eng)
    rng = np.random.default_rng(0)
    for i in range(4):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 4).astype(
            np.int32), max_new=3))
    eng.run()
    assert eng.fused is False
    assert eng.stats["degradations"] == {"engine": 1}
    for _label, _site, a in eng.cfg.plan.assignments():
        assert a.backend == "interp-guarded"
    assert len(eng.finished) == 2
