"""StudyStore: fsync'd journal appends, torn-write recovery, compaction."""
from __future__ import annotations

import json

import pytest

from repro.dse.store import StoreCorrupt, StudyStore
from repro.dse.trial import TrialParams, TrialRecord


def _rec(i: int) -> TrialRecord:
    p = TrialParams(kind="recip", lookup_bits=4 + i, target="asic")
    return TrialRecord(p, "ok",
                       metrics={"area": float(10 * i), "delay": 2.0,
                                "accuracy_margin": i},
                       objectives=[float(10 * i), 2.0, -float(i)],
                       timing={"eval_s": 0.1 * i})


def test_roundtrip(tmp_path):
    with StudyStore(tmp_path / "s") as store:
        for i in range(4):
            store.append(_rec(i))
    loaded = StudyStore(tmp_path / "s").load()
    assert len(loaded) == 4
    for i in range(4):
        rec = loaded[_rec(i).params.key]
        assert rec.metrics == _rec(i).metrics
        assert rec.objectives == _rec(i).objectives
        assert rec.ok


def test_appends_are_fsynced(tmp_path, monkeypatch):
    # the durable-append machinery is shared (repro.util.journal): patch
    # the fsync where it actually happens
    import repro.util.journal as journal_mod

    calls = []
    real_fsync = journal_mod.os.fsync
    monkeypatch.setattr(journal_mod.os, "fsync",
                        lambda fd: (calls.append(fd), real_fsync(fd))[1])
    with StudyStore(tmp_path / "s") as store:
        store.append(_rec(0))
        store.append(_rec(1))
    assert len(calls) == 2  # one fsync per durable append


def test_torn_tail_without_newline_dropped(tmp_path):
    store = StudyStore(tmp_path / "s")
    for i in range(3):
        store.append(_rec(i))
    store.close()
    # simulate a kill mid-append: a partial record with no newline
    with open(store.journal_path, "a") as f:
        f.write('{"schema": 1, "key": "torn", "par')
    reloaded = StudyStore(tmp_path / "s")
    assert len(reloaded.load()) == 3
    assert reloaded.torn_tail_drops == 1
    # appending after the torn tail truncates the fragment first: the new
    # record must not merge into it
    reloaded.append(_rec(7))
    assert len(StudyStore(tmp_path / "s").load()) == 4


def test_unterminated_but_complete_record_kept(tmp_path):
    store = StudyStore(tmp_path / "s")
    store.append(_rec(0))
    store.append(_rec(1))
    store.close()
    # strip only the final newline: the record itself is complete
    data = store.journal_path.read_bytes()
    store.journal_path.write_bytes(data[:-1])
    reloaded = StudyStore(tmp_path / "s")
    assert len(reloaded.load()) == 2  # not dropped
    reloaded.append(_rec(2))  # trim path terminates, never truncates it
    assert len(StudyStore(tmp_path / "s").load()) == 3


def test_torn_final_line_with_newline_dropped(tmp_path):
    store = StudyStore(tmp_path / "s")
    for i in range(2):
        store.append(_rec(i))
    store.close()
    with open(store.journal_path, "a") as f:
        f.write('{"schema": 1, "key": "half\n')
    reloaded = StudyStore(tmp_path / "s")
    assert len(reloaded.load()) == 2
    assert reloaded.torn_tail_drops == 1


def test_mid_file_corruption_raises(tmp_path):
    store = StudyStore(tmp_path / "s")
    for i in range(3):
        store.append(_rec(i))
    store.close()
    lines = store.journal_path.read_text().splitlines()
    lines[1] = lines[1][:10]  # damage a NON-tail line
    store.journal_path.write_text("\n".join(lines) + "\n")
    with pytest.raises(StoreCorrupt):
        StudyStore(tmp_path / "s").load()


def test_compaction(tmp_path):
    store = StudyStore(tmp_path / "s")
    for i in range(5):
        store.append(_rec(i))
    before = store.load()
    store.compact()
    assert store.snapshot_path.exists()
    assert store.journal_path.read_text() == ""
    assert not list(store.root.glob("*.tmp"))
    after = StudyStore(tmp_path / "s").load()
    assert after.keys() == before.keys()
    assert all(after[k].to_dict() == before[k].to_dict() for k in after)
    # appends keep working post-compaction and merge with the snapshot
    store.append(_rec(9))
    assert len(StudyStore(tmp_path / "s").load()) == 6


def test_crash_between_snapshot_and_journal_reset_dedups(tmp_path):
    store = StudyStore(tmp_path / "s")
    for i in range(3):
        store.append(_rec(i))
    journal_bytes = store.journal_path.read_text()
    store.compact()
    # crash window: snapshot renamed, journal reset lost — records doubled
    store.journal_path.write_text(journal_bytes)
    assert len(StudyStore(tmp_path / "s").load()) == 3


def test_snapshot_schema_guard(tmp_path):
    store = StudyStore(tmp_path / "s")
    store.append(_rec(0))
    store.compact()
    doc = json.loads(store.snapshot_path.read_text())
    doc["schema"] = 99
    store.snapshot_path.write_text(json.dumps(doc))
    with pytest.raises(StoreCorrupt):
        StudyStore(tmp_path / "s").load()
