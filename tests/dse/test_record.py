"""Schema-versioned snapshots (repro.dse.record) + the dse CLI surface."""
from __future__ import annotations

import json

import pytest

from repro.dse.record import (RECORD_SCHEMA, read_snapshot, run_meta,
                              update_snapshot)


def test_fresh_snapshot_is_versioned_and_stamped(tmp_path):
    path = tmp_path / "BENCH_X.json"
    doc = update_snapshot(path, {"t1": [{"a": 1}]}, seed=7)
    on_disk = json.loads(path.read_text())
    assert on_disk == doc
    assert on_disk["schema"] == RECORD_SCHEMA
    assert on_disk["meta"]["seed"] == 7
    assert on_disk["meta"]["jax"]  # jax version string
    assert on_disk["meta"]["platform"]
    assert "created" in on_disk["meta"]
    assert on_disk["tables"] == {"t1": [{"a": 1}]}


def test_merge_keeps_other_tables(tmp_path):
    path = tmp_path / "BENCH_X.json"
    update_snapshot(path, {"t1": [1]}, seed=0)
    update_snapshot(path, {"t2": [2]}, seed=0)
    assert read_snapshot(path) == {"t1": [1], "t2": [2]}


def test_unversioned_snapshot_backed_up_not_overwritten(tmp_path):
    path = tmp_path / "BENCH_X.json"
    legacy = {"t1": [{"old": True}]}
    path.write_text(json.dumps(legacy))
    update_snapshot(path, {"t2": [2]}, seed=0)
    backup = tmp_path / "BENCH_X.pre-schema.json"
    assert json.loads(backup.read_text()) == legacy  # old numbers preserved
    assert read_snapshot(path) == {"t1": [{"old": True}], "t2": [2]}
    # the backup is written once, never clobbered by later runs
    update_snapshot(path, {"t3": [3]}, seed=0)
    assert json.loads(backup.read_text()) == legacy


def test_newer_schema_refused(tmp_path):
    path = tmp_path / "BENCH_X.json"
    path.write_text(json.dumps({"schema": RECORD_SCHEMA + 1, "tables": {}}))
    with pytest.raises(ValueError, match="newer"):
        update_snapshot(path, {"t": []})


def test_read_snapshot_handles_both_layouts(tmp_path):
    legacy = tmp_path / "legacy.json"
    legacy.write_text(json.dumps({"t": [1]}))
    assert read_snapshot(legacy) == {"t": [1]}
    assert read_snapshot(tmp_path / "absent.json") == {}


def test_run_meta_time_stamp_optional():
    assert "created" in run_meta(0)
    meta = run_meta(0, stamp_time=False, extra={"measure": "none"})
    assert "created" not in meta
    assert meta["measure"] == "none"


def test_cli_run_report_check_roundtrip(tmp_path, capsys):
    """launch/dse.py end-to-end on a tiny proxy-only space."""
    from repro.dse.space import SearchSpace
    from repro.launch import dse as cli

    space = SearchSpace(kinds=("recip",), lookup_bits=(4, 5, 6),
                        targets=("asic",), bits=(8,))
    space_file = tmp_path / "space.json"
    space_file.write_text(json.dumps(space.to_dict()))
    study_dir = tmp_path / "study"
    assert cli.main(["run", "--study", str(study_dir),
                     "--space-json", str(space_file),
                     "--measure", "none"]) == 0
    assert cli.main(["resume", "--study", str(study_dir),
                     "--assert-no-exec"]) == 0
    assert cli.main(["report", "--study", str(study_dir)]) == 0
    out = capsys.readouterr().out
    assert "frontier" in out and "asic" in out
    # self-check passes; an injected better committed point fails
    frontier = study_dir / "frontier.json"
    assert cli.main(["check", "--study", str(study_dir),
                     "--against", str(frontier)]) == 0
    doc = json.loads(frontier.read_text())
    doc["groups"]["asic"].append({"params": {"kind": "recip",
                                             "lookup_bits": 2},
                                  "metrics": {},
                                  "objectives": [0.0, 0.0, -1e9]})
    fake = tmp_path / "committed.json"
    fake.write_text(json.dumps(doc))
    assert cli.main(["check", "--study", str(study_dir),
                     "--against", str(fake)]) == 1
    # resume on a directory that was never a study is a usage error
    assert cli.main(["resume", "--study", str(tmp_path / "nope")]) == 2
