"""Study resume semantics, kill-mid-write recovery, frontier regression.

The acceptance contract of ISSUE 6: a study killed mid-run and resumed
produces a BIT-IDENTICAL frontier artifact to an uninterrupted run, with
zero completed trials re-executed (asserted via the executed/replayed
counters), and the check mode flags an injected frontier regression.

These tests run with ``measure="none"`` (proxy objectives only) so no
serve engine is compiled; the modeled-throughput probe has its own test
at the bottom (one tiny engine, cached across trials).
"""
from __future__ import annotations

import json

import pytest

from repro.dse import (SearchSpace, Study, compare_frontiers, load_frontier,
                       smoke_space)
from repro.dse.study import accuracy_margin_ulp


def _space() -> SearchSpace:
    # 8-bit recip keeps exploration sub-second per trial; two targets so the
    # frontier has two unit systems (groups); R=3 is typically infeasible,
    # exercising the infeasible-records path deterministically either way
    return SearchSpace(kinds=("recip",), lookup_bits=(3, 4, 5, 6),
                       targets=("asic", "pallas-tpu"), bits=(8,),
                       fused=(True,), horizons=(4,), batches=(2,))


N = 8  # |_space()|


def _run_full(root, **kw):
    with Study(root, _space(), measure="none", name="t", **kw) as study:
        study.run()
        return study


def test_full_run_counts_and_artifacts(tmp_path):
    study = _run_full(tmp_path / "a")
    assert study.stats["executed"] == N
    assert study.stats["replayed"] == 0
    assert study.frontier_path().exists()
    front = load_frontier(study.frontier_path())
    assert front["objectives"] == ["area", "delay", "neg_accuracy_margin"]
    assert set(front["groups"]) <= {"asic", "pallas-tpu"}
    assert all(front["groups"].values())  # every group non-empty
    # objective sanity: margins are >= 0 for verified designs
    for pts in front["groups"].values():
        for pt in pts:
            assert pt["metrics"]["accuracy_margin"] >= 0
            assert pt["objectives"][2] == -pt["metrics"]["accuracy_margin"]


def test_resume_replays_zero_trials(tmp_path):
    _run_full(tmp_path / "a")
    bytes_before = (tmp_path / "a" / "frontier.json").read_bytes()
    # space=None: everything (space, measure, seed) comes from study.json
    with Study(tmp_path / "a") as resumed:
        resumed.run()
        assert resumed.stats["executed"] == 0
        assert resumed.stats["replayed"] == N
    assert (tmp_path / "a" / "frontier.json").read_bytes() == bytes_before


def test_kill_mid_run_resume_bit_identical(tmp_path):
    ref = _run_full(tmp_path / "a")
    # interrupted run: 3 trials land, then the process dies mid-append
    with Study(tmp_path / "b", _space(), measure="none", name="t") as part:
        part.run(max_trials=3)
        assert part.stats["executed"] == 3
        journal = part.store.journal_path
    with open(journal, "a") as f:
        f.write('{"schema": 1, "key": "killed-mid-')  # torn tail, no newline
    assert not (tmp_path / "b" / "frontier.json").exists()
    with Study(tmp_path / "b") as resumed:
        resumed.run()
        assert resumed.stats["replayed"] == 3  # zero completed re-executed
        assert resumed.stats["executed"] == N - 3
    assert (tmp_path / "b" / "frontier.json").read_bytes() == \
        ref.frontier_path().read_bytes()


def test_compaction_preserves_frontier(tmp_path):
    study = _run_full(tmp_path / "a")
    bytes_before = study.frontier_path().read_bytes()
    with Study(tmp_path / "a") as again:
        again.run(compact=True)
    assert (tmp_path / "a" / "snapshot.json").exists()
    with Study(tmp_path / "a") as resumed:
        resumed.run()
        assert resumed.stats["executed"] == 0
        assert resumed.stats["replayed"] == N
    assert study.frontier_path().read_bytes() == bytes_before


def test_check_flags_injected_regression(tmp_path):
    study = _run_full(tmp_path / "a")
    fresh = load_frontier(study.frontier_path())
    # self-comparison: healthy
    assert compare_frontiers(fresh, fresh) == []
    # inject an unattainable committed point: area/delay 0 with a huge margin
    committed = json.loads(json.dumps(fresh))
    committed["groups"]["asic"].append({
        "params": {"kind": "recip", "lookup_bits": 2},
        "metrics": {},
        "objectives": [0.0, 0.0, -1e9],
    })
    problems = compare_frontiers(fresh, committed)
    assert len(problems) == 1 and "no longer attained" in problems[0]
    # axis change is its own loud failure
    renamed = dict(fresh, objectives=list(fresh["objectives"]) + ["extra"])
    assert "objective axes changed" in compare_frontiers(renamed, fresh)[0]
    # a vanished target group is flagged
    missing = json.loads(json.dumps(fresh))
    del missing["groups"]["asic"]
    assert any("vanished" in p for p in compare_frontiers(missing, fresh))


def test_check_accepts_axis_superset(tmp_path):
    """A fresh study whose trial axes strictly contain the committed one's
    (ISSUE 8: the new ``segmentation`` axis vs the pre-segment
    FRONTIER_6.json) must not be flagged — only a *lost* axis is a
    regression, because then the fresh space cannot express the committed
    points."""
    study = _run_full(tmp_path / "a")
    fresh = load_frontier(study.frontier_path())
    # committed predates the new axis: strip it from every point's params
    committed = json.loads(json.dumps(fresh))
    for pts in committed["groups"].values():
        for pt in pts:
            pt["params"].pop("segmentation", None)
    assert compare_frontiers(fresh, committed) == []
    # the reverse direction — the fresh study LOST an axis — is flagged
    problems = compare_frontiers(committed, fresh)
    assert problems and "segmentation" in problems[0]


def test_measure_change_refused(tmp_path):
    _run_full(tmp_path / "a")
    with pytest.raises(ValueError, match="measure"):
        Study(tmp_path / "a", measure="modeled")


def test_margin_is_exact_envelope_slack():
    from repro.api import get_table
    from repro.api.config import spec_for

    design = get_table("recip", bits=8, lookup_bits=6)
    spec = spec_for("recip", 8)
    margin = accuracy_margin_ulp(design, spec)
    ok, worst = design.verify(spec)
    assert ok and worst == 0
    assert margin >= 0  # verified <=> non-negative slack


def test_smoke_space_shape():
    space = smoke_space()
    trials = list(space.trials())
    assert len(trials) == len(space) == 16
    keys = {p.key for p in trials}
    assert len(keys) == 16  # keys are unique
    # round-trip through the study-file serialization
    assert SearchSpace.from_dict(space.to_dict()) == space


def test_modeled_probe_end_to_end(tmp_path):
    """One real ServeEngine probe, shared across trials via the shape cache;
    deterministic counter-modeled throughput lands in the objectives."""
    space = SearchSpace(kinds=("recip", "exp2neg"), lookup_bits=(6,),
                        targets=("asic",), fused=(True,), horizons=(4,),
                        batches=(2,), arch="yi_6b")
    with Study(tmp_path / "m", space, measure="modeled", name="m") as study:
        records = study.run()
        assert study.stats["executed"] == 2
        # both trials share one serving shape: one engine run, one cache hit
        assert study.probe.stats == {"runs": 1, "hits": 1, "retries": 0}
        recs = [r for r in records.values() if r.ok]
        assert recs, "smoke trials must be feasible at the registry defaults"
        for rec in recs:
            assert rec.metrics["throughput_mode"] == "modeled"
            assert rec.metrics["tokens_per_s"] > 0
            assert len(rec.objectives) == 4
            assert rec.objectives[3] == -rec.metrics["tokens_per_s"]
    front = load_frontier((tmp_path / "m") / "frontier.json")
    assert front["objectives"][-1] == "neg_tokens_per_s"
