"""ServeProbe robustness (ISSUE 7 satellite): per-trial timeout, the
retry-once-with-backoff policy, and the guarantee that retries reach
``TrialRecord.timing`` without perturbing the deterministic metrics."""
from __future__ import annotations

import pytest

from repro.dse.probe import ProbeTimeout, ServeProbe
from repro.dse.trial import TrialParams


def _params(**kw):
    base = dict(kind="recip", lookup_bits=4, target="asic", arch="yi_6b",
                fused=True, horizon=4, batch=2)
    base.update(kw)
    return TrialParams(**base)


def test_transient_failure_retried_once_and_reported(monkeypatch):
    probe = ServeProbe("modeled", backoff_s=0.0)
    real = probe._serve_once
    failures = {"left": 1}

    def flaky(p):
        if failures["left"]:
            failures["left"] -= 1
            raise RuntimeError("transient device loss")
        return real(p)

    monkeypatch.setattr(probe, "_serve_once", flaky)
    out = probe.measure(_params())
    assert out["probe_retries"] == 1
    assert probe.retries == 1
    assert probe.stats["retries"] == 1
    # the deterministic fields are identical to a clean run's
    clean = ServeProbe("modeled").measure(_params())
    out.pop("probe_retries")
    assert out == clean
    # and the cache never replays the accident: a second measure of the
    # same shape is a hit with no retry marker
    again = probe.measure(_params())
    assert "probe_retries" not in again
    assert probe.hits == 1


def test_second_failure_propagates(monkeypatch):
    probe = ServeProbe("modeled", backoff_s=0.0)

    def always_down(p):
        raise RuntimeError("device is gone")

    monkeypatch.setattr(probe, "_serve_once", always_down)
    with pytest.raises(RuntimeError, match="device is gone"):
        probe.measure(_params())
    assert probe.retries == 1  # it did try again before giving up


def test_timeout_raises_after_retry(monkeypatch):
    probe = ServeProbe("modeled", timeout_s=0.0, backoff_s=0.0)
    with pytest.raises(ProbeTimeout, match="timeout_s"):
        probe.measure(_params())
    assert probe.retries == 1


def test_study_records_retries_in_timing(tmp_path, monkeypatch):
    from repro.dse import SearchSpace, Study

    space = SearchSpace(kinds=("recip",), lookup_bits=(4,), targets=("asic",),
                        bits=(8,), fused=(True,), horizons=(4,), batches=(2,))
    with Study(tmp_path / "s", space, measure="modeled", name="t") as study:
        real = study.probe._serve_once
        failures = {"left": 1}

        def flaky(p):
            if failures["left"]:
                failures["left"] -= 1
                raise RuntimeError("transient")
            return real(p)

        monkeypatch.setattr(study.probe, "_serve_once", flaky)
        monkeypatch.setattr(study.probe, "backoff_s", 0.0)
        records = study.run()
    (rec,) = records.values()
    assert rec.timing.get("retries") == 1
    assert "retries" not in rec.metrics and "probe_retries" not in rec.metrics
