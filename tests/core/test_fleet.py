"""Fleet engine ≡ per-spec batched engine (the equivalence oracle).

The padded (P, B_max, N_max) stacked program must reproduce
``core.batched``'s per-spec results bit for bit under ragged (mixed R,
mixed N) padding — including the mask/sentinel handling at slot boundaries
— and the lockstep decision procedure must reproduce ``run_decision`` per
kind, candidate for candidate.
"""
import numpy as np
import pytest

from repro.core import batched, fleet
from repro.core import designspace as dsp
from repro.core.decision import (DecisionPolicy, IntervalSet,
                                 alg1_interval_precision, run_decision)
from repro.core.funcspec import get_spec


def _same_float(a, b):
    return (a == b) or (np.isnan(a) and np.isnan(b))


def _assert_spaces_equal(got, want, ctx):
    assert len(got) == len(want), ctx
    for r, (g, w) in enumerate(zip(got, want)):
        assert np.array_equal(g.big_m, w.big_m), (ctx, r)
        assert np.array_equal(g.small_m, w.small_m), (ctx, r)
        assert _same_float(g.a_lo, w.a_lo), (ctx, r)
        assert _same_float(g.a_hi, w.a_hi), (ctx, r)
        assert g.feasible == w.feasible, (ctx, r)


def _rand_bounds(rng, b, n, slack=4):
    L = rng.integers(0, 80, (b, n)).astype(np.int64)
    return L, L + rng.integers(0, slack, (b, n))


# ------------------------------------------------------ stacked front half

def test_stacked_ragged_bitwise_matches_batched():
    """Property: mixed-R, mixed-N probes through ONE padded program equal
    the per-probe batched engine bit for bit (inf column sentinels lose
    every reduction; pad region rows are sliced away)."""
    rng = np.random.default_rng(0)
    shapes = [(4, 16), (8, 8), (2, 32), (16, 4), (8, 16), (1, 32), (4, 4)]
    bounds = [_rand_bounds(rng, b, n, slack=3) for b, n in shapes]
    stack = fleet.stack_bounds(bounds)
    assert stack.L.shape == (7, 16, 32)
    spaces = fleet.fleet_region_spaces_stacked(stack)
    for i, (L, U) in enumerate(bounds):
        _assert_spaces_equal(spaces[i], batched.region_spaces(L, U), i)


def test_stacked_degenerate_widths():
    """n == 1 and n == 2 probes inside a ragged stack keep the trivial-space
    semantics of the per-spec engine."""
    rng = np.random.default_rng(1)
    bounds = [_rand_bounds(rng, 8, 1), _rand_bounds(rng, 4, 2),
              _rand_bounds(rng, 2, 16)]
    spaces = fleet.fleet_region_spaces_stacked(fleet.stack_bounds(bounds))
    for i, (L, U) in enumerate(bounds):
        _assert_spaces_equal(spaces[i], batched.region_spaces(L, U), i)


def test_fleet_region_spaces_real_specs_mixed_r():
    """Real spec probes at several R (the sweep/min-R traffic pattern)."""
    pairs = [("recip", 8, 2), ("recip", 8, 5), ("exp2", 8, 3),
             ("silu", 8, 4), ("recip", 8, 8)]
    bounds = [get_spec(k, b).region_bounds(r) for k, b, r in pairs]
    out = fleet.fleet_region_spaces(bounds)
    for i, (L, U) in enumerate(bounds):
        _assert_spaces_equal(out[i], batched.region_spaces(L, U), pairs[i])


def test_fleet_feasible_mask_matches_per_probe():
    rng = np.random.default_rng(2)
    bounds = [_rand_bounds(rng, 8, 8, slack=2) for _ in range(6)]
    bounds += [get_spec("recip", 8).region_bounds(r) for r in (1, 2, 3, 8)]
    mask = fleet.fleet_feasible_mask(bounds)
    for i, (L, U) in enumerate(bounds):
        assert mask[i] == bool(batched.regions_feasible_mask(L, U).all()), i


# ------------------------------------------------------------- fleet alg1

def _rand_interval_sets(rng, n_regions, max_iv, lo, hi):
    sets = []
    for _ in range(n_regions):
        ivs = []
        for _ in range(rng.integers(1, max_iv + 1)):
            a, b = sorted(rng.integers(lo, hi, 2).tolist())
            ivs.append((int(a), int(b)))
        sets.append(IntervalSet(tuple(ivs)))
    return sets


@pytest.mark.parametrize("lo,hi", [(-50, 50), (0, 1 << 20), (-(1 << 40), -3),
                                   (-5, 5), (1, 2)])
def test_fleet_alg1_bit_identical(lo, hi):
    """Property: the vectorized Algorithm 1 picks the same (bits, shift,
    signed) as the scalar routine on random interval unions spanning signs,
    zeros and wide magnitudes."""
    rng = np.random.default_rng(abs(lo) + abs(hi))
    for trial in range(40):
        sets = _rand_interval_sets(rng, int(rng.integers(1, 9)), 3, lo, hi)
        assert fleet.fleet_alg1(sets) == alg1_interval_precision(sets), sets


def test_fleet_alg1_zero_only_sets():
    sets = [IntervalSet(((0, 0),)), IntervalSet(((0, 4),))]
    assert fleet.fleet_alg1(sets) == alg1_interval_precision(sets)


def test_fleet_alg1_huge_values_fall_back_to_scalar():
    sets = [IntervalSet(((1 << 55, (1 << 55) + 7),))]
    assert fleet.fleet_alg1(sets) == alg1_interval_precision(sets)


# --------------------------------------------- batched helpers (fleet ops)

def test_a_window_matches_a_candidates_set():
    spec = get_spec("recip", 8)
    L, U = spec.region_bounds(3)
    for space in batched.region_spaces(L, U):
        for k in (0, 4, 9, 14):
            vals = dsp.a_candidates(space, k)
            win = dsp.a_window(space, k)
            if not vals:
                assert win is None
                continue
            assert sorted(vals) == list(range(win[0], win[1] + 1))
            assert list(dsp.a_magnitude_order(*win)) == vals


def test_candidates_feasible_matches_design_candidates():
    """The wave-based existence check agrees with full generation on every
    region, including infeasible (exhausting) ones."""
    spec = get_spec("recip", 8)
    for lookup_bits in (1, 2, 3):
        L, U = spec.region_bounds(lookup_bits)
        spaces = batched.region_spaces(L, U)
        for k in (0, 2, 5, 8):
            for force_linear in (False, True):
                full = batched.design_candidates(spaces, L, U, k, force_linear)
                okv = batched.candidates_feasible(spaces, L, U, k, force_linear)
                assert list(okv) == [len(c) > 0 for c in full], \
                    (lookup_bits, k, force_linear)


def test_trunc_candidates_vector_k_and_sq_matches_scalar():
    """Per-row (k, sq_t) vectors reproduce per-kind scalar calls: stacking
    two kinds' regions at different ladder states is the fleet trunc step."""
    spec_a = get_spec("recip", 8)
    spec_b = get_spec("exp2", 8)
    r = 3
    parts = []
    for spec, k, sq_t in ((spec_a, 6, 0), (spec_b, 9, 2)):
        L, U = spec.region_bounds(r)
        ds = dsp.minimal_k(spec, r, engine="batched")
        assert ds is not None
        a_sets = [[c.a for c in row] for row in ds.candidates]
        parts.append((L, U, ds.k, a_sets, sq_t))
    for lin_t in (0, 1):
        ref = []
        for L, U, k, a_sets, sq_t in parts:
            ref.extend(batched.trunc_candidates(L, U, k, a_sets, sq_t, lin_t))
        b = 1 << r
        got = batched.trunc_candidates(
            np.concatenate([p[0] for p in parts]),
            np.concatenate([p[1] for p in parts]),
            np.repeat([p[2] for p in parts], b),
            [row for p in parts for row in p[3]],
            np.repeat([p[4] for p in parts], b), lin_t)
        assert got == ref, lin_t


# ------------------------------------------------------ lockstep decisions

def test_fleet_decisions_bit_identical_to_run_decision():
    """The tentpole equivalence: a same-shape probe group through the
    lockstep procedure yields each kind's serial design exactly."""
    kinds = ["recip", "exp2", "log2", "silu", "sigmoid", "gelu"]
    specs = [get_spec(k, 8) for k in kinds]
    r = 3
    bounds = [s.region_bounds(r) for s in specs]
    spaces = fleet.fleet_region_spaces(bounds)
    results = fleet.fleet_decisions(specs, r, bounds, spaces,
                                    policy=DecisionPolicy())
    for spec, res in zip(specs, results):
        ref = run_decision(spec, r, engine="batched")
        assert (res is None) == (ref is None), spec.name
        if ref is None:
            continue
        d1, r1 = ref
        d2, r2 = res
        assert (d1.k, d1.degree, d1.sq_trunc, d1.lin_trunc) == \
            (d2.k, d2.degree, d2.sq_trunc, d2.lin_trunc), spec.name
        assert d1.lut_widths == d2.lut_widths, spec.name
        assert np.array_equal(d1.a, d2.a), spec.name
        assert np.array_equal(d1.b, d2.b), spec.name
        assert np.array_equal(d1.c, d2.c), spec.name
        assert r1.linear_possible == r2.linear_possible, spec.name


@pytest.mark.parametrize("degree", [1, 2])
def test_fleet_decisions_forced_degree(degree):
    specs = [get_spec("recip", 8), get_spec("exp2", 8)]
    r = 4
    bounds = [s.region_bounds(r) for s in specs]
    spaces = fleet.fleet_region_spaces(bounds)
    results = fleet.fleet_decisions(specs, r, bounds, spaces, degree=degree,
                                    policy=DecisionPolicy())
    for spec, res in zip(specs, results):
        ref = run_decision(spec, r, degree=degree, engine="batched")
        assert (res is None) == (ref is None), spec.name
        if ref is not None:
            assert np.array_equal(ref[0].c, res[0].c), spec.name
            assert ref[0].degree == res[0].degree == degree


def test_fleet_decisions_policy_without_truncation():
    """A pallas-style policy (no truncation maximization) locksteps too."""
    pol = DecisionPolicy(maximize_sq_trunc=False, maximize_lin_trunc=False)
    specs = [get_spec("recip", 8), get_spec("sigmoid", 8)]
    r = 3
    bounds = [s.region_bounds(r) for s in specs]
    spaces = fleet.fleet_region_spaces(bounds)
    results = fleet.fleet_decisions(specs, r, bounds, spaces, policy=pol)
    for spec, res in zip(specs, results):
        ref = run_decision(spec, r, engine="batched", policy=pol)
        assert (res is None) == (ref is None)
        if ref is not None:
            assert ref[0].sq_trunc == res[0].sq_trunc == 0
            assert np.array_equal(ref[0].c, res[0].c)


# ------------------------------------------------- pool lifecycle (PR fix)

def test_region_pool_clean_exit_drains_work():
    """Clean context exit close()s the pool (letting submitted work drain)
    instead of terminate()ing it; the exception path still terminates."""
    from repro.core.pmap import RegionPool

    with RegionPool(2) as p:
        out = p.map(abs, [-3, -1, 4, -7])
        assert out == [3, 1, 4, 7]
    assert p._pool is None
    p2 = RegionPool(2)
    p2.__enter__()
    try:
        raise RuntimeError("boom")
    except RuntimeError:
        p2.__exit__(RuntimeError, None, None)
    assert p2._pool is None


# ----------------------------------------------------- device-path (f32)

def test_fleet_device_path_steep_table_a_interval():
    """Regression: TILE-pad t-slots (and other widths' sentinel columns)
    must be sliced off before the device a-interval reduction — their
    ~±2^30/(2e) envelopes would otherwise win the dd max against steep
    tables and inflate a_lo."""
    from repro.kernels.dspace.ops import (fleet_region_envelopes_device,
                                          region_envelopes_device)

    x = np.arange(16, dtype=np.int64)
    L = (-(1 << 24) * x).reshape(1, 16)
    U = L + 8
    one = region_envelopes_device(L, U, interpret=True)
    fl = fleet_region_envelopes_device(L[None], U[None], shards=1,
                                       interpret=True)
    np.testing.assert_allclose(fl[2], one[2], rtol=1e-5)  # a_lo
    np.testing.assert_allclose(fl[3], one[3], rtol=1e-5)  # a_hi
    # ragged stack: sharing a device call with a narrower probe must not
    # change either probe's results (no cross-width sentinel contamination
    # — each width group gets its own kernel launch)
    nb = _rand_bounds(np.random.default_rng(5), 4, 8)
    ragged = fleet.fleet_region_spaces_device(
        fleet.stack_bounds([(L, U), nb]), interpret=True)
    for i, b in enumerate([(L, U), nb]):
        alone = fleet.fleet_region_spaces_device(fleet.stack_bounds([b]),
                                                 interpret=True)[0]
        for d, e in zip(ragged[i], alone):
            assert d.feasible == e.feasible, i
            assert np.array_equal(d.big_m, e.big_m), i
            assert _same_float(d.a_lo, e.a_lo) and _same_float(d.a_hi, e.a_hi), i


def test_fleet_device_path_interpret_matches_exact_verdicts():
    """The stacked device program (interpret mode off-TPU) agrees with the
    exact engine on feasibility and to f32 tolerance on envelopes."""
    pairs = [("recip", 8, 3), ("exp2", 8, 4)]
    bounds = [get_spec(k, b).region_bounds(r) for k, b, r in pairs]
    stack = fleet.stack_bounds(bounds)
    dev = fleet.fleet_region_spaces_device(stack, interpret=True)
    exact = fleet.fleet_region_spaces_stacked(stack)
    for i in range(len(bounds)):
        for d, e in zip(dev[i], exact[i]):
            assert d.feasible == e.feasible, pairs[i]
            np.testing.assert_allclose(d.big_m[1:], e.big_m[1:], rtol=2e-5)
            np.testing.assert_allclose(d.small_m[1:], e.small_m[1:], rtol=2e-5)
