"""core.pareto: the shared multi-objective frontier (ISSUE 6).

The 2-D behaviour is pinned to the seed's inline sort-and-scan algorithm
(kept here as the oracle) — ``DesignSpaceResult.pareto`` was rewired onto
``pareto_indices`` and must not change output. The k-D generalization is
property-tested against the domination definition directly.
"""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api.result import DesignSpaceResult, ExploreEntry
from repro.core.pareto import dominates, pareto_front, pareto_indices


def _oracle_2d(points: list[tuple[float, float]]) -> list[tuple[float, float]]:
    """The seed's DesignSpaceResult.pareto algorithm, verbatim."""
    front, best_delay = [], float("inf")
    for p in sorted(points):
        if p[1] < best_delay:
            front.append(p)
            best_delay = p[1]
    return front


def test_empty_and_singleton():
    assert pareto_indices([]) == []
    assert pareto_indices([(3.0, 4.0)]) == [0]


def test_matches_2d_oracle_random():
    rng = np.random.default_rng(0)
    for n in (1, 2, 5, 40):
        for _ in range(20):
            # quantized coords force plenty of ties and duplicates
            pts = [tuple(map(float, p))
                   for p in rng.integers(0, 6, size=(n, 2))]
            assert pareto_front(pts) == _oracle_2d(pts)


def test_duplicates_keep_first_index():
    pts = [(1.0, 1.0), (1.0, 1.0), (2.0, 0.5)]
    assert pareto_indices(pts) == [0, 2]


def test_3d_invariants_random():
    rng = np.random.default_rng(1)
    for _ in range(30):
        pts = [tuple(map(float, p))
               for p in rng.integers(0, 5, size=(25, 3))]
        kept = pareto_indices(pts)
        kept_set = set(kept)
        # kept points: not weakly dominated by any distinct-valued point
        for i in kept:
            assert not any(dominates(pts[j], pts[i])
                           for j in range(len(pts)) if pts[j] != pts[i])
        # every dropped point is weakly dominated by some kept point
        for j in range(len(pts)):
            if j not in kept_set:
                assert any(dominates(pts[i], pts[j]) for i in kept)
        # ordering: ascending objective vectors
        assert [pts[i] for i in kept] == sorted(pts[i] for i in kept)


def test_dominates_arity_mismatch():
    with pytest.raises(ValueError):
        dominates((1.0,), (1.0, 2.0))
    with pytest.raises(ValueError):
        pareto_indices([(1.0, 2.0), (1.0,)])


@given(st.lists(st.tuples(st.integers(0, 8), st.integers(0, 8)), max_size=30))
@settings(max_examples=60, deadline=None)
def test_property_matches_2d_oracle(pts):
    pts = [tuple(map(float, p)) for p in pts]
    assert pareto_front(pts) == _oracle_2d(pts)


@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 5),
                          st.integers(0, 5)), max_size=25))
@settings(max_examples=60, deadline=None)
def test_property_kd_sound_and_complete(pts):
    pts = [tuple(map(float, p)) for p in pts]
    kept = pareto_indices(pts)
    kept_set = set(kept)
    for j in range(len(pts)):
        if j in kept_set:
            # nothing strictly better exists
            assert not any(dominates(pts[i], pts[j]) and pts[i] != pts[j]
                           for i in range(len(pts)) if i != j)
        else:
            assert any(dominates(pts[i], pts[j]) for i in kept)


def _entry(area: float, delay: float) -> ExploreEntry:
    # pareto() only touches .area/.delay; design/report stay out of play
    return ExploreEntry(design=None, report=None, area=area, delay=delay,
                        runtime_s=0.0, objective=area * delay)


def test_design_space_result_rewired():
    entries = [_entry(1, 5), _entry(2, 3), _entry(2, 4), _entry(3, 3),
               _entry(4, 1), _entry(4, 1)]
    res = DesignSpaceResult("spec", "asic", entries, None)
    front = [(e.area, e.delay) for e in res.pareto()]
    assert front == _oracle_2d([(e.area, e.delay) for e in entries])
    assert front == [(1, 5), (2, 3), (4, 1)]
