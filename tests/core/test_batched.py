"""Batched region engine ≡ per-region scalar path (the equivalence oracle).

The numpy engine must match the pooled per-region routines *bit for bit*
(same float64 expressions, batched over a leading region axis); the Pallas
engine matches to float32 tolerance and must agree on every feasibility
verdict for the specs under test.
"""
import numpy as np
import pytest

from repro.core import batched, decision
from repro.core import designspace as dsp
from repro.core.funcspec import get_spec


def _scalar_spaces(L, U):
    return [dsp.region_space(L[r], U[r], "hull") for r in range(L.shape[0])]


def _same_float(a, b):
    return (a == b) or (np.isnan(a) and np.isnan(b))


def _rand_bounds(rng, b, n, slack=5):
    L = rng.integers(0, 60, (b, n)).astype(np.int64)
    return L, L + rng.integers(0, slack, (b, n))


# ------------------------------------------------------- region spaces

@pytest.mark.parametrize("kind,bits", [("recip", 8), ("exp2", 8), ("silu", 8)])
def test_region_spaces_bitwise_match(kind, bits):
    spec = get_spec(kind, bits)
    # includes the n == 2 (R = bits-1) and n == 1 (R = bits) degenerate rows
    for lookup_bits in (0, 1, 2, 3, bits - 2, bits - 1, bits):
        L, U = spec.region_bounds(lookup_bits)
        ref = _scalar_spaces(L, U)
        bat = batched.region_spaces(L, U)
        assert len(ref) == len(bat) == 1 << lookup_bits
        for r, (a, b) in enumerate(zip(ref, bat)):
            assert np.array_equal(a.big_m, b.big_m), (lookup_bits, r)
            assert np.array_equal(a.small_m, b.small_m), (lookup_bits, r)
            assert _same_float(a.a_lo, b.a_lo), (lookup_bits, r)
            assert _same_float(a.a_hi, b.a_hi), (lookup_bits, r)
            assert a.feasible == b.feasible, (lookup_bits, r)
        mask = batched.regions_feasible_mask(L, U)
        assert list(mask) == [s.feasible for s in ref]


def test_region_spaces_random_rows_include_infeasible():
    rng = np.random.default_rng(0)
    for n in (4, 8, 16):
        L, U = _rand_bounds(rng, 32, n, slack=3)
        ref = _scalar_spaces(L, U)
        bat = batched.region_spaces(L, U)
        verdicts = {s.feasible for s in ref}
        for a, b in zip(ref, bat):
            assert a.feasible == b.feasible
            assert _same_float(a.a_lo, b.a_lo) and _same_float(a.a_hi, b.a_hi)
        assert len(verdicts) == 2 or n > 4, "want a feasible/infeasible mix"


def test_batched_dd_matches_scalar_searches():
    rng = np.random.default_rng(1)
    g = rng.integers(-1000, 1000, (16, 40)).astype(np.float64)
    h = rng.integers(-1000, 1000, (16, 40)).astype(np.float64)
    from repro.core import searches
    mx = batched.batched_max_dd(g, h)
    mn = batched.batched_min_dd(g, h)
    for i in range(16):
        assert mx[i] == searches.max_dd(g[i], h[i], "naive")[0]
        assert mn[i] == searches.min_dd(g[i], h[i], "naive")[0]


def test_batched_dd_hull_fallback_path():
    rng = np.random.default_rng(2)
    t = batched._HULL_T_THRESHOLD
    g = rng.integers(-1000, 1000, (2, t)).astype(np.float64)
    h = rng.integers(-1000, 1000, (2, t)).astype(np.float64)
    from repro.core import searches
    mx = batched.batched_max_dd(g, h)
    for i in range(2):
        assert mx[i] == searches.max_dd(g[i], h[i], "hull")[0]


# ------------------------------------------------------- candidates

@pytest.mark.parametrize("force_linear", [False, True])
def test_design_candidates_match_per_region(force_linear):
    spec = get_spec("recip", 8)
    for lookup_bits in (2, 3, 7, 8):
        L, U = spec.region_bounds(lookup_bits)
        spaces = batched.region_spaces(L, U)
        for k in (0, 3, 6):
            ref = [dsp._region_candidates(spaces[r], L[r], U[r], k, force_linear)
                   for r in range(L.shape[0])]
            bat = batched.design_candidates(spaces, L, U, k, force_linear)
            assert ref == bat, (lookup_bits, k, force_linear)


def test_trunc_candidates_match_per_region():
    spec = get_spec("recip", 8)
    for lookup_bits in (2, 3):
        ds = dsp.minimal_k(spec, lookup_bits, engine="batched")
        assert ds is not None
        n_regions = 1 << lookup_bits
        a_sets = [[c.a for c in ds.candidates[r]] for r in range(n_regions)]
        for sq_t, lin_t in ((0, 0), (1, 0), (2, 1), (3, 2)):
            if max(sq_t, lin_t) > ds.eval_bits:
                continue
            ref = [decision._region_trunc_candidates(
                       ds.L[r], ds.U[r], ds.k, a_sets[r], sq_t, lin_t, "hull")
                   for r in range(n_regions)]
            bat = batched.trunc_candidates(ds.L, ds.U, ds.k, a_sets, sq_t, lin_t)
            assert ref == bat, (lookup_bits, sq_t, lin_t)


def test_batched_linear_fit_matches_scalar():
    rng = np.random.default_rng(3)
    lo = rng.integers(-200, 200, (64, 8)).astype(np.int64)
    hi = lo + rng.integers(0, 60, (64, 8))
    hi[::9] -= 100  # force some empty (lo > hi) rows
    for stride in (1, 2, 4):
        bat = batched.batched_linear_fit(lo, hi, stride)
        for i in range(64):
            assert bat[i] == decision.linear_fit_interval(lo[i], hi[i], stride)


# ------------------------------------------------------- full decision

@pytest.mark.parametrize("kind,bits,lookup_bits",
                         [("recip", 8, 2), ("recip", 8, 4), ("exp2", 8, 3),
                          ("log2", 8, 3)])
def test_run_decision_engines_identical(kind, bits, lookup_bits):
    spec = get_spec(kind, bits)
    pooled = decision.run_decision(spec, lookup_bits, engine="pooled", impl="hull")
    bat = decision.run_decision(spec, lookup_bits, engine="batched")
    assert (pooled is None) == (bat is None)
    if pooled is None:
        return
    d1, r1 = pooled
    d2, r2 = bat
    assert (d1.k, d1.degree, d1.sq_trunc, d1.lin_trunc) == \
        (d2.k, d2.degree, d2.sq_trunc, d2.lin_trunc)
    assert d1.lut_widths == d2.lut_widths
    assert np.array_equal(d1.a, d2.a)
    assert np.array_equal(d1.b, d2.b)
    assert np.array_equal(d1.c, d2.c)
    assert r1.linear_possible == r2.linear_possible


# ------------------------------------------------------- pallas engine

def test_pallas_engine_matches_numpy_interpret():
    spec = get_spec("recip", 8)
    for lookup_bits in (2, 3, 5):
        L, U = spec.region_bounds(lookup_bits)
        ref = batched.region_spaces(L, U)
        pal = batched.region_spaces_pallas(L, U, interpret=True)
        for r, (a, b) in enumerate(zip(ref, pal)):
            np.testing.assert_allclose(b.big_m[1:], a.big_m[1:], rtol=2e-5)
            np.testing.assert_allclose(b.small_m[1:], a.small_m[1:], rtol=2e-5)
            assert a.feasible == b.feasible, (lookup_bits, r)
            if a.feasible:
                np.testing.assert_allclose([b.a_lo, b.a_hi], [a.a_lo, a.a_hi],
                                           rtol=2e-4)


def test_pallas_engine_trivial_widths_use_numpy_path():
    spec = get_spec("recip", 8)
    for lookup_bits in (7, 8):  # n == 2 / n == 1
        L, U = spec.region_bounds(lookup_bits)
        ref = batched.region_spaces(L, U)
        pal = batched.region_spaces_pallas(L, U)
        for a, b in zip(ref, pal):
            assert a.feasible == b.feasible
            assert np.array_equal(a.big_m, b.big_m)
