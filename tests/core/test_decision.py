"""Decision procedure + Algorithm 1 tests."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import decision as dec
from repro.core.funcspec import get_spec
from repro.core.generate import generate_for_r


# ---------------------------------------------------------------- Algorithm 1

@st.composite
def interval_families(draw):
    n_regions = draw(st.integers(1, 5))
    fams = []
    for _ in range(n_regions):
        lo = draw(st.integers(0, 500))
        width = draw(st.integers(0, 60))
        fams.append((lo, lo + width))
    return fams


@settings(max_examples=100, deadline=None)
@given(interval_families())
def test_alg1_interval_matches_set_version(fams):
    sets = [list(range(lo, hi + 1)) for lo, hi in fams]
    p_set, t_set = dec.alg1_set_precision(sets)
    meta = dec.alg1_interval_precision([dec.IntervalSet.single(lo, hi) for lo, hi in fams])
    assert not meta.signed  # non-negative inputs
    # widths must agree (the shift may differ at equal width)
    assert meta.bits == p_set, (p_set, t_set, meta)


def test_alg1_literal_example():
    # regions {12, 20}, {8}: tz >= 2 everywhere; P_{t,r} takes the *min* over
    # each region's set: 12>>2=3 fits in 2 bits, 8>>2=2 fits in 2 bits.
    p, t = dec.alg1_set_precision([[12, 20], [8]])
    assert (p, t) == (2, 2)


def test_alg1_signed_fallback():
    meta = dec.alg1_interval_precision([
        dec.IntervalSet.single(-6, -2), dec.IntervalSet.single(3, 9)])
    assert meta.signed


# --------------------------------------------------------- linear_fit_interval

@settings(max_examples=100, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 100), st.integers(0, 10)),
                min_size=1, max_size=20))
def test_linear_fit_interval_sound_and_complete(rows):
    lo = np.array([a for a, _ in rows], np.int64)
    hi = lo + np.array([d for _, d in rows], np.int64)
    iv = dec.linear_fit_interval(lo, hi)
    n = len(lo)
    idx = np.arange(n, dtype=np.int64)
    feas = [b for b in range(-150, 151)
            if ((lo - b * idx).max() <= (hi - b * idx).min())]
    if iv is None:
        assert not feas
    elif n == 1:
        assert iv == (0, 0)  # any slope works; 0 is the representative
    else:
        b_min, b_max = iv
        for b in (b_min, b_max):
            assert (lo - b * idx).max() <= (hi - b * idx).min()
        assert set(feas) == set(range(b_min, b_max + 1))


# ------------------------------------------------------------- full procedure

@pytest.mark.parametrize("kind,bits,r", [
    ("recip", 8, 4), ("recip", 10, 6), ("exp2", 8, 4),
    ("log2", 8, 4), ("sigmoid", 8, 4), ("silu", 8, 4),
])
def test_generated_designs_verify_exhaustively(kind, bits, r):
    spec = get_spec(kind, bits)
    res = generate_for_r(spec, r)
    assert res is not None, f"{kind}{bits} R={r} infeasible"
    ok, worst = res.design.verify(spec)
    assert ok, worst
    assert res.design.max_error_ulp(spec) <= spec.ulp + 1.0


def test_truncation_never_breaks_validity():
    spec = get_spec("recip", 10)
    res = generate_for_r(spec, 4)  # quadratic with truncations
    assert res is not None and res.report.degree == 2
    assert res.design.verify(spec)[0]
    assert res.report.sq_trunc >= 0 and res.report.lin_trunc >= 0


def test_signed_function_roundtrip():
    spec = get_spec("silu", 10)
    res = generate_for_r(spec, 5)
    assert res is not None
    assert res.design.verify(spec)[0]
    # silu has negative outputs -> c (or the eval) must go negative
    codes = np.arange(1 << 10)
    assert res.design.eval_int(codes).min() < 0


def test_widths_not_wider_than_remez():
    """Table II's qualitative claim: complete-space a-width <= Remez a-width."""
    from repro.core.remez import generate_remez_table
    spec = get_spec("recip", 10)
    ours = generate_for_r(spec, 4)
    rz = generate_remez_table(spec, 4, degree=2)
    assert ours is not None and rz is not None
    assert ours.design.lut_widths[0] <= rz.widths[0]
    assert sum(ours.design.lut_widths) <= sum(rz.widths) + 4
