"""Design-space correctness: envelopes, feasibility (Eqns 9-10) vs brute
force, interval soundness, and completeness on tiny problems."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import designspace as dsp
from repro.core.funcspec import FunctionSpec, get_spec


def brute_force_quadratic_exists(L, U, k, a_range=12, b_range=200):
    """Tiny-problem oracle: does ANY integer (a,b,c) satisfy the sandwich?"""
    n = len(L)
    x = np.arange(n, dtype=np.int64)
    for a in range(-a_range, a_range + 1):
        for b in range(-b_range, b_range + 1):
            poly = a * x * x + b * x
            c_lo = ((L << k) - poly).max()
            c_hi = (((U + 1) << k) - poly).min() - 1
            if c_lo <= c_hi:
                return True
    return False


def test_envelopes_match_definition():
    rng = np.random.default_rng(1)
    L = rng.integers(0, 40, 8).astype(np.int64)
    U = L + rng.integers(0, 5, 8)
    M, m = dsp.envelopes(L, U)
    n = len(L)
    for t in range(1, 2 * n - 2):
        pairs = [(x, t - x) for x in range(n) if x < t - x < n]
        if not pairs:
            continue
        exp_m = min((U[y] + 1 - L[x]) / (y - x) for x, y in pairs)
        exp_M = max((L[y] - U[x] - 1) / (y - x) for x, y in pairs)
        assert m[t] == pytest.approx(exp_m), t
        assert M[t] == pytest.approx(exp_M), t


@st.composite
def bound_rows(draw):
    n = draw(st.sampled_from([4, 8]))
    base = draw(st.lists(st.integers(0, 60), min_size=n, max_size=n))
    slack = draw(st.lists(st.integers(0, 6), min_size=n, max_size=n))
    L = np.array(base, np.int64)
    return L, L + np.array(slack, np.int64)


@settings(max_examples=60, deadline=None)
@given(bound_rows())
def test_feasibility_matches_brute_force(LU):
    """Eqns 9-10 + integer candidate search == brute-force existence (k=4)."""
    L, U = LU
    space = dsp.region_space(L, U)
    k = 4
    cands = dsp._region_candidates(space, L, U, k, force_linear=False)
    # soundness: every claimed candidate has an exact-integer witness
    x = np.arange(len(L), dtype=np.int64)
    for cand in cands[:3]:
        lo_c, hi_c = None, None
        for b in (cand.b_min, cand.b_max):
            lo_c, hi_c = dsp.c_interval(L, U, cand.a, b, k)
            if lo_c <= hi_c:
                poly = cand.a * x * x + b * x + lo_c
                assert np.all(poly >> k >= L) and np.all(poly >> k <= U)
                break
        assert lo_c is not None and lo_c <= hi_c, "candidate without witness"
    # completeness: brute force searches a small (a, b) box, so anything it
    # finds must also be in the (complete) candidate space.
    if brute_force_quadratic_exists(L, U, k):
        assert cands, "brute force found a quadratic the space missed"


@settings(max_examples=40, deadline=None)
@given(bound_rows())
def test_candidates_are_sound(LU):
    """Every (a, b in interval) candidate admits an exact integer c."""
    L, U = LU
    space = dsp.region_space(L, U)
    cands = dsp._region_candidates(space, L, U, 3, force_linear=False)
    x = np.arange(len(L), dtype=np.int64)
    for cand in cands[:5]:
        for b in {cand.b_min, (cand.b_min + cand.b_max) // 2, cand.b_max}:
            lo, hi = dsp.c_interval(L, U, cand.a, b, 3)
            if lo > hi:
                continue  # float-slop interior misses allowed; endpoints checked below
            poly = cand.a * x * x + b * x + lo
            assert np.all(poly >> 3 >= L) and np.all(poly >> 3 <= U)


def test_linear_flag_matches_paper_rule():
    spec = get_spec('recip', 8)
    ok, spaces = dsp.regions_feasible(spec, 4)
    assert ok
    lin = dsp.minimal_k(spec, 4, force_linear=True)
    if all(s.linear_ok for s in spaces):
        assert lin is not None and lin.feasible


def test_minimal_k_is_minimal():
    spec = get_spec('recip', 8)
    ds = dsp.minimal_k(spec, 3)
    assert ds is not None
    if ds.k > 0:
        smaller = dsp.build_design_space(spec, 3, ds.k - 1, ds.linear)
        assert not smaller.feasible
