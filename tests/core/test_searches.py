"""Equivalence of the four divided-difference search implementations."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import searches


def arrays(draw, n):
    g = draw(st.lists(st.integers(-1000, 1000), min_size=n, max_size=n))
    h = draw(st.lists(st.integers(-1000, 1000), min_size=n, max_size=n))
    return np.array(g, np.float64), np.array(h, np.float64)


@st.composite
def gh_pairs(draw):
    n = draw(st.integers(2, 40))
    return arrays(draw, n)


@settings(max_examples=200, deadline=None)
@given(gh_pairs())
def test_all_impls_agree_on_value(gh):
    g, h = gh
    vals = {name: impl(g, h)[0] for name, impl in searches.IMPLS.items()}
    ref = vals["naive"]
    for name, v in vals.items():
        assert v == pytest.approx(ref, rel=1e-12, abs=1e-12), name


@settings(max_examples=100, deadline=None)
@given(gh_pairs())
def test_min_dd_is_negated_max(gh):
    g, h = gh
    v_min, *_ = searches.min_dd(g, h, "naive")
    brute = min((g[y] - h[x]) / (y - x) for x in range(len(g)) for y in range(x + 1, len(g)))
    assert v_min == pytest.approx(brute)


def test_claim21_prunes_but_matches_on_convex_data():
    # convex-ish data triggers heavy pruning; values must still agree
    n = 200
    x = np.arange(n, dtype=np.float64)
    g = 0.01 * x**2 - x
    h = 0.01 * x**2 + 1.0
    ref = searches.max_dd_naive(g, h)
    pruned = searches.max_dd_claim21(g, h)
    assert pruned[0] == pytest.approx(ref[0])


def test_argmax_is_a_true_maximizer():
    rng = np.random.default_rng(0)
    for _ in range(20):
        g = rng.integers(-50, 50, 30).astype(np.float64)
        h = rng.integers(-50, 50, 30).astype(np.float64)
        for name, impl in searches.IMPLS.items():
            val, x, y = impl(g, h)
            assert x < y
            assert val == pytest.approx((g[y] - h[x]) / (y - x)), name


def test_degenerate_sizes():
    one = np.zeros(1)
    for impl in searches.IMPLS.values():
        assert impl(one, one)[0] == -np.inf
