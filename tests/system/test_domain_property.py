"""Out-of-domain semantics of every ``DEFAULT_LIBRARY_KINDS`` table
(ISSUE 7 satellite): for inputs outside a table's certified domain the
datapath must either *clamp* — bit-identically across the per-table glue,
the library-bound glue and the fused backend's pointwise path — or *raise*
through ``GuardedNumerics(strict=True)``. It must never silently wrap a
code into the ROM and decode an unrelated row.

(The fused backend's softmax/rmsnorm composites are exempt from bitwise
comparison by design — their code derivation differs by up to one table
ulp, see ``FusedInterpNumerics`` — but their pointwise table entry points
are the inherited library glue and must agree exactly.)
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import DEFAULT_LIBRARY_KINDS, default_explorer
from repro.core.funcspec import ACT_HI, ACT_LO
from repro.numerics import ops as nops
from repro.numerics.guard import DomainViolation, GuardedNumerics
from repro.numerics.ops import FusedInterpNumerics, InterpNumerics

ACT_KINDS = ("gelu", "sigmoid", "silu", "softplus", "tanh")
PER_TABLE = {"gelu": nops.approx_gelu, "sigmoid": nops.approx_sigmoid,
             "silu": nops.approx_silu, "softplus": nops.approx_softplus,
             "tanh": nops.approx_tanh}


@pytest.fixture(scope="module")
def lib():
    return default_explorer().compile()


def _paths(lib, kind):
    """The three float entry points for one kind: per-table glue, library
    glue, fused-backend (inherited library glue for pointwise ops)."""
    plain, fused = InterpNumerics(lib), FusedInterpNumerics(lib)
    if kind == "exp2neg":
        return (nops.approx_exp_neg, plain.exp_neg, fused.exp_neg)
    if kind == "recip":
        return (nops.approx_recip_pos, plain.recip_pos, fused.recip_pos)
    if kind == "rsqrt":
        return (nops.approx_rsqrt_pos, plain.rsqrt_pos, fused.rsqrt_pos)
    return (PER_TABLE[kind], getattr(plain, kind), getattr(fused, kind))


def _assert_paths_agree(lib, kind, x):
    a, b, c = (np.asarray(p(jnp.asarray(x, jnp.float32)), np.float32)
               for p in _paths(lib, kind))
    np.testing.assert_array_equal(a, b, err_msg=f"{kind}: per-table vs library")
    np.testing.assert_array_equal(b, c, err_msg=f"{kind}: library vs fused")
    return a


# ------------------------------------------------- example-based (always run)

def test_every_default_kind_covered():
    assert set(("exp2neg", "recip", "rsqrt") + ACT_KINDS) == set(
        DEFAULT_LIBRARY_KINDS)


@pytest.mark.parametrize("kind", ACT_KINDS)
def test_activation_out_of_window_clamps_to_tails(lib, kind):
    """Finite inputs past the table window take the certified tail values —
    identical across all three paths, saturating, never wrapped."""
    x = np.array([ACT_LO - 100.0, ACT_LO, -1.0, 0.0, 1.0, ACT_HI - 1e-3,
                  ACT_HI, ACT_HI + 100.0], np.float32)
    y = _assert_paths_agree(lib, kind, x)
    assert np.all(np.isfinite(y))
    top = 1.0 if kind in ("sigmoid", "tanh") else x[-1]
    bot = -1.0 if kind == "tanh" else 0.0
    assert y[-1] == np.float32(top)  # right tail: identity (or 1)
    assert y[0] == np.float32(bot)  # left tail: saturates to 0 (or -1)
    # saturation, not modular wrap: deep out-of-window equals the edge tail
    assert y[0] == np.asarray(PER_TABLE[kind](
        jnp.asarray([ACT_LO - 1e6], jnp.float32)), np.float32)[0]


def test_exp_neg_positive_input_clamps_to_one(lib):
    """exp2neg's domain is x <= 0; positive inputs clamp to exp(0) — the
    glue's max(-x, 0) — and deeply negative inputs underflow to 0, never
    wrapping around the exponent table."""
    x = np.array([-500.0, -126.0, -3.0, 0.0, 1.0, 700.0], np.float32)
    y = _assert_paths_agree(lib, "exp2neg", x)
    assert np.all(np.isfinite(y)) and np.all(y >= 0.0)
    assert y[3] == y[4] == y[5]  # every x >= 0 pins to the x=0 value
    assert y[0] <= 2.0 ** -120  # deep negative: underflow, not wrap


@pytest.mark.parametrize("kind", ["recip", "rsqrt"])
def test_positive_domain_extremes_agree_across_paths(lib, kind):
    from repro.numerics.guard import _POS_HUGE, _POS_TINY

    x = np.array([_POS_TINY, 1e-12, 0.5, 1.0, 2.0, 3.0, 4.0, 1e12,
                  _POS_HUGE], np.float32)
    y = _assert_paths_agree(lib, kind, x)
    # recip of the domain ceiling lands subnormal and flushes to 0 — a
    # saturated answer, still never a wrapped code
    assert np.all(np.isfinite(y)) and np.all(y >= 0.0)
    assert np.all(y[:-1] > 0.0)


@pytest.mark.parametrize("kind", ["recip", "rsqrt"])
def test_nonpositive_input_raises_through_strict_guard(lib, kind):
    """The positive-domain tables have NO certified meaning at x <= 0 (frexp
    yields garbage codes): strict GuardedNumerics refuses instead of
    wrapping."""
    g = GuardedNumerics(InterpNumerics(lib), strict=True)
    op = g.recip_pos if kind == "recip" else g.rsqrt_pos
    for bad in (0.0, -1.0, np.nan, np.inf, -np.inf):
        with pytest.raises(DomainViolation):
            op(jnp.asarray([bad], jnp.float32))
    assert g.total_violations() == 5


@pytest.mark.parametrize("kind", ["recip", "rsqrt"])
def test_guard_clamp_equals_unguarded_on_clamped_input(lib, kind):
    """Non-strict guard semantics: a bad input evaluates exactly as the
    nearest in-domain input would through the unguarded path — a bounded
    wrong answer, bit-identical to the clamp, never a wrapped code."""
    from repro.numerics.guard import _POS_HUGE, _POS_TINY

    g = GuardedNumerics(InterpNumerics(lib))
    plain = InterpNumerics(lib)
    gop = getattr(g, f"{kind}_pos")
    pop = getattr(plain, f"{kind}_pos")
    bad = np.array([0.0, -5.0, np.inf, -np.inf, np.nan, 2.0], np.float32)
    clamped = np.array([_POS_TINY, _POS_TINY, _POS_HUGE, _POS_TINY, 1.0, 2.0],
                       np.float32)
    np.testing.assert_array_equal(
        np.asarray(gop(jnp.asarray(bad)), np.float32),
        np.asarray(pop(jnp.asarray(clamped)), np.float32))
    assert g.violations[f"{kind}_pos"] == 5


@pytest.mark.parametrize("kind", ACT_KINDS)
def test_guard_repairs_nonfinite_activations(lib, kind):
    g = GuardedNumerics(InterpNumerics(lib))
    x = np.array([np.nan, np.inf, -np.inf, 1.0], np.float32)
    y = np.asarray(getattr(g, kind)(jnp.asarray(x)), np.float32)
    assert np.all(np.isfinite(y))
    assert g.violations[kind] == 3
    # the healthy element is untouched by the repair
    ref = np.asarray(getattr(InterpNumerics(lib), kind)(
        jnp.asarray([1.0], jnp.float32)), np.float32)
    assert y[3] == ref[0]


# -------------------------------------------------- property-based (hypothesis)

@settings(max_examples=25, deadline=None)
@given(st.sampled_from(ACT_KINDS),
       st.lists(st.floats(-1e6, 1e6, width=32), min_size=1, max_size=64))
def test_activation_paths_bitwise_everywhere(kind, xs):
    library = default_explorer().compile()
    _assert_paths_agree(library, kind, np.array(xs, np.float32))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-1e4, 1e4, width=32), min_size=1, max_size=64))
def test_exp_neg_paths_bitwise_everywhere(xs):
    library = default_explorer().compile()
    y = _assert_paths_agree(library, "exp2neg", np.array(xs, np.float32))
    assert np.all(y >= 0.0)


@settings(max_examples=25, deadline=None)
@given(st.sampled_from(["recip", "rsqrt"]),
       st.lists(st.floats(np.float32(1e-30), np.float32(1e30), width=32),
                min_size=1, max_size=64))
def test_positive_domain_paths_bitwise_everywhere(kind, xs):
    library = default_explorer().compile()
    y = _assert_paths_agree(library, kind, np.array(xs, np.float32))
    assert np.all(np.isfinite(y))


@settings(max_examples=25, deadline=None)
@given(st.sampled_from(["recip", "rsqrt"]),
       st.floats(-1e30, 0.0, width=32))
def test_nonpositive_never_silently_wraps(kind, bad):
    """Any non-positive float either raises (strict guard) or, unguarded +
    non-strict-guarded, never produces a value that looks like a valid
    in-domain evaluation of some wrapped code — the guard pins it to the
    domain-edge result."""
    library = default_explorer().compile()
    g = GuardedNumerics(InterpNumerics(library), strict=True)
    op = g.recip_pos if kind == "recip" else g.rsqrt_pos
    with pytest.raises(DomainViolation):
        op(jnp.asarray([np.float32(bad)], jnp.float32))
