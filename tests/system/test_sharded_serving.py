"""Sharded serving tier (DESIGN.md §17). Each case runs in a subprocess
with ``--xla_force_host_platform_device_count=8`` so the rest of the suite
keeps seeing one device (per the dry-run isolation rule).

The contract: a ``("data", "tp")``-meshed engine — KV pool batch-sharded
over data and head-sharded over tp, weights TP-sharded, ROM replicated —
emits **bitwise** the token streams of the single-host engine, on the
exact path and under a uniform interp-fused :class:`NumericsPlan`; ROM
verification and the degradation ladder keep working on sharded state.
"""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys
import textwrap

SRC = str(pathlib.Path(__file__).resolve().parents[2] / "src")


def _run(body: str) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import numpy as np
        assert len(jax.devices()) == 8
        from repro.configs.base import get_smoke_config
        from repro.launch.mesh import make_serve_mesh
        from repro.models import transformer as tf
        from repro.serve.engine import Request, ServeEngine

        def serve(cfg, params, prompts, **kw):
            eng = ServeEngine(cfg, params, slots=4, cache_len=48, **kw)
            for i, p in enumerate(prompts):
                eng.submit(Request(i, p, max_new=5))
            out = {r.rid: tuple(r.out) for r in eng.run()}
            eng.close()
            return out, eng

        cfg = get_smoke_config("yi_6b")
        params = tf.init_params(jax.random.key(0), cfg)
        rng = np.random.default_rng(7)
        prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
                   for n in (5, 11, 3, 16, 9, 2)]
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_meshed_engine_bitwise_exact_path():
    _run("""
    ref, _ = serve(cfg, params, prompts)
    for data, tp in ((2, 1), (1, 2), (2, 2), (4, 2)):
        got, eng = serve(cfg, params, prompts,
                         mesh=make_serve_mesh(data, tp),
                         aot_buckets=(8, 16), async_host=True)
        assert got == ref, f"{data}x{tp} diverged"
        assert eng.stats["aot_misses"] == 0, eng.stats
        assert eng.stats["aot_hits"] > 0, eng.stats
    print("exact OK")
    """)


def test_meshed_engine_bitwise_uniform_plan():
    _run("""
    from repro.plan.schema import SlotSpec, plan_for
    cfgp = cfg.replace(plan=plan_for(cfg, backend="interp-fused",
                                     slot=SlotSpec(lookup_bits=6)))
    ref, leg = serve(cfgp, params, prompts)
    got, eng = serve(cfgp, params, prompts, library=leg.library,
                     mesh=make_serve_mesh(2, 2), aot_buckets=(8, 16))
    assert got == ref, "uniform-plan mesh engine diverged"
    print("plan OK")
    """)


def test_rom_verify_and_degradation_on_sharded_state():
    _run("""
    import dataclasses
    from repro.faults import flip_rom_bit

    cfg_i = dataclasses.replace(cfg, numerics="interp")
    ref, leg = serve(cfg_i, params, prompts)
    # periodic verification passes on the replicated ROM
    got, eng = serve(cfg_i, params, prompts, library=leg.library,
                     mesh=make_serve_mesh(2, 2), verify_rom_every=2,
                     aot_buckets=(8, 16))
    assert got == ref
    assert eng.stats["rom_verifies"] >= 1, eng.stats
    assert eng.stats["rom_faults"] == 0

    # a corrupt replicated ROM is detected and the ladder degrades —
    # the engine still finishes every request on sharded state
    eng2 = ServeEngine(cfg_i, params, slots=4, cache_len=48,
                       library=leg.library, mesh=make_serve_mesh(2, 2),
                       verify_rom_every=1)
    eng2.library = flip_rom_bit(eng2.library, seed=9)
    for i, p in enumerate(prompts):
        eng2.submit(Request(i, p, max_new=5))
    done = eng2.run()
    assert eng2.stats["rom_faults"] >= 1, eng2.stats
    assert eng2.stats["degradations"] >= 1, eng2.stats
    assert len(done) == len(prompts)
    print("rom OK")
    """)


def test_mesh_factory_validation():
    _run("""
    from repro.launch.mesh import parse_mesh_spec
    assert parse_mesh_spec("2x4") == (2, 4)
    assert parse_mesh_spec("4") == (4, 1)
    for bad in ("", "0x2", "2x", "axb"):
        try:
            parse_mesh_spec(bad)
        except ValueError:
            pass
        else:
            raise AssertionError(f"{bad!r} accepted")
    m = make_serve_mesh(2, 2)
    assert m.axis_names == ("data", "tp")
    assert m.devices.shape == (2, 2)
    try:
        make_serve_mesh(8, 2)  # 16 > 8 devices
    except ValueError:
        pass
    else:
        raise AssertionError("oversized mesh accepted")

    # the kernels' SPMD contract: a partitioned ROM operand is refused
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.kernels.interp.ops import assert_rom_replicated
    rom = np.zeros((8, 4, 3), np.int32)
    assert_rom_replicated(jax.device_put(rom, NamedSharding(m, P())))
    try:
        assert_rom_replicated(jax.device_put(rom, NamedSharding(m, P("data"))))
    except ValueError:
        pass
    else:
        raise AssertionError("partitioned ROM accepted")
    print("factory OK")
    """)
