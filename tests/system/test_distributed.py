"""Distributed semantics tests. Each case runs in a subprocess with
``--xla_force_host_platform_device_count=8`` so the rest of the suite keeps
seeing one device (per the dry-run isolation rule)."""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys
import textwrap

SRC = str(pathlib.Path(__file__).resolve().parents[2] / "src")


def _run(body: str) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        assert len(jax.devices()) == 8
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_train_step_matches_single_device():
    _run("""
    from repro.configs.base import get_smoke_config
    from repro.data import make_batch
    from repro.launch import sharding as shlib
    from repro.train.step import StepConfig, make_train_step, train_state_init

    cfg = get_smoke_config("yi_6b").replace(n_layers=2)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 32, 4).items()}
    sc = StepConfig(peak_lr=1e-3, warmup=0)
    step = make_train_step(cfg, sc)

    s0 = train_state_init(jax.random.key(0), cfg)
    _, m_single = jax.jit(step)(s0, batch, jnp.asarray(0))

    s0b = train_state_init(jax.random.key(0), cfg)
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s0b)
    st_sh = shlib.param_specs(shapes, mesh)
    b_sh = shlib.batch_specs({k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                              for k, v in batch.items()}, mesh)
    s0b = jax.tree.map(jax.device_put, s0b, st_sh)
    batch_s = jax.tree.map(jax.device_put, batch, b_sh)
    with shlib.axis_rules(mesh):
        jstep = jax.jit(step, in_shardings=(st_sh, b_sh, None),
                        out_shardings=(st_sh, None))
        _, m_shard = jstep(s0b, batch_s, jnp.asarray(0))
    np.testing.assert_allclose(float(m_single["loss"]), float(m_shard["loss"]),
                               rtol=2e-4)
    np.testing.assert_allclose(float(m_single["grad_norm"]),
                               float(m_shard["grad_norm"]), rtol=2e-3)
    print("OK sharded == single")
    """)


def test_sharded_decode_matches_single_device():
    _run("""
    from repro.configs.base import get_smoke_config
    from repro.launch import sharding as shlib
    from repro.models import transformer as tf
    from repro.numerics.ops import get_numerics

    cfg = get_smoke_config("qwen1_5_110b").replace(n_layers=2)
    numerics = get_numerics("exact")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params = tf.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (4, 16), 0, cfg.vocab_size)

    logits, caches, _ = tf.prefill(params, toks, cfg, numerics, 32)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    l_single, _ = tf.decode_step(params, tok, jnp.asarray(16, jnp.int32),
                                 caches, cfg, numerics)

    p_shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    p_sh = shlib.param_specs(p_shapes, mesh)
    c_shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), caches)
    c_sh = shlib.cache_specs_sharding(c_shapes, cfg, mesh)
    params_s = jax.tree.map(jax.device_put, params, p_sh)
    caches_s = jax.tree.map(jax.device_put, caches, c_sh)
    with shlib.axis_rules(mesh):
        fn = jax.jit(lambda p, t, q, c: tf.decode_step(p, t, q, c, cfg, numerics),
                     in_shardings=(p_sh, None, None, c_sh))
        l_shard, _ = fn(params_s, tok, jnp.asarray(16, jnp.int32), caches_s)
    np.testing.assert_allclose(np.asarray(l_single, np.float32),
                               np.asarray(l_shard, np.float32),
                               rtol=5e-3, atol=5e-3)
    print("OK decode sharded == single")
    """)


def test_fleet_front_half_sharded_matches_single_device():
    """The fleet §II front half sharded over 8 host devices (shard_map over
    the probe axis) agrees with the single-device program exactly — each
    shard runs the same per-row kernel — and with the exact numpy engine on
    every feasibility verdict."""
    _run("""
    from repro.core import batched, fleet
    from repro.core.funcspec import get_spec
    from repro.kernels.dspace.ops import fleet_region_envelopes_device

    pairs = [("recip", 8, 3), ("exp2", 8, 3), ("silu", 8, 3), ("recip", 8, 4)]
    bounds = [get_spec(k, b).region_bounds(r) for k, b, r in pairs]
    stack = fleet.stack_bounds(bounds)
    one = fleet_region_envelopes_device(stack.L, stack.U, shards=1,
                                        interpret=True)
    sh8 = fleet_region_envelopes_device(stack.L, stack.U, shards=8,
                                        interpret=True)
    # probe count (4) does not divide 8: exercises the sentinel probe pad
    for a, b in zip(one, sh8):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    spaces = fleet.fleet_region_spaces_device(stack, shards=8, interpret=True)
    for i, (L, U) in enumerate(bounds):
        exact = batched.region_spaces(L, U)
        assert [s.feasible for s in spaces[i]] == \\
            [s.feasible for s in exact], i
    print("OK fleet sharded == single == exact verdicts")
    """)


def test_elastic_reshard_roundtrip(tmp_path):
    _run(f"""
    from repro.checkpoint import save
    from repro.launch.elastic import remesh_state, reshard_checkpoint
    from repro.launch import sharding as shlib

    tree = {{"embed": {{"tok": jnp.arange(64.0).reshape(16, 4)}},
            "mixer": {{"wq": jnp.ones((8, 16))}}}}
    mesh8 = jax.make_mesh((2, 4), ("data", "model"))
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    sh8 = shlib.param_specs(shapes, mesh8)
    t8 = jax.tree.map(jax.device_put, tree, sh8)
    save(r"{tmp_path}", 3, t8)

    mesh2 = jax.make_mesh((1, 2), ("data", "model"))
    step, t2 = reshard_checkpoint(r"{tmp_path}", shapes, mesh2)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(t2["embed"]["tok"]),
                                  np.arange(64.0).reshape(16, 4))
    # and in-memory remesh back up to 8
    t8b = remesh_state(t2, mesh8)
    np.testing.assert_array_equal(np.asarray(t8b["mixer"]["wq"]), np.ones((8, 16)))
    print("OK elastic")
    """)


def test_pipeline_parallel_matches_sequential():
    _run("""
    from functools import partial
    from repro.launch.pipeline import pipeline_apply, bubble_fraction

    n_stages, n_micro, mb, d = 4, 8, 2, 16
    mesh = jax.make_mesh((4,), ("stage",))
    key = jax.random.key(0)
    w = jax.random.normal(key, (n_stages, d, d)) / jnp.sqrt(d)
    x = jax.random.normal(jax.random.fold_in(key, 1), (n_micro, mb, d))

    def stage_fn(p, h):
        return jnp.tanh(h @ p)

    y_pipe = pipeline_apply(w, x, stage_fn, mesh, axis="stage")

    y_ref = x
    for s in range(n_stages):
        y_ref = jnp.tanh(y_ref @ w[s])
    np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9
    print("OK pipeline")
    """)


def test_grad_compression_pod_axis():
    _run("""
    from repro.optim.compress import compress_grads, compress_init, decompress_grads
    # pod-axis semantics: compress per shard, all-reduce int8 payloads'
    # dequantized means across a 2-pod axis == mean of raw grads (within
    # quantization error + EF residual carry)
    g_pod = [{"w": jax.random.normal(jax.random.key(i), (256,))} for i in range(2)]
    res = [compress_init(g) for g in g_pod]
    deq = []
    for g, r in zip(g_pod, res):
        payload, scales, _ = compress_grads(g, r)
        deq.append(decompress_grads(payload, scales)["w"])
    mean_q = (deq[0] + deq[1]) / 2
    mean_t = (g_pod[0]["w"] + g_pod[1]["w"]) / 2
    err = float(jnp.max(jnp.abs(mean_q - mean_t)))
    scale = float(jnp.max(jnp.abs(mean_t)))
    assert err < 0.02 * scale + 0.05, (err, scale)
    print("OK compression")
    """)
