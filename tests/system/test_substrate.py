"""Substrate tests: data determinism, AdamW, compression, checkpointing,
trainer resume-after-crash, straggler telemetry, serving engine."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.configs.base import get_smoke_config
from repro.data.synthetic import dataset_for
from repro.optim.adamw import adamw_init, adamw_update, global_norm
from repro.optim.compress import compress_grads, compress_init, decompress_grads
from repro.optim.schedule import cosine_schedule
from repro.serve import ServeEngine
from repro.serve.engine import Request
from repro.train.step import StepConfig, make_train_step, train_state_init
from repro.train.trainer import Trainer, TrainerConfig


# ----------------------------------------------------------------- data

def test_data_deterministic_skip_ahead():
    cfg = get_smoke_config("yi_6b")
    ds = dataset_for(cfg, 32, 8, seed=3)
    b1 = ds.batch_at(17)
    b2 = ds.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(ds.batch_at(18)["tokens"], b1["tokens"])
    # host slicing: rows [2,6) of the global batch match the full batch rows
    sl = ds.batch_at(17, 2, 6)
    np.testing.assert_array_equal(sl["tokens"], b1["tokens"][2:6])
    # labels are next-token of the same stream
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_data_vocab_range():
    cfg = get_smoke_config("mamba2_130m")
    b = dataset_for(cfg, 64, 4).batch_at(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < cfg.vocab_size


# ------------------------------------------------------------ optimizer

def test_adamw_descends_quadratic():
    w = {"w": jnp.array([3.0, -2.0])}
    st = adamw_init(w)
    params = w
    for i in range(200):
        g = {"w": 2 * st.master["w"]}  # d/dw of ||w||^2
        params, st, _ = adamw_update(g, st, jnp.asarray(0.05), weight_decay=0.0,
                                     param_dtype=jnp.float32)
    assert float(global_norm(params)) < 0.05


def test_adamw_master_weights_fp32():
    w = {"w": jnp.ones((4,), jnp.bfloat16)}
    st = adamw_init(w)
    assert st.master["w"].dtype == jnp.float32
    p, st2, _ = adamw_update({"w": jnp.ones((4,), jnp.bfloat16)}, st,
                             jnp.asarray(1e-3))
    assert p["w"].dtype == jnp.bfloat16 and st2.master["w"].dtype == jnp.float32


def test_schedule_warmup_and_decay():
    lr0 = float(cosine_schedule(0, peak_lr=1.0, warmup=10, total=100))
    lrw = float(cosine_schedule(10, peak_lr=1.0, warmup=10, total=100))
    lre = float(cosine_schedule(100, peak_lr=1.0, warmup=10, total=100))
    assert lr0 == 0.0 and abs(lrw - 1.0) < 1e-6 and abs(lre - 0.1) < 1e-6


def test_compression_error_feedback_telescopes():
    """Sum of dequantized grads over T steps ~= sum of true grads (EF)."""
    key = jax.random.key(0)
    g_true = [{"w": jax.random.normal(jax.random.fold_in(key, i), (64,))}
              for i in range(20)]
    res = compress_init(g_true[0])
    acc_q = jnp.zeros((64,))
    acc_t = jnp.zeros((64,))
    for g in g_true:
        payload, scales, res = compress_grads(g, res)
        acc_q = acc_q + decompress_grads(payload, scales)["w"]
        acc_t = acc_t + g["w"]
    # residual carries the outstanding error; totals match to within it
    err = float(jnp.max(jnp.abs(acc_q + res["w"] - acc_t)))
    assert err < 1e-4, err


# ----------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.bfloat16) * 1.5}}
    save(tmp_path, 7, tree, {"note": "x"})
    assert latest_step(tmp_path) == 7
    got, extra = restore(tmp_path, 7, tree)
    np.testing.assert_array_equal(got["a"], np.arange(6).reshape(2, 3))
    # bf16 must round-trip through npy (stored as uint16 view) and be
    # jnp-convertible again — regression for the |V2 dtype bug
    back = jnp.asarray(got["b"]["c"])
    assert back.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(back, np.float32), 1.5)
    assert extra == {"note": "x"}
    # corrupt leaf detection
    import glob
    f = sorted(glob.glob(str(tmp_path / "step_*" / "arr_00000.npy")))[0]
    a = np.load(f)
    np.save(f, a + 1)
    with pytest.raises(AssertionError, match="corrupt"):
        restore(tmp_path, 7, tree)


def test_checkpoint_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1, keep=2)
    tree = {"w": jnp.zeros(3)}
    for s in range(5):
        mgr.maybe_save(s, tree)
    import os
    kept = sorted(p for p in os.listdir(tmp_path) if p.startswith("step_"))
    assert len(kept) == 2 and kept[-1] == "step_000000004"


# ------------------------------------------------------ trainer + resume

def _tc(tmp_path, steps, every=2):
    return TrainerConfig(steps=steps, ckpt_dir=str(tmp_path), ckpt_every=every,
                         log_every=100, seq_len=32, global_batch=4,
                         step=StepConfig(total_steps=steps, warmup=2, peak_lr=1e-3))


def test_trainer_loss_decreases(tmp_path):
    cfg = get_smoke_config("yi_6b").replace(n_layers=2)
    hist = Trainer(cfg, _tc(tmp_path, 8)).run()
    assert len(hist) == 8
    assert hist[-1]["loss"] < hist[0]["loss"] + 0.5  # descending-ish, no blowup
    assert np.isfinite([h["loss"] for h in hist]).all()


def test_trainer_crash_resume_bitexact(tmp_path):
    """Train 6 steps straight vs. crash-at-4 + resume: identical final loss."""
    cfg = get_smoke_config("mamba2_130m").replace(n_layers=2)
    t1 = Trainer(cfg, _tc(tmp_path / "a", 6, every=2))
    h_straight = t1.run()

    t2 = Trainer(cfg, _tc(tmp_path / "b", 4, every=2))
    t2.run()  # "crash" after step 3 (ckpt at step 2)
    t3 = Trainer(cfg, _tc(tmp_path / "b", 6, every=2))
    assert t3.start_step == 3  # resumed from the step-2 checkpoint? no: latest is 2
    h_resumed = t3.run()
    np.testing.assert_allclose(h_straight[-1]["loss"], h_resumed[-1]["loss"],
                               rtol=1e-5)


def test_microbatching_matches_full_batch():
    cfg = get_smoke_config("yi_6b").replace(n_layers=2, remat="none")
    from repro.data import make_batch
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 32, 4).items()}
    s0 = train_state_init(jax.random.key(0), cfg)
    step1 = make_train_step(cfg, StepConfig(microbatches=1, peak_lr=1e-3, warmup=0))
    step2 = make_train_step(cfg, StepConfig(microbatches=2, peak_lr=1e-3, warmup=0))
    _, m1 = step1(s0, batch, jnp.asarray(0))
    _, m2 = step2(s0, batch, jnp.asarray(0))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    np.testing.assert_allclose(float(m1["grad_norm"]), float(m2["grad_norm"]),
                               rtol=1e-3)


# -------------------------------------------------------------- serving

def test_serve_engine_continuous_batching():
    cfg = get_smoke_config("yi_6b")
    from repro.models import transformer as tf
    params = tf.init_params(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, slots=2, cache_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 8).astype(np.int32), 4)
            for i in range(5)]  # 5 requests > 2 slots: forces slot reuse
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.out) >= r.max_new for r in done)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.out)
