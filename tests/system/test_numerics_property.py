"""Property-based tests (hypothesis) on the numerics layer invariants:
every table-backed op must respect its certified bound on arbitrary inputs,
softmax must stay a probability distribution, and the fused kernels must
match their jnp references bit-for-bit on integer paths."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.numerics import ops as nops
from repro.numerics.registry import get_table

f32 = np.float32


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-80.0, 0.0, width=32), min_size=1, max_size=64))
def test_exp_neg_certified_bound(xs):
    x = jnp.asarray(np.array(xs, f32))
    got = np.asarray(nops.approx_exp_neg(x), np.float64)
    want = np.exp(np.array(xs, np.float64))
    d = get_table("exp2neg")
    # table ULP + input quantization of the fractional exponent
    bound = 2.0 ** -d.out_bits * 4 + np.log(2) * 2.0 ** -d.in_bits
    assert np.all(np.abs(got - want) <= bound * np.maximum(want, 1e-300) + 1e-38)
    assert np.all(got >= 0.0)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(np.float32(1e-8), np.float32(1e30), width=32), min_size=1, max_size=64))
def test_recip_certified_bound(xs):
    x = jnp.asarray(np.array(xs, f32))
    got = np.asarray(nops.approx_recip_pos(x), np.float64)
    want = 1.0 / np.array(xs, np.float64)
    d = get_table("recip")
    bound = 2.0 ** -d.in_bits * 2  # quantization + 1 ULP table error
    assert np.all(np.abs(got - want) <= bound * want)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(np.float32(1e-8), np.float32(1e30), width=32), min_size=1, max_size=64))
def test_rsqrt_certified_bound(xs):
    x = jnp.asarray(np.array(xs, f32))
    got = np.asarray(nops.approx_rsqrt_pos(x), np.float64)
    want = 1.0 / np.sqrt(np.array(xs, np.float64))
    d = get_table("rsqrt")
    bound = 2.0 ** -(d.in_bits - 2)
    assert np.all(np.abs(got - want) <= bound * want)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 7), st.integers(2, 33), st.integers(0, 2**31 - 1))
def test_softmax_is_distribution(rows, cols, seed):
    x = jax.random.normal(jax.random.key(seed), (rows, cols)) * 8
    p = np.asarray(nops.approx_softmax(x), np.float64)
    assert np.all(p >= 0)
    np.testing.assert_allclose(p.sum(-1), 1.0, atol=5e-3)
    # argmax preserved whenever the margin exceeds the certified bound
    xf = np.asarray(x, np.float64)
    top2 = np.sort(xf, -1)[:, -2:]
    margin_ok = (top2[:, 1] - top2[:, 0]) > 0.01
    exact_arg = xf.argmax(-1)
    assert np.all(p.argmax(-1)[margin_ok] == exact_arg[margin_ok])


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 200))
def test_interp_kernel_matches_int_oracle(seed, n):
    """Pallas interp kernel (interpret) == pure-int64 table evaluation."""
    from repro.kernels.interp.ops import table_eval
    d = get_table("silu")
    codes = jax.random.randint(jax.random.key(seed), (n,), 0,
                               1 << d.in_bits, jnp.int32)
    a = np.asarray(table_eval(codes, d, use_kernel=True, interpret=True))
    b = np.asarray(table_eval(codes, d, use_kernel=False))
    np.testing.assert_array_equal(a, b)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_silu_gelu_softplus_pointwise(seed):
    x = jax.random.uniform(jax.random.key(seed), (256,), jnp.float32, -12, 12)
    for approx, exact in ((nops.approx_silu, jax.nn.silu),
                          (nops.approx_softplus, jax.nn.softplus)):
        got = np.asarray(approx(x), np.float64)
        want = np.asarray(exact(x), np.float64)
        assert np.max(np.abs(got - want)) < 2e-2, approx.__name__
