"""Unit tests for the structural HLO profiler against hand-built HLO text and
a real compiled module (1 device, so collectives are absent but flops/bytes
and loop scaling are exercised end-to-end)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.xprof import analyze_hlo

SYNTH = """
HloModule test

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={{0,1,2,3}}, to_apply=%add
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ip, %ar)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_synthetic_loop_scaling():
    p = analyze_hlo(SYNTH)
    # one 8x8x8 dot per iteration, 10 iterations
    assert p.flops == 10 * 2 * 8 * 8 * 8, p.flops
    # ring all-reduce over 4 chips: 2*(3/4) * 256 bytes * 10 trips
    want = 10 * 2 * (3 / 4) * (8 * 8 * 4)
    assert abs(p.collective_bytes["all-reduce"] - want) < 1e-6
    assert p.trip_counts == [10]


def test_synthetic_trip_from_condition_constant():
    text = SYNTH.replace(', backend_config={"known_trip_count":{"n":"10"}}', "")
    p = analyze_hlo(text)
    assert p.flops == 10 * 2 * 8 * 8 * 8  # falls back to constant(10) in cond


def test_real_compiled_scan_matches_analytic():
    """L scanned matmuls: profiler flops == L * 2mnk regardless of scan."""
    L, m, k, n = 7, 32, 64, 48
    w = jnp.ones((L, k, n), jnp.float32)
    x = jnp.ones((m, k), jnp.float32)

    def f(x, w):
        def body(h, wl):
            return (h @ wl) @ jnp.ones((n, k), h.dtype), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    hlo = jax.jit(f).lower(x, w).compile().as_text()
    p = analyze_hlo(hlo)
    want = L * (2 * m * k * n + 2 * m * n * k)
    assert p.flops >= want * 0.99, (p.flops, want)
    assert p.flops <= want * 1.5, (p.flops, want)  # fusion dup tolerance
    assert 7 in p.trip_counts


def test_bytes_positive_and_no_collectives_on_one_device():
    x = jnp.ones((128, 128), jnp.float32)
    hlo = jax.jit(lambda a: jnp.tanh(a @ a)).lower(x).compile().as_text()
    p = analyze_hlo(hlo)
    assert p.flops >= 2 * 128**3 * 0.99
    assert p.hbm_bytes > 0
    assert p.total_collective_bytes == 0.0
