"""Shared torn-write journal machinery (repro.util.journal): the durability
primitives behind the DSE study store, the checkpoint writer and the serve
engine's admission/token journal."""
from __future__ import annotations

import json

import pytest

from repro.util.journal import (JournalCorrupt, JournalWriter,
                                atomic_write_bytes, atomic_write_text,
                                read_journal, trim_torn_tail)


def test_atomic_write_leaves_no_tmp(tmp_path):
    p = tmp_path / "a" / "doc.json"
    atomic_write_text(p, json.dumps({"x": 1}))
    assert json.loads(p.read_text()) == {"x": 1}
    assert not list(p.parent.glob("*.tmp"))
    atomic_write_bytes(p, b"raw")  # overwrite is atomic too
    assert p.read_bytes() == b"raw"


def test_writer_appends_are_replayable(tmp_path):
    p = tmp_path / "j.jsonl"
    with JournalWriter(p) as w:
        w.append({"i": 0})
        w.append({"i": 1})
    with JournalWriter(p) as w:  # reopen appends, never truncates
        w.append({"i": 2})
    records, dropped = read_journal(p)
    assert [r["i"] for r in records] == [0, 1, 2]
    assert dropped == 0


def test_torn_tail_dropped_and_truncated(tmp_path):
    p = tmp_path / "j.jsonl"
    with JournalWriter(p) as w:
        w.append({"i": 0})
    with open(p, "a") as f:
        f.write('{"i": 1, "par')  # kill mid-append
    records, dropped = read_journal(p)
    assert [r["i"] for r in records] == [0]
    assert dropped == 1
    # a writer reopening after the crash truncates the fragment first
    with JournalWriter(p) as w:
        w.append({"i": 2})
    records, dropped = read_journal(p)
    assert [r["i"] for r in records] == [0, 2]
    assert dropped == 0


def test_unterminated_complete_record_is_terminated_not_lost(tmp_path):
    p = tmp_path / "j.jsonl"
    with JournalWriter(p) as w:
        w.append({"i": 0})
        w.append({"i": 1})
    p.write_bytes(p.read_bytes()[:-1])  # strip only the final newline
    trim_torn_tail(p)
    records, dropped = read_journal(p)
    assert [r["i"] for r in records] == [0, 1]
    assert dropped == 0


def test_mid_file_corruption_raises(tmp_path):
    p = tmp_path / "j.jsonl"
    with JournalWriter(p) as w:
        for i in range(3):
            w.append({"i": i})
    lines = p.read_text().splitlines()
    lines[0] = lines[0][:5]
    p.write_text("\n".join(lines) + "\n")
    with pytest.raises(JournalCorrupt):
        read_journal(p)

    class Custom(JournalCorrupt):
        pass

    with pytest.raises(Custom):  # callers brand their own corruption type
        read_journal(p, corrupt=Custom)


def test_read_missing_journal_is_empty(tmp_path):
    records, dropped = read_journal(tmp_path / "absent.jsonl")
    assert records == [] and dropped == 0
