"""NumericsPlan schema (ISSUE 9): hashable per-layer x per-op-site
assignments, snapshot-envelope round-trips, degradation rungs.

The plan is the single source of truth the configs/models/serve layers
thread — these tests pin its invariants: value-hashability (the serve
engine keys its jit cache on the config), exact serialization round-trip
through the schema-versioned snapshot envelope, refusal of newer payloads,
slot bookkeeping (``slot_keys`` / ``layers_using_slot``), and the three
degradation rungs (serial, exact, per-layer).
"""
from __future__ import annotations

import pytest

from repro.configs.base import get_smoke_config
from repro.plan import (PLAN_SCHEMA, SITES, LayerAssign, NumericsPlan,
                        SiteAssign, SlotSpec, load_plan, plan_for, save_plan)


def _mixed_plan(n=4) -> NumericsPlan:
    """Layer 0 fully interp-fused on R5; layer 1 softmax-only interp on the
    default slot; the rest exact. ``rest`` reads R5 through its act site."""
    r5 = SlotSpec(lookup_bits=5)
    layers = [LayerAssign(SiteAssign("interp-fused", r5),
                          SiteAssign("interp-fused", r5),
                          SiteAssign("interp-fused", r5)),
              LayerAssign(softmax=SiteAssign("interp"))]
    layers += [LayerAssign()] * (n - 2)
    return NumericsPlan(layers=tuple(layers),
                        rest=LayerAssign(act=SiteAssign("interp-guarded", r5)))


def test_slot_key_canonicalization():
    assert SlotSpec().key == "default"
    assert SlotSpec(lookup_bits=6).key == "R6"
    assert SlotSpec(lookup_bits=6, degree=2).key == "R6.d2"
    assert SlotSpec(lookup_bits=6, degree=2, segmentation="hier").key \
        == "R6.d2.hier"
    assert SlotSpec(segmentation="hier").key == "hier"
    assert SlotSpec(lookup_bits=6).table_kwargs() == {"lookup_bits": 6}


def test_invalid_names_refused():
    with pytest.raises(ValueError, match="backend"):
        SiteAssign("fp8")
    with pytest.raises(ValueError, match="segmentation"):
        SlotSpec(segmentation="octree")


def test_uniform_plan_collapses():
    plan = NumericsPlan.uniform("interp-fused", 3)
    assert plan.n_layers == 3
    assert plan.uses_interp
    assert plan.slot_keys() == ("default",)
    for la in plan.layers + (plan.rest,):
        assert la.uniform_backend == "interp-fused"
    exact = NumericsPlan.uniform("exact", 3)
    assert not exact.uses_interp and exact.slot_keys() == ()


def test_mixed_layer_has_no_uniform_backend():
    la = LayerAssign(softmax=SiteAssign("interp"))
    assert la.uniform_backend is None
    # same backend, different slots: still not uniform
    la2 = LayerAssign(SiteAssign("interp", SlotSpec(lookup_bits=5)),
                      SiteAssign("interp"), SiteAssign("interp"))
    assert la2.uniform_backend is None


def test_plan_is_hashable_and_config_embeddable():
    plan = _mixed_plan()
    assert hash(plan) == hash(_mixed_plan())
    cfg = get_smoke_config("yi_6b").replace(plan=plan)
    assert hash(cfg) != hash(get_smoke_config("yi_6b"))
    assert cfg.replace(plan=plan) == cfg


def test_round_trip_dict():
    plan = _mixed_plan()
    assert NumericsPlan.from_dict(plan.to_dict()) == plan


def test_snapshot_envelope_round_trip(tmp_path):
    plan = _mixed_plan()
    path = tmp_path / "plan.json"
    save_plan(path, plan, seed=3, meta_extra={"arch": "yi_6b"})
    assert load_plan(path) == plan


def test_newer_schema_refused():
    doc = _mixed_plan().to_dict()
    doc["plan_schema"] = PLAN_SCHEMA + 1
    with pytest.raises(ValueError, match="newer"):
        NumericsPlan.from_dict(doc)


def test_slot_bookkeeping():
    plan = _mixed_plan()
    assert plan.slot_keys() == ("R5", "default")
    assert plan.layers_using_slot("R5") == (0, "rest")
    assert plan.layers_using_slot("default") == (1,)
    assert plan.layers_using_slot("R9") == ()


def test_degrade_serial_guards_every_interp_site():
    plan = _mixed_plan().degrade_serial()
    for _label, _site, a in plan.assignments():
        assert a.backend in ("exact", "interp-guarded")
    # already-guarded sites stay guarded, exact stays exact
    assert plan.rest.act.backend == "interp-guarded"
    assert plan.layers[2].softmax.backend == "exact"


def test_degrade_exact_kills_all_interp():
    plan = _mixed_plan().degrade_exact()
    assert not plan.uses_interp
    # slots are retained for forensics even after the downgrade
    assert plan.layers[0].softmax.slot == SlotSpec(lookup_bits=5)


def test_degrade_layers_is_surgical():
    plan = _mixed_plan()
    down = plan.degrade_layers([0, "rest"], ["R5"])
    # layer 0 and rest lose their R5 sites...
    assert down.layers[0].uniform_backend == "exact"
    assert down.rest.act.backend == "exact"
    # ...but layer 1's default-slot site is untouched
    assert down.layers[1].softmax.backend == "interp"
    # degrading a slot nobody poisoned is a no-op
    assert plan.degrade_layers([1], ["R5"]) == plan


def test_plan_for_matches_config_numerics():
    cfg = get_smoke_config("yi_6b").replace(numerics="interp")
    plan = plan_for(cfg)
    assert plan == NumericsPlan.uniform("interp", cfg.n_layers)
    assert set(s for _, s, _ in plan.assignments()) == set(SITES)
