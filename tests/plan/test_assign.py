"""The budget-driven auto-assigner (ISSUE 9): frontier-seeded slots,
additive error composition, greedy budget descent, modeled throughput.

Everything here is deterministic and modeled (no wall clock): the same
config and budget must always produce the same plan, tighter budgets can
only flip more sites to exact, and any plan with interp sites must beat
the all-exact plan on modeled decode tokens/sec (that gap is the whole
point of the assigner). One end-to-end case runs ``verify=True`` on the
smoke model and asserts the measured prefill-logit error meets the budget.
"""
from __future__ import annotations

import jax
import pytest

from repro.configs.base import get_smoke_config
from repro.models import transformer as tf
from repro.plan import NumericsPlan, SITES
from repro.plan.assign import (DEFAULT_FRONTIERS, auto_plan,
                               load_frontier_candidates, modeled_tokens_per_s,
                               predicted_error, site_errors)


def test_committed_frontiers_cover_softmax_kinds():
    cand = load_frontier_candidates(DEFAULT_FRONTIERS, target="asic")
    assert "exp2neg" in cand and "recip" in cand
    rs = set(cand["exp2neg"]) & set(cand["recip"])
    assert rs, "no common lookup height for the softmax site"
    for r, entry in cand["exp2neg"].items():
        assert entry["delay"] > 0 and entry["area"] > 0


def test_missing_frontier_files_are_skipped(tmp_path):
    cand = load_frontier_candidates((tmp_path / "nope.json",))
    assert cand == {}


def test_site_errors_positive_and_softmax_dominates_rsqrt():
    errs = site_errors()
    assert set(errs) == set(SITES)
    assert all(v > 0 for v in errs.values())
    # softmax composes two kinds twice each — strictly the largest term
    assert errs["softmax"] > errs["rmsnorm"]


def test_auto_plan_deterministic():
    cfg = get_smoke_config("yi_6b")
    a = auto_plan(cfg, error_budget=0.05, verify=False)
    b = auto_plan(cfg, error_budget=0.05, verify=False)
    assert a.plan == b.plan
    assert a.predicted_error == b.predicted_error
    assert a.modeled_tokens_per_s == b.modeled_tokens_per_s


def test_budget_monotonicity():
    cfg = get_smoke_config("yi_6b")
    loose = auto_plan(cfg, error_budget=1.0, verify=False)
    tight = auto_plan(cfg, error_budget=loose.predicted_error / 4,
                      verify=False)
    assert len(tight.flipped) > len(loose.flipped)
    assert tight.predicted_error <= loose.predicted_error
    assert tight.predicted_error <= loose.predicted_error / 4
    # an impossible budget degenerates to (nearly) all-exact
    zero = auto_plan(cfg, error_budget=0.0, verify=False)
    assert not zero.plan.uses_interp
    assert zero.predicted_error == 0.0


def test_interp_plan_beats_exact_on_modeled_throughput():
    cfg = get_smoke_config("yi_6b")
    rep = auto_plan(cfg, error_budget=0.05, verify=False)
    assert rep.plan.uses_interp
    assert rep.modeled_tokens_per_s > rep.exact_tokens_per_s
    assert rep.speedup > 1.0
    # the model itself is monotone: flipping any site to exact only slows
    slower = modeled_tokens_per_s(rep.plan.degrade_exact(), rep.slot_delays)
    assert slower < rep.modeled_tokens_per_s


def test_predicted_error_weights_edge_layers():
    errs = site_errors()
    n = 4
    mid = NumericsPlan.uniform("exact", n)
    import dataclasses

    from repro.plan import LayerAssign, SiteAssign

    def one_interp(i):
        layers = list(mid.layers)
        layers[i] = LayerAssign(softmax=SiteAssign("interp"))
        return dataclasses.replace(mid, layers=tuple(layers))

    edge = predicted_error(one_interp(0), errs)
    inner = predicted_error(one_interp(1), errs)
    assert edge == pytest.approx(2 * inner)
    assert predicted_error(one_interp(n - 1), errs) == pytest.approx(edge)


def test_report_round_trips_to_dict():
    cfg = get_smoke_config("yi_6b")
    rep = auto_plan(cfg, error_budget=0.05, verify=False)
    d = rep.to_dict()
    assert d["arch"] == "yi_6b"
    assert d["measured_error"] is None
    assert NumericsPlan.from_dict(d["plan"]) == rep.plan
    assert d["speedup"] == pytest.approx(rep.speedup)


def test_auto_plan_verified_meets_budget_end_to_end():
    """The acceptance loop on the smoke model: the verified plan's measured
    whole-model prefill-logit error fits the budget, and the plan still
    carries interp sites (the budget is attainable, not vacuous)."""
    cfg = get_smoke_config("yi_6b")
    params = tf.init_params(jax.random.key(0), cfg)
    rep = auto_plan(cfg, error_budget=0.05, verify=True, params=params)
    assert rep.measured_error is not None
    assert rep.measured_error <= rep.error_budget
    assert rep.predicted_error <= rep.error_budget
    assert rep.plan.uses_interp
    assert rep.speedup > 1.0


def test_calibration_measures_aot_tick_and_feeds_throughput():
    """ISSUE 10 satellite: measured (not modeled) per-slot decode latencies
    from the AOT-warmed tick. The calibration dict carries a per-step cost
    per numerics slot plus the derived per-site constants, the throughput
    model consumes them, and the report stores them for the snapshot
    envelope — while calibration=None keeps the bit-reproducible modeled
    scoring unchanged."""
    from repro.plan.assign import calibrate_slot_latencies

    cfg = get_smoke_config("yi_6b")
    params = tf.init_params(jax.random.key(0), cfg)
    calib = calibrate_slot_latencies(cfg, params, horizon=4, reps=1)
    assert "exact" in calib["site_cost_s"]
    assert len(calib["site_cost_s"]) >= 2  # exact + at least one slot
    assert all(v > 0 for v in calib["site_cost_s"].values())
    assert all(v > 0 for v in calib["per_slot_step_s"].values())

    plan = NumericsPlan.uniform("exact", cfg.n_layers)
    modeled = modeled_tokens_per_s(plan, {}, horizon=4)
    measured = modeled_tokens_per_s(plan, {}, horizon=4, calibration=calib)
    assert measured != modeled  # wall clock actually displaced the model
    from repro.dse.probe import DISPATCH_COST_S, TRANSFER_COST_S

    n_terms = len(list(plan.assignments()))  # layers x sites, plus rest
    expected = 1.0 / ((DISPATCH_COST_S + TRANSFER_COST_S) / 4
                      + n_terms * calib["site_cost_s"]["exact"])
    assert measured == pytest.approx(expected)

    rep = auto_plan(cfg, error_budget=0.05, verify=False, calibrate=True,
                    params=params, horizon=4)
    assert rep.calibration is not None
    assert rep.to_dict()["calibration"] == rep.calibration
    rep_modeled = auto_plan(cfg, error_budget=0.05, verify=False)
    assert rep_modeled.calibration is None
    assert rep_modeled.plan == rep.plan  # calibration rescores, never reflips
