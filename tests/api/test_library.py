"""Compiled InterpLibrary artifact: bit-exactness golden tests, pytree
round-trips (jit / vmap / shard / checkpoint / npz), and serving from a
preloaded library with zero exploration calls."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (DEFAULT_LIBRARY_KINDS, Explorer, InterpLibrary,
                       default_explorer, load_library)
from repro.numerics.ops import (InterpNumerics, approx_rmsnorm,
                                approx_softmax, get_numerics, table_eval_int)


@pytest.fixture(scope="module")
def lib() -> InterpLibrary:
    # tables come through the session persistence layer (disk-cached after
    # the first generation), so compile() is a pure pack step
    return default_explorer().compile()


# ---------------------------------------------------------------------------
# golden bit-exactness: fused library evaluation vs per-table oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", DEFAULT_LIBRARY_KINDS)
def test_library_eval_bit_identical(lib, kind):
    m = lib.meta(kind)
    codes = jnp.arange(1 << m.in_bits, dtype=jnp.int32)
    ref = np.asarray(table_eval_int(codes, default_explorer().get_table(kind)))
    # static-kind slice path (the off-TPU runtime path)
    np.testing.assert_array_equal(np.asarray(lib.eval_int(codes, kind)), ref)
    # fused gather semantics (jnp oracle of the multi-function kernel)
    fused = lib.eval_fused(codes, lib.func_id(kind), use_kernel=False)
    np.testing.assert_array_equal(np.asarray(fused), ref)


@pytest.mark.parametrize("kind", DEFAULT_LIBRARY_KINDS)
def test_library_kernel_bit_identical(lib, kind):
    """The Pallas kernel (interpret mode off-TPU) matches the oracle."""
    m = lib.meta(kind)
    codes = jnp.arange(1 << m.in_bits, dtype=jnp.int32)
    ref = np.asarray(table_eval_int(codes, default_explorer().get_table(kind)))
    out = lib.eval_fused(codes, lib.func_id(kind), use_kernel=True,
                         interpret=True)
    np.testing.assert_array_equal(np.asarray(out), ref)


def test_mixed_function_fused_eval(lib):
    """One fused call evaluating every function at once, element-wise."""
    rng = np.random.default_rng(0)
    fids = rng.integers(0, len(lib), 4096).astype(np.int32)
    in_bits = np.array([m.in_bits for m in lib.metas])
    codes = (rng.integers(0, 1 << 30, 4096) % (1 << in_bits[fids])).astype(np.int32)
    out = np.asarray(lib.eval_fused(jnp.asarray(codes), jnp.asarray(fids),
                                    use_kernel=False))
    kout = np.asarray(lib.eval_fused(jnp.asarray(codes), jnp.asarray(fids),
                                     use_kernel=True, interpret=True))
    for f, kind in enumerate(lib.kinds):
        mask = fids == f
        ref = np.asarray(table_eval_int(jnp.asarray(codes[mask]),
                                        default_explorer().get_table(kind)))
        np.testing.assert_array_equal(out[mask], ref)
        np.testing.assert_array_equal(kout[mask], ref)


def test_library_numerics_match_per_table_glue(lib):
    """Library-bound numerics == the per-table reference functions, bit for
    bit (shared float glue + bit-identical integer eval)."""
    num = get_numerics("interp", lib)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 3, (4, 64)).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(num.softmax(x)),
                                  np.asarray(approx_softmax(x)))
    gamma = jnp.asarray(rng.normal(1, 0.1, 64).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(num.rmsnorm(x, gamma)),
                                  np.asarray(approx_rmsnorm(x, gamma)))
    from repro.numerics.ops import (approx_gelu, approx_sigmoid, approx_silu,
                                    approx_softplus)
    for fn, ref in [(num.silu, approx_silu), (num.gelu, approx_gelu),
                    (num.sigmoid, approx_sigmoid), (num.softplus, approx_softplus)]:
        np.testing.assert_array_equal(np.asarray(fn(x)), np.asarray(ref(x)))


# ---------------------------------------------------------------------------
# pytree round-trips
# ---------------------------------------------------------------------------

def test_library_is_registered_pytree(lib):
    leaves, treedef = jax.tree.flatten(lib)
    assert len(leaves) == 1 and leaves[0] is lib.coeffs
    back = jax.tree.unflatten(treedef, leaves)
    assert isinstance(back, InterpLibrary)
    assert back.kinds == lib.kinds and back.metas == lib.metas
    # keyed flatten gives the stable leaf name checkpointing relies on
    keyed, _ = jax.tree_util.tree_flatten_with_path(lib)
    assert "coeffs" in "".join(str(k) for k in keyed[0][0])


def test_jit_closure_vs_argument(lib):
    codes = jnp.arange(1 << lib.meta("silu").in_bits, dtype=jnp.int32)

    as_closure = jax.jit(lambda c: lib.eval_int(c, "silu"))
    as_argument = jax.jit(lambda l, c: l.eval_int(c, "silu"))
    np.testing.assert_array_equal(np.asarray(as_closure(codes)),
                                  np.asarray(as_argument(lib, codes)))
    # static metadata is jit-stable: same treedef -> no retrace
    n0 = as_argument._cache_size()
    as_argument(jax.tree.unflatten(jax.tree.structure(lib),
                                   [lib.coeffs]), codes)
    assert as_argument._cache_size() == n0


def test_vmap_over_codes(lib):
    codes = jnp.arange(1024, dtype=jnp.int32).reshape(8, 128)
    out = jax.vmap(lambda c: lib.eval_int(c, "recip"))(codes)
    ref = lib.eval_int(codes.reshape(-1), "recip").reshape(8, 128)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_replicated_sharding(lib):
    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("d",))
    sharding = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    placed = jax.device_put(lib, jax.tree.map(lambda _: sharding, lib))
    assert isinstance(placed, InterpLibrary)
    codes = jnp.arange(256, dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(placed.eval_int(codes, "recip")),
                                  np.asarray(lib.eval_int(codes, "recip")))


def test_checkpoint_round_trip(lib, tmp_path):
    """The library rides inside a state pytree through repro.checkpoint."""
    from repro import checkpoint as ckpt

    state = {"weights": jnp.ones((4, 4), jnp.float32), "library": lib}
    ckpt.save(tmp_path, 7, state)
    step, restored, _ = ckpt.CheckpointManager(str(tmp_path)).restore_latest(state)
    assert step == 7
    assert isinstance(restored["library"], InterpLibrary)
    assert restored["library"].metas == lib.metas
    np.testing.assert_array_equal(np.asarray(restored["library"].coeffs),
                                  np.asarray(lib.coeffs))


def test_save_load_round_trip(lib, tmp_path):
    path = lib.save(tmp_path / "lib")
    assert path.exists()
    import json as json_mod
    man = json_mod.loads(path.read_text())
    assert (tmp_path / man["coeffs_file"]).exists()  # content-addressed ROM
    back = load_library(path)
    assert back.metas == lib.metas
    np.testing.assert_array_equal(np.asarray(back.coeffs),
                                  np.asarray(lib.coeffs))
    codes = jnp.arange(1 << back.meta("gelu").in_bits, dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(back.eval_int(codes, "gelu")),
                                  np.asarray(lib.eval_int(codes, "gelu")))


def test_save_crash_never_tears_existing_artifact(lib, tmp_path, monkeypatch):
    """A crash mid-ROM-write must leave the previous npz/json pair intact:
    the npz goes through a tmp path + atomic rename, never in-place."""
    path = lib.save(tmp_path / "lib")
    ref = np.asarray(lib.coeffs).copy()

    def torn_savez(f, **kw):
        f.write(b"PK\x03\x04 partial garbage")  # half-written archive ...
        raise RuntimeError("simulated crash mid-save")  # ... then the crash

    monkeypatch.setattr(np, "savez", torn_savez)
    with pytest.raises(RuntimeError, match="simulated crash"):
        lib.save(tmp_path / "lib")
    monkeypatch.undo()
    assert not list(tmp_path.glob("*.tmp"))  # no tmp litter
    back = load_library(path)  # old pair still consistent + loadable
    np.testing.assert_array_equal(np.asarray(back.coeffs), ref)
    assert back.metas == lib.metas


def test_resave_crash_between_rom_and_manifest_keeps_old_artifact(
        lib, tmp_path, monkeypatch):
    """Re-saving over an existing artifact: a crash after the new ROM lands
    but before the manifest swap must leave the OLD pair loadable — the
    manifest references its ROM by content-addressed name, so the old json
    never points at the new bytes."""
    import pathlib

    path = lib.save(tmp_path / "lib")
    ref = np.asarray(lib.coeffs).copy()
    changed = InterpLibrary(np.asarray(lib.coeffs) + 1, lib.metas)

    real_write = pathlib.Path.write_text

    def crash_on_manifest(self, *a, **kw):
        if self.name.endswith(".json.tmp"):
            raise RuntimeError("simulated crash before manifest swap")
        return real_write(self, *a, **kw)

    monkeypatch.setattr(pathlib.Path, "write_text", crash_on_manifest)
    with pytest.raises(RuntimeError, match="before manifest swap"):
        changed.save(tmp_path / "lib")
    monkeypatch.undo()
    back = load_library(path)  # old manifest -> old ROM, untouched
    np.testing.assert_array_equal(np.asarray(back.coeffs), ref)
    # a completed re-save supersedes cleanly and prunes the stale ROM
    path2 = changed.save(tmp_path / "lib")
    np.testing.assert_array_equal(np.asarray(load_library(path2).coeffs),
                                  ref + 1)
    assert len(list(tmp_path.glob("lib.*.npz"))) == 1


def test_load_detects_corrupt_rom(lib, tmp_path):
    import json as json_mod

    path = lib.save(tmp_path / "lib")
    coeffs = np.asarray(lib.coeffs).copy()
    coeffs[0, 0, 2] += 1
    rom = json_mod.loads(path.read_text())["coeffs_file"]
    np.savez(open(tmp_path / rom, "wb"), coeffs=coeffs)
    with pytest.raises(ValueError, match="corrupt"):
        load_library(path)


def test_compile_subset_and_overrides(tmp_path):
    ex = Explorer()
    lib = ex.compile([("recip", {"bits": 8, "lookup_bits": 4}),
                      "exp2neg"])
    assert lib.kinds == ("recip", "exp2neg")
    assert lib.meta("recip").in_bits == 8
    assert lib.r_max == 64  # exp2neg's default R=6 dominates the padding
    codes = jnp.arange(1 << 8, dtype=jnp.int32)
    ref = table_eval_int(codes, ex.get_table("recip", bits=8, lookup_bits=4))
    np.testing.assert_array_equal(np.asarray(lib.eval_int(codes, "recip")),
                                  np.asarray(ref))


def test_duplicate_kinds_rejected():
    with pytest.raises(ValueError, match="duplicate"):
        default_explorer().compile(["recip", ("recip", {"bits": 8})])


def test_custom_activation_window_honored():
    """A library compiled over a non-default activation window records it in
    the metadata and the bound glue quantizes over that window — not the
    defaults (which would read the wrong table rows)."""
    from repro.numerics.ops import _act_tails, _range_glue, table_eval_int

    lo, hi = -4.0, 4.0
    ex = default_explorer()
    lib2 = ex.compile([("silu", {"lo": lo, "hi": hi})])
    m = lib2.meta("silu")
    assert (m.act_lo, m.act_hi, m.act_span) == (lo, hi, hi - lo)
    num = get_numerics("interp", lib2)
    x = jnp.linspace(-6.0, 6.0, 97)
    d = ex.get_table("silu", lo=lo, hi=hi)
    want = _act_tails("silu", x,
                      _range_glue(x, d.in_bits, d.out_bits, hi - lo,
                                  lambda c: table_eval_int(c, d), lo, hi),
                      lo, hi)
    np.testing.assert_array_equal(np.asarray(num.silu(x)), np.asarray(want))


def test_missing_kind_raises(lib):
    with pytest.raises(KeyError, match="log2"):
        lib.func_id("log2")
    num = InterpNumerics(default_explorer().compile(["recip"]))
    with pytest.raises(KeyError):
        num.silu(jnp.zeros((4,)))


# ---------------------------------------------------------------------------
# serving from a preloaded artifact: zero exploration calls
# ---------------------------------------------------------------------------

def test_serve_engine_from_preloaded_library(lib, tmp_path, monkeypatch):
    import repro.api.explorer as explorer_mod
    import repro.serve.engine as engine_mod
    from repro.configs.base import get_smoke_config
    from repro.models import transformer as tf
    from repro.serve.engine import Request, ServeEngine

    loaded = load_library(lib.save(tmp_path / "served"))

    def _poisoned(*a, **kw):
        raise AssertionError("exploration session touched while serving "
                             "from a preloaded library")

    monkeypatch.setattr(explorer_mod, "default_explorer", _poisoned)
    monkeypatch.setattr(engine_mod, "default_explorer", _poisoned)
    monkeypatch.setattr(explorer_mod.Explorer, "get_table", _poisoned)
    monkeypatch.setattr(explorer_mod.Explorer, "compile", _poisoned)

    cfg = get_smoke_config("yi_6b").replace(numerics="interp")
    params = tf.init_params(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, slots=2, cache_len=48, library=loaded)
    assert isinstance(eng.queue, __import__("collections").deque)
    rng = np.random.default_rng(3)
    for i in range(3):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                           max_new=4))
    done = eng.run()
    assert len(done) == 3
    assert all(len(r.out) >= 4 for r in done)


def test_funcmeta_frozen_and_hashable(lib):
    m = lib.meta("silu")
    with pytest.raises(dataclasses.FrozenInstanceError):
        m.k = 0
    assert hash(lib.metas) == hash(tuple(lib.metas))
    assert m.eval_bits == m.in_bits - m.lookup_bits
