"""Unified Explorer/Target API: back-compat, registry, envelope reuse."""
import numpy as np
import pytest

from repro.api import (DecisionPolicy, ExploreConfig, Explorer, get_spec,
                       get_target, list_targets, register_target)
from repro.api.target import _REGISTRY
from repro.core.generate import generate_table


def _same_design(a, b):
    return (a.lookup_bits == b.lookup_bits and a.degree == b.degree
            and a.k == b.k and a.sq_trunc == b.sq_trunc
            and a.lin_trunc == b.lin_trunc
            and np.array_equal(a.a, b.a) and np.array_equal(a.b, b.b)
            and np.array_equal(a.c, b.c))


# ------------------------------------------------------------- back-compat

@pytest.mark.parametrize("kind,bits", [("recip", 8), ("exp2", 8)])
def test_generate_table_shim_matches_explorer_best(kind, bits):
    """Golden: the legacy entry point and the session API agree exactly."""
    spec = get_spec(kind, bits)
    legacy = generate_table(spec)
    with Explorer() as ex:
        best = ex.explore(spec, target="asic").best
    assert _same_design(legacy.design, best.design)
    assert legacy.area == best.area and legacy.delay == best.delay


def test_explore_fixed_r_matches_legacy_error():
    spec = get_spec("recip", 8)
    with pytest.raises(ValueError, match="no feasible design"):
        generate_table(spec, lookup_bits=0)


def test_config_spec_with_explicit_bits_matches_get_spec():
    """Explicit widths must NOT inherit DEFAULTS kwargs tuned for the
    default width (seed semantics: quickstart --kind log2 --bits 16
    means 16 -> 17 bits)."""
    assert ExploreConfig(kind="log2", bits=16).spec().out_bits == \
        get_spec("log2", 16).out_bits == 17
    # default width still picks up the ML-table defaults
    assert ExploreConfig(kind="log2").spec().out_bits == 13


def test_config_degree_consistent_across_entry_points():
    """explore_r and explore honor ExploreConfig.degree identically."""
    spec = get_spec("recip", 8)
    with Explorer(ExploreConfig(degree=1)) as ex:
        # linear is infeasible at R=2 (needs a quadratic): both paths agree
        assert ex.explore_r(spec, 2) is None
        assert not ex.explore(spec, lookup_bits=2).entries
        assert ex.explore_r(spec, 4).design.degree == 1


def test_target_policy_k_max_respected():
    """ExploreConfig.k_max=None defers to the target policy's cap."""
    @register_target("test-kmax")
    class TinyK:
        policy = DecisionPolicy(k_max=3)

        def estimate(self, design):
            from repro.core.area import AreaDelay
            return AreaDelay(1.0, 1.0)

        def objective(self, design, ad):
            return 0.0

    try:
        spec = get_spec("recip", 8)
        with Explorer() as ex:
            # R=2 needs k~9: a k cap of 3 must make the decision fail ...
            assert ex.explore_r(spec, 2, target="test-kmax") is None
        # ... unless the session config explicitly overrides the cap
        with Explorer(ExploreConfig(k_max=24)) as ex:
            assert ex.explore_r(spec, 2, target="test-kmax") is not None
    finally:
        _REGISTRY.pop("test-kmax", None)


# --------------------------------------------------------- target registry

def test_builtin_targets_registered():
    assert {"asic", "fpga-lut", "pallas-tpu"} <= set(list_targets())


def test_register_target_roundtrip():
    @register_target("test-rt")
    class TestTarget:
        policy = DecisionPolicy(maximize_sq_trunc=False)

        def estimate(self, design):
            from repro.core.area import AreaDelay
            return AreaDelay(1.0, 1.0)

        def objective(self, design, ad):
            return design.lookup_bits

    try:
        tgt = get_target("test-rt")
        assert tgt.name == "test-rt"
        assert not tgt.policy.maximize_sq_trunc
        assert "test-rt" in list_targets()
        # a Target instance passes through get_target unchanged, and the
        # decorator rebinds the symbol to that same registered instance
        assert get_target(tgt) is tgt
        assert TestTarget is tgt
        assert callable(get_target(tgt).estimate)
    finally:
        _REGISTRY.pop("test-rt", None)


def test_unknown_target_raises():
    with pytest.raises(KeyError, match="unknown target"):
        get_target("not-a-technology")


# ---------------------------------------------- all targets produce valid HW

def test_all_builtin_targets_best_designs_verify():
    spec = get_spec("recip", 8)
    with Explorer() as ex:
        for name in ("asic", "fpga-lut", "pallas-tpu"):
            res = ex.explore(spec, target=name)
            assert res, f"target {name}: no feasible design"
            ok, worst = res.best.design.verify(spec)
            assert ok, f"target {name}: best design invalid (worst={worst})"
            assert res.target == name


def test_pallas_policy_skips_truncation_steps():
    spec = get_spec("recip", 8)
    with Explorer() as ex:
        e = ex.explore_r(spec, 2, target="pallas-tpu", degree=2)
    assert e is not None
    assert e.report.sq_trunc == 0 and e.report.lin_trunc == 0


# ------------------------------------------------------------ envelope reuse

def test_envelopes_computed_once_per_spec_r():
    """RegionSpace envelopes are target-independent: exploring the same spec
    under every registered target computes each (spec, R) at most once."""
    spec = get_spec("recip", 8)
    with Explorer() as ex:
        ex.explore(spec, target="asic")
        computed_after_first = ex.envelope_stats["computed"]
        base = {k: v for k, v in ex._spaces.items()}
        for name in ("fpga-lut", "pallas-tpu", "asic"):
            ex.explore(spec, target=name)
        stats = ex.envelope_stats
        assert stats["computed"] == computed_after_first, (
            "retargeting recomputed envelopes")
        assert stats["hits"] > 0
        # identical RegionSpace objects are served to every target
        for key, spaces in base.items():
            assert ex._spaces[key] is spaces


def test_envelope_reuse_returns_identical_bounds():
    spec = get_spec("exp2", 8)
    with Explorer() as ex:
        first = ex.envelopes(spec, 3)
        second = ex.envelopes(spec, 3)
        assert first is second
        assert ex.envelope_stats == {"computed": 1, "hits": 1, "evictions": 0}


def test_envelope_cache_lru_bound():
    """The (spec, R) cache is LRU-bounded by config.envelope_cache and
    evictions are observable in envelope_stats."""
    spec = get_spec("recip", 8)
    with Explorer(ExploreConfig(envelope_cache=2)) as ex:
        ex.envelopes(spec, 2)
        ex.envelopes(spec, 3)
        ex.envelopes(spec, 3)  # R=3 becomes most-recent
        ex.envelopes(spec, 4)  # evicts R=2
        stats = ex.envelope_stats
        assert stats == {"computed": 3, "hits": 1, "evictions": 1}
        assert len(ex._spaces) == 2
        ex.envelopes(spec, 3)  # still cached (was most-recent at eviction)
        assert ex.envelope_stats["hits"] == 2
        ex.envelopes(spec, 2)  # evicted -> recomputed
        assert ex.envelope_stats["computed"] == 4
        assert ex.envelope_stats["evictions"] == 2


def test_unbounded_envelope_cache():
    spec = get_spec("recip", 8)
    with Explorer(ExploreConfig(envelope_cache=None)) as ex:
        for r in range(6):
            ex.envelopes(spec, r)
        assert ex.envelope_stats["evictions"] == 0
        assert len(ex._spaces) == 6


# ------------------------------------------------------------ region engine

def test_engine_knob_validated():
    with pytest.raises(ValueError, match="unknown engine"):
        Explorer(ExploreConfig(engine="nope"))


@pytest.mark.parametrize("engine", ["pooled", "batched", "pallas"])
def test_engines_produce_identical_designs(engine):
    """The tentpole equivalence: every engine yields the same RegionSpace
    verdicts and, through the decision procedure, the same design."""
    spec = get_spec("recip", 8)
    with Explorer(ExploreConfig(engine="batched")) as ref_ex:
        ref = ref_ex.explore_r(spec, 3)
    with Explorer(ExploreConfig(engine=engine)) as ex:
        got = ex.explore_r(spec, 3)
    assert ref is not None and got is not None
    assert _same_design(ref.design, got.design)


def test_min_regions_binary_matches_linear_scan():
    """Feasibility is monotone in R (region splitting only removes
    constraints): the exponential-descent + binary search must agree with
    the seed's linear scan on every registered spec kind."""
    from repro.api.config import DEFAULTS

    with Explorer() as ex:
        for kind in DEFAULTS:
            spec = ExploreConfig(kind=kind, bits=8).spec()
            fast = ex.min_regions(spec)
            linear = next((r for r in range(spec.in_bits + 1)
                           if ex.feasible(spec, r)), None)
            assert fast == linear, kind
            # feasibility really is monotone above the minimum
            assert all(ex.feasible(spec, r)
                       for r in range(fast, spec.in_bits + 1)), kind


def test_min_regions_r_max_cutoff():
    spec = get_spec("recip", 8)
    with Explorer() as ex:
        true_min = ex.min_regions(spec)
        assert true_min == 2
        assert ex.min_regions(spec, r_max=true_min - 1) is None
        assert ex.min_regions(spec, r_max=true_min) == true_min


# ------------------------------------------------------------ fleet engine

def test_fleet_compile_bit_identical_to_serial(tmp_path):
    """Golden: the manifest compiled through the fleet engine equals the
    serial per-kind path — same metadata, same ROM, same disk artifacts."""
    with Explorer(ExploreConfig(cache_dir=str(tmp_path / "fleet"))) as ex:
        lib_fleet = ex.compile()
    with Explorer(ExploreConfig(cache_dir=str(tmp_path / "serial"),
                                fleet=False)) as ex:
        lib_serial = ex.compile()
    assert lib_fleet.kinds == lib_serial.kinds
    assert lib_fleet.metas == lib_serial.metas
    np.testing.assert_array_equal(np.asarray(lib_fleet.coeffs),
                                  np.asarray(lib_serial.coeffs))
    fleet_files = sorted(p.name for p in (tmp_path / "fleet").glob("*.json"))
    serial_files = sorted(p.name for p in (tmp_path / "serial").glob("*.json"))
    assert fleet_files == serial_files and fleet_files


def test_fleet_compile_warm_cache_short_circuits(tmp_path):
    """A second fleet compile must load every table from cache (no new disk
    writes, identical objects from the session memo)."""
    cfg = ExploreConfig(cache_dir=str(tmp_path))
    with Explorer(cfg) as ex:
        lib1 = ex.compile(["recip", "exp2neg"])
        stamp = {p.name: p.stat().st_mtime_ns for p in tmp_path.glob("*.json")}
        lib2 = ex.compile(["recip", "exp2neg"])
        assert {p.name: p.stat().st_mtime_ns
                for p in tmp_path.glob("*.json")} == stamp
    np.testing.assert_array_equal(np.asarray(lib1.coeffs),
                                  np.asarray(lib2.coeffs))


def test_min_regions_many_matches_serial():
    """Lockstep fleet min-R == per-spec min_regions for every registered
    kind, and the verdicts land in the shared feasibility LRU."""
    from repro.api.config import DEFAULTS

    specs = [ExploreConfig(kind=k, bits=8).spec() for k in DEFAULTS]
    with Explorer() as ex:
        many = ex.min_regions_many(specs)
        assert ex.feasible_stats["computed"] > 0
        # every probe the lockstep answered is now a cache hit
        hits0 = ex.feasible_stats["hits"]
        again = ex.min_regions_many(specs)
        assert again == many
        assert ex.feasible_stats["hits"] > hits0
    with Explorer() as ex2:
        serial = [ex2.min_regions(s) for s in specs]
    assert many == serial


def test_explore_sweep_primes_envelopes_through_fleet():
    """The height sweep computes every (spec, R) envelope in one fleet pass
    before the per-R loop — the loop itself only hits the cache."""
    spec = get_spec("recip", 8)
    with Explorer() as ex:
        res = ex.explore(spec, r_lo=2, r_hi=5)
        assert [e.lookup_bits for e in res] == [2, 3, 4, 5]
        stats = ex.envelope_stats
        assert stats["computed"] == 4
        assert stats["hits"] >= 4  # explore_r served from the primed cache


def test_mesh_device_spaces_never_poison_exact_cache():
    """Under mesh > 1 the fleet front half runs in float32 on device; those
    spaces must not be primed under the exact batched engine's cache keys —
    feasibility answers must not depend on call order."""
    spec = get_spec("recip", 8)
    with Explorer(ExploreConfig(mesh=2)) as ex:
        spaces = ex._envelopes_fleet([(spec, 3)])
        assert len(spaces[0]) == 8
        assert ex.envelope_stats["computed"] == 0
        assert not ex._spaces
        # the exact verdict is computed fresh, not read from f32 spaces
        assert ex.feasible(spec, 3) == ex.feasible(spec, 3)


def test_feasible_cache_lru_stats():
    spec = get_spec("recip", 8)
    with Explorer() as ex:
        ex._FEAS_CACHE_CAP = 2
        ex.feasible(spec, 3)
        ex.feasible(spec, 3)
        ex.feasible(spec, 4)
        ex.feasible(spec, 5)  # evicts R=3
        stats = ex.feasible_stats
        assert stats["computed"] == 3
        assert stats["hits"] == 1
        assert stats["evictions"] == 1
        assert len(ex._feasible) == 2


# ------------------------------------------------------------ result object

def test_result_frontier_pareto_and_min_regions():
    spec = get_spec("recip", 8)
    with Explorer() as ex:
        res = ex.explore(spec)
    assert res.min_regions_r == 2
    assert res.minimal_regions.lookup_bits == 2
    heights = [e.lookup_bits for e in res]
    assert heights == sorted(heights)
    front = res.pareto()
    assert front, "empty Pareto front"
    # no front point dominates another
    for i, e in enumerate(front):
        for f in front[i + 1:]:
            assert not (f.area <= e.area and f.delay <= e.delay)
    assert res.best in res.entries


def test_explorer_get_table_caches(tmp_path):
    cfg = ExploreConfig(cache_dir=str(tmp_path))
    with Explorer(cfg) as ex:
        t1 = ex.get_table("recip", bits=8, lookup_bits=4)
        assert (tmp_path / "recip_8b_R4_d0.json").exists()
        t2 = ex.get_table("recip", bits=8, lookup_bits=4)
        assert t1 is t2  # memory cache hit
    with Explorer(cfg) as ex2:
        t3 = ex2.get_table("recip", bits=8, lookup_bits=4)
        assert _same_design(t1, t3)  # disk round-trip
