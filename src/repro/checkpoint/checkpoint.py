"""Fault-tolerant checkpointing: atomic, manifest-verified, keep-K, resumable.

Layout per step::

    <dir>/step_000000420/
        manifest.json       # leaf paths, shapes, dtypes, per-leaf checksum
        arr_00000.npy ...   # one .npy per leaf (np.save, mmap-able)
    <dir>/LATEST            # text file: last *committed* step

Write protocol (crash-safe at every point):
  1. write into ``step_X.tmp/``
  2. fsync-free rename ``step_X.tmp -> step_X``   (atomic on POSIX)
  3. rewrite ``LATEST`` via temp+rename           (atomic pointer flip)
A failure between 2 and 3 leaves a complete-but-unreferenced checkpoint;
``latest_step`` only trusts LATEST, and ``save`` garbage-collects strays.

Multi-host: each host writes only the leaves it owns (``host_shard`` filter);
host 0 writes the manifest after a barrier in the launcher. In this container
we exercise the single-host path; the protocol is host-count agnostic because
files are per-leaf and the manifest is written last.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import shutil

import jax
import numpy as np

from repro.util.journal import atomic_write_text


def _leaf_paths(tree) -> list[tuple[str, object]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        # DictKey carries .key, SequenceKey .idx, GetAttrKey (custom pytree
        # nodes like repro.api.InterpLibrary) .name
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        out.append((name, leaf))
    return out


def _checksum(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()[:16]


def _to_savable(a: np.ndarray) -> tuple[np.ndarray, str]:
    """npy can't round-trip ml_dtypes (bf16 loads back as void); store a
    uint16 view and keep the logical dtype in the manifest."""
    import ml_dtypes
    if a.dtype == ml_dtypes.bfloat16:
        return a.view(np.uint16), "bfloat16"
    return a, str(a.dtype)


def _from_saved(a: np.ndarray, logical: str) -> np.ndarray:
    import ml_dtypes
    if logical == "bfloat16" and a.dtype != ml_dtypes.bfloat16:
        return a.view(ml_dtypes.bfloat16)
    return a


def save(directory: str | pathlib.Path, step: int, tree, extra: dict | None = None,
         verify: bool = True) -> pathlib.Path:
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    final = d / f"step_{step:09d}"
    tmp = d / f"step_{step:09d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves = _leaf_paths(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (name, leaf) in enumerate(leaves):
        a, logical = _to_savable(np.asarray(leaf))
        fn = f"arr_{i:05d}.npy"
        with open(tmp / fn, "wb") as f:
            np.save(f, a)
            f.flush()
            os.fsync(f.fileno())  # leaf bytes durable before the manifest
        manifest["leaves"].append({
            "name": name, "file": fn, "shape": list(a.shape),
            "dtype": logical, "sha": _checksum(a) if verify else "",
        })
    # the shared tmp+fsync+rename discipline (repro.util.journal): the
    # manifest and the LATEST pointer can never be torn by a crash — at
    # every instant they are either the old complete file or the new one
    atomic_write_text(tmp / "manifest.json", json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit
    atomic_write_text(d / "LATEST", str(step))  # atomic pointer flip
    return final


def latest_step(directory: str | pathlib.Path) -> int | None:
    f = pathlib.Path(directory) / "LATEST"
    if not f.exists():
        return None
    step = int(f.read_text().strip())
    if not (pathlib.Path(directory) / f"step_{step:09d}" / "manifest.json").exists():
        return None  # pointer ahead of data: treat as no checkpoint
    return step


def restore(directory: str | pathlib.Path, step: int, like, verify: bool = True):
    """Restore into the structure of ``like`` (shapes checked leaf-by-leaf)."""
    d = pathlib.Path(directory) / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())
    by_name = {e["name"]: e for e in manifest["leaves"]}
    flat = _leaf_paths(like)
    out = []
    for name, leaf in flat:
        e = by_name[name]
        a = np.load(d / e["file"])
        if verify and e["sha"]:
            assert _checksum(a) == e["sha"], f"corrupt leaf {name}"
        a = _from_saved(a, e["dtype"])
        want = tuple(getattr(leaf, "shape", a.shape))
        assert tuple(a.shape) == want, (name, a.shape, want)
        out.append(a)
    treedef = jax.tree.structure(like)
    return jax.tree.unflatten(treedef, out), manifest["extra"]


@dataclasses.dataclass
class CheckpointManager:
    """save-every-N + keep-K retention + resume-from-latest."""

    directory: str
    every: int = 100
    keep: int = 3

    def maybe_save(self, step: int, tree, extra: dict | None = None) -> bool:
        if step % self.every:
            return False
        save(self.directory, step, tree, extra)
        self._gc()
        return True

    def _gc(self):
        d = pathlib.Path(self.directory)
        committed = latest_step(d)
        steps = sorted(int(p.name.split("_")[1]) for p in d.glob("step_*")
                       if not p.name.endswith(".tmp"))
        for s in steps[:-self.keep] if len(steps) > self.keep else []:
            if s != committed:
                shutil.rmtree(d / f"step_{s:09d}", ignore_errors=True)
        for p in d.glob("step_*.tmp"):  # crashed writers
            shutil.rmtree(p, ignore_errors=True)

    def restore_latest(self, like):
        s = latest_step(self.directory)
        if s is None:
            return None, None, None
        tree, extra = restore(self.directory, s, like)
        return s, tree, extra
