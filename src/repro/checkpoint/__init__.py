from repro.checkpoint.checkpoint import (CheckpointManager, latest_step,  # noqa: F401
                                         restore, save)
