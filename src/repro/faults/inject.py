"""Deterministic fault injectors for the serving-robustness chaos suite.

Four fault families, all seedable and process-local:

  ROM corruption      :func:`flip_rom_bit` — flip one bit of a compiled
                      :class:`repro.api.InterpLibrary`'s resident
                      coefficient ROM while *keeping its sealed checksum*,
                      exactly what a post-load memory fault looks like to
                      ``verify_resident()``.
  poisoned inputs     :func:`poison_prompt` (out-of-range token ids) and
                      :func:`poison_values` (NaN/Inf/huge floats planted
                      into an activation array) — the inputs
                      ``GuardedNumerics`` and the admission validator must
                      catch.
  tick faults         :class:`TickFaultInjector` — wraps a
                      ``ServeEngine``'s jitted tick to delay a tick
                      (wedged dispatch), drop it (no progress), or replace
                      its token/sentinel output with NaN-poisoned values
                      (tripping the engine watchdog) on a seeded schedule.
  crash points        :func:`crashpoint`/:func:`arm_crashpoint` — named
                      markers compiled into the engine's journaled state
                      transitions; arming one makes the N-th hit raise
                      :class:`Crashed`, simulating a kill-9 *between* two
                      specific durability events. The recovery tests
                      assert the journal protocol survives a crash at
                      every marker.

Nothing here mutates global state except the crash-point registry, which
tests reset via :func:`reset_crashpoints` (autouse-fixture friendly).
"""
from __future__ import annotations

import time
from typing import Callable

import numpy as np


# ---------------------------------------------------------------------------
# ROM corruption
# ---------------------------------------------------------------------------

def flip_rom_bit(library, *, seed: int = 0, bit: int | None = None):
    """Return a copy of ``library`` with ONE coefficient bit flipped but the
    original sealed checksum retained — ``verify_resident()`` on the result
    must fail. The flip location is drawn deterministically from ``seed``
    (or forced with ``bit``, an absolute bit index into the packed ROM)."""
    import jax.numpy as jnp

    from repro.api.library import InterpLibrary

    coeffs = np.array(np.asarray(library.coeffs, np.int32))  # private copy
    nbits = coeffs.size * 32
    if bit is None:
        bit = int(np.random.default_rng(seed).integers(0, nbits))
    flat = coeffs.reshape(-1)
    flat[bit // 32] ^= np.int32(1) << np.int32(bit % 32)
    flipped = InterpLibrary(jnp.asarray(coeffs), library.metas)
    # carry the victim's baseline over: the flip must be *detected*, not
    # re-sealed away
    flipped.seal(library.sealed_sha or library.rom_sha())
    return flipped


# ---------------------------------------------------------------------------
# poisoned inputs
# ---------------------------------------------------------------------------

def poison_prompt(prompt: np.ndarray, vocab_size: int, *, seed: int = 0,
                  n: int = 1) -> np.ndarray:
    """Plant ``n`` out-of-range token ids into a copy of ``prompt`` — the
    admission-time validation target (an OOB id would silently clamp
    through the embedding gather and decode plausible-looking garbage)."""
    rng = np.random.default_rng(seed)
    out = np.array(prompt, np.int32)
    idx = rng.choice(len(out), size=min(n, len(out)), replace=False)
    out[idx] = vocab_size + rng.integers(1, 1 << 20, size=len(idx))
    return out


def poison_values(x, *, seed: int = 0, frac: float = 0.05,
                  kind: str = "nan"):
    """Plant non-finite (or absurdly large) values into a float array copy:
    ``kind`` in {"nan", "inf", "-inf", "huge"}. Deterministic in ``seed``."""
    rng = np.random.default_rng(seed)
    out = np.array(x, np.float32)
    flat = out.reshape(-1)
    n = max(1, int(len(flat) * frac))
    idx = rng.choice(len(flat), size=n, replace=False)
    flat[idx] = {"nan": np.nan, "inf": np.inf, "-inf": -np.inf,
                 "huge": 3.0e38}[kind]
    return out


# ---------------------------------------------------------------------------
# tick faults
# ---------------------------------------------------------------------------

class FaultClock:
    """A controllable monotonic clock for deadline/watchdog tests: pass as
    ``ServeEngine(clock=...)`` and ``advance`` it instead of sleeping."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> None:
        self.now += float(dt)


class TickFaultInjector:
    """Wrap a ``ServeEngine``'s tick executable with a seeded fault schedule.

    ``install(engine)`` interposes on ``engine._tick_fn``; each engine tick
    consults the schedule:

      "delay"   sleep ``delay_s`` (or advance the engine's FaultClock)
                before running the real tick — a wedged dispatch, visible
                to the stall watchdog;
      "nan"     run the real tick but poison its token/sentinel outputs
                with a non-finite marker — trips the in-program NaN/Inf
                watchdog exactly like a poisoned datapath would;
      "drop"    skip the dispatch entirely: no tokens, no progress.

    ``every_n``: fault on ticks where ``tick_index % every_n == offset``
    (deterministic — no RNG on the schedule, runs replay exactly).
    """

    def __init__(self, mode: str = "nan", *, every_n: int = 2,
                 offset: int = 0, delay_s: float = 0.0, limit: int | None = 1):
        if mode not in ("delay", "nan", "drop"):
            raise ValueError(f"unknown tick fault mode {mode!r}")
        self.mode = mode
        self.every_n, self.offset = max(1, every_n), offset
        self.delay_s = delay_s
        self.limit = limit  # max faults to inject (None = unbounded)
        self.ticks = 0
        self.injected = 0

    def _due(self) -> bool:
        due = (self.ticks % self.every_n) == (self.offset % self.every_n)
        self.ticks += 1
        if not due or (self.limit is not None and self.injected >= self.limit):
            return False
        self.injected += 1
        return True

    def install(self, engine) -> "TickFaultInjector":
        import jax.numpy as jnp

        real_tick_fn = engine._tick_fn
        injector = self

        def faulty_tick_fn(steps: int) -> Callable:
            real = real_tick_fn(steps)

            def tick(params, tok, pos, live, caches, cross=None,
                     library=None):
                due = injector._due()
                if due and injector.mode == "delay":
                    clk = getattr(engine, "clock", None)
                    if isinstance(clk, FaultClock):
                        clk.advance(injector.delay_s)
                    else:
                        time.sleep(injector.delay_s)
                if due and injector.mode == "drop":
                    # no dispatch at all: echo the inputs, zero tokens, and
                    # a tripped sentinel (a dropped tick IS a fault)
                    b = tok.shape[0]
                    toks = jnp.zeros((steps, b), jnp.int32)
                    ok = jnp.zeros((b,), jnp.bool_)
                    return toks, tok, pos, ok, caches
                out = real(params, tok, pos, live, caches, cross=cross,
                           library=library)
                if due and injector.mode == "nan":
                    toks, tok2, pos2, ok, caches2 = out
                    return toks, tok2, pos2, jnp.zeros_like(ok), caches2
                return out

            return tick

        engine._tick_fn = faulty_tick_fn
        return self


# ---------------------------------------------------------------------------
# crash points (simulated kill-9 between durability events)
# ---------------------------------------------------------------------------

class Crashed(BaseException):
    """Simulated hard kill at a named crash point. Deliberately a
    ``BaseException``: ordinary ``except Exception`` recovery code must
    not swallow it, exactly like a real SIGKILL."""

    def __init__(self, point: str):
        self.point = point
        super().__init__(f"simulated crash at {point!r}")


_ARMED: dict[str, int] = {}  # point name -> remaining hits before crash


def arm_crashpoint(point: str, *, after: int = 0) -> None:
    """Arm ``point``: the ``after``-th subsequent hit raises (0 = next)."""
    _ARMED[point] = int(after)


def reset_crashpoints() -> None:
    _ARMED.clear()


def crashpoints_armed() -> dict[str, int]:
    return dict(_ARMED)


def crashpoint(point: str) -> None:
    """Marker compiled into crash-safe code paths; free when unarmed."""
    if not _ARMED:
        return
    left = _ARMED.get(point)
    if left is None:
        return
    if left <= 0:
        del _ARMED[point]
        raise Crashed(point)
    _ARMED[point] = left - 1
