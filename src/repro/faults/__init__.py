"""repro.faults — deterministic, seedable fault injection (DESIGN.md §14).

Injectors for the chaos suite and ``benchmarks/chaos_serve.py``: ROM bit
flips, poisoned prompts/activations, dropped/delayed/NaN'd serve ticks,
and named crash points that simulate a kill-9 at precise code locations.
Everything is driven by explicit seeds — a chaos run is a reproducible
experiment, not a fuzzer.
"""
from repro.faults.inject import (Crashed, FaultClock, TickFaultInjector,  # noqa: F401
                                 arm_crashpoint, crashpoint, crashpoints_armed,
                                 flip_rom_bit, poison_prompt, poison_values,
                                 reset_crashpoints)
