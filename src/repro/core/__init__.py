"""Core library: the paper's complete polynomial-interpolation design space.

The public entry point is ``repro.api`` (Explorer sessions, Target registry,
ExploreConfig); this package holds the underlying machinery:
    get_spec            — fixed-point function specifications (funcspec)
    run_decision        — §III decision procedure, policy-driven (decision)
    regions_feasible    — Eqns 9-10 feasibility (designspace)
    generate_remez_table— FloPoCo-style Remez baseline (remez)
Legacy shims (generate_table, sweep_lub, generate_for_r, min_feasible_r)
delegate to the default Explorer and stay importable from here.
"""
from repro.core.decision import run_decision  # noqa: F401
from repro.core.designspace import build_design_space, minimal_k, regions_feasible  # noqa: F401
from repro.core.funcspec import FunctionSpec, get_spec  # noqa: F401
from repro.core.generate import (GenResult, generate_for_r, generate_table,  # noqa: F401
                                 min_feasible_r, sweep_lub)
from repro.core.remez import generate_remez_table  # noqa: F401
from repro.core.table import TableDesign  # noqa: F401
