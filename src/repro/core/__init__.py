"""Core library: the paper's complete polynomial-interpolation design space.

Public API:
    get_spec            — fixed-point function specifications (funcspec)
    generate_table      — spec -> verified TableDesign (generate)
    sweep_lub           — LUT-height sweep (generate)
    run_decision        — §III decision procedure (decision)
    regions_feasible    — Eqns 9-10 feasibility (designspace)
    generate_remez_table— FloPoCo-style Remez baseline (remez)
"""
from repro.core.decision import run_decision  # noqa: F401
from repro.core.designspace import build_design_space, minimal_k, regions_feasible  # noqa: F401
from repro.core.funcspec import FunctionSpec, get_spec  # noqa: F401
from repro.core.generate import (GenResult, generate_for_r, generate_table,  # noqa: F401
                                 min_feasible_r, sweep_lub)
from repro.core.remez import generate_remez_table  # noqa: F401
from repro.core.table import TableDesign  # noqa: F401
