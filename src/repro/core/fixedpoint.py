"""Fixed-point format helpers (paper §II notation).

A format ``n.m`` has ``n`` integer bits and ``m`` fractional bits; an unsigned
integer code ``Z`` in ``[0, 2^(n+m))`` represents the real value ``Z * 2^-m``
(plus any affine range mapping owned by the function spec, e.g. the implicit
leading ``1.`` of the paper's ``1/1.x`` reciprocal).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class FixedFormat:
    """An ``n.m`` unsigned fixed-point format."""

    n: int  # integer bits
    m: int  # fractional bits

    @property
    def bits(self) -> int:
        return self.n + self.m

    @property
    def count(self) -> int:
        return 1 << self.bits

    @property
    def scale(self) -> int:
        """Grid denominator: value = code / scale."""
        return 1 << self.m

    def to_real(self, code: int) -> float:
        return code / self.scale

    def __str__(self) -> str:  # "n.m"
        return f"{self.n}.{self.m}"


def split_input(z: int, total_bits: int, lookup_bits: int) -> tuple[int, int]:
    """Split code ``z`` into (r, x): top ``R`` lookup bits and low ``W`` bits."""
    w = total_bits - lookup_bits
    return z >> w, z & ((1 << w) - 1)


def join_input(r: int, x: int, total_bits: int, lookup_bits: int) -> int:
    w = total_bits - lookup_bits
    return (r << w) | x


def bit_length_of(value: int) -> int:
    """Bits needed for unsigned ``value`` (paper: ceil(log2(s+1)))."""
    return max(int(value).bit_length(), 1) if value >= 0 else int(-value).bit_length() + 1


def ceil_log2(x: int) -> int:
    return max(math.ceil(math.log2(x)), 0) if x > 1 else 0


def trailing_zeros(s: int) -> int:
    """max_i ((s >> i) << i == s) — trailing zero count; tz(0) = large."""
    if s == 0:
        return 63
    s = abs(int(s))
    return (s & -s).bit_length() - 1


def interval_trailing_zeros(lo: int, hi: int) -> int:
    """Largest t such that some multiple of 2^t lies in [lo, hi] (integers).

    Interval-analytic counterpart of Algorithm 1's per-element trailing-zero
    maximum: ``max_{s in [lo,hi]} tz(s)`` for non-negative intervals.
    """
    if lo > hi:
        raise ValueError("empty interval")
    if lo <= 0 <= hi:
        return 63  # zero has unbounded trailing zeros
    if hi < 0:
        lo, hi = -hi, -lo
    t = 0
    while True:
        step = 1 << (t + 1)
        if ((lo + step - 1) // step) * step > hi:
            return t
        t += 1
        if t >= 62:
            return 62


def min_bits_in_interval(lo: int, hi: int, t: int) -> int | None:
    """Min of ceil(log2(s+1)) - t over multiples s of 2^t in [lo, hi], |s| form.

    Works on non-negative intervals (callers split signs). Returns None if no
    multiple of 2^t is in range.
    """
    if lo > hi:
        return None
    step = 1 << t
    s = ((max(lo, 0) + step - 1) // step) * step
    if s > hi:
        return None
    # smallest magnitude multiple minimizes the bit count
    return max(bit_length_of(s) - t, 0) if s > 0 else 0
