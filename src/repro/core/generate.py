"""Top-level table generation: the public entry point of the core library.

``generate_table(spec)`` reproduces the paper's flow end to end: find the
feasible lookup-bit range, run the §III decision procedure per R, rank by the
area-delay proxy (paper: "We select the number of lookup bits based on the
best area-delay product") and return a verified artifact.
"""
from __future__ import annotations

import dataclasses
import time

from repro.core import area as area_model
from repro.core.decision import DecisionReport, run_decision
from repro.core.designspace import regions_feasible
from repro.core.funcspec import FunctionSpec
from repro.core.table import TableDesign


@dataclasses.dataclass
class GenResult:
    design: TableDesign
    report: DecisionReport
    runtime_s: float
    area: float
    delay: float

    @property
    def area_delay(self) -> float:
        return self.area * self.delay


def generate_for_r(spec: FunctionSpec, lookup_bits: int, degree: int | None = None,
                   impl: str = "hull", processes: int | None = None
                   ) -> GenResult | None:
    t0 = time.perf_counter()
    out = run_decision(spec, lookup_bits, degree=degree, impl=impl,
                       processes=processes)
    if out is None:
        return None
    design, report = out
    ad = area_model.estimate(design)
    return GenResult(design, report, time.perf_counter() - t0, ad.area, ad.delay)


def min_feasible_r(spec: FunctionSpec, impl: str = "hull",
                   r_max: int | None = None) -> int | None:
    """Smallest R whose every region passes Eqns 9-10 (min #regions needed —
    the 'minimum number of regions' knowledge the abstract advertises)."""
    r_max = spec.in_bits if r_max is None else r_max
    for r in range(0, r_max + 1):
        ok, _ = regions_feasible(spec, r, impl)
        if ok:
            return r
    return None


def sweep_lub(spec: FunctionSpec, r_lo: int | None = None, r_hi: int | None = None,
              degree: int | None = None, impl: str = "hull") -> list[GenResult]:
    """Generate designs across LUT heights (Fig 3's x-axis)."""
    if r_lo is None:
        r_lo = min_feasible_r(spec, impl)
        if r_lo is None:
            return []
    r_hi = min(spec.in_bits, r_lo + 6) if r_hi is None else r_hi
    out = []
    for r in range(r_lo, r_hi + 1):
        res = generate_for_r(spec, r, degree=degree, impl=impl)
        if res is not None:
            out.append(res)
    return out


def generate_table(spec: FunctionSpec, lookup_bits: int | None = None,
                   degree: int | None = None, impl: str = "hull") -> GenResult:
    """Best-area-delay design; fixed R if given, else swept."""
    if lookup_bits is not None:
        res = generate_for_r(spec, lookup_bits, degree=degree, impl=impl)
        if res is None:
            raise ValueError(f"no feasible design: {spec.name} R={lookup_bits}")
        return res
    results = sweep_lub(spec, degree=degree, impl=impl)
    if not results:
        raise ValueError(f"no feasible design for {spec.name}")
    return min(results, key=lambda g: g.area_delay)
