"""Legacy table-generation entry points — thin shims over ``repro.api``.

.. deprecated::
    ``generate_table`` / ``sweep_lub`` / ``generate_for_r`` /
    ``min_feasible_r`` predate the :class:`repro.api.Explorer` session and
    are kept for callers of the seed API. They delegate to the process-wide
    default Explorer (so they now share its envelope cache and worker pool)
    and preserve the seed's exact semantics: sweep from the minimum feasible
    R over 7 heights, rank by the ASIC area-delay product.

New code should use::

    from repro.api import Explorer, ExploreConfig
    with Explorer(ExploreConfig(...)) as ex:
        best = ex.explore(spec).best
"""
from __future__ import annotations

import dataclasses

from repro.core.decision import DecisionReport
from repro.core.funcspec import FunctionSpec
from repro.core.table import TableDesign


@dataclasses.dataclass
class GenResult:
    design: TableDesign
    report: DecisionReport
    runtime_s: float
    area: float
    delay: float

    @property
    def area_delay(self) -> float:
        return self.area * self.delay


def _as_genresult(entry) -> GenResult:
    return GenResult(entry.design, entry.report, entry.runtime_s,
                     entry.area, entry.delay)


def generate_for_r(spec: FunctionSpec, lookup_bits: int, degree: int | None = None,
                   impl: str = "hull", processes: int | None = None
                   ) -> GenResult | None:
    """Deprecated shim: one fixed-R decision run on the default Explorer
    (``processes`` is ignored — configure ``ExploreConfig.workers`` instead)."""
    from repro.api import default_explorer

    entry = default_explorer().explore_r(spec, lookup_bits, target="asic",
                                         degree=degree, impl=impl)
    return None if entry is None else _as_genresult(entry)


def min_feasible_r(spec: FunctionSpec, impl: str = "hull",
                   r_max: int | None = None) -> int | None:
    """Deprecated shim: smallest R whose every region passes Eqns 9-10
    (min #regions needed — the 'minimum number of regions' knowledge the
    abstract advertises)."""
    from repro.api import default_explorer

    return default_explorer().min_regions(spec, r_max=r_max, impl=impl)


def sweep_lub(spec: FunctionSpec, r_lo: int | None = None, r_hi: int | None = None,
              degree: int | None = None, impl: str = "hull") -> list[GenResult]:
    """Deprecated shim: designs across LUT heights (Fig 3's x-axis)."""
    from repro.api import default_explorer

    res = default_explorer().explore(spec, target="asic", r_lo=r_lo, r_hi=r_hi,
                                     degree=degree, impl=impl)
    return [_as_genresult(e) for e in res.entries]


def generate_table(spec: FunctionSpec, lookup_bits: int | None = None,
                   degree: int | None = None, impl: str = "hull") -> GenResult:
    """Deprecated shim: best-area-delay design; fixed R if given, else swept."""
    from repro.api import default_explorer

    res = default_explorer().explore(spec, target="asic", lookup_bits=lookup_bits,
                                     degree=degree, impl=impl)
    if not res.entries:
        if lookup_bits is not None:
            raise ValueError(f"no feasible design: {spec.name} R={lookup_bits}")
        raise ValueError(f"no feasible design for {spec.name}")
    return _as_genresult(res.best)
