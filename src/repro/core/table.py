"""Interpolation table artifact + exhaustive bit-exact verification.

A ``TableDesign`` is the framework's equivalent of the paper's generated RTL:
a coefficient ROM (one (a, b, c) row per region) plus the static datapath
parameters (k, square/linear input truncations, coefficient widths/shifts).
``verify`` replaces the paper's HECTOR formal check with an exhaustive int64
sweep over every input code — exact, and feasible at the widths we target.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

import numpy as np

from repro.core.funcspec import FunctionSpec


@dataclasses.dataclass
class CoeffMeta:
    """Storage format of one coefficient column (Algorithm 1 output)."""

    bits: int  # stored magnitude bits P
    shift: int  # trailing zeros truncated from storage
    signed: bool  # whether a sign bit is stored

    @property
    def width(self) -> int:  # LUT column width as reported in Table II
        return self.bits + (1 if self.signed else 0)

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class TableDesign:
    """A concrete, verified piecewise-polynomial implementation."""

    name: str
    in_bits: int
    out_bits: int
    lookup_bits: int  # R
    k: int
    degree: int  # 1 (linear) or 2 (quadratic)
    sq_trunc: int  # i: low bits of x zeroed before squaring
    lin_trunc: int  # j: low bits of x zeroed in the linear term
    a: np.ndarray  # (2^R,) int64
    b: np.ndarray
    c: np.ndarray
    a_meta: CoeffMeta
    b_meta: CoeffMeta
    c_meta: CoeffMeta
    # lazily-populated device-side coefficient arrays (see device_coeffs);
    # excluded from serialization and never part of design identity
    _device_cache: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    @property
    def eval_bits(self) -> int:  # W
        return self.in_bits - self.lookup_bits

    @property
    def lut_widths(self) -> tuple[int, int, int]:
        return (self.a_meta.width, self.b_meta.width, self.c_meta.width)

    @property
    def lut_total_width(self) -> int:
        return sum(self.lut_widths)

    def eval_int(self, codes: np.ndarray) -> np.ndarray:
        """Exact integer evaluation: floor((a*sq(x) + b*lin(x) + c) / 2^k).

        Arithmetic right shift on signed int64 == floor division by 2^k,
        matching the paper's floor semantics.
        """
        codes = np.asarray(codes, dtype=np.int64)
        w = self.eval_bits
        r = codes >> w
        x = codes & ((1 << w) - 1)
        xs = (x >> self.sq_trunc) << self.sq_trunc
        xl = (x >> self.lin_trunc) << self.lin_trunc
        acc = self.a[r] * xs * xs + self.b[r] * xl + self.c[r]
        return acc >> self.k

    def verify(self, spec: FunctionSpec) -> tuple[bool, int]:
        """Exhaustive check: every input's output inside [L, U].

        Returns (ok, worst signed violation in output ULPs; 0 when ok).
        """
        lo, hi = spec.bound_arrays()
        codes = np.arange(1 << self.in_bits, dtype=np.int64)
        y = self.eval_int(codes)
        under = lo - y
        over = y - hi
        worst = int(max(under.max(), over.max()))
        return worst <= 0, max(worst, 0)

    def max_error_ulp(self, spec: FunctionSpec) -> float:
        """Max |y - value| in output ULPs against the real-valued target."""
        if spec.value is None:
            raise ValueError("spec has no real-valued target")
        codes = np.arange(1 << self.in_bits, dtype=np.int64)
        y = self.eval_int(codes).astype(np.float64)
        return float(np.abs(y - spec.value(codes)).max())

    # -- serialization ----------------------------------------------------
    def to_dict(self) -> dict[str, Any]:
        d = {
            "name": self.name,
            "in_bits": self.in_bits,
            "out_bits": self.out_bits,
            "lookup_bits": self.lookup_bits,
            "k": self.k,
            "degree": self.degree,
            "sq_trunc": self.sq_trunc,
            "lin_trunc": self.lin_trunc,
            "a": self.a.tolist(),
            "b": self.b.tolist(),
            "c": self.c.tolist(),
            "a_meta": self.a_meta.to_dict(),
            "b_meta": self.b_meta.to_dict(),
            "c_meta": self.c_meta.to_dict(),
        }
        return d

    def to_json(self) -> str:
        return json.dumps(self.to_dict())

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "TableDesign":
        return cls(
            name=d["name"], in_bits=d["in_bits"], out_bits=d["out_bits"],
            lookup_bits=d["lookup_bits"], k=d["k"], degree=d["degree"],
            sq_trunc=d["sq_trunc"], lin_trunc=d["lin_trunc"],
            a=np.array(d["a"], dtype=np.int64),
            b=np.array(d["b"], dtype=np.int64),
            c=np.array(d["c"], dtype=np.int64),
            a_meta=CoeffMeta(**d["a_meta"]),
            b_meta=CoeffMeta(**d["b_meta"]),
            c_meta=CoeffMeta(**d["c_meta"]),
        )

    @property
    def fits_int32(self) -> bool:
        """Whether every coefficient fits the kernels' int32 ROM. Designs
        that don't (e.g. wide-output reciprocals) evaluate on the emulated
        int64 jnp path (DESIGN.md §7.5, ``interp_eval_wide``)."""
        fits = self._device_cache.get("fits")
        if fits is None:
            mat = np.stack([self.a, self.b, self.c], axis=1)
            fits = bool(np.abs(mat).max() < 2**31)
            self._device_cache["fits"] = fits
        return fits

    def packed_coeffs(self) -> np.ndarray:
        """(2^R, 3) int32 coefficient matrix for the Pallas kernels.

        Raises if any coefficient exceeds int32 — such tables (e.g. the
        23-bit reciprocal's 37-bit c) evaluate on the int64 jnp path instead
        (DESIGN.md §7.5).
        """
        mat = np.stack([self.a, self.b, self.c], axis=1)
        if np.abs(mat).max() >= 2**31:
            raise ValueError(f"{self.name}: coefficients exceed int32")
        return mat.astype(np.int32)

    def device_coeffs(self, checked: bool = False):
        """Cached device-side (2^R, 3) int32 coefficient array.

        Every evaluation path used to re-stack the numpy columns into a
        fresh ``jnp.asarray`` on each trace; the transfer now happens once
        per design. ``checked=True`` additionally enforces the int32 range
        (``packed_coeffs``) — the Pallas kernels require it, the jnp paths
        keep the historical silent-wrap semantics for oversized tables.
        """
        import jax
        import jax.numpy as jnp  # local: core stays importable without jax

        if checked and "checked" not in self._device_cache:
            self.packed_coeffs()  # raises on overflow; same int32 values
            self._device_cache["checked"] = True
        dev = self._device_cache.get("coeffs")
        if dev is None:
            mat = self._device_cache.get("host")
            if mat is None:
                mat = np.stack([self.a, self.b, self.c], axis=1).astype(np.int32)
                self._device_cache["host"] = mat
            # under an active trace jnp.asarray returns a tracer even for a
            # concrete numpy constant (verified on jax 0.4.37) — caching one
            # would leak it; mid-trace callers reuse the host cache only
            dev = jnp.asarray(mat)
            if isinstance(dev, jax.core.Tracer):
                return dev
            self._device_cache["coeffs"] = dev
        return dev

    def device_coeffs_wide(self):
        """Cached device-side (2^R, 3, 2) int32 [hi, lo] word pairs of the
        int64 coefficients — the operand of ``interp_eval_wide``, the exact
        evaluation path for designs whose coefficients exceed int32."""
        import jax
        import jax.numpy as jnp  # local: core stays importable without jax

        dev = self._device_cache.get("wide")
        if dev is None:
            mat = self._device_cache.get("wide_host")
            if mat is None:
                m64 = np.stack([self.a, self.b, self.c], axis=1)
                hi = (m64 >> 32).astype(np.int32)
                lo = (m64 & 0xFFFFFFFF).astype(np.uint32).view(np.int32)
                mat = np.stack([hi, lo], axis=-1)
                self._device_cache["wide_host"] = mat
            dev = jnp.asarray(mat)
            if isinstance(dev, jax.core.Tracer):  # see device_coeffs
                return dev
            self._device_cache["wide"] = dev
        return dev
