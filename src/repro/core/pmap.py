"""Region-parallel execution — the paper's §V future-work line
("Scalability concerns could be addressed by introducing parallelism").

Regions are embarrassingly parallel in every phase of §II generation: the
M/m envelopes, the Eqn 9-10 feasibility searches and the truncation
re-checks of §III all touch one region's (L, U) rows only. ``RegionPool``
wraps a fork-based process pool; all submitted callables must be
module-level (picklable) functions.

Since ISSUE 2 this pool is the ``engine="pooled"`` fallback only: the
default region backend is ``core.batched``, which runs the same per-region
math as one array program over stacked ``(regions, N)`` rows — no pickling,
no per-region Python dispatch — and is bit-identical to the pooled path
(it doubles as the equivalence oracle in tests/core/test_batched.py).
"""
from __future__ import annotations

import contextlib
import multiprocessing as mp
import os
from typing import Callable, Iterable, Sequence


def default_processes() -> int:
    return max(1, min(8, os.cpu_count() or 1))


class RegionPool:
    """map() over per-region work items; transparent when processes <= 1."""

    def __init__(self, processes: int | None = None):
        self.processes = 1 if processes is None else processes
        self._pool = None

    def __enter__(self):
        if self.processes > 1:
            self._pool = mp.get_context("fork").Pool(self.processes)
        return self

    def __exit__(self, exc_type=None, exc=None, tb=None):
        if self._pool is not None:
            if exc_type is None:
                # clean exit: let in-flight pooled work drain before joining
                # (terminate() here used to kill submitted regions mid-map)
                self._pool.close()
            else:
                self._pool.terminate()
            self._pool.join()
            self._pool = None

    def map(self, fn: Callable, items: Sequence, chunksize: int | None = None):
        if self._pool is None or len(items) <= 1:
            return [fn(it) for it in items]
        cs = chunksize or max(1, len(items) // (4 * self.processes))
        return self._pool.map(fn, items, cs)


@contextlib.contextmanager
def region_pool(processes: int | None):
    with RegionPool(processes) as p:
        yield p
