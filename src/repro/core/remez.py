"""Minimax (Remez-exchange) baseline — the FloPoCo/Sollya stand-in.

The paper compares its complete-space tables against FloPoCo, whose
polynomials come from Sollya's modified Remez algorithm (paper refs [8-11]).
FloPoCo is not installable here, so we implement the same *method*: per
region, a discrete Remez exchange computes the real minimax polynomial of the
target values; coefficients are then rounded to finite precision at the
smallest k that still meets the bound spec, with the constant re-centred
exactly after rounding (the standard trick). Table II's comparison (Remez
needs wider `a`) is reproduced against this baseline.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.fixedpoint import bit_length_of
from repro.core.funcspec import FunctionSpec
from repro.core.table import CoeffMeta, TableDesign


def remez_fit(xs: np.ndarray, vals: np.ndarray, degree: int,
              iters: int = 60) -> np.ndarray:
    """Discrete minimax polynomial coefficients (low-to-high) on grid xs."""
    n = len(xs)
    if n <= degree + 1:
        return _exact_fit(xs, vals, degree)
    # initial reference: Chebyshev-spaced indices
    ref = np.unique(np.round(
        (n - 1) * (0.5 - 0.5 * np.cos(np.pi * np.arange(degree + 2) / (degree + 1)))
    ).astype(int))
    while len(ref) < degree + 2:
        pool = np.setdiff1d(np.arange(n), ref)
        ref = np.sort(np.append(ref, pool[0]))
    coeffs = np.zeros(degree + 1)
    for _ in range(iters):
        # solve p(x_i) + (-1)^i E = v_i on the reference
        a_mat = np.vander(xs[ref], degree + 1, increasing=True)
        sys = np.hstack([a_mat, ((-1.0) ** np.arange(len(ref)))[:, None]])
        sol, *_ = np.linalg.lstsq(sys, vals[ref], rcond=None)
        coeffs = sol[:-1]
        err = np.polyval(coeffs[::-1], xs) - vals
        worst = int(np.argmax(np.abs(err)))
        if worst in ref:
            break
        # single-point exchange preserving sign alternation
        new_ref = ref.copy()
        pos = np.searchsorted(ref, worst)
        if pos == 0:
            new_ref[0] = worst if np.sign(err[worst]) == np.sign(err[ref[0]]) else new_ref[0]
            if np.sign(err[worst]) != np.sign(err[ref[0]]):
                new_ref = np.sort(np.append(ref[:-1], worst))
        elif pos >= len(ref):
            if np.sign(err[worst]) == np.sign(err[ref[-1]]):
                new_ref[-1] = worst
            else:
                new_ref = np.sort(np.append(ref[1:], worst))
        else:
            side = pos if np.sign(err[worst]) == np.sign(err[ref[pos]]) else pos - 1
            new_ref[side] = worst
        new_ref = np.unique(new_ref)
        if len(new_ref) < degree + 2 or np.array_equal(new_ref, ref):
            break
        ref = new_ref
    return coeffs


def _exact_fit(xs: np.ndarray, vals: np.ndarray, degree: int) -> np.ndarray:
    c = np.polyfit(xs, vals, min(degree, len(xs) - 1))[::-1]
    return np.pad(c, (0, degree + 1 - len(c)))


@dataclasses.dataclass
class RemezResult:
    design: TableDesign
    k: int
    widths: tuple[int, int, int]


def generate_remez_table(spec: FunctionSpec, lookup_bits: int, degree: int = 2,
                         k_max: int = 30) -> RemezResult | None:
    """Round-and-verify loop: smallest k whose rounded minimax coefficients
    satisfy the integer bound spec in every region (c re-centred exactly)."""
    lo_all, hi_all = spec.region_bounds(lookup_bits)
    n_regions, n = lo_all.shape
    xs = np.arange(n, dtype=np.float64)
    x_int = np.arange(n, dtype=np.int64)
    # real minimax fit of the bound midpoints per region
    fits = np.zeros((n_regions, degree + 1))
    mids = (lo_all + hi_all).astype(np.float64) / 2.0
    for r in range(n_regions):
        fits[r] = (remez_fit(xs, mids[r], degree) if n > 1
                   else np.array([mids[r][0]] + [0.0] * degree))

    for k in range(k_max + 1):
        scale = float(1 << k)
        av = np.round(fits[:, 2] * scale).astype(np.int64) if degree == 2 else np.zeros(n_regions, np.int64)
        bv = np.round(fits[:, 1] * scale).astype(np.int64)
        cv = np.zeros(n_regions, dtype=np.int64)
        ok = True
        for r in range(n_regions):
            poly = av[r] * x_int * x_int + bv[r] * x_int
            c_lo = int(((lo_all[r].astype(np.int64) << k) - poly).max())
            c_hi = int((((hi_all[r].astype(np.int64) + 1) << k) - poly).min()) - 1
            if c_lo > c_hi:
                ok = False
                break
            cv[r] = (c_lo + c_hi) // 2  # exact re-centring
        if not ok:
            continue

        def meta(vals: np.ndarray) -> CoeffMeta:
            signed = bool((vals < 0).any())
            mags = np.abs(vals)
            return CoeffMeta(bits=max(bit_length_of(int(mags.max())), 1) if mags.max() else 0,
                             shift=0, signed=signed)

        design = TableDesign(
            name=f"{spec.name}_remez_R{lookup_bits}", in_bits=spec.in_bits,
            out_bits=spec.out_bits, lookup_bits=lookup_bits, k=k, degree=degree,
            sq_trunc=0, lin_trunc=0, a=av, b=bv, c=cv,
            a_meta=meta(av), b_meta=meta(bv), c_meta=meta(cv),
        )
        valid, _ = design.verify(spec)
        if valid:
            return RemezResult(design=design, k=k, widths=design.lut_widths)
    return None
