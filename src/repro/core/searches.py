"""2-D divided-difference searches (paper §II-A).

Everything in design-space generation reduces to searches of the form

    max_{x < y} D(x, y),   D(x, y) = (g(y) - h(x)) / (y - x)

(or the min, obtained by negation). Four implementations are kept on purpose:

* ``naive``      — scalar double loop; the paper's baseline.
* ``claim21``    — scalar loop with the paper's Claim II.1 column pruning
                   (reported 5x faster @ 16-bit reciprocal; benchmarked in
                   benchmarks/claim21.py).
* ``vectorized`` — per-delta numpy sweep, O(N^2) work, data-parallel
                   (the "introduce parallelism" future-work line of §V).
* ``hull``       — beyond-paper O(N log N): incremental lower convex hull of
                   the (x, h[x]) points + binary search for the tangent from
                   each (y, g[y]). Exact (maxima of slopes from an external
                   point over a point set are attained at hull vertices).

All four are property-tested for equivalence in tests/core/test_searches.py.
"""
from __future__ import annotations

from typing import Callable

import numpy as np

Result = tuple[float, int, int]  # (value, argmax x, argmax y)

_NEG_INF: Result = (-np.inf, -1, -1)


def max_dd_naive(g: np.ndarray, h: np.ndarray) -> Result:
    n = len(g)
    best, bx, by = _NEG_INF
    for x in range(n - 1):
        hx = h[x]
        for y in range(x + 1, n):
            d = (g[y] - hx) / (y - x)
            if d > best:
                best, bx, by = d, x, y
    return best, bx, by


def max_dd_claim21(g: np.ndarray, h: np.ndarray) -> Result:
    """Claim II.1: once (x', y') is optimal among columns <= x', a later column
    x can only win if D(x', y') > (h(x) - h(x')) / (x - x')."""
    n = len(g)
    best, bx, by = _NEG_INF
    for x in range(n - 1):
        if bx >= 0:
            gate = (h[x] - h[bx]) / (x - bx)
            if best <= gate:
                continue  # no y in this column can beat the incumbent
        hx = h[x]
        for y in range(x + 1, n):
            d = (g[y] - hx) / (y - x)
            if d > best:
                best, bx, by = d, x, y
    return best, bx, by


def max_dd_vectorized(g: np.ndarray, h: np.ndarray) -> Result:
    n = len(g)
    if n < 2:
        return _NEG_INF
    g = np.asarray(g, dtype=np.float64)
    h = np.asarray(h, dtype=np.float64)
    best, bx, by = _NEG_INF
    for delta in range(1, n):
        d = (g[delta:] - h[: n - delta]) / delta
        i = int(np.argmax(d))
        if d[i] > best:
            best, bx, by = float(d[i]), i, i + delta
    return best, bx, by


def _hull_tangent_max(hull_x: list[int], hull_y: list[float], gx: int, gy: float) -> tuple[float, int]:
    """Max slope from external point (gx, gy) to vertices of a lower convex
    hull (hull strictly left of gx). Slopes are unimodal over vertex index."""
    lo, hi = 0, len(hull_x) - 1

    def slope(i: int) -> float:
        return (gy - hull_y[i]) / (gx - hull_x[i])

    while hi - lo > 1:
        mid = (lo + hi) // 2
        if slope(mid) < slope(mid + 1):
            lo = mid + 1
        else:
            hi = mid
    if slope(lo) >= slope(hi):
        return slope(lo), hull_x[lo]
    return slope(hi), hull_x[hi]


def max_dd_hull(g: np.ndarray, h: np.ndarray) -> Result:
    """O(N log N): sweep y ascending; maintain lower hull of (x, h[x]), x < y."""
    n = len(g)
    if n < 2:
        return _NEG_INF
    hull_x: list[int] = []
    hull_y: list[float] = []
    best, bx, by = _NEG_INF
    for y in range(1, n):
        # push x = y - 1 onto the lower hull
        x, hx = y - 1, float(h[y - 1])
        while len(hull_x) >= 2:
            x1, y1 = hull_x[-1], hull_y[-1]
            x0, y0 = hull_x[-2], hull_y[-2]
            # pop if (x1, y1) is above or on segment (x0,y0)-(x,hx)
            if (y1 - y0) * (x - x0) >= (hx - y0) * (x1 - x0):
                hull_x.pop(), hull_y.pop()
            else:
                break
        hull_x.append(x), hull_y.append(hx)
        val, arg = _hull_tangent_max(hull_x, hull_y, y, float(g[y]))
        if val > best:
            best, bx, by = val, arg, y
    return best, bx, by


IMPLS: dict[str, Callable[[np.ndarray, np.ndarray], Result]] = {
    "naive": max_dd_naive,
    "claim21": max_dd_claim21,
    "vectorized": max_dd_vectorized,
    "hull": max_dd_hull,
}

_DEFAULT_IMPL: str | None = None  # lazy memo of api.config.DEFAULT_IMPL


def resolve_impl(impl: str | None) -> str:
    """``impl`` or the single session-wide default (``api.config.DEFAULT_IMPL``).

    The import is deferred (and memoized) so the low-level search module
    never participates in the ``repro.api`` import cycle.
    """
    if impl is not None:
        return impl
    global _DEFAULT_IMPL
    if _DEFAULT_IMPL is None:
        from repro.api.config import DEFAULT_IMPL

        _DEFAULT_IMPL = DEFAULT_IMPL
    return _DEFAULT_IMPL


def max_dd(g: np.ndarray, h: np.ndarray, impl: str | None = None) -> Result:
    return IMPLS[resolve_impl(impl)](np.asarray(g, np.float64),
                                     np.asarray(h, np.float64))


def min_dd(g: np.ndarray, h: np.ndarray, impl: str | None = None) -> Result:
    """min_{x<y} (g[y]-h[x])/(y-x) via negation."""
    val, x, y = max_dd(-np.asarray(g, np.float64), -np.asarray(h, np.float64), impl)
    return -val, x, y
