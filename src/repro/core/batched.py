"""Batched region engine: one array program for §II generation.

The seed dispatched every region of the design space as an independent
Python/numpy call fanned out through a fork pool (``core.pmap``) — for a
full min-R sweep that is ``2^R`` pickle round-trips per probed R, and the
generation hot path ran as fast as pickling allows. This module computes
the §II M/m envelopes, Eqn 9-10 feasibility, the a-interval searches and
the §III truncation re-checks for **all regions at once** over stacked
``(regions, N)`` arrays:

* ``batched_envelopes``        strided per-delta sweeps batched over the
                               leading (region) axis — same float64
                               expressions as ``designspace.envelopes``,
                               so results are bit-identical.
* ``batched_max_dd/min_dd``    divided-difference searches over stacked
                               rows; per-delta sweep for short rows, the
                               O(T log T) hull per row once the scalar
                               loop beats the O(T^2) sweep. Values are
                               bit-identical to ``core.searches`` (every
                               implementation evaluates the same float64
                               slope on the argmax pair).
* ``region_spaces``            all RegionSpaces in one shot (exact).
* ``region_spaces_pallas``     the same through one ``pallas_call`` with a
                               grid over regions plus an on-device parity
                               merge + a-interval reduction
                               (kernels/dspace; float32 envelopes).
* ``design_candidates``        batched twin of the per-region
                               (a, b-interval) candidate generation.
* ``trunc_candidates``         batched twin of the §III step-2/3
                               truncation re-checks, over (region, a)
                               pairs per truncation level.

Every batched routine has a scalar twin in ``designspace``/``decision``
(the ``pooled`` engine), which stays available as the equivalence oracle —
see tests/core/test_batched.py and DESIGN.md §9.
"""
from __future__ import annotations

import concurrent.futures
import itertools
import os
import threading

import numpy as np

from repro.core.designspace import (A_ENUM_CAP, Candidate, RegionSpace,
                                    a_candidates, a_magnitude_order, a_window)

# Work-shape heuristics: above this row length the O(T log T) scalar hull
# beats the O(T^2) batched per-delta sweep per row (long rows only occur at
# small region counts, where the python loop is cheap anyway).
_HULL_T_THRESHOLD = 8192
# Element budget per temporary in the pair-chunked passes (~32 MiB int64).
_CHUNK_ELEMS = 1 << 22
# Row-axis thread fan-out for the element-bound loops (numpy releases the
# GIL inside ufuncs; rows are independent, so results are bit-identical to
# the serial pass). Default 1: the loops are memory-bandwidth-bound, so
# threads only pay off with real (non-SMT-sibling) cores — opt in via
# REPRO_BATCHED_THREADS on such machines. Engaged only above a work floor.
_MAX_THREADS = max(1, int(os.environ.get("REPRO_BATCHED_THREADS", "1")))
_THREAD_WORK_FLOOR = 1 << 22  # elements of O(B*N^2) work

_executor: concurrent.futures.ThreadPoolExecutor | None = None
_executor_lock = threading.Lock()


def _get_executor() -> concurrent.futures.ThreadPoolExecutor:
    global _executor
    if _executor is None:
        with _executor_lock:
            if _executor is None:
                _executor = concurrent.futures.ThreadPoolExecutor(
                    _MAX_THREADS, thread_name_prefix="batched-region")
    return _executor


def _run_row_blocks(b: int, work: int, fn) -> None:
    """Run ``fn(row_start, row_end)`` over the whole row axis, fanned out
    across threads when the element work justifies it."""
    if _MAX_THREADS == 1 or b < 2 or work < _THREAD_WORK_FLOOR:
        fn(0, b)
        return
    k = min(_MAX_THREADS, b)
    step = -(-b // k)
    futs = [_get_executor().submit(fn, s, min(b, s + step))
            for s in range(0, b, step)]
    for f in futs:
        f.result()  # propagate worker exceptions


def _chunks(total: int, width: int):
    step = max(1, _CHUNK_ELEMS // max(width, 1))
    for s in range(0, total, step):
        yield s, min(total, s + step)


# --------------------------------------------------------------------------
# Envelopes + divided-difference searches, batched over regions
# --------------------------------------------------------------------------

def batched_envelopes(L: np.ndarray, U: np.ndarray
                      ) -> tuple[np.ndarray, np.ndarray]:
    """M(t), m(t) for every region at once: two ``(B, 2N-2)`` float64 arrays.

    Row ``r`` equals ``designspace.envelopes(L[r], U[r])`` bit-for-bit: the
    per-delta strided-slice updates are the same expressions, evaluated over
    a leading batch axis.
    """
    L = np.asarray(L)
    U = np.asarray(U)
    b, n = L.shape
    if n < 2:
        return np.full((b, 1), -np.inf), np.full((b, 1), np.inf)
    lf = L.astype(np.float64)
    # Bounds are int64, so every intermediate below is an exact float64
    # integer and hoisting the +1 preserves bit-equality with the scalar
    # expressions (U[y] + 1 - L[x]) and (L[y] - U[x] - 1).
    uf1 = U.astype(np.float64) + 1.0
    # Parity-split accumulators (the kernel's center-stencil trick, DESIGN.md
    # §4/§9): a fixed delta lands on consecutive centers j of one parity, so
    # every update is a contiguous slice instead of the scalar path's
    # stride-2 read-modify-write. slot j holds t = 2j (even) / t = 2j+1 (odd).
    half = n - 1
    s_even = np.full((b, half), np.inf)
    s_odd = np.full((b, half), np.inf)
    b_even = np.full((b, half), -np.inf)
    b_odd = np.full((b, half), -np.inf)

    def block(r0: int, r1: int) -> None:
        lfb, ufb = lf[r0:r1], uf1[r0:r1]
        for delta in range(1, n):
            up = (ufb[:, delta:] - lfb[:, : n - delta]) / delta
            lo = (lfb[:, delta:] - ufb[:, : n - delta]) / delta
            e = delta // 2  # pairs (x, x+delta): j = x + e, x in [0, n-delta)
            sl = slice(e, n - e) if delta % 2 == 0 else slice(e, e + n - delta)
            tgt_s = s_even if delta % 2 == 0 else s_odd
            tgt_b = b_even if delta % 2 == 0 else b_odd
            np.minimum(tgt_s[r0:r1, sl], up, out=tgt_s[r0:r1, sl])
            np.maximum(tgt_b[r0:r1, sl], lo, out=tgt_b[r0:r1, sl])

    _run_row_blocks(b, b * n * n, block)
    t_size = 2 * n - 2
    small_m = np.empty((b, t_size))
    big_m = np.empty((b, t_size))
    small_m[:, 0::2] = s_even
    small_m[:, 1::2] = s_odd
    big_m[:, 0::2] = b_even
    big_m[:, 1::2] = b_odd
    return big_m, small_m


def batched_max_dd(g: np.ndarray, h: np.ndarray) -> np.ndarray:
    """Row-wise ``max_{x<y} (g[y]-h[x])/(y-x)`` — values only, ``(B,)``."""
    g = np.asarray(g, np.float64)
    h = np.asarray(h, np.float64)
    b, t = g.shape
    if t < 2:
        return np.full(b, -np.inf)
    if t >= _HULL_T_THRESHOLD:
        from repro.core import searches

        return np.array([searches.max_dd(g[i], h[i], "hull")[0]
                         for i in range(b)])
    best = np.full(b, -np.inf)

    def block(r0: int, r1: int) -> None:
        gb, hb = g[r0:r1], h[r0:r1]
        bb = best[r0:r1]
        for delta in range(1, t):
            # reduce-then-divide: division by a positive constant is monotone
            # in IEEE float64, so max and /delta commute — one big op saved
            # per delta, values still bit-identical to the scalar searches
            d = (gb[:, delta:] - hb[:, : t - delta]).max(axis=1)
            np.maximum(bb, d / delta, out=bb)

    _run_row_blocks(b, b * t * t, block)
    return best


def batched_min_dd(g: np.ndarray, h: np.ndarray) -> np.ndarray:
    """Row-wise min via negation (exactly as ``searches.min_dd``)."""
    return -batched_max_dd(-np.asarray(g, np.float64),
                           -np.asarray(h, np.float64))


def _dd_interval_rows(mt: np.ndarray, st: np.ndarray
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Fused Eqn 7-8 pair per row: (a_lo, a_hi) in ONE per-delta pass.

    a_lo = max (M[s]-m[t])/(s-t) and a_hi = min (m[s]-M[t])/(s-t) stream the
    same ``mt``/``st`` slices each delta, so fusing them halves the memory
    traffic of two separate sweeps. IEEE negation and positive-constant
    division are exact/monotone, so both values stay bit-identical to
    ``searches.max_dd`` / ``min_dd``.
    """
    b, t = mt.shape
    if t < 2:
        return np.full(b, -np.inf), np.full(b, np.inf)
    if t >= _HULL_T_THRESHOLD:
        return batched_max_dd(mt, st), batched_min_dd(st, mt)
    a_lo = np.full(b, -np.inf)
    a_hi = np.full(b, np.inf)

    def block(r0: int, r1: int) -> None:
        mb, sb = mt[r0:r1], st[r0:r1]
        lo_b, hi_b = a_lo[r0:r1], a_hi[r0:r1]
        for delta in range(1, t):
            d_lo = (mb[:, delta:] - sb[:, : t - delta]).max(axis=1)
            d_hi = (sb[:, delta:] - mb[:, : t - delta]).min(axis=1)
            np.maximum(lo_b, d_lo / delta, out=lo_b)
            np.minimum(hi_b, d_hi / delta, out=hi_b)

    _run_row_blocks(b, 2 * b * t * t, block)
    return a_lo, a_hi


# --------------------------------------------------------------------------
# RegionSpaces and feasibility for all regions
# --------------------------------------------------------------------------

def _trivial_spaces(big_m: np.ndarray, small_m: np.ndarray, n: int
                    ) -> list[RegionSpace]:
    """n <= 2: Eqn 10 is vacuous; a unconstrained (same as region_space)."""
    out = []
    for r in range(big_m.shape[0]):
        ok = bool(np.all(big_m[r, 1:] < small_m[r, 1:])) if n == 2 else True
        out.append(RegionSpace(big_m[r], small_m[r], -np.inf, np.inf, ok))
    return out


def region_spaces(L: np.ndarray, U: np.ndarray) -> list[RegionSpace]:
    """Batched-numpy twin of ``[region_space(L[r], U[r]) for r]`` — exact."""
    L = np.asarray(L)
    U = np.asarray(U)
    b, n = L.shape
    big_m, small_m = batched_envelopes(L, U)
    if n <= 2:
        return _trivial_spaces(big_m, small_m, n)
    feas9 = np.all(big_m[:, 1:] < small_m[:, 1:], axis=1)  # Eqn 9
    a_lo = np.full(b, np.nan)
    a_hi = np.full(b, np.nan)
    idx = np.flatnonzero(feas9)
    if idx.size:
        a_lo[idx], a_hi[idx] = _dd_interval_rows(big_m[idx, 1:],
                                                 small_m[idx, 1:])
    return [RegionSpace(big_m[r], small_m[r], float(a_lo[r]), float(a_hi[r]),
                        bool(feas9[r]) and bool(a_lo[r] < a_hi[r]))  # Eqn 10
            for r in range(b)]


def regions_feasible_mask(L: np.ndarray, U: np.ndarray) -> np.ndarray:
    """Eqns 9-10 verdict per region without materializing RegionSpaces.

    The min-R search probes many (spec, R) pairs it will never explore;
    this path skips the per-region object construction entirely.
    """
    L = np.asarray(L)
    U = np.asarray(U)
    b, n = L.shape
    if n < 2:
        return np.ones(b, bool)
    big_m, small_m = batched_envelopes(L, U)
    ok9 = np.all(big_m[:, 1:] < small_m[:, 1:], axis=1)
    if n <= 2:
        return ok9
    out = np.zeros(b, bool)
    idx = np.flatnonzero(ok9)
    if idx.size:
        a_lo, a_hi = _dd_interval_rows(big_m[idx, 1:], small_m[idx, 1:])
        out[idx] = a_lo < a_hi
    return out


def region_spaces_pallas(L: np.ndarray, U: np.ndarray,
                         interpret: bool | None = None) -> list[RegionSpace]:
    """All RegionSpaces from one device program (see kernels/dspace/ops).

    Float32 envelope precision: a marginal verdict can differ from the exact
    engines, which per the DESIGN.md §4 contract can cost a retry, never an
    unsound artifact (every emitted design is exhaustively re-verified).
    """
    L = np.asarray(L)
    U = np.asarray(U)
    b, n = L.shape
    if n <= 2:  # no device win possible; use the exact path
        return _trivial_spaces(*batched_envelopes(L, U), n)
    from repro.kernels.dspace.ops import region_envelopes_device

    big_m, small_m, a_lo, a_hi, feas9 = region_envelopes_device(
        L, U, interpret=interpret)
    out = []
    for r in range(b):
        ok = bool(feas9[r])
        lo = float(a_lo[r]) if ok else np.nan
        hi = float(a_hi[r]) if ok else np.nan
        out.append(RegionSpace(big_m[r], small_m[r], lo, hi, ok and lo < hi))
    return out


# --------------------------------------------------------------------------
# Batched candidate generation (decision step 1 body)
# --------------------------------------------------------------------------

def _flatten_pairs(avals: list[list[int]]) -> tuple[np.ndarray, np.ndarray]:
    rid: list[int] = []
    flat: list[int] = []
    for r, av in enumerate(avals):
        rid.extend([r] * len(av))
        flat.extend(av)
    return np.asarray(rid, np.int64), np.asarray(flat, np.int64)


def stack_envelopes(spaces: list[RegionSpace]
                    ) -> tuple[np.ndarray, np.ndarray]:
    """(M, m) rows of ``spaces`` stacked once, t >= 1 columns — the ``env``
    operand of :func:`design_candidates`. The fleet engine's k ladders call
    ``design_candidates`` many times over the same spaces; stacking here
    instead of per call removes the dominant per-round overhead."""
    return (np.stack([s.big_m for s in spaces])[:, 1:],
            np.stack([s.small_m for s in spaces])[:, 1:])


def design_candidates(spaces: list[RegionSpace], L: np.ndarray, U: np.ndarray,
                      k: int, force_linear: bool,
                      env: tuple[np.ndarray, np.ndarray] | None = None
                      ) -> list[list[Candidate]]:
    """Batched twin of ``designspace._region_candidates`` for every region.

    The admissible-a enumeration is per region (tiny, capped); the Eqn 3-4
    b-intervals and the exact c-interval witness confirmations run over all
    (region, a) pairs at once, chunked to a fixed temporary budget.
    ``env`` optionally injects :func:`stack_envelopes` output (row-aligned
    with ``spaces``) so repeated calls over one space set skip restacking.
    """
    L = np.asarray(L)
    U = np.asarray(U)
    b, n = L.shape
    avals: list[list[int]] = []
    for space in spaces:
        if not space.feasible or (
                force_linear and not (space.linear_ok or n <= 2)):
            avals.append([])
        elif force_linear:
            avals.append([0])
        else:
            avals.append(a_candidates(space, k))
    if n == 1:
        # c-interval is [L << k, ((U+1) << k) - 1]: nonempty for any a
        return [[Candidate(a, 0, 0) for a in av] for av in avals]
    out: list[list[Candidate]] = [[] for _ in range(b)]
    rid, a_arr = _flatten_pairs(avals)
    if rid.size == 0:
        return out
    check = _PairCheck(spaces, L, U, k, env)
    for s, e in _chunks(len(rid), max(check.t_size, n)):
        r_c, a_c = rid[s:e], a_arr[s:e]
        ok, b_min, b_max = check(r_c, a_c)
        for i in np.flatnonzero(ok):
            out[int(r_c[i])].append(
                Candidate(int(a_c[i]), int(b_min[i]), int(b_max[i])))
    return out


class _PairCheck:
    """Shared (region, a)-pair math of the decision-step-1 body: the Eqn 3-4
    b-interval plus the exact witness confirmation, vectorized over a flat
    pair axis. Row results depend only on the row, so any grouping of calls
    (chunks, waves) yields bit-identical values."""

    def __init__(self, spaces, L, U, k: int, env=None):
        self.t_size = len(spaces[0].big_m)
        self.ts = np.arange(1, self.t_size, dtype=np.float64)
        self.big_m, self.small_m = (env if env is not None
                                    else stack_envelopes(spaces))
        self.scale = float(1 << k)
        n = L.shape[1]
        self.x = np.arange(n, dtype=np.int64)
        self.sq = self.x * self.x
        self.lo_all = L.astype(np.int64) << k
        self.hi_all = (U.astype(np.int64) + 1) << k

    def __call__(self, r_c: np.ndarray, a_c: np.ndarray):
        """-> (survives, b_min, b_max) for each (region, a) pair row."""
        # Eqns 3-4 (same float64 expressions as b_interval)
        lin_t = a_c[:, None] * self.ts[None, :]
        lo = (self.scale * self.big_m[r_c] - lin_t).max(axis=1)
        hi = (self.scale * self.small_m[r_c] - lin_t).min(axis=1)
        b_min = np.floor(lo).astype(np.int64) + 1
        b_max = np.ceil(hi).astype(np.int64) - 1
        ok_iv = b_min <= b_max
        # exact confirmation at a witness b, widened one lattice step against
        # float slop in M/m — same candidate order as _region_candidates
        base_lo = self.lo_all[r_c] - a_c[:, None] * self.sq[None, :]
        base_hi = self.hi_all[r_c] - a_c[:, None] * self.sq[None, :]
        confirmed = np.zeros(len(r_c), bool)
        for b_opt in (b_min, b_min + 1, b_max, b_min - 1):
            need = ok_iv & ~confirmed
            if not need.any():
                break
            poly_b = b_opt[:, None] * self.x[None, :]
            c_lo = (base_lo - poly_b).max(axis=1)
            c_hi = (base_hi - poly_b).min(axis=1) - 1
            confirmed |= need & (c_lo <= c_hi)
        return ok_iv & confirmed, b_min, b_max


def candidates_feasible(spaces: list[RegionSpace], L: np.ndarray,
                        U: np.ndarray, k: int, force_linear: bool,
                        env: tuple[np.ndarray, np.ndarray] | None = None
                        ) -> np.ndarray:
    """Per-region verdict ``bool(design_candidates(...)[r])`` without
    materializing the candidate lists.

    The k ladders of the decision procedure discard every candidate list
    except the final k's; this check walks the same per-region admissible-a
    enumerations in |a|-rank *waves* — one stacked pair program per rank —
    and retires a region at its first surviving candidate (the common case:
    the smallest |a|, deep inside the a-interval, survives immediately).
    Verdicts are bit-identical to the full generation: the same pair rows
    run through the same :class:`_PairCheck` expressions, and existence is
    order-independent.
    """
    L = np.asarray(L)
    U = np.asarray(U)
    b, n = L.shape
    # lazy |a|-ordered window iterators: the common case retires a region on
    # its very first candidate, so the full (capped) enumeration that
    # design_candidates sorts per region is never materialized here
    iters: list = []
    for space in spaces:
        if not space.feasible or (
                force_linear and not (space.linear_ok or n <= 2)):
            iters.append(None)
        elif force_linear:
            iters.append(iter((0,)))
        else:
            win = a_window(space, k)
            iters.append(None if win is None else a_magnitude_order(*win))
    verdict = np.zeros(b, bool)
    if n == 1:  # any a works pointwise (see design_candidates)
        verdict[:] = [it is not None for it in iters]
        return verdict
    check = _PairCheck(spaces, L, U, k, env)
    pending = [r for r in range(b) if iters[r] is not None]
    width = 1  # ranks per wave: grows geometrically so a region with NO
    # surviving candidate exhausts its enumeration in O(log cap) waves
    while pending:
        rid_l: list[int] = []
        a_l: list[int] = []
        exhausted = set()
        for r in pending:
            take = list(itertools.islice(iters[r], width))
            if len(take) < width:
                exhausted.add(r)
            rid_l.extend([r] * len(take))
            a_l.extend(take)
        r_c = np.asarray(rid_l, np.int64)
        ok, _, _ = check(r_c, np.asarray(a_l, np.int64))
        verdict[r_c[ok]] = True
        pending = [r for r in pending
                   if not verdict[r] and r not in exhausted]
        width = min(4 * width, A_ENUM_CAP)
    return verdict


# --------------------------------------------------------------------------
# Batched truncation re-checks (decision steps 2-3)
# --------------------------------------------------------------------------

def batched_linear_fit(lo: np.ndarray, hi: np.ndarray, stride: int = 1
                       ) -> list[tuple[int, int] | None]:
    """Row-wise twin of ``decision.linear_fit_interval``.

    The dd bounds, the common case (both endpoint witnesses pass) and the
    empty-interval one-step widening (``b_min > b_max``: try ``b_min - 1``
    then ``b_max + 1`` — the dominant outcome on truncation trials that kill
    feasibility) are fully vectorized; only the rare float-slop endpoint
    adjustments fall back to the scalar routine row by row, so results match
    it exactly.
    """
    c, nb = lo.shape
    res: list[tuple[int, int] | None] = [None] * c
    valid = ~(lo > hi).any(axis=1)
    if nb < 2:
        for i in np.flatnonzero(valid):
            res[int(i)] = (0, 0)
        return res
    # fused per-delta pass (the Eqn 7-8 fusion of _dd_interval_rows applies
    # verbatim: b_lo = max (lo[y]-hi[x])/(y-x), b_hi = min (hi[y]-lo[x])/..)
    b_lo, b_hi = _dd_interval_rows(lo.astype(np.float64),
                                   hi.astype(np.float64))
    b_min = np.ceil(b_lo / stride - 1e-12).astype(np.int64)
    b_max = np.floor(b_hi / stride + 1e-12).astype(np.int64)
    idx = np.arange(nb, dtype=np.int64) * stride

    def ok_vec(bv: np.ndarray) -> np.ndarray:
        t = bv[:, None] * idx[None, :]
        return (lo - t).max(axis=1) <= (hi - t).min(axis=1)

    nonempty = b_min <= b_max
    fast = valid & nonempty
    fast &= ok_vec(b_min) & ok_vec(b_max)
    for i in np.flatnonzero(fast):
        res[int(i)] = (int(b_min[i]), int(b_max[i]))
    empty = valid & ~nonempty
    if empty.any():
        # same order as the scalar routine: b_min - 1 first, then b_max + 1
        w1 = empty & ok_vec(b_min - 1)
        w2 = empty & ~w1 & ok_vec(b_max + 1)
        for i in np.flatnonzero(w1):
            res[int(i)] = (int(b_min[i]) - 1, int(b_min[i]) - 1)
        for i in np.flatnonzero(w2):
            res[int(i)] = (int(b_max[i]) + 1, int(b_max[i]) + 1)
    slow = np.flatnonzero(valid & nonempty & ~fast)
    if slow.size:
        from repro.core.decision import linear_fit_interval

        for i in slow:
            res[int(i)] = linear_fit_interval(lo[i], hi[i], stride)
    return res


def trunc_candidates(L: np.ndarray, U: np.ndarray, k,
                     a_sets: list[list[int]], sq_t, lin_t: int
                     ) -> list[list[Candidate]]:
    """Batched twin of ``decision._region_trunc_candidates`` for every region:
    surviving (a, b-interval) choices under truncations ``(sq_t, lin_t)``.

    ``k`` and ``sq_t`` accept either a scalar (one spec) or a per-region
    vector — the fleet engine stacks regions of several specs, each at its
    own precision slack / square-truncation state, into one call. Per-row
    values reproduce the scalar expressions exactly.
    """
    L = np.asarray(L)
    U = np.asarray(U)
    b, n = L.shape
    out: list[list[Candidate]] = [[] for _ in range(b)]
    rid, a_arr = _flatten_pairs(a_sets)
    if rid.size == 0:
        return out
    x = np.arange(n, dtype=np.int64)
    k_arr = np.asarray(k, np.int64)
    sq_t_arr = np.asarray(sq_t, np.int64)
    if sq_t_arr.ndim:
        sq = ((x[None, :] >> sq_t_arr[:, None]) << sq_t_arr[:, None]) ** 2
    else:
        sq = ((x >> int(sq_t_arr)) << int(sq_t_arr)) ** 2
    kb = k_arr[:, None] if k_arr.ndim else k_arr
    lo_all = L.astype(np.int64) << kb
    hi_all = ((U.astype(np.int64) + 1) << kb) - 1
    nb = n >> lin_t if lin_t else n
    for s, e in _chunks(len(rid), n):
        r_c, a_c = rid[s:e], a_arr[s:e]
        sq_rows = sq[r_c] if sq.ndim == 2 else sq[None, :]
        v_lo = lo_all[r_c] - a_c[:, None] * sq_rows
        v_hi = hi_all[r_c] - a_c[:, None] * sq_rows
        if lin_t:
            v_lo = v_lo.reshape(len(r_c), nb, -1).max(axis=2)
            v_hi = v_hi.reshape(len(r_c), nb, -1).min(axis=2)
        ivs = batched_linear_fit(v_lo, v_hi, stride=1 << lin_t)
        for i, iv in enumerate(ivs):
            if iv is not None:
                out[int(r_c[i])].append(Candidate(int(a_c[i]), iv[0], iv[1]))
    return out
