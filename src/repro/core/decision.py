"""Design-space exploration: the §III decision procedure + Algorithm 1.

Order (paper §III, tuned for the square-critical-path ASIC target, kept
verbatim here because the same ordering also minimizes the Pallas kernel's
integer-multiply widths and VMEM table footprint):

  1. Minimize k                  (polynomial evaluation precision)
  2. Maximize square truncation  (bits dropped from x before squaring)
  3. Maximize linear truncation  (bits dropped from x in the b*x term)
  4. Minimize a, then b, then c storage widths (Algorithm 1), pruning the
     candidate dictionary after each step; pick the first survivor per region.

Algorithm 1 is implemented twice: literally on explicit value sets
(`alg1_set_precision`) and analytically on integer intervals
(`alg1_interval_precision`) — equivalence is property-tested. Production uses
the interval form (value sets here are intervals or small unions of them).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import searches
from repro.core.designspace import Candidate, DesignSpace, minimal_k
from repro.core.fixedpoint import (bit_length_of, interval_trailing_zeros,
                                   min_bits_in_interval, trailing_zeros)
from repro.core.funcspec import FunctionSpec
from repro.core.table import CoeffMeta, TableDesign

B_ENUM_CAP = 64


# --------------------------------------------------------------------------
# Linear (degree-1) exact feasibility: exists (b, c) with
#   forall p: Lo[p] <= b*pos[p] + c <= Hi[p]
# --------------------------------------------------------------------------

def linear_fit_interval(lo: np.ndarray, hi: np.ndarray, stride: int = 1,
                        impl: str | None = None) -> tuple[int, int] | None:
    """Integer interval [b_min, b_max] of slopes b such that some intercept c
    satisfies Lo <= b * (stride * index) + c <= Hi pointwise; None if empty.

    Derivation: c exists iff forall x,y: Lo[x] - b*px <= Hi[y] - b*py, i.e.
    max_{x<y}(Lo[y]-Hi[x])/(py-px) <= b <= min_{x<y}(Hi[y]-Lo[x])/(py-px).
    """
    if np.any(lo > hi):
        return None
    if len(lo) < 2:
        return (0, 0)
    b_lo, *_ = searches.max_dd(lo, hi, impl)
    b_hi, *_ = searches.min_dd(hi, lo, impl)
    # positions are stride*index, so real slopes divide by stride; b integer.
    b_min = int(math.ceil(b_lo / stride - 1e-12))
    b_max = int(math.floor(b_hi / stride + 1e-12))
    # exact witness check (float-slop guard): shrink/grow by one if needed
    idx = np.arange(len(lo), dtype=np.int64) * stride

    def c_ok(b: int) -> bool:
        t = b * idx
        return int((lo - t).max()) <= int((hi - t).min())

    while b_min <= b_max and not c_ok(b_min):
        b_min += 1
    while b_min <= b_max and not c_ok(b_max):
        b_max -= 1
    if b_min > b_max:
        for b in (b_min - 1, b_max + 1):
            if c_ok(b):
                return (b, b)
        return None
    return b_min, b_max


def _trunc(x: np.ndarray, bits: int) -> np.ndarray:
    return (x >> bits) << bits


def _region_trunc_candidates(L: np.ndarray, U: np.ndarray, k: int,
                             a_values: list[int], sq_t: int, lin_t: int,
                             impl: str | None = None) -> list[Candidate]:
    """Surviving (a, b-interval) choices under truncations (i, j) — exact."""
    n = len(L)
    x = np.arange(n, dtype=np.int64)
    sq = _trunc(x, sq_t) ** 2
    out: list[Candidate] = []
    lo_base = L.astype(np.int64) << k
    hi_base = ((U.astype(np.int64) + 1) << k) - 1
    n_buckets = n >> lin_t if lin_t else n
    for a in a_values:
        v_lo = lo_base - a * sq
        v_hi = hi_base - a * sq
        if lin_t:
            v_lo = v_lo.reshape(n_buckets, -1).max(axis=1)
            v_hi = v_hi.reshape(n_buckets, -1).min(axis=1)
        iv = linear_fit_interval(v_lo, v_hi, stride=1 << lin_t, impl=impl)
        if iv is not None:
            out.append(Candidate(a, iv[0], iv[1]))
    return out


# --------------------------------------------------------------------------
# Algorithm 1 — precision minimization
# --------------------------------------------------------------------------

def alg1_set_precision(sets: list[list[int]]) -> tuple[int, int]:
    """Literal Algorithm 1 on explicit non-negative value sets.

    Returns (P, t): minimal storage bits P with t truncated trailing zeros.
    """
    if any(len(s) == 0 for s in sets):
        raise ValueError("empty region set")
    t_cap = min(max(trailing_zeros(s) for s in sr) for sr in sets)
    best_p, best_t = None, 0
    for t in range(t_cap + 1):
        p_t = 0
        for sr in sets:
            pruned = [s for s in sr if trailing_zeros(s) >= t]
            p_t = max(p_t, min(max(bit_length_of(s) - t, 0) if s else 0
                               for s in pruned))
        if best_p is None or p_t < best_p:
            best_p, best_t = p_t, t
    return best_p, best_t


@dataclasses.dataclass(frozen=True)
class IntervalSet:
    """Union of disjoint inclusive integer intervals (may span signs)."""

    intervals: tuple[tuple[int, int], ...]

    @classmethod
    def single(cls, lo: int, hi: int) -> "IntervalSet":
        return cls(((lo, hi),))

    @classmethod
    def union(cls, sets: list["IntervalSet"]) -> "IntervalSet":
        ivs = sorted(i for s in sets for i in s.intervals)
        merged: list[tuple[int, int]] = []
        for lo, hi in ivs:
            if merged and lo <= merged[-1][1] + 1:
                merged[-1] = (merged[-1][0], max(merged[-1][1], hi))
            else:
                merged.append((lo, hi))
        return cls(tuple(merged))

    def abs_part(self, sign: int) -> "IntervalSet | None":
        """Non-negative magnitudes of the sign-restricted part (0 in both)."""
        out = []
        for lo, hi in self.intervals:
            if sign > 0 and hi >= 0:
                out.append((max(lo, 0), hi))
            elif sign < 0 and lo <= 0:
                out.append((max(-hi, 0), -lo))
        return IntervalSet(tuple(sorted(out))) if out else None

    def max_trailing_zeros(self) -> int:
        return max(interval_trailing_zeros(lo, hi) for lo, hi in self.intervals)

    def min_bits(self, t: int) -> int | None:
        cands = [min_bits_in_interval(lo, hi, t) for lo, hi in self.intervals]
        cands = [c for c in cands if c is not None]
        return min(cands) if cands else None

    def restrict(self, bits: int, shift: int, signed: bool, sign: int) -> "IntervalSet":
        """Intersect with representable values: s = +-(v << shift), v < 2^bits."""
        cap = ((1 << bits) - 1) << shift
        lo_cap = -cap if (signed or sign < 0) else 0
        hi_cap = cap if (signed or sign > 0) else 0
        out = []
        for lo, hi in self.intervals:
            lo2, hi2 = max(lo, lo_cap), min(hi, hi_cap)
            step = 1 << shift
            lo3 = -((-lo2) // step) * step  # ceil to multiple
            hi3 = (hi2 // step) * step  # floor to multiple
            if lo3 <= hi3:
                out.append((lo3, hi3))
        return IntervalSet(tuple(out))

    def first_value(self) -> int | None:
        """Smallest-magnitude member (ties: positive)."""
        best = None
        for lo, hi in self.intervals:
            v = lo if lo >= 0 else (hi if hi <= 0 else 0)
            if best is None or abs(v) < abs(best) or (abs(v) == abs(best) and v > best):
                best = v
        return best

    def enumerate(self, shift: int, cap: int = B_ENUM_CAP) -> list[int]:
        vals: list[int] = []
        step = 1 << shift
        for lo, hi in self.intervals:
            lo = -((-lo) // step) * step
            v = lo
            while v <= hi and len(vals) < cap * 4:
                vals.append(v)
                v += step
        vals.sort(key=abs)
        return vals[:cap]

    @property
    def empty(self) -> bool:
        return len(self.intervals) == 0


def alg1_interval_precision(sets: list[IntervalSet]) -> CoeffMeta:
    """Algorithm 1 over interval-sets, trying sign modes {pos, neg, signed}
    and returning the narrowest storage format valid for EVERY region."""
    best: CoeffMeta | None = None
    for mode in ("pos", "neg", "signed"):
        if mode == "pos":
            parts = [s.abs_part(+1) for s in sets]
            signed = False
        elif mode == "neg":
            parts = [s.abs_part(-1) for s in sets]
            signed = False
        else:
            parts = [IntervalSet.union([p for p in (s.abs_part(+1), s.abs_part(-1)) if p])
                     for s in sets]
            signed = True
        if any(p is None or p.empty for p in parts):
            continue
        t_cap = min(p.max_trailing_zeros() for p in parts)
        for t in range(min(t_cap, 62) + 1):
            per_region = [p.min_bits(t) for p in parts]
            if any(b is None for b in per_region):
                continue
            p_t = max(per_region)  # type: ignore[type-var]
            meta = CoeffMeta(bits=p_t, shift=t, signed=signed)
            if best is None or (meta.width, -meta.shift) < (best.width, -best.shift):
                best = meta
    assert best is not None, "alg1: no sign mode feasible (impossible for nonempty sets)"
    return best


# --------------------------------------------------------------------------
# Full decision procedure
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecisionPolicy:
    """Ordering knobs of the §III procedure — the part of a hardware target
    that is a *decision procedure* rather than a cost model.

    The paper's ASIC ordering maximizes both input truncations because the
    square path dominates the critical path. Other technologies weigh the
    steps differently: an FPGA soft-multiplier target still wants truncation
    (fewer logic LUTs), while a vector-unit target (Pallas/TPU) gains nothing
    from truncating — lane width is fixed — and skips straight to Algorithm 1
    width minimization. See DESIGN.md §6.
    """

    prefer_linear: bool = True  # paper rule: linear iff feasible
    maximize_sq_trunc: bool = True  # §III step 2
    maximize_lin_trunc: bool = True  # §III step 3
    k_max: int = 24


@dataclasses.dataclass
class DecisionReport:
    lookup_bits: int
    degree: int
    k: int
    sq_trunc: int
    lin_trunc: int
    widths: tuple[int, int, int]
    linear_possible: bool


def _trunc_worker(args):
    L_row, U_row, k, a_vals, i, j, impl = args
    return _region_trunc_candidates(L_row, U_row, k, a_vals, i, j, impl)


def run_decision(spec: FunctionSpec, lookup_bits: int, degree: int | None = None,
                 impl: str | None = None, k_max: int | None = None,
                 processes: int | None = None, pool=None, spaces=None,
                 policy: DecisionPolicy | None = None, engine: str | None = None,
                 bounds=None) -> tuple[TableDesign, DecisionReport] | None:
    """Run the full §III procedure; returns a verified TableDesign or None if
    no piecewise polynomial of the requested degree exists at this R.

    ``engine`` selects the region backend (api.config.ENGINES): the default
    batched engine runs every per-region phase as one array program; under
    ``"pooled"``, ``processes > 1`` parallelizes the per-region work (paper
    §V future work) and an externally-owned ``pool`` takes precedence (the
    Explorer session keeps one alive across the whole R-sweep instead of
    forking per call). ``spaces`` injects precomputed per-region envelopes;
    ``policy`` swaps the step ordering — together they are what makes
    "retargeting = a modified decision procedure" cheap.
    """
    from repro.core.designspace import resolve_engine
    from repro.core.pmap import RegionPool

    policy = policy or DecisionPolicy()
    engine = resolve_engine(engine)
    if k_max is None:
        k_max = policy.k_max
    if engine != "pooled" or pool is not None:
        return _run_decision_pooled(spec, lookup_bits, degree, impl, k_max, pool,
                                    spaces=spaces, policy=policy, engine=engine,
                                    bounds=bounds)
    with RegionPool(processes) as owned:
        return _run_decision_pooled(spec, lookup_bits, degree, impl, k_max, owned,
                                    spaces=spaces, policy=policy, engine=engine,
                                    bounds=bounds)


def _run_decision_pooled(spec, lookup_bits, degree, impl, k_max, pool,
                         spaces=None, policy: DecisionPolicy | None = None,
                         engine: str | None = None, bounds=None
                         ) -> tuple[TableDesign, DecisionReport] | None:
    from repro.core.designspace import resolve_engine

    policy = policy or DecisionPolicy()
    engine = resolve_engine(engine)

    def trunc_all(ds, k, a_sets, i, j):
        """Step-2/3 truncation re-checks for every region at one (i, j)."""
        if engine == "pooled":
            return pool.map(_trunc_worker,
                            [(ds.L[r], ds.U[r], k, a_sets[r], i, j, impl)
                             for r in range(len(a_sets))])
        from repro.core import batched

        return batched.trunc_candidates(ds.L, ds.U, k, a_sets, i, j)

    # -- step 1: minimal k, and lin-vs-quad choice (paper: linear iff 0 is in
    # every region's a-interval — smaller, faster hardware) ----------------
    lin_ds = minimal_k(spec, lookup_bits, force_linear=True, impl=impl, k_max=k_max,
                       pool=pool, spaces=spaces, engine=engine, bounds=bounds)
    linear_possible = lin_ds is not None and lin_ds.feasible
    if degree == 1 or (degree is None and policy.prefer_linear and linear_possible):
        ds = lin_ds
        deg = 1
    else:
        ds = minimal_k(spec, lookup_bits, force_linear=False, impl=impl, k_max=k_max,
                       pool=pool, spaces=spaces, engine=engine, bounds=bounds)
        deg = 2
    if ds is None or not ds.feasible:
        return None

    # region count comes from the bound rows, not 2^R: a segmented caller
    # (repro.segment) passes one row per same-width leaf via ``bounds``
    n_regions = len(ds.candidates)
    w = ds.eval_bits
    k = ds.k
    a_sets: list[list[int]] = [[c.a for c in ds.candidates[r]] for r in range(n_regions)]

    # -- step 2: maximize square truncation i (quadratic only) -------------
    sq_t = 0
    if policy.maximize_sq_trunc and deg == 2 and w > 0:
        for i in range(1, w + 1):
            rows = trunc_all(ds, k, a_sets, i, 0)
            if any(not c for c in rows):
                break
            sq_t, a_sets = i, [[c.a for c in cands] for cands in rows]

    # -- step 3: maximize linear truncation j ------------------------------
    lin_t = 0
    region_cands: list[list[Candidate]] = trunc_all(ds, k, a_sets, sq_t, 0)
    if any(not c for c in region_cands):
        return None  # should not happen: step-2 kept feasibility
    for j in range(1, (w if policy.maximize_lin_trunc else 0) + 1):
        trial = trunc_all(ds, k, [[c.a for c in region_cands[r]]
                                  for r in range(n_regions)], sq_t, j)
        if any(not c for c in trial):
            break
        lin_t, region_cands = j, trial

    # -- step 4: Algorithm 1 width minimization, a -> b -> c ---------------
    verify_bounds = (ds.L, ds.U) if bounds is not None else None
    return finalize_design(spec, lookup_bits, ds.L, ds.U, k, deg, sq_t, lin_t,
                           region_cands, linear_possible,
                           verify_bounds=verify_bounds)


def finalize_design(spec, lookup_bits: int, L: np.ndarray, U: np.ndarray,
                    k: int, deg: int, sq_t: int, lin_t: int,
                    region_cands: list[list[Candidate]],
                    linear_possible: bool,
                    alg1_fn=None, verify_bounds=None
                    ) -> tuple[TableDesign, DecisionReport] | None:
    """Step 4 of the §III procedure: Algorithm-1 width minimization over the
    surviving candidates (a -> b -> c), first-survivor pick per region, and
    the final exhaustive verification.

    ``alg1_fn`` must be *value-identical* to :func:`alg1_interval_precision`
    (the default); the fleet engine injects its vectorized twin
    (``repro.core.fleet.fleet_alg1``), property-tested as bit-identical.
    ``verify_bounds=(L, U)`` verifies the design directly against those bound
    rows instead of ``spec.bound_arrays()`` — required when the rows are not
    the spec's full-domain reshape (segmented depth groups, where ``spec`` is
    a width-only pseudo-spec and only the first ``n_regions * 2^w`` codes are
    meaningful).
    """
    alg1 = alg1_fn if alg1_fn is not None else alg1_interval_precision
    n_regions = len(region_cands)
    w = spec.in_bits - lookup_bits
    # The interval sets fed to Algorithm 1 skip union() normalization: the
    # width search only takes min/max over each set's intervals, which is
    # insensitive to merge order (same point set either way).
    # a widths
    a_meta = alg1([
        IntervalSet(tuple((c.a, c.a) for c in region_cands[r]))
        for r in range(n_regions)
    ])
    region_cands = [
        [c for c in cands
         if not IntervalSet.single(c.a, c.a).restrict(
             a_meta.bits, a_meta.shift, a_meta.signed, 1 if c.a >= 0 else -1).empty]
        for cands in region_cands
    ]
    if any(not c for c in region_cands):
        return None
    # b widths over the union of surviving b-intervals
    b_meta = alg1([
        IntervalSet(tuple((c.b_min, c.b_max) for c in cands))
        for cands in region_cands
    ])
    # prune b to representable values; keep (a, bs) with survivors
    pruned: list[list[tuple[int, list[int]]]] = []
    for cands in region_cands:
        row = []
        for c in cands:
            iv = IntervalSet.single(c.b_min, c.b_max).restrict(
                b_meta.bits, b_meta.shift, b_meta.signed, 1 if c.b_max >= 0 else -1)
            if not b_meta.signed:
                # unsigned mode: restrict() above guessed a sign; redo both
                iv = IntervalSet.union([
                    IntervalSet.single(c.b_min, c.b_max).restrict(
                        b_meta.bits, b_meta.shift, False, +1),
                    IntervalSet.single(c.b_min, c.b_max).restrict(
                        b_meta.bits, b_meta.shift, False, -1),
                ])
            bs = iv.enumerate(b_meta.shift)
            if bs:
                row.append((c.a, bs))
        pruned.append(row)
    if any(not row for row in pruned):
        return None

    # c width over exact c-intervals of surviving (a, b) pairs — one int64
    # sweep over every (region, a, b) triple at once (identical expressions
    # to ``c_interval``, batched over a leading pair axis)
    x = np.arange(1 << w, dtype=np.int64)
    sqv = _trunc(x, sq_t) ** 2
    linv = _trunc(x, lin_t)
    rid_l: list[int] = []
    av_l: list[int] = []
    bv_l: list[int] = []
    offsets = []
    for r in range(n_regions):
        offsets.append(len(rid_l))
        for a, bs in pruned[r]:
            for b in bs:
                rid_l.append(r)
                av_l.append(a)
                bv_l.append(b)
    rid = np.asarray(rid_l, np.int64)
    poly = (np.asarray(av_l, np.int64)[:, None] * sqv[None, :]
            + np.asarray(bv_l, np.int64)[:, None] * linv[None, :])
    c_lo = ((L.astype(np.int64) << k)[rid] - poly).max(axis=1)
    c_hi = (((U.astype(np.int64) + 1) << k)[rid] - poly).min(axis=1) - 1

    c_sets = []
    for r in range(n_regions):
        end = offsets[r + 1] if r + 1 < n_regions else len(rid_l)
        ivs = tuple((int(c_lo[j]), int(c_hi[j]))
                    for j in range(offsets[r], end) if c_lo[j] <= c_hi[j])
        if not ivs:
            return None
        c_sets.append(IntervalSet(ivs))
    c_meta = alg1(c_sets)

    # final pick: first surviving (a, b, c) per region
    av = np.zeros(n_regions, dtype=np.int64)
    bv = np.zeros(n_regions, dtype=np.int64)
    cv = np.zeros(n_regions, dtype=np.int64)
    for r in range(n_regions):
        done = False
        j = offsets[r]
        for a, bs in pruned[r]:
            for b in bs:
                lo, hi = int(c_lo[j]), int(c_hi[j])
                j += 1
                if lo > hi:
                    continue
                sign = 1 if hi >= 0 else -1
                iv = IntervalSet.single(lo, hi).restrict(
                    c_meta.bits, c_meta.shift, c_meta.signed, sign)
                if not c_meta.signed and iv.empty:
                    iv = IntervalSet.single(lo, hi).restrict(
                        c_meta.bits, c_meta.shift, False, -sign)
                val = iv.first_value()
                if val is not None:
                    av[r], bv[r], cv[r] = a, b, val
                    done = True
                    break
            if done:
                break
        if not done:
            return None

    design = TableDesign(
        name=f"{spec.name}_R{lookup_bits}", in_bits=spec.in_bits,
        out_bits=spec.out_bits, lookup_bits=lookup_bits, k=k, degree=deg,
        sq_trunc=sq_t, lin_trunc=lin_t, a=av, b=bv, c=cv,
        a_meta=a_meta, b_meta=b_meta, c_meta=c_meta,
    )
    if verify_bounds is None:
        ok, _ = design.verify(spec)
    else:
        vb_lo, vb_hi = verify_bounds
        codes = np.arange(n_regions << w, dtype=np.int64)
        y = design.eval_int(codes)
        ok = bool(np.all((y >= vb_lo.reshape(-1).astype(np.int64))
                         & (y <= vb_hi.reshape(-1).astype(np.int64))))
    assert ok, f"decision produced an invalid design for {spec.name} R={lookup_bits}"
    report = DecisionReport(lookup_bits, deg, k, sq_t, lin_t,
                            design.lut_widths, linear_possible)
    return design, report
