"""Fleet engine: one array program for the whole library manifest.

PR 2 collapsed the ``2^R`` regions of ONE (spec, R) probe into a single
array program (``core.batched``); the deployable artifact of PR 3 is a
*library* of many functions. This module closes the gap: every (kind, spec,
R) probe a manifest needs is stacked into one padded
``(P, B_max, N_max)`` program — §II envelopes, Eqn 9-10 feasibility and the
Eqn 7-8 a-interval searches for **all probes of all functions at once** —
and the §III decision procedure runs in *lockstep* over the stacked
(kind, region) rows, so ``Explorer.compile()`` over a manifest is a handful
of array dispatches instead of F × R serial probes. The probe/region row
axis shards across devices through ``kernels/dspace`` (``shard_map``; pmap
fallback, single program on one device).

Layout and masking rules (DESIGN.md §11):

* ``stack_bounds``        ragged probes -> one ``(P, B_max, N_max)`` float64
                          pair. Column pads hold ``L = -inf`` / ``U = +inf``:
                          any divided difference touching a pad lane is
                          ``±inf`` and loses every min/max reduction
                          *exactly* (IEEE), so real-lane envelope values are
                          bit-identical to an unpadded run. Pad region rows
                          are all-sentinel and sliced away on unpacking.
* ``fleet_region_spaces_stacked``  the padded program itself: envelopes for
                          every (probe, region) row in one pass; the
                          a-interval reduction slices each row group back to
                          its real ``t`` range (so the hull fallback never
                          sees a sentinel).
* ``fleet_region_spaces`` the production wrapper: groups probes by row
                          width N (identical-width probes stack directly;
                          mixed-N probes never pay quadratic column-pad
                          work) and unpacks per-probe ``RegionSpace`` lists
                          bit-identical to ``batched.region_spaces``.
* ``fleet_feasible_mask`` per-probe Eqn 9-10 verdicts without materializing
                          spaces (min-R probe traffic).
* ``fleet_alg1``          vectorized, bit-identical twin of Algorithm 1
                          (``decision.alg1_interval_precision``) — the
                          decision tail's Python hot spot.
* ``fleet_decisions``     the §III procedure for F same-shape probes in
                          lockstep: shared-k rounds of candidate
                          generation, truncation trials with per-row
                          ``(k, sq_t)`` vectors, and ``finalize_design``
                          with the vectorized Algorithm 1.

Every routine is bit-identical to its per-spec twin in ``core.batched`` /
``core.decision`` (property-tested in tests/core/test_fleet.py); the serial
path stays available as the equivalence oracle, exactly as the pooled path
does for the batched engine.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import batched
from repro.core.decision import alg1_interval_precision
from repro.core.designspace import RegionSpace
from repro.core.table import CoeffMeta

Bounds = tuple[np.ndarray, np.ndarray]

# fleet_alg1 exactness bound: bit lengths come from an exact float64 frexp,
# valid for magnitudes below 2^53 (coefficient values are < 2^45 in any
# representable design; beyond the bound we fall back to the scalar loop).
_EXACT_MAG = 1 << 52


# --------------------------------------------------------------------------
# Padded probe stacking
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FleetStack:
    """P ragged probes padded into one ``(P, B_max, N_max)`` float64 pair.

    ``shapes[p]`` is probe p's real ``(B_p, N_p)``; everything outside it is
    sentinel (``L = -inf`` / ``U = +inf``) — see the module docstring for
    why sentinels are exact.
    """

    L: np.ndarray
    U: np.ndarray
    shapes: tuple[tuple[int, int], ...]

    @property
    def flat(self) -> Bounds:
        p, bm, nm = self.L.shape
        return self.L.reshape(p * bm, nm), self.U.reshape(p * bm, nm)


def stack_bounds(bounds: Sequence[Bounds]) -> FleetStack:
    """Stack ragged (L, U) region-bound pairs into one padded array pair."""
    shapes = tuple((int(L.shape[0]), int(L.shape[1])) for L, _ in bounds)
    b_max = max(b for b, _ in shapes)
    n_max = max(n for _, n in shapes)
    ls = np.full((len(bounds), b_max, n_max), -np.inf)
    us = np.full((len(bounds), b_max, n_max), np.inf)
    for i, (L, U) in enumerate(bounds):
        b, n = shapes[i]
        ls[i, :b, :n] = L
        us[i, :b, :n] = U
    return FleetStack(ls, us, shapes)


# --------------------------------------------------------------------------
# §II front half over a stack: envelopes + feasibility + a-intervals
# --------------------------------------------------------------------------

def _stacked_front_half(stack: FleetStack):
    """One batched-envelope pass over every (probe, region) row of the
    padded stack, then Eqn 9 and the fused a-interval per real-width group.

    Returns float64 ``(rows, 2*N_max - 2)`` envelopes plus per-row
    ``(a_lo, a_hi, feas9)``. Rows are ``probe-major``: probe p owns rows
    ``[p*B_max, p*B_max + B_p)``. The a-interval reduction runs on each
    row's REAL ``t`` range (grouped by width), so its values — including the
    long-row hull fallback — are bit-identical to the per-probe engine.
    """
    lf, uf = stack.flat
    big_m, small_m = batched.batched_envelopes(lf, uf)
    rows = big_m.shape[0]
    # Eqn 9 over the padded t range: pad columns hold -inf < +inf and can
    # never flip a verdict
    feas9 = np.all(big_m[:, 1:] < small_m[:, 1:], axis=1)
    a_lo = np.full(rows, np.nan)
    a_hi = np.full(rows, np.nan)
    p, b_max, _ = stack.L.shape
    by_width: dict[int, list[int]] = {}
    for i, (b, n) in enumerate(stack.shapes):
        if n > 2:
            by_width.setdefault(n, []).extend(
                range(i * b_max, i * b_max + b))
    for n, rws in by_width.items():
        idx = np.asarray(rws)[feas9[np.asarray(rws)]]
        if idx.size:
            t_real = slice(1, 2 * n - 2)
            a_lo[idx], a_hi[idx] = batched._dd_interval_rows(
                big_m[idx, t_real], small_m[idx, t_real])
    return big_m, small_m, a_lo, a_hi, feas9


def _unpack_spaces(stack: FleetStack, big_m, small_m, a_lo, a_hi, feas9
                   ) -> list[list[RegionSpace]]:
    """Slice the stacked front half back into per-probe RegionSpace lists,
    matching ``batched.region_spaces`` verdict-for-verdict (including the
    n <= 2 trivial-space semantics)."""
    out: list[list[RegionSpace]] = []
    _, b_max, _ = stack.L.shape
    for i, (b, n) in enumerate(stack.shapes):
        rows = slice(i * b_max, i * b_max + b)
        if n < 2:
            out.append([RegionSpace(np.full(1, -np.inf), np.full(1, np.inf),
                                    -np.inf, np.inf, True)
                        for _ in range(b)])
            continue
        big = big_m[rows, : 2 * n - 2]
        small = small_m[rows, : 2 * n - 2]
        f9 = feas9[rows]
        if n == 2:  # Eqn 10 is vacuous; a unconstrained
            out.append([RegionSpace(big[r], small[r], -np.inf, np.inf,
                                    bool(f9[r])) for r in range(b)])
            continue
        al, ah = a_lo[rows], a_hi[rows]
        out.append([RegionSpace(big[r], small[r], float(al[r]), float(ah[r]),
                                bool(f9[r]) and bool(al[r] < ah[r]))
                    for r in range(b)])
    return out


def fleet_region_spaces_stacked(stack: FleetStack) -> list[list[RegionSpace]]:
    """All probes' RegionSpaces from ONE padded array program — exact."""
    return _unpack_spaces(stack, *_stacked_front_half(stack))


def fleet_region_spaces_device(stack: FleetStack, shards: int | None = None,
                               interpret: bool | None = None
                               ) -> list[list[RegionSpace]]:
    """The padded program on device: one ``pallas_call`` with a grid over
    (probe, region, tile), probe axis sharded across ``shards`` devices.

    Float32 envelopes (same contract as the ``pallas`` engine): a marginal
    verdict can differ from the exact engines, which per DESIGN.md §4 can
    cost a retry, never an unsound artifact. Probes too narrow for the
    kernel (N <= 2) are answered by the exact path.
    """
    from repro.kernels.dspace.ops import fleet_region_envelopes_device

    p, b_max, n_max = stack.L.shape
    if n_max <= 2:
        return fleet_region_spaces_stacked(stack)
    out: list[list[RegionSpace]] = [None] * p  # type: ignore
    # one kernel launch per real width: a narrower probe's ±inf column
    # sentinels must never enter another width's f32 a-interval reduction
    # (the t-slots are sliced to each group's real range on device)
    by_width: dict[int, list[int]] = {}
    for i, (_, n) in enumerate(stack.shapes):
        by_width.setdefault(n, []).append(i)
    for n, idxs in by_width.items():
        if n <= 2:  # exact trivial semantics, recomputed from real bounds
            for i in idxs:
                b = stack.shapes[i][0]
                sub = FleetStack(stack.L[i:i + 1, :b, :n],
                                 stack.U[i:i + 1, :b, :n], ((b, n),))
                out[i] = fleet_region_spaces_stacked(sub)[0]
            continue
        big, small, a_lo, a_hi, feas9 = fleet_region_envelopes_device(
            stack.L[idxs][:, :, :n], stack.U[idxs][:, :, :n],
            shards=shards, interpret=interpret)
        for j, i in enumerate(idxs):
            b = stack.shapes[i][0]
            spaces = []
            for r in range(b):
                row = j * b_max + r
                ok = bool(feas9[row])
                lo = float(a_lo[row]) if ok else np.nan
                hi = float(a_hi[row]) if ok else np.nan
                spaces.append(RegionSpace(big[row, : 2 * n - 2],
                                          small[row, : 2 * n - 2],
                                          lo, hi, ok and lo < hi))
            out[i] = spaces
    return out


def _width_groups(bounds: Sequence[Bounds]) -> dict[int, list[int]]:
    groups: dict[int, list[int]] = {}
    for i, (L, _) in enumerate(bounds):
        groups.setdefault(int(L.shape[1]), []).append(i)
    return groups


def fleet_region_spaces(bounds: Sequence[Bounds], shards: int | None = None
                        ) -> list[list[RegionSpace]]:
    """Per-probe RegionSpaces for a ragged probe fleet.

    Probes are grouped by row width N before stacking: identical-width
    probes (the manifest case, and every lockstep min-R round) share one
    program with zero column padding; mixed-N probes run one program per
    width so nobody pays another probe's quadratic column-pad work. Results
    are bit-identical to ``batched.region_spaces`` per probe (``shards > 1``
    routes through the float32 device program instead — same contract as
    the ``pallas`` engine).
    """
    out: list[list[RegionSpace]] = [None] * len(bounds)  # type: ignore
    for _, idxs in _width_groups(bounds).items():
        stack = stack_bounds([bounds[i] for i in idxs])
        if shards is not None and shards > 1 and stack.L.shape[2] > 2:
            spaces = fleet_region_spaces_device(stack, shards=shards)
        else:
            spaces = fleet_region_spaces_stacked(stack)
        for i, sp in zip(idxs, spaces):
            out[i] = sp
    return out


def fleet_feasible_mask(bounds: Sequence[Bounds]) -> np.ndarray:
    """Per-probe Eqn 9-10 verdict (`all regions feasible`) — the fleet twin
    of ``batched.regions_feasible_mask(...).all()``, one program per width
    group and no RegionSpace materialization."""
    out = np.zeros(len(bounds), bool)
    for n, idxs in _width_groups(bounds).items():
        stack = stack_bounds([bounds[i] for i in idxs])
        _, _, a_lo, a_hi, feas9 = _stacked_front_half(stack)
        _, b_max, _ = stack.L.shape
        for j, i in enumerate(idxs):
            b, n_p = stack.shapes[j]
            rows = slice(j * b_max, j * b_max + b)
            if n_p < 2:
                out[i] = True
            elif n_p == 2:
                out[i] = bool(feas9[rows].all())
            else:
                out[i] = bool((feas9[rows]
                               & (a_lo[rows] < a_hi[rows])).all())
    return out


# --------------------------------------------------------------------------
# Vectorized Algorithm 1 (the decision tail's Python hot spot)
# --------------------------------------------------------------------------

def _bit_length(s: np.ndarray) -> np.ndarray:
    """ceil(log2(s+1)) for non-negative int64 ``s < 2^53``, exactly: frexp
    returns s = m * 2^e with m in [0.5, 1), so e IS the bit length."""
    _, e = np.frexp(s.astype(np.float64))
    return e.astype(np.int64)


def fleet_alg1(sets) -> CoeffMeta:
    """Vectorized twin of ``decision.alg1_interval_precision`` — the same
    (bits, shift, signed) for every input, chosen by the same ordering.

    The per-(sign mode, truncation t, region, interval) Python loops become
    one masked ``(T, intervals)`` grid per mode: min-bits per cell, a
    segment-min over each region's intervals, and the scalar routine's
    lexicographic pick ``(width, -shift)`` with first-mode-wins ties.
    """
    rid_l: list[int] = []
    lo_l: list[int] = []
    hi_l: list[int] = []
    for r, s in enumerate(sets):
        for l, h in s.intervals:
            rid_l.append(r)
            lo_l.append(l)
            hi_l.append(h)
    n_regions = len(sets)
    if not rid_l or max(max(map(abs, lo_l)), max(map(abs, hi_l))) >= _EXACT_MAG:
        return alg1_interval_precision(sets)
    rid = np.asarray(rid_l, np.int64)
    lo = np.asarray(lo_l, np.int64)
    hi = np.asarray(hi_l, np.int64)
    # only t up to the largest magnitude's bit length can have a multiple in
    # range (beyond it every cell is sentinel and the row is skipped anyway);
    # always include t = 0 and allow t = 62 for zero-containing intervals
    mx = max(max(map(abs, lo_l)), max(map(abs, hi_l)))
    t_hi = 62 if any(l <= 0 <= h for l, h in zip(lo_l, hi_l)) else \
        min(int(_bit_length(np.asarray([mx]))[0]), 62)
    t = np.arange(t_hi + 1, dtype=np.int64)
    step = np.int64(1) << t
    sent = np.int64(127)  # > any real bit count: marks "no multiple in range"
    best: CoeffMeta | None = None
    for mode in ("pos", "neg", "signed"):
        if mode == "pos":
            m = hi >= 0
            plo, phi, prid = np.maximum(lo[m], 0), hi[m], rid[m]
        elif mode == "neg":
            m = lo <= 0
            plo, phi, prid = np.maximum(-hi[m], 0), -lo[m], rid[m]
        else:
            mp, mn = hi >= 0, lo <= 0
            plo = np.concatenate([np.maximum(lo[mp], 0), np.maximum(-hi[mn], 0)])
            phi = np.concatenate([hi[mp], -lo[mn]])
            prid = np.concatenate([rid[mp], rid[mn]])
        if prid.size == 0 or \
                np.bincount(prid, minlength=n_regions).min() == 0:
            continue  # some region has no part under this sign mode
        order = np.argsort(prid, kind="stable")
        plo, phi, prid = plo[order], phi[order], prid[order]
        offsets = np.searchsorted(prid, np.arange(n_regions))
        # smallest multiple of 2^t at or above lo, per (t, interval) cell
        s_mult = ((plo[None, :] + step[:, None] - 1) >> t[:, None]) << t[:, None]
        in_range = s_mult <= phi[None, :]
        val = np.where(s_mult > 0,
                       np.maximum(_bit_length(s_mult) - t[:, None], 0), 0)
        val = np.where(in_range, val, sent)
        # segment min over each region's intervals (ids are region-sorted and
        # every region nonempty, so reduceat segments are well-formed)
        per_tr = np.minimum.reduceat(val, offsets, axis=1)
        t_ok = (per_tr < sent).all(axis=1)
        if not t_ok.any():
            continue
        p_t = per_tr.max(axis=1)
        signed = mode == "signed"
        width = p_t + (1 if signed else 0)
        w_min = width[t_ok].min()
        t_best = int(np.flatnonzero(t_ok & (width == w_min)).max())
        meta = CoeffMeta(bits=int(p_t[t_best]), shift=t_best, signed=signed)
        if best is None or (meta.width, -meta.shift) < (best.width, -best.shift):
            best = meta
    assert best is not None, "alg1: no sign mode feasible (impossible for nonempty sets)"
    return best


# --------------------------------------------------------------------------
# Lockstep §III decision procedure over a same-shape probe group
# --------------------------------------------------------------------------

def fleet_decisions(specs, lookup_bits: int, bounds: Sequence[Bounds],
                    spaces: Sequence[list[RegionSpace]], *,
                    degree: int | None = None, policy=None,
                    k_max: int | None = None):
    """Run the §III decision procedure for F probes of identical shape
    (same in_bits and lookup_bits) in lockstep, every per-region phase
    stacked over the (kind, region) rows of the whole group.

    Returns a list of ``(TableDesign, DecisionReport) | None`` — entry i is
    bit-identical to ``decision.run_decision(specs[i], lookup_bits,
    degree=degree, policy=policy, k_max=k_max, engine="batched")``: each
    kind walks exactly the serial k / truncation ladders, only the array
    work is shared. Step 4 runs per kind with the vectorized Algorithm 1.
    """
    from repro.core.decision import DecisionPolicy, finalize_design

    policy = policy or DecisionPolicy()
    k_max = policy.k_max if k_max is None else k_max
    f = len(specs)
    assert f == len(bounds) == len(spaces) and f > 0
    b_regions, n = bounds[0][0].shape
    assert all(b[0].shape == (b_regions, n) for b in bounds), \
        "fleet_decisions needs a same-shape probe group"
    w = n.bit_length() - 1  # eval bits; n == 2^w
    feas = [all(s.feasible for s in sp) for sp in spaces]

    def cat(idxs, which):
        return np.concatenate([np.asarray(bounds[i][which]) for i in idxs])

    # the k ladders below revisit the same spaces once per k round: stack
    # the envelope rows once per kind, subset per round
    env_of = {i: batched.stack_envelopes(spaces[i]) for i in range(f)
              if feas[i]}

    def lockstep_min_k(idxs, force_linear):
        """Per-kind minimal k + candidates: the serial ``minimal_k`` ladder,
        all still-searching kinds sharing each k round's array program.

        Force-linear pre-screen: ``design_candidates`` hands every region
        with ``not linear_ok`` an empty a-set *independently of k*, so a
        kind with such a region can never climb out of the ladder — the
        serial path still probes all k_max rounds for it; here it is
        excluded up front with an identical (absent) result."""
        found: dict[int, tuple[int, list]] = {}
        active = [i for i in idxs if feas[i]]
        if force_linear and n > 2:
            active = [i for i in active
                      if all(s.linear_ok for s in spaces[i])]
        for k in range(k_max + 1):
            if not active:
                break
            # cheap existence waves decide which kinds retire at this k;
            # candidate lists are materialized once, at the found k only
            # (the serial ladder discards every earlier k's lists anyway)
            sp = [s for i in active for s in spaces[i]]
            env = (np.concatenate([env_of[i][0] for i in active]),
                   np.concatenate([env_of[i][1] for i in active]))
            okv = batched.candidates_feasible(
                sp, cat(active, 0), cat(active, 1), k, force_linear, env=env)
            newly = [i for j, i in enumerate(active)
                     if okv[j * b_regions:(j + 1) * b_regions].all()]
            if newly:
                sp2 = [s for i in newly for s in spaces[i]]
                env2 = (np.concatenate([env_of[i][0] for i in newly]),
                        np.concatenate([env_of[i][1] for i in newly]))
                cands = batched.design_candidates(
                    sp2, cat(newly, 0), cat(newly, 1), k, force_linear,
                    env=env2)
                for j, i in enumerate(newly):
                    found[i] = (k, cands[j * b_regions:(j + 1) * b_regions])
            active = [i for i in active if i not in found]
        return found

    # -- step 1: minimal k and the lin-vs-quad choice per kind -------------
    lin = lockstep_min_k(range(f), True)
    linear_possible = [i in lin for i in range(f)]
    deg = [0] * f
    state: list[tuple[int, list] | None] = [None] * f
    need_quad = []
    for i in range(f):
        if degree == 1 or (degree is None and policy.prefer_linear
                           and linear_possible[i]):
            if i in lin:
                deg[i], state[i] = 1, lin[i]
        else:
            need_quad.append(i)
    quad = lockstep_min_k(need_quad, False)
    for i in need_quad:
        if i in quad:
            deg[i], state[i] = 2, quad[i]
    live = [i for i in range(f) if state[i] is not None]
    if not live:
        return [None] * f

    k_of = {i: state[i][0] for i in live}
    a_sets = {i: [[c.a for c in row] for row in state[i][1]] for i in live}
    sq_t = {i: 0 for i in live}

    def kvec(idxs):
        return np.repeat([k_of[i] for i in idxs], b_regions)

    def sqvec(idxs):
        return np.repeat([sq_t[i] for i in idxs], b_regions)

    # -- step 2: maximize square truncation, quadratic kinds in lockstep ---
    # an accepted round's rows ARE trunc candidates at (sq_t, 0) restricted
    # to the surviving a-sets, i.e. exactly what step 3's baseline would
    # recompute — keep them and skip that kind's baseline call
    step2_rows: dict[int, list] = {}
    if policy.maximize_sq_trunc and w > 0:
        active = [i for i in live if deg[i] == 2]
        for i_step in range(1, w + 1):
            if not active:
                break
            rows = batched.trunc_candidates(
                cat(active, 0), cat(active, 1), kvec(active),
                [r for i in active for r in a_sets[i]], i_step, 0)
            still = []
            for j, i in enumerate(active):
                block = rows[j * b_regions:(j + 1) * b_regions]
                if any(not c for c in block):
                    continue  # freeze at sq_t[i]
                sq_t[i] = i_step
                a_sets[i] = [[c.a for c in row] for row in block]
                step2_rows[i] = block
                still.append(i)
            active = still

    # -- step 3: baseline at (sq_t, 0), then maximize linear truncation ----
    region_cands = dict(step2_rows)
    base = [i for i in live if i not in region_cands]
    if base:
        rows = batched.trunc_candidates(
            cat(base, 0), cat(base, 1), kvec(base),
            [r for i in base for r in a_sets[i]], sqvec(base), 0)
        for j, i in enumerate(base):
            block = rows[j * b_regions:(j + 1) * b_regions]
            if any(not c for c in block):
                state[i] = None  # serial: should not happen; drop the kind
            else:
                region_cands[i] = block
    live = [i for i in live if state[i] is not None]
    lin_t = {i: 0 for i in live}
    if policy.maximize_lin_trunc and w > 0:
        active = list(live)
        for j_step in range(1, w + 1):
            if not active:
                break
            rows = batched.trunc_candidates(
                cat(active, 0), cat(active, 1), kvec(active),
                [[c.a for c in row] for i in active for row in region_cands[i]],
                sqvec(active), j_step)
            still = []
            for j, i in enumerate(active):
                block = rows[j * b_regions:(j + 1) * b_regions]
                if any(not c for c in block):
                    continue  # freeze at lin_t[i]
                lin_t[i] = j_step
                region_cands[i] = block
                still.append(i)
            active = still

    # -- step 4: Algorithm 1 tail per kind, vectorized alg1 ----------------
    out = [None] * f
    for i in live:
        out[i] = finalize_design(
            specs[i], lookup_bits, np.asarray(bounds[i][0]),
            np.asarray(bounds[i][1]), k_of[i], deg[i], sq_t[i], lin_t[i],
            region_cands[i], linear_possible[i], alg1_fn=fleet_alg1)
    return out
