"""Area/delay proxy model (stands in for Design Compiler + TSMC 7nm).

The paper ranks candidate designs by synthesized area x delay; this container
has no synthesis flow, so the decision layer ranks with an explicit
bit-operation model instead (DESIGN.md §7.1). Units are arbitrary
("NAND2-equivalents" for area, "FO4-ish" for delay) — only *relative* order
matters, exactly how §III uses the target-technology cost to steer the
exploration. The model follows Figure 1's architecture:

    LUT[r] -> (a, b, c);   square path:  x -> x_i^2 -> a * x_i^2
    accumulate a*x_i^2 + b*x_j + c, then >> k.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.table import TableDesign


def _log2(v: float) -> float:
    return math.log2(max(v, 2.0))


@dataclasses.dataclass(frozen=True)
class AreaDelay:
    area: float
    delay: float

    @property
    def product(self) -> float:
        return self.area * self.delay


def estimate(design: TableDesign) -> AreaDelay:
    r = design.lookup_bits
    w = design.eval_bits
    wa, wb, wc = design.lut_widths
    s = max(w - design.sq_trunc, 0)  # squarer input bits
    lb = max(w - design.lin_trunc, 0)  # linear-term input bits
    acc_w = max(wc, wa + 2 * s, wb + lb) + 2  # accumulator width

    # --- area ---------------------------------------------------------------
    # Non-uniform designs store fewer rows than their address span (the
    # segment decoder is costed separately by the target); uniform designs
    # have no ``rows`` attribute and keep the 2^r ROM.
    rows = int(getattr(design, "rows", 0) or (1 << r))
    lut_bits = rows * (wa + wb + wc)
    area = 0.25 * lut_bits  # ROM cell ~ 1/4 logic cell
    if design.degree == 2 and s > 0:
        area += 0.5 * s * s  # dedicated squarer (folded Booth array)
        area += 1.0 * wa * (2 * s)  # a * x^2 multiplier array
    area += 1.0 * wb * lb  # b * x array
    area += 2.0 * acc_w  # carry-propagate adder + rounding

    # --- delay (critical path; paper §III assumes the square path) -----------
    d_lut = 1.0 + 0.35 * r + 0.2 * _log2(wa + wb + wc)
    d_add = 0.5 * _log2(acc_w)
    if design.degree == 2 and s > 0:
        d_sq = 0.8 * _log2(s)
        d_mul = 0.8 * _log2(wa) + 0.8 * _log2(2 * s)
        delay = max(d_sq + d_mul, d_lut) + d_add
    else:
        d_mul = 0.8 * _log2(wb) + 0.8 * _log2(lb)
        delay = max(d_mul, d_lut) + d_add
    return AreaDelay(area=area, delay=delay)
