"""Function specifications: fixed-point targets with integer bound functions.

The paper specifies a target only through integer upper/lower bound functions
``u, l`` over the input codes (§II): any implementation whose integer output
lands in ``[l(Z), u(Z)]`` for every code ``Z`` is correct. This module builds
those bound arrays for the paper's three functions (reciprocal, log2, exp2)
and for the ML-numerics functions used by the transformer stack (exp2 of a
negative fraction for softmax, rsqrt for RMSNorm, sigmoid/SiLU, softplus).

Exactness: reciprocal bounds are computed in exact integer arithmetic; the
transcendental ones use float64 (as the paper used Python's math library) and
every generated table is later re-verified exhaustively in int64, so a float
edge case can only cost a retry, never an unsound artifact (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

# Input window of the direct activation tables (silu / sigmoid / softplus /
# gelu): codes map affinely onto [ACT_LO, ACT_HI). The float glue in
# ``repro.numerics`` and the library metadata in ``repro.api.library`` both
# read these — the window lives here, next to the bound makers, and nowhere
# else.
ACT_LO, ACT_HI = -8.0, 8.0
ACT_KINDS = ("silu", "sigmoid", "softplus", "gelu", "tanh")


def act_out_span(kind: str, lo: float = ACT_LO, hi: float = ACT_HI) -> float:
    """Output span S of a direct activation table: the stored integer is
    ``value * 2^out_bits / S``, so the float glue rescales by
    ``S / 2^out_bits``. sigmoid's range is (0, 1), tanh's (-1, 1); the
    others scale by the input window width so the signed/linear tails stay
    representable."""
    if kind not in ACT_KINDS:
        raise KeyError(f"{kind!r} is not a direct activation table")
    if kind == "sigmoid":
        return 1.0
    if kind == "tanh":
        return 2.0
    return hi - lo


@dataclasses.dataclass(frozen=True)
class FunctionSpec:
    """A fixed-point approximation target.

    Attributes:
      name: identifier, e.g. ``recip16``.
      in_bits: input code width; codes run over ``[0, 2^in_bits)``.
      out_bits: nominal output width (bits of the produced integer; used for
        reporting and the area model — bounds carry the real constraint).
      bounds: callable mapping an int64 code array to ``(L, U)`` int64 arrays.
      value: callable mapping codes to the real-valued target on the output
        integer grid (for plotting/Remez); may be None for bound-only specs.
      ulp: the accuracy budget in output ULPs used to build default bounds.
      signed_output: whether outputs may be negative (SiLU).
    """

    name: str
    in_bits: int
    out_bits: int
    bounds: Callable[[np.ndarray], tuple[np.ndarray, np.ndarray]]
    value: Callable[[np.ndarray], np.ndarray] | None = None
    ulp: float = 1.0
    signed_output: bool = False

    def bound_arrays(self) -> tuple[np.ndarray, np.ndarray]:
        codes = np.arange(1 << self.in_bits, dtype=np.int64)
        lo, hi = self.bounds(codes)
        if np.any(lo > hi):
            raise ValueError(f"{self.name}: empty bound interval")
        return lo.astype(np.int64), hi.astype(np.int64)

    def region_bounds(self, lookup_bits: int) -> tuple[np.ndarray, np.ndarray]:
        """(L, U) reshaped to (2^R, 2^W): one row per region r."""
        lo, hi = self.bound_arrays()
        r = 1 << lookup_bits
        return lo.reshape(r, -1), hi.reshape(r, -1)


def _float_bounds(values: np.ndarray, ulp: float) -> tuple[np.ndarray, np.ndarray]:
    """Default ±ulp bounds around real-valued targets on the integer grid."""
    lo = np.ceil(values - ulp).astype(np.int64)
    hi = np.floor(values + ulp).astype(np.int64)
    return lo, hi


def make_reciprocal(bits: int, ulp: float = 1.0) -> FunctionSpec:
    """``0.1y = 1 / 1.x`` (paper Table I), exact integer bounds.

    Input code Z: X = 1 + Z/2^bits in [1, 2).  Output integer targets
    V = 2^(2*bits+1) / (2^bits + Z), spanning (2^bits, 2^(bits+1)].
    """
    num = 1 << (2 * bits + 1)

    def bounds(codes: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        # V = num/den; |Y - V| <= ulp with exact rational comparisons.
        # ceil(num/den - ulp) and floor(num/den + ulp) for rational ulp.
        u_num, u_den = _as_fraction(ulp)
        den64 = (1 << bits) + codes.astype(np.int64)
        d_max = int(den64.max()) if len(den64) else 1
        if num * u_den + u_num * d_max < (1 << 62):
            # every intermediate fits int64: numpy floor division is exact
            # and rounds toward -inf exactly like python's // on negatives
            lo = -((-(num * u_den - u_num * den64)) // (den64 * u_den))
            hi = (num * u_den + u_num * den64) // (den64 * u_den)
            return lo, hi
        den = (1 << bits) + codes.astype(object)  # exact python ints
        lo = [-((-(num * u_den - u_num * int(d))) // (int(d) * u_den)) for d in den]
        hi = [(num * u_den + u_num * int(d)) // (int(d) * u_den) for d in den]
        return np.array(lo, dtype=np.int64), np.array(hi, dtype=np.int64)

    def value(codes: np.ndarray) -> np.ndarray:
        return num / ((1 << bits) + codes.astype(np.float64))

    return FunctionSpec(f"recip{bits}", bits, bits + 1, bounds, value, ulp)


def _as_fraction(x: float) -> tuple[int, int]:
    from fractions import Fraction

    f = Fraction(x).limit_denominator(1 << 20)
    return f.numerator, f.denominator


def make_log2(bits: int, out_bits: int | None = None, ulp: float = 1.0) -> FunctionSpec:
    """``0.y = log2(1.x)`` (paper Table I: 16 -> 17)."""
    out_bits = out_bits if out_bits is not None else bits + 1

    def value(codes: np.ndarray) -> np.ndarray:
        x = 1.0 + codes.astype(np.float64) / (1 << bits)
        return np.log2(x) * (1 << out_bits)

    return FunctionSpec(
        f"log2_{bits}", bits, out_bits, lambda c: _float_bounds(value(c), ulp), value, ulp
    )


def make_exp2(bits: int, out_bits: int | None = None, ulp: float = 1.0) -> FunctionSpec:
    """``1.y = 2^(0.x)`` (paper Table I)."""
    out_bits = out_bits if out_bits is not None else bits

    def value(codes: np.ndarray) -> np.ndarray:
        x = codes.astype(np.float64) / (1 << bits)
        return np.exp2(x) * (1 << out_bits)

    return FunctionSpec(
        f"exp2_{bits}", bits, out_bits + 1, lambda c: _float_bounds(value(c), ulp), value, ulp
    )


def make_exp2neg(bits: int, out_bits: int | None = None, ulp: float = 1.0) -> FunctionSpec:
    """``y = 2^(-0.x)`` in (1/2, 1] — the softmax exponential's fraction part."""
    out_bits = out_bits if out_bits is not None else bits

    def value(codes: np.ndarray) -> np.ndarray:
        x = codes.astype(np.float64) / (1 << bits)
        return np.exp2(-x) * (1 << out_bits)

    return FunctionSpec(
        f"exp2neg_{bits}", bits, out_bits, lambda c: _float_bounds(value(c), ulp), value, ulp
    )


def make_rsqrt(bits: int, out_bits: int | None = None, ulp: float = 1.0) -> FunctionSpec:
    """``y = 1/sqrt(1.x or 1x.x)`` over X in [1, 4) — RMSNorm normalizer.

    Input code covers [1,4): X = 1 + 3*Z/2^bits is NOT hardware-friendly;
    instead use two implicit-exponent segments: X = 2^(Z_top) * (1 + frac)
    with the top input bit selecting [1,2) vs [2,4).
    """
    out_bits = out_bits if out_bits is not None else bits

    def value(codes: np.ndarray) -> np.ndarray:
        z = codes.astype(np.float64)
        seg = np.floor(z / (1 << (bits - 1)))  # 0 -> [1,2), 1 -> [2,4)
        frac = (z - seg * (1 << (bits - 1))) / (1 << (bits - 1))
        x = (1.0 + frac) * (2.0**seg)
        return (1 << out_bits) / np.sqrt(x)

    return FunctionSpec(
        f"rsqrt{bits}", bits, out_bits, lambda c: _float_bounds(value(c), ulp), value, ulp
    )


def make_sigmoid(bits: int, out_bits: int | None = None, lo: float = ACT_LO, hi: float = ACT_HI,
                 ulp: float = 1.0) -> FunctionSpec:
    """``y = sigmoid(s)``, s affinely mapped from codes over [lo, hi)."""
    out_bits = out_bits if out_bits is not None else bits

    def value(codes: np.ndarray) -> np.ndarray:
        s = lo + (hi - lo) * codes.astype(np.float64) / (1 << bits)
        return (1 << out_bits) / (1.0 + np.exp(-s))

    return FunctionSpec(
        f"sigmoid{bits}", bits, out_bits, lambda c: _float_bounds(value(c), ulp), value, ulp
    )


def make_silu(bits: int, out_bits: int | None = None, lo: float = ACT_LO, hi: float = ACT_HI,
              ulp: float = 1.0) -> FunctionSpec:
    """``y = s * sigmoid(s)`` — signed output (min ~= -0.278 * scale / range)."""
    out_bits = out_bits if out_bits is not None else bits

    def value(codes: np.ndarray) -> np.ndarray:
        s = lo + (hi - lo) * codes.astype(np.float64) / (1 << bits)
        return s / (1.0 + np.exp(-s)) * (1 << out_bits) / (hi - lo)

    return FunctionSpec(
        f"silu{bits}", bits, out_bits, lambda c: _float_bounds(value(c), ulp), value, ulp,
        signed_output=True,
    )


def make_softplus(bits: int, out_bits: int | None = None, lo: float = ACT_LO, hi: float = ACT_HI,
                  ulp: float = 1.0) -> FunctionSpec:
    """``y = log(1 + e^s)`` — Mamba2's dt activation."""
    out_bits = out_bits if out_bits is not None else bits

    def value(codes: np.ndarray) -> np.ndarray:
        s = lo + (hi - lo) * codes.astype(np.float64) / (1 << bits)
        return np.logaddexp(0.0, s) * (1 << out_bits) / (hi - lo)

    return FunctionSpec(
        f"softplus{bits}", bits, out_bits, lambda c: _float_bounds(value(c), ulp), value, ulp
    )


def make_gelu(bits: int, out_bits: int | None = None, lo: float = ACT_LO, hi: float = ACT_HI,
              ulp: float = 1.0) -> FunctionSpec:
    """tanh-form GELU (Whisper/ViT MLPs) — signed output."""
    out_bits = out_bits if out_bits is not None else bits

    def value(codes: np.ndarray) -> np.ndarray:
        s = lo + (hi - lo) * codes.astype(np.float64) / (1 << bits)
        inner = np.sqrt(2.0 / np.pi) * (s + 0.044715 * s**3)
        return 0.5 * s * (1.0 + np.tanh(inner)) * (1 << out_bits) / (hi - lo)

    return FunctionSpec(
        f"gelu{bits}", bits, out_bits, lambda c: _float_bounds(value(c), ulp), value, ulp,
        signed_output=True,
    )


def make_tanh(bits: int, out_bits: int | None = None, lo: float = ACT_LO, hi: float = ACT_HI,
              ulp: float = 1.0) -> FunctionSpec:
    """``y = tanh(s)`` — signed output in (-1, 1), span 2 (Jamba/Mamba gates,
    classic RNN cells; the VLSI segmentation literature's canonical case)."""
    out_bits = out_bits if out_bits is not None else bits

    def value(codes: np.ndarray) -> np.ndarray:
        s = lo + (hi - lo) * codes.astype(np.float64) / (1 << bits)
        return np.tanh(s) * (1 << out_bits) / 2.0

    return FunctionSpec(
        f"tanh{bits}", bits, out_bits, lambda c: _float_bounds(value(c), ulp), value, ulp,
        signed_output=True,
    )


MAKERS: dict[str, Callable[..., FunctionSpec]] = {
    "tanh": make_tanh,
    "recip": make_reciprocal,
    "log2": make_log2,
    "exp2": make_exp2,
    "exp2neg": make_exp2neg,
    "rsqrt": make_rsqrt,
    "sigmoid": make_sigmoid,
    "silu": make_silu,
    "softplus": make_softplus,
    "gelu": make_gelu,
}


def get_spec(kind: str, bits: int, **kw) -> FunctionSpec:
    return MAKERS[kind](bits, **kw)
