"""Multi-objective Pareto-frontier extraction (minimize every axis).

One implementation shared by :class:`repro.api.result.DesignSpaceResult`
(the per-spec (area, delay) frontier) and the :mod:`repro.dse` study layer
(the full-stack (area, delay, -accuracy-margin, -tokens/sec) frontier).
Both previously needed the same logic; ``DesignSpaceResult.pareto`` carried
an inline 2-D copy, and the study layer would have grown a second one.

Semantics (k objectives, all minimized):

  * a point is dropped iff some *other* point weakly dominates it — every
    coordinate <= , with duplicates resolved by keeping only the first in
    the canonical order below;
  * the kept indices come back sorted by objective vector (ties broken by
    original index), i.e. ascending along the first objective — exactly the
    ordering the old 2-D code produced.
"""
from __future__ import annotations

from typing import Iterable, Sequence


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """Weak domination: ``a`` no worse than ``b`` on every (minimized) axis.

    Equal vectors dominate each other; callers that need strictness check
    ``a != b`` themselves (the frontier code resolves ties positionally).
    """
    if len(a) != len(b):
        raise ValueError(f"objective arity mismatch: {len(a)} vs {len(b)}")
    return all(x <= y for x, y in zip(a, b))


def pareto_indices(points: Iterable[Sequence[float]]) -> list[int]:
    """Indices of the non-dominated points, sorted by objective vector.

    Exact duplicates keep only the earliest original index — matching the
    stable-sort-then-scan behaviour of the seed's 2-D frontier. O(n * front)
    comparisons; study and R-sweep frontiers are tens of points, not
    millions.
    """
    pts = [tuple(float(x) for x in p) for p in points]
    if pts:
        k = len(pts[0])
        for p in pts:
            if len(p) != k:
                raise ValueError("ragged objective vectors")
    order = sorted(range(len(pts)), key=lambda i: (pts[i], i))
    kept: list[int] = []
    for i in order:
        # earlier kept points are sorted <= lexicographically, so checking
        # kept alone suffices: weak domination is transitive through any
        # dropped intermediary
        if not any(dominates(pts[j], pts[i]) for j in kept):
            kept.append(i)
    return kept


def pareto_front(points: Iterable[Sequence[float]]) -> list[tuple[float, ...]]:
    """The non-dominated vectors themselves, sorted ascending."""
    pts = [tuple(float(x) for x in p) for p in points]
    return [pts[i] for i in pareto_indices(pts)]
