"""Complete design-space generation (paper §II, Eqns 1-10).

For each region ``r`` (top ``R`` input bits) with integer bound rows ``L, U``
over ``x in [0, 2^W)``, a feasible quadratic ``(a, b, c, k)`` satisfies

    forall x:  2^k L[x] <= a x^2 + b x + c < 2^k (U[x] + 1).

The chain of interval conditions:

  c:  max_x (2^k L - a x^2 - b x)  <=  c  <  min_x (2^k (U+1) - a x^2 - b x)   (1)
  b:  max_t (2^k M(t) - a t)  <  b  <  min_t (2^k m(t) - a t)                  (3,4)
  a:  max_{t<s} (M(s)-m(t))/(s-t) < a/2^k < min_{t<s} (m(s)-M(t))/(s-t)        (7,8)

with the per-sum envelopes over divided differences d(x,y) = (U[y]+1-L[x])/(y-x):

  m(t) = min_{x<y, x+y=t} (U[y]+1-L[x])/(y-x)      ("upper" slope envelope)
  M(t) = max_{x<y, x+y=t} (L[y]-U[x]-1)/(y-x)      ("lower" slope envelope)

Region feasibility (9,10): forall t: M(t) < m(t), and a_lo < a_hi above.

c-intervals are computed in exact int64 arithmetic; M/m and the a/b bounds run
in float64 and every emitted design is exhaustively re-verified (table.py).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import searches
from repro.core.funcspec import FunctionSpec

# Enumeration caps (the design *space* is complete; exploration caps only
# bound the heuristic decision procedure, see DESIGN.md §4).
A_ENUM_CAP = 1024
A_UNCONSTRAINED = 1 << 20


def resolve_engine(engine: str | None) -> str:
    """``engine`` or ``api.config.DEFAULT_ENGINE``, validated against
    ``api.config.ENGINES`` (deferred import — same layering rule as
    :func:`repro.core.searches.resolve_impl`)."""
    from repro.api.config import DEFAULT_ENGINE, ENGINES

    if engine is None:
        return DEFAULT_ENGINE
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; expected one of {ENGINES}")
    return engine


def envelopes(L: np.ndarray, U: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-sum-t envelopes M(t), m(t) as arrays of size ``2N - 2``.

    A pair ``x < y`` exists exactly for sums ``t`` in ``[1, 2N-3]``, so the
    returned arrays are indexed ``t = 0 .. 2N-3`` with index 0 a placeholder
    (-inf / +inf) and every ``t >= 1`` finite. Pure strided-slice updates — no
    scatter — one vector op per delta (this is the §II-A hot loop; the batched
    twin is ``core.batched.batched_envelopes``, the Pallas twin lives in
    kernels/dspace).
    """
    n = len(L)
    if n < 2:
        return np.full(1, -np.inf), np.full(1, np.inf)
    t_size = 2 * n - 2
    big_m = np.full(t_size, -np.inf)
    small_m = np.full(t_size, np.inf)
    lf = L.astype(np.float64)
    uf = U.astype(np.float64)
    for delta in range(1, n):
        up = (uf[delta:] + 1.0 - lf[: n - delta]) / delta
        lo = (lf[delta:] - uf[: n - delta] - 1.0) / delta
        sl = slice(delta, 2 * n - 1 - delta, 2)
        small_m[sl] = np.minimum(small_m[sl], up)
        big_m[sl] = np.maximum(big_m[sl], lo)
    return big_m, small_m


@dataclasses.dataclass
class RegionSpace:
    """Envelopes + real a-interval for one region (Eqns 9-10)."""

    big_m: np.ndarray  # M(t)
    small_m: np.ndarray  # m(t)
    a_lo: float  # sup of Eqn 8 RHS (a/2^k strictly above)
    a_hi: float  # inf of Eqn 7 RHS (a/2^k strictly below)
    feasible: bool

    @property
    def linear_ok(self) -> bool:
        return self.feasible and self.a_lo < 0.0 < self.a_hi


def region_space(L: np.ndarray, U: np.ndarray, impl: str | None = None) -> RegionSpace:
    big_m, small_m = envelopes(L, U)
    n = len(L)
    if n <= 2:
        # 1-2 evaluation points: any slope/curvature works pointwise; Eqn 10
        # is vacuous. Treat a as unconstrained (clamped later).
        lo, hi = -np.inf, np.inf
        ok = bool(np.all(big_m[1:] < small_m[1:])) if n == 2 else True
        return RegionSpace(big_m, small_m, lo, hi, ok)
    mt, st = big_m[1:], small_m[1:]  # valid t range, all finite
    if not np.all(mt < st):  # Eqn 9
        return RegionSpace(big_m, small_m, np.nan, np.nan, False)
    a_lo, *_ = searches.max_dd(mt, st, impl)  # max (M(s)-m(t))/(s-t)
    a_hi, *_ = searches.min_dd(st, mt, impl)  # min (m(s)-M(t))/(s-t)
    return RegionSpace(big_m, small_m, a_lo, a_hi, a_lo < a_hi)  # Eqn 10


def b_interval(space: RegionSpace, a: int, k: int) -> tuple[int, int]:
    """Integer interval [b_min, b_max] (inclusive) from Eqns 3-4; empty if
    b_min > b_max."""
    t_size = len(space.big_m)
    ts = np.arange(1, t_size, dtype=np.float64)
    scale = float(1 << k)
    lo = np.max(scale * space.big_m[1:] - a * ts)
    hi = np.min(scale * space.small_m[1:] - a * ts)
    b_min = int(math.floor(lo)) + 1
    b_max = int(math.ceil(hi)) - 1
    return b_min, b_max


def c_interval(L: np.ndarray, U: np.ndarray, a: int, b: int, k: int,
               sq: np.ndarray | None = None, lin: np.ndarray | None = None
               ) -> tuple[int, int]:
    """Exact integer interval [c_min, c_max] (inclusive) from Eqn 1.

    ``sq``/``lin`` override the x^2 / x basis vectors (used by the truncation
    steps of the decision procedure: sq = trunc_i(x)^2, lin = trunc_j(x)).
    """
    n = len(L)
    x = np.arange(n, dtype=np.int64)
    sq = (x * x) if sq is None else sq.astype(np.int64)
    lin = x if lin is None else lin.astype(np.int64)
    poly = int(a) * sq + int(b) * lin
    lo = (L.astype(np.int64) << k) - poly
    hi = ((U.astype(np.int64) + 1) << k) - poly
    return int(lo.max()), int(hi.min()) - 1


def a_window(space: RegionSpace, k: int, cap: int = A_ENUM_CAP
             ) -> tuple[int, int] | None:
    """The capped contiguous window [a_min, a_max] of admissible integer a
    values strictly inside (2^k a_lo, 2^k a_hi) — the exact SET that
    :func:`a_candidates` enumerates; ``None`` when empty."""
    scale = float(1 << k)
    lo = space.a_lo * scale
    hi = space.a_hi * scale
    a_min = int(math.floor(lo)) + 1 if np.isfinite(lo) else -A_UNCONSTRAINED
    a_max = int(math.ceil(hi)) - 1 if np.isfinite(hi) else A_UNCONSTRAINED
    if a_min > a_max:
        return None
    if a_max - a_min + 1 > cap:
        # keep the magnitude-ordered prefix around 0 or the nearest end
        center = min(max(0, a_min), a_max)
        half = cap // 2
        a_min2 = max(a_min, center - half)
        a_max2 = min(a_max, a_min2 + cap - 1)
        a_min, a_max = a_min2, a_max2
    return a_min, a_max


def a_magnitude_order(a_min: int, a_max: int):
    """Yield [a_min, a_max] in the |a|-then-negative-first order of
    ``sorted(range(a_min, a_max + 1), key=abs)`` (Python's stable sort puts
    -m before +m), without materializing the window."""
    if a_min > 0:
        yield from range(a_min, a_max + 1)
    elif a_max < 0:
        yield from range(a_max, a_min - 1, -1)
    else:
        yield 0
        m = 1
        while -m >= a_min or m <= a_max:
            if -m >= a_min:
                yield -m
            if m <= a_max:
                yield m
            m += 1


def a_candidates(space: RegionSpace, k: int, cap: int = A_ENUM_CAP) -> list[int]:
    """Integer a values strictly inside (2^k a_lo, 2^k a_hi), small |a| first."""
    win = a_window(space, k, cap)
    if win is None:
        return []
    return list(a_magnitude_order(*win))


@dataclasses.dataclass
class Candidate:
    """One surviving (a, integer-b-interval) choice for a region."""

    a: int
    b_min: int
    b_max: int


@dataclasses.dataclass
class DesignSpace:
    """The complete feasible space for (spec, R) at precision slack k."""

    spec: FunctionSpec
    lookup_bits: int
    k: int
    L: np.ndarray  # (2^R, 2^W)
    U: np.ndarray
    spaces: list[RegionSpace]
    candidates: list[list[Candidate]]  # per region
    linear: bool  # True if generated with a forced to 0

    @property
    def eval_bits(self) -> int:
        return self.spec.in_bits - self.lookup_bits  # W

    @property
    def feasible(self) -> bool:
        return all(len(c) > 0 for c in self.candidates)


def _region_candidates(space: RegionSpace, L: np.ndarray, U: np.ndarray, k: int,
                       force_linear: bool) -> list[Candidate]:
    out: list[Candidate] = []
    if not space.feasible:
        return out
    avals = [0] if force_linear else a_candidates(space, k)
    if force_linear and not (space.linear_ok or len(L) <= 2):
        return out
    n = len(L)
    for a in avals:
        if n == 1:
            lo, hi = c_interval(L, U, a, 0, k)
            if lo <= hi:
                out.append(Candidate(a, 0, 0))
            continue
        b_min, b_max = b_interval(space, a, k)
        if b_min > b_max:
            continue
        # Exact confirmation at one witness b (guards float slop in M/m);
        # widen to neighbours if the float bound was off by one.
        ok = None
        for b in (b_min, b_min + 1, b_max, b_min - 1):
            if b_min - 1 <= b <= b_max + 1:
                lo, hi = c_interval(L, U, a, b, k)
                if lo <= hi:
                    ok = b
                    break
        if ok is None:
            continue
        out.append(Candidate(a, b_min, b_max))
    return out


def _space_worker(args):
    L_row, U_row, impl = args
    return region_space(L_row, U_row, impl)


def _cand_worker(args):
    space, L_row, U_row, k, force_linear = args
    return _region_candidates(space, L_row, U_row, k, force_linear)


def compute_spaces(L: np.ndarray, U: np.ndarray, impl: str | None = None,
                   engine: str | None = None, pool=None) -> list[RegionSpace]:
    """All per-region RegionSpaces under the selected engine.

    ``batched``/``pallas`` run one array program over the stacked
    ``(regions, N)`` rows; ``pooled`` is the seed's per-region dispatch
    (and the equivalence oracle — all engines agree, exactly for
    ``batched``, to float32 for ``pallas``).
    """
    engine = resolve_engine(engine)
    if engine == "pooled":
        from repro.core.pmap import RegionPool

        pool = pool or RegionPool(1)
        return pool.map(_space_worker,
                        [(L[r], U[r], impl) for r in range(L.shape[0])])
    from repro.core import batched

    if engine == "pallas":
        return batched.region_spaces_pallas(L, U)
    return batched.region_spaces(L, U)


def build_design_space(spec: FunctionSpec, lookup_bits: int, k: int,
                       force_linear: bool = False, impl: str | None = None,
                       spaces: list[RegionSpace] | None = None,
                       pool=None, engine: str | None = None,
                       bounds: tuple[np.ndarray, np.ndarray] | None = None
                       ) -> DesignSpace:
    engine = resolve_engine(engine)
    L, U = bounds if bounds is not None else spec.region_bounds(lookup_bits)
    if spaces is None:
        spaces = compute_spaces(L, U, impl, engine, pool)
    if engine == "pooled":
        from repro.core.pmap import RegionPool

        pool = pool or RegionPool(1)
        cands = pool.map(_cand_worker,
                         [(spaces[r], L[r], U[r], k, force_linear)
                          for r in range(L.shape[0])])
    else:
        from repro.core import batched

        cands = batched.design_candidates(spaces, L, U, k, force_linear)
    return DesignSpace(spec, lookup_bits, k, L, U, spaces, cands, force_linear)


def regions_feasible(spec: FunctionSpec, lookup_bits: int, impl: str | None = None,
                     pool=None, engine: str | None = None,
                     bounds: tuple[np.ndarray, np.ndarray] | None = None
                     ) -> tuple[bool, list[RegionSpace]]:
    """Eqns 9-10 over every region: does ANY piecewise quadratic exist?"""
    L, U = bounds if bounds is not None else spec.region_bounds(lookup_bits)
    spaces = compute_spaces(L, U, impl, engine, pool)
    return all(s.feasible for s in spaces), spaces


def minimal_k(spec: FunctionSpec, lookup_bits: int, force_linear: bool = False,
              impl: str | None = None, k_max: int = 24,
              pool=None, spaces: list[RegionSpace] | None = None,
              engine: str | None = None,
              bounds: tuple[np.ndarray, np.ndarray] | None = None
              ) -> DesignSpace | None:
    """Decision step 1: smallest k giving >=1 integer candidate per region.

    "k can be increased until the intervals contain an integer" (paper §II);
    across all regions k is constant. ``spaces`` short-circuits the envelope
    computation — RegionSpace is target-independent, so callers (the
    ``repro.api.Explorer`` session) compute it once per (spec, R) and reuse
    it across k values, targets, and decision policies.
    """
    if spaces is None:
        ok, spaces = regions_feasible(spec, lookup_bits, impl, pool=pool,
                                      engine=engine, bounds=bounds)
        if not ok:
            return None
    elif not all(s.feasible for s in spaces):
        return None
    if bounds is None:
        bounds = spec.region_bounds(lookup_bits)  # invariant across the k loop
    for k in range(k_max + 1):
        ds = build_design_space(spec, lookup_bits, k, force_linear, impl, spaces,
                                pool=pool, engine=engine, bounds=bounds)
        if ds.feasible:
            return ds
    return None
