"""Model assembly: blocks -> scanned segments -> LM with loss / prefill /
decode. Covers all six families (dense, moe, ssm, hybrid, encdec, vlm).

Layer stacks are grouped into *segments* of identical parameter structure and
executed with ``lax.scan`` over stacked parameters, keeping HLO size (and
512-device compile time) independent of depth. Hybrid (Jamba) uses a period-8
macro-block so the 1:7 attn:mamba interleave with alternating MoE still
scans. ``jax.checkpoint`` wraps each scanned body per ``cfg.remat``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.launch.sharding import constrain
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (Params, ShapeTree, apply_mlp, apply_norm,
                                 embed_shapes, embed_tokens, init_tree,
                                 lm_logits, mlp_shapes, norm_shapes, pdtype,
                                 spec, stack_specs)

LOSS_CHUNK = 512


# ---------------------------------------------------------------------------
# layer plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerKind:
    mixer: str  # attn | mla | ssm
    ffn: str | None  # mlp | moe | None
    mlp_ff: int = 0  # dense MLP hidden size when ffn == "mlp"


@dataclasses.dataclass(frozen=True)
class Segment:
    pattern: tuple[LayerKind, ...]  # sub-layers inside one scanned step
    repeat: int  # scan length


def layer_plan(cfg) -> list[Segment]:
    if cfg.family == "ssm":
        return [Segment((LayerKind("ssm", None),), cfg.n_layers)]
    if cfg.family == "hybrid":
        period = cfg.attn_period
        assert cfg.n_layers % period == 0
        pattern = []
        for i in range(period):
            mixer = "attn" if i == period // 2 else "ssm"
            ffn = "moe" if (cfg.moe and i % cfg.moe.every == 1) else "mlp"
            pattern.append(LayerKind(mixer, ffn, cfg.d_ff))
        return [Segment(tuple(pattern), cfg.n_layers // period)]
    mixer = "mla" if cfg.mla is not None else "attn"
    if cfg.family == "moe":
        segs = []
        n = cfg.n_layers
        if cfg.first_dense_ff:
            segs.append(Segment((LayerKind(mixer, "mlp", cfg.first_dense_ff),), 1))
            n -= 1
        segs.append(Segment((LayerKind(mixer, "moe"),), n))
        return segs
    # dense / vlm / encdec-decoder
    return [Segment((LayerKind(mixer, "mlp", cfg.d_ff),), cfg.n_layers)]


# ---------------------------------------------------------------------------
# block shapes
# ---------------------------------------------------------------------------

def _mixer_shapes(kind: LayerKind, cfg) -> ShapeTree:
    if kind.mixer == "ssm":
        return ssm_mod.ssm_shapes(cfg)
    if kind.mixer == "mla":
        return attn.mla_shapes(cfg)
    return attn.gqa_shapes(cfg)


def block_shapes(kind: LayerKind, cfg, cross: bool = False) -> ShapeTree:
    out: ShapeTree = {"norm1": norm_shapes(cfg), "mixer": _mixer_shapes(kind, cfg)}
    if cross:
        out["norm_x"] = norm_shapes(cfg)
        out["cross"] = attn.cross_shapes(cfg)
    if kind.ffn is not None:
        out["norm2"] = norm_shapes(cfg)
        out["ffn"] = (moe_mod.moe_shapes(cfg) if kind.ffn == "moe"
                      else mlp_shapes(cfg, kind.mlp_ff))
    return out


def segment_shapes(seg: Segment, cfg, cross: bool = False) -> ShapeTree:
    inner = {str(i): block_shapes(k, cfg, cross) for i, k in enumerate(seg.pattern)}
    return stack_specs(inner, seg.repeat) if seg.repeat > 1 else inner


# ---------------------------------------------------------------------------
# block apply (train path + cache-emitting / cache-consuming variants)
# ---------------------------------------------------------------------------

def apply_block(p: Params, kind: LayerKind, h, positions, cfg, numerics,
                mode: str = "train", cache=None, cache_len: int = 0,
                cross_kv=None, pos=None):
    """Returns (h, new_cache, aux_loss). ``cross_kv`` is the *encoder hidden
    state* (B, S_src, d); per-layer K/V are derived from it inside the block
    so scanned decoder stacks keep one parameter structure."""
    # Megatron sequence parallelism (perf iteration C1): the residual stream
    # is sharded along seq over the model axis between mixer/FFN bodies —
    # norms/adds run 1/16th-size, the scan carry shrinks 16x, and GSPMD
    # materializes the all-gather(seq) / reduce-scatter(seq) pair around the
    # head-sharded attention and TP MLP exactly like Megatron-SP. No-op when
    # seq doesn't divide the axis (decode S=1, whisper enc 1500).
    h = constrain(h, ("batch", "seq", None))
    # C2: pin the norm OUTPUT to the seq shard too — otherwise GSPMD hoists
    # the all-gather above the norm and the f32 norm intermediates run at
    # full sequence length inside the layer scan (measured 38x
    # f32[B,S,d] = 2.1 GB/op on qwen-110b). Megatron-SP gathers the bf16
    # norm output, 4x smaller and 1/16th as often.
    x = constrain(apply_norm(p["norm1"], h, cfg, numerics),
                  ("batch", "seq", None))
    new_cache = None
    if kind.mixer == "ssm":
        if mode == "train":
            y = ssm_mod.ssm_train(p["mixer"], x, cfg, numerics)
        elif mode == "prefill":
            y, new_cache = ssm_mod.ssm_prefill(p["mixer"], x, cfg, numerics)
        else:
            y, new_cache = ssm_mod.ssm_decode(p["mixer"], x, cache, cfg, numerics)
    elif kind.mixer == "mla":
        if mode == "train":
            y = attn.mla_train(p["mixer"], x, positions, cfg, numerics)
        elif mode == "prefill":
            y, new_cache = attn.mla_prefill(p["mixer"], x, positions, cfg, numerics, cache_len)
        else:
            y, new_cache = attn.mla_decode(p["mixer"], x, pos, cache, cfg, numerics)
    else:
        if mode == "train":
            y = attn.gqa_train(p["mixer"], x, positions, cfg, numerics)
        elif mode == "prefill":
            y, new_cache = attn.gqa_prefill(p["mixer"], x, positions, cfg, numerics, cache_len)
        else:
            y, new_cache = attn.gqa_decode(p["mixer"], x, pos, cache, cfg, numerics)
    h = h + y
    if cross_kv is not None:
        xc = apply_norm(p["norm_x"], h, cfg, numerics)
        kv = attn.cross_kv(p["cross"], cross_kv, cfg)
        h = h + attn.cross_apply(p["cross"], xc, kv, cfg, numerics)
    aux = jnp.zeros((), jnp.float32)
    if kind.ffn is not None:
        x2 = constrain(apply_norm(p["norm2"], h, cfg, numerics),
                       ("batch", "seq", None))
        if kind.ffn == "moe":
            y2, probs = moe_mod.moe_block(p["ffn"], x2, cfg, numerics,
                                          return_probs=True)
            if mode == "train":
                aux = moe_mod.load_balance_loss_from_probs(probs, cfg)
        else:
            y2 = apply_mlp(p["ffn"], x2, cfg, numerics)
        h = h + y2
    h = constrain(h, ("batch", "seq", None))
    return h, new_cache, aux


def _maybe_remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy)


def apply_segment(p_seg: Params, seg: Segment, h, positions, cfg, numerics,
                  mode: str = "train", caches=None, cache_len: int = 0,
                  cross_kv=None, pos=None, layer_offset: int = 0):
    """Scan a segment. caches: pytree stacked over `repeat` (or None).

    ``numerics`` is either one backend for the whole segment (the
    homogeneous path) or a plan-resolved object exposing ``for_layer(i)``
    (``repro.plan.numerics.PlanNumerics``); ``layer_offset`` is the global
    index of this segment's first layer. Heterogeneous plans split the scan
    into runs of consecutive layers with identical assignments — a uniform
    plan collapses to a single run over the unsliced stack, i.e. exactly
    the homogeneous program.

    Returns (h, stacked caches or None, aux sum).
    """
    npat = len(seg.pattern)

    def make_body(layer_nums):
        def body(carry, xs):
            h_in = carry
            p_layer, cache_layer = xs
            aux_sum = jnp.zeros((), jnp.float32)
            new_caches = {}
            for i, kind in enumerate(seg.pattern):
                c_i = cache_layer[str(i)] if cache_layer is not None else None
                h_out, nc, aux = apply_block(
                    p_layer[str(i)], kind, h_in, positions, cfg,
                    layer_nums[i], mode=mode, cache=c_i, cache_len=cache_len,
                    cross_kv=cross_kv, pos=pos)
                h_in = h_out
                new_caches[str(i)] = nc
                aux_sum = aux_sum + aux
            return h_in, (new_caches, aux_sum)
        return body

    plan_aware = hasattr(numerics, "for_layer")

    def nums_at(r: int):
        if not plan_aware:
            return (numerics,) * npat
        return tuple(numerics.for_layer(layer_offset + r * npat + j)
                     for j in range(npat))

    if seg.repeat == 1:
        h, (ncache, aux) = make_body(nums_at(0))(h, (p_seg, caches))
        return h, ncache, aux

    # runs of consecutive scan steps whose per-position numerics agree
    # (plan backends are interned, so equal assignments compare identical)
    groups: list[list] = []  # [start, length, layer_nums]
    for r in range(seg.repeat):
        nt = nums_at(r)
        if groups and groups[-1][2] == nt:
            groups[-1][1] += 1
        else:
            groups.append([r, 1, nt])

    if len(groups) == 1:
        body_fn = (_maybe_remat(make_body(groups[0][2]), cfg)
                   if mode == "train" else make_body(groups[0][2]))
        h, (ncaches, auxs) = jax.lax.scan(body_fn, h, (p_seg, caches))
        return h, ncaches, auxs.sum()

    aux_total = jnp.zeros((), jnp.float32)
    nc_parts = []
    for start, length, nt in groups:
        def sl(x, start=start, length=length):
            return jax.lax.slice_in_dim(x, start, start + length, axis=0)
        p_sl = jax.tree.map(sl, p_seg)
        c_sl = jax.tree.map(sl, caches) if caches is not None else None
        body_fn = (_maybe_remat(make_body(nt), cfg) if mode == "train"
                   else make_body(nt))
        h, (nc, auxs) = jax.lax.scan(body_fn, h, (p_sl, c_sl))
        nc_parts.append(nc)
        aux_total = aux_total + auxs.sum()
    ncaches = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *nc_parts)
    return h, ncaches, aux_total


# ---------------------------------------------------------------------------
# encoder (whisper): non-causal full-attention stack over stub frame embeddings
# ---------------------------------------------------------------------------

def encoder_shapes(cfg) -> ShapeTree:
    enc = cfg.encoder
    dt = pdtype(cfg)
    kind = LayerKind("attn", "mlp", cfg.d_ff)
    layer = {"norm1": norm_shapes(cfg), "mixer": attn.gqa_shapes(cfg),
             "norm2": norm_shapes(cfg), "ffn": mlp_shapes(cfg, cfg.d_ff)}
    return {
        "pos": spec((enc.source_len, cfg.d_model), dt),
        "layers": stack_specs(layer, enc.n_layers),
        "final_norm": norm_shapes(cfg),
    }


def encoder_forward(p: Params, frames: jax.Array, cfg, numerics) -> jax.Array:
    """frames: (B, S_src, d) stub frame/patch embeddings -> encoder hidden."""
    b, s, _ = frames.shape
    frames = frames.astype(pdtype(cfg))  # stub inputs arrive f32
    h = frames + p["pos"][:s].astype(frames.dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(h_in, p_layer):
        x = apply_norm(p_layer["norm1"], h_in, cfg, numerics)
        y = attn.gqa_train(p_layer["mixer"], x, positions, cfg, numerics, causal=False)
        h_mid = h_in + y
        x2 = apply_norm(p_layer["norm2"], h_mid, cfg, numerics)
        return h_mid + apply_mlp(p_layer["ffn"], x2, cfg, numerics), None

    h, _ = jax.lax.scan(body, h, p["layers"])
    return apply_norm(p["final_norm"], h, cfg, numerics)


# ---------------------------------------------------------------------------
# full-model parameter tree
# ---------------------------------------------------------------------------

def model_shapes(cfg) -> ShapeTree:
    dt = pdtype(cfg)
    cross = cfg.family == "encdec"
    out: ShapeTree = {
        "embed": embed_shapes(cfg),
        "segments": {f"seg{i}": segment_shapes(seg, cfg, cross)
                     for i, seg in enumerate(layer_plan(cfg))},
        "final_norm": norm_shapes(cfg),
    }
    if cfg.learned_pos:
        out["pos"] = spec((cfg.max_pos, cfg.d_model), dt)
    if cfg.encoder is not None:
        out["encoder"] = encoder_shapes(cfg)
    if cfg.frontend == "vision_stub":
        out["projector"] = {
            "norm": {"scale": spec((cfg.frontend_dim,), dt),
                     "bias": spec((cfg.frontend_dim,), dt)},
            "w1": spec((cfg.frontend_dim, cfg.d_model), dt),
            "b1": spec((cfg.d_model,), dt),
            "w2": spec((cfg.d_model, cfg.d_model), dt),
            "b2": spec((cfg.d_model,), dt),
        }
    return out


def init_params(key: jax.Array, cfg) -> Params:
    return init_tree(key, model_shapes(cfg))


def _project_frontend(p: Params, emb: jax.Array, cfg, numerics) -> jax.Array:
    """InternVL-style MLP projector: patch embeddings -> d_model tokens."""
    pr = p["projector"]
    xf = emb.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
    x = ((xf - mu) * jax.lax.rsqrt(var + 1e-5) * pr["norm"]["scale"]
         + pr["norm"]["bias"]).astype(emb.dtype)
    h = numerics.gelu(x @ pr["w1"] + pr["b1"])
    return (h @ pr["w2"] + pr["b2"]).astype(emb.dtype)


def _embed_inputs(p: Params, tokens: jax.Array, positions: jax.Array, cfg,
                  numerics, frontend_emb=None) -> jax.Array:
    h = embed_tokens(p["embed"], tokens)
    if frontend_emb is not None and cfg.frontend == "vision_stub":
        patches = _project_frontend(p, frontend_emb, cfg, numerics)
        n = patches.shape[1]
        h = jnp.concatenate([patches.astype(h.dtype), h[:, n:]], axis=1)
    if cfg.learned_pos:
        h = h + p["pos"][positions].astype(h.dtype)
    return constrain(h, ("batch", "seq", None))


# ---------------------------------------------------------------------------
# train-mode forward + chunked cross-entropy loss
# ---------------------------------------------------------------------------

def backbone(p: Params, h, positions, cfg, numerics, mode="train",
             caches=None, cache_len: int = 0, cross_kv=None, pos=None):
    """Run all segments. Returns (h, caches-per-segment, aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_caches = {}
    offset = 0
    for i, seg in enumerate(layer_plan(cfg)):
        name = f"seg{i}"
        c = caches[name] if caches is not None else None
        h, nc, a = apply_segment(p["segments"][name], seg, h, positions, cfg,
                                 numerics, mode=mode, caches=c,
                                 cache_len=cache_len, cross_kv=cross_kv,
                                 pos=pos, layer_offset=offset)
        new_caches[name] = nc
        aux = aux + a
        offset += seg.repeat * len(seg.pattern)
    h = apply_norm(p["final_norm"], h, cfg, numerics)
    return h, new_caches, aux


def forward(p: Params, tokens: jax.Array, cfg, numerics,
            frontend_emb=None, enc_frames=None) -> jax.Array:
    """Training-shaped forward -> logits (B, S, V). For large-vocab training
    use ``loss_fn`` instead (chunked CE, never materializes full logits)."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    cross = encoder_forward(p["encoder"], enc_frames, cfg, numerics) if enc_frames is not None else None
    h = _embed_inputs(p, tokens, positions, cfg, numerics, frontend_emb)
    h, _, _ = backbone(p, h, positions, cfg, numerics, cross_kv=cross)
    return lm_logits(p["embed"], h)


def chunked_ce_loss(p_embed: Params, h: jax.Array, labels: jax.Array,
                    mask: jax.Array) -> jax.Array:
    """Mean CE over masked tokens; logits materialized LOSS_CHUNK sequence
    positions at a time (vocab up to 256k x 1M tokens never forms a (B, S, V)
    buffer). Chunks run along the *sequence* axis so the batch axis keeps its
    DP sharding inside the scan — chunking along flattened global tokens
    would turn the scan axis into the sharded axis and replicate the LM-head
    matmul on every data shard (measured: ~1000x collective blow-up)."""
    b, s, d = h.shape
    chunk = min(LOSS_CHUNK, s)
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = h.shape[1] // chunk
    mask = mask.astype(jnp.float32)

    def body(carry, xs):
        hc, lc, mc = xs  # (B, chunk, d), (B, chunk), (B, chunk)
        logits = lm_logits(p_embed, hc).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum((lse - gold) * mc), None

    xs = (h.reshape(b, n, chunk, d).transpose(1, 0, 2, 3),
          labels.reshape(b, n, chunk).transpose(1, 0, 2),
          mask.reshape(b, n, chunk).transpose(1, 0, 2))
    total, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32), xs)
    return total / jnp.maximum(mask.sum(), 1.0)


AUX_WEIGHT = 0.01


def loss_fn(p: Params, batch: dict, cfg, numerics) -> tuple[jax.Array, dict]:
    """batch: tokens (B,S) int32, labels (B,S) int32, mask (B,S) -- plus
    optional frontend_emb / enc_frames for vlm / encdec."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    cross = (encoder_forward(p["encoder"], batch["enc_frames"], cfg, numerics)
             if cfg.encoder is not None else None)
    h = _embed_inputs(p, tokens, positions, cfg, numerics,
                      batch.get("frontend_emb"))
    h, _, aux = backbone(p, h, positions, cfg, numerics, cross_kv=cross)
    ce = chunked_ce_loss(p["embed"], h, batch["labels"], batch["mask"])
    loss = ce + AUX_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving: cache specs, prefill, decode
# ---------------------------------------------------------------------------

def _kind_cache_spec(kind: LayerKind, cfg, b: int, cache_len: int, dtype):
    if kind.mixer == "ssm":
        return ssm_mod.ssm_state_specs(cfg, b, dtype)
    if kind.mixer == "mla":
        return attn.mla_cache_specs(cfg, b, cache_len, dtype)
    return attn.gqa_cache_specs(cfg, b, cache_len, dtype)


def cache_shapes(cfg, b: int, cache_len: int) -> ShapeTree:
    dt = pdtype(cfg)
    out = {}
    for i, seg in enumerate(layer_plan(cfg)):
        inner = {str(j): _kind_cache_spec(k, cfg, b, cache_len, dt)
                 for j, k in enumerate(seg.pattern)}
        out[f"seg{i}"] = stack_specs(inner, seg.repeat) if seg.repeat > 1 else inner
    return out


def splice_cache(cfg, pool: Params, one: Params, slot: int) -> Params:
    """Write one request's prefilled cache (batch size 1) into ``slot`` of a
    pooled cache along the *batch* axis.

    Scanned segments stack their cache leaves with a leading layer axis
    (``stack_specs``), so the batch axis is 1 there and 0 for unscanned
    segments — a naive tree-wide ``axis=0`` splice would hit the layer axis
    (and silently clamp the slot index to 0 for every slot past the layer
    count, corrupting the whole pool).
    """
    out = {}
    for i, seg in enumerate(layer_plan(cfg)):
        name = f"seg{i}"
        ax = 1 if seg.repeat > 1 else 0
        out[name] = jax.tree.map(
            lambda p, o: jax.lax.dynamic_update_slice_in_dim(
                p, o.astype(p.dtype), slot, axis=ax),
            pool[name], one[name])
    return out


def init_cache(cfg, b: int, cache_len: int) -> Params:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype) if s.dtype != jnp.int32
                        else jnp.full(s.shape, -1, jnp.int32), cache_shapes(cfg, b, cache_len))


def prefill(p: Params, tokens: jax.Array, cfg, numerics, cache_len: int,
            frontend_emb=None, enc_frames=None):
    """Process the prompt; returns (last-position logits, caches, cross)."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    cross = (encoder_forward(p["encoder"], enc_frames, cfg, numerics)
             if cfg.encoder is not None else None)
    h = _embed_inputs(p, tokens, positions, cfg, numerics, frontend_emb)
    h, caches, _ = backbone(p, h, positions, cfg, numerics, mode="prefill",
                            cache_len=cache_len, cross_kv=cross)
    logits = lm_logits(p["embed"], h[:, -1:])
    return logits, caches, cross


def mask_cache_tail(caches: Params, true_lens: jax.Array) -> Params:
    """Mark every cache position row at or past each batch row's true length
    as *empty* (``pos = -1``, the ``init_cache`` sentinel the attention mask
    treats as dead).

    A padded (bucketed) prefill writes the pad suffix's K/V rows with live
    position values — a later decode step would attend to that garbage. The
    K/V rows themselves can stay: with their ``pos`` slot at -1 the mask
    assigns them ``NEG`` scores, and decode overwrites row ``p`` in place
    when the sequence actually reaches position ``p``. Only positional
    (attention) caches carry a ``pos`` leaf; SSM state is not positional and
    cannot be padded-prefilled at all (callers gate on the layer plan).
    """
    lens = jnp.asarray(true_lens, jnp.int32)

    def one(path, leaf):
        field = str(getattr(path[-1], "key", path[-1])).lstrip(".")
        if field != "pos":
            return leaf
        # (B, S) — or (L, B, S) for scan-stacked segments; the (B, S)
        # validity mask broadcasts across the leading layer axis either way
        valid = jnp.arange(leaf.shape[-1], dtype=jnp.int32) < lens[:, None]
        return jnp.where(valid, leaf, jnp.int32(-1))

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    return jax.tree_util.tree_unflatten(
        treedef, [one(path, leaf) for path, leaf in flat])


def prefill_padded(p: Params, tokens: jax.Array, true_lens: jax.Array, cfg,
                   numerics, cache_len: int):
    """Bucketed prefill: ``tokens`` is (B, S_bucket) with each row right-
    padded to the bucket length and ``true_lens`` (B,) giving the real
    prompt lengths. Returns (per-row logits at position ``true_len - 1``
    (B, 1, V), caches with the pad tails masked dead, None).

    Positions run 0..S_bucket-1 exactly as a full-length prefill would:
    causality already guarantees every row below its true length computes
    the same values as an exact-length prefill of that prompt (the pad
    suffix can only influence *later* positions), so the gathered logits
    match the exact path and :func:`mask_cache_tail` is the only repair the
    caches need. Restricted to attention-cache decoder-only configs: SSM
    state is cumulative (a pad token pollutes it for good), sliding-window
    caches wrap ``pos % cache_len``, and encoder/frontend extras carry no
    per-row length — callers fall back to exact-length prefill there.
    """
    if cfg.encoder is not None or cfg.frontend is not None:
        raise ValueError("prefill_padded: encoder/frontend configs must "
                         "use exact-length prefill")
    if cfg.sliding_window is not None:
        raise ValueError("prefill_padded: sliding-window caches wrap; use "
                         "exact-length prefill")
    if any(k.mixer == "ssm" for seg in layer_plan(cfg) for k in seg.pattern):
        raise ValueError("prefill_padded: SSM state is cumulative, a pad "
                         "suffix corrupts it; use exact-length prefill")
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = _embed_inputs(p, tokens, positions, cfg, numerics)
    h, caches, _ = backbone(p, h, positions, cfg, numerics, mode="prefill",
                            cache_len=cache_len)
    idx = (jnp.asarray(true_lens, jnp.int32) - 1)[:, None, None]
    logits = lm_logits(p["embed"], jnp.take_along_axis(h, idx, axis=1))
    return logits, mask_cache_tail(caches, true_lens), None


def extract_cache_row(cfg, pool: Params, i) -> Params:
    """Slice batch row ``i`` out of a pooled cache, keeping the batch dim —
    the inverse of :func:`splice_cache`'s insertion, with the same per-
    segment batch-axis bookkeeping (scan-stacked segments lead with a layer
    axis). ``i`` may be a traced index."""
    out = {}
    for si, seg in enumerate(layer_plan(cfg)):
        name = f"seg{si}"
        ax = 1 if seg.repeat > 1 else 0
        out[name] = jax.tree.map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, i, 1, axis=ax),
            pool[name])
    return out


def decode_step(p: Params, token: jax.Array, pos: jax.Array, caches, cfg,
                numerics, cross=None):
    """token: (B, 1) int32; pos: scalar int32 (uniform across the batch) or
    (B,) per-slot positions (continuous batching: each slot decodes at its own
    next position). Returns (logits, new caches)."""
    b = token.shape[0]
    pos, positions = attn._decode_positions(pos, b)
    h = _embed_inputs(p, token, positions, cfg, numerics)
    h, caches, _ = backbone(p, h, positions, cfg, numerics, mode="decode",
                            caches=caches, cross_kv=cross, pos=pos)
    return lm_logits(p["embed"], h), caches
