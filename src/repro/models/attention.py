"""Attention: GQA (+ sliding window), MLA, cross-attention, and a blockwise
(flash-style) core that keeps 32k-prefill activation footprints bounded.

The numerics backend is threaded through every softmax so the paper's
table-based exponential/reciprocal can replace the XLA transcendentals
(``cfg.numerics = "interp"``).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.launch.sharding import constrain
from repro.models.layers import Params, ShapeTree, apply_rope, pdtype, rope_angles, spec

NEG = -1e30
M_FLOOR = -1e20  # running-max clamp: exp(NEG - M_FLOOR) == 0 without a
                 # second mask-select on the (B,KV,G,Q,S) prob block
                 # (perf iteration B1, EXPERIMENTS.md §Perf)


class KVCache(NamedTuple):
    k: jax.Array  # (B, KV, S, D)  [MLA: (B, S, kv_lora); k holds compressed]
    v: jax.Array  # (B, KV, S, D)  [MLA: (B, S, rope_dim) shared rope key]
    pos: jax.Array  # (B, S) int32 positions held in each slot, -1 = empty


# ---------------------------------------------------------------------------
# blockwise softmax(QK^T)V with running renormalization
# ---------------------------------------------------------------------------

def _mask(q_pos, kv_pos, causal: bool, window: Optional[int]):
    """(B, Tq, Tk) bool validity mask."""
    d = q_pos[:, :, None] - kv_pos[:, None, :]
    ok = kv_pos[:, None, :] >= 0
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    return ok


def attention_core(q: jax.Array, k: jax.Array, v: jax.Array,
                   q_pos: jax.Array, kv_pos: jax.Array, numerics,
                   causal: bool = True, window: Optional[int] = None,
                   q_chunk: int = 1024, kv_chunk: int = 1024,
                   softmax_scale: float | None = None) -> jax.Array:
    """q: (B,Sq,H,D); k,v: (B,Sk,KV,Dk/Dv); *_pos: (B, S*) int32.

    Grouped heads are expressed as (KV, G) so the head contraction matches
    the GQA weight sharding; chunked over both Sq and Sk with flash-style
    renormalization (all exponentials/reciprocals via the numerics backend).
    """
    b, sq, h, d = q.shape
    _, sk, kvh, dk = k.shape
    dv = v.shape[-1]
    g = h // kvh
    fused = getattr(numerics, "fused_attention", None)
    if fused is not None:
        # fused numerics inline the whole datapath (scores, table-backed
        # exp/recip, PV product) into one kernel; None = unsupported layout,
        # fall through to the chunked glue path
        out = fused(q, k, v, q_pos, kv_pos, causal=causal, window=window,
                    scale=softmax_scale)
        if out is not None:
            return out
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)
    q = q.reshape(b, sq, kvh, g, d)

    def _divisor_chunk(n: int, target: int) -> int:
        c = min(target, n)
        while n % c:
            c -= 1
        return c

    q_chunk = _divisor_chunk(sq, q_chunk)
    kv_chunk = _divisor_chunk(sk, kv_chunk)
    nq, nk = sq // q_chunk, sk // kv_chunk

    if nq == 1 and nk == 1:
        s = jnp.einsum("bqkgd,bskd->bkgqs", q, k, preferred_element_type=jnp.float32) * scale
        m = _mask(q_pos, kv_pos, causal, window)[:, None, None]
        s = jnp.where(m, s, NEG)
        mx = jax.lax.stop_gradient(
            jnp.maximum(jnp.max(s, -1, keepdims=True), M_FLOOR))
        p = numerics.exp_neg(s - mx)  # masked entries: exp(NEG - mx) == 0
        l = jnp.sum(p, -1, keepdims=True)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        o = o * numerics.recip_pos(l).transpose(0, 3, 1, 2, 4)
        return o.reshape(b, sq, h, dv).astype(v.dtype)

    kc = k.reshape(b, nk, kv_chunk, kvh, dk).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nk, kv_chunk, kvh, dv).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(b, nk, kv_chunk).transpose(1, 0, 2)

    def q_block(qb, qpb):
        # qb: (B, Tq, KV, G, D); qpb: (B, Tq)
        def compute_chunk(carry, kb, vb, kpb, masked: bool):
            m_i, l_i, acc = carry
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            if masked:  # only boundary chunks pay the mask-select (B2)
                msk = _mask(qpb, kpb, causal, window)[:, None, None]
                s = jnp.where(msk, s, NEG)
            m_new = jnp.maximum(
                jnp.maximum(m_i, jax.lax.stop_gradient(jnp.max(s, -1))),
                M_FLOOR)
            p = numerics.exp_neg(s - m_new[..., None])  # masked -> exp(NEG)=0
            corr = numerics.exp_neg(jnp.minimum(m_i - m_new, 0.0))
            l_new = l_i * corr + jnp.sum(p, -1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return m_new, l_new, acc_new

        # B1/B2 pay off when many chunks are skippable; below this the
        # lax.cond branches just break XLA fusion (measured: ~-15% memory
        # term on 4-chunk train cells, +2.3x on 32-chunk prefill cells)
        use_skip = nk >= 8

        def kv_step(carry, xs):
            kb, vb, kpb = xs
            if not use_skip:
                return compute_chunk(carry, kb, vb, kpb, masked=True), None
            # chunk-level liveness (perf iteration B1): a kv chunk is dead if
            # it is entirely in the causal future of every query, entirely
            # outside the sliding window, or entirely empty cache slots.
            # lax.cond skips the matmuls at runtime (~2x for causal prefill).
            need = jnp.any(kpb >= 0)
            if causal:
                need &= jnp.min(jnp.where(kpb < 0, jnp.iinfo(jnp.int32).max,
                                          kpb)) <= jnp.max(qpb)
            if window is not None:
                need &= jnp.max(kpb) > jnp.min(qpb) - window
            # B2: interior chunks (entirely valid for every query) skip the
            # mask-select chain; only diagonal/window-boundary chunks pay it.
            full = jnp.all(kpb >= 0)
            if causal:
                full &= jnp.max(kpb) <= jnp.min(qpb)
            if window is not None:
                full &= jnp.min(kpb) > jnp.max(qpb) - window

            def live(c):
                return jax.lax.cond(
                    full,
                    lambda cc: compute_chunk(cc, kb, vb, kpb, masked=False),
                    lambda cc: compute_chunk(cc, kb, vb, kpb, masked=True),
                    c)

            carry = jax.lax.cond(need, live, lambda c: c, carry)
            return carry, None

        tq = qb.shape[1]
        init = (jnp.full((b, kvh, g, tq), M_FLOOR, jnp.float32),
                jnp.zeros((b, kvh, g, tq), jnp.float32),
                jnp.zeros((b, kvh, g, tq, dv), jnp.float32))
        (m_i, l_i, acc), _ = jax.lax.scan(kv_step, init, (kc, vc, pc))
        o = acc * numerics.recip_pos(jnp.maximum(l_i, 1e-30))[..., None]
        return o.transpose(0, 3, 1, 2, 4).reshape(b, tq, h, dv).astype(v.dtype)

    qs = q.reshape(b, nq, q_chunk, kvh, g, d).transpose(1, 0, 2, 3, 4, 5)
    qps = q_pos.reshape(b, nq, q_chunk).transpose(1, 0, 2)
    out = jax.lax.map(lambda xs: q_block(*xs), (qs, qps))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dv)


# ---------------------------------------------------------------------------
# GQA (+ QKV bias, + sliding window)
# ---------------------------------------------------------------------------

def gqa_shapes(cfg) -> ShapeTree:
    d, hd, dt = cfg.d_model, cfg.head_size, pdtype(cfg)
    out = {
        "wq": spec((d, cfg.n_heads * hd), dt),
        "wk": spec((d, cfg.n_kv_heads * hd), dt),
        "wv": spec((d, cfg.n_kv_heads * hd), dt),
        "wo": spec((cfg.n_heads * hd, d), dt),
    }
    if cfg.attn_bias:
        out.update({
            "bq": spec((cfg.n_heads * hd,), dt),
            "bk": spec((cfg.n_kv_heads * hd,), dt),
            "bv": spec((cfg.n_kv_heads * hd,), dt),
        })
    return out


def _gqa_qkv(p: Params, x: jax.Array, positions: jax.Array, cfg):
    b, s, _ = x.shape
    hd = cfg.head_size
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.attn_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    if not cfg.learned_pos:
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = constrain(q, ("batch", "seq2", "heads", None))
    k = constrain(k, ("batch", "seq2", "kv_heads", None))
    v = constrain(v, ("batch", "seq2", "kv_heads", None))
    return q, k, v


def gqa_train(p: Params, x: jax.Array, positions: jax.Array, cfg, numerics,
              causal: bool = True) -> jax.Array:
    b, s, _ = x.shape
    q, k, v = _gqa_qkv(p, x, positions, cfg)
    o = attention_core(q, k, v, positions, positions, numerics,
                       causal=causal, window=cfg.sliding_window)
    o = constrain(o, ("batch", "seq2", "heads", None))
    # C3: sequence-parallel output — constraining the row-parallel matmul
    # result to the seq shard turns its partial-sum all-reduce into a
    # reduce-scatter (Megatron-SP), 16x less traffic and no full-seq f32
    # buffer in the scan body.
    return constrain(o.reshape(b, s, -1) @ p["wo"], ("batch", "seq", None))


def gqa_prefill(p: Params, x, positions, cfg, numerics, cache_len: int):
    """Training-shaped pass that also emits a right-padded KV cache."""
    b, s, _ = x.shape
    q, k, v = _gqa_qkv(p, x, positions, cfg)
    o = attention_core(q, k, v, positions, positions, numerics,
                       causal=True, window=cfg.sliding_window)
    y = o.reshape(b, s, -1) @ p["wo"]
    s_eff = (min(cache_len, cfg.sliding_window)
             if cfg.sliding_window is not None else cache_len)
    kc = jnp.zeros((b, cfg.n_kv_heads, s_eff, cfg.head_size), k.dtype)
    vc = jnp.zeros_like(kc)
    pos_buf = jnp.full((b, s_eff), -1, jnp.int32)
    if cfg.sliding_window is not None and s > s_eff:
        # windowed caches keep the last s_eff tokens; prompts overflowing a
        # non-windowed cache stay a hard (shape) error, never a silent clip
        k, v = k[:, -s_eff:], v[:, -s_eff:]
        positions = positions[:, -s_eff:]
    kc = jax.lax.dynamic_update_slice(kc, k.transpose(0, 2, 1, 3), (0, 0, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v.transpose(0, 2, 1, 3), (0, 0, 0, 0))
    pos_buf = jax.lax.dynamic_update_slice(pos_buf, positions.astype(jnp.int32), (0, 0))
    if cfg.sliding_window is not None and s > s_eff:
        # decode slots windowed rows at position % s_eff; rotate the
        # compacted tail so row r holds the position with p % s_eff == r —
        # otherwise the first wrap-around decode overwrites live in-window
        # KV instead of the expired row
        shift = s % s_eff
        kc = jnp.roll(kc, shift, axis=2)
        vc = jnp.roll(vc, shift, axis=2)
        pos_buf = jnp.roll(pos_buf, shift, axis=1)
    return y, KVCache(kc, vc, pos_buf)


def _decode_positions(pos: jax.Array, b: int) -> tuple[jax.Array, jax.Array]:
    """Normalize a decode position argument: scalar (uniform batch) or (B,)
    per-slot vector. Returns (pos, positions (B, 1))."""
    pos = jnp.asarray(pos, jnp.int32)
    positions = (jnp.broadcast_to(pos[None, None], (b, 1)) if pos.ndim == 0
                 else pos.reshape(b, 1)).astype(jnp.int32)
    return pos, positions


def gqa_decode(p: Params, x: jax.Array, pos: jax.Array, cache: KVCache, cfg,
               numerics) -> tuple[jax.Array, KVCache]:
    """x: (B, 1, d); pos: scalar int32 (uniform across batch) or (B,)
    per-slot positions (mixed-length continuous batching)."""
    b = x.shape[0]
    pos, positions = _decode_positions(pos, b)
    q, k, v = _gqa_qkv(p, x, positions, cfg)
    s_max = cache.k.shape[2]
    slot = (pos % s_max).astype(jnp.int32) if cfg.sliding_window else pos
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if pos.ndim == 0:
        kc = jax.lax.dynamic_update_slice(cache.k, kt, (0, 0, slot, 0))
        vc = jax.lax.dynamic_update_slice(cache.v, vt, (0, 0, slot, 0))
        pc = jax.lax.dynamic_update_slice(cache.pos, positions, (0, slot))
    else:
        # per-slot write positions: one dynamic_update per batch row (vmap
        # lowers these to a batched scatter)
        upd = jax.vmap(lambda buf, new, s:
                       jax.lax.dynamic_update_slice(buf, new, (0, s, 0)))
        kc = upd(cache.k, kt, slot)
        vc = upd(cache.v, vt, slot)
        pc = jax.vmap(lambda buf, new, s:
                      jax.lax.dynamic_update_slice(buf, new, (s,)))(
            cache.pos, positions, slot)
    kv_pos = pc
    o = attention_core(q, kc.transpose(0, 2, 1, 3), vc.transpose(0, 2, 1, 3),
                       positions, kv_pos, numerics, causal=True,
                       window=cfg.sliding_window,
                       kv_chunk=min(4096, s_max))
    y = o.reshape(b, 1, -1) @ p["wo"]
    return y, KVCache(kc, vc, pc)


def gqa_cache_specs(cfg, b: int, s: int, dtype) -> KVCache:
    s_eff = min(s, cfg.sliding_window) if cfg.sliding_window else s
    return KVCache(
        k=spec((b, cfg.n_kv_heads, s_eff, cfg.head_size), dtype),
        v=spec((b, cfg.n_kv_heads, s_eff, cfg.head_size), dtype),
        pos=spec((b, s_eff), jnp.int32),
    )


# ---------------------------------------------------------------------------
# MLA (DeepSeek/MiniCPM3 multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_shapes(cfg) -> ShapeTree:
    m, d, dt = cfg.mla, cfg.d_model, pdtype(cfg)
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": spec((d, m.q_lora_rank), dt),
        "q_norm": {"scale": spec((m.q_lora_rank,), dt)},
        "wq_b": spec((m.q_lora_rank, cfg.n_heads * qk), dt),
        "wkv_a": spec((d, m.kv_lora_rank + m.qk_rope_head_dim), dt),
        "kv_norm": {"scale": spec((m.kv_lora_rank,), dt)},
        "wkv_b": spec((m.kv_lora_rank, cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim)), dt),
        "wo": spec((cfg.n_heads * m.v_head_dim, d), dt),
    }


def _mla_q(p, x, positions, cfg, numerics):
    m = cfg.mla
    b, s, _ = x.shape
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ql = numerics.rmsnorm(x @ p["wq_a"], p["q_norm"]["scale"].astype(jnp.float32)).astype(x.dtype)
    q = (ql @ p["wq_b"]).reshape(b, s, cfg.n_heads, qk)
    qn, qr = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    cos, sin = rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
    qr = apply_rope(qr, cos, sin)
    return jnp.concatenate([qn, qr], -1)


def _mla_kv_latent(p, x, positions, cfg, numerics):
    m = cfg.mla
    kv = x @ p["wkv_a"]
    ckv, kr = kv[..., : m.kv_lora_rank], kv[..., m.kv_lora_rank:]
    ckv = numerics.rmsnorm(ckv, p["kv_norm"]["scale"].astype(jnp.float32)).astype(x.dtype)
    cos, sin = rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
    kr = apply_rope(kr[:, :, None, :], cos, sin)[:, :, 0, :]
    return ckv, kr  # (B,S,kv_lora), (B,S,rope)


def _mla_expand(p, ckv, kr, cfg):
    """Latents -> per-head K (nope+rope) and V."""
    m = cfg.mla
    b, s, _ = ckv.shape
    kvb = (ckv @ p["wkv_b"]).reshape(b, s, cfg.n_heads, m.qk_nope_head_dim + m.v_head_dim)
    kn, v = kvb[..., : m.qk_nope_head_dim], kvb[..., m.qk_nope_head_dim:]
    kr_b = jnp.broadcast_to(kr[:, :, None, :], (b, s, cfg.n_heads, m.qk_rope_head_dim))
    k = jnp.concatenate([kn, kr_b], -1)
    return k, v


def mla_train(p: Params, x, positions, cfg, numerics, causal: bool = True):
    b, s, _ = x.shape
    q = _mla_q(p, x, positions, cfg, numerics)
    ckv, kr = _mla_kv_latent(p, x, positions, cfg, numerics)
    k, v = _mla_expand(p, ckv, kr, cfg)
    q = constrain(q, ("batch", "seq2", "heads", None))
    k = constrain(k, ("batch", "seq2", "heads", None))
    o = attention_core(q, k, v, positions, positions, numerics, causal=causal)
    return constrain(o.reshape(b, s, -1) @ p["wo"], ("batch", "seq", None))  # C3


def mla_prefill(p, x, positions, cfg, numerics, cache_len: int):
    m = cfg.mla
    b, s, _ = x.shape
    y = mla_train(p, x, positions, cfg, numerics)
    ckv, kr = _mla_kv_latent(p, x, positions, cfg, numerics)
    ck_buf = jnp.zeros((b, cache_len, m.kv_lora_rank), ckv.dtype)
    kr_buf = jnp.zeros((b, cache_len, m.qk_rope_head_dim), kr.dtype)
    pos_buf = jnp.full((b, cache_len), -1, jnp.int32)
    ck_buf = jax.lax.dynamic_update_slice(ck_buf, ckv, (0, 0, 0))
    kr_buf = jax.lax.dynamic_update_slice(kr_buf, kr, (0, 0, 0))
    pos_buf = jax.lax.dynamic_update_slice(pos_buf, positions.astype(jnp.int32), (0, 0))
    return y, KVCache(ck_buf, kr_buf, pos_buf)


def mla_decode(p, x, pos, cache: KVCache, cfg, numerics):
    """pos: scalar int32 or (B,) per-slot positions (continuous batching)."""
    b = x.shape[0]
    pos, positions = _decode_positions(pos, b)
    q = _mla_q(p, x, positions, cfg, numerics)
    ckv, kr = _mla_kv_latent(p, x, positions, cfg, numerics)
    if pos.ndim == 0:
        ck = jax.lax.dynamic_update_slice(cache.k, ckv, (0, pos, 0))
        krb = jax.lax.dynamic_update_slice(cache.v, kr, (0, pos, 0))
        pc = jax.lax.dynamic_update_slice(cache.pos, positions, (0, pos))
    else:
        upd = jax.vmap(lambda buf, new, s:
                       jax.lax.dynamic_update_slice(buf, new, (s, 0)))
        ck = upd(cache.k, ckv, pos)
        krb = upd(cache.v, kr, pos)
        pc = jax.vmap(lambda buf, new, s:
                      jax.lax.dynamic_update_slice(buf, new, (s,)))(
            cache.pos, positions, pos)
    k, v = _mla_expand(p, ck, krb, cfg)  # chunked expansion would go here
    o = attention_core(q, k, v, positions, pc, numerics, causal=True,
                       kv_chunk=min(4096, k.shape[1]))
    y = o.reshape(b, 1, -1) @ p["wo"]
    return y, KVCache(ck, krb, pc)


def mla_cache_specs(cfg, b: int, s: int, dtype) -> KVCache:
    m = cfg.mla
    return KVCache(
        k=spec((b, s, m.kv_lora_rank), dtype),
        v=spec((b, s, m.qk_rope_head_dim), dtype),
        pos=spec((b, s), jnp.int32),
    )


# ---------------------------------------------------------------------------
# cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_shapes(cfg) -> ShapeTree:
    d, hd, dt = cfg.d_model, cfg.head_size, pdtype(cfg)
    return {
        "wq": spec((d, cfg.n_heads * hd), dt),
        "wk": spec((d, cfg.n_kv_heads * hd), dt),
        "wv": spec((d, cfg.n_kv_heads * hd), dt),
        "wo": spec((cfg.n_heads * hd, d), dt),
    }


def cross_kv(p: Params, enc: jax.Array, cfg):
    b, s, _ = enc.shape
    hd = cfg.head_size
    k = (enc @ p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (enc @ p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    return k, v


def cross_apply(p: Params, x: jax.Array, kv: tuple[jax.Array, jax.Array], cfg,
                numerics) -> jax.Array:
    b, s, _ = x.shape
    hd = cfg.head_size
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k, v = kv
    sk = k.shape[1]
    qp = jnp.zeros((b, s), jnp.int32)
    kp = jnp.zeros((b, sk), jnp.int32)
    o = attention_core(q, k, v, qp, kp, numerics, causal=False)
    return o.reshape(b, s, -1) @ p["wo"]
