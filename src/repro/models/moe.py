"""Mixture-of-experts: token-choice top-k routing with capacity-bounded
per-example dispatch and intra-expert tensor parallelism.

Sharding design (perf iteration A1, EXPERIMENTS.md §Perf — the original
global-capacity formulation replicated an (E, C_global, d) dispatch buffer on
every chip because E=8/64/16 never divides the 16-way model axis; measured
2.9e13 collective bytes/chip/step on mixtral train_4k):

  * dispatch runs *per example*: position-in-expert cumsum over one example's
    S*k assignments only — no cross-device sequential dependency, batch axis
    keeps its DP sharding, capacity is the standard GShard group capacity
    with group = one example.
  * expert weights are sharded on the *d_expert* axis over the model axis
    (Megatron column/row inside every expert) and on d_model over the FSDP
    axis; every chip holds a 1/(16*16) shard of every expert. The only
    collective in the MoE block is the row-parallel all-reduce of the
    combined token outputs — (B_local, S, d) once per layer, exactly what a
    dense Megatron MLP pays.

Router softmax goes through the numerics backend: the paper's table-based
softmax certifies the routing probabilities too (``MoEConfig.router_numerics``).
``moe_block`` returns (y, router_probs) so the load-balance aux loss reuses
the routing pass instead of recomputing it (the old separate aux function
doubled router flops and collectives).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.sharding import constrain
from repro.models.layers import Params, ShapeTree, pdtype, spec


def moe_shapes(cfg) -> ShapeTree:
    m, d, dt = cfg.moe, cfg.d_model, pdtype(cfg)
    out: ShapeTree = {
        "router": spec((d, m.n_experts), jnp.float32),
        "wi": spec((m.n_experts, d, 2 * m.d_expert), dt),  # SwiGLU gate+up
        "wo": spec((m.n_experts, m.d_expert, d), dt),
    }
    if m.n_shared:
        out["shared_wi"] = spec((d, 2 * m.n_shared * m.d_expert), dt)
        out["shared_wo"] = spec((m.n_shared * m.d_expert, d), dt)
    return out


def _capacity(seq: int, cfg) -> int:
    m = cfg.moe
    c = int(seq * m.top_k * m.capacity_factor / m.n_experts)
    return max(min(c, seq * m.top_k), 4)


def moe_block(p: Params, x: jax.Array, cfg, numerics,
              return_probs: bool = False):
    """x: (B, S, d) -> (B, S, d). Dropped tokens (over per-example capacity)
    fall through on the residual path, standard GShard behaviour."""
    m = cfg.moe
    b, s, d = x.shape
    cap = _capacity(s, cfg)
    k = m.top_k

    logits = x.astype(jnp.float32) @ p["router"]  # (B, S, E)
    probs = (numerics.softmax(logits, axis=-1) if m.router_numerics
             else jax.nn.softmax(logits, axis=-1))
    gate, idx = jax.lax.top_k(probs, k)  # (B, S, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # --- per-example dispatch plan (no cross-device dependencies) ----------
    flat_e = idx.reshape(b, s * k)  # (B, SK) expert ids, token-major
    onehot = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.int32)  # (B, SK, E)
    pos = jnp.cumsum(onehot, axis=1) - onehot
    pos_in_e = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]
    keep = pos_in_e < cap
    slot = jnp.where(keep, pos_in_e, cap)  # overflow -> scratch row

    # --- dispatch: (B, E, C+1, d), batch keeps its DP sharding -------------
    xk = jnp.repeat(x, k, axis=1)  # (B, SK, d) token-major copies
    buf = jnp.zeros((b, m.n_experts, cap + 1, d), x.dtype)
    bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
    buf = buf.at[bidx, flat_e, slot].add(xk, mode="drop")
    buf = constrain(buf, ("batch", None, None, None))

    # --- expert FFN, d_expert sharded on the model axis (Megatron col/row) -
    # (A2 — explicitly pre-gathering the weights' FSDP axis here — was tried
    # and REFUTED: +14% collective, +27% memory vs letting GSPMD place the
    # d-contraction partial sums. See EXPERIMENTS.md §Perf.)
    h = jnp.einsum("becd,edf->becf", buf, p["wi"],
                   preferred_element_type=jnp.float32)
    gate_h, up = jnp.split(h, 2, axis=-1)
    h = (numerics.silu(gate_h) * up).astype(x.dtype)
    h = constrain(h, ("batch", None, None, "mlp"))
    out_buf = jnp.einsum("becf,efd->becd", h, p["wo"],
                         preferred_element_type=jnp.float32)

    # --- combine (gather is linear, so it commutes with the row-parallel
    # partial sum; the single all-reduce lands on y below) -------------------
    tok_out = out_buf[bidx, flat_e, slot]  # (B, SK, d)
    tok_out = tok_out * (keep * gate.reshape(b, s * k))[..., None]
    y = tok_out.reshape(b, s, k, d).sum(axis=2).astype(x.dtype)

    if m.n_shared:
        hs = x @ p["shared_wi"]
        gs, us = jnp.split(hs, 2, axis=-1)
        y = y + ((numerics.silu(gs) * us) @ p["shared_wo"]).astype(x.dtype)
    y = constrain(y, ("batch", "seq", None))
    if return_probs:
        return y, probs
    return y


def load_balance_loss_from_probs(probs: jax.Array, cfg) -> jax.Array:
    """Switch-style load-balance aux from the routing pass's probs (B, S, E)."""
    m = cfg.moe
    pe = probs.reshape(-1, m.n_experts)
    me = pe.mean(0)
    _, idx = jax.lax.top_k(pe, m.top_k)
    ce = jnp.mean(jax.nn.one_hot(idx, m.n_experts).sum(1), 0)
    return m.n_experts * jnp.sum(me * ce)
