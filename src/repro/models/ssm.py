"""Mamba2 (state-space duality) mixer: chunked SSD for train/prefill and a
single-step state update for decode.

All exponentials (the decay factors exp(dt*A) with dt >= 0, A < 0) and the
dt softplus run through the numerics backend, so the paper's tables certify
the SSM recurrence too (DESIGN.md §6). Chunked SSD follows arXiv:2405.21060
§6: quadratic attention-like compute inside chunks (matmul-friendly) plus a
linear recurrence over chunk states.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.launch.sharding import constrain
from repro.models.layers import Params, ShapeTree, pdtype, spec


class SSMState(NamedTuple):
    conv: jax.Array  # (B, d_conv-1, conv_dim) shift register
    ssm: jax.Array  # (B, H, P, N) recurrent state


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_dim


def ssm_shapes(cfg) -> ShapeTree:
    s, dt = cfg.ssm, pdtype(cfg)
    d_inner, n_heads, conv_dim = _dims(cfg)
    return {
        "in_proj": spec((cfg.d_model, 2 * d_inner + 2 * s.n_groups * s.d_state + n_heads), dt),
        "conv_w": spec((s.d_conv, conv_dim), dt),
        "conv_b": spec((conv_dim,), dt),
        "a_log": spec((n_heads,), jnp.float32),
        "dt_bias": spec((n_heads,), jnp.float32),
        "d_skip": spec((n_heads,), jnp.float32),
        "norm": {"scale": spec((d_inner,), dt)},
        "out_proj": spec((d_inner, cfg.d_model), dt),
    }


def _split_proj(p: Params, x: jax.Array, cfg):
    s = cfg.ssm
    d_inner, n_heads, conv_dim = _dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : d_inner + conv_dim]
    dt = zxbcdt[..., d_inner + conv_dim :]
    return z, xbc, dt


def _conv_scan(p: Params, xbc: jax.Array, cfg, numerics) -> jax.Array:
    """Causal depthwise conv over sequence (train/prefill path)."""
    s = cfg.ssm
    pad = jnp.pad(xbc, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * p["conv_w"][i]
        for i in range(s.d_conv)
    )
    return numerics.silu(out + p["conv_b"])


def _gated_norm(p: Params, y: jax.Array, z: jax.Array, numerics) -> jax.Array:
    g = y * numerics.silu(z)
    return numerics.rmsnorm(g, p["norm"]["scale"].astype(jnp.float32)).astype(y.dtype)


def ssd_chunked(x, dt, a, b_mat, c_mat, d_skip, cfg, numerics,
                h0: jax.Array | None = None):
    """Chunked SSD.

    x: (B,S,H,P); dt: (B,S,H); a: (H,) < 0; b_mat/c_mat: (B,S,G,N).
    Returns (y: (B,S,H,P), h_final: (B,H,P,N)).
    """
    s_cfg = cfg.ssm
    bsz, seq, h, p_dim = x.shape
    g = s_cfg.n_groups
    hg = h // g
    q = min(s_cfg.chunk, seq)
    assert seq % q == 0, (seq, q)
    nc = seq // q

    xr = x.reshape(bsz, nc, q, g, hg, p_dim)
    dtr = dt.reshape(bsz, nc, q, h)
    br = b_mat.reshape(bsz, nc, q, g, s_cfg.d_state)
    cr = c_mat.reshape(bsz, nc, q, g, s_cfg.d_state)
    dta = dtr * a  # (B,nc,Q,H) <= 0
    cum = jnp.cumsum(dta, axis=2)  # within-chunk cumulative decay

    # intra-chunk (quadratic in Q, matmul-friendly)
    cb = jnp.einsum("bcqgn,bcsgn->bcgqs", cr, br, preferred_element_type=jnp.float32)
    seg = cum[..., :, None, :] - cum[..., None, :, :]  # (B,nc,Q,S,H): cum_i - cum_j <= 0 for i>=j
    tri = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(tri[None, None, :, :, None], numerics.exp_neg(jnp.minimum(seg, 0.0)), 0.0)
    dgr = decay.reshape(bsz, nc, q, q, g, hg)  # (B,nc,Q,S,G,HG)
    # mat[b,c,g,q,s,m] = (C_q.B_s) * exp(cum_q-cum_s) * dt_s
    mat = (cb[:, :, :, :, :, None] * dgr.transpose(0, 1, 4, 2, 3, 5)
           * dtr.reshape(bsz, nc, q, g, hg).transpose(0, 1, 3, 2, 4)[:, :, :, None, :, :])
    y_intra = jnp.einsum("bcgqsm,bcsgmp->bcqgmp", mat, xr,
                         preferred_element_type=jnp.float32)

    # chunk states: S_c = sum_j exp(cum_last - cum_j) dt_j B_j (x) x_j
    to_end = numerics.exp_neg(cum[:, :, -1:, :] - cum)  # arg <= 0
    wts = (to_end * dtr).reshape(bsz, nc, q, g, hg)
    states = jnp.einsum("bcqgm,bcqgn,bcqgmp->bcgmpn", wts, br, xr,
                        preferred_element_type=jnp.float32)

    # inter-chunk linear recurrence over chunk states
    chunk_decay = numerics.exp_neg(jnp.sum(dta, axis=2))  # exp(sum dta), arg <= 0
    cd = chunk_decay.reshape(bsz, nc, g, hg)

    def step(h_prev, xs):
        st, dec = xs  # (B,G,HG,P,N), (B,G,HG)
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    init = (jnp.zeros((bsz, g, hg, p_dim, s_cfg.d_state), jnp.float32)
            if h0 is None else h0.reshape(bsz, g, hg, p_dim, s_cfg.d_state))
    h_last, h_prevs = jax.lax.scan(step, init,
                                   (states.transpose(1, 0, 2, 3, 4, 5), cd.transpose(1, 0, 2, 3)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4, 5)  # (B,nc,G,HG,P,N)

    # inter-chunk contribution: C_i . (exp(cum_i) * h_prev)
    from_start = numerics.exp_neg(cum).reshape(bsz, nc, q, g, hg)  # exp(cum_i), cum <= 0
    y_inter = jnp.einsum("bcqgn,bcgmpn,bcqgm->bcqgmp", cr, h_prevs, from_start,
                         preferred_element_type=jnp.float32)

    y = (y_intra + y_inter).reshape(bsz, seq, h, p_dim)
    y = y + x * d_skip[None, None, :, None]
    return y.astype(x.dtype), h_last.reshape(bsz, h, p_dim, s_cfg.d_state)


def ssm_train(p: Params, x: jax.Array, cfg, numerics) -> jax.Array:
    y, _ = _ssm_forward(p, x, cfg, numerics)
    return y


def _ssm_forward(p: Params, x: jax.Array, cfg, numerics,
                 h0: jax.Array | None = None):
    s = cfg.ssm
    d_inner, n_heads, conv_dim = _dims(cfg)
    z, xbc, dt = _split_proj(p, x, cfg)
    xbc = _conv_scan(p, xbc, cfg, numerics)
    x_ssm = xbc[..., :d_inner]
    b_mat = xbc[..., d_inner : d_inner + s.n_groups * s.d_state]
    c_mat = xbc[..., d_inner + s.n_groups * s.d_state :]
    bsz, seq, _ = x.shape
    x_ssm = constrain(x_ssm.reshape(bsz, seq, n_heads, s.head_dim),
                      ("batch", None, "heads", None))
    b_mat = b_mat.reshape(bsz, seq, s.n_groups, s.d_state)
    c_mat = c_mat.reshape(bsz, seq, s.n_groups, s.d_state)
    dt_f = numerics.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    y, h_last = ssd_chunked(x_ssm, dt_f, a, b_mat, c_mat, p["d_skip"], cfg, numerics, h0)
    y = _gated_norm(p, y.reshape(bsz, seq, d_inner), z, numerics)
    return y @ p["out_proj"], h_last


def ssm_prefill(p: Params, x: jax.Array, cfg, numerics):
    s = cfg.ssm
    d_inner, _, conv_dim = _dims(cfg)
    y, h_last = _ssm_forward(p, x, cfg, numerics)
    _, xbc, _ = _split_proj(p, x, cfg)
    tail = xbc[:, -(s.d_conv - 1):, :]
    pad = s.d_conv - 1 - tail.shape[1]
    if pad > 0:
        tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
    return y, SSMState(conv=tail, ssm=h_last)


def ssm_decode(p: Params, x: jax.Array, state: SSMState, cfg, numerics):
    """x: (B, 1, d)."""
    s = cfg.ssm
    d_inner, n_heads, conv_dim = _dims(cfg)
    bsz = x.shape[0]
    z, xbc, dt = _split_proj(p, x, cfg)  # (B,1,*)
    window = jnp.concatenate([state.conv, xbc], axis=1)  # (B, d_conv, conv_dim)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc1 = numerics.silu(conv_out)[:, None, :]
    x_ssm = xbc1[..., :d_inner].reshape(bsz, n_heads, s.head_dim)
    b_mat = xbc1[..., d_inner : d_inner + s.n_groups * s.d_state].reshape(bsz, s.n_groups, s.d_state)
    c_mat = xbc1[..., d_inner + s.n_groups * s.d_state :].reshape(bsz, s.n_groups, s.d_state)
    dt_f = numerics.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    decay = numerics.exp_neg(dt_f * a)  # exp(dt*A), arg <= 0 since A < 0
    hg = n_heads // s.n_groups
    xg = x_ssm.reshape(bsz, s.n_groups, hg, s.head_dim)
    dtg = dt_f.reshape(bsz, s.n_groups, hg)
    upd = jnp.einsum("bgm,bgn,bgmp->bgmpn", dtg, b_mat, xg,
                     preferred_element_type=jnp.float32)
    h = state.ssm.reshape(bsz, s.n_groups, hg, s.head_dim, s.d_state)
    h_new = h * decay.reshape(bsz, s.n_groups, hg)[..., None, None] + upd
    y = jnp.einsum("bgn,bgmpn->bgmp", c_mat, h_new,
                   preferred_element_type=jnp.float32)
    y = y.reshape(bsz, n_heads, s.head_dim) + x_ssm * p["d_skip"][None, :, None]
    y = _gated_norm(p, y.reshape(bsz, 1, d_inner).astype(x.dtype), z, numerics)
    new_state = SSMState(conv=window[:, 1:, :], ssm=h_new.reshape(bsz, n_heads, s.head_dim, s.d_state))
    return y @ p["out_proj"], new_state


def ssm_state_specs(cfg, b: int, dtype) -> SSMState:
    s = cfg.ssm
    d_inner, n_heads, conv_dim = _dims(cfg)
    return SSMState(
        conv=spec((b, s.d_conv - 1, conv_dim), dtype),
        ssm=spec((b, n_heads, s.head_dim, s.d_state), jnp.float32),
    )
