"""Shared model layers: norms, RoPE, MLPs, embeddings, parameter utilities.

Parameters are plain nested dicts of jnp arrays. Every layer exposes
``*_shapes(cfg) -> dict[name, jax.ShapeDtypeStruct]`` so the dry-run can
build abstract parameter trees without allocating, and ``init_tree`` turns
the same specs into real arrays for the smoke tests / examples.
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Params = dict
ShapeTree = dict

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


def pdtype(cfg) -> jnp.dtype:
    return _DTYPES[cfg.param_dtype]


def spec(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def stack_specs(tree: ShapeTree, n: int) -> ShapeTree:
    """Prepend a layer dimension to every leaf (scanned layer stacks)."""
    return jax.tree.map(lambda s: spec((n, *s.shape), s.dtype), tree)


def init_tree(key: jax.Array, shapes: ShapeTree, scale_rules: Callable[[str, Any], float] | None = None) -> Params:
    """Materialize a shape tree: truncated-normal fan-in init, zeros for
    biases/norm offsets, ones for norm scales."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    keys = jax.random.split(key, len(flat))

    def one(path, s, k):
        name = "/".join(str(p.key) if hasattr(p, "key") else str(p) for p in path)
        if name.endswith(("bias", "b", "a_log", "dt_bias", "d_skip")):
            if name.endswith("a_log"):
                row = jnp.log(jnp.arange(1, s.shape[-1] + 1, dtype=jnp.float32))
                return jnp.broadcast_to(row, s.shape).astype(s.dtype)
            if name.endswith("d_skip"):
                return jnp.ones(s.shape, s.dtype)
            return jnp.zeros(s.shape, s.dtype)
        if name.endswith(("scale", "gamma")):
            return jnp.ones(s.shape, s.dtype)
        fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
        std = 1.0 / math.sqrt(max(fan_in, 1))
        if scale_rules is not None:
            std *= scale_rules(name, s)
        return (jax.random.truncated_normal(k, -2.0, 2.0, s.shape, jnp.float32) * std).astype(s.dtype)

    leaves = [one(p, s, k) for (p, s), k in zip(flat, keys)]
    return jax.tree.unflatten(treedef, leaves)


def count_params(shapes: ShapeTree) -> int:
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))


# ----------------------------------------------------------------- norms

def norm_shapes(cfg, d=None) -> ShapeTree:
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": spec((d,), pdtype(cfg)), "bias": spec((d,), pdtype(cfg))}
    return {"scale": spec((d,), pdtype(cfg))}


def apply_norm(p: Params, x: jax.Array, cfg, numerics) -> jax.Array:
    if cfg.norm == "layernorm":
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        return (y * p["scale"] + p["bias"]).astype(x.dtype)
    return numerics.rmsnorm(x, p["scale"].astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------------ RoPE

def rope_angles(positions: jax.Array, dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions: (...,) int32 -> cos/sin of shape (..., dim//2), fp32."""
    freqs = jnp.exp(-jnp.arange(0, dim, 2, dtype=jnp.float32) / dim * math.log(theta))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, D); cos/sin: (..., S, D/2) broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1).astype(x.dtype)


# ------------------------------------------------------------------- MLP

def mlp_shapes(cfg, d_ff=None) -> ShapeTree:
    d, dt = cfg.d_model, pdtype(cfg)
    f = d_ff or cfg.d_ff
    if cfg.act == "silu":  # SwiGLU: gate + up + down
        return {"wi": spec((d, 2 * f), dt), "wo": spec((f, d), dt)}
    return {"wi": spec((d, f), dt), "wo": spec((f, d), dt)}


def apply_mlp(p: Params, x: jax.Array, cfg, numerics) -> jax.Array:
    h = x @ p["wi"]
    if cfg.act == "silu":
        gate, up = jnp.split(h, 2, axis=-1)
        h = numerics.silu(gate) * up
    elif cfg.act == "gelu":
        h = numerics.gelu(h)
    elif cfg.act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(cfg.act)
    from repro.launch.sharding import constrain  # C3: reduce-scatter output
    return constrain(h @ p["wo"], ("batch", "seq", None))


# ------------------------------------------------------------- embeddings

def embed_shapes(cfg) -> ShapeTree:
    dt = pdtype(cfg)
    out: ShapeTree = {"tok": spec((cfg.vocab_size, cfg.d_model), dt)}
    if not cfg.tie_embeddings:
        out["head"] = spec((cfg.d_model, cfg.vocab_size), dt)
    return out


def embed_tokens(p: Params, tokens: jax.Array) -> jax.Array:
    return p["tok"][tokens]


def lm_logits(p: Params, h: jax.Array) -> jax.Array:
    w = p["head"] if "head" in p else p["tok"].T
    return h @ w
