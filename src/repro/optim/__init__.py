from repro.optim.adamw import AdamWState, adamw_init, adamw_update  # noqa: F401
from repro.optim.compress import (compress_grads, compress_state_shapes,  # noqa: F401
                                  decompress_grads)
from repro.optim.schedule import cosine_schedule  # noqa: F401
