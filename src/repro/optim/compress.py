"""int8 error-feedback gradient compression for cross-pod all-reduce.

Per-tensor symmetric int8 quantization with an error-feedback residual: the
quantization error of step t is added back to the gradient at step t+1, so
the compression bias telescopes away (Seide et al. 1-bit SGD lineage). Used
on the *pod* axis only — intra-pod ICI reduces full-precision grads, and the
slow DCN hop between pods carries 4x fewer bytes.

The all-reduce itself stays a standard jnp.sum under GSPMD; compression is a
(quantize -> dequantize) pair around the pod-axis reduction, which XLA fuses
around the collective. Residuals are part of the train state (checkpointed).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_state_shapes(param_shapes: dict) -> dict:
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                        param_shapes)


def compress_init(params: dict) -> dict:
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)


def compress_grads(grads: dict, residual: dict):
    """Returns (int8 payload, fp32 scales, new residual)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_r = gf - q.astype(jnp.float32) * scale
        return q, scale, new_r

    flat, tdef = jax.tree.flatten(grads)
    rflat = jax.tree.leaves(residual)
    qs, scales, rs = zip(*[one(g, r) for g, r in zip(flat, rflat)])
    return (jax.tree.unflatten(tdef, qs), jax.tree.unflatten(tdef, scales),
            jax.tree.unflatten(tdef, rs))


def decompress_grads(payload: dict, scales: dict) -> dict:
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, payload, scales)
