"""AdamW with decoupled weight decay and global-norm clipping.

Built from scratch (no optax in this environment). Moments are kept in fp32
regardless of the bf16 parameter dtype — the master copy of the weights is
also fp32 (stored in the optimizer state) so repeated bf16 rounding never
accumulates across steps.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # () int32
    master: dict  # fp32 master weights
    mu: dict  # first moment, fp32
    nu: dict  # second moment, fp32


def adamw_init(params: dict) -> AdamWState:
    # copy=True: fp32 params must not alias the master buffers (donation)
    f32 = lambda t: jax.tree.map(lambda x: jnp.array(x, jnp.float32, copy=True), t)
    zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), f32(params), zeros,
                      jax.tree.map(jnp.copy, zeros))


def adamw_state_shapes(param_shapes: dict) -> AdamWState:
    """ShapeDtypeStruct mirror for the dry-run (no allocation)."""
    f32 = lambda t: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), t)
    return AdamWState(jax.ShapeDtypeStruct((), jnp.int32), f32(param_shapes),
                      f32(param_shapes), f32(param_shapes))


def global_norm(tree: dict) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree.leaves(tree)))


def _is_matrix(x: jax.Array) -> bool:
    return x.ndim >= 2


def adamw_update(grads: dict, state: AdamWState, lr: jax.Array,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0,
                 param_dtype=jnp.bfloat16):
    """Returns (new bf16 params, new state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(w, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if _is_matrix(w):  # decay matrices only (norm scales/biases exempt)
            u = u + weight_decay * w
        return w - lr * u

    master = jax.tree.map(upd, state.master, mu, nu)
    params = jax.tree.map(lambda w: w.astype(param_dtype), master)
    return params, AdamWState(step, master, mu, nu), {"grad_norm": gnorm}
