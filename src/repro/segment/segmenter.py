"""Greedy dyadic segmenter: split only where the function needs it.

A uniform table must size its region count 2^R for the *worst* region —
one high-curvature stretch (tanh's knee, exp's head) forces every flat
stretch to the same resolution. The segmenter instead starts coarse and
splits leaves individually:

1. start from the uniform tree at ``min_depth``;
2. probe each leaf's Eqns 9-10 feasibility (a leaf is a single region —
   ``compute_spaces`` over the stacked same-depth rows, one batched call
   per depth group) and split every infeasible leaf, until all leaves are
   feasible or sit at ``max_depth``;
3. run the per-depth-group §III decisions (:mod:`repro.segment.decide`);
   if a group fails (no integer design at its shared k), split that
   group's leaves and go back to 2;
4. assemble + exhaustively verify the :class:`SegmentedDesign`.

Splitting a feasible leaf keeps it feasible (a dyadic child's bound rows
are a subset of constraints), so the refinement is monotone and terminates
at ``max_depth`` — which defaults to the smallest *uniform* feasible R, the
depth at which every leaf is feasible by the uniform argument. The result
therefore never has more resolution anywhere than the uniform design, and
strictly less wherever the function is flat: fewer ROM rows at the same
faithful-rounding guarantee (BENCH_8).

``engine`` threads through untouched: ``pooled`` is the serial oracle,
``batched``/``pallas`` the fleet engines — all bit-identical (tested).
"""
from __future__ import annotations

import numpy as np

from repro.core.decision import DecisionPolicy
from repro.core.designspace import compute_spaces, regions_feasible
from repro.core.funcspec import FunctionSpec
from repro.segment.decide import _decide_groups, assemble, group_bounds
from repro.segment.design import SegmentedDesign
from repro.segment.tree import Segmentation


def min_uniform_depth(spec: FunctionSpec, *, lo: int = 1,
                      impl: str | None = None, engine: str | None = None
                      ) -> int:
    """Smallest R whose uniform 2^R regions all pass Eqns 9-10."""
    for r in range(lo, spec.in_bits):
        ok, _ = regions_feasible(spec, r, impl, engine=engine)
        if ok:
            return r
    raise ValueError(f"{spec.name}: no feasible uniform R < in_bits")


def _infeasible_leaves(spec: FunctionSpec, seg: Segmentation,
                       lo: np.ndarray, hi: np.ndarray,
                       impl: str | None, engine: str | None) -> list[int]:
    """Leaves failing the Eqns 9-10 existence test, one batched
    ``compute_spaces`` call per depth group."""
    bad: list[int] = []
    for _depth, leaves in sorted(seg.depth_groups().items()):
        L, U = group_bounds(spec, seg, leaves, lo, hi)
        spaces = compute_spaces(L, U, impl, engine)
        bad.extend(i for i, s in zip(leaves, spaces) if not s.feasible)
    return sorted(bad)


def explore_segmented(spec: FunctionSpec, *, min_depth: int = 2,
                      max_depth: int | None = None,
                      degree: int | None = None, impl: str | None = None,
                      k_max: int | None = None, engine: str | None = None,
                      policy: DecisionPolicy | None = None,
                      name: str | None = None) -> SegmentedDesign | None:
    """Grow the cheapest feasible dyadic segmentation and decide it.

    Returns a verified :class:`SegmentedDesign`, or None when even the
    all-``max_depth`` (uniform-equivalent) tree admits no integer design
    under ``k_max`` — the same condition under which the uniform path
    returns None at R = max_depth.
    """
    lo, hi = spec.bound_arrays()
    if max_depth is None:
        max_depth = min_uniform_depth(spec, lo=min_depth, impl=impl,
                                      engine=engine)
    min_depth = min(min_depth, max_depth)
    seg = Segmentation.uniform(spec.in_bits, min_depth)

    # Phase 1: split to Eqns 9-10 feasibility.
    while True:
        bad = _infeasible_leaves(spec, seg, lo, hi, impl, engine)
        if not bad:
            break
        splittable = [i for i in bad if seg.depths[i] < max_depth]
        if not splittable:
            return None
        seg = seg.split_many(splittable)

    # Phase 2: per-depth-group decisions; split any group that cannot
    # realize integer coefficients at its shared k.
    while True:
        designs, failed = _decide_groups(spec, seg, degree=degree, impl=impl,
                                         k_max=k_max, engine=engine,
                                         policy=policy, lo=lo, hi=hi)
        if failed is None:
            return assemble(spec, seg, designs, name=name)
        splittable = [i for i, d in enumerate(seg.depths)
                      if d == failed and d < max_depth]
        if not splittable:
            return None
        seg = seg.split_many(splittable)
