"""repro.segment — non-uniform (hierarchical power-of-two) segmentation.

The uniform paper layout is the degenerate case of a dyadic prefix tree
(every leaf at the same depth); this package generates, decides, costs and
packs the general case end to end:

  * :class:`Segmentation` — the combinatorial tree (tree.py)
  * :func:`decide_segmentation` — §III decisions per depth group (decide.py)
  * :func:`explore_segmented` — the greedy split refinement (segmenter.py)
  * :class:`SegmentedDesign` — the verified artifact + int64 oracle (design.py)
  * :func:`estimate_segmented` — target costs incl. decoder (cost.py)

DESIGN.md §15 walks the whole pipeline.
"""
from repro.segment.cost import estimate_segmented
from repro.segment.decide import decide_segmentation
from repro.segment.design import SegmentedDesign
from repro.segment.segmenter import explore_segmented, min_uniform_depth
from repro.segment.tree import Segmentation

__all__ = [
    "Segmentation",
    "SegmentedDesign",
    "decide_segmentation",
    "explore_segmented",
    "min_uniform_depth",
    "estimate_segmented",
]
