"""Hierarchical power-of-two segmentations (dyadic prefix trees).

The paper's layout is *uniform*: the top R input bits select one of 2^R
equal regions. The classic VLSI refinement (Lee/Cheung-style hierarchical
segmentation; PAPERS.md) keeps the hardware-friendly power-of-two address
decode but lets region *widths* vary: segments are the leaves of a binary
prefix tree over the input domain, so every leaf is an aligned dyadic
interval ``[p * 2^(B-d), (p+1) * 2^(B-d))`` at some depth ``d``. The region
index then comes from a small 2^D-entry table addressed by the top
``D = max(d)`` input bits — a one-level indirection instead of 2^B
comparators, which is exactly what the segment-index datapath in
``kernels/interp`` (``_lut_seg``) and the ROM-v2 slot layout implement.

:class:`Segmentation` is the pure combinatorial object: an ordered tuple of
leaf depths whose dyadic intervals tile ``[0, 2^B)`` exactly. Everything
else (bounds, coefficients, costs) lives in the sibling modules.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Segmentation:
    """Leaves of a dyadic prefix tree tiling ``[0, 2^in_bits)``.

    ``depths[i]`` is the tree depth of leaf ``i`` (left to right); leaf i
    covers ``2^(in_bits - depths[i])`` input codes. Validity — each leaf
    aligned to its own width and the widths summing to the full domain — is
    checked at construction, so every instance is a correct tiling.
    """

    in_bits: int
    depths: tuple[int, ...]

    def __post_init__(self):
        b = self.in_bits
        if b <= 0:
            raise ValueError(f"in_bits must be positive, got {b}")
        if not self.depths:
            raise ValueError("segmentation needs at least one leaf")
        pos = 0
        for i, d in enumerate(self.depths):
            if not 0 <= d <= b:
                raise ValueError(f"leaf {i}: depth {d} outside [0, {b}]")
            width = 1 << (b - d)
            if pos % width:
                raise ValueError(
                    f"leaf {i}: start {pos} not aligned to width {width}")
            pos += width
        if pos != 1 << b:
            raise ValueError(
                f"leaves cover [0, {pos}), domain is [0, {1 << b})")

    # -- constructors ------------------------------------------------------
    @classmethod
    def uniform(cls, in_bits: int, lookup_bits: int) -> "Segmentation":
        """The degenerate segmentation: 2^R equal leaves — the paper's
        uniform layout expressed as a prefix tree (every leaf at depth R)."""
        return cls(in_bits, (lookup_bits,) * (1 << lookup_bits))

    def split(self, leaf: int) -> "Segmentation":
        """Replace leaf ``leaf`` by its two children (depth + 1)."""
        d = self.depths[leaf]
        if d >= self.in_bits:
            raise ValueError(f"leaf {leaf} already at max depth {d}")
        return Segmentation(
            self.in_bits,
            self.depths[:leaf] + (d + 1, d + 1) + self.depths[leaf + 1:])

    def split_many(self, leaves) -> "Segmentation":
        """Split several leaves at once (indices into the current tree)."""
        out = list(self.depths)
        for i in sorted(set(leaves), reverse=True):
            d = out[i]
            if d >= self.in_bits:
                raise ValueError(f"leaf {i} already at max depth {d}")
            out[i:i + 1] = [d + 1, d + 1]
        return Segmentation(self.in_bits, tuple(out))

    # -- structure ---------------------------------------------------------
    @property
    def n_leaves(self) -> int:
        return len(self.depths)

    @property
    def max_depth(self) -> int:
        """D: the segment-index table is addressed by the top D input bits."""
        return max(self.depths)

    @property
    def is_uniform(self) -> bool:
        return len(set(self.depths)) == 1

    def leaf_starts(self) -> np.ndarray:
        """(S,) int64 first code of each leaf."""
        widths = np.array([1 << (self.in_bits - d) for d in self.depths],
                          np.int64)
        starts = np.zeros(len(widths), np.int64)
        np.cumsum(widths[:-1], out=starts[1:])
        return starts

    def leaf_widths(self) -> np.ndarray:
        return np.array([1 << (self.in_bits - d) for d in self.depths],
                        np.int64)

    def seg_table(self) -> np.ndarray:
        """(2^D,) int32 leaf index per cell of the top-D-bit address space —
        the content of the ROM-v2 segment-index table. Cell c belongs to the
        leaf whose dyadic interval contains code ``c << (B - D)``; leaves at
        depth d < D own ``2^(D - d)`` consecutive cells."""
        d_max = self.max_depth
        out = np.empty(1 << d_max, np.int32)
        pos = 0
        for i, d in enumerate(self.depths):
            n = 1 << (d_max - d)
            out[pos:pos + n] = i
            pos += n
        return out

    def packed_table(self) -> np.ndarray:
        """The seg table packed 3 int32 entries per ROM row:
        ``(ceil(2^D / 3), 3)`` — the rows appended after the per-leaf
        coefficients in a ROM-v2 slot (``FuncMeta.rows_used``)."""
        tab = self.seg_table()
        n_rows = (len(tab) + 2) // 3
        out = np.zeros(n_rows * 3, np.int32)
        out[: len(tab)] = tab
        return out.reshape(n_rows, 3)

    def depth_groups(self) -> dict[int, list[int]]:
        """depth -> leaf indices at that depth (insertion-ordered)."""
        groups: dict[int, list[int]] = {}
        for i, d in enumerate(self.depths):
            groups.setdefault(d, []).append(i)
        return groups
