"""Cost estimation for segmented designs against the registered targets.

A :class:`~repro.segment.design.SegmentedDesign` is costed as the uniform
model over a *conservative scalar view* (widest datapath over the leaves,
stored row count instead of the 2^R address span) **plus** the target's
segment-index decoder — the extra address-translation hardware a
non-uniform layout needs (``Target.decoder_estimate``). Targets that pack
the seg table into the coefficient ROM itself (pallas-tpu, ROM v2) set
``seg_table_in_rom`` and get the full ``rows_used`` charged as ROM; the
others store only the per-leaf coefficient rows there and pay the table
inside ``decoder_estimate``.
"""
from __future__ import annotations

import dataclasses

from repro.core.area import AreaDelay
from repro.core.table import CoeffMeta
from repro.segment.design import SegmentedDesign


@dataclasses.dataclass(frozen=True)
class _CostView:
    """Duck-typed stand-in for TableDesign in the uniform cost models:
    worst-case (widest) per-leaf datapath + explicit stored row count."""

    lookup_bits: int
    eval_bits: int
    degree: int
    sq_trunc: int
    lin_trunc: int
    a_meta: CoeffMeta
    b_meta: CoeffMeta
    c_meta: CoeffMeta
    rows: int

    @property
    def lut_widths(self) -> tuple[int, int, int]:
        return (self.a_meta.width, self.b_meta.width, self.c_meta.width)


def cost_view(design: SegmentedDesign, rows: int | None = None) -> _CostView:
    metas = [m for m in design.leaf_meta]
    return _CostView(
        lookup_bits=design.seg_depth,
        eval_bits=max(m[0] for m in metas),
        degree=max(m[4] for m in metas),
        sq_trunc=min(m[2] for m in metas),
        lin_trunc=min(m[3] for m in metas),
        a_meta=design.a_meta, b_meta=design.b_meta, c_meta=design.c_meta,
        rows=int(rows if rows is not None else design.n_leaves))


def estimate_segmented(design: SegmentedDesign, target) -> AreaDelay:
    """(area, delay) of a segmented design under ``target``: uniform model
    over the conservative view + the segment-index decoder."""
    from repro.api.target import get_target

    t = get_target(target)
    packed = bool(getattr(t, "seg_table_in_rom", False))
    view = cost_view(design, rows=design.rows_used if packed
                     else design.n_leaves)
    base = t.estimate(view)
    dec = t.decoder_estimate(design.n_leaves, design.seg_depth) \
        if hasattr(t, "decoder_estimate") else AreaDelay(0.0, 0.0)
    return AreaDelay(area=base.area + dec.area, delay=base.delay + dec.delay)
