"""Per-depth-group §III decisions over a fixed segmentation.

A segmentation's leaves at one depth d all span the same number of input
codes (2^(B-d)), so the group IS a uniform sub-problem: stack one bound row
per leaf and run the unmodified envelopes -> Eqns 9-10 -> minimal-k ->
truncation -> Algorithm 1 pipeline (``core.decision.run_decision``) over
those rows via its ``bounds`` hook. Nothing in the §II/§III machinery knows
the rows came from non-adjacent dyadic intervals — the decision procedure
is generic over bound rows, which is the whole point of reusing it.

The pseudo-spec trick: ``run_decision`` reads only ``spec.in_bits`` (to
derive the evaluation width W = in_bits - lookup_bits), ``spec.out_bits``
and ``spec.name`` when ``bounds`` is given, so a depth group of m leaves of
width 2^W runs as a width-only clone of the real spec with
``in_bits = W + ceil_log2(m)`` and ``lookup_bits = ceil_log2(m)``. The
*degenerate* segmentation (every leaf at depth R) produces exactly one
group whose rows equal ``spec.region_bounds(R)`` — the identical arrays the
uniform path derives internally — so the resulting coefficients are
bit-identical to ``run_decision(spec, R)`` (property-tested).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.decision import DecisionPolicy, run_decision
from repro.core.funcspec import FunctionSpec
from repro.core.table import TableDesign
from repro.segment.design import SegmentedDesign
from repro.segment.tree import Segmentation


def _ceil_log2(n: int) -> int:
    return max(n - 1, 0).bit_length()


def group_bounds(spec: FunctionSpec, seg: Segmentation, leaves: list[int],
                 lo: np.ndarray | None = None, hi: np.ndarray | None = None
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Stacked (m, 2^W) bound rows of same-depth leaves (one slice per
    leaf's dyadic interval out of the full-domain bound arrays)."""
    if lo is None or hi is None:
        lo, hi = spec.bound_arrays()
    starts = seg.leaf_starts()
    widths = seg.leaf_widths()
    w = int(widths[leaves[0]])
    assert all(int(widths[i]) == w for i in leaves), "mixed-depth group"
    L = np.stack([lo[starts[i]:starts[i] + w] for i in leaves])
    U = np.stack([hi[starts[i]:starts[i] + w] for i in leaves])
    return L, U


def decide_group(spec: FunctionSpec, seg: Segmentation, leaves: list[int],
                 bounds: tuple[np.ndarray, np.ndarray], *,
                 degree: int | None = None, impl: str | None = None,
                 k_max: int | None = None, engine: str | None = None,
                 policy: DecisionPolicy | None = None
                 ) -> TableDesign | None:
    """Run the full §III procedure on one depth group; row r of the result
    is leaf ``leaves[r]``'s coefficient triple."""
    m = len(leaves)
    depth = seg.depths[leaves[0]]
    w = spec.in_bits - depth
    lb = _ceil_log2(m)
    pseudo = dataclasses.replace(
        spec, name=f"{spec.name}@d{depth}", in_bits=w + lb)
    out = run_decision(pseudo, lb, degree=degree, impl=impl, k_max=k_max,
                       policy=policy, engine=engine, bounds=bounds)
    return out[0] if out is not None else None


def decide_segmentation(spec: FunctionSpec, seg: Segmentation, *,
                        degree: int | None = None, impl: str | None = None,
                        k_max: int | None = None, engine: str | None = None,
                        policy: DecisionPolicy | None = None,
                        name: str | None = None
                        ) -> SegmentedDesign | None:
    """Decide every depth group of ``seg`` and assemble a verified
    :class:`SegmentedDesign`; None if any group has no design (callers
    split that group and retry — ``_decide_groups`` reports which)."""
    designs, failed = _decide_groups(spec, seg, degree=degree, impl=impl,
                                     k_max=k_max, engine=engine,
                                     policy=policy)
    if failed is not None:
        return None
    return assemble(spec, seg, designs, name=name)


def _decide_groups(spec: FunctionSpec, seg: Segmentation, *,
                   degree: int | None = None, impl: str | None = None,
                   k_max: int | None = None, engine: str | None = None,
                   policy: DecisionPolicy | None = None,
                   lo: np.ndarray | None = None, hi: np.ndarray | None = None
                   ) -> tuple[dict[int, TableDesign], int | None]:
    """(depth -> group design, first failing depth or None)."""
    if lo is None or hi is None:
        lo, hi = spec.bound_arrays()
    designs: dict[int, TableDesign] = {}
    for depth, leaves in sorted(seg.depth_groups().items()):
        b = group_bounds(spec, seg, leaves, lo, hi)
        d = decide_group(spec, seg, leaves, b, degree=degree, impl=impl,
                         k_max=k_max, engine=engine, policy=policy)
        if d is None:
            return designs, depth
        designs[depth] = d
    return designs, None


def assemble(spec: FunctionSpec, seg: Segmentation,
             group_designs: dict[int, TableDesign],
             name: str | None = None) -> SegmentedDesign:
    """Scatter per-group coefficient rows back to leaf order and merge the
    Algorithm-1 storage formats (widest per column across groups); the
    assembled artifact is exhaustively re-verified against the spec."""
    s = seg.n_leaves
    a = np.zeros(s, np.int64)
    b = np.zeros(s, np.int64)
    c = np.zeros(s, np.int64)
    meta_rows: list[tuple[int, int, int, int, int]] = [None] * s  # type: ignore
    for depth, leaves in seg.depth_groups().items():
        d = group_designs[depth]
        w = spec.in_bits - depth
        for r, i in enumerate(leaves):
            a[i], b[i], c[i] = int(d.a[r]), int(d.b[r]), int(d.c[r])
            meta_rows[i] = (w, d.k, d.sq_trunc, d.lin_trunc, d.degree)

    def widest(col: str):
        metas = [getattr(group_designs[dp], col) for dp in group_designs]
        return max(metas, key=lambda m: (m.width, -m.shift))

    design = SegmentedDesign(
        name=name or f"{spec.name}_S{s}D{seg.max_depth}",
        in_bits=spec.in_bits, out_bits=spec.out_bits, seg=seg,
        a=a, b=b, c=c, leaf_meta=tuple(meta_rows),
        a_meta=widest("a_meta"), b_meta=widest("b_meta"),
        c_meta=widest("c_meta"))
    ok, worst = design.verify(spec)
    assert ok, (f"segmented decision produced an invalid design for "
                f"{spec.name} ({worst} ULP violation)")
    return design
