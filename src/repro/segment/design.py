"""SegmentedDesign: a verified non-uniform piecewise-polynomial artifact.

The non-uniform counterpart of :class:`repro.core.table.TableDesign`: one
(a, b, c) coefficient row *per leaf* of a :class:`~repro.segment.tree.
Segmentation`, plus the per-leaf datapath constants (eval_bits, k,
truncations, degree) that the uniform design keeps as scalars. ``eval_int``
is the exact int64 oracle of the whole artifact — bit-identical to the
jnp/Pallas segment-index datapath (``kernels/interp``) and used by the
exhaustive ``verify`` sweep, the same contract the uniform design has.

Duck-typing contract: :meth:`repro.api.InterpLibrary.from_designs` consumes
``seg_depth`` / ``leaf_meta`` / ``packed_coeffs()`` plus the usual
name/width fields, so a SegmentedDesign drops into a library slot (ROM v2)
next to uniform TableDesigns with no special casing at the call site.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.funcspec import FunctionSpec
from repro.core.table import CoeffMeta
from repro.segment.tree import Segmentation


@dataclasses.dataclass
class SegmentedDesign:
    """A concrete, verified non-uniform piecewise-polynomial implementation.

    ``leaf_meta[i]`` is the (eval_bits, k, sq_trunc, lin_trunc, degree) row
    of leaf i — the static per-leaf datapath the kernels gather through the
    segment-index table. The scalar ``k`` / ``degree`` / truncation
    attributes mirror leaf 0 (a *representative*, for FuncMeta's uniform
    fields); per-leaf values always come from ``leaf_meta``.
    """

    name: str
    in_bits: int
    out_bits: int
    seg: Segmentation
    a: np.ndarray  # (S,) int64 — one row per leaf, left to right
    b: np.ndarray
    c: np.ndarray
    leaf_meta: tuple[tuple[int, int, int, int, int], ...]
    a_meta: CoeffMeta  # merged storage formats (widest over depth groups)
    b_meta: CoeffMeta
    c_meta: CoeffMeta

    def __post_init__(self):
        s = self.seg.n_leaves
        assert len(self.a) == len(self.b) == len(self.c) == s, \
            (len(self.a), s)
        assert len(self.leaf_meta) == s, (len(self.leaf_meta), s)
        for i, (eb, *_rest) in enumerate(self.leaf_meta):
            assert eb == self.in_bits - self.seg.depths[i], \
                f"leaf {i}: eval_bits {eb} != B - d"

    # -- representative scalars (FuncMeta's uniform fields) ----------------
    @property
    def seg_depth(self) -> int:
        return self.seg.max_depth

    @property
    def lookup_bits(self) -> int:
        """For a segmented slot the 'lookup' is the segment-index table
        depth D — what the top input bits actually address."""
        return self.seg.max_depth

    @property
    def eval_bits(self) -> int:
        """Widest per-leaf evaluation width (worst-case datapath)."""
        return max(m[0] for m in self.leaf_meta)

    @property
    def k(self) -> int:
        return self.leaf_meta[0][1]

    @property
    def sq_trunc(self) -> int:
        return self.leaf_meta[0][2]

    @property
    def lin_trunc(self) -> int:
        return self.leaf_meta[0][3]

    @property
    def degree(self) -> int:
        """2 if any leaf is quadratic (the squarer must exist)."""
        return max(m[4] for m in self.leaf_meta)

    @property
    def n_leaves(self) -> int:
        return self.seg.n_leaves

    @property
    def lut_widths(self) -> tuple[int, int, int]:
        return (self.a_meta.width, self.b_meta.width, self.c_meta.width)

    @property
    def rows_used(self) -> int:
        """ROM-v2 slot rows: per-leaf coeffs + the packed seg table."""
        return self.n_leaves + ((1 << self.seg_depth) + 2) // 3

    rows = rows_used  # cost-model override (targets read getattr 'rows')

    # -- evaluation / verification ----------------------------------------
    def eval_int(self, codes: np.ndarray) -> np.ndarray:
        """Exact int64 oracle of the segment-index datapath.

        cell = top D bits -> seg table -> leaf; x = code & (2^W_leaf - 1)
        (leaves are aligned, so the low W_leaf bits ARE the intra-leaf
        offset); then the per-leaf Figure-1 tail. The accumulation order
        matches the kernels' ``a*xs*xs + b*xl + c`` — int64 is exact here,
        and the int32 kernels agree bitwise because wrapping adds commute.
        """
        codes = np.asarray(codes, dtype=np.int64)
        d_max = self.seg_depth
        cell = codes >> (self.in_bits - d_max)
        leaf = self.seg.seg_table().astype(np.int64)[cell]
        meta = np.asarray(self.leaf_meta, np.int64)[leaf]  # (..., 5)
        eb, k, sq, lin, deg = (meta[..., i] for i in range(5))
        x = codes & ((np.int64(1) << eb) - 1)
        xs = (x >> sq) << sq
        xl = (x >> lin) << lin
        sq_term = np.where(deg == 2, self.a[leaf] * xs * xs, 0)
        acc = sq_term + self.b[leaf] * xl + self.c[leaf]
        return acc >> k

    def verify(self, spec: FunctionSpec) -> tuple[bool, int]:
        """Exhaustive int64 sweep over every input code (same contract as
        ``TableDesign.verify``). Returns (ok, worst violation in ULPs)."""
        lo, hi = spec.bound_arrays()
        codes = np.arange(1 << self.in_bits, dtype=np.int64)
        y = self.eval_int(codes)
        worst = int(max((lo - y).max(), (y - hi).max()))
        return worst <= 0, max(worst, 0)

    def max_error_ulp(self, spec: FunctionSpec) -> float:
        if spec.value is None:
            raise ValueError("spec has no real-valued target")
        codes = np.arange(1 << self.in_bits, dtype=np.int64)
        y = self.eval_int(codes).astype(np.float64)
        return float(np.abs(y - spec.value(codes)).max())

    # -- ROM packing -------------------------------------------------------
    @property
    def fits_int32(self) -> bool:
        mat = np.stack([self.a, self.b, self.c], axis=1)
        return bool(np.abs(mat).max() < 2**31)

    def packed_coeffs(self) -> np.ndarray:
        """(rows_used, 3) int32 ROM-v2 slot: per-leaf coefficient rows, then
        the packed segment-index table (``Segmentation.packed_table``)."""
        mat = np.stack([self.a, self.b, self.c], axis=1)
        if np.abs(mat).max() >= 2**31:
            raise ValueError(f"{self.name}: coefficients exceed int32")
        return np.concatenate(
            [mat.astype(np.int32), self.seg.packed_table()], axis=0)
