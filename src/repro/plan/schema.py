"""NumericsPlan: per-layer x per-op-site numerics assignment (DESIGN.md §16).

A plan maps every decoder layer onto three *op sites* — the attention
softmax path (``exp_neg``/``recip_pos``/``softmax``), the rmsnorm path
(``rmsnorm``/``rsqrt_pos``), and the activation path (``silu``/``gelu``/
``sigmoid``/``softplus``/``tanh``) — and assigns each site a backend
(exact / interp / interp-fused / interp-guarded) plus a *library slot*: the
(lookup_bits, degree, segmentation) point of the per-function Pareto
frontier that site's tables are compiled at. ``rest`` covers every op
outside the layer stack (final norm, encoder, projector, embeddings-side
glue).

Everything here is frozen dataclasses over tuples so a plan — and hence a
``ModelConfig`` carrying one — stays hashable: the serve engine keys its
jit cache on the config, and two engines differing only in plan must not
share traces. The module is dependency-light (no jax import) because
``configs.base`` imports it at module load.

Serialization rides the same schema-versioned snapshot envelope as the
BENCH/DSE artifacts (``repro.dse.record``): ``save_plan`` writes
``{"schema", "meta", "tables": {"numerics_plan": {...}}}`` and
``load_plan`` refuses plan payloads newer than :data:`PLAN_SCHEMA`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Optional

PLAN_SCHEMA = 1

SITES = ("softmax", "rmsnorm", "act")

PLAN_BACKENDS = ("exact", "interp", "interp-fused", "interp-guarded")

# which table kinds an op site draws on (the softmax site needs both the
# exponential and the normalization reciprocal; a site's certified error is
# composed over exactly these kinds)
SITE_KINDS = {
    "softmax": ("exp2neg", "recip"),
    "rmsnorm": ("rsqrt",),
    "act": ("gelu", "sigmoid", "silu", "softplus", "tanh"),
}

SEGMENTATIONS = ("uniform", "hier")


@dataclasses.dataclass(frozen=True)
class SlotSpec:
    """A library slot choice: where on the per-function frontier the site's
    tables sit. ``None`` fields mean "the Explorer's per-kind default"."""

    lookup_bits: Optional[int] = None
    degree: Optional[int] = None
    segmentation: str = "uniform"

    def __post_init__(self):
        if self.segmentation not in SEGMENTATIONS:
            raise ValueError(f"unknown segmentation {self.segmentation!r}")

    @property
    def key(self) -> str:
        """Canonical slot identity — the library-dict key engines thread."""
        parts = []
        if self.lookup_bits is not None:
            parts.append(f"R{self.lookup_bits}")
        if self.degree is not None:
            parts.append(f"d{self.degree}")
        if self.segmentation != "uniform":
            parts.append(self.segmentation)
        return ".".join(parts) if parts else "default"

    def table_kwargs(self) -> dict[str, Any]:
        kw: dict[str, Any] = {}
        if self.lookup_bits is not None:
            kw["lookup_bits"] = int(self.lookup_bits)
        if self.degree is not None:
            kw["degree"] = int(self.degree)
        return kw

    def to_dict(self) -> dict[str, Any]:
        return {"lookup_bits": self.lookup_bits, "degree": self.degree,
                "segmentation": self.segmentation}

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "SlotSpec":
        return cls(lookup_bits=d.get("lookup_bits"), degree=d.get("degree"),
                   segmentation=d.get("segmentation", "uniform"))


@dataclasses.dataclass(frozen=True)
class SiteAssign:
    """One op site's (backend, slot) assignment."""

    backend: str = "exact"
    slot: SlotSpec = SlotSpec()

    def __post_init__(self):
        if self.backend not in PLAN_BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r} "
                             f"(choose from {PLAN_BACKENDS})")

    @property
    def interp(self) -> bool:
        return self.backend != "exact"

    def to_dict(self) -> dict[str, Any]:
        return {"backend": self.backend, "slot": self.slot.to_dict()}

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "SiteAssign":
        return cls(backend=d.get("backend", "exact"),
                   slot=SlotSpec.from_dict(d.get("slot", {})))


@dataclasses.dataclass(frozen=True)
class LayerAssign:
    """The three op-site assignments of one layer (or of ``rest``)."""

    softmax: SiteAssign = SiteAssign()
    rmsnorm: SiteAssign = SiteAssign()
    act: SiteAssign = SiteAssign()

    def site(self, name: str) -> SiteAssign:
        if name not in SITES:
            raise KeyError(name)
        return getattr(self, name)

    @property
    def uniform_backend(self) -> Optional[str]:
        """The single backend name when all three sites agree (slot
        included), else None. The collapsed case binds one raw backend
        instance for the whole layer — the bitwise-identity path."""
        a = (self.softmax, self.rmsnorm, self.act)
        return self.softmax.backend if a[0] == a[1] == a[2] else None

    def with_site(self, name: str, assign: SiteAssign) -> "LayerAssign":
        return dataclasses.replace(self, **{name: assign})

    def to_dict(self) -> dict[str, Any]:
        return {s: self.site(s).to_dict() for s in SITES}

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "LayerAssign":
        return cls(**{s: SiteAssign.from_dict(d[s]) for s in SITES if s in d})


_EXACT = LayerAssign()


@dataclasses.dataclass(frozen=True)
class NumericsPlan:
    """Per-layer numerics assignment for a whole model."""

    layers: tuple[LayerAssign, ...]
    rest: LayerAssign = _EXACT

    @classmethod
    def uniform(cls, backend: str, n_layers: int,
                slot: SlotSpec = SlotSpec()) -> "NumericsPlan":
        """The degenerate plan: one (backend, slot) everywhere — including
        ``rest`` — which must reproduce the homogeneous engines bitwise."""
        la = LayerAssign(SiteAssign(backend, slot), SiteAssign(backend, slot),
                         SiteAssign(backend, slot))
        return cls(layers=(la,) * int(n_layers), rest=la)

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    def layer(self, i: int) -> LayerAssign:
        return self.layers[i]

    def assignments(self) -> Iterable[tuple[str, str, SiteAssign]]:
        """Yields (layer-label, site, assign) over layers then ``rest``."""
        for i, la in enumerate(self.layers):
            for s in SITES:
                yield str(i), s, la.site(s)
        for s in SITES:
            yield "rest", s, self.rest.site(s)

    @property
    def uses_interp(self) -> bool:
        return any(a.interp for _, _, a in self.assignments())

    def slot_keys(self) -> tuple[str, ...]:
        """Distinct slot keys of the non-exact assignments, sorted — the
        set of libraries an engine must compile/thread."""
        return tuple(sorted({a.slot.key for _, _, a in self.assignments()
                             if a.interp}))

    def slots(self) -> dict[str, SlotSpec]:
        return {a.slot.key: a.slot for _, _, a in self.assignments()
                if a.interp}

    def layers_using_slot(self, key: str) -> tuple:
        """Layer labels whose live (non-exact) sites read slot ``key`` —
        int indices, plus ``"rest"`` when the out-of-stack ops do."""
        hit = set()
        for i, la in enumerate(self.layers):
            for s in SITES:
                a = la.site(s)
                if a.interp and a.slot.key == key:
                    hit.add(i)
        labels = tuple(sorted(hit))
        if any(a.interp and a.slot.key == key
               for a in (self.rest.site(s) for s in SITES)):
            labels = labels + ("rest",)
        return labels

    def map_assignments(self, fn) -> "NumericsPlan":
        """New plan with ``fn(layer_label, site, assign) -> assign`` applied
        everywhere (``layer_label`` is the int index or ``"rest"``)."""
        layers = []
        for i, la in enumerate(self.layers):
            layers.append(LayerAssign(
                **{s: fn(i, s, la.site(s)) for s in SITES}))
        rest = LayerAssign(**{s: fn("rest", s, self.rest.site(s))
                              for s in SITES})
        return NumericsPlan(layers=tuple(layers), rest=rest)

    def degrade_serial(self) -> "NumericsPlan":
        """The plan-level fused -> serial rung: every interp site drops to
        the guarded per-table datapath; exact sites stay exact."""
        def down(_i, _s, a):
            if a.backend in ("interp", "interp-fused"):
                return dataclasses.replace(a, backend="interp-guarded")
            return a
        return self.map_assignments(down)

    def degrade_exact(self) -> "NumericsPlan":
        return self.map_assignments(
            lambda _i, _s, a: SiteAssign("exact", a.slot))

    def degrade_layers(self, layer_ids: Iterable[int],
                       slot_keys: Iterable[str]) -> "NumericsPlan":
        """Downgrade only the named layers' sites that draw on the named
        slots to exact — the per-layer degradation rung: a poisoned slot
        library takes down exactly the layers reading it."""
        ids = {i if i == "rest" else int(i) for i in layer_ids}
        keys = set(slot_keys)

        def down(i, _s, a):
            if i in ids and a.interp and a.slot.key in keys:
                return SiteAssign("exact", a.slot)
            return a
        return self.map_assignments(down)

    def to_dict(self) -> dict[str, Any]:
        return {"plan_schema": PLAN_SCHEMA,
                "layers": [la.to_dict() for la in self.layers],
                "rest": self.rest.to_dict()}

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "NumericsPlan":
        v = d.get("plan_schema", 1)
        if v > PLAN_SCHEMA:
            raise ValueError(f"plan schema {v} is newer than this code "
                             f"({PLAN_SCHEMA})")
        return cls(layers=tuple(LayerAssign.from_dict(x)
                                for x in d["layers"]),
                   rest=LayerAssign.from_dict(d.get("rest", {})))


def save_plan(path, plan: NumericsPlan, *, seed: int | None = None,
              meta_extra: dict[str, Any] | None = None) -> None:
    """Emit a plan through the schema-versioned snapshot envelope."""
    from repro.dse.record import update_snapshot

    update_snapshot(path, {"numerics_plan": plan.to_dict()}, seed=seed,
                    meta_extra=meta_extra)


def load_plan(path) -> NumericsPlan:
    from repro.dse.record import read_snapshot

    tables = read_snapshot(path)
    if "numerics_plan" not in tables:
        raise ValueError(f"{path}: no 'numerics_plan' table in snapshot")
    return NumericsPlan.from_dict(tables["numerics_plan"])


def plan_for(cfg, backend: str | None = None,
             slot: SlotSpec = SlotSpec()) -> NumericsPlan:
    """Uniform plan matching a model config (``backend`` defaults to
    ``cfg.numerics``)."""
    return NumericsPlan.uniform(backend or cfg.numerics, cfg.n_layers,
                                slot=slot)
