"""Resolve a :class:`NumericsPlan` into executable backend objects.

The model stack consumes one ``numerics`` object per layer; a plan engine
holds a :class:`PlanNumerics`, asks it ``for_layer(i)`` inside
``apply_segment`` and gets either a raw homogeneous backend (when all three
op sites of the layer agree — the bitwise-identity path) or a
:class:`SiteNumerics` that routes each op family to its site's backend.
``PlanNumerics`` itself answers every op by delegating to the ``rest``
assignment, so call sites outside the layer stack (final norm, encoder,
projector) need no plan awareness.

Backends and per-layer wrappers are interned per distinct assignment, so
two layers with equal assignments share one instance — ``apply_segment``
groups consecutive equal layers by identity and scans each group once.
"""
from __future__ import annotations

from typing import Optional

from repro.plan.schema import SITES, LayerAssign, NumericsPlan, SiteAssign

# op name -> op site; everything the model stack calls on a numerics object
SITE_OF_OP = {
    "exp_neg": "softmax", "recip_pos": "softmax", "softmax": "softmax",
    "rmsnorm": "rmsnorm", "rsqrt_pos": "rmsnorm",
    "silu": "act", "gelu": "act", "sigmoid": "act", "softplus": "act",
    "tanh": "act",
}


def _resolve_backend(assign: SiteAssign, libraries):
    """Instantiate the backend of one site assignment. ``libraries`` is a
    dict keyed by slot key, a single library applied to every slot, or
    None (per-op lazy table resolution through the default session)."""
    from repro.numerics.ops import (ExactNumerics, FusedInterpNumerics,
                                    InterpNumerics)

    if assign.backend == "exact":
        return ExactNumerics()
    if isinstance(libraries, dict):
        lib = libraries.get(assign.slot.key)
    else:
        lib = libraries
    if assign.backend == "interp":
        return InterpNumerics(lib)
    if assign.backend == "interp-guarded":
        from repro.numerics.guard import GuardedNumerics

        return GuardedNumerics(InterpNumerics(lib))
    if assign.backend == "interp-fused":
        if lib is None:
            raise ValueError(
                f"plan site {assign} is interp-fused but no library is "
                f"bound for slot {assign.slot.key!r}; compile one with "
                f"compile_plan_libraries()")
        return FusedInterpNumerics(lib)
    raise KeyError(assign.backend)


class SiteNumerics:
    """Per-op-site router: one layer's three backends behind the uniform
    numerics interface the model stack already speaks."""

    name = "plan-site"

    def __init__(self, softmax_b, rmsnorm_b, act_b):
        self._softmax = softmax_b
        self._rmsnorm = rmsnorm_b
        self._act = act_b

    @property
    def library(self):
        return self._softmax.library

    # softmax site
    def exp_neg(self, x):
        return self._softmax.exp_neg(x)

    def recip_pos(self, x):
        return self._softmax.recip_pos(x)

    def softmax(self, x, axis: int = -1):
        return self._softmax.softmax(x, axis=axis)

    def fused_attention(self, q, k, v, q_pos, kv_pos, *, causal, window,
                        scale):
        fa = getattr(self._softmax, "fused_attention", None)
        if fa is None:
            return None  # caller falls back to the chunked glue path
        return fa(q, k, v, q_pos, kv_pos, causal=causal, window=window,
                  scale=scale)

    # rmsnorm site
    def rmsnorm(self, x, gamma, eps: float = 1e-6):
        return self._rmsnorm.rmsnorm(x, gamma, eps)

    def rsqrt_pos(self, x):
        return self._rmsnorm.rsqrt_pos(x)

    # activation site
    def silu(self, x):
        return self._act.silu(x)

    def gelu(self, x):
        return self._act.gelu(x)

    def sigmoid(self, x):
        return self._act.sigmoid(x)

    def softplus(self, x):
        return self._act.softplus(x)

    def tanh(self, x):
        return self._act.tanh(x)


class PlanNumerics:
    """A resolved plan: per-layer numerics plus the ``rest`` delegate."""

    name = "plan"

    def __init__(self, plan: NumericsPlan, libraries=None):
        self.plan = plan
        self.libraries = libraries
        self._backends: dict[SiteAssign, object] = {}
        self._by_layer: dict[LayerAssign, object] = {}
        self._layers = tuple(self._layer_numerics(la) for la in plan.layers)
        self._rest = self._layer_numerics(plan.rest)

    def _backend(self, assign: SiteAssign):
        b = self._backends.get(assign)
        if b is None:
            b = _resolve_backend(assign, self.libraries)
            self._backends[assign] = b
        return b

    def _layer_numerics(self, la: LayerAssign):
        n = self._by_layer.get(la)
        if n is None:
            if la.uniform_backend is not None:
                # collapsed case: the layer's three sites share one backend
                # instance — the exact program the homogeneous path builds
                n = self._backend(la.softmax)
            else:
                n = SiteNumerics(*(self._backend(la.site(s)) for s in SITES))
            self._by_layer[la] = n
        return n

    def for_layer(self, i: int):
        return self._layers[i]

    @property
    def library(self):
        return self.libraries

    def __getattr__(self, attr):
        # ops outside the layer stack (final norm, encoder, projector,
        # embeddings glue) evaluate under the ``rest`` assignment
        if attr.startswith("_"):
            raise AttributeError(attr)
        return getattr(self._rest, attr)


def compile_plan_libraries(plan: NumericsPlan, explorer=None
                           ) -> Optional[dict]:
    """One compiled :class:`InterpLibrary` per distinct slot of the plan.

    Every slot library carries the full default kind manifest (not just the
    site's kinds): a collapsed uniform layer binds a single backend serving
    all three sites, and the homogeneous engines it must match bitwise
    compile the full manifest too.
    """
    slots = plan.slots()
    if not slots:
        return None
    from repro.api import default_explorer

    ex = explorer if explorer is not None else default_explorer()
    out = {}
    for key, slot in sorted(slots.items()):
        kw = slot.table_kwargs()
        if slot.segmentation == "hier":
            out[key] = ex.compile_segmented(**kw)
        else:
            out[key] = ex.compile(**kw)
    return out


def plan_numerics(plan: NumericsPlan, libraries=None,
                  explorer=None) -> PlanNumerics:
    """Resolve a plan, compiling slot libraries when none are supplied and
    the plan has fused sites (serial interp sites can stay lazy)."""
    if libraries is None and any(
            a.backend == "interp-fused" for _, _, a in plan.assignments()):
        libraries = compile_plan_libraries(plan, explorer)
    return PlanNumerics(plan, libraries)
