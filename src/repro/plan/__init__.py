"""Per-layer heterogeneous numerics: plan schema, resolution, auto-assign.

``repro.plan.schema`` is dependency-light (imported by ``configs.base``);
``repro.plan.numerics`` resolves a plan into backend objects; the
budget-driven auto-assigner lives in ``repro.plan.assign`` and is imported
lazily (it pulls in the DSE stack).
"""
from repro.plan.schema import (PLAN_BACKENDS, PLAN_SCHEMA, SITE_KINDS, SITES,
                               LayerAssign, NumericsPlan, SiteAssign,
                               SlotSpec, load_plan, plan_for, save_plan)

__all__ = [
    "PLAN_BACKENDS", "PLAN_SCHEMA", "SITE_KINDS", "SITES", "LayerAssign",
    "NumericsPlan", "SiteAssign", "SlotSpec", "load_plan", "plan_for",
    "save_plan", "auto_plan", "plan_numerics", "compile_plan_libraries",
    "PlanNumerics", "SiteNumerics",
]


def __getattr__(name):
    if name in ("plan_numerics", "compile_plan_libraries", "PlanNumerics",
                "SiteNumerics"):
        from repro.plan import numerics as _n

        return getattr(_n, name)
    if name in ("auto_plan", "PlanReport"):
        from repro.plan import assign as _a

        return getattr(_a, name)
    raise AttributeError(name)
