"""Budget-driven auto-assignment of per-layer numerics (DESIGN.md §16).

:func:`auto_plan` searches the per-layer (R, degree, segmentation) space
for a :class:`NumericsPlan` that maximizes *modeled* decode tokens/sec
subject to a whole-model output-error budget:

  1. **Candidate slots** are seeded from committed DSE frontier artifacts
     (``artifacts/dse/FRONTIER_*.json``): for each op site, the slot whose
     tables minimize the site's summed frontier delay across the site's
     kinds (``SITE_KINDS``) wins; sites with no frontier coverage fall
     back to the Explorer's per-kind defaults.
  2. **Error composition** is additive over layers and sites: each interp
     site contributes a certified relative-error term derived from its
     kinds' spec widths (the :func:`repro.numerics.ops.softmax_ulp_bound`
     construction generalized per site), weighted by layer sensitivity
     (edge layers 2x — the embedding-adjacent and logits-adjacent blocks
     amplify numerics error the most).
  3. **Greedy budget descent**: start all-interp (max throughput), flip
     the (layer, site) with the largest weighted error to exact until the
     predicted whole-model error fits the budget. Deterministic: ties
     break on (layer index, site order).
  4. **End-to-end verification** (``verify=True``): prefill logits under
     the plan vs. all-exact on deterministic tokens; while the *measured*
     relative error exceeds the budget, keep flipping sites in the same
     greedy order and re-measure. The returned plan's ``measured_error``
     is therefore guaranteed ``<= error_budget`` (worst case the plan
     degenerates to all-exact, error 0).

Candidate slot libraries compile through one Explorer session with the
envelope probes batched up front (``prime_envelopes`` — the fleet engine
answers every (spec, R) in one stacked program).

The throughput model extends the DSE probe's dispatch/transfer cost model
(:mod:`repro.dse.probe`) below the tick: a fused tick costs
``(DISPATCH_COST_S + TRANSFER_COST_S) / horizon`` per decoded token, and
each layer's op sites add a per-step term — one fused table lookup
(``delay x DELAY_UNIT_S``, delay from the frontier metrics) for an interp
site vs. a multi-op exact transcendental (``EXACT_SITE_COST_S``). All
constants are modeled, not wall clock: scores are bit-reproducible, which
is what lets the bench artifact regress them in CI.
"""
from __future__ import annotations

import dataclasses
import json
import math
import pathlib
from typing import Any, Optional

from repro.plan.schema import (SITE_KINDS, SITES, LayerAssign, NumericsPlan,
                               SiteAssign, SlotSpec)

# modeled per-token cost of one op site, per layer (seconds). An exact
# site evaluates a transcendental through multiple vector ops; an interp
# site is one fused ROM lookup whose latency scales with the frontier's
# delay estimate (levels of logic -> modeled seconds).
EXACT_SITE_COST_S = 5e-7
DELAY_UNIT_S = 1e-9
DEFAULT_DELAY = 8.0  # frontier delay proxy when a kind has no coverage

_REPO = pathlib.Path(__file__).resolve().parents[3]
DEFAULT_FRONTIERS = (_REPO / "artifacts" / "dse" / "FRONTIER_8.json",
                     _REPO / "artifacts" / "dse" / "FRONTIER_6.json")


def _site_weight(layer: int, n_layers: int) -> float:
    """Edge layers amplify numerics error the most (embedding-adjacent and
    logits-adjacent); interior layers get unit weight."""
    return 2.0 if layer in (0, n_layers - 1) else 1.0


def _rel_error(kind: str) -> float:
    """Certified relative error of one table kind from its spec widths —
    the ``softmax_ulp_bound`` construction: ~2 output ulps plus half an
    input ulp through the function's slope."""
    from repro.api.config import spec_for

    spec = spec_for(kind)
    return (2.0 ** -spec.out_bits) * 2 + 2.0 ** -(spec.in_bits + 1)


def site_errors() -> dict[str, float]:
    """Per-site certified relative error of an interp assignment.

    softmax composes the exponential and the normalization reciprocal
    exactly as :func:`repro.numerics.ops.softmax_ulp_bound`; rmsnorm rides
    its rsqrt table; the activation site takes the worst of its kinds
    (the plan does not know which activation a layer's FFN uses).
    """
    from repro.api.config import spec_for

    exp, recip = spec_for("exp2neg"), spec_for("recip")
    exp_rel = ((2.0 ** -exp.out_bits) * 2
               + math.log(2.0) * 2.0 ** -(exp.in_bits + 1))
    recip_rel = 2.0 ** -recip.in_bits
    return {
        "softmax": 2 * exp_rel + 2 * recip_rel,
        "rmsnorm": _rel_error("rsqrt"),
        "act": max(_rel_error(k) for k in SITE_KINDS["act"]),
    }


# ---------------------------------------------------------------------------
# frontier-seeded candidate slots
# ---------------------------------------------------------------------------

def load_frontier_candidates(paths=DEFAULT_FRONTIERS, *, target: str = "asic"
                             ) -> dict[str, dict[int, dict[str, Any]]]:
    """``{kind: {lookup_bits: {"area", "delay", "segmentation"}}}`` from
    committed frontier artifacts. Later paths only fill gaps (earlier ones
    win), so FRONTIER_8 (which carries segmentation points) seeds before
    FRONTIER_6. Missing files are skipped — the assigner then falls back
    to default slots rather than failing."""
    out: dict[str, dict[int, dict[str, Any]]] = {}
    for path in paths:
        p = pathlib.Path(path)
        if not p.exists():
            continue
        doc = json.loads(p.read_text())
        groups = doc.get("groups", doc.get("tables", {}).get("groups", {}))
        for entry in groups.get(target, []):
            params, metrics = entry.get("params", {}), entry.get("metrics", {})
            kind, r = params.get("kind"), params.get("lookup_bits")
            if kind is None or r is None:
                continue
            out.setdefault(kind, {}).setdefault(int(r), {
                "area": float(metrics.get("area", 0.0)),
                "delay": float(metrics.get("delay", DEFAULT_DELAY)),
                "segmentation": params.get("segmentation", "uniform"),
            })
    return out


def _choose_slot(site: str, cand: dict[str, dict[int, dict[str, Any]]]
                 ) -> tuple[SlotSpec, float]:
    """The site's slot: the R minimizing summed frontier delay over the
    site's kinds (ties: smaller summed area, then smaller R), restricted
    to heights every kind of the site has coverage for. Returns the slot
    and its summed delay (the throughput model's per-site latency proxy).
    No common coverage -> the default slot at the default delay proxy."""
    kinds = SITE_KINDS[site]
    heights: Optional[set] = None
    for k in kinds:
        rs = set(cand.get(k, {}))
        heights = rs if heights is None else (heights & rs)
    if not heights:
        return SlotSpec(), DEFAULT_DELAY * len(kinds)
    scored = []
    for r in sorted(heights):
        entries = [cand[k][r] for k in kinds]
        delay = sum(e["delay"] for e in entries)
        area = sum(e["area"] for e in entries)
        seg = ("hier" if all(e["segmentation"] == "hier" for e in entries)
               else "uniform")
        scored.append((delay, area, r, seg))
    delay, _area, r, seg = min(scored)
    return SlotSpec(lookup_bits=r, segmentation=seg), delay


# ---------------------------------------------------------------------------
# modeled / measured throughput
# ---------------------------------------------------------------------------

def modeled_tokens_per_s(plan: NumericsPlan, slot_delays: dict[str, float],
                         *, horizon: int = 8,
                         calibration: dict | None = None) -> float:
    """Modeled decode tokens/sec of a fused plan engine: the amortized
    tick dispatch plus every (layer, site) term. ``slot_delays`` maps slot
    keys to their summed frontier delay (``_choose_slot``).

    With ``calibration`` (from :func:`calibrate_slot_latencies`) the
    per-site constants come from *measured* wall clock of the AOT-warmed
    fused tick instead of the modeled ``EXACT_SITE_COST_S`` /
    ``DELAY_UNIT_S`` proxies: ``calibration["site_cost_s"]`` maps
    ``"exact"`` and each slot key to a measured per-(layer, site, step)
    cost. Slots the calibration never measured fall back to the model."""
    from repro.dse.probe import DISPATCH_COST_S, TRANSFER_COST_S

    site_cost = (calibration or {}).get("site_cost_s", {})
    per_step = (DISPATCH_COST_S + TRANSFER_COST_S) / max(1, horizon)
    for _label, _site, a in plan.assignments():
        if a.interp:
            if a.slot.key in site_cost:
                per_step += site_cost[a.slot.key]
            else:
                delay = slot_delays.get(a.slot.key, DEFAULT_DELAY * 2)
                per_step += delay * DELAY_UNIT_S
        else:
            per_step += site_cost.get("exact", EXACT_SITE_COST_S)
    return 1.0 / per_step


def _measure_per_slot_step_s(cfg_run, params, *, horizon: int, slots: int,
                             reps: int, seed: int) -> float:
    """Wall-clock seconds per (slot, decode step) of an AOT-warmed fused
    engine at full occupancy: construction compiles every tick chunk ahead
    of time, one untimed ``step()`` settles admissions, then ``reps``
    timed ticks divide out to the per-slot latency the throughput model
    wants. Measured, not modeled — results vary run to run; callers that
    need reproducible scores keep ``calibration=None``."""
    import time as _time

    import numpy as np

    from repro.serve.engine import Request, ServeEngine

    max_new = (2 + reps) * horizon + 1
    cache_len = max(32, 8 + max_new, cfg_run.sliding_window or 0)
    eng = ServeEngine(cfg_run, params, slots=slots, cache_len=cache_len,
                      horizon=horizon, aot_buckets=(8,))
    rng = np.random.default_rng(seed)
    for i in range(slots):
        eng.submit(Request(i, rng.integers(
            0, cfg_run.vocab_size, 4).astype(np.int32), max_new=max_new))
    eng.step()  # admissions + first (untimed) tick
    n0 = eng.stats["decode_steps"]
    t0 = _time.perf_counter()
    for _ in range(reps):
        eng.step()
    dt = _time.perf_counter() - t0
    dn = eng.stats["decode_steps"] - n0
    return dt / max(1, dn) / slots


def calibrate_slot_latencies(cfg, params=None, slots=None, *,
                             horizon: int = 8, engine_slots: int = 2,
                             reps: int = 3, seed: int = 0) -> dict[str, Any]:
    """Measure per-(layer, site, step) decode cost from the AOT-warmed
    fused tick — the ROADMAP's "feed the assigner *measured* per-slot
    latencies" note.

    One uniform-exact engine and one uniform interp-fused engine per
    distinct candidate slot are AOT-warmed and timed at full occupancy;
    subtracting the modeled amortized dispatch and dividing by the number
    of (layer, site) terms turns each whole-engine latency into the
    per-site constant :func:`modeled_tokens_per_s` consumes. The returned
    dict is JSON-ready and travels in the plan snapshot envelope
    (``meta.report.calibration``), so a saved plan records the wall clock
    its scoring used."""
    import jax

    from repro.dse.probe import DISPATCH_COST_S, TRANSFER_COST_S
    from repro.models import transformer as tf
    from repro.plan.schema import plan_for

    if params is None:
        params = tf.init_params(jax.random.key(seed), cfg)
    if slots is None:
        cand = load_frontier_candidates()
        slots = {s: _choose_slot(s, cand)[0] for s in SITES}
    n_terms = max(1, cfg.n_layers * len(SITES))
    overhead = (DISPATCH_COST_S + TRANSFER_COST_S) / max(1, horizon)
    per_step: dict[str, float] = {}
    site_cost: dict[str, float] = {}

    def record(key: str, cfg_run) -> None:
        t = _measure_per_slot_step_s(cfg_run, params, horizon=horizon,
                                     slots=engine_slots, reps=reps, seed=seed)
        per_step[key] = t
        site_cost[key] = max(t - overhead, 1e-12) / n_terms

    record("exact", cfg.replace(numerics="exact", plan=None))
    for slot in {s.key: s for s in slots.values()}.values():
        cfg_i = cfg.replace(numerics="exact", plan=plan_for(
            cfg, backend="interp-fused", slot=slot))
        record(slot.key, cfg_i)
    return {"horizon": int(horizon), "engine_slots": int(engine_slots),
            "reps": int(reps), "n_layers": int(cfg.n_layers),
            "per_slot_step_s": per_step, "site_cost_s": site_cost}


# ---------------------------------------------------------------------------
# the assigner
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PlanReport:
    """The assigner's full accounting — what the bench artifact records."""

    plan: NumericsPlan
    arch: str
    error_budget: float
    predicted_error: float
    measured_error: Optional[float]
    modeled_tokens_per_s: float
    exact_tokens_per_s: float
    site_errors: dict[str, float]
    slot_delays: dict[str, float]
    flipped: tuple  # (layer, site) pairs downgraded to exact, greedy order
    # measured tick calibration (calibrate_slot_latencies) when the scores
    # came from wall clock instead of the modeled constants; None keeps
    # the bit-reproducible modeled scoring
    calibration: Optional[dict] = None

    @property
    def speedup(self) -> float:
        return self.modeled_tokens_per_s / max(self.exact_tokens_per_s, 1e-12)

    def to_dict(self) -> dict[str, Any]:
        return {
            "arch": self.arch,
            "plan": self.plan.to_dict(),
            "error_budget": self.error_budget,
            "predicted_error": self.predicted_error,
            "measured_error": self.measured_error,
            "modeled_tokens_per_s": self.modeled_tokens_per_s,
            "exact_tokens_per_s": self.exact_tokens_per_s,
            "speedup": self.speedup,
            "site_errors": self.site_errors,
            "slot_delays": self.slot_delays,
            "flipped": [list(f) for f in self.flipped],
            "calibration": self.calibration,
        }


def predicted_error(plan: NumericsPlan, errs: dict[str, float]) -> float:
    """Additive sensitivity-weighted composition over every interp site."""
    n = plan.n_layers
    total = 0.0
    for i, la in enumerate(plan.layers):
        w = _site_weight(i, n)
        for s in SITES:
            if la.site(s).interp:
                total += w * errs[s]
    for s in SITES:
        if plan.rest.site(s).interp:
            total += errs[s]
    return total


def _measure_error(cfg_plan, cfg_exact, params, *, seed: int,
                   prompt_len: int) -> float:
    """End-to-end relative output error: prefill logits under the plan vs.
    all-exact numerics on deterministic tokens (max |delta| over the
    logits range)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.models import transformer as tf
    from repro.numerics.ops import get_numerics

    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg_exact.vocab_size,
                                      (1, prompt_len)).astype(np.int32))
    cache_len = max(prompt_len + 1, cfg_exact.sliding_window or 0)
    got, _, _ = tf.prefill(params, tokens, cfg_plan,
                           get_numerics(cfg_plan), cache_len)
    want, _, _ = tf.prefill(params, tokens, cfg_exact,
                            get_numerics(cfg_exact), cache_len)
    got, want = np.asarray(got, np.float64), np.asarray(want, np.float64)
    scale = max(float(np.abs(want).max()), 1e-12)
    return float(np.abs(got - want).max()) / scale


def auto_plan(cfg, *, error_budget: float, backend: str = "interp-fused",
              frontier_paths=DEFAULT_FRONTIERS, target: str = "asic",
              horizon: int = 8, verify: bool = True, params=None,
              explorer=None, seed: int = 0, prompt_len: int = 16,
              calibrate: bool = False) -> PlanReport:
    """Assign per-layer numerics for ``cfg`` under an output-error budget.

    Returns a :class:`PlanReport` whose ``plan`` maximizes modeled decode
    tokens/sec subject to ``predicted_error <= error_budget`` — and, with
    ``verify=True`` (needs ``params``, or initializes smoke params from
    ``seed``), subject to the *measured* end-to-end prefill-logit error
    too. ``rest`` (final norm, projector, encoder glue) stays exact: its
    single evaluation per token is throughput-negligible but sits closest
    to the logits.

    ``calibrate=True`` replaces the modeled throughput constants with
    wall clock measured from AOT-warmed fused engines
    (:func:`calibrate_slot_latencies`): the report's tokens/sec columns
    become machine-dependent measurements (stored under
    ``report.calibration`` in the snapshot envelope) instead of the
    bit-reproducible model — never enable it for scores CI regresses.
    """
    n = cfg.n_layers
    errs = site_errors()
    cand = load_frontier_candidates(frontier_paths, target=target)
    slots: dict[str, SlotSpec] = {}
    slot_delays: dict[str, float] = {}
    for s in SITES:
        slot, delay = _choose_slot(s, cand)
        slots[s] = slot
        slot_delays.setdefault(slot.key, delay)

    def build(flipped: set) -> NumericsPlan:
        layers = []
        for i in range(n):
            la = LayerAssign(**{
                s: (SiteAssign("exact", slots[s]) if (i, s) in flipped
                    else SiteAssign(backend, slots[s]))
                for s in SITES})
            layers.append(la)
        return NumericsPlan(layers=tuple(layers), rest=LayerAssign())

    # greedy flip order: largest weighted site error first; deterministic
    # tie-break on (layer, site order). Every flip buys the same modeled
    # throughput loss (EXACT_SITE_COST_S dominates any table delay), so
    # max-error-reduction-per-cost == max-error-reduction.
    order = sorted(((i, s) for i in range(n) for s in SITES),
                   key=lambda t: (-_site_weight(t[0], n) * errs[t[1]],
                                  t[0], SITES.index(t[1])))
    flipped: set = set()
    plan = build(flipped)
    pred = predicted_error(plan, errs)
    it = iter(order)
    while pred > error_budget:
        try:
            flipped.add(next(it))
        except StopIteration:
            break
        plan = build(flipped)
        pred = predicted_error(plan, errs)

    measured: Optional[float] = None
    if verify:
        import jax

        from repro.models import transformer as tf

        if params is None:
            params = tf.init_params(jax.random.key(seed), cfg)
        # batch the envelope probes of every slot x kind through the fleet
        # engine before any library compiles serially off the warm cache
        if plan.uses_interp:
            from repro.api import default_explorer
            from repro.api.config import spec_for

            ex = explorer if explorer is not None else default_explorer()
            pairs = []
            for s in SITES:
                r = slots[s].lookup_bits
                if r is not None:
                    pairs.extend((spec_for(k), r) for k in SITE_KINDS[s])
            if pairs:
                ex.prime_envelopes(pairs)
        cfg_exact = cfg.replace(numerics="exact", plan=None)
        while True:
            measured = _measure_error(cfg.replace(plan=plan), cfg_exact,
                                      params, seed=seed,
                                      prompt_len=prompt_len)
            if measured <= error_budget or not plan.uses_interp:
                break
            try:
                flipped.add(next(it))
            except StopIteration:
                plan = plan.degrade_exact()
                continue
            plan = build(flipped)
        pred = predicted_error(plan, errs)

    calib: Optional[dict] = None
    if calibrate:
        import jax

        from repro.models import transformer as tf

        if params is None:
            params = tf.init_params(jax.random.key(seed), cfg)
        calib = calibrate_slot_latencies(cfg, params, slots,
                                         horizon=horizon, seed=seed)

    return PlanReport(
        plan=plan, arch=getattr(cfg, "name", "?"),
        error_budget=float(error_budget), predicted_error=pred,
        measured_error=measured,
        modeled_tokens_per_s=modeled_tokens_per_s(
            plan, slot_delays, horizon=horizon, calibration=calib),
        exact_tokens_per_s=modeled_tokens_per_s(
            NumericsPlan.uniform("exact", n), slot_delays, horizon=horizon,
            calibration=calib),
        site_errors=errs, slot_delays=slot_delays,
        flipped=tuple(sorted(flipped)), calibration=calib)
