"""Compiled interpolation libraries: the runtime-side artifact of a session.

The paper's deployable product is not one table but the *set* of certified
piecewise-polynomial designs a model's numerics touch. ``InterpLibrary``
packs that set into a single frozen, registered JAX pytree:

  * one padded ``(F, R_max, 3)`` int32 coefficient ROM — the only dynamic
    leaf, so the artifact shards (replicated), donates, and rides inside a
    params/cache pytree through ``jit`` / ``vmap`` / ``repro.checkpoint``;
  * a tuple of static :class:`FuncMeta` records (hashable — jit treats the
    library's structure as compile-time constant): per-function widths,
    datapath shifts, and the input-window/output-span constants the float
    glue in ``repro.numerics`` needs.

Evaluation is fused: element ``i`` reads function ``fids[i]``'s rows, so
softmax's exp+recip, rmsnorm's rsqrt and the activations all lower to the
same ``(shapes, F, R_max)`` Pallas executable instead of one specialization
per table (``repro.kernels.interp``). The per-table path remains the
bit-exactness oracle. ``save``/``load`` (npz + json manifest) let a served
model start from a library with zero exploration calls. DESIGN.md §10.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
from typing import Iterable, Sequence

import numpy as np

from repro.core.funcspec import ACT_HI, ACT_KINDS, ACT_LO, act_out_span
from repro.core.table import TableDesign

# The library manifest: every table kind the interp numerics backend can
# touch at runtime (softmax exp/recip, rmsnorm rsqrt, all activations).
# ``Explorer.compile()`` defaults to this set — serving warm-up compiles it
# once instead of hand-maintaining a per-engine kind list.
DEFAULT_LIBRARY_KINDS = ("exp2neg", "gelu", "recip", "rsqrt", "sigmoid",
                         "silu", "softplus", "tanh")

# Manifest format: version 1 is the uniform layout (rows [0, 2^R) of a slot
# hold packed coeffs). Version 2 adds non-uniform segmentation (ISSUE 8 /
# DESIGN.md §15): a segmented slot stores S per-leaf coefficient rows
# followed by the segment-index table packed 3 int32 entries per row; the
# per-leaf datapath lives in FuncMeta.seg_meta. A library with no segmented
# function still saves as version 1, so v1 artifacts round-trip byte- and
# checksum-identically through this code.
_FORMAT_VERSION = 1
_FORMAT_VERSION_SEG = 2


class LibraryIntegrityError(RuntimeError):
    """The resident ROM no longer matches the checksum it was sealed with.

    Raised by :meth:`InterpLibrary.verify_resident` — the serve-time
    counterpart of the load-time ``coeffs_sha`` check: a bit flipped in the
    in-memory coefficient ROM *after* a clean load (DMA corruption, a rogue
    write, an injected fault) is caught here instead of silently decoding
    garbage through every fused kernel that gathers the ROM.
    """


@dataclasses.dataclass(frozen=True)
class FuncMeta:
    """Static per-function metadata of one library slot (hashable)."""

    kind: str  # registry kind, e.g. "exp2neg" — the numerics lookup key
    name: str  # design name, e.g. "exp2neg_12"
    in_bits: int
    out_bits: int
    lookup_bits: int  # R: this function uses rows [0, 2^R) of its slot
    k: int
    degree: int
    sq_trunc: int
    lin_trunc: int
    act_lo: float = 0.0  # input window (direct activation tables only)
    act_hi: float = 0.0
    act_span: float = 0.0  # output span S: float value = int * S / 2^out_bits
    # non-uniform segmentation (ROM v2; 0/() = uniform): seg_depth is the
    # segment-index table depth D (the top D input bits address the table),
    # seg_meta holds one (eval_bits, k, sq_trunc, lin_trunc, degree) row per
    # leaf. For a segmented slot the scalar k/degree/truncation fields above
    # record leaf 0's values and lookup_bits records D.
    seg_depth: int = 0
    seg_meta: tuple = ()

    @property
    def eval_bits(self) -> int:
        return self.in_bits - self.lookup_bits

    @property
    def segmented(self) -> bool:
        return self.seg_depth > 0

    @property
    def rows_used(self) -> int:
        """Slot rows this function occupies: 2^R uniform, else the per-leaf
        coefficient rows plus the packed segment-index table rows."""
        if not self.seg_depth:
            return 1 << self.lookup_bits
        return len(self.seg_meta) + ((1 << self.seg_depth) + 2) // 3

    def seg_spec(self) -> tuple | None:
        """Static segment-datapath tuple the fused kernels consume
        (``None`` = uniform): (in_bits, depth, n_leaves, leaf_meta)."""
        if not self.seg_depth:
            return None
        return (self.in_bits, self.seg_depth, len(self.seg_meta),
                self.seg_meta)

    def datapath_row(self) -> tuple[int, int, int, int, int]:
        """The (eval_bits, k, sq_trunc, lin_trunc, degree) kernel row."""
        return (self.eval_bits, self.k, self.sq_trunc, self.lin_trunc,
                self.degree)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if not self.seg_depth:  # keep uniform manifests byte-stable with v1
            d.pop("seg_depth")
            d.pop("seg_meta")
        else:
            d["seg_meta"] = [list(row) for row in self.seg_meta]
        return d


def _meta_from_dict(d: dict) -> FuncMeta:
    """Rebuild a FuncMeta from a manifest entry (v1 entries carry no seg
    fields; v2 seg_meta arrives as JSON lists and must re-freeze to nested
    tuples so the dataclass stays hashable)."""
    d = dict(d)
    if "seg_meta" in d:
        d["seg_meta"] = tuple(tuple(int(v) for v in row)
                              for row in d["seg_meta"])
    return FuncMeta(**d)


class InterpLibrary:
    """Frozen pytree of every table a model's numerics touch.

    Construct through :meth:`from_designs` / :meth:`repro.api.Explorer.
    compile` / :meth:`load`; the raw constructor is the pytree-unflatten
    hook and performs no validation (leaves may be tracers).
    """

    __slots__ = ("coeffs", "metas", "_index", "_meta_rows", "_walk_rows",
                 "_sealed_sha")

    def __init__(self, coeffs, metas: tuple[FuncMeta, ...]):
        self.coeffs = coeffs  # (F, R_max, 3) int32 — the only dynamic leaf
        self.metas = tuple(metas)
        self._index = {m.kind: i for i, m in enumerate(self.metas)}
        self._meta_rows = None  # lazy (F, 5) device array
        self._walk_rows = None  # lazy ((F, 5), (L, 5)) walk/datapath arrays
        self._sealed_sha = None  # integrity baseline (seal/verify_resident)

    # -- construction ------------------------------------------------------
    @classmethod
    def from_designs(cls, designs: Sequence[TableDesign],
                     kinds: Sequence[str],
                     act_windows: dict | None = None) -> "InterpLibrary":
        """Pack verified designs into one padded ROM + static metadata.

        ``act_windows``: optional ``{kind: (lo, hi)}`` for activation tables
        generated over a non-default input window — recorded in the metadata
        and honored by the library-bound float glue.
        """
        import jax.numpy as jnp

        assert len(designs) == len(kinds) and len(designs) > 0
        dupes = {k for k in kinds if list(kinds).count(k) > 1}
        if dupes:  # _index would silently shadow the earlier slot
            raise ValueError(f"duplicate kinds in library: {sorted(dupes)}")
        metas = []
        for kind, d in zip(kinds, designs):
            seg_depth = getattr(d, "seg_depth", 0)
            if not seg_depth and d.degree != 2 and np.any(d.a != 0):
                raise ValueError(  # fused path zeroes the squarer by degree
                    f"{d.name}: degree-{d.degree} design with nonzero a")
            act = kind in ACT_KINDS
            lo, hi = (act_windows or {}).get(kind, (ACT_LO, ACT_HI))
            metas.append(FuncMeta(
                kind=kind, name=d.name, in_bits=d.in_bits,
                out_bits=d.out_bits, lookup_bits=d.lookup_bits, k=d.k,
                degree=d.degree, sq_trunc=d.sq_trunc, lin_trunc=d.lin_trunc,
                act_lo=lo if act else 0.0, act_hi=hi if act else 0.0,
                act_span=act_out_span(kind, lo, hi) if act else 0.0,
                seg_depth=seg_depth,
                seg_meta=tuple(getattr(d, "leaf_meta", ()))))
        r_max = max(m.rows_used for m in metas)
        packed = np.zeros((len(designs), r_max, 3), np.int32)
        for i, (m, d) in enumerate(zip(metas, designs)):
            packed[i, : m.rows_used] = d.packed_coeffs()
        return cls(jnp.asarray(packed), tuple(metas)).seal()

    # -- introspection -----------------------------------------------------
    @property
    def kinds(self) -> tuple[str, ...]:
        return tuple(m.kind for m in self.metas)

    @property
    def r_max(self) -> int:
        return max(m.rows_used for m in self.metas)

    @property
    def segmented_kinds(self) -> tuple[str, ...]:
        return tuple(m.kind for m in self.metas if m.seg_depth)

    def __contains__(self, kind: str) -> bool:
        return kind in self._index

    def __len__(self) -> int:
        return len(self.metas)

    def __repr__(self) -> str:
        return (f"InterpLibrary({len(self.metas)} funcs, "
                f"coeffs{tuple(np.shape(self.coeffs))}: "
                f"{', '.join(self.kinds)})")

    def func_id(self, kind: str) -> int:
        try:
            return self._index[kind]
        except KeyError:
            raise KeyError(f"{kind!r} not in library {self.kinds}") from None

    def meta(self, kind: str) -> FuncMeta:
        return self.metas[self.func_id(kind)]

    def meta_rows(self):
        """(F, 5) int32 device array of datapath rows (kernel operand)."""
        import jax
        import jax.numpy as jnp

        if self._meta_rows is None:
            rows = jnp.asarray(
                np.array([m.datapath_row() for m in self.metas], np.int32))
            if isinstance(rows, jax.core.Tracer):
                # jnp.asarray returns a tracer under an active trace even
                # for a concrete constant; caching one would leak it
                return rows
            self._meta_rows = rows
        return self._meta_rows

    def walk_rows(self):
        """Operands of the generalized multi-function ROM walk: a ``(F, 5)``
        int32 walk table of ``(in_bits, depth, seg_flag, leaf_base,
        n_leaves)`` rows — depth is R for a uniform slot, the segment-index
        depth D for a segmented one — plus an ``(L, 5)`` datapath table with
        one ``(eval_bits, k, sq_trunc, lin_trunc, degree)`` row per uniform
        function and one per segmented leaf (``leaf_base`` indexes it)."""
        import jax
        import jax.numpy as jnp

        if self._walk_rows is None:
            walk, dp = [], []
            for m in self.metas:
                base = len(dp)
                if m.seg_depth:
                    walk.append((m.in_bits, m.seg_depth, 1, base,
                                 len(m.seg_meta)))
                    dp.extend(m.seg_meta)
                else:
                    walk.append((m.in_bits, m.lookup_bits, 0, base, 1))
                    dp.append(m.datapath_row())
            rows = (jnp.asarray(np.array(walk, np.int32)),
                    jnp.asarray(np.array(dp, np.int32)))
            if any(isinstance(r, jax.core.Tracer) for r in rows):
                return rows  # see meta_rows: never cache a traced constant
            self._walk_rows = rows
        return self._walk_rows

    # -- integrity ---------------------------------------------------------
    def rom_sha(self) -> str:
        """Checksum of the ROM bits actually resident right now (downloads
        the coefficient leaf; host-side only — never call under a trace)."""
        coeffs = np.asarray(self.coeffs, np.int32)
        return hashlib.sha256(
            np.ascontiguousarray(coeffs).tobytes()).hexdigest()[:16]

    def seal(self, sha: str | None = None) -> "InterpLibrary":
        """Record the integrity baseline ``verify_resident`` checks against
        (the current resident checksum, or a known-good one from a saved
        manifest). Construction paths seal automatically; returns self."""
        self._sealed_sha = sha or self.rom_sha()
        return self

    @property
    def sealed_sha(self) -> str | None:
        return self._sealed_sha

    def verify_resident(self) -> str:
        """Re-checksum the in-memory ROM against the sealed baseline.

        This is the *serve-time* integrity guard (DESIGN.md §14): ``load``
        already rejects a corrupt artifact, but a post-load bit flip in the
        resident device buffer is invisible to that check. An unsealed
        library (pytree round-trips drop the baseline) is sealed on first
        verify. Returns the verified checksum; raises
        :class:`LibraryIntegrityError` on mismatch.
        """
        sha = self.rom_sha()
        if self._sealed_sha is None:
            self._sealed_sha = sha
        elif sha != self._sealed_sha:
            raise LibraryIntegrityError(
                f"resident ROM checksum {sha} != sealed {self._sealed_sha}: "
                f"the in-memory coefficient ROM was corrupted after load")
        return sha

    def manifest(self) -> dict:
        f, r_max, _ = np.shape(self.coeffs)
        version = (_FORMAT_VERSION_SEG if any(m.seg_depth for m in self.metas)
                   else _FORMAT_VERSION)
        return {
            "version": version,
            "kinds": list(self.kinds),
            "n_funcs": int(f),
            "r_max": int(r_max),
            "funcs": [m.to_dict() for m in self.metas],
        }

    # -- evaluation --------------------------------------------------------
    def eval_int(self, codes, kind: str, use_kernel: bool | None = None,
                 interpret: bool | None = None):
        """Exact integer evaluation of one function (static kind).

        ``use_kernel=None`` picks the fused Pallas kernel on TPU and the
        jnp slice path elsewhere; both are bit-identical to the per-table
        ``table_eval_int`` oracle (tests/api/test_library.py).
        """
        import jax

        from repro.kernels.interp.ops import _on_tpu
        from repro.kernels.interp.ref import interp_eval_ref

        fid = self.func_id(kind)
        m = self.metas[fid]
        if use_kernel or (use_kernel is None and _on_tpu()):
            return self.eval_fused(codes, fid, use_kernel=True,
                                   interpret=interpret)
        rows = jax.lax.index_in_dim(self.coeffs, fid, 0, keepdims=False)
        if m.seg_depth:
            # jnp path of a non-uniform slot: the segment-index gather
            # oracle (bit-identical to the in-kernel walk)
            from repro.kernels.interp.ref import interp_eval_seg_ref

            return interp_eval_seg_ref(codes, rows, seg=m.seg_spec())
        return interp_eval_ref(
            codes, rows[: 1 << m.lookup_bits], eval_bits=m.eval_bits,
            k=m.k, sq_trunc=m.sq_trunc, lin_trunc=m.lin_trunc,
            degree=m.degree)

    def eval_fused(self, codes, fids, use_kernel: bool = True,
                   interpret: bool | None = None):
        """Fused multi-function evaluation: element i reads table fids[i].

        Serves any mix of uniform (v1) and segmented (v2) slots. An
        all-uniform library keeps the original (F, 5)-meta fast path —
        byte-stable programs for v1 artifacts — while the presence of any
        segmented slot switches the call onto the generalized ROM walk
        (``library_walk``): per-function walk rows plus per-leaf datapath
        rows as kernel operands, same one-hot gathers and fixed-point
        tail, bit-identical per slot to the specialized paths.
        """
        if any(m.seg_depth for m in self.metas):
            from repro.kernels.interp.ops import library_walk

            walk, dp = self.walk_rows()
            return library_walk(codes, fids, self.coeffs, walk, dp,
                                use_kernel=use_kernel, interpret=interpret)
        from repro.kernels.interp.ops import library_eval

        return library_eval(codes, fids, self.coeffs, self.meta_rows(),
                            use_kernel=use_kernel, interpret=interpret)

    # -- persistence (npz coefficients + json manifest) --------------------
    def save(self, path: str | pathlib.Path) -> pathlib.Path:
        """Write the ROM npz + ``<path>.json`` manifest; returns the
        manifest path. A saved library serves with zero exploration.

        A crash mid-save can never tear an artifact — not even a re-save
        over an existing one: the ROM is written to a tmp path and renamed
        to a *content-addressed* name (``<path>.<sha>.npz``, which the
        manifest references), then the manifest is atomically replaced. At
        every instant the on-disk json points at a complete ROM whose
        checksum matches. Superseded ROM files are unlinked only after the
        new manifest is in place (best-effort).
        """
        base = pathlib.Path(path)
        if base.suffix in (".json", ".npz"):
            base = base.with_suffix("")
        base.parent.mkdir(parents=True, exist_ok=True)
        coeffs = np.asarray(self.coeffs, np.int32)
        sha = hashlib.sha256(
            np.ascontiguousarray(coeffs).tobytes()).hexdigest()[:16]
        npz_path = base.parent / f"{base.name}.{sha}.npz"
        tmp_npz = npz_path.with_suffix(".npz.tmp")
        try:
            with open(tmp_npz, "wb") as f:
                np.savez(f, coeffs=coeffs)
            tmp_npz.replace(npz_path)
        finally:
            tmp_npz.unlink(missing_ok=True)
        man = self.manifest()
        man["coeffs_file"] = npz_path.name
        man["coeffs_sha"] = sha
        tmp = base.with_suffix(".json.tmp")
        tmp.write_text(json.dumps(man, indent=1))
        tmp.replace(base.with_suffix(".json"))
        for stale in base.parent.glob(f"{base.name}.*.npz"):
            if stale.name != npz_path.name:
                stale.unlink(missing_ok=True)
        return base.with_suffix(".json")

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "InterpLibrary":
        import jax.numpy as jnp

        base = pathlib.Path(path)
        if base.suffix in (".json", ".npz"):
            base = base.with_suffix("")
        man = json.loads(base.with_suffix(".json").read_text())
        if man.get("version") not in (_FORMAT_VERSION, _FORMAT_VERSION_SEG):
            raise ValueError(f"unsupported library version {man.get('version')}")
        with np.load(base.parent / man["coeffs_file"]) as z:
            coeffs = z["coeffs"].astype(np.int32)
        sha = hashlib.sha256(
            np.ascontiguousarray(coeffs).tobytes()).hexdigest()[:16]
        if man.get("coeffs_sha") and sha != man["coeffs_sha"]:
            raise ValueError(f"corrupt library ROM {base}.npz")
        metas = tuple(_meta_from_dict(f) for f in man["funcs"])
        return cls(jnp.asarray(coeffs), metas).seal(sha)


def load_library(path: str | pathlib.Path) -> InterpLibrary:
    """Module-level convenience: :meth:`InterpLibrary.load`."""
    return InterpLibrary.load(path)


def _flatten_with_keys(lib: InterpLibrary):
    import jax

    return ((jax.tree_util.GetAttrKey("coeffs"), lib.coeffs),), lib.metas


def _flatten(lib: InterpLibrary):
    return (lib.coeffs,), lib.metas


def _unflatten(metas, leaves) -> InterpLibrary:
    return InterpLibrary(leaves[0], metas)


def _register() -> None:
    import jax

    jax.tree_util.register_pytree_with_keys(
        InterpLibrary, _flatten_with_keys, _unflatten, _flatten)


_register()
