"""Exploration results: the full per-R frontier + Pareto extraction.

The seed returned a single best ``GenResult``; serving, benchmarks and
retargeting all want the *frontier* — every feasible LUT height with its
target-units cost — so :class:`DesignSpaceResult` keeps all of it and
derives the answers (best design, Pareto set over (area, delay), minimum
feasible region count) as views.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.core.decision import DecisionReport
from repro.core.pareto import pareto_indices
from repro.core.table import TableDesign


@dataclasses.dataclass
class ExploreEntry:
    """One explored LUT height under one target."""

    design: TableDesign
    report: DecisionReport
    area: float  # target units (NAND2-eq / LUTs / VMEM bytes)
    delay: float  # target units (FO4-ish / LUT levels / product bits)
    runtime_s: float
    objective: Any  # the target's ranking key (lower is better)

    @property
    def lookup_bits(self) -> int:
        return self.design.lookup_bits

    @property
    def area_delay(self) -> float:
        return self.area * self.delay


@dataclasses.dataclass
class DesignSpaceResult:
    """Everything one ``Explorer.explore()`` call learned about a spec."""

    spec_name: str
    target: str
    entries: list[ExploreEntry]  # ascending R, feasible heights only
    min_regions_r: int | None  # smallest R passing Eqns 9-10 (if swept)

    def __bool__(self) -> bool:
        return bool(self.entries)

    def __iter__(self):
        return iter(self.entries)

    def __len__(self) -> int:
        return len(self.entries)

    @property
    def best(self) -> ExploreEntry:
        """Minimal-objective entry (ties: smallest R, i.e. first in sweep)."""
        if not self.entries:
            raise ValueError(f"no feasible design for {self.spec_name} "
                             f"(target {self.target})")
        return min(self.entries, key=lambda e: e.objective)

    def pareto(self) -> list[ExploreEntry]:
        """Non-dominated entries over (area, delay), ascending area.

        Delegates to :func:`repro.core.pareto.pareto_indices` — the same
        frontier logic the DSE study layer uses over its 4-objective
        vectors (DESIGN.md §13)."""
        idx = pareto_indices([(e.area, e.delay) for e in self.entries])
        return [self.entries[i] for i in idx]

    @property
    def minimal_regions(self) -> ExploreEntry | None:
        """The feasible design with the fewest regions (the abstract's
        'minimum number of regions' answer), if any height was feasible."""
        return min(self.entries, key=lambda e: e.lookup_bits) if self.entries else None
