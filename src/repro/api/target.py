"""Hardware targets: decision-procedure ordering + area/delay estimation.

The paper's §III claim — "targeting alternative hardware technologies simply
requires a modified decision procedure to explore the space" — is made
first-class here. A :class:`Target` bundles exactly the two things a
technology contributes:

  * a :class:`~repro.core.decision.DecisionPolicy` — *how* the complete
    space is walked (which §III steps run, lin-vs-quad preference), and
  * an estimator + objective — *what* a finished design costs in that
    technology's units, used to rank the R-sweep.

The region envelopes (§II Eqns 9-10) are target-independent; the Explorer
computes them once per (spec, R) and every registered target explores the
same cached space. Registering a new technology is a ~20-line subclass —
no changes to the core procedure (DESIGN.md §6).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Protocol, runtime_checkable

from repro.core import area as area_model
from repro.core.area import AreaDelay
from repro.core.decision import DecisionPolicy
from repro.core.table import TableDesign


@runtime_checkable
class Target(Protocol):
    """Protocol every hardware technology implements."""

    name: str
    policy: DecisionPolicy

    def estimate(self, design: TableDesign) -> AreaDelay:
        """Cost of a finished design in this technology's (area, delay) units."""
        ...

    def objective(self, design: TableDesign, ad: AreaDelay) -> Any:
        """Ranking key over the R-sweep (lower is better; tuples allowed)."""
        ...


_REGISTRY: Dict[str, Target] = {}


def register_target(name: str):
    """Class/instance decorator adding a Target to the global registry.

    Returns the registered *instance*, so the decorated symbol is the same
    object ``get_target(name)`` resolves to and can itself be passed as a
    target."""

    def deco(obj):
        target = obj() if isinstance(obj, type) else obj
        target.name = name
        _REGISTRY[name] = target
        return target

    return deco


def get_target(target: str | Target) -> Target:
    if isinstance(target, str):
        try:
            return _REGISTRY[target]
        except KeyError:
            raise KeyError(
                f"unknown target {target!r}; registered: {sorted(_REGISTRY)}"
            ) from None
    if isinstance(target, type):  # an unregistered Target class: instantiate
        target = target()
    if not hasattr(target, "name"):  # unregistered ad-hoc target: default it
        target.name = type(target).__name__
    return target


def list_targets() -> list[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Built-in technologies
# ---------------------------------------------------------------------------

@register_target("asic")
class AsicTarget:
    """The paper's target: standard-cell ASIC, square path on the critical
    path. Ordering is §III verbatim (max truncations, then Algorithm 1);
    cost is the bit-operation proxy of core.area (DESIGN.md §7.1)."""

    name = "asic"
    policy = DecisionPolicy()

    def estimate(self, design: TableDesign) -> AreaDelay:
        return area_model.estimate(design)

    def objective(self, design: TableDesign, ad: AreaDelay) -> float:
        return ad.area * ad.delay

    def decoder_estimate(self, n_leaves: int, depth: int) -> AreaDelay:
        """Segment-index decoder: a 2^depth x ceil(log2 S)-bit ROM feeding
        the coefficient LUT address — same cell model as the main ROM plus
        one extra serial lookup level on the critical path."""
        idx_bits = max(n_leaves - 1, 1).bit_length()
        area = 0.25 * (1 << depth) * idx_bits
        delay = 1.0 + 0.35 * depth + 0.2 * math.log2(max(idx_bits, 2.0))
        return AreaDelay(area=area, delay=delay)


@register_target("fpga-lut")
class FpgaLutTarget:
    """LUT-fabric FPGA: everything — ROM and arithmetic — is 6-input LUTs.

    Ordering keeps the truncation steps (fewer partial products = fewer
    logic LUTs), but the ranking is LUT-count-weighted: total LUT count
    first, routed depth only as a tie-breaker, because fabric frequency is
    routing-dominated and far less sensitive to the datapath than an ASIC's.
    """

    name = "fpga-lut"
    policy = DecisionPolicy()

    def estimate(self, design: TableDesign) -> AreaDelay:
        r, w = design.lookup_bits, design.eval_bits
        wa, wb, wc = design.lut_widths
        s = max(w - design.sq_trunc, 0)
        lb = max(w - design.lin_trunc, 0)
        # ROM as distributed LUTRAM: one 6-LUT holds 64x1 bits. Segmented
        # designs carry their (smaller) stored row count in ``rows``.
        rows = int(getattr(design, "rows", 0) or (1 << r))
        rom_luts = (wa + wb + wc) * max(rows // 64, 1)
        # soft multipliers: ~half a LUT per partial-product bit.
        mul_luts = 0.5 * wb * lb
        if design.degree == 2 and s > 0:
            mul_luts += 0.25 * s * s + 0.5 * wa * (2 * s)  # squarer + a-mul
        acc_w = max(wc, wa + 2 * s, wb + lb) + 2
        add_luts = float(acc_w)  # carry chain
        area = rom_luts + mul_luts + add_luts
        # depth in LUT levels (logic only; routing folded into the constant)
        levels = 1.0 + math.log2(max(acc_w, 2.0)) / 2.0
        if design.degree == 2 and s > 0:
            levels += math.log2(max(2 * s, 2.0)) / 2.0
        return AreaDelay(area=area, delay=levels)

    def objective(self, design: TableDesign, ad: AreaDelay) -> tuple:
        return (round(ad.area), ad.delay)

    def decoder_estimate(self, n_leaves: int, depth: int) -> AreaDelay:
        """Segment-index table as LUTRAM plus one extra LUT level of
        address indirection before the coefficient read."""
        idx_bits = max(n_leaves - 1, 1).bit_length()
        luts = idx_bits * max((1 << depth) // 64, 1)
        return AreaDelay(area=float(luts), delay=1.0)


@register_target("pallas-tpu")
class PallasTpuTarget:
    """This repo's serving target: the table evaluated inside Pallas kernels.

    Input truncation buys nothing on a vector unit (lane width is fixed), so
    the policy skips §III steps 2-3 and goes straight to Algorithm 1. Cost is
    what actually constrains the kernels: VMEM footprint of the staged
    coefficient matrix (area axis) and the widest integer product the
    evaluation needs (delay axis) — products past 31 bits force the int64
    jnp fallback path, which the objective penalizes first (DESIGN.md §7.5).
    """

    name = "pallas-tpu"
    policy = DecisionPolicy(maximize_sq_trunc=False, maximize_lin_trunc=False)

    # A segmented slot's packed seg table lives inside the coefficient ROM
    # rows (ROM v2), so the ``rows`` override below already pays its VMEM.
    seg_table_in_rom = True

    def estimate(self, design: TableDesign) -> AreaDelay:
        rows = int(getattr(design, "rows", 0) or (1 << design.lookup_bits))
        wa, wb, _ = design.lut_widths
        w = design.eval_bits
        s = max(w - design.sq_trunc, 0)
        lb = max(w - design.lin_trunc, 0)
        int32_ok = all(m.width <= 31 for m in
                       (design.a_meta, design.b_meta, design.c_meta))
        vmem = rows * 3 * (4 if int32_ok else 8)  # packed coeff bytes
        mult_bits = max(wa + 2 * s, wb + lb, 1)
        return AreaDelay(area=float(vmem), delay=float(mult_bits))

    def objective(self, design: TableDesign, ad: AreaDelay) -> tuple:
        # VMEM bytes first (already 2x when not int32-packable), then width
        return (ad.area, ad.delay)

    def decoder_estimate(self, n_leaves: int, depth: int) -> AreaDelay:
        """VMEM is already counted via ``rows`` (the packed table rides in
        the slot); the marginal cost is the extra one-hot gather contraction,
        whose width scales with the 2^depth cell count."""
        return AreaDelay(area=0.0, delay=float(depth))
