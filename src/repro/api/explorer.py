"""The Explorer session: one object that owns the pool, the envelope cache
and the table persistence layer.

Seed pain points this replaces (ISSUE 1):

  * ``run_decision`` forked a fresh process pool per call — the session owns
    one ``RegionPool`` for its whole lifetime.
  * ``RegionSpace`` envelopes (§II Eqns 9-10) were recomputed per call even
    though they are target-independent — the session computes them at most
    once per (spec, R) and every target / k-value / degree reuses them
    (``envelope_stats`` exposes the compute/hit counters).
  * ``numerics/registry.py`` kept its own disk+memory cache — that cache is
    now the Explorer's persistence layer (``get_table``).

Since ISSUE 2 the per-region §II work routes through the batched region
engine by default (``ExploreConfig.engine``): envelopes, feasibility and
the decision-procedure truncation re-checks run as one array program over
all ``2^R`` regions (``core.batched`` / the ``kernels.dspace`` Pallas
backend), the envelope cache is LRU-bounded, and ``min_regions`` exploits
feasibility monotonicity in R (exponential descent + binary search)
instead of linearly scanning from the most expensive probe. DESIGN.md §9.

Typical use::

    with Explorer(ExploreConfig(kind="recip", bits=12)) as ex:
        asic = ex.explore(target="asic").best
        tpu = ex.explore(target="pallas-tpu").best   # same envelopes, re-decided

See DESIGN.md §6 for the architecture.
"""
from __future__ import annotations

import collections
import hashlib
import json
import re
import threading
import time

from repro.api.config import DEFAULTS, ENGINES, ExploreConfig, spec_for
from repro.api.library import DEFAULT_LIBRARY_KINDS, InterpLibrary
from repro.core.funcspec import ACT_HI, ACT_LO
from repro.api.result import DesignSpaceResult, ExploreEntry
from repro.api.target import Target, get_target
from repro.core import batched, fleet
from repro.core.decision import _run_decision_pooled
from repro.core.designspace import RegionSpace, compute_spaces
from repro.core.funcspec import FunctionSpec
from repro.core.pmap import RegionPool
from repro.core.table import TableDesign


class _MinRSearch:
    """State machine of the min-R search (exponential descent from the cheap
    end + binary bracket), factored out of :meth:`Explorer.min_regions` so
    the fleet path can lockstep many searches: each round collects one
    pending (spec, R) probe per live search and answers the whole frontier
    as one stacked array program. Probe sequences — and therefore results
    and cache traffic — are identical to the serial search.
    """

    _WORK_CAP = 1 << 26  # element-work floor where stepping turns costly

    def __init__(self, spec: FunctionSpec, r_max: int | None = None):
        self.spec = spec
        # R > in_bits doesn't exist; a larger r_max must behave like
        # "unbounded", not crash
        self.r_max = spec.in_bits if r_max is None else min(r_max, spec.in_bits)
        self.result: int | None = None
        self.done = self.r_max < 0
        self.hi = self.r_max  # known feasible once init passes
        self.lo = -1  # known infeasible
        self.step = 1
        self.phase = "init"

    def _probe_work(self, r: int) -> int:
        return 4 ** self.spec.in_bits >> max(r, 0)  # ~ 2^R regions x N^2

    def next_probe(self) -> int | None:
        if self.done:
            return None
        if self.phase == "init":
            return self.r_max
        if self.phase == "gallop":
            return max(self.hi - self.step, self.lo + 1)
        return (self.lo + self.hi) // 2  # binary

    def _settle(self) -> None:
        if self.hi - self.lo <= 1:
            self.done = True
            self.result = self.hi

    def feed(self, ok: bool) -> None:
        """Consume the verdict for the probe ``next_probe()`` returned."""
        if self.phase == "init":
            if not ok:  # monotone: nothing below r_max can work either
                self.done = True
                return
            self.phase = "gallop"
            self._settle()
            return
        if self.phase == "gallop":
            if ok:
                self.hi = max(self.hi - self.step, self.lo + 1)
                nxt = max(self.hi - 2 * self.step, self.lo + 1)
                self.step = (2 * self.step
                             if self._probe_work(nxt) <= self._WORK_CAP else 1)
            else:
                self.lo = max(self.hi - self.step, self.lo + 1)
                self.phase = "binary"
            self._settle()
            return
        mid = (self.lo + self.hi) // 2
        if ok:
            self.hi = mid
        else:
            self.lo = mid
        self._settle()


class Explorer:
    """A design-space exploration session.

    Cheap to construct; the worker pool (when ``config.workers > 1``) starts
    lazily on first use and is released by ``close()`` / context exit. All
    caches are per-session except the table disk cache, which is shared
    through ``config.cache_dir``. Table fetches, the envelope cache and the
    pool lifecycle are lock-guarded, so concurrent threads can share one
    session (envelope computation serializes; decision runs don't).
    """

    def __init__(self, config: ExploreConfig | None = None,
                 *, target: str | Target = "asic"):
        self.config = config or ExploreConfig()
        if self.config.engine not in ENGINES:
            raise ValueError(f"unknown engine {self.config.engine!r}; "
                             f"expected one of {ENGINES}")
        self.default_target = target
        self._pool: RegionPool | None = None
        self._spaces: collections.OrderedDict[tuple, list[RegionSpace]] = \
            collections.OrderedDict()
        self._space_computes = 0
        self._space_hits = 0
        self._space_evictions = 0
        self._feasible: collections.OrderedDict[tuple, bool] = \
            collections.OrderedDict()
        self._feas_computes = 0
        self._feas_hits = 0
        self._feas_evictions = 0
        self._bounds: dict[tuple, tuple] = {}  # spec value-key -> (lo, hi)
        self._spec_keys: dict[int, tuple] = {}
        self._spec_refs: dict[int, FunctionSpec] = {}
        self._tables: dict[str, TableDesign] = {}
        self._lock = threading.Lock()  # table cache
        # envelope cache / pool lifecycle / spec-key memo; RLock because
        # envelopes() -> _get_pool() nests (lock order: _lock before _l)
        self._state_lock = threading.RLock()

    # -- lifecycle ---------------------------------------------------------
    def __enter__(self) -> "Explorer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        with self._state_lock:
            if self._pool is not None:
                self._pool.__exit__()
                self._pool = None

    def _get_pool(self) -> RegionPool:
        with self._state_lock:
            if self._pool is None:
                self._pool = RegionPool(self.config.workers)
                self._pool.__enter__()
            return self._pool

    # -- envelope cache ----------------------------------------------------
    @property
    def envelope_stats(self) -> dict[str, int]:
        """{'computed': n, 'hits': m, 'evictions': e} — asserts the
        once-per-(spec, R) contract and the LRU bound in tests."""
        return {"computed": self._space_computes, "hits": self._space_hits,
                "evictions": self._space_evictions}

    _FEAS_CACHE_CAP = 4096  # boolean feasibility verdicts kept (LRU)

    @property
    def feasible_stats(self) -> dict[str, int]:
        """{'computed', 'hits', 'evictions'} of the boolean feasibility-
        verdict LRU (min-R probes; shared with the fleet engine's bulk
        probes) — same contract as ``envelope_stats``."""
        return {"computed": self._feas_computes, "hits": self._feas_hits,
                "evictions": self._feas_evictions}

    def _feasible_get(self, fkey: tuple) -> bool | None:
        """LRU lookup + hit accounting; call with _state_lock held."""
        ok = self._feasible.get(fkey)
        if ok is not None:
            self._feasible.move_to_end(fkey)
            self._feas_hits += 1
        return ok

    def _feasible_put(self, fkey: tuple, ok: bool) -> None:
        """LRU insert + eviction accounting; call with _state_lock held."""
        self._feasible[fkey] = ok
        self._feas_computes += 1
        while len(self._feasible) > self._FEAS_CACHE_CAP:
            self._feasible.popitem(last=False)
            self._feas_evictions += 1

    _SPEC_MEMO_CAP = 1024  # id-keyed memo entries before a wholesale reset

    def _spec_key(self, spec: FunctionSpec) -> tuple:
        """Value-identity for a spec: name/widths/ulp + bound fingerprint
        (names don't capture kwargs like sigmoid's input range).

        The id-keyed memo avoids re-hashing bounds for a spec object used
        across many calls; it pins the spec (id() must stay unique) and is
        reset at a size cap so a long-lived session fed a fresh spec object
        per request cannot grow without bound — the value key, and thus the
        envelope cache, is unaffected by a reset."""
        key = self._spec_keys.get(id(spec))
        if key is None:
            lo, hi = spec.bound_arrays()
            digest = hashlib.sha1(lo.tobytes() + hi.tobytes()).hexdigest()[:16]
            key = (spec.name, spec.in_bits, spec.out_bits, spec.ulp, digest)
            if len(self._spec_keys) >= self._SPEC_MEMO_CAP:
                self._spec_keys.clear()
                self._spec_refs.clear()
            self._spec_keys[id(spec)] = key
            self._spec_refs[id(spec)] = spec
            if len(self._bounds) >= 64:  # a few MB per spec at 16 bits
                self._bounds.clear()
            self._bounds.setdefault(key, (lo, hi))
        return key

    def _region_bounds(self, spec: FunctionSpec, lookup_bits: int):
        """``spec.region_bounds`` through a per-spec cache: the exact
        (rational-arithmetic) bound construction is paid once per spec, not
        once per probed R — min-R probes sweep many R over one spec."""
        key = self._spec_key(spec)
        arrs = self._bounds.get(key)
        if arrs is None:
            arrs = spec.bound_arrays()
            self._bounds[key] = arrs
        lo, hi = arrs
        r = 1 << lookup_bits
        return lo.reshape(r, -1), hi.reshape(r, -1)

    def _cached_spaces(self, key: tuple):
        """LRU lookup + hit accounting; call with _state_lock held."""
        spaces = self._spaces.get(key)
        if spaces is not None:
            self._spaces.move_to_end(key)
            self._space_hits += 1
        return spaces

    def _space_key(self, spec: FunctionSpec, lookup_bits: int, impl: str,
                   engine: str) -> tuple:
        # the batched engines do not consult `impl` (their searches are
        # value-identical to every IMPLS entry), so all impls share one entry
        return (*self._spec_key(spec), lookup_bits, engine,
                impl if engine == "pooled" else "-")

    def envelopes(self, spec: FunctionSpec, lookup_bits: int,
                  impl: str | None = None, engine: str | None = None
                  ) -> list[RegionSpace]:
        """Per-region §II envelopes — computed at most once per (spec, R),
        LRU-bounded at ``config.envelope_cache`` entries."""
        impl = impl or self.config.impl
        engine = engine or self.config.engine
        with self._state_lock:
            key = self._space_key(spec, lookup_bits, impl, engine)
            spaces = self._cached_spaces(key)
            if spaces is not None:
                return spaces
            L, U = self._region_bounds(spec, lookup_bits)
            spaces = compute_spaces(
                L, U, impl, engine,
                pool=self._get_pool() if engine == "pooled" else None)
            self._spaces[key] = spaces
            self._space_computes += 1
            cap = self.config.envelope_cache
            while cap is not None and len(self._spaces) > max(cap, 1):
                self._spaces.popitem(last=False)
                self._space_evictions += 1
            return spaces

    def _envelopes_fleet(self, pairs: list[tuple[FunctionSpec, int]]
                         ) -> list[list[RegionSpace]]:
        """Bulk twin of :meth:`envelopes` for the fleet paths: every missing
        (spec, R) of ``pairs`` is computed as one stacked array program
        (grouped by row width) and primed into the envelope LRU with the
        same accounting. Returns the spaces aligned with ``pairs``.

        With ``config.mesh > 1`` the stack runs on the float32 device
        program instead; those spaces are returned for the caller's
        immediate (re-verified) use but are NEVER primed into the cache —
        the exact batched engine's keys must keep answering with exact
        float64 verdicts, exactly as the ``pallas`` engine keeps its own.
        """
        impl, engine = self.config.impl, "batched"
        sharded = bool(self.config.mesh and self.config.mesh > 1)
        with self._state_lock:
            out: list = [None] * len(pairs)
            missing = []
            for i, (spec, r) in enumerate(pairs):
                spaces = self._cached_spaces(
                    self._space_key(spec, r, impl, engine))
                if spaces is None:
                    missing.append(i)
                else:
                    out[i] = spaces
            if missing:
                computed = fleet.fleet_region_spaces(
                    [self._region_bounds(*pairs[i]) for i in missing],
                    shards=self.config.mesh)
                cap = self.config.envelope_cache
                for i, spaces in zip(missing, computed):
                    out[i] = spaces
                    if sharded:
                        continue
                    spec, r = pairs[i]
                    self._spaces[self._space_key(spec, r, impl, engine)] = spaces
                    self._space_computes += 1
                    while cap is not None and len(self._spaces) > max(cap, 1):
                        self._spaces.popitem(last=False)
                        self._space_evictions += 1
            return out

    def prime_envelopes(self, pairs) -> None:
        """Bulk-prime the envelope cache for many (spec, lookup_bits) pairs
        as one fleet program — the batch-probe entry point the DSE study
        layer uses before walking its trials serially off the warm cache.

        No-op (the per-pair path will compute lazily) when the fleet is
        disabled, the engine isn't ``batched``, or ``mesh > 1`` (sharded
        f32 spaces never enter the exact engine's cache — see
        :meth:`_envelopes_fleet`).
        """
        if not (self.config.fleet and self.config.engine == "batched"):
            return
        if self.config.mesh and self.config.mesh > 1:
            return
        uniq, seen = [], set()
        for spec, r in pairs:
            key = (*self._spec_key(spec), r)
            if key not in seen:
                seen.add(key)
                uniq.append((spec, r))
        if uniq:
            self._envelopes_fleet(uniq)

    def feasible(self, spec: FunctionSpec, lookup_bits: int,
                 impl: str | None = None, engine: str | None = None) -> bool:
        """Eqns 9-10 over every region: does ANY piecewise quadratic exist?

        Under the batched engine this uses a lightweight all-regions verdict
        (no RegionSpace materialization) with its own boolean cache, so min-R
        probes don't churn the envelope LRU; cached envelopes are reused when
        present. The pooled and pallas engines answer from their own
        RegionSpaces — the verdict must come from the same arithmetic
        ``explore_r`` will judge with (the float32 pallas envelopes can
        disagree with the exact mask on marginal specs).
        """
        impl = impl or self.config.impl
        engine = engine or self.config.engine
        if engine != "batched":
            return all(s.feasible
                       for s in self.envelopes(spec, lookup_bits, impl, engine))
        with self._state_lock:
            spaces = self._cached_spaces(
                self._space_key(spec, lookup_bits, impl, engine))
            if spaces is not None:
                return all(s.feasible for s in spaces)
            fkey = (*self._spec_key(spec), lookup_bits)
            ok = self._feasible_get(fkey)
            if ok is None:
                L, U = self._region_bounds(spec, lookup_bits)
                ok = bool(batched.regions_feasible_mask(L, U).all())
                self._feasible_put(fkey, ok)
            return ok

    def min_regions(self, spec: FunctionSpec, r_max: int | None = None,
                    impl: str | None = None, engine: str | None = None
                    ) -> int | None:
        """Smallest feasible R — the paper's 'minimum number of regions'.

        Splitting a region leaves each half with a subset of the parent's
        constraints, so feasibility is monotone in R and the linear scan of
        the seed is wasteful twice over: it probes every R, and it starts at
        the *expensive* end (a probe at R costs O(4^in_bits / 2^R) element
        work, so R=0 is the worst probe in the whole sweep). This descends
        from ``r_max`` (cheap end) with exponentially growing steps while
        probes stay overhead-bound, dropping to single steps once element
        work dominates (each level down already quadruples the probe cost,
        so the *cost* keeps galloping and overshoot stays bounded), then
        binary-searches the final bracket. Any correct search must probe
        both min_R and min_R - 1; this pays O(1) such probes beyond them.
        Probes reuse cached envelopes/verdicts. The search itself lives in
        :class:`_MinRSearch`; :meth:`min_regions_many` locksteps it over a
        whole manifest through the fleet engine.
        """
        search = _MinRSearch(spec, r_max)
        while (r := search.next_probe()) is not None:
            search.feed(self.feasible(spec, r, impl, engine))
        return search.result

    def _feasible_cached(self, spec: FunctionSpec, lookup_bits: int
                         ) -> bool | None:
        """Cached-only feasibility verdict (spaces cache, then the boolean
        LRU) — the fleet paths consult this before bulk-probing."""
        with self._state_lock:
            spaces = self._cached_spaces(
                self._space_key(spec, lookup_bits, self.config.impl, "batched"))
            if spaces is not None:
                return all(s.feasible for s in spaces)
            return self._feasible_get((*self._spec_key(spec), lookup_bits))

    def min_regions_many(self, specs, r_max: int | None = None,
                         impl: str | None = None, engine: str | None = None
                         ) -> list[int | None]:
        """Fleet min-R: the monotone search for MANY specs in lockstep.

        Each round gathers every live search's next (spec, R) probe and
        answers the whole frontier with one stacked array program
        (``fleet.fleet_feasible_mask``) — a manifest's worth of min-R
        queries costs a handful of dispatches instead of F x R serial
        probes. Probe sequences per spec are identical to
        :meth:`min_regions` (same state machine), verdicts land in the same
        feasibility LRU, and results are bit-identical.
        """
        engine = engine or self.config.engine
        specs = list(specs)
        if not (self.config.fleet and engine == "batched") or len(specs) <= 1:
            return [self.min_regions(s, r_max, impl, engine) for s in specs]
        searches = [_MinRSearch(s, r_max) for s in specs]
        while True:
            pending: list[tuple[_MinRSearch, int]] = []
            for s in searches:
                while not s.done:
                    r = s.next_probe()
                    ok = self._feasible_cached(s.spec, r)
                    if ok is None:
                        pending.append((s, r))
                        break
                    s.feed(ok)
            if not pending:
                return [s.result for s in searches]
            mask = fleet.fleet_feasible_mask(
                [self._region_bounds(s.spec, r) for s, r in pending])
            with self._state_lock:
                for (s, r), ok in zip(pending, mask):
                    self._feasible_put((*self._spec_key(s.spec), r), bool(ok))
            for (s, _), ok in zip(pending, mask):
                s.feed(bool(ok))

    # -- exploration -------------------------------------------------------
    def explore_r(self, spec: FunctionSpec, lookup_bits: int,
                  target: str | Target | None = None,
                  degree: int | None = None, impl: str | None = None,
                  engine: str | None = None) -> ExploreEntry | None:
        """Run one target's decision procedure at a fixed LUT height."""
        tgt = get_target(target if target is not None else self.default_target)
        impl = impl or self.config.impl
        engine = engine or self.config.engine
        degree = degree if degree is not None else self.config.degree
        t0 = time.perf_counter()
        spaces = self.envelopes(spec, lookup_bits, impl, engine)
        if not all(s.feasible for s in spaces):
            return None
        k_max = (self.config.k_max if self.config.k_max is not None
                 else tgt.policy.k_max)
        out = _run_decision_pooled(
            spec, lookup_bits, degree, impl, k_max,
            self._get_pool() if engine == "pooled" else None,
            spaces=spaces, policy=tgt.policy, engine=engine,
            bounds=self._region_bounds(spec, lookup_bits))
        if out is None:
            return None
        design, report = out
        ad = tgt.estimate(design)
        return ExploreEntry(design, report, ad.area, ad.delay,
                            time.perf_counter() - t0,
                            tgt.objective(design, ad))

    def explore(self, spec: FunctionSpec | None = None,
                *, target: str | Target | None = None,
                lookup_bits: int | None = None,
                r_lo: int | None = None, r_hi: int | None = None,
                degree: int | None = None, impl: str | None = None,
                engine: str | None = None) -> DesignSpaceResult:
        """Sweep LUT heights under one target; returns the full frontier.

        Defaults come from the session config: a fixed ``lookup_bits`` if
        set, else ``[r_lo, r_hi]``, else [minimum feasible R, +6]. Swapping
        ``target`` re-decides over the *cached* envelopes — no regeneration.
        """
        spec = spec if spec is not None else self.config.spec()
        tgt = get_target(target if target is not None else self.default_target)
        degree = degree if degree is not None else self.config.degree
        if lookup_bits is None and r_lo is None and r_hi is None:
            # a per-call sweep request overrides a config-pinned height
            lookup_bits = self.config.lookup_bits
        min_r: int | None = None
        if lookup_bits is not None:
            heights = [lookup_bits]
        else:
            r_lo = r_lo if r_lo is not None else self.config.r_lo
            if r_lo is None:
                r_lo = min_r = self.min_regions(spec, impl=impl, engine=engine)
                if r_lo is None:
                    return DesignSpaceResult(spec.name, tgt.name, [], None)
            r_hi = r_hi if r_hi is not None else self.config.r_hi
            if r_hi is None:
                r_hi = min(spec.in_bits, r_lo + 6)
            heights = list(range(r_lo, r_hi + 1))
        # fleet path: prime every height's envelopes in one stacked program
        # (each height its own width group — no cross-height pad work) so the
        # per-R explore loop below runs entirely off the cache. Skipped under
        # mesh > 1: f32 device spaces never enter the exact engine's cache,
        # so priming would just duplicate the per-R exact computation.
        if (self.config.fleet and len(heights) > 1 and impl is None
                and (engine or self.config.engine) == "batched"
                and not (self.config.mesh and self.config.mesh > 1)):
            self._envelopes_fleet([(spec, r) for r in heights])
        entries = []
        for r in heights:
            e = self.explore_r(spec, r, tgt, degree, impl, engine)
            if e is not None:
                entries.append(e)
        return DesignSpaceResult(spec.name, tgt.name, entries, min_r)

    # -- table persistence (absorbed from numerics/registry) ---------------
    def _table_request(self, kind: str, bits: int | None,
                       lookup_bits: int | None, degree: int | None,
                       tgt: Target, kw: dict) -> tuple[str, int, int, int | None]:
        """Resolve one table request against the registry defaults; returns
        ``(cache key, bits, lookup_bits, degree)``. Shared by
        :meth:`get_table` and the fleet compile path so both produce the
        same artifacts under the same keys."""
        d_bits, _, d_r = DEFAULTS[kind]
        bits = bits if bits is not None else d_bits
        r = lookup_bits if lookup_bits is not None else d_r
        # resolve the session default now so the cache key names the degree
        # the design is actually generated with
        degree = degree if degree is not None else self.config.degree
        key = f"{kind}_{bits}b_R{r}_d{degree or 0}"
        if tgt.name != "asic":
            key += f"_{tgt.name}"
        if kw:  # spec overrides (ulp, out_bits, ...) change the artifact
            raw = "_".join(f"{k}{kw[k]}" for k in sorted(kw))
            key += "_" + re.sub(r"[^\w.\-]", "", raw)
        return key, bits, r, degree

    def _table_store(self, key: str, design: TableDesign) -> None:
        """Persist a verified design under ``key`` (tmp + atomic rename) and
        memoize it; call with ``self._lock`` held."""
        cache_dir = self.config.resolved_cache_dir()
        cache_dir.mkdir(parents=True, exist_ok=True)
        path = cache_dir / f"{key}.json"
        tmp = path.with_suffix(".tmp")
        tmp.write_text(design.to_json())
        tmp.replace(path)
        self._tables[key] = design

    def get_table(self, kind: str, bits: int | None = None,
                  lookup_bits: int | None = None, degree: int | None = None,
                  target: str | Target | None = None, **kw) -> TableDesign:
        """Fetch (generating + verifying if needed) a cached table artifact.

        Disk layout and key format are the seed registry's, so existing
        ``artifacts/tables`` caches stay valid; non-default targets get a
        suffixed key.
        """
        tgt = get_target(target if target is not None else self.default_target)
        key, bits, r, degree = self._table_request(kind, bits, lookup_bits,
                                                   degree, tgt, kw)
        with self._lock:
            if key in self._tables:
                return self._tables[key]
            cache_dir = self.config.resolved_cache_dir()
            path = cache_dir / f"{key}.json"
            if path.exists():
                design = TableDesign.from_dict(json.loads(path.read_text()))
                self._tables[key] = design
                return design
            spec = spec_for(kind, bits, **kw)
            entry = None
            for r_try in range(r, min(bits, r + 4) + 1):
                entry = self.explore_r(spec, r_try, tgt, degree)
                if entry is not None:
                    break
            if entry is None:
                raise ValueError(f"no feasible table for {key}")
            ok, worst = entry.design.verify(spec)
            assert ok, f"unverified table {key}: worst={worst}"
            self._table_store(key, entry.design)
            return entry.design

    # -- compiled libraries (the runtime-side artifact) --------------------
    def compile(self, kinds=None, *, target: str | Target | None = None,
                **table_kw) -> InterpLibrary:
        """Compile a set of certified tables into one :class:`InterpLibrary`.

        ``kinds`` is an iterable of registry kind names or ``(kind, kwargs)``
        pairs (kwargs forwarded to :meth:`get_table` — bits, lookup_bits,
        ulp...); ``None`` compiles :data:`DEFAULT_LIBRARY_KINDS`, the full
        manifest of tables the interp numerics backend can touch. Each table
        comes through the session's persistence layer, so a warm cache makes
        this a pure pack step; a cold one generates + verifies once and the
        resulting artifact can be ``save``d so serving never explores again.

        Under the fleet engine (``config.fleet``, batched sessions) a cold
        compile stacks every cache-missing (kind, spec, R) probe into one
        array program and runs the decision procedures in lockstep
        (``core.fleet``) — bit-identical designs to the serial per-kind
        path, a handful of dispatches instead of F x R serial probes.
        """
        items: list[tuple[str, dict]] = []
        for it in (DEFAULT_LIBRARY_KINDS if kinds is None else kinds):
            if isinstance(it, str):
                items.append((it, dict(table_kw)))
            else:
                kind, kw = it
                items.append((kind, {**table_kw, **dict(kw)}))
        if self.config.fleet and self.config.engine == "batched":
            designs = self._tables_fleet(items, target)
        else:
            designs = [self.get_table(kind, target=target, **kw)
                       for kind, kw in items]
        # non-default activation windows (lo/hi spec kwargs) must reach the
        # metadata, or the library-bound glue would quantize over the wrong
        # input range
        windows = {kind: (kw.get("lo", ACT_LO), kw.get("hi", ACT_HI))
                   for kind, kw in items if "lo" in kw or "hi" in kw}
        return InterpLibrary.from_designs(designs, [k for k, _ in items],
                                          act_windows=windows)

    def compile_segmented(self, kinds=None, *,
                          segment=None, target: str | Target | None = None,
                          **table_kw) -> InterpLibrary:
        """:meth:`compile`, with non-uniform (ROM v2) slots where they pay.

        ``segment`` names the kinds to try the greedy dyadic segmenter on
        (``None`` = every compiled kind). Each candidate kind is segmented
        with its uniform design's R as the depth cap and swapped in only
        when it stores *strictly fewer* ROM rows (per-leaf coefficients +
        packed segment table) than the uniform 2^R — accuracy is identical
        by construction, since both verify against the same §II envelope.
        Kinds the segmenter cannot improve keep their uniform slot, so the
        resulting library is never worse than :meth:`compile`'s.
        """
        from repro.segment import explore_segmented

        items: list[tuple[str, dict]] = []
        for it in (DEFAULT_LIBRARY_KINDS if kinds is None else kinds):
            if isinstance(it, str):
                items.append((it, dict(table_kw)))
            else:
                kind, kw = it
                items.append((kind, {**table_kw, **dict(kw)}))
        seg_set = set(segment if segment is not None
                      else [k for k, _ in items])
        designs: list = []
        for kind, kw in items:
            kw = dict(kw)
            uni = self.get_table(kind, target=target, **kw)
            if kind in seg_set:
                bits = kw.pop("bits", None)
                kw.pop("lookup_bits", None)
                degree = kw.pop("degree", None)
                spec = spec_for(kind, bits, **kw)
                sd = explore_segmented(spec, max_depth=uni.lookup_bits,
                                       degree=degree,
                                       engine=self.config.engine)
                if sd is not None and sd.rows_used < (1 << uni.lookup_bits):
                    designs.append(sd)
                    continue
            designs.append(uni)
        windows = {kind: (kw["lo"], kw["hi"])
                   for kind, kw in items if "lo" in kw or "hi" in kw}
        return InterpLibrary.from_designs(designs, [k for k, _ in items],
                                          act_windows=windows)

    def _tables_fleet(self, items: list[tuple[str, dict]],
                      target: str | Target | None) -> list[TableDesign]:
        """Fleet twin of ``[self.get_table(kind, **kw) for ...]``.

        Warm keys (memory or disk) load exactly as :meth:`get_table` would;
        the cache-missing remainder is grouped by probe shape + degree, its
        envelopes computed as one stacked program (priming the envelope
        LRU), and each group's decision procedures run in lockstep with
        shared array work (``fleet.fleet_decisions`` — bit-identical per
        kind to the serial path). Results persist under the same disk keys.
        A kind the lockstep finds infeasible at its requested R falls back
        to :meth:`get_table`, which owns the R-retry ladder.
        """
        tgt = get_target(target if target is not None else self.default_target)
        reqs = []
        for kind, kw in items:
            kw = dict(kw)
            bits = kw.pop("bits", None)
            r = kw.pop("lookup_bits", None)
            dg = kw.pop("degree", None)
            key, bits, r, dg = self._table_request(kind, bits, r, dg, tgt, kw)
            reqs.append((kind, kw, key, bits, r, dg))
        designs: dict[int, TableDesign] = {}
        missing: list[int] = []
        with self._lock:
            for idx, (kind, kw, key, bits, r, dg) in enumerate(reqs):
                if key in self._tables:
                    designs[idx] = self._tables[key]
                    continue
                path = self.config.resolved_cache_dir() / f"{key}.json"
                if path.exists():
                    design = TableDesign.from_dict(json.loads(path.read_text()))
                    self._tables[key] = design
                    designs[idx] = design
                    continue
                missing.append(idx)
        # group cold probes by (shape, degree): one lockstep decision each
        groups: dict[tuple, list[tuple[int, FunctionSpec]]] = {}
        for idx in missing:
            kind, kw, key, bits, r, dg = reqs[idx]
            spec = spec_for(kind, bits, **kw)
            groups.setdefault(
                (r, spec.in_bits - r, dg), []).append((idx, spec))
        k_max = self.config.k_max  # None defers to the target policy's cap
        for (r, _, dg), members in groups.items():
            specs = [spec for _, spec in members]
            bounds = [self._region_bounds(spec, r) for spec in specs]
            spaces = self._envelopes_fleet([(spec, r) for spec in specs])
            results = fleet.fleet_decisions(
                specs, r, bounds, spaces, degree=dg, policy=tgt.policy,
                k_max=k_max if k_max is not None else tgt.policy.k_max)
            for (idx, spec), res in zip(members, results):
                kind, kw, key, bits, _, dg = reqs[idx]
                if res is None:  # rare: get_table owns the R-retry ladder
                    designs[idx] = self.get_table(kind, bits=bits,
                                                  lookup_bits=r, degree=dg,
                                                  target=tgt, **kw)
                    continue
                design, _report = res  # finalize_design already verified it
                with self._lock:
                    self._table_store(key, design)
                designs[idx] = design
        return [designs[i] for i in range(len(items))]


# ---------------------------------------------------------------------------
# Default session: what the deprecation shims and the serving stack use
# ---------------------------------------------------------------------------

_default: Explorer | None = None
_default_lock = threading.Lock()


def default_explorer() -> Explorer:
    """Process-wide Explorer used by ``repro.api.get_table`` and the legacy
    ``generate_table`` / ``sweep_lub`` / ``registry.get_table`` shims."""
    global _default
    with _default_lock:
        if _default is None:
            _default = Explorer()
        return _default


def set_default_explorer(explorer: Explorer) -> None:
    """Install ``explorer`` as the process-wide default session.

    Everything that resolves tables lazily (the numerics backends inside
    jitted model code, the legacy shims) goes through ``default_explorer()``;
    installing a configured session here is how a caller points all of it at
    one cache dir / worker pool."""
    global _default
    with _default_lock:
        _default = explorer


def get_table(kind: str, bits: int | None = None, lookup_bits: int | None = None,
              degree: int | None = None, **kw) -> TableDesign:
    """Module-level convenience: ``default_explorer().get_table(...)``."""
    return default_explorer().get_table(kind, bits, lookup_bits, degree, **kw)


def explore(spec: FunctionSpec | None = None, **kw) -> DesignSpaceResult:
    """Module-level convenience: ``default_explorer().explore(...)``."""
    return default_explorer().explore(spec, **kw)
