"""Frozen exploration configuration + the per-kind default table.

``ExploreConfig`` replaces the per-function keyword soup (``impl`` /
``degree`` / ``processes`` / ``lookup_bits`` threaded through every call in
the seed) with one frozen, hashable session configuration. ``DEFAULTS`` is
the single source of truth for the ML-numerics kinds' widths and lookup
bits — ``repro.numerics.registry`` re-exports it instead of carrying its own
copy (DESIGN.md §7.5).
"""
from __future__ import annotations

import dataclasses
import os
import pathlib

from repro.core.funcspec import FunctionSpec, get_spec

# Single source of truth for the divided-difference search implementation
# (core.searches.IMPLS) and the region-engine backend. Core modules resolve
# their ``impl=None`` / ``engine=None`` defaults against these lazily, so the
# whole pipeline is retuned from one place.
DEFAULT_IMPL = "hull"
DEFAULT_ENGINE = "batched"

# engine -> how the per-region §II work (envelopes, Eqns 9-10 feasibility,
# a-intervals, truncation re-checks) is dispatched:
#   batched  one numpy array program over stacked (regions, N) arrays
#   pallas   one pallas_call + on-device parity merge / a-interval reduction
#            (compiled on TPU, interpret elsewhere; float32 envelopes)
#   pooled   the seed's per-region scalar dispatch through RegionPool —
#            kept as fallback and as the equivalence oracle in tests
ENGINES = ("batched", "pallas", "pooled")

# Envelope-cache LRU cap (entries, one per (spec, R, engine)); None = unbounded.
DEFAULT_ENVELOPE_CACHE = 64

# Fleet engine default: stack every (kind, spec, R) probe a manifest needs
# into one array program (core.fleet) instead of F x R serial probes. Only
# the batched engine routes through it (the fleet is bit-identical to that
# engine; pooled/pallas sessions keep their per-spec dispatch).
DEFAULT_FLEET = True

# kind -> (in_bits, spec kwargs, lookup_bits). Widths are chosen so every
# coefficient fits int32 and the one-hot LUT contraction is exact in fp32.
DEFAULTS: dict[str, tuple[int, dict, int]] = {
    "exp2neg": (12, {"out_bits": 13}, 6),
    "recip": (12, {}, 6),
    "rsqrt": (12, {"out_bits": 13}, 6),
    "silu": (12, {"out_bits": 12}, 6),
    "sigmoid": (12, {"out_bits": 12}, 6),
    "softplus": (12, {"out_bits": 12}, 6),
    "gelu": (12, {"out_bits": 12}, 6),
    "tanh": (12, {"out_bits": 12}, 6),
    "log2": (12, {"out_bits": 13}, 6),
    "exp2": (12, {"out_bits": 12}, 6),
}


def default_cache_dir() -> pathlib.Path:
    return pathlib.Path(
        os.environ.get(
            "REPRO_TABLE_CACHE",
            pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "tables",
        )
    )


def spec_for(kind: str, bits: int | None = None, **kw) -> FunctionSpec:
    """Build a FunctionSpec for ``kind`` with the registry defaults merged in."""
    d_bits, d_kw, _ = DEFAULTS[kind]
    merged = dict(d_kw)
    merged.update(kw)
    return get_spec(kind, bits if bits is not None else d_bits, **merged)


@dataclasses.dataclass(frozen=True)
class ExploreConfig:
    """Session-wide exploration parameters (all optional, all overridable
    per-call on :class:`repro.api.Explorer` methods).

    Attributes:
      kind/bits/out_bits/ulp: the function spec, resolved through
        :data:`DEFAULTS` (``spec()`` builds the FunctionSpec).
      degree: force degree 1/2; None = the target policy's lin-vs-quad rule.
      lookup_bits: fixed R; None = sweep ``[r_lo, r_hi]`` (a per-call
        ``r_lo``/``r_hi`` on ``explore()`` overrides a pinned height).
      r_lo/r_hi: sweep range; None = minimum feasible R and ``r_lo + 6``.
      impl: divided-difference search implementation (core.searches.IMPLS);
        only exercised by the ``pooled`` engine — the batched engines carry
        their own (value-identical) searches.
      engine: region-engine backend, one of :data:`ENGINES`.
      fleet: route ``compile()`` / ``min_regions_many`` / sweep envelope
        priming through the fleet engine (``core.fleet``): every (kind,
        spec, R) probe of a manifest stacked into one array program,
        bit-identical to the serial batched path (which remains the
        equivalence oracle). Ignored unless ``engine == "batched"``.
      mesh: device count to shard the fleet's §II front half over
        (``kernels/dspace`` ``shard_map`` grid over (probe, region); capped
        at the local device count). ``None``/1 keeps the exact single-host
        numpy program; > 1 switches that front half to float32 device
        arithmetic — same contract as ``engine="pallas"``: a marginal
        feasibility verdict can cost a retry, never an unsound artifact.
      envelope_cache: LRU cap on cached (spec, R) RegionSpace lists; None
        disables eviction (evictions are counted in ``envelope_stats``).
      k_max: precision-slack search cap of decision step 1; None defers to
        the target policy's cap.
      workers: RegionPool process count (None/1 = in-process); only the
        ``pooled`` engine forks.
      cache_dir: table persistence directory; None = $REPRO_TABLE_CACHE or
        ``artifacts/tables``.
    """

    kind: str = "recip"
    bits: int | None = None
    out_bits: int | None = None
    ulp: float = 1.0
    degree: int | None = None
    lookup_bits: int | None = None
    r_lo: int | None = None
    r_hi: int | None = None
    impl: str = DEFAULT_IMPL
    engine: str = DEFAULT_ENGINE
    fleet: bool = DEFAULT_FLEET
    mesh: int | None = None
    envelope_cache: int | None = DEFAULT_ENVELOPE_CACHE
    k_max: int | None = None
    workers: int | None = None
    cache_dir: str | None = None

    def spec(self) -> FunctionSpec:
        kw: dict = {"ulp": self.ulp}
        if self.out_bits is not None:
            kw["out_bits"] = self.out_bits
        if self.bits is None:
            # default width: the ML-table defaults (out_bits etc.) apply
            return spec_for(self.kind, None, **kw)
        # explicit width: DEFAULTS kwargs are tuned for the default width
        # only — use the maker's own defaults, as the seed's get_spec did
        return get_spec(self.kind, self.bits, **kw)

    def resolved_cache_dir(self) -> pathlib.Path:
        if self.cache_dir is not None:
            return pathlib.Path(self.cache_dir)
        return default_cache_dir()
