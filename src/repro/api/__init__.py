"""repro.api — the single public entry point to the design-space pipeline.

The paper computes the complete design space once; everything downstream is
"a modified decision procedure". This package makes that literal:

    ExploreConfig       frozen session configuration (spec, sweep, workers,
                        cache dir) — replaces the per-function keyword soup
    Target              protocol: decision-procedure ordering + area/delay
                        estimator; @register_target adds a technology
                        (built-ins: asic, fpga-lut, pallas-tpu)
    Explorer            session object owning the worker pool, the
                        (spec, R) -> RegionSpace envelope cache and the
                        table persistence layer
    DesignSpaceResult   full per-R frontier + Pareto / best / min-regions

Legacy entry points (``repro.core.generate.generate_table`` / ``sweep_lub``,
``repro.numerics.registry.get_table``) are deprecation shims over
``default_explorer()``. See DESIGN.md §6.
"""
from repro.api.config import DEFAULTS, ExploreConfig, spec_for  # noqa: F401
from repro.api.explorer import (Explorer, default_explorer, explore,  # noqa: F401
                                get_table, set_default_explorer)
from repro.api.library import (DEFAULT_LIBRARY_KINDS, FuncMeta,  # noqa: F401
                               InterpLibrary, LibraryIntegrityError,
                               load_library)
from repro.api.result import DesignSpaceResult, ExploreEntry  # noqa: F401
from repro.api.target import (Target, get_target, list_targets,  # noqa: F401
                              register_target)
from repro.core.decision import DecisionPolicy  # noqa: F401
from repro.core.funcspec import FunctionSpec, get_spec  # noqa: F401
from repro.core.table import TableDesign  # noqa: F401

__all__ = [
    "DEFAULTS", "DEFAULT_LIBRARY_KINDS", "DecisionPolicy",
    "DesignSpaceResult", "ExploreConfig", "ExploreEntry", "Explorer",
    "FuncMeta", "FunctionSpec", "InterpLibrary", "LibraryIntegrityError",
    "TableDesign", "Target",
    "default_explorer", "explore", "get_spec", "get_table", "get_target",
    "list_targets", "load_library", "register_target",
    "set_default_explorer", "spec_for",
]
