import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape x mesh) cell on 512 placeholder devices and dump
memory_analysis / cost_analysis / HLO-parsed collective bytes to JSON.

The two lines above run before ANY other import — jax locks the device count
on first init. Do not move them.

Usage:
    python -m repro.launch.dryrun --arch yi_6b --shape train_4k [--multi-pod]
    python -m repro.launch.dryrun --all [--out artifacts/dryrun]
"""

import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import (ARCH_IDS, SHAPES, ModelConfig, ShapeConfig,
                                cell_is_runnable, get_config)
from repro.launch import sharding as shlib
from repro.launch.xprof import analyze_hlo
from repro.launch.inputs import batch_shapes, input_specs
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tf
from repro.numerics.ops import get_numerics
from repro.serve.engine import make_serve_step
from repro.train.step import StepConfig, make_train_step, train_state_shapes

DEFAULT_OUT = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _train_lowered(cfg: ModelConfig, shape: ShapeConfig, mesh, step_cfg: StepConfig):
    state_shapes = train_state_shapes(cfg, step_cfg)
    b_shapes = batch_shapes(cfg, shape.global_batch, shape.seq_len)
    state_sh = shlib.param_specs(state_shapes, mesh)
    batch_sh = shlib.batch_specs(b_shapes, mesh)
    rep = shlib.replicated(mesh)
    step = make_train_step(cfg, step_cfg)
    jitted = jax.jit(
        step,
        in_shardings=(state_sh, batch_sh, rep),
        out_shardings=(state_sh, jax.tree.map(lambda _: rep, {
            "loss": 0, "aux": 0, "lr": 0, "grad_norm": 0})),
        donate_argnums=0,
    )
    with shlib.axis_rules(mesh):
        return jitted.lower(state_shapes, b_shapes,
                            jax.ShapeDtypeStruct((), jnp.int32))


def _prefill_lowered(cfg: ModelConfig, shape: ShapeConfig, mesh):
    numerics = get_numerics(cfg)
    specs = input_specs(cfg, shape)
    p_shapes = tf.model_shapes(cfg)
    p_sh = shlib.param_specs(p_shapes, mesh)
    extras = {k: specs[k] for k in ("frontend_emb", "enc_frames") if k in specs}

    def pf(params, tokens, extras):
        logits, caches, cross = tf.prefill(params, tokens, cfg, numerics,
                                           shape.seq_len,
                                           frontend_emb=extras.get("frontend_emb"),
                                           enc_frames=extras.get("enc_frames"))
        return logits, caches

    in_sh = (p_sh,
             shlib.batch_specs({"t": specs["tokens"]}, mesh)["t"],
             shlib.batch_specs(extras, mesh))
    jitted = jax.jit(pf, in_shardings=in_sh)
    with shlib.axis_rules(mesh):
        return jitted.lower(p_shapes, specs["tokens"], extras)


def _decode_lowered(cfg: ModelConfig, shape: ShapeConfig, mesh):
    specs = input_specs(cfg, shape)
    p_shapes = tf.model_shapes(cfg)
    p_sh = shlib.param_specs(p_shapes, mesh)
    tok_sh = shlib.batch_specs({"t": specs["token"]}, mesh)["t"]
    cache_sh = shlib.cache_specs_sharding(specs["caches"], cfg, mesh)
    rep = shlib.replicated(mesh)
    step = make_serve_step(cfg)
    in_sh = [p_sh, tok_sh, rep, cache_sh]
    args = [p_shapes, specs["token"], specs["pos"], specs["caches"]]
    if "cross" in specs:
        in_sh.append(shlib.batch_specs({"c": specs["cross"]}, mesh)["c"])
        args.append(specs["cross"])
    jitted = jax.jit(step, in_shardings=tuple(in_sh),
                     donate_argnums=3)
    with shlib.axis_rules(mesh):
        return jitted.lower(*args)


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False,
               step_cfg: StepConfig | None = None, cfg: ModelConfig | None = None):
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    step_cfg = step_cfg or StepConfig(
        microbatches=1, compress_pods=multi_pod)
    if shape.kind == "train":
        return _train_lowered(cfg, shape, mesh, step_cfg), mesh
    if shape.kind == "prefill":
        return _prefill_lowered(cfg, shape, mesh), mesh
    return _decode_lowered(cfg, shape, mesh), mesh


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             out_dir: pathlib.Path | None = None, save_hlo: bool = False,
             cfg: ModelConfig | None = None, tag: str = "",
             step_cfg: StepConfig | None = None) -> dict:
    shape = SHAPES[shape_name]
    the_cfg = cfg or get_config(arch)
    ok, why = cell_is_runnable(the_cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag}
    if not ok:
        rec.update(status="skipped", reason=why)
        if out_dir is not None:
            out_dir.mkdir(parents=True, exist_ok=True)
            (out_dir / f"{arch}_{shape_name}_{mesh_name}{tag}.json").write_text(
                json.dumps(rec, indent=1, default=str))
        return rec
    t0 = time.perf_counter()
    try:
        lowered, mesh = lower_cell(arch, shape_name, multi_pod, cfg=cfg,
                                   step_cfg=step_cfg)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        prof = analyze_hlo(hlo)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            },
            # raw HloCostAnalysis numbers (loop bodies counted once — kept
            # for reference); the roofline uses the trip-scaled profile
            xla_cost_flops=cost.get("flops", 0.0) if cost else None,
            xla_cost_bytes=cost.get("bytes accessed", 0.0) if cost else None,
            profile=prof.to_dict(),
        )
        if save_hlo and out_dir is not None:
            (out_dir / f"{arch}_{shape_name}_{mesh_name}{tag}.hlo.txt").write_text(hlo)
    except Exception as e:  # a failure here is a bug in our sharding
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{arch}_{shape_name}_{mesh_name}{tag}.json").write_text(
            json.dumps(rec, indent=1, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    args = ap.parse_args()
    out = pathlib.Path(args.out)

    cells = ([(a, s, m) for a in ARCH_IDS for s in SHAPES for m in (False, True)]
             if args.all else [(args.arch, args.shape, args.multi_pod)])
    n_ok = n_skip = n_err = 0
    for arch, shape, multi in cells:
        rec = run_cell(arch, shape, multi, out, save_hlo=args.save_hlo, tag=args.tag)
        n_ok += rec["status"] == "ok"
        n_skip += rec["status"] == "skipped"
        n_err += rec["status"] == "error"
        msg = rec.get("error", "") or f"compile {rec.get('compile_s', '-')}s"
        print(f"[{rec['status']:>7}] {arch:18s} {shape:12s} {rec['mesh']:10s} {msg}",
              flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
