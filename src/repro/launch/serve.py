"""Serving launcher CLI: continuous-batching greedy decoding demo.

    PYTHONPATH=src python -m repro.launch.serve --arch yi_6b --smoke \
        --requests 8 --slots 4 --prompt-len 16 --max-new 12

With ``--numerics interp`` the engine serves from a compiled interpolation
library; ``--library PATH`` loads a saved artifact (no exploration at all),
``--save-library PATH`` persists the compiled artifact for the next launch.

Per-layer heterogeneous numerics (DESIGN.md §16): ``--plan PATH`` serves
under a saved :class:`repro.plan.NumericsPlan` (the schema-versioned
snapshot envelope ``repro.launch.dse plan --save-plan`` emits — one
backend + library slot per layer x op site); ``--save-plan PATH`` writes
the plan the engine actually served under (useful with ``--numerics`` to
snapshot a uniform plan for later editing).

Robustness knobs (DESIGN.md §14): ``--deadline-ms N`` gives every request a
TTL (expired work is retired with a structured ``deadline_exceeded`` error),
``--max-queue N`` bounds the admission queue (overflow submissions raise
``Rejected(reason="queue_full")`` instead of growing memory), ``--journal
PATH`` records admissions and emitted tokens through an fsync'd append-only
journal, and ``--resume`` (with ``--journal``) rebuilds the engine from that
journal after a crash — completed requests are not re-served and in-flight
streams continue bitwise where they left off.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.api import InterpLibrary
from repro.configs.base import ARCH_IDS, get_config, get_smoke_config
from repro.models import transformer as tf
from repro.serve import Rejected, ServeEngine
from repro.serve.engine import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--numerics", choices=["exact", "interp"], default=None)
    ap.add_argument("--library", default=None,
                    help="serve from this saved InterpLibrary (json/npz base)")
    ap.add_argument("--save-library", default=None,
                    help="persist the engine's compiled library here")
    ap.add_argument("--plan", default=None,
                    help="serve under this saved NumericsPlan snapshot "
                         "(per-layer x per-op-site numerics)")
    ap.add_argument("--save-plan", default=None,
                    help="write the served plan (from --plan, or a uniform "
                         "plan matching --numerics) as a snapshot")
    ap.add_argument("--serial", action="store_true",
                    help="per-op dispatch path (the pre-fused oracle) "
                         "instead of the fused single-dispatch tick")
    ap.add_argument("--horizon", type=int, default=8,
                    help="fused tick: max decode steps per dispatch")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request TTL; expired requests are retired "
                         "with a structured deadline_exceeded error")
    ap.add_argument("--max-queue", type=int, default=1024,
                    help="admission queue bound; overflow submissions are "
                         "rejected (reason=queue_full), never buffered")
    ap.add_argument("--journal", default=None,
                    help="fsync'd serve journal (admissions + tokens); "
                         "makes the run crash-recoverable via --resume")
    ap.add_argument("--resume", action="store_true",
                    help="rebuild engine state from --journal instead of "
                         "submitting fresh requests")
    ap.add_argument("--mesh", default=None, metavar="DxT",
                    help="serve on a (data, tp) device mesh, e.g. 2x4 "
                         "(bare N means Nx1); KV pool batch-sharded over "
                         "data, heads over tp, ROM replicated (DESIGN.md "
                         "§17). Needs data*tp <= len(jax.devices())")
    ap.add_argument("--aot-buckets", default=None, metavar="B1,B2,...",
                    help="AOT warm-up: compile the decode tick and a packed "
                         "prefill program per bucket at construction; "
                         "'default' uses the built-in table clipped to "
                         "--cache-len")
    ap.add_argument("--max-pack", type=int, default=4,
                    help="max prompts packed into one bucketed prefill "
                         "dispatch (power-of-two group sizes)")
    ap.add_argument("--async-host", action="store_true",
                    help="detokenize/journal on a background host thread "
                         "behind a bounded queue (DESIGN.md §17)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.resume and not args.journal:
        ap.error("--resume requires --journal")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.numerics:
        cfg = cfg.replace(numerics=args.numerics)
    if args.plan:
        from repro.plan import load_plan

        plan = load_plan(args.plan)
        if plan.n_layers != cfg.n_layers:
            ap.error(f"--plan has {plan.n_layers} layers but {args.arch} "
                     f"(smoke={args.smoke}) has {cfg.n_layers}")
        cfg = cfg.replace(plan=plan)
        if args.library:
            ap.error("--plan engines compile one library per plan slot; "
                     "--library cannot override them")
    if args.library or args.save_library:
        if args.numerics == "exact":
            ap.error("--library/--save-library require interp numerics")
        if cfg.plan is None and cfg.numerics != "interp":
            cfg = cfg.replace(numerics="interp")  # the flags imply it
    if args.save_plan:
        from repro.plan import plan_for, save_plan

        served = cfg.plan if cfg.plan is not None else plan_for(cfg)
        save_plan(args.save_plan, served, seed=args.seed,
                  meta_extra={"arch": args.arch, "smoke": args.smoke})
        print(f"saved plan -> {args.save_plan}")
    library = InterpLibrary.load(args.library) if args.library else None
    params = tf.init_params(jax.random.key(args.seed), cfg)
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_serve_mesh, parse_mesh_spec

        data, tp = parse_mesh_spec(args.mesh)
        mesh = make_serve_mesh(data, tp)
        print(f"serve mesh: data={data} x tp={tp} "
              f"({len(jax.devices())} devices visible)")
    buckets = None
    if args.aot_buckets:
        buckets = (True if args.aot_buckets == "default" else
                   tuple(int(b) for b in args.aot_buckets.split(",")))
    kw = dict(slots=args.slots, cache_len=args.cache_len, library=library,
              fused=not args.serial, horizon=args.horizon,
              max_queue=args.max_queue,
              deadline_s=(args.deadline_ms / 1e3
                          if args.deadline_ms is not None else None),
              mesh=mesh, aot_buckets=buckets, max_pack=args.max_pack,
              async_host=args.async_host)
    t0 = time.perf_counter()
    if args.resume:
        eng = ServeEngine.resume(args.journal, cfg, params, **kw)
    else:
        eng = ServeEngine(cfg, params, journal=args.journal, **kw)
    if args.save_library and eng.library is not None:
        if isinstance(eng.library, dict):  # plan engine: one artifact/slot
            for key, lib in sorted(eng.library.items()):
                print(f"saved library [{key}] -> "
                      f"{lib.save(f'{args.save_library}.{key}')}")
        else:
            print(f"saved library -> {eng.library.save(args.save_library)}")
    if not args.resume:
        rng = np.random.default_rng(args.seed)
        for i in range(args.requests):
            try:
                eng.submit(Request(i, rng.integers(
                    0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                    args.max_new))
            except Rejected as e:
                print(f"  req {i} rejected ({e.reason})")
    done = eng.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s incl. compile; "
          f"{eng.stats['dispatches']} dispatches / "
          f"{eng.stats['decode_steps']} decode steps)")
    if args.resume:
        print(f"  resumed: {eng.stats['resumed']} in-flight replayed "
              f"({eng.stats['resume_replay_steps']} teacher-forced steps), "
              f"{eng.stats['resume_skipped_done']} already-done skipped")
    if eng.failed:
        print(f"  failed: {len(eng.failed)} "
              f"({sorted({r.error for r in eng.failed})})")
    if eng.faults:
        print(f"  faults: {eng.faults}")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out[:8]}...")


if __name__ == "__main__":
    main()
