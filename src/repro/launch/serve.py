"""Serving launcher CLI: continuous-batching greedy decoding demo.

    PYTHONPATH=src python -m repro.launch.serve --arch yi_6b --smoke \
        --requests 8 --slots 4 --prompt-len 16 --max-new 12

With ``--numerics interp`` the engine serves from a compiled interpolation
library; ``--library PATH`` loads a saved artifact (no exploration at all),
``--save-library PATH`` persists the compiled artifact for the next launch.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.api import InterpLibrary
from repro.configs.base import ARCH_IDS, get_config, get_smoke_config
from repro.models import transformer as tf
from repro.serve import ServeEngine
from repro.serve.engine import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--numerics", choices=["exact", "interp"], default=None)
    ap.add_argument("--library", default=None,
                    help="serve from this saved InterpLibrary (json/npz base)")
    ap.add_argument("--save-library", default=None,
                    help="persist the engine's compiled library here")
    ap.add_argument("--serial", action="store_true",
                    help="per-op dispatch path (the pre-fused oracle) "
                         "instead of the fused single-dispatch tick")
    ap.add_argument("--horizon", type=int, default=8,
                    help="fused tick: max decode steps per dispatch")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.numerics:
        cfg = cfg.replace(numerics=args.numerics)
    if args.library or args.save_library:
        if args.numerics == "exact":
            ap.error("--library/--save-library require interp numerics")
        if cfg.numerics != "interp":
            cfg = cfg.replace(numerics="interp")  # the flags imply it
    library = InterpLibrary.load(args.library) if args.library else None
    params = tf.init_params(jax.random.key(args.seed), cfg)
    eng = ServeEngine(cfg, params, slots=args.slots, cache_len=args.cache_len,
                      library=library, fused=not args.serial,
                      horizon=args.horizon)
    if args.save_library and eng.library is not None:
        print(f"saved library -> {eng.library.save(args.save_library)}")
    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    for i in range(args.requests):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size,
                                           args.prompt_len).astype(np.int32),
                           args.max_new))
    done = eng.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s incl. compile; "
          f"{eng.stats['dispatches']} dispatches / "
          f"{eng.stats['decode_steps']} decode steps)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out[:8]}...")


if __name__ == "__main__":
    main()
