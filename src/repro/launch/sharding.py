"""Logical-axis sharding: rule engine mapping named tensor axes to mesh axes.

Models annotate activations with *logical* names (``constrain(x, ("batch",
"seq", "heads", None))``); a thread-local rule set maps those names onto
physical mesh axes (DP/TP/EP/SP), checking divisibility so e.g. 8 KV heads
never get forced onto a 16-way axis (they fall back to the next candidate or
to replication). Outside an active rule context ``constrain`` is a no-op, so
the same model code runs in single-device smoke tests and 512-chip dry-runs.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# logical axis -> ordered mesh-axis candidates (first divisible one wins).
# Training meshes name their TP axis "model"; the serving mesh
# (launch.mesh.make_serve_mesh) names it "tp" — both appear as candidates so
# the same model annotations resolve on either without a separate rule set.
DEFAULT_RULES: dict[str, tuple[tuple[str, ...], ...]] = {
    "batch": (("pod", "data"), ("data",)),
    "seq": (("model",),),  # sequence parallelism (long-context fallback)
    "heads": (("model",), ("tp",)),
    "kv_heads": (("model",), ("tp",)),
    "embed": (),  # activations replicated along d_model by default
    "mlp": (("model",), ("tp",)),
    "vocab": (("model",), ("tp",)),
    "expert": (("model",), ("tp",)),
    "kv_seq": (("model",),),  # decode KV cache sequence axis
}


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: dict | None = None):
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, rules or DEFAULT_RULES)
    try:
        yield
    finally:
        _state.ctx = prev


def active_mesh() -> Optional[Mesh]:
    ctx = getattr(_state, "ctx", None)
    return ctx[0] if ctx else None


def _resolve(name: Optional[str], size: int, mesh: Mesh, rules: dict,
             taken: set[str]) -> Optional[tuple[str, ...]]:
    if name is None:
        return None
    for cand in rules.get(name, ()):
        if any(ax in taken or ax not in mesh.shape for ax in cand):
            continue
        total = 1
        for ax in cand:
            total *= mesh.shape[ax]
        if size % total == 0 and size > 0:
            return cand
    return None


def logical_spec(names: Sequence[Optional[str]], shape: Sequence[int],
                 mesh: Mesh, rules: dict) -> P:
    taken: set[str] = set()
    out = []
    for name, size in zip(names, shape):
        axes = _resolve(name, int(size), mesh, rules, taken)
        if axes is None:
            out.append(None)
        else:
            taken.update(axes)
            out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def constrain(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """Attach a logical sharding constraint; no-op without an active mesh."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_spec(names, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(names: Sequence[Optional[str]], shape: Sequence[int],
                   mesh: Mesh, rules: dict | None = None) -> NamedSharding:
    return NamedSharding(mesh, logical_spec(names, shape, mesh, rules or DEFAULT_RULES))


# ---------------------------------------------------------------------------
# parameter / state sharding (name-based Megatron TP x FSDP rules)
# ---------------------------------------------------------------------------

# logical parameter axes; resolution falls back left-to-right per candidate
PARAM_RULES: dict[str, tuple[tuple[str, ...], ...]] = {
    "tp": (("model",), ("tp",)),         # Megatron column/row axis
    "fsdp": (("pod", "data"), ("data",)),  # ZeRO-3 shard of the other big axis
    "expert": (("model",), ("tp",)),     # expert parallelism
    "vocab": (("model",), ("tp",)),
}

# Serving-tier parameter rules (DESIGN.md §17): weights tensor-parallel over
# the serve mesh's "tp" axis, *replicated* over "data". The training "fsdp"
# rule would ZeRO-shard weights over the data axis and pay a per-layer
# all-gather on every decode tick — batch slots are the data-parallel unit
# when serving, not parameters.
SERVE_PARAM_RULES: dict[str, tuple[tuple[str, ...], ...]] = {
    "tp": (("tp",), ("model",)),
    "fsdp": (),
    "expert": (("tp",), ("model",)),
    "vocab": (("tp",), ("model",)),
}

# leaf-name suffix -> logical axes for the *trailing* dims (stacked layer
# dims are padded with None on the left automatically)
_COL = ("fsdp", "tp")   # (d_in, d_out) column-parallel: shard d_out
_ROW = ("tp", "fsdp")   # (d_in, d_out) row-parallel: shard d_in
_PARAM_AXES: tuple[tuple[str, tuple], ...] = (
    ("embed/tok", ("vocab", "fsdp")),
    ("embed/head", ("fsdp", "vocab")),
    ("projector/w1", _COL), ("projector/w2", _ROW),
    ("mixer/wq", _COL), ("mixer/wk", _COL), ("mixer/wv", _COL),
    ("mixer/wo", _ROW),
    ("cross/wq", _COL), ("cross/wk", _COL), ("cross/wv", _COL), ("cross/wo", _ROW),
    ("wq_a", _COL), ("wq_b", _COL), ("wkv_a", _COL), ("wkv_b", _COL),
    ("ffn/wi", _COL), ("ffn/wo", _ROW),
    ("shared_wi", _COL), ("shared_wo", _ROW),
    ("router", ("fsdp", None)),
    ("in_proj", _COL), ("out_proj", _ROW),
    ("conv_w", (None, "tp")), ("conv_b", ("tp",)),
    ("pos", (None, "fsdp")),
)
# MoE expert tensors — layout is divisibility-adaptive (perf iterations
# A1/A4 in EXPERIMENTS.md §Perf):
#   * E % model_axis == 0 (deepseek 64, jamba 16): classic expert
#     parallelism — experts sharded on `model`, each expert dense locally.
#   * otherwise (mixtral 8 on a 16-way axis): intra-expert Megatron col/row —
#     d_expert on `model`, d_model on FSDP, experts replicated. The naive
#     expert-dim rule here replicated the dispatch buffers (measured 2.9e13
#     collective bytes/chip/step before the rewrite).
# (A4 — true expert-dim EP for divisible E — was tried and REFUTED: GSPMD
# partitions the data-dependent dispatch scatter/combine gather against an
# expert-sharded buffer with full per-layer gathers; measured 12x collective
# blow-up on deepseek/jamba train. Intra-expert TP is universal here.)
_MOE_TP = {"ffn/wi": (None, "fsdp", "tp"), "ffn/wo": (None, "tp", "fsdp")}


def param_logical_axes(name: str, ndim: int, shape: tuple = (),
                       mesh: Optional[Mesh] = None) -> tuple:
    for suffix, axes in _PARAM_AXES:
        if suffix in name:
            if suffix in _MOE_TP and ndim >= 3:
                cand = _MOE_TP[suffix]
                if ndim in (3, 4):  # maybe scan-stacked
                    axes3 = cand if ndim == 3 else (None, *cand)
                    return axes3
            pad = ndim - len(axes)
            if pad < 0:
                return (None,) * ndim
            return (None,) * pad + tuple(axes)
    return (None,) * ndim  # norms, biases, scalars: replicated


def param_specs(shapes: dict, mesh: Mesh, rules: dict | None = None) -> dict:
    """ShapeDtypeStruct tree -> NamedSharding tree (same structure)."""
    rules = rules or PARAM_RULES
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        names = param_logical_axes(name, len(leaf.shape), tuple(leaf.shape), mesh)
        out.append(NamedSharding(mesh, logical_spec(names, leaf.shape, mesh, rules)))
    return jax.tree.unflatten(treedef, out)


def batch_specs(batch_shapes: dict, mesh: Mesh, rules: dict | None = None) -> dict:
    """Input batch: leading axis is the global batch -> DP axes."""
    r = dict(DEFAULT_RULES)
    r.update(rules or {})

    def one(s):
        names = ("batch",) + (None,) * (len(s.shape) - 1)
        return NamedSharding(mesh, logical_spec(names, s.shape, mesh, r))

    return jax.tree.map(one, batch_shapes)


def cache_specs_sharding(cache_shapes: dict, cfg, mesh: Mesh) -> dict:
    """KV caches: batch->data; kv-heads->model if divisible, else the cache
    sequence axis (sequence parallelism for long-context decode).

    Field layouts (a leading scan-stacked layer dim may be prepended):
      GQA:  k,v (B, KV, S, D)   pos (B, S)
      MLA:  k (B, S, lora)  v (B, S, rope)  pos (B, S)
      SSM:  conv (B, K, C)  ssm (B, H, P, N)
    """
    kv_base = 3 if cfg.mla is not None else 4

    def one_leaf(path, s):
        field = str(path[-1]).lstrip(".")
        nd = len(s.shape)
        base = {"k": kv_base, "v": kv_base, "pos": 2, "conv": 3, "ssm": 4}[field]
        stacked = nd == base + 1
        if field in ("k", "v"):
            names = (("batch", "kv_heads", "kv_seq", None) if kv_base == 4
                     else ("batch", "kv_seq", None))
        elif field == "pos":
            names = ("batch", "kv_seq")
        elif field == "conv":
            names = ("batch", None, "tp")
        else:  # ssm state
            names = ("batch", "heads", None, None)
        if stacked:
            names = (None, *names)
        rules = dict(DEFAULT_RULES)
        rules["tp"] = (("model",), ("tp",))
        return NamedSharding(mesh, logical_spec(names, s.shape, mesh, rules))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree.unflatten(treedef, [one_leaf(p, s) for p, s in flat])


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
