"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh)
cell from the dry-run profiles.

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / link_bw

All three are *seconds per step on one chip's resources* — the bottleneck is
the largest. MODEL_FLOPS = 6 N D (dense) or 6 N_active D (MoE); the ratio
MODEL_FLOPS / HLO_FLOPs measures how much compiled compute is useful
(remat/redundancy waste shows up here). The profile numbers are already
per-chip (SPMD-partitioned HLO), trip-count scaled by launch/xprof.py.

Usage: python -m repro.launch.roofline [--dir artifacts/dryrun] [--md]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

DEFAULT_DIR = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


# ---------------------------------------------------------------------------
# analytic parameter counts / useful-FLOPs model
# ---------------------------------------------------------------------------

def param_counts(cfg) -> tuple[int, int]:
    """(total params, active-per-token params) from the config alone."""
    from repro.models.layers import count_params
    from repro.models.transformer import model_shapes
    total = count_params(model_shapes(cfg))
    active = total
    if cfg.moe is not None:
        m = cfg.moe
        # routed expert params counted per layer: only top_k of n_experts fire
        per_expert = 3 * cfg.d_model * m.d_expert  # SwiGLU wi(2f) + wo(f)
        n_moe_layers = _n_moe_layers(cfg)
        active = total - n_moe_layers * (m.n_experts - m.top_k) * per_expert
    return total, active


def _n_moe_layers(cfg) -> int:
    from repro.models.transformer import layer_plan
    n = 0
    for seg in layer_plan(cfg):
        n += sum(k.ffn == "moe" for k in seg.pattern) * seg.repeat
    return n


def model_flops(cfg, shape, kind: str) -> float:
    """Useful FLOPs per step: 6 N_active D for training, 2 N_active per
    decoded token for decode, 2 N_active D for prefill."""
    _, active = param_counts(cfg)
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    return 2.0 * active * shape.global_batch  # decode: one token per row


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

def cell_roofline(rec: dict) -> dict | None:
    if rec.get("status") != "ok" or "profile" not in rec:
        return None
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    chips = 512 if rec["mesh"] == "pod2x16x16" else 256
    prof = rec["profile"]
    t_c = prof["flops"] / PEAK_FLOPS_BF16
    t_m = prof["hbm_bytes"] / HBM_BW
    t_l = prof["total_collective_bytes"] / ICI_BW
    useful = model_flops(cfg, shape, shape.kind)
    useful_per_chip = useful / chips
    terms = {"compute": t_c, "memory": t_m, "collective": t_l}
    dom = max(terms, key=terms.get)
    bound = max(t_c, t_m, t_l)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "tag": rec.get("tag", ""),
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_l,
        "dominant": dom,
        "model_flops": useful, "hlo_flops_per_chip": prof["flops"],
        "useful_ratio": useful_per_chip / prof["flops"] if prof["flops"] else 0.0,
        # fraction of roofline: useful work at peak over the bound term
        "roofline_frac": (useful_per_chip / PEAK_FLOPS_BF16) / bound if bound else 0.0,
        "temp_bytes": rec["memory"]["temp_bytes"],
    }


def load_records(d: pathlib.Path, tag: str = "") -> list[dict]:
    out = []
    for f in sorted(d.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("tag", "") == tag:
            out.append(rec)
    return out


def fmt_s(x: float) -> str:
    return f"{x*1e3:8.2f}ms" if x >= 1e-4 else f"{x*1e6:8.1f}us"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=str(DEFAULT_DIR))
    ap.add_argument("--tag", default="")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = []
    for rec in load_records(pathlib.Path(args.dir), args.tag):
        r = cell_roofline(rec)
        if r is None:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "status": rec["status"],
                         "reason": rec.get("reason", rec.get("error", ""))[:60]})
        else:
            r["status"] = "ok"
            rows.append(r)
    if args.md:
        print("| arch | shape | mesh | compute | memory | collective | bound |"
              " useful/HLO | roofline |")
        print("|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            if r["status"] != "ok":
                print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - |"
                      f" {r['status']}: {r['reason']} | - | - |")
            else:
                print(f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
                      f" {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} |"
                      f" {fmt_s(r['collective_s'])} | {r['dominant']} |"
                      f" {r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} |")
    else:
        for r in rows:
            if r["status"] != "ok":
                print(f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:10s} "
                      f"{r['status']}: {r['reason']}")
            else:
                print(f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:10s} "
                      f"C {fmt_s(r['compute_s'])}  M {fmt_s(r['memory_s'])}  "
                      f"L {fmt_s(r['collective_s'])}  -> {r['dominant']:10s} "
                      f"useful {r['useful_ratio']:.2f}  roofline {r['roofline_frac']:.3f}")


if __name__ == "__main__":
    main()
