"""Structural HLO profiler: trip-count-scaled FLOPs / HBM bytes / collective
bytes from compiled HLO text.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis counts each
while-loop *body once*, so any scanned-layer model (every model here) is
under-counted by the scan length. This profiler splits the module into
computations, walks the call graph (while bodies x known_trip_count, fusion
bodies inline), and accumulates:

  * **flops** — 2 x M x N x K for every ``dot`` (including dots inside fusion
    bodies), the MXU-relevant count. Elementwise FLOPs are not counted
    (<~3% for these models); noted in EXPERIMENTS.md.
  * **hbm_bytes** — 2 x sum of top-level op output bytes (one write + ~one
    read per produced value). Ops inside fusion bodies are VMEM/register
    traffic and excluded; parameters/tuples/GTEs/bitcasts move no data.
  * **collective bytes** — per-chip ring-model bytes by kind (see factors),
    trip-count scaled.

Trip counts come from ``backend_config={"known_trip_count":{"n":...}}``
(emitted by XLA's while-loop analysis), falling back to the largest integer
constant in the loop condition.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c\d+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_WHILE_RE = re.compile(r"\bwhile\(.*condition=%?([\w\.\-]+).*body=%?([\w\.\-]+)")
_FUSION_RE = re.compile(r"\b(?:fusion|call)\(.*?(?:calls|to_apply)=%?([\w\.\-]+)")
_COND_RE = re.compile(
    r"\bconditional\(.*?(?:branch_computations=\{([^}]+)\}|"
    r"true_computation=%?([\w\.\-]+).*?false_computation=%?([\w\.\-]+))")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPCODE_RE = re.compile(r"=\s*(?:\([^=]*?\)|[\w\[\]\{\},\/ ]+?)\s+([\w\-]+)\(")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "after-all", "partition-id", "replica-id",
               "get-dimension-size", "opt-barrier", "domain"}

_FACTORS = {
    "all-gather": lambda g: (g - 1) / g,
    "all-reduce": lambda g: 2 * (g - 1) / g,
    "reduce-scatter": lambda g: float(g - 1),  # x output bytes
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}


def _shapes_in(s: str) -> list[tuple[str, int]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(s):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        out.append((dt, n))
    return out


def _bytes_of(shapes: list[tuple[str, int]]) -> int:
    return sum(n * _DTYPE_BYTES[dt] for dt, n in shapes)


def _result_type_str(line: str) -> str:
    """Text between '=' and the opcode's '(' — the result type."""
    m = _OPCODE_RE.search(line)
    if not m:
        return ""
    return line[line.index("=") + 1: m.start(1)]


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


@dataclasses.dataclass
class HloProfile:
    flops: float
    hbm_bytes: float
    collective_bytes: dict  # kind -> per-chip bytes
    collective_count: dict  # kind -> static op count
    trip_counts: list

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))

    def to_dict(self) -> dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "collective_bytes": dict(self.collective_bytes),
                "collective_count": dict(self.collective_count),
                "total_collective_bytes": self.total_collective_bytes,
                "trip_counts": self.trip_counts[:24]}


def _split_computations(hlo_text: str) -> tuple[dict[str, list[str]], str | None]:
    comps: dict[str, list[str]] = {}
    entry = None
    cur: list[str] | None = None
    for raw in hlo_text.splitlines():
        line = _COMMENT_RE.sub("", raw).rstrip()  # /*index=5*/ breaks [^=]
        if cur is None or (line and not line.startswith(" ")):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                name = m.group(2)
                comps[name] = cur = []
                if m.group(1):
                    entry = name
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            cur.append(line)
    return comps, entry


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=")
# first operand of a call site; the operand's element type may or may not be
# spelled inline depending on jaxlib's HLO printer version
_OPERAND_RE = re.compile(r"\(\s*(?:\w+\[[^\]]*\](?:\{[^}]*\})?\s+)?%([\w\.\-]+)")


def _build_symtab(comps: dict[str, list[str]]) -> dict[str, list[int]]:
    """op name -> result dims (first array shape in the result type)."""
    tab: dict[str, list[int]] = {}
    for lines in comps.values():
        for line in lines:
            md = _DEF_RE.match(line)
            if not md:
                continue
            res = _result_type_str(line) or line.split("=", 1)[1][:160]
            ms = _SHAPE_RE.search(res)
            if ms:
                tab[md.group(1)] = [int(d) for d in ms.group(2).split(",") if d]
    return tab


def _dot_flops(line: str, symtab: dict[str, list[int]]) -> float:
    """2 x prod(result dims) x prod(lhs contracting dims)."""
    res_shapes = _shapes_in(_result_type_str(line))
    if not res_shapes:
        return 0.0
    out_elems = res_shapes[0][1]
    # optimized HLO omits operand types inline: resolve lhs via symbol table
    mo = _OPCODE_RE.search(line)
    oper = _OPERAND_RE.search(line[mo.end(1):])
    cd = _DOT_DIMS_RE.search(line)
    k = 1
    if cd and oper:
        lhs_dims = symtab.get(oper.group(1), [])
        for ax in (int(a) for a in cd.group(1).split(",") if a):
            if ax < len(lhs_dims):
                k *= lhs_dims[ax]
    return 2.0 * out_elems * k


def analyze_hlo(hlo_text: str) -> HloProfile:
    comps, entry = _split_computations(hlo_text)
    symtab = _build_symtab(comps)
    coll_count: dict = defaultdict(int)
    trips_seen: list[int] = []
    memo: dict[tuple[str, bool], tuple] = {}

    def visit(name: str, internal: bool, stack=()):
        """Returns (flops, bytes, coll: dict) for ONE execution."""
        key = (name, internal)
        if key in memo:
            return memo[key]
        if name not in comps or name in stack:
            return 0.0, 0.0, {}
        flops = 0.0
        byts = 0.0
        coll: dict = defaultdict(float)
        for line in comps[name]:
            mo = _OPCODE_RE.search(line)
            opcode = mo.group(1) if mo else ""
            if opcode in ("dot", "convolution"):
                flops += _dot_flops(line, symtab)
            mc = _COLL_RE.search(line)
            if mc and "-done" not in line:
                g = _group_size(line)
                kind = mc.group(1)
                if g > 1 or kind == "collective-permute":
                    shapes = _shapes_in(_result_type_str(line))
                    # async -start forms type as (input, ..., output): the
                    # last array shape is the transferred result buffer
                    payload = _bytes_of(shapes[-1:])
                    coll[kind] += payload * _FACTORS[kind](g)
                    coll_count[kind] += 1
            mw = _WHILE_RE.search(line)
            if mw:
                mt = _TRIP_RE.search(line)
                if mt:
                    trips = int(mt.group(1))
                else:
                    best = 1
                    for cl in comps.get(mw.group(1), []):
                        for c in _CONST_RE.findall(cl):
                            best = max(best, int(c))
                    trips = best
                trips_seen.append(trips)
                f, b, c = visit(mw.group(2), internal, stack + (name,))
                flops += trips * f
                byts += trips * b
                for k, v in c.items():
                    coll[k] += trips * v
                continue
            md = _COND_RE.search(line)
            if md:
                # data-dependent branch (e.g. flash-attention chunk-skip):
                # weight each branch by its expected execution probability
                # (uniform 1/n — for causal chunk-skipping the true rate is
                # ~(nq+1)/2nq ~= 0.5, so this is the honest estimate)
                branches = ([x.strip().lstrip("%") for x in md.group(1).split(",")]
                            if md.group(1) else [md.group(2), md.group(3)])
                w = 1.0 / max(len(branches), 1)
                for br in branches:
                    f, bb, c = visit(br, internal, stack + (name,))
                    flops += w * f
                    byts += w * bb
                    for k, v in c.items():
                        coll[k] += w * v
                continue
            mf = _FUSION_RE.search(line)
            if mf:
                f, b, c = visit(mf.group(1), True, stack + (name,))
                flops += f  # dots inside fusions still burn MXU flops
                for k, v in c.items():
                    coll[k] += v
            if not internal and opcode and opcode not in _NO_TRAFFIC:
                byts += 2.0 * _bytes_of(_shapes_in(_result_type_str(line)))
        memo[key] = (flops, byts, dict(coll))
        return memo[key]

    if entry is None and comps:
        entry = list(comps)[-1]
    flops, byts, coll = visit(entry, False) if entry else (0.0, 0.0, {})
    return HloProfile(flops, byts, dict(coll), dict(coll_count), trips_seen)


def breakdown(hlo_text: str, top: int = 20) -> list[dict]:
    """Per-op_name aggregation of trip-scaled bytes / flops / collective
    bytes — the 'where is it going' view used by the perf hillclimb."""
    comps, entry = _split_computations(hlo_text)
    symtab = _build_symtab(comps)
    execn: dict = defaultdict(float)

    def walk(name: str, mult: float, internal: bool, stack=()):
        if name in stack or name not in comps:
            return
        execn[(name, internal)] = execn.get((name, internal), 0.0) + mult
        for line in comps[name]:
            mw = _WHILE_RE.search(line)
            if mw:
                mt = _TRIP_RE.search(line)
                trips = int(mt.group(1)) if mt else 1
                walk(mw.group(2), mult * trips, internal, stack + (name,))
                continue
            md = _COND_RE.search(line)
            if md:
                branches = ([x.strip().lstrip("%") for x in md.group(1).split(",")]
                            if md.group(1) else [md.group(2), md.group(3)])
                for br in branches:
                    walk(br, mult / max(len(branches), 1), internal,
                         stack + (name,))
                continue
            mf = _FUSION_RE.search(line)
            if mf:
                walk(mf.group(1), mult, True, stack + (name,))

    if entry is None and comps:
        entry = list(comps)[-1]
    walk(entry, 1.0, False)

    agg: dict = {}
    meta_re = re.compile(r'op_name="([^"]+)"')
    for (name, internal), mult in execn.items():
        for line in comps[name]:
            mo = _OPCODE_RE.search(line)
            opcode = mo.group(1) if mo else ""
            if not opcode:
                continue
            mm = meta_re.search(line)
            op_name = mm.group(1) if mm else f"({opcode})"
            key = op_name[:110]
            e = agg.setdefault(key, {"op": key, "bytes": 0.0, "flops": 0.0,
                                     "coll_bytes": 0.0})
            if opcode in ("dot", "convolution"):
                e["flops"] += mult * _dot_flops(line, symtab)
            mc = _COLL_RE.search(line)
            if mc and "-done" not in line:
                g = _group_size(line)
                if g > 1 or mc.group(1) == "collective-permute":
                    shapes = _shapes_in(_result_type_str(line))
                    e["coll_bytes"] += mult * _bytes_of(shapes[-1:]) * _FACTORS[mc.group(1)](g)
            if not internal and opcode not in _NO_TRAFFIC:
                e["bytes"] += mult * 2.0 * _bytes_of(_shapes_in(_result_type_str(line)))
    rows = sorted(agg.values(), key=lambda r: -(r["bytes"] + r["coll_bytes"]))
    return rows[:top]
