"""DSE launcher CLI: persistent, resumable Pareto studies (DESIGN.md §13).

    PYTHONPATH=src python -m repro.launch.dse run    --study artifacts/dse/study6 --preset smoke
    PYTHONPATH=src python -m repro.launch.dse resume --study artifacts/dse/study6
    PYTHONPATH=src python -m repro.launch.dse report --study artifacts/dse/study6
    PYTHONPATH=src python -m repro.launch.dse check  --study artifacts/dse/study6 \\
        --against artifacts/dse/FRONTIER_6.json

``run`` creates (or extends) the study and evaluates every un-journaled
trial; ``resume`` is ``run`` restricted to an existing study dir (space,
probe mode and seed come from its ``study.json``) — with ``--assert-no-exec``
it exits nonzero if any trial had to be executed, which is how CI proves
the resume path replays instead of recomputing. ``--write-frontier`` emits
``frontier.json`` even when the space is only partially journaled (the
committed prefix studies rely on this). ``report`` prints the frontier;
``check`` compares the study's frontier against a committed artifact and
exits 1 on regression.

``plan`` runs the budget-driven per-layer numerics assigner (DESIGN.md
§16) against the committed frontiers and writes the resulting
:class:`repro.plan.NumericsPlan` snapshot:

    PYTHONPATH=src python -m repro.launch.dse plan --arch yi_6b --smoke \\
        --budget 0.05 --save-plan artifacts/plans/yi_6b.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.dse import (Study, compare_frontiers, load_frontier,
                       update_snapshot)
from repro.dse.space import PRESETS, SearchSpace
from repro.dse.study import FRONTIER_FILE

BENCH_DIR = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "bench"
BENCH_SNAPSHOT = "BENCH_6.json"


def _load_space(args) -> SearchSpace | None:
    if getattr(args, "space_json", None):
        return SearchSpace.from_dict(
            json.loads(pathlib.Path(args.space_json).read_text()))
    if getattr(args, "preset", None):
        return PRESETS[args.preset]()
    return None


def _print_summary(study: Study) -> dict:
    row = study.summary()
    print(f"study {row['study']}: {row['trials_recorded']}/"
          f"{row['trials_total']} trials recorded "
          f"({row['trials_infeasible']} infeasible) — this run executed "
          f"{row['executed_this_run']}, replayed {row['replayed_this_run']}; "
          f"serve probes {row['probe_runs']} run / "
          f"{row['probe_cache_hits']} cached")
    for target, n in row["frontier_points"].items():
        print(f"  frontier[{target}]: {n} points")
    return row


def _emit_bench(row: dict) -> None:
    path = BENCH_DIR / BENCH_SNAPSHOT
    update_snapshot(path, {"dse_summary": [row]}, seed=row.get("seed"))
    print(f"folded summary into {path}")


def cmd_run(args, resume_only: bool = False) -> int:
    space = None if resume_only else _load_space(args)
    root = pathlib.Path(args.study)
    if resume_only and not (root / "study.json").exists():
        print(f"no study at {root} (run `dse run` first)", file=sys.stderr)
        return 2
    with Study(root, space, measure=getattr(args, "measure", None),
               seed=getattr(args, "seed", None)) as study:
        records = study.run(max_trials=args.max_trials, compact=args.compact)
        if args.write_frontier:
            print(f"frontier -> {study.write_frontier(records)}")
        row = _print_summary(study)
        if args.emit_bench:
            _emit_bench({**row, "seed": study.seed})
        if getattr(args, "assert_no_exec", False) and row["executed_this_run"]:
            print(f"RESUME REGRESSION: {row['executed_this_run']} trials "
                  f"re-executed (expected 0)", file=sys.stderr)
            return 1
    return 0


def cmd_report(args) -> int:
    root = pathlib.Path(args.study)
    front = load_frontier(root / FRONTIER_FILE)
    names = front["objectives"]
    print(f"objectives: {names}  "
          f"(trials: {front['trials']['completed']} completed, "
          f"{front['trials']['infeasible']} infeasible)")
    for target, pts in front["groups"].items():
        print(f"\n## {target} ({len(pts)} frontier points)\n")
        cols = ["kind", "R", "degree", "fused", "batch"] + list(names)
        print("| " + " | ".join(cols) + " |")
        print("|" + "---|" * len(cols))
        for pt in pts:
            p = pt["params"]
            row = [p["kind"], p["lookup_bits"], pt["metrics"].get("degree"),
                   p["fused"], p["batch"]]
            row += [f"{v:.4g}" for v in pt["objectives"]]
            print("| " + " | ".join(str(v) for v in row) + " |")
    return 0


def cmd_check(args) -> int:
    fresh_path = pathlib.Path(args.study) / FRONTIER_FILE
    if not fresh_path.exists():
        print(f"no frontier at {fresh_path} — run the study to completion "
              f"first", file=sys.stderr)
        return 2
    fresh = load_frontier(fresh_path)
    committed = load_frontier(args.against)
    problems = compare_frontiers(fresh, committed)
    if problems:
        print(f"FRONTIER REGRESSION vs {args.against}:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    n = sum(len(v) for v in committed["groups"].values())
    print(f"frontier check OK: all {n} committed points attained")
    return 0


def cmd_plan(args) -> int:
    from repro.configs.base import get_config, get_smoke_config
    from repro.plan import save_plan
    from repro.plan.assign import auto_plan

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    report = auto_plan(cfg, error_budget=args.budget, target=args.target,
                       verify=not args.no_verify, seed=args.seed,
                       calibrate=args.calibrate)
    plan = report.plan
    print(f"plan[{report.arch}]: budget {report.error_budget:.3g} -> "
          f"predicted {report.predicted_error:.3g}"
          + (f", measured {report.measured_error:.3g}"
             if report.measured_error is not None else "")
          + f"; slots {list(plan.slot_keys())}"
          + (f", downgraded {list(report.flipped)}" if report.flipped else ""))
    kind = "measured" if report.calibration is not None else "modeled"
    print(f"  {kind} decode: {report.modeled_tokens_per_s:.1f} tok/s vs "
          f"{report.exact_tokens_per_s:.1f} all-exact "
          f"({report.speedup:.3f}x)")
    if args.save_plan:
        save_plan(args.save_plan, plan, seed=args.seed,
                  meta_extra={"arch": args.arch, "smoke": args.smoke,
                              "report": report.to_dict()})
        print(f"saved plan -> {args.save_plan}")
    if (report.measured_error is not None
            and report.measured_error > args.budget):
        print(f"PLAN ERROR BUDGET VIOLATED: {report.measured_error:.3g} > "
              f"{args.budget:.3g}", file=sys.stderr)
        return 1
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.dse")
    sub = ap.add_subparsers(dest="cmd", required=True)

    def common(p, with_space: bool):
        p.add_argument("--study", required=True, help="study directory")
        p.add_argument("--max-trials", type=int, default=None)
        p.add_argument("--compact", action="store_true",
                       help="fold the journal into snapshot.json afterwards")
        p.add_argument("--emit-bench", action="store_true",
                       help=f"fold a summary row into "
                            f"artifacts/bench/{BENCH_SNAPSHOT}")
        p.add_argument("--write-frontier", action="store_true",
                       help="emit frontier.json even if the space is only "
                            "partially journaled")
        if with_space:
            p.add_argument("--preset", choices=sorted(PRESETS),
                           default="smoke")
            p.add_argument("--space-json", default=None,
                           help="SearchSpace JSON file (overrides --preset)")
            p.add_argument("--measure", choices=("modeled", "wall", "none"),
                           default=None)
            p.add_argument("--seed", type=int, default=None)

    p_run = sub.add_parser("run", help="create/extend a study")
    common(p_run, with_space=True)

    p_res = sub.add_parser("resume", help="continue an existing study")
    common(p_res, with_space=False)
    p_res.add_argument("--assert-no-exec", action="store_true",
                       help="fail if any trial had to be (re-)executed")

    p_rep = sub.add_parser("report", help="print the frontier tables")
    p_rep.add_argument("--study", required=True)

    p_chk = sub.add_parser("check",
                           help="regression-check vs a committed frontier")
    p_chk.add_argument("--study", required=True)
    p_chk.add_argument("--against", required=True,
                       help="committed frontier artifact path")

    p_pln = sub.add_parser("plan", help="budget-driven per-layer numerics "
                                        "assignment (DESIGN.md §16)")
    from repro.configs.base import ARCH_IDS
    p_pln.add_argument("--arch", choices=ARCH_IDS, required=True)
    p_pln.add_argument("--smoke", action="store_true")
    p_pln.add_argument("--budget", type=float, default=0.05,
                       help="whole-model relative output-error bound")
    p_pln.add_argument("--target", choices=("asic", "fpga-lut", "pallas-tpu"),
                       default="asic",
                       help="frontier cost group the slots are picked from")
    p_pln.add_argument("--save-plan", default=None,
                       help="write the NumericsPlan snapshot here")
    p_pln.add_argument("--no-verify", action="store_true",
                       help="skip the measured end-to-end error check "
                            "(predicted budget only; no table compilation)")
    p_pln.add_argument("--calibrate", action="store_true",
                       help="score throughput from wall clock measured on "
                            "AOT-warmed fused ticks instead of the modeled "
                            "constants (machine-dependent; stored in the "
                            "snapshot under report.calibration)")
    p_pln.add_argument("--seed", type=int, default=0)

    args = ap.parse_args(argv)
    if args.cmd == "run":
        return cmd_run(args)
    if args.cmd == "resume":
        return cmd_run(args, resume_only=True)
    if args.cmd == "report":
        return cmd_report(args)
    if args.cmd == "plan":
        return cmd_plan(args)
    return cmd_check(args)


if __name__ == "__main__":
    sys.exit(main())
