"""Elastic scaling: re-shard a checkpoint onto a different device count.

When the straggler telemetry (train/trainer.py) or the fleet scheduler drops
hosts, the controller calls ``reshard_checkpoint``: the training state is
loaded host-side (numpy), new shardings are derived from the same rule
engine on the *new* mesh, and the arrays are device_put with the new layout.
Nothing about the rules is mesh-shape specific — the divisibility-checked
fallback chain picks new axes automatically (e.g. vocab sharded 16-way
re-shards 8-way, or falls to replication on a single device).

Also hosts ``remesh_state`` for in-memory re-sharding (no checkpoint round
trip) when the new mesh is visible from the same process.
"""
from __future__ import annotations

import jax

from repro.checkpoint import latest_step, restore
from repro.launch import sharding as shlib


def remesh_state(state, new_mesh, rules: dict | None = None):
    """Re-device_put a (possibly sharded) pytree onto a new mesh."""
    shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    specs = shlib.param_specs(shapes, new_mesh, rules)
    host = jax.tree.map(lambda x: jax.device_get(x), state)
    return jax.tree.map(lambda a, s: jax.device_put(a, s), host, specs)


def reshard_checkpoint(ckpt_dir: str, like, new_mesh, step: int | None = None,
                       rules: dict | None = None):
    """Load the latest (or given) checkpoint and place it on ``new_mesh``.

    Returns (step, resharded state). ``like`` provides the pytree structure
    (ShapeDtypeStructs or arrays).
    """
    s = latest_step(ckpt_dir) if step is None else step
    if s is None:
        raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    host_state, _ = restore(ckpt_dir, s, like)
    shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), host_state)
    specs = shlib.param_specs(shapes, new_mesh, rules)
    return s, jax.tree.map(lambda a, sp: jax.device_put(a, sp), host_state, specs)
