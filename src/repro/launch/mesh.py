"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
forces 512 host devices while tests/benches must see 1.

Axes:
  * ``pod``   — outer data parallelism across pods; crosses DCN. Gradient
    all-reduce on this axis is the slow hop (int8 EF compression applies).
  * ``data``  — data parallelism / FSDP (ZeRO-3 parameter+optimizer sharding)
    inside a pod; ICI.
  * ``model`` — tensor parallelism (Megatron column/row), expert parallelism
    for MoE, and sequence parallelism for long-context serving; ICI.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever this host actually has (tests, examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_serve_mesh(data: int = 1, tp: int = 1, *, devices=None):
    """Serving mesh (DESIGN.md §17): ``("data", "tp")``.

    * ``data`` — batch-slot parallelism: the engine's KV pool is sharded on
      its slot axis, each device group decodes its own slice of the batch.
    * ``tp``   — tensor parallelism: attention / KV heads and the Megatron
      column/row weight shards (``sharding.SERVE_PARAM_RULES``).

    The axis names are distinct from the training meshes so serve processes
    size each axis independently of the trainer rules; ``sharding``'s rule
    tables carry ``("tp",)`` candidates for exactly this mesh. Extra local
    devices beyond ``data * tp`` are left unused (a forced-host-device CI
    run can carve a 2x2 mesh out of 8 fake devices).
    """
    devs = list(devices if devices is not None else jax.devices())
    need = int(data) * int(tp)
    if need < 1:
        raise ValueError(f"mesh axes must be positive, got {data}x{tp}")
    if len(devs) < need:
        raise ValueError(
            f"serve mesh {data}x{tp} needs {need} devices, "
            f"have {len(devs)}")
    import numpy as np

    from jax.sharding import Mesh
    arr = np.asarray(devs[:need], dtype=object).reshape(int(data), int(tp))
    return Mesh(arr, ("data", "tp"))


def parse_mesh_spec(spec: str) -> tuple[int, int]:
    """``"2x4"`` -> ``(data=2, tp=4)``; a bare ``"4"`` means ``(4, 1)``."""
    s = spec.strip().lower()
    parts = s.split("x")
    if len(parts) == 1:
        parts = [parts[0], "1"]
    if len(parts) != 2:
        raise ValueError(f"mesh spec {spec!r}: expected 'DATAxTP'")
    try:
        data, tp = int(parts[0]), int(parts[1])
    except ValueError:
        raise ValueError(f"mesh spec {spec!r}: expected 'DATAxTP'") from None
    if data < 1 or tp < 1:
        raise ValueError(f"mesh spec {spec!r}: axes must be >= 1")
    return data, tp


# TPU v5e-class hardware constants used by the roofline (DESIGN.md §2)
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (~per-direction per chip, 1 axis)
DCN_BW = 6.25e9  # bytes/s per chip cross-pod (50 Gbit)
