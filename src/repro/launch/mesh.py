"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
forces 512 host devices while tests/benches must see 1.

Axes:
  * ``pod``   — outer data parallelism across pods; crosses DCN. Gradient
    all-reduce on this axis is the slow hop (int8 EF compression applies).
  * ``data``  — data parallelism / FSDP (ZeRO-3 parameter+optimizer sharding)
    inside a pod; ICI.
  * ``model`` — tensor parallelism (Megatron column/row), expert parallelism
    for MoE, and sequence parallelism for long-context serving; ICI.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever this host actually has (tests, examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


# TPU v5e-class hardware constants used by the roofline (DESIGN.md §2)
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (~per-direction per chip, 1 axis)
DCN_BW = 6.25e9  # bytes/s per chip cross-pod (50 Gbit)
