"""ShapeDtypeStruct stand-ins for every model input (dry-run signature).

``input_specs(cfg, shape)`` returns the abstract arguments for the jit'd
step that cell lowers: a training batch for ``train_*`` shapes, a prompt
batch for ``prefill_*``, and (token, pos, caches) for ``decode_*`` /
``long_*`` — one new token against a seq_len-deep cache, per the shape table.
No device allocation happens anywhere here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tf
from repro.models.layers import spec


def batch_shapes(cfg: ModelConfig, b: int, s: int) -> dict:
    out = {
        "tokens": spec((b, s), jnp.int32),
        "labels": spec((b, s), jnp.int32),
        "mask": spec((b, s), jnp.float32),
    }
    if cfg.frontend == "vision_stub":
        out["frontend_emb"] = spec((b, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
    elif cfg.frontend == "audio_stub":
        out["enc_frames"] = spec((b, cfg.encoder.source_len, cfg.d_model), jnp.float32)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract inputs keyed by argument name, per the cell's step kind."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": batch_shapes(cfg, b, s)}
    if shape.kind == "prefill":
        out = {"tokens": spec((b, s), jnp.int32)}
        if cfg.frontend == "vision_stub":
            out["frontend_emb"] = spec((b, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
        if cfg.encoder is not None:
            out["enc_frames"] = spec((b, cfg.encoder.source_len, cfg.d_model), jnp.float32)
        return out
    # decode: one token against a seq_len-deep cache
    out = {
        "token": spec((b, 1), jnp.int32),
        "pos": spec((), jnp.int32),
        "caches": tf.cache_shapes(cfg, b, s),
    }
    if cfg.encoder is not None:
        out["cross"] = spec((b, cfg.encoder.source_len, cfg.d_model),
                            jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32)
    return out
