"""GPipe-style pipeline parallelism over ``shard_map`` + ``lax.ppermute``.

The production dry-run mesh uses DP x TP (the right choice at these sizes on
a v5e-class pod); PP is provided for 1000+-node scaling headroom, where a
third mesh axis keeps TP domains inside an ICI-connected slice and pipelines
across slices.

Schedule: classic GPipe. ``n_stages`` devices each own ``layers/n_stages``
layers; ``n_micro`` microbatches stream through. Each outer tick every stage
(in parallel, SPMD) applies its block to its current microbatch and
``ppermute``s activations to the next stage. Bubble fraction is
``(S-1)/(M+S-1)``. The stage body is any ``(params, x) -> x`` function, so
models plug in per-segment.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(stage_params, x_micro, stage_fn: Callable, mesh: Mesh,
                   axis: str = "stage"):
    """Run microbatches through pipeline stages.

    stage_params: pytree with leading dim = n_stages (stage-sharded).
    x_micro: (n_micro, mb, ...) microbatched input, replicated.
    stage_fn: (params_for_stage, x) -> y, applied by every stage.
    Returns (n_micro, mb, ...) outputs after all stages.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]

    p_spec = jax.tree.map(lambda _: P(axis), stage_params)

    @partial(shard_map, mesh=mesh, in_specs=(p_spec, P()), out_specs=P(),
             check_rep=False)
    def run(params, xs):
        params = jax.tree.map(lambda a: a[0], params)  # this stage's slice
        idx = jax.lax.axis_index(axis)
        total = n_micro + n_stages - 1  # GPipe ticks incl. bubble
        buf = jnp.zeros_like(xs[0])  # current activation on this stage
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any left)
            feed = xs[jnp.clip(t, 0, n_micro - 1)]
            buf = jnp.where(idx == 0, jnp.where(t < n_micro, feed, buf), buf)
            # every stage processes its current microbatch
            y = stage_fn(params, buf)
            # last stage commits microbatch (t - (S-1)) once it's real
            out_slot = t - (n_stages - 1)
            commit = (idx == n_stages - 1) & (out_slot >= 0)
            outs = jax.lax.cond(
                commit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(out_slot, 0, n_micro - 1), 0),
                lambda o: o, outs)
            # rotate activations downstream
            buf = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return buf, outs

        _, outs = jax.lax.fori_loop(0, total, tick, (buf, outs))
        # outs live on the last stage; share them (replicated out_specs)
        outs = jax.lax.psum(
            jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    return run(stage_params, x_micro)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
