"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch yi_6b --smoke \
        --steps 50 --seq-len 256 --global-batch 8 [--numerics interp]

``--smoke`` selects the reduced config (CPU-runnable); without it the full
config is used (requires real accelerators). SIGTERM triggers a clean
save-and-exit (preemption handling). On a multi-host fleet this same entry
point runs per host under ``jax.distributed.initialize``; host sharding of
the batch comes from the data pipeline's ``lo/hi`` slicing.
"""
from __future__ import annotations

import argparse
import signal

import jax

from repro.configs.base import ARCH_IDS, get_config, get_smoke_config
from repro.launch import sharding as shlib
from repro.launch.mesh import make_host_mesh
from repro.train.step import StepConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--numerics", choices=["exact", "interp"], default=None)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.numerics:
        cfg = cfg.replace(numerics=args.numerics)

    tc = TrainerConfig(
        steps=args.steps, ckpt_dir=f"{args.ckpt_dir}/{args.arch}",
        ckpt_every=args.ckpt_every, seq_len=args.seq_len,
        global_batch=args.global_batch, seed=args.seed,
        step=StepConfig(microbatches=args.microbatches, peak_lr=args.lr,
                        warmup=args.warmup, total_steps=args.steps),
    )
    mesh = make_host_mesh(args.model_parallel)

    def shard_batch(b):
        sh = shlib.batch_specs({k: v for k, v in b.items()}, mesh)
        return jax.tree.map(jax.device_put, b, sh)

    trainer = Trainer(cfg, tc, mesh=mesh, shard_batch=shard_batch)
    signal.signal(signal.SIGTERM, lambda *_: trainer.request_stop())
    with mesh, shlib.axis_rules(mesh):
        hist = trainer.run()
    if trainer.stragglers:
        print(f"stragglers: {trainer.stragglers[:5]}")
    print(f"final loss {hist[-1]['loss']:.4f} over {len(hist)} steps")


if __name__ == "__main__":
    main()
