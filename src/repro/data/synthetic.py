"""Deterministic synthetic data pipeline.

Counter-based PRNG (Philox keyed on ``(seed, step)``) gives O(1) skip-ahead:
after a restart the trainer asks for ``batch_at(resume_step)`` and gets
bit-identical data with no state to checkpoint and no stream to replay. Each
host materializes only its own shard (``host_slice``), so the pipeline scales
to any number of data-parallel workers.

Tokens follow a Zipf-ish marginal (realistic softmax/router load for the
numerics tables) and labels are next-token targets within the batch.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _rng(seed: int, step: int, salt: int = 0) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(key=[seed + (salt << 32), step]))


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    u = rng.random(shape)
    # inverse-CDF of a truncated zipf(s=1.1) via the analytic pareto form
    z = ((vocab ** 0.1) - 1.0) * u + 1.0
    tok = (z ** 10.0 - 1.0).astype(np.int64)
    return np.clip(tok, 0, vocab - 1).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class SyntheticDataset:
    """Step-indexed synthetic batches for a (cfg, shape) cell."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # modality frontends (stubs per the assignment)
    frontend: str | None = None
    frontend_len: int = 0
    frontend_dim: int = 0
    source_len: int = 0
    d_model: int = 0

    def batch_at(self, step: int, lo: int = 0, hi: int | None = None) -> dict:
        """Global batch rows [lo, hi) for ``step`` (host sharding slice)."""
        hi = self.global_batch if hi is None else hi
        n = hi - lo
        rng = _rng(self.seed, step)
        toks = _zipf_tokens(rng, (self.global_batch, self.seq_len + 1), self.vocab_size)
        toks = toks[lo:hi]
        out = {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].copy(),
            "mask": np.ones((n, self.seq_len), np.float32),
        }
        if self.frontend == "vision_stub":
            out["frontend_emb"] = rng.standard_normal(
                (self.global_batch, self.frontend_len, self.frontend_dim),
                dtype=np.float32)[lo:hi]
        elif self.frontend == "audio_stub":
            out["enc_frames"] = rng.standard_normal(
                (self.global_batch, self.source_len, self.d_model),
                dtype=np.float32)[lo:hi]
        return out


def dataset_for(cfg, seq_len: int, global_batch: int, seed: int = 0) -> SyntheticDataset:
    return SyntheticDataset(
        vocab_size=cfg.vocab_size,
        seq_len=seq_len,
        global_batch=global_batch,
        seed=seed,
        frontend=cfg.frontend,
        frontend_len=cfg.frontend_len,
        frontend_dim=cfg.frontend_dim,
        source_len=cfg.encoder.source_len if cfg.encoder else 0,
        d_model=cfg.d_model,
    )


def make_batch(cfg, seq_len: int, batch: int, step: int = 0, seed: int = 0) -> dict:
    """Convenience: one full (small) batch as numpy, for tests/examples."""
    return dataset_for(cfg, seq_len, batch, seed).batch_at(step)
