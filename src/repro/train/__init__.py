from repro.train.step import TrainState, make_train_step, train_state_shapes  # noqa: F401
from repro.train.trainer import Trainer, TrainerConfig  # noqa: F401
