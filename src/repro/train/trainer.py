"""Trainer: jit'd step + checkpoint/restart + straggler telemetry.

Fault tolerance model (designed for 1000+ nodes, exercised here at small
scale):
  * **checkpoint/restart** — CheckpointManager saves atomically every N steps;
    on construction the trainer restores the latest committed step and the
    data pipeline skips ahead deterministically (counter-based PRNG keyed on
    the step index, no stream replay).
  * **straggler mitigation** — per-step wall time feeds an EMA; steps slower
    than ``straggler_factor x`` EMA are logged with their step index. On a
    real fleet this telemetry drives the elastic re-mesh path
    (``launch/elastic.py``): the controller drops the slow host and restarts
    from the last checkpoint on a smaller mesh. Both halves (detection here,
    re-shard there) are unit-tested.
  * **preemption** — ``request_stop()`` (wired to SIGTERM in launch/train.py)
    finishes the in-flight step, saves, and exits cleanly.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data.synthetic import dataset_for
from repro.train.step import StepConfig, TrainState, make_train_step, train_state_init


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    ckpt_keep: int = 3
    log_every: int = 10
    seed: int = 0
    seq_len: int = 256
    global_batch: int = 8
    straggler_factor: float = 3.0
    step: StepConfig = dataclasses.field(default_factory=StepConfig)


class Trainer:
    def __init__(self, cfg, tc: TrainerConfig, mesh=None, shard_batch=None,
                 shard_state=None):
        self.cfg, self.tc = cfg, tc
        self.mesh = mesh
        self.shard_batch = shard_batch or (lambda b: b)
        self.data = dataset_for(cfg, tc.seq_len, tc.global_batch, tc.seed)
        self.ckpt = CheckpointManager(tc.ckpt_dir, tc.ckpt_every, tc.ckpt_keep)
        self.step_fn = jax.jit(make_train_step(cfg, tc.step), donate_argnums=0)
        self._stop = False
        self.step_times: list[float] = []
        self.stragglers: list[tuple[int, float]] = []
        self.history: list[dict] = []

        state = train_state_init(jax.random.key(tc.seed), cfg, tc.step)
        if shard_state is not None:
            state = shard_state(state)
        self.start_step = 0
        got = self.ckpt.restore_latest(state)
        if got[0] is not None:
            self.start_step = got[0] + 1
            state = jax.tree.map(jax.numpy.asarray, got[1])
        self.state: TrainState = state

    def request_stop(self):
        self._stop = True

    def run(self) -> list[dict]:
        import jax.numpy as jnp

        ema = None
        for step in range(self.start_step, self.tc.steps):
            t0 = time.perf_counter()
            batch = {k: jnp.asarray(v) for k, v in self.data.batch_at(step).items()}
            batch = self.shard_batch(batch)
            self.state, metrics = self.step_fn(self.state, batch,
                                               jnp.asarray(step, jnp.int32))
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            # straggler telemetry (EMA excludes the compile-heavy first step)
            if step > self.start_step:
                if ema is not None and dt > self.tc.straggler_factor * ema:
                    self.stragglers.append((step, dt))
                ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            metrics["step"] = step
            metrics["wall_s"] = dt
            self.history.append(metrics)
            if step % self.tc.log_every == 0:
                print(f"step {step:5d} loss {metrics['loss']:.4f} "
                      f"lr {metrics['lr']:.2e} gnorm {metrics['grad_norm']:.2f} "
                      f"{dt*1e3:.0f} ms", flush=True)
            self.ckpt.maybe_save(step, self.state, {"step": step})
            if self._stop:
                self.ckpt.maybe_save(step, self.state, {"step": step}) \
                    or self._force_save(step)
                break
        return self.history

    def _force_save(self, step: int):
        from repro.checkpoint import save
        save(self.tc.ckpt_dir, step, self.state, {"step": step})
