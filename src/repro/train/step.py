"""The jit-compiled train step: microbatched grad accumulation, optional
cross-pod int8 error-feedback gradient compression, AdamW, LR schedule.

One function is lowered for every (arch x mesh) dry-run cell, so everything
here must be shape-polymorphic only through the config (no python state).

Compute/communication overlap: gradients are accumulated over microbatches
with ``lax.scan``; under GSPMD+latency-hiding-scheduler the per-microbatch
reduce-scatter of the previous slice overlaps the next microbatch's compute.
The cross-pod hop is deferred to once per step (after accumulation), where
the optional int8 compression cuts DCN bytes 4x.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.numerics.ops import get_numerics
from repro.optim.adamw import (AdamWState, adamw_init, adamw_state_shapes,
                               adamw_update)
from repro.optim.compress import compress_grads, compress_init, decompress_grads
from repro.optim.schedule import cosine_schedule


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState
    residual: dict | None  # error-feedback residual (compression on) or None


@dataclasses.dataclass(frozen=True)
class StepConfig:
    microbatches: int = 1
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    clip_norm: float = 1.0
    weight_decay: float = 0.1
    compress_pods: bool = False  # int8 EF compression on the pod axis


def train_state_init(key, cfg, step_cfg: "StepConfig | None" = None) -> TrainState:
    params = tf.init_params(key, cfg)
    res = compress_init(params) if (step_cfg and step_cfg.compress_pods) else None
    return TrainState(params, adamw_init(params), res)


def train_state_shapes(cfg, step_cfg: StepConfig) -> TrainState:
    ps = tf.model_shapes(cfg)
    res = (jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), ps)
           if step_cfg.compress_pods else None)
    return TrainState(ps, adamw_state_shapes(ps), res)


def _split_micro(batch: dict, n: int) -> dict:
    """(B, ...) -> (n, B/n, ...) for scan."""
    def r(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(cfg, step_cfg: StepConfig, library=None) -> Callable:
    """Returns step(state, batch, step_idx) -> (state, metrics).

    ``library``: optional compiled :class:`repro.api.InterpLibrary` binding
    the interp numerics to one packed artifact (closure leaf — jit folds the
    replicated coefficient ROM into the step like any other constant). When
    ``cfg.plan`` carries a :class:`repro.plan.NumericsPlan`, pass a dict
    keyed by the plan's slot keys instead (or None to compile per slot) —
    ``get_numerics`` resolves the per-layer backends either way, so a
    heterogeneous plan trains through the same step function."""
    numerics = get_numerics(cfg, library)
    pdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.param_dtype]

    def loss(p, mb):
        return tf.loss_fn(p, mb, cfg, numerics)

    def step(state: TrainState, batch: dict, step_idx: jax.Array):
        n = step_cfg.microbatches
        if n > 1:
            micro = _split_micro(batch, n)

            def acc_body(carry, mb):
                gsum, lsum, auxsum = carry
                (l, m), g = jax.value_and_grad(loss, has_aux=True)(state.params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l, auxsum + m["aux"]), None

            gz = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), state.params)
            (grads, lsum, auxsum), _ = jax.lax.scan(
                acc_body, (gz, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / n, grads)
            l, aux = lsum / n, auxsum / n
        else:
            (l, m), grads = jax.value_and_grad(loss, has_aux=True)(state.params, batch)
            aux = m["aux"]

        residual = state.residual
        if step_cfg.compress_pods and residual is not None:
            # DCN-side compression: quantize -> (implicit pod all-reduce via
            # GSPMD when grads are pod-sharded) -> dequantize, with EF residual
            payload, scales, residual = compress_grads(grads, residual)
            grads = decompress_grads(payload, scales)

        lr = cosine_schedule(step_idx, peak_lr=step_cfg.peak_lr,
                             warmup=step_cfg.warmup, total=step_cfg.total_steps)
        params, opt, om = adamw_update(
            grads, state.opt, lr, clip_norm=step_cfg.clip_norm,
            weight_decay=step_cfg.weight_decay, param_dtype=pdt)
        metrics = {"loss": l, "aux": aux, "lr": lr, "grad_norm": om["grad_norm"]}
        return TrainState(params, opt, residual), metrics

    return step


def make_eval_step(cfg, library=None) -> Callable:
    numerics = get_numerics(cfg, library)

    def eval_step(params, batch):
        l, m = tf.loss_fn(params, batch, cfg, numerics)
        return {"loss": l, **m}

    return eval_step
