"""GuardedNumerics: the degraded-mode wrapper around a numerics backend.

The certified tables only promise anything *inside* their proved input
domains: ``exp2neg`` over non-positive exponents, ``recip``/``rsqrt`` over
strictly positive operands, the activation tables over the generated
``[act_lo, act_hi)`` window (outside which the float glue's tails take
over). A poisoned activation — NaN from an upstream overflow, an Inf from
a bad prompt embedding, a negative variance from corrupted state — feeds
those lookups values with *no* certified meaning: ``frexp`` of a
non-positive operand silently yields garbage codes that gather arbitrary
ROM rows.

:class:`GuardedNumerics` wraps any backend and sanitizes every table input
into its certified domain first:

  * non-finite values are replaced by the nearest domain sentinel (NaN →
    the domain's safe center, +Inf/-Inf → the domain edges), so a poisoned
    element degrades to a *bounded wrong answer* instead of NaN-flooding
    the whole tick;
  * out-of-domain finite values are clamped to the domain edge — for the
    activation kinds this is exactly the tail semantics the unguarded glue
    already applies, so guarding is a no-op on healthy inputs.

When evaluated eagerly (host-side values, not under ``jit``) the guard
also *counts* violations per op in ``self.violations`` and, with
``strict=True``, raises :class:`DomainViolation` instead of clamping —
that is the mode the domain property tests drive. Under a trace the clamp
is silent (counting would need a host round-trip per op); in-program fault
detection there is the serve tick's NaN/Inf watchdog sentinel
(DESIGN.md §14), which is what escalates an engine onto this wrapper in
the first place.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# float32 extremes of the positive domains: below/above these, recip and
# rsqrt glue saturates rather than feeding frexp a non-positive operand.
# The floor is the smallest NORMAL float32: subnormals are both flushed to
# zero by XLA comparisons (the clamp itself would stop working) and
# overflow the glue's power-of-two rescale.
_POS_TINY = 1.1754944e-38  # 2**-126
_POS_HUGE = 3e38
_EXP_NEG_FLOOR = -126.0  # exp2 underflows to 0 below this anyway


class DomainViolation(RuntimeError):
    """A table input left its certified domain under ``strict=True``."""


def _is_concrete(x) -> bool:
    return not isinstance(x, jax.core.Tracer)


class GuardedNumerics:
    """Domain-guarding wrapper; delegates everything else to ``inner``."""

    def __init__(self, inner, *, strict: bool = False):
        self.inner = inner
        self.strict = bool(strict)
        self.violations: dict[str, int] = {}

    # the engine and model stack probe these on whatever backend they hold
    @property
    def name(self) -> str:
        return self.inner.name

    @property
    def library(self):
        return self.inner.library

    def __getattr__(self, attr):
        # unguarded capabilities (e.g. fused_attention) pass through; the
        # guard only interposes on the table-input entry points below
        return getattr(self.inner, attr)

    # -- sanitization core -------------------------------------------------
    def _guard(self, op: str, x, lo, hi, nan_to):
        xf = jnp.asarray(x, jnp.float32) if not hasattr(x, "dtype") else x
        xf32 = xf.astype(jnp.float32)
        bad = ~jnp.isfinite(xf32) | (xf32 < lo) | (xf32 > hi)
        if _is_concrete(bad):
            n = int(jnp.sum(bad))
            if n:
                self.violations[op] = self.violations.get(op, 0) + n
                if self.strict:
                    raise DomainViolation(
                        f"{op}: {n} input(s) outside certified domain "
                        f"[{lo}, {hi}] (or non-finite)")
        clean = jnp.clip(jnp.nan_to_num(xf32, nan=nan_to, posinf=hi,
                                        neginf=lo), lo, hi)
        return jnp.where(bad, clean, xf32).astype(xf.dtype)

    def _act_window(self, kind: str):
        lib = self.library
        if lib is not None and kind in lib:
            m = lib.meta(kind)
            return m.act_lo, m.act_hi
        from repro.core.funcspec import ACT_HI, ACT_LO

        return ACT_LO, ACT_HI

    # -- guarded table entry points ---------------------------------------
    def exp_neg(self, x):
        return self.inner.exp_neg(
            self._guard("exp_neg", x, _EXP_NEG_FLOOR, 0.0, nan_to=_EXP_NEG_FLOOR))

    def recip_pos(self, x):
        return self.inner.recip_pos(
            self._guard("recip_pos", x, _POS_TINY, _POS_HUGE, nan_to=1.0))

    def rsqrt_pos(self, x):
        return self.inner.rsqrt_pos(
            self._guard("rsqrt_pos", x, _POS_TINY, _POS_HUGE, nan_to=1.0))

    def _act(self, kind: str, x):
        lo, hi = self._act_window(kind)
        # finite out-of-window inputs are the tails' job (certified glue);
        # the guard only repairs non-finite poison, mapping it to the same
        # saturation the tails produce at the window edges
        xf = x.astype(jnp.float32)
        bad = ~jnp.isfinite(xf)
        if _is_concrete(bad):
            n = int(jnp.sum(bad))
            if n:
                self.violations[kind] = self.violations.get(kind, 0) + n
                if self.strict:
                    raise DomainViolation(f"{kind}: {n} non-finite input(s)")
        clean = jnp.nan_to_num(xf, nan=0.0, posinf=hi, neginf=lo)
        y = getattr(self.inner, kind)(jnp.where(bad, clean, xf))
        return y.astype(x.dtype)

    def silu(self, x):
        return self._act("silu", x)

    def sigmoid(self, x):
        return self._act("sigmoid", x)

    def softplus(self, x):
        return self._act("softplus", x)

    def gelu(self, x):
        return self._act("gelu", x)

    def tanh(self, x):
        return self._act("tanh", x)

    # -- guarded composites ------------------------------------------------
    def softmax(self, x, axis: int = -1):
        xf = x.astype(jnp.float32)
        m = jax.lax.stop_gradient(jnp.max(xf, axis=axis, keepdims=True))
        e = self.exp_neg(xf - m)
        s = jnp.sum(e, axis=axis, keepdims=True)
        return (e * self.recip_pos(s)).astype(x.dtype)

    def rmsnorm(self, x, gamma, eps: float = 1e-6):
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True) + eps
        return (xf * self.rsqrt_pos(var) * gamma).astype(x.dtype)

    def total_violations(self) -> int:
        return sum(self.violations.values())
