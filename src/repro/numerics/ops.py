"""JAX evaluation of generated tables + approximate transcendental ops.

This is the integration layer between the paper's artifacts and the model
stack: pure-jnp (GSPMD-shardable) implementations of softmax / rsqrt / SiLU /
exp built on the certified piecewise-polynomial tables. The Pallas kernels in
``repro.kernels`` fuse the same math for the hot paths; these functions are
their reference semantics and the portable fallback used inside the large
models (so the multi-pod dry-run lowers identically on any backend).

Float glue (max-subtract, exponent split, power-of-two scaling) is exact
hardware-wise — only the table lookups carry approximation error, and those
errors are *proved* bounds from table verification.

Since ISSUE 3 the backends are *instances*: ``get_numerics(cfg)`` returns an
object, and the interp backend can be bound to a compiled
:class:`repro.api.InterpLibrary` so every lookup resolves against one packed
artifact (no process-global registry on the hot path). Unbound instances
fall back to the default Explorer session, preserving the legacy behavior.
The float glue is shared between the per-table and library paths — the two
differ only in who evaluates the integer table, which is exactly the part
the golden tests pin bit-for-bit.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.api import get_table
from repro.core.funcspec import ACT_HI, ACT_LO, act_out_span
from repro.core.table import TableDesign

LOG2E = 1.4426950408889634


def table_eval_int(codes: jax.Array, design: TableDesign) -> jax.Array:
    """Evaluate a table on int32 input codes (exact integer semantics).

    Designs whose coefficients exceed int32 route to the emulated-int64
    path (DESIGN.md §7.5) instead of silently wrapping through the int32
    device cache."""
    if not design.fits_int32:
        from repro.kernels.interp.ref import interp_eval_wide

        return interp_eval_wide(codes, design.device_coeffs_wide(),
                                eval_bits=design.eval_bits, k=design.k,
                                sq_trunc=design.sq_trunc,
                                lin_trunc=design.lin_trunc,
                                degree=design.degree)
    w = design.eval_bits
    coeffs = design.device_coeffs()
    r = jax.lax.shift_right_logical(codes, w)
    x = jnp.bitwise_and(codes, (1 << w) - 1)
    sel = coeffs[r]  # gather: (..., 3)
    xs = jax.lax.shift_left(jax.lax.shift_right_logical(x, design.sq_trunc), design.sq_trunc)
    xl = jax.lax.shift_left(jax.lax.shift_right_logical(x, design.lin_trunc), design.lin_trunc)
    acc = sel[..., 0] * xs * xs + sel[..., 1] * xl + sel[..., 2]
    return jax.lax.shift_right_arithmetic(acc, design.k)


def _quantize(v: jax.Array, bits: int) -> jax.Array:
    """Map v in [0, 1) to an input code (round-to-nearest, clamped)."""
    q = jnp.round(v * (1 << bits)).astype(jnp.int32)
    return jnp.clip(q, 0, (1 << bits) - 1)


# ---------------------------------------------------------------------------
# float glue, parameterized over the integer table evaluator. ``ev`` maps
# int32 codes to the table's integer output; in_bits/out_bits come from the
# design or the library metadata. Exactly one implementation of each glue
# exists, so the per-table and library-bound paths cannot drift.
# ---------------------------------------------------------------------------

def _exp_neg_glue(x, in_bits: int, out_bits: int, ev) -> jax.Array:
    """exp(x) for x <= 0:  2^(x*log2e) = 2^(-n) * tab(-f)."""
    t = jnp.maximum(-x, 0.0).astype(jnp.float32) * LOG2E
    t = jnp.minimum(t, 126.0)  # below fp32 denormal cliff anyway
    n = jnp.floor(t)
    f = t - n  # in [0, 1)
    codes = _quantize(f, in_bits)
    frac = ev(codes).astype(jnp.float32) * (2.0 ** -out_bits)
    return frac * jnp.exp2(-n)  # exp2 of an integer == exact exponent shift


def _recip_pos_glue(x, in_bits: int, ev) -> jax.Array:
    """1/(m * 2^e) = recip(m) * 2^-e,  m in [1, 2)."""
    m, e = jnp.frexp(x.astype(jnp.float32))  # m in [0.5, 1)
    m2 = 2.0 * m  # [1, 2)
    codes = _quantize(m2 - 1.0, in_bits)
    # table target: V = 2^(2b+1)/(2^b + Z)  ==  (1/m2) * 2^(bits+1)
    val = ev(codes).astype(jnp.float32) * (2.0 ** -(in_bits + 1))
    return val * jnp.exp2(1.0 - e.astype(jnp.float32))  # 1/x = (1/m2) * 2^(1-e)


def _rsqrt_pos_glue(x, in_bits: int, out_bits: int, ev) -> jax.Array:
    """x = v * 4^h, v in [1,4);  rsqrt = tab(v) * 2^-h."""
    m, e = jnp.frexp(x.astype(jnp.float32))  # x = m * 2^e, m in [0.5, 1)
    e = e.astype(jnp.int32)
    odd = jnp.bitwise_and(e, 1)  # e odd -> v = m*2 in [1,2); even -> v = m*4 in [2,4)
    v = jnp.where(odd == 1, 2.0 * m, 4.0 * m)
    h = jnp.where(odd == 1, (e - 1) // 2, (e - 2) // 2)
    half = 1 << (in_bits - 1)
    codes = jnp.where(
        odd == 1,
        _quantize(v - 1.0, in_bits - 1),
        half + _quantize((v - 2.0) * 0.5, in_bits - 1),
    ).astype(jnp.int32)
    codes = jnp.clip(codes, 0, (1 << in_bits) - 1)
    val = ev(codes).astype(jnp.float32) * (2.0 ** -out_bits)
    return val * jnp.exp2(-h.astype(jnp.float32))


def _range_glue(x, in_bits: int, out_bits: int, span: float, ev,
                lo: float = ACT_LO, hi: float = ACT_HI) -> jax.Array:
    """Direct table over [lo, hi): quantize the window, rescale the output."""
    xc = jnp.clip(x.astype(jnp.float32), lo, hi - 1e-6)
    codes = _quantize((xc - lo) / (hi - lo), in_bits)
    return ev(codes).astype(jnp.float32) * (span / (1 << out_bits))


def _act_tails(kind: str, x, y, lo: float = ACT_LO, hi: float = ACT_HI):
    """Outside the table window the activations are linear (right tail) or
    saturate; sigmoid saturates to 1/0, tanh to 1/-1, the rest to x/0."""
    top = 1.0 if kind in ("sigmoid", "tanh") else x
    bot = -1.0 if kind == "tanh" else 0.0
    return jnp.where(x >= hi, top, jnp.where(x <= lo, bot, y)).astype(x.dtype)


# ---------------------------------------------------------------------------
# per-table entry points (design argument; default = the process session).
# These remain the bit-exactness oracle for the library-fused path.
# ---------------------------------------------------------------------------

def _tab(kind: str, design: TableDesign | None) -> TableDesign:
    return design if design is not None else get_table(kind)


def approx_exp_neg(x: jax.Array, design: TableDesign | None = None) -> jax.Array:
    """exp(x) for x <= 0 via the exp2neg table; exact power-of-two scaling."""
    d = _tab("exp2neg", design)
    return _exp_neg_glue(x, d.in_bits, d.out_bits, lambda c: table_eval_int(c, d))


def approx_recip_pos(x: jax.Array, design: TableDesign | None = None) -> jax.Array:
    d = _tab("recip", design)
    return _recip_pos_glue(x, d.in_bits, lambda c: table_eval_int(c, d))


def approx_rsqrt_pos(x: jax.Array, design: TableDesign | None = None) -> jax.Array:
    d = _tab("rsqrt", design)
    return _rsqrt_pos_glue(x, d.in_bits, d.out_bits, lambda c: table_eval_int(c, d))


def _approx_act(kind: str, x: jax.Array, design: TableDesign | None) -> jax.Array:
    d = _tab(kind, design)
    y = _range_glue(x, d.in_bits, d.out_bits, act_out_span(kind),
                    lambda c: table_eval_int(c, d))
    return _act_tails(kind, x, y)


def approx_silu(x: jax.Array, design: TableDesign | None = None) -> jax.Array:
    return _approx_act("silu", x, design)


def approx_sigmoid(x: jax.Array, design: TableDesign | None = None) -> jax.Array:
    return _approx_act("sigmoid", x, design)


def approx_softplus(x: jax.Array, design: TableDesign | None = None) -> jax.Array:
    return _approx_act("softplus", x, design)


def approx_gelu(x: jax.Array, design: TableDesign | None = None) -> jax.Array:
    return _approx_act("gelu", x, design)


def approx_tanh(x: jax.Array, design: TableDesign | None = None) -> jax.Array:
    return _approx_act("tanh", x, design)


# ---------------------------------------------------------------------------
# composite ops
# ---------------------------------------------------------------------------

def approx_softmax(x: jax.Array, axis: int = -1,
                   exp_design: TableDesign | None = None,
                   recip_design: TableDesign | None = None) -> jax.Array:
    """Softmax with table-backed exponential and normalization reciprocal."""
    xf = x.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(xf, axis=axis, keepdims=True))
    e = approx_exp_neg(xf - m, exp_design)
    s = jnp.sum(e, axis=axis, keepdims=True)
    return (e * approx_recip_pos(s, recip_design)).astype(x.dtype)


def approx_rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6,
                   design: TableDesign | None = None) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True) + eps
    return (xf * approx_rsqrt_pos(var, design) * gamma).astype(x.dtype)


# ---------------------------------------------------------------------------
# numerics backends handed to the model stack
# ---------------------------------------------------------------------------

class ExactNumerics:
    """Plain XLA transcendentals (the no-technique baseline)."""

    name = "exact"
    library = None

    softmax = staticmethod(jax.nn.softmax)
    silu = staticmethod(jax.nn.silu)
    gelu = staticmethod(partial(jax.nn.gelu, approximate=True))
    sigmoid = staticmethod(jax.nn.sigmoid)
    softplus = staticmethod(jax.nn.softplus)
    tanh = staticmethod(jnp.tanh)

    @staticmethod
    def exp_neg(x):
        return jnp.exp(x)

    @staticmethod
    def rmsnorm(x, gamma, eps=1e-6):
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True) + eps
        return (xf * jax.lax.rsqrt(var) * gamma).astype(x.dtype)

    @staticmethod
    def recip_pos(x):
        return 1.0 / x


class InterpNumerics:
    """The paper's technique as the model's numerics backend.

    An instance optionally binds a compiled :class:`repro.api.InterpLibrary`
    — then every table lookup evaluates through the library's packed ROM
    (one artifact, no registry, fused Pallas kernel on TPU) and the instance
    never calls the default Explorer. Unbound (``library=None``, the legacy
    behavior and the ``get_numerics("interp")`` default) each op resolves
    its table lazily through ``repro.api.get_table``.
    """

    name = "interp"

    def __init__(self, library=None):
        self.library = library

    def _ev(self, kind: str):
        """(in_bits, out_bits, int-evaluator) for ``kind``."""
        lib = self.library
        if lib is not None:
            m = lib.meta(kind)  # KeyError = artifact missing a used kind
            return m.in_bits, m.out_bits, lambda c: lib.eval_int(c, kind)
        d = get_table(kind)
        return d.in_bits, d.out_bits, lambda c: table_eval_int(c, d)

    def exp_neg(self, x):
        ib, ob, ev = self._ev("exp2neg")
        return _exp_neg_glue(x, ib, ob, ev)

    def recip_pos(self, x):
        ib, _, ev = self._ev("recip")
        return _recip_pos_glue(x, ib, ev)

    def rsqrt_pos(self, x):
        ib, ob, ev = self._ev("rsqrt")
        return _rsqrt_pos_glue(x, ib, ob, ev)

    def _act(self, kind: str, x):
        lib = self.library
        if lib is not None:
            # the artifact records the window the table was generated over —
            # honor it (a custom-window library must not quantize over the
            # defaults)
            m = lib.meta(kind)
            y = _range_glue(x, m.in_bits, m.out_bits, m.act_span,
                            lambda c: lib.eval_int(c, kind),
                            m.act_lo, m.act_hi)
            return _act_tails(kind, x, y, m.act_lo, m.act_hi)
        ib, ob, ev = self._ev(kind)
        return _act_tails(kind, x, _range_glue(x, ib, ob, act_out_span(kind), ev))

    def silu(self, x):
        return self._act("silu", x)

    def sigmoid(self, x):
        return self._act("sigmoid", x)

    def softplus(self, x):
        return self._act("softplus", x)

    def gelu(self, x):
        return self._act("gelu", x)

    def tanh(self, x):
        return self._act("tanh", x)

    def softmax(self, x, axis: int = -1):
        xf = x.astype(jnp.float32)
        m = jax.lax.stop_gradient(jnp.max(xf, axis=axis, keepdims=True))
        e = self.exp_neg(xf - m)
        s = jnp.sum(e, axis=axis, keepdims=True)
        return (e * self.recip_pos(s)).astype(x.dtype)

    def rmsnorm(self, x, gamma, eps: float = 1e-6):
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True) + eps
        return (xf * self.rsqrt_pos(var) * gamma).astype(x.dtype)


class FusedInterpNumerics(InterpNumerics):
    """Library-bound interp numerics with fused-kernel lowering.

    Same certified tables, different datapath: softmax, rmsnorm and the
    attention inner loop lower to the library-bound fused kernels
    (``kernels/{softmax,rmsnorm,flashattn}``) — the ROM gather and the
    fixed-point Horner evaluation happen *inside* the consuming kernel, so
    a decode layer is O(1) kernel launches instead of a gather→eval→
    elementwise chain per transcendental. Off-TPU the same ops run through
    the fused jnp oracles (bit-identical integer datapath, identical glue).

    Float-level caveat: the fused reciprocal/rsqrt glue derives table codes
    by IEEE-754 bit twiddles where the unfused glue uses ``frexp`` — the
    int datapath is bit-identical (golden-tested per kind against
    ``table_eval_int``), but composite float outputs may differ by one
    table ulp from :class:`InterpNumerics`. The engine-level oracles
    therefore compare fused-vs-fused runs.
    """

    name = "interp"
    fused = True

    def __init__(self, library):
        if library is None:
            raise ValueError(
                "FusedInterpNumerics needs a compiled InterpLibrary: the "
                "fused kernels thread its ROM as an operand (compile one "
                "with Explorer.compile() or pass fused=False)")
        super().__init__(library)

    def softmax(self, x, axis: int = -1):
        if axis not in (-1, x.ndim - 1):
            return super().softmax(x, axis=axis)
        # local import: kernels.flashattn.ref imports this module
        from repro.kernels.softmax.ops import approx_softmax_library

        return approx_softmax_library(x, self.library).astype(x.dtype)

    def rmsnorm(self, x, gamma, eps: float = 1e-6):
        from repro.kernels.rmsnorm.ops import approx_rmsnorm_library

        return approx_rmsnorm_library(x, gamma, self.library,
                                      eps=eps).astype(x.dtype)

    def fused_attention(self, q, k, v, q_pos, kv_pos, *, causal, window,
                        scale):
        """The ``attention_core`` fast path: whole-datapath flash attention
        with the library ROM inlined. Returns None (caller falls back to
        the chunked glue path) when the layout is unsupported."""
        from repro.kernels.flashattn.ops import attention_fused_library

        b, sq, h, d = q.shape
        kvh = k.shape[2]
        if h % kvh:
            return None
        if k.shape[1] > 4096:
            # the kernel holds the whole K/V stripe per program (the
            # flashattn VMEM bound); longer contexts keep the chunked
            # memory-bounded glue path on every backend
            return None
        if sq * k.shape[1] > (1 << 22) and jax.default_backend() != "tpu":
            # the off-TPU oracle materializes the (N, Sq, Sk) score block;
            # long-context prefill stays on the chunked glue path there
            return None
        # grouped kv heads pass through unexpanded: the kernel maps each
        # query-head program onto its kv stripe by index
        return attention_fused_library(q, k, v, self.library, causal=causal,
                                       window=window, scale=scale,
                                       q_pos=q_pos, kv_pos=kv_pos)


BACKENDS = {"exact": ExactNumerics, "interp": InterpNumerics,
            "interp-fused": FusedInterpNumerics}

INTERP_BACKENDS = ("interp", "interp-fused", "interp-guarded")


def get_numerics(cfg_or_name="exact", library=None, fused: bool = False):
    """Resolve a numerics backend *instance* for a model config (or a plain
    backend name). ``library`` binds the interp backend to a compiled
    :class:`repro.api.InterpLibrary`; the exact backend gets the trivial
    instance (no tables to bind). ``fused=True`` (or the explicit
    ``"interp-fused"`` name) selects the fused-kernel lowering — softmax /
    rmsnorm / attention evaluate the library ROM *inside* the consuming
    kernel; it requires a bound library. ``"interp-guarded"`` is the
    degraded-mode backend (DESIGN.md §14): the same per-table interp
    datapath behind the :class:`repro.numerics.guard.GuardedNumerics`
    domain clamp.

    A config carrying a :class:`repro.plan.NumericsPlan` resolves to a
    :class:`repro.plan.numerics.PlanNumerics` instead — per-layer x per-site
    backends; ``fused`` is then ignored (each site assignment names its own
    lowering) and ``library`` may be a dict keyed by plan slot."""
    plan = getattr(cfg_or_name, "plan", None)
    if plan is not None:
        from repro.plan.numerics import plan_numerics

        return plan_numerics(plan, libraries=library)
    name = getattr(cfg_or_name, "numerics", cfg_or_name)
    if name == "exact":
        return ExactNumerics()
    if name == "interp-guarded":
        from repro.numerics.guard import GuardedNumerics

        return GuardedNumerics(InterpNumerics(library))
    if name == "interp-fused" or (name == "interp" and fused):
        return FusedInterpNumerics(library)
    if name == "interp":
        return InterpNumerics(library)
    raise KeyError(f"unknown numerics backend {name!r}")


def softmax_ulp_bound(exp_design=None, recip_design=None) -> float:
    """Certified relative error bound of approx_softmax terms, from the
    tables' verified ULP guarantees (used by tests and EXPERIMENTS.md).
    Accepts ``TableDesign`` or library ``FuncMeta`` (only widths are read);
    ``None`` resolves through the default session."""
    exp_design = exp_design or get_table("exp2neg")
    recip_design = recip_design or get_table("recip")
    # quantization of f adds 1/2 ulp of 2^-in_bits in the exponent argument
    exp_rel = (2.0 ** -exp_design.out_bits) * 2 + math.log(2.0) * 2.0 ** -(exp_design.in_bits + 1)
    recip_rel = 2.0 ** -recip_design.in_bits  # quantization + 1 ulp of output
    return 2 * exp_rel + 2 * recip_rel
