"""JAX evaluation of generated tables + approximate transcendental ops.

This is the integration layer between the paper's artifacts and the model
stack: pure-jnp (GSPMD-shardable) implementations of softmax / rsqrt / SiLU /
exp built on the certified piecewise-polynomial tables. The Pallas kernels in
``repro.kernels`` fuse the same math for the hot paths; these functions are
their reference semantics and the portable fallback used inside the large
models (so the multi-pod dry-run lowers identically on any backend).

Float glue (max-subtract, exponent split, power-of-two scaling) is exact
hardware-wise — only the table lookups carry approximation error, and those
errors are *proved* bounds from table verification.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.table import TableDesign
from repro.api import get_table

LOG2E = 1.4426950408889634


def table_eval_int(codes: jax.Array, design: TableDesign) -> jax.Array:
    """Evaluate a table on int32 input codes (exact integer semantics)."""
    w = design.eval_bits
    coeffs = jnp.asarray(np.stack([design.a, design.b, design.c], 1), jnp.int32)
    r = jax.lax.shift_right_logical(codes, w)
    x = jnp.bitwise_and(codes, (1 << w) - 1)
    sel = coeffs[r]  # gather: (..., 3)
    xs = jax.lax.shift_left(jax.lax.shift_right_logical(x, design.sq_trunc), design.sq_trunc)
    xl = jax.lax.shift_left(jax.lax.shift_right_logical(x, design.lin_trunc), design.lin_trunc)
    acc = sel[..., 0] * xs * xs + sel[..., 1] * xl + sel[..., 2]
    return jax.lax.shift_right_arithmetic(acc, design.k)


def _quantize(v: jax.Array, bits: int) -> jax.Array:
    """Map v in [0, 1) to an input code (round-to-nearest, clamped)."""
    q = jnp.round(v * (1 << bits)).astype(jnp.int32)
    return jnp.clip(q, 0, (1 << bits) - 1)


# ---------------------------------------------------------------------------
# exp(x) for x <= 0  (softmax exponential):  2^(x*log2e) = 2^(-n) * 2^(-f)
# ---------------------------------------------------------------------------

def approx_exp_neg(x: jax.Array, design: TableDesign | None = None) -> jax.Array:
    """exp(x) for x <= 0 via the exp2neg table; exact power-of-two scaling."""
    design = design or get_table("exp2neg")
    t = jnp.maximum(-x, 0.0).astype(jnp.float32) * LOG2E
    t = jnp.minimum(t, 126.0)  # below fp32 denormal cliff anyway
    n = jnp.floor(t)
    f = t - n  # in [0, 1)
    codes = _quantize(f, design.in_bits)
    frac = table_eval_int(codes, design).astype(jnp.float32) * (2.0 ** -design.out_bits)
    return frac * jnp.exp2(-n)  # exp2 of an integer == exact exponent shift


# ---------------------------------------------------------------------------
# reciprocal of positive floats:  1/(m * 2^e) = recip(m) * 2^-e,  m in [1, 2)
# ---------------------------------------------------------------------------

def approx_recip_pos(x: jax.Array, design: TableDesign | None = None) -> jax.Array:
    design = design or get_table("recip")
    m, e = jnp.frexp(x.astype(jnp.float32))  # m in [0.5, 1)
    m2 = 2.0 * m  # [1, 2)
    codes = _quantize(m2 - 1.0, design.in_bits)
    # table target: V = 2^(2b+1)/(2^b + Z)  ==  (1/m2) * 2^(bits+1)
    val = table_eval_int(codes, design).astype(jnp.float32) * (2.0 ** -(design.in_bits + 1))
    return val * jnp.exp2(1.0 - e.astype(jnp.float32))  # 1/x = (1/m2) * 2^(1-e)


# ---------------------------------------------------------------------------
# rsqrt of positive floats:  x = v * 4^h, v in [1,4);  rsqrt = tab(v) * 2^-h
# ---------------------------------------------------------------------------

def approx_rsqrt_pos(x: jax.Array, design: TableDesign | None = None) -> jax.Array:
    design = design or get_table("rsqrt")
    m, e = jnp.frexp(x.astype(jnp.float32))  # x = m * 2^e, m in [0.5, 1)
    e = e.astype(jnp.int32)
    odd = jnp.bitwise_and(e, 1)  # e odd -> v = m*2 in [1,2); even -> v = m*4 in [2,4)
    v = jnp.where(odd == 1, 2.0 * m, 4.0 * m)
    h = jnp.where(odd == 1, (e - 1) // 2, (e - 2) // 2)
    half = 1 << (design.in_bits - 1)
    codes = jnp.where(
        odd == 1,
        _quantize(v - 1.0, design.in_bits - 1),
        half + _quantize((v - 2.0) * 0.5, design.in_bits - 1),
    ).astype(jnp.int32)
    codes = jnp.clip(codes, 0, (1 << design.in_bits) - 1)
    val = table_eval_int(codes, design).astype(jnp.float32) * (2.0 ** -design.out_bits)
    return val * jnp.exp2(-h.astype(jnp.float32))


# ---------------------------------------------------------------------------
# bounded-range activations (SiLU / sigmoid / softplus / GELU): direct tables
# ---------------------------------------------------------------------------

def _range_table_eval(x: jax.Array, design: TableDesign, lo: float, hi: float,
                      out_scale: float) -> jax.Array:
    xc = jnp.clip(x.astype(jnp.float32), lo, hi - 1e-6)
    codes = _quantize((xc - lo) / (hi - lo), design.in_bits)
    return table_eval_int(codes, design).astype(jnp.float32) * out_scale


def approx_silu(x: jax.Array, design: TableDesign | None = None) -> jax.Array:
    design = design or get_table("silu")
    y = _range_table_eval(x, design, -8.0, 8.0, 16.0 / (1 << design.out_bits))
    # outside the table range silu(x) ~= x (right) or ~= 0 (left)
    return jnp.where(x >= 8.0, x, jnp.where(x <= -8.0, 0.0, y)).astype(x.dtype)


def approx_sigmoid(x: jax.Array, design: TableDesign | None = None) -> jax.Array:
    design = design or get_table("sigmoid")
    y = _range_table_eval(x, design, -8.0, 8.0, 1.0 / (1 << design.out_bits))
    return jnp.where(x >= 8.0, 1.0, jnp.where(x <= -8.0, 0.0, y)).astype(x.dtype)


def approx_softplus(x: jax.Array, design: TableDesign | None = None) -> jax.Array:
    design = design or get_table("softplus")
    y = _range_table_eval(x, design, -8.0, 8.0, 16.0 / (1 << design.out_bits))
    return jnp.where(x >= 8.0, x, jnp.where(x <= -8.0, 0.0, y)).astype(x.dtype)


def approx_gelu(x: jax.Array, design: TableDesign | None = None) -> jax.Array:
    design = design or get_table("gelu")
    y = _range_table_eval(x, design, -8.0, 8.0, 16.0 / (1 << design.out_bits))
    return jnp.where(x >= 8.0, x, jnp.where(x <= -8.0, 0.0, y)).astype(x.dtype)


# ---------------------------------------------------------------------------
# composite ops
# ---------------------------------------------------------------------------

def approx_softmax(x: jax.Array, axis: int = -1,
                   exp_design: TableDesign | None = None,
                   recip_design: TableDesign | None = None) -> jax.Array:
    """Softmax with table-backed exponential and normalization reciprocal."""
    xf = x.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(xf, axis=axis, keepdims=True))
    e = approx_exp_neg(xf - m, exp_design)
    s = jnp.sum(e, axis=axis, keepdims=True)
    return (e * approx_recip_pos(s, recip_design)).astype(x.dtype)


def approx_rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6,
                   design: TableDesign | None = None) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True) + eps
    return (xf * approx_rsqrt_pos(var, design) * gamma).astype(x.dtype)


# ---------------------------------------------------------------------------
# numerics backends handed to the model stack
# ---------------------------------------------------------------------------

class ExactNumerics:
    """Plain XLA transcendentals (the no-technique baseline)."""

    name = "exact"

    softmax = staticmethod(jax.nn.softmax)
    silu = staticmethod(jax.nn.silu)
    gelu = staticmethod(partial(jax.nn.gelu, approximate=True))
    sigmoid = staticmethod(jax.nn.sigmoid)
    softplus = staticmethod(jax.nn.softplus)

    @staticmethod
    def exp_neg(x):
        return jnp.exp(x)

    @staticmethod
    def rmsnorm(x, gamma, eps=1e-6):
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True) + eps
        return (xf * jax.lax.rsqrt(var) * gamma).astype(x.dtype)

    @staticmethod
    def recip_pos(x):
        return 1.0 / x


class InterpNumerics:
    """The paper's technique as the model's numerics backend."""

    name = "interp"

    softmax = staticmethod(approx_softmax)
    silu = staticmethod(approx_silu)
    gelu = staticmethod(approx_gelu)
    sigmoid = staticmethod(approx_sigmoid)
    softplus = staticmethod(approx_softplus)
    exp_neg = staticmethod(approx_exp_neg)
    rmsnorm = staticmethod(approx_rmsnorm)
    recip_pos = staticmethod(approx_recip_pos)


BACKENDS = {"exact": ExactNumerics, "interp": InterpNumerics}


def get_numerics(name: str):
    return BACKENDS[name]


def softmax_ulp_bound(exp_design: TableDesign | None = None,
                      recip_design: TableDesign | None = None) -> float:
    """Certified relative error bound of approx_softmax terms, from the
    tables' verified ULP guarantees (used by tests and EXPERIMENTS.md)."""
    exp_design = exp_design or get_table("exp2neg")
    recip_design = recip_design or get_table("recip")
    # quantization of f adds 1/2 ulp of 2^-in_bits in the exponent argument
    exp_rel = (2.0 ** -exp_design.out_bits) * 2 + math.log(2.0) * 2.0 ** -(exp_design.in_bits + 1)
    recip_rel = 2.0 ** -recip_design.in_bits  # quantization + 1 ulp of output
    return 2 * exp_rel + 2 * recip_rel
