"""Table registry — deprecation shim over the ``repro.api`` Explorer.

.. deprecated::
    The disk/memory cache that lived here is now the Explorer session's
    persistence layer (:meth:`repro.api.Explorer.get_table`), and the
    per-kind defaults table moved to :data:`repro.api.config.DEFAULTS` so
    widths/lookup-bits live in exactly one place. This module re-exports
    both so seed-era imports (``from repro.numerics.registry import
    get_table``) keep working; key format and the ``artifacts/tables``
    layout are unchanged (DESIGN.md §7.5).
"""
from __future__ import annotations

from repro.api.config import DEFAULTS, spec_for  # noqa: F401
from repro.core.table import TableDesign


def get_table(kind: str, bits: int | None = None, lookup_bits: int | None = None,
              degree: int | None = None, **kw) -> TableDesign:
    """Deprecated shim: fetch (generating + verifying if needed) the table
    for ``kind`` from the process-wide default Explorer."""
    from repro.api import default_explorer

    return default_explorer().get_table(kind, bits, lookup_bits, degree, **kw)
