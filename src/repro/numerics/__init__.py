"""Table-backed approximate numerics (the paper's technique, integrated)."""
from repro.numerics.ops import (BACKENDS, ExactNumerics, InterpNumerics,  # noqa: F401
                                approx_exp_neg, approx_gelu, approx_recip_pos,
                                approx_rmsnorm, approx_rsqrt_pos, approx_sigmoid,
                                approx_silu, approx_softmax, approx_softplus,
                                get_numerics, softmax_ulp_bound, table_eval_int)
from repro.numerics.guard import DomainViolation, GuardedNumerics  # noqa: F401
from repro.api import get_table, spec_for  # noqa: F401
