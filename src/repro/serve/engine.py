"""Serving: jit'd prefill/decode steps + a continuous-batching engine.

``make_serve_step`` builds the decode function the dry-run lowers for the
``decode_32k`` / ``long_500k`` cells: one new token against a seq_len-deep
KV cache (or SSM state), exactly as the shape table specifies.

``ServeEngine`` is a minimal continuous-batching driver: a fixed pool of B
slots, each slot holding one request's cache rows; finished requests free
their slot and a queued request is prefilled into it. Slot state lives in
the batched cache pytree — insertion is a per-slot dynamic_update on the
batch axis.

Interp numerics serve from a compiled :class:`repro.api.InterpLibrary`: the
engine compiles the full library manifest at construction (or accepts a
preloaded artifact, e.g. ``InterpLibrary.load(...)`` — then serving makes
zero exploration calls) and threads it through the jitted prefill/decode
steps as an explicit pytree argument, alongside params and caches. That is
what makes the deployed tables shardable (replicated leaf), donatable and
checkpointable instead of ambient global state.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import InterpLibrary, default_explorer
from repro.models import transformer as tf
from repro.numerics.ops import get_numerics


def make_serve_step(cfg) -> Callable:
    """decode_step(params, token (B,1), pos () or (B,), caches, cross=None,
    library=None) -> (logits, caches). ``pos`` may be a scalar (uniform
    batch) or a per-slot position vector — continuous batching decodes every
    live slot at its *own* next position. ``library`` is a jit-traced pytree:
    swapping artifacts does not retrace, and the leaf obeys the caller's
    sharding/donation just like params."""

    def step(params, token, pos, caches, cross=None, library=None):
        numerics = get_numerics(cfg, library)
        return tf.decode_step(params, token, pos, caches, cfg, numerics, cross=cross)

    return step


def make_prefill(cfg, cache_len: int) -> Callable:
    def pf(params, tokens, frontend_emb=None, enc_frames=None, library=None):
        numerics = get_numerics(cfg, library)
        return tf.prefill(params, tokens, cfg, numerics, cache_len,
                          frontend_emb=frontend_emb, enc_frames=enc_frames)

    return pf


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Continuous batching over a fixed slot pool (greedy decoding).

    ``library``: a preloaded :class:`InterpLibrary` for interp numerics;
    ``None`` compiles the default manifest through the process session at
    construction (generation, if the disk cache is cold, happens here — not
    inside the first jitted step). Exact-numerics engines carry no library.
    """

    def __init__(self, cfg, params, slots: int, cache_len: int,
                 library: InterpLibrary | None = None):
        self.cfg, self.params = cfg, params
        self.slots, self.cache_len = slots, cache_len
        if cfg.sliding_window is not None and cache_len < cfg.sliding_window:
            # the wrapped decode slot (pos % cache) would overwrite KV rows
            # that are still inside the attention window — silent context
            # loss on every wrap; serving must retain the full window
            raise ValueError(
                f"cache_len {cache_len} < sliding_window "
                f"{cfg.sliding_window}: a windowed engine must retain the "
                f"full attention window")
        if cfg.numerics != "interp":
            if library is not None:
                raise ValueError(
                    f"library passed to ServeEngine but cfg.numerics="
                    f"{cfg.numerics!r} never consults it; drop the library "
                    f"or serve with numerics='interp'")
        elif library is None:
            # The library manifest replaces the hand-maintained warm-up kind
            # set: Explorer.compile() packs every table the interp numerics
            # can touch (activations hardcoded by MoE/SSM layers and the
            # vision-stub projector included), so a kind can't be forgotten
            # here again. To serve from a custom session (cache dir, worker
            # pool), install it with repro.api.set_default_explorer() before
            # constructing the engine — or pass a compiled/loaded library.
            library = default_explorer().compile()
        self.library = library
        self.numerics = get_numerics(cfg, library)
        self.caches = tf.init_cache(cfg, slots, cache_len)
        self.pos = np.zeros(slots, np.int32)  # next position per slot
        self.cur = np.full(slots, -1, np.int32)  # current token per slot
        self.req: list[Request | None] = [None] * slots
        self.queue: collections.deque[Request] = collections.deque()
        self.finished: list[Request] = []

        self._prefill1 = jax.jit(make_prefill(cfg, cache_len))
        self._decode = jax.jit(make_serve_step(cfg))

    def submit(self, req: Request):
        """Enqueue a request; rejects work that cannot fit the slot cache.

        Without a sliding window, decode writes KV rows at absolute positions
        ``len(prompt) .. len(prompt) + max_new - 2``; anything past
        ``cache_len - 1`` would be silently clamped by the dynamic-slice
        update (overwriting the last row again and again), so it is an error
        here rather than corruption later. Sliding-window engines wrap their
        (full-window, checked at construction) cache: prompts beyond the
        window prefill position-aligned to the wrap slots, and decode length
        is unbounded.
        """
        if self.cfg.sliding_window is None:
            if len(req.prompt) > self.cache_len:
                raise ValueError(
                    f"request {req.rid}: prompt length {len(req.prompt)} "
                    f"exceeds cache_len {self.cache_len}")
            if len(req.prompt) + req.max_new - 1 > self.cache_len:
                raise ValueError(
                    f"request {req.rid}: prompt ({len(req.prompt)}) + "
                    f"max_new ({req.max_new}) overflows cache_len "
                    f"{self.cache_len}")
        self.queue.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self.req[s] is None and self.queue:
                r = self.queue.popleft()
                logits, cache1, _ = self._prefill1(self.params, r.prompt[None, :],
                                                   library=self.library)
                # splice this request's cache rows into slot s of the pool
                # (batch axis differs per segment: tf.splice_cache knows the
                # stacked-layer layout)
                self.caches = tf.splice_cache(self.cfg, self.caches, cache1, s)
                tok = int(jnp.argmax(logits[0, -1]))
                r.out.append(tok)
                self.req[s] = r
                self.pos[s] = len(r.prompt)
                self.cur[s] = tok

    def _retire(self):
        for s, r in enumerate(self.req):
            if r is not None and (len(r.out) >= r.max_new):
                r.done = True
                self.finished.append(r)
                self.req[s] = None
                self.cur[s] = -1
                self.pos[s] = 0

    def step(self):
        """One engine tick: admit, batch-decode every live slot, retire.

        Each slot decodes at its *own* next position (``self.pos`` is passed
        as a per-slot vector): a freshly admitted short-prompt request keeps
        writing KV/state rows contiguously after its prefill instead of at
        the batch-wide max position. Empty slots decode garbage at position 0
        that is ignored and overwritten on admission (standard slot padding).
        """
        self._admit()
        if all(r is None for r in self.req):
            return False
        toks = jnp.asarray(np.maximum(self.cur, 0)[:, None], jnp.int32)
        logits, self.caches = self._decode(self.params, toks,
                                           jnp.asarray(self.pos, jnp.int32),
                                           self.caches, library=self.library)
        nxt = np.asarray(jnp.argmax(logits[:, 0], -1), np.int32)
        for s, r in enumerate(self.req):
            if r is not None:
                r.out.append(int(nxt[s]))
                self.cur[s] = int(nxt[s])
                self.pos[s] += 1
        self._retire()
        return True

    def run(self, max_ticks: int = 10_000) -> list[Request]:
        t = 0
        while (self.queue or any(r is not None for r in self.req)) and t < max_ticks:
            self.step()
            t += 1
        return self.finished
