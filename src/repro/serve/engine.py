"""Serving: jit'd prefill/decode steps + a continuous-batching engine.

``make_serve_step`` builds the decode function the dry-run lowers for the
``decode_32k`` / ``long_500k`` cells: one new token against a seq_len-deep
KV cache (or SSM state), exactly as the shape table specifies.

``ServeEngine`` is a minimal continuous-batching driver: a fixed pool of B
slots, each slot holding one request's cache rows; finished requests free
their slot and a queued request is prefilled into it. Slot state lives in
the batched cache pytree — insertion is a per-slot dynamic_update on the
batch axis.

Interp numerics serve from a compiled :class:`repro.api.InterpLibrary`: the
engine compiles the full library manifest at construction (or accepts a
preloaded artifact, e.g. ``InterpLibrary.load(...)`` — then serving makes
zero exploration calls) and threads it through the jitted prefill/decode
steps as an explicit pytree argument, alongside params and caches. That is
what makes the deployed tables shardable (replicated leaf), donatable and
checkpointable instead of ambient global state.

Since ISSUE 5 the default engine path is *fused* (DESIGN.md §12): one
jitted multi-slot tick per chunk of decode steps — greedy argmax and the
per-slot position bump happen inside the program, the KV cache (and slot
state) buffers are **donated** so XLA updates them in place instead of
copying every tick, and interp numerics lower through the library-bound
fused kernels (ROM gather + Horner inside softmax/rmsnorm/attention). The
serial per-op path (`fused=False`) is kept as the dispatch-per-op oracle
and benchmark baseline.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import InterpLibrary, default_explorer
from repro.models import transformer as tf
from repro.numerics.ops import get_numerics


def _interp(cfg) -> bool:
    """Does this config's numerics backend consult an InterpLibrary?
    Covers both the plain and the explicitly-fused backend names."""
    return cfg.numerics in ("interp", "interp-fused")


def make_serve_step(cfg, fused: bool = False) -> Callable:
    """decode_step(params, token (B,1), pos () or (B,), caches, cross=None,
    library=None) -> (logits, caches). ``pos`` may be a scalar (uniform
    batch) or a per-slot position vector — continuous batching decodes every
    live slot at its *own* next position. ``library`` is a jit-traced pytree:
    swapping artifacts does not retrace, and the leaf obeys the caller's
    sharding/donation just like params. ``fused=True`` lowers interp
    numerics through the library-bound fused kernels."""

    def step(params, token, pos, caches, cross=None, library=None):
        numerics = get_numerics(cfg, library, fused=fused)
        return tf.decode_step(params, token, pos, caches, cfg, numerics, cross=cross)

    return step


def make_prefill(cfg, cache_len: int, fused: bool = False) -> Callable:
    def pf(params, tokens, frontend_emb=None, enc_frames=None, library=None):
        numerics = get_numerics(cfg, library, fused=fused)
        return tf.prefill(params, tokens, cfg, numerics, cache_len,
                          frontend_emb=frontend_emb, enc_frames=enc_frames)

    return pf


def make_engine_admit(cfg, cache_len: int) -> Callable:
    """Fused admission: prefill + pool splice + greedy first token + slot-
    state update in ONE dispatch.

    admit(params, prompt (1,S), pool, slot (), tok (B,1), pos (B,),
    live (B,), library=None) -> (first_token (), pool, tok, pos, live).
    ``pool`` and the slot-state vectors are donated by the engine — an
    admission splices the new request's cache rows in place and flips its
    slot live without a host round-trip per update (the eager ``.at[].set``
    path recompiled per concrete index/token value).
    """

    def admit(params, prompt, pool, slot, tok, pos, live, library=None):
        numerics = get_numerics(cfg, library,
                                fused=_interp(cfg))
        logits, cache1, _ = tf.prefill(params, prompt, cfg, numerics,
                                       cache_len)
        pool = tf.splice_cache(cfg, pool, cache1, slot)
        first = jnp.argmax(logits[0, -1]).astype(jnp.int32)
        tok = tok.at[slot, 0].set(first)
        pos = pos.at[slot].set(prompt.shape[1])
        live = live.at[slot].set(True)
        return first, pool, tok, pos, live

    return admit


def make_engine_tick(cfg, steps: int) -> Callable:
    """The fused serve tick: ``steps`` greedy decode steps for every live
    slot in ONE dispatch.

    tick(params, tok (B,1), pos (B,), live (B,), caches, cross=None,
    library=None) -> (toks (steps, B), tok, pos, caches). The decode →
    argmax → feed-back loop runs as a ``lax.scan`` inside the program, so
    the host neither uploads tokens nor round-trips logits between steps;
    dead slots (live=False) keep decoding placeholder garbage at a frozen
    position that admission later overwrites (standard slot padding).
    Interp numerics lower through the library-bound fused kernels."""

    def tick(params, tok, pos, live, caches, cross=None, library=None):
        numerics = get_numerics(cfg, library, fused=_interp(cfg))

        def body(carry, _):
            tok, pos, caches = carry
            logits, caches = tf.decode_step(params, tok, pos, caches, cfg,
                                            numerics, cross=cross)
            nxt = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
            nxt = jnp.where(live, nxt, tok[:, 0])
            pos = jnp.where(live, pos + 1, pos)
            return (nxt[:, None], pos, caches), nxt

        (tok, pos, caches), toks = jax.lax.scan(body, (tok, pos, caches),
                                                None, length=steps)
        return toks, tok, pos, caches

    return tick


# Jitted executables shared across engines (keyed by the frozen config):
# re-constructing a ServeEngine must not retrace the decode program, and
# the fused tick donates the cache + slot-state buffers so each chunk
# updates them in place instead of copying the pool.
_JIT_CACHE: dict = {}


def _cached_jit(key: tuple, builder: Callable, **jit_kw) -> Callable:
    fn = _JIT_CACHE.get(key)
    if fn is None:
        fn = jax.jit(builder(), **jit_kw)
        _JIT_CACHE[key] = fn
    return fn


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Continuous batching over a fixed slot pool (greedy decoding).

    ``library``: a preloaded :class:`InterpLibrary` for interp numerics;
    ``None`` compiles the default manifest through the process session at
    construction (generation, if the disk cache is cold, happens here — not
    inside the first jitted step). Exact-numerics engines carry no library.

    ``fused`` (default): each engine tick is ONE donated-buffer dispatch
    covering up to ``horizon`` decode steps (``make_engine_tick``); interp
    numerics run the library-bound fused kernels. ``fused=False`` keeps the
    ISSUE-3/4 serial path — one decode dispatch plus a host argmax round-
    trip per token — as the oracle and benchmark baseline. ``self.stats``
    counts host→device program dispatches and device→host transfers either
    way (the numbers ``benchmarks/decode_fused.py`` reports).
    """

    def __init__(self, cfg, params, slots: int, cache_len: int,
                 library: InterpLibrary | None = None, fused: bool = True,
                 horizon: int = 8):
        self.cfg, self.params = cfg, params
        self.slots, self.cache_len = slots, cache_len
        self.fused, self.horizon = bool(fused), max(1, int(horizon))
        if cfg.sliding_window is not None and cache_len < cfg.sliding_window:
            # the wrapped decode slot (pos % cache) would overwrite KV rows
            # that are still inside the attention window — silent context
            # loss on every wrap; serving must retain the full window
            raise ValueError(
                f"cache_len {cache_len} < sliding_window "
                f"{cfg.sliding_window}: a windowed engine must retain the "
                f"full attention window")
        if not _interp(cfg):
            if library is not None:
                raise ValueError(
                    f"library passed to ServeEngine but cfg.numerics="
                    f"{cfg.numerics!r} never consults it; drop the library "
                    f"or serve with numerics='interp'")
        elif library is None:
            # The library manifest replaces the hand-maintained warm-up kind
            # set: Explorer.compile() packs every table the interp numerics
            # can touch (activations hardcoded by MoE/SSM layers and the
            # vision-stub projector included), so a kind can't be forgotten
            # here again. To serve from a custom session (cache dir, worker
            # pool), install it with repro.api.set_default_explorer() before
            # constructing the engine — or pass a compiled/loaded library.
            library = default_explorer().compile()
        self.library = library
        self.numerics = get_numerics(
            cfg, library, fused=self.fused and _interp(cfg))
        self.caches = tf.init_cache(cfg, slots, cache_len)
        self.pos = np.zeros(slots, np.int32)  # next position per slot
        self.cur = np.full(slots, -1, np.int32)  # current token per slot
        self.req: list[Request | None] = [None] * slots
        self.queue: collections.deque[Request] = collections.deque()
        self.finished: list[Request] = []
        self.stats = {"dispatches": 0, "transfers": 0, "ticks": 0,
                      "decode_steps": 0}
        # device-resident slot state (fused path): current token, next
        # position, liveness — donated through the tick alongside the caches
        self._tok_dev = jnp.zeros((slots, 1), jnp.int32)
        self._pos_dev = jnp.zeros((slots,), jnp.int32)
        self._live_dev = jnp.zeros((slots,), jnp.bool_)

        self._prefill1 = _cached_jit(("prefill", cfg, cache_len),
                                     lambda: make_prefill(cfg, cache_len))
        self._decode = _cached_jit(("decode", cfg),
                                   lambda: make_serve_step(cfg))
        # admission splice: donate the pool so slot insertion is in place
        self._splice = _cached_jit(
            ("splice", cfg),
            lambda: (lambda pool, one, slot:
                     tf.splice_cache(cfg, pool, one, slot)),
            donate_argnums=(0,))
        # fused admission: prefill + splice + first-token argmax + slot
        # state, one dispatch, pool and slot-state buffers donated
        self._admit_fused = _cached_jit(
            ("admit", cfg, cache_len),
            lambda: make_engine_admit(cfg, cache_len),
            donate_argnums=(2, 4, 5, 6))
        # retire flips one slot's liveness (traced index: one trace total,
        # unlike the eager .at[].set which recompiles per concrete index)
        self._set_live = _cached_jit(
            ("set_live",),
            lambda: (lambda live, slot, val: live.at[slot].set(val)),
            donate_argnums=(0,))

    def _tick_fn(self, steps: int) -> Callable:
        """Jitted fused tick for a chunk of ``steps`` decode steps; caches
        and slot-state buffers (token/pos) are donated — decode updates the
        pool in place every tick instead of copying it."""
        return _cached_jit(("tick", self.cfg, steps),
                           lambda: make_engine_tick(self.cfg, steps),
                           donate_argnums=(1, 2, 4))

    def submit(self, req: Request):
        """Enqueue a request; rejects work that cannot fit the slot cache.

        Without a sliding window, decode writes KV rows at absolute positions
        ``len(prompt) .. len(prompt) + max_new - 2``; anything past
        ``cache_len - 1`` would be silently clamped by the dynamic-slice
        update (overwriting the last row again and again), so it is an error
        here rather than corruption later. Sliding-window engines wrap their
        (full-window, checked at construction) cache: prompts beyond the
        window prefill position-aligned to the wrap slots, and decode length
        is unbounded.
        """
        if self.cfg.sliding_window is None:
            if len(req.prompt) > self.cache_len:
                raise ValueError(
                    f"request {req.rid}: prompt length {len(req.prompt)} "
                    f"exceeds cache_len {self.cache_len}")
            if len(req.prompt) + req.max_new - 1 > self.cache_len:
                raise ValueError(
                    f"request {req.rid}: prompt ({len(req.prompt)}) + "
                    f"max_new ({req.max_new}) overflows cache_len "
                    f"{self.cache_len}")
        self.queue.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self.req[s] is None and self.queue:
                r = self.queue.popleft()
                if self.fused:
                    # one dispatch: prefill + in-place pool splice + greedy
                    # first token + slot-state update (donated buffers)
                    (first, self.caches, self._tok_dev, self._pos_dev,
                     self._live_dev) = self._admit_fused(
                        self.params, r.prompt[None, :], self.caches, s,
                        self._tok_dev, self._pos_dev, self._live_dev,
                        library=self.library)
                    tok = int(first)
                else:
                    logits, cache1, _ = self._prefill1(
                        self.params, r.prompt[None, :], library=self.library)
                    # splice this request's cache rows into slot s of the
                    # pool (batch axis differs per segment: tf.splice_cache
                    # knows the stacked-layer layout); the pool buffer is
                    # donated — the insertion is in place, not a pool copy
                    self.caches = self._splice(self.caches, cache1, s)
                    tok = int(jnp.argmax(logits[0, -1]))
                r.out.append(tok)
                self.req[s] = r
                self.pos[s] = len(r.prompt)
                self.cur[s] = tok

    def _retire(self):
        for s, r in enumerate(self.req):
            if r is not None and (len(r.out) >= r.max_new):
                r.done = True
                self.finished.append(r)
                self.req[s] = None
                self.cur[s] = -1
                self.pos[s] = 0
                if self.fused:
                    self._live_dev = self._set_live(self._live_dev, s, False)

    def step(self, max_steps: int = 1):
        """One engine tick: admit, batch-decode every live slot, retire.

        Each slot decodes at its *own* next position (``self.pos`` is passed
        as a per-slot vector): a freshly admitted short-prompt request keeps
        writing KV/state rows contiguously after its prefill instead of at
        the batch-wide max position. Empty slots decode garbage at position 0
        that is ignored and overwritten on admission (standard slot padding).

        A fused engine batches up to ``max_steps`` decode steps into the
        tick (``run`` passes ``self.horizon``) — bounded by the smallest
        remaining budget among live slots, so no in-flight request
        overshoots its ``max_new`` mid-chunk and the freed slot admits at
        the next tick (after ``_admit`` drains the queue into free slots, a
        chunk never delays an admission that could have happened). The one
        historical edge is shared with the serial path: a request whose
        admission token already fills its budget (``max_new <= 1``) still
        decodes once before retiring. The default ``step()`` performs
        exactly one decode step either way.
        """
        self._admit()
        if all(r is None for r in self.req):
            return False
        if not self.fused:
            return self._step_serial()
        remaining = min(r.max_new - len(r.out)
                        for r in self.req if r is not None)
        steps = max(1, min(max_steps, remaining))
        # quantize to the largest power of two <= steps: retirement tails
        # then reuse log2(horizon)+1 compiled tick programs (1, 2, 4, ...)
        # instead of jitting one decode-scan per distinct tail length
        steps = 1 << (steps.bit_length() - 1)
        toks, self._tok_dev, self._pos_dev, self.caches = self._tick_fn(steps)(
            self.params, self._tok_dev, self._pos_dev, self._live_dev,
            self.caches, library=self.library)
        self.stats["dispatches"] += 1  # the tick program
        out = np.asarray(toks)  # (steps, B): ONE device->host transfer
        self.stats["transfers"] += 1
        self.stats["ticks"] += 1
        self.stats["decode_steps"] += steps
        for s, r in enumerate(self.req):
            if r is not None:
                r.out.extend(int(t) for t in out[:, s])
                self.cur[s] = int(out[-1, s])
                self.pos[s] += steps
        self._retire()
        return True

    def _step_serial(self):
        """The ISSUE-3/4 per-op tick: token upload, one decode dispatch, a
        host argmax round-trip — kept as the fused path's oracle/baseline."""
        toks = jnp.asarray(np.maximum(self.cur, 0)[:, None], jnp.int32)
        pos = jnp.asarray(self.pos, jnp.int32)
        self.stats["transfers"] += 2  # token + position upload
        logits, self.caches = self._decode(self.params, toks, pos,
                                           self.caches, library=self.library)
        self.stats["dispatches"] += 1  # decode program
        nxt = np.asarray(jnp.argmax(logits[:, 0], -1), np.int32)
        self.stats["dispatches"] += 1  # eager argmax program
        self.stats["transfers"] += 1  # next-token download
        self.stats["ticks"] += 1
        self.stats["decode_steps"] += 1
        for s, r in enumerate(self.req):
            if r is not None:
                r.out.append(int(nxt[s]))
                self.cur[s] = int(nxt[s])
                self.pos[s] += 1
        self._retire()
        return True

    def run(self, max_ticks: int = 10_000) -> list[Request]:
        t = 0
        while (self.queue or any(r is not None for r in self.req)) and t < max_ticks:
            self.step(self.horizon)
            t += 1
        return self.finished
