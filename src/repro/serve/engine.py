"""Serving: jit'd prefill/decode steps + a fault-tolerant continuous-batching engine.

``make_serve_step`` builds the decode function the dry-run lowers for the
``decode_32k`` / ``long_500k`` cells: one new token against a seq_len-deep
KV cache (or SSM state), exactly as the shape table specifies.

``ServeEngine`` is a continuous-batching driver: a fixed pool of B
slots, each slot holding one request's cache rows; finished requests free
their slot and a queued request is prefilled into it. Slot state lives in
the batched cache pytree — insertion is a per-slot dynamic_update on the
batch axis.

Interp numerics serve from a compiled :class:`repro.api.InterpLibrary`: the
engine compiles the full library manifest at construction (or accepts a
preloaded artifact, e.g. ``InterpLibrary.load(...)`` — then serving makes
zero exploration calls) and threads it through the jitted prefill/decode
steps as an explicit pytree argument, alongside params and caches. That is
what makes the deployed tables shardable (replicated leaf), donatable and
checkpointable instead of ambient global state.

Since ISSUE 5 the default engine path is *fused* (DESIGN.md §12): one
jitted multi-slot tick per chunk of decode steps — greedy argmax and the
per-slot position bump happen inside the program, the KV cache (and slot
state) buffers are **donated** so XLA updates them in place instead of
copying every tick, and interp numerics lower through the library-bound
fused kernels (ROM gather + Horner inside softmax/rmsnorm/attention). The
serial per-op path (`fused=False`) is kept as the dispatch-per-op oracle
and benchmark baseline.

Since ISSUE 7 the engine carries the serving-robustness layer
(DESIGN.md §14):

  * request lifecycle guarantees — bounded-queue backpressure and
    per-request deadlines with typed :class:`Rejected` errors;
  * an in-program NaN/Inf watchdog sentinel reduced inside the fused scan
    (one extra scalar riding the existing token download, zero extra
    dispatches) that retires a poisoned slot with a structured error
    instead of streaming garbage;
  * a degradation ladder — fused → serial (domain-guarded numerics) →
    exact — walked on repeated watchdog trips, and jumped straight to
    exact on a resident-ROM integrity failure
    (:meth:`InterpLibrary.verify_resident`);
  * a crash-recoverable admission/token journal
    (:mod:`repro.serve.journal`) with :meth:`ServeEngine.resume`.

Since ISSUE 10 the engine also carries the sharded, AOT-warmed serving tier
(DESIGN.md §17):

  * ``mesh=`` — a ``("data", "tp")`` serve mesh
    (:func:`repro.launch.mesh.make_serve_mesh`): the KV pool is sharded
    slot-wise over ``data`` and KV-head-wise over ``tp``, weights follow
    ``sharding.SERVE_PARAM_RULES`` (tensor-parallel, data-replicated), and
    the library ROM(s) are replicated per device — ROM verification and the
    degradation ladder operate on the sharded state unchanged;
  * ``aot_buckets=`` — AOT warm-up (:mod:`repro.serve.aot`): the decode
    tick and a grid of packed bucketed-prefill admission programs are
    ``jit.lower().compile()``d at construction, so steady-state serving
    never pays a compile (``stats["aot_hits"]``/``["aot_misses"]``); short
    prompts pack several-to-one into a padded prefill dispatch
    (:func:`repro.models.transformer.prefill_padded`);
  * ``async_host=`` — the host pipeline (:mod:`repro.serve.pipeline`):
    detokenize + journal bookkeeping move to a background worker behind a
    bounded queue; the main thread's per-tick host work shrinks to the (B,)
    watchdog-sentinel download.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import InterpLibrary, LibraryIntegrityError, default_explorer
from repro.faults.inject import crashpoint
from repro.launch import sharding as shlib
from repro.models import transformer as tf
from repro.numerics.ops import INTERP_BACKENDS, get_numerics
from repro.serve import aot as aot_mod
from repro.serve.journal import ServeJournal, load_requests
from repro.serve.pipeline import HostPipeline


def _interp(cfg) -> bool:
    """Does this config's numerics backend consult an InterpLibrary?
    Covers the plain, explicitly-fused and degraded-guarded backend names —
    and per-layer plans (DESIGN.md §16), which consult one library per
    distinct slot as long as any site assignment is non-exact."""
    plan = getattr(cfg, "plan", None)
    if plan is not None:
        return plan.uses_interp
    return cfg.numerics in INTERP_BACKENDS


def make_serve_step(cfg, fused: bool = False) -> Callable:
    """decode_step(params, token (B,1), pos () or (B,), caches, cross=None,
    library=None) -> (logits, caches). ``pos`` may be a scalar (uniform
    batch) or a per-slot position vector — continuous batching decodes every
    live slot at its *own* next position. ``library`` is a jit-traced pytree:
    swapping artifacts does not retrace, and the leaf obeys the caller's
    sharding/donation just like params. ``fused=True`` lowers interp
    numerics through the library-bound fused kernels."""

    def step(params, token, pos, caches, cross=None, library=None):
        numerics = get_numerics(cfg, library, fused=fused)
        return tf.decode_step(params, token, pos, caches, cfg, numerics, cross=cross)

    return step


def make_prefill(cfg, cache_len: int, fused: bool = False) -> Callable:
    def pf(params, tokens, frontend_emb=None, enc_frames=None, library=None):
        numerics = get_numerics(cfg, library, fused=fused)
        return tf.prefill(params, tokens, cfg, numerics, cache_len,
                          frontend_emb=frontend_emb, enc_frames=enc_frames)

    return pf


def make_engine_admit(cfg, cache_len: int) -> Callable:
    """Fused admission: prefill + pool splice + greedy first token + slot-
    state update in ONE dispatch.

    admit(params, prompt (1,S), pool, slot (), tok (B,1), pos (B,),
    live (B,), library=None) -> (first_token (), pool, tok, pos, live).
    ``pool`` and the slot-state vectors are donated by the engine — an
    admission splices the new request's cache rows in place and flips its
    slot live without a host round-trip per update (the eager ``.at[].set``
    path recompiled per concrete index/token value).
    """

    def admit(params, prompt, pool, slot, tok, pos, live, library=None):
        numerics = get_numerics(cfg, library,
                                fused=_interp(cfg))
        logits, cache1, _ = tf.prefill(params, prompt, cfg, numerics,
                                       cache_len)
        pool = tf.splice_cache(cfg, pool, cache1, slot)
        first = jnp.argmax(logits[0, -1]).astype(jnp.int32)
        tok = tok.at[slot, 0].set(first)
        pos = pos.at[slot].set(prompt.shape[1])
        live = live.at[slot].set(True)
        return first, pool, tok, pos, live

    return admit


def make_engine_admit_packed(cfg, cache_len: int, pack: int) -> Callable:
    """Bucketed admission: prefill ``pack`` right-padded prompts, splice
    each into its slot, take each greedy first token — ONE dispatch.

    admit(params, prompts (P, S_bucket), true_lens (P,), slots (P,), pool,
    tok (B,1), pos (B,), live (B,), library=None) -> (firsts (P,), pool,
    tok, pos, live). ``prompts`` rows are right-padded to the bucket length
    (pad id 0 — any in-vocab id works, the pad tail is causally invisible
    and its cache rows are masked dead by ``prefill_padded``); the splice
    loop unrolls over the static pack size with traced slot indices, so one
    compiled program serves every slot assignment."""

    def admit(params, prompts, true_lens, slots, pool, tok, pos, live,
              library=None):
        numerics = get_numerics(cfg, library, fused=_interp(cfg))
        logits, cache_p, _ = tf.prefill_padded(params, prompts, true_lens,
                                               cfg, numerics, cache_len)
        firsts = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)  # (P,)
        for i in range(pack):
            one = tf.extract_cache_row(cfg, cache_p, i)
            pool = tf.splice_cache(cfg, pool, one, slots[i])
            tok = tok.at[slots[i], 0].set(firsts[i])
            pos = pos.at[slots[i]].set(true_lens[i])
            live = live.at[slots[i]].set(True)
        return firsts, pool, tok, pos, live

    return admit


def make_engine_tick(cfg, steps: int) -> Callable:
    """The fused serve tick: ``steps`` greedy decode steps for every live
    slot in ONE dispatch.

    tick(params, tok (B,1), pos (B,), live (B,), caches, cross=None,
    library=None) -> (toks (steps, B), tok, pos, ok (B,), caches). The
    decode → argmax → feed-back loop runs as a ``lax.scan`` inside the
    program, so the host neither uploads tokens nor round-trips logits
    between steps; dead slots (live=False) keep decoding placeholder
    garbage at a frozen position that admission later overwrites (standard
    slot padding). Interp numerics lower through the library-bound fused
    kernels.

    ``ok`` is the watchdog sentinel (DESIGN.md §14): per-slot all-finite
    logits across the whole scan, reduced *inside* the program (dead slots
    masked healthy) and downloaded alongside the token block — a poisoned
    datapath is detected with zero additional dispatches."""

    def tick(params, tok, pos, live, caches, cross=None, library=None):
        numerics = get_numerics(cfg, library, fused=_interp(cfg))

        def body(carry, _):
            tok, pos, ok, caches = carry
            logits, caches = tf.decode_step(params, tok, pos, caches, cfg,
                                            numerics, cross=cross)
            step_ok = jnp.all(jnp.isfinite(logits[:, 0]), axis=-1)
            ok = ok & (step_ok | ~live)
            nxt = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
            nxt = jnp.where(live, nxt, tok[:, 0])
            pos = jnp.where(live, pos + 1, pos)
            return (nxt[:, None], pos, ok, caches), nxt

        ok0 = jnp.ones(live.shape, jnp.bool_)
        (tok, pos, ok, caches), toks = jax.lax.scan(
            body, (tok, pos, ok0, caches), None, length=steps)
        return toks, tok, pos, ok, caches

    return tick


# Jitted executables shared across engines (keyed by the frozen config):
# re-constructing a ServeEngine must not retrace the decode program, and
# the fused tick donates the cache + slot-state buffers so each chunk
# updates them in place instead of copying the pool.
_JIT_CACHE: dict = {}


def _cached_jit(key: tuple, builder: Callable, **jit_kw) -> Callable:
    fn = _JIT_CACHE.get(key)
    if fn is None:
        fn = jax.jit(builder(), **jit_kw)
        _JIT_CACHE[key] = fn
    return fn


class Rejected(ValueError):
    """Typed request rejection (admission control, DESIGN.md §14).

    ``reason`` is a stable machine key: ``"prompt_overflow"`` /
    ``"decode_overflow"`` (the request cannot fit the slot cache),
    ``"queue_full"`` (bounded-queue backpressure), ``"bad_prompt"``
    (token ids outside the vocabulary — they would silently clamp through
    the embedding gather), ``"deadline"`` (already expired at submit).
    Subclasses ``ValueError`` so pre-ISSUE-7 callers keep working.
    """

    def __init__(self, reason: str, message: str):
        self.reason = reason
        super().__init__(message)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    deadline: float | None = None  # absolute engine-clock seconds
    error: str | None = None  # structured failure ("deadline_exceeded", ...)


class ServeEngine:
    """Fault-tolerant continuous batching over a fixed slot pool (greedy).

    ``library``: a preloaded :class:`InterpLibrary` for interp numerics;
    ``None`` compiles the default manifest through the process session at
    construction (generation, if the disk cache is cold, happens here — not
    inside the first jitted step). Exact-numerics engines carry no library.

    When ``cfg.plan`` is a :class:`repro.plan.NumericsPlan` (per-layer
    heterogeneous numerics, DESIGN.md §16) the engine threads a *dict* of
    libraries — one per distinct plan slot, compiled at construction when
    none is passed — and the degradation ladder gains a per-layer rung: a
    corrupt slot ROM downgrades exactly the layers reading that slot
    (:meth:`_degrade_slots`), the rest stay fused, and
    ``stats["degradations"]`` becomes a per-layer-label dict (``"engine"``
    counts whole-ladder rungs).

    ``fused`` (default): each engine tick is ONE donated-buffer dispatch
    covering up to ``horizon`` decode steps (``make_engine_tick``); interp
    numerics run the library-bound fused kernels. ``fused=False`` keeps the
    ISSUE-3/4 serial path — one decode dispatch plus a host argmax round-
    trip per token — as the oracle and benchmark baseline. ``self.stats``
    counts host→device program dispatches and device→host transfers either
    way (the numbers ``benchmarks/decode_fused.py`` reports).

    Robustness knobs (ISSUE 7, DESIGN.md §14):

    ``max_queue``        bounded admission queue; ``submit`` raises
                         :class:`Rejected` ("queue_full") beyond it.
                         ``None`` = unbounded (legacy).
    ``deadline_s``       default per-request TTL in engine-clock seconds
                         (``Request.deadline``, absolute, overrides);
                         expired requests fail with a structured
                         ``"deadline_exceeded"`` error instead of holding
                         a slot.
    ``clock``            monotonic clock (injectable:
                         ``repro.faults.FaultClock`` drives deadline and
                         stall tests without sleeping).
    ``watchdog_limit``   watchdog trips (non-finite tick output, stalled
                         tick) tolerated before degrading one ladder rung.
    ``max_tick_s``       stall watchdog: a tick exceeding this wall budget
                         counts as a trip (``None`` = off).
    ``verify_rom_every`` re-verify the resident ROM checksum every N ticks
                         (0 = at construction and on watchdog trips only).
    ``journal``          path (or :class:`ServeJournal`): durably journal
                         admissions and emitted tokens; see
                         :meth:`resume`.

    Sharded/AOT/async knobs (ISSUE 10, DESIGN.md §17):

    ``mesh``             a ``("data", "tp")`` serve mesh
                         (:func:`repro.launch.mesh.make_serve_mesh`): KV
                         pool sharded slot-wise over ``data`` / KV-head-wise
                         over ``tp``, weights TP-sharded + data-replicated,
                         ROM(s) replicated, slot-state batch-sharded over
                         ``data`` (the AOT fixed point). ``None`` = single
                         host (legacy).
    ``aot_buckets``      AOT warm-up: ``True`` (default bucket table
                         clipped to ``cache_len``), a tuple of prefill
                         bucket lengths, or ``None`` (lazy jit, legacy).
                         Short prompts pack into one padded bucketed
                         prefill dispatch; longer-than-every-bucket prompts
                         fall back to exact-length admission
                         (``stats["aot_fallbacks"]``).
    ``max_pack``         largest packed-admission group compiled (grouping
                         uses powers of two up to ``min(max_pack, slots)``).
    ``async_host``       move detokenize + journal writes onto a background
                         worker (:class:`repro.serve.pipeline.HostPipeline`)
                         behind a bounded queue of ``pipeline_depth``
                         chunks. ``run()``/``close()`` drain it; while
                         running, ``Request.out`` trails the device by up to
                         the queue depth (read it after ``run``).

    The degradation ladder: a *fused* engine degrades to the *serial*
    per-op path with domain-guarded numerics (``"interp-guarded"`` — the
    clamp stops a recurrent poison source); a serial engine degrades to
    *exact* numerics (drops the library entirely). A resident-ROM
    integrity failure jumps straight to exact — both interp rungs gather
    the corrupt ROM, so only the table-free twin is trustworthy. Every
    transition is recorded in ``self.faults`` and counted in
    ``self.stats["degradations"]``; tokens never silently come from a
    known-bad datapath.
    """

    def __init__(self, cfg, params, slots: int, cache_len: int,
                 library: InterpLibrary | None = None, fused: bool = True,
                 horizon: int = 8, max_queue: int | None = 1024,
                 deadline_s: float | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 watchdog_limit: int = 2, max_tick_s: float | None = None,
                 verify_rom_every: int = 0,
                 journal: str | ServeJournal | None = None,
                 mesh=None, aot_buckets=None, max_pack: int = 4,
                 async_host: bool = False, pipeline_depth: int = 4):
        self.cfg, self.params = cfg, params
        self.slots, self.cache_len = slots, cache_len
        self.fused, self.horizon = bool(fused), max(1, int(horizon))
        self.max_queue = max_queue
        self.deadline_s = deadline_s
        self.clock = clock
        self.watchdog_limit = max(1, int(watchdog_limit))
        self.max_tick_s = max_tick_s
        self.verify_rom_every = max(0, int(verify_rom_every))
        if cfg.sliding_window is not None and cache_len < cfg.sliding_window:
            # the wrapped decode slot (pos % cache) would overwrite KV rows
            # that are still inside the attention window — silent context
            # loss on every wrap; serving must retain the full window
            raise ValueError(
                f"cache_len {cache_len} < sliding_window "
                f"{cfg.sliding_window}: a windowed engine must retain the "
                f"full attention window")
        if not _interp(cfg):
            if library is not None:
                raise ValueError(
                    f"library passed to ServeEngine but cfg.numerics="
                    f"{cfg.numerics!r} never consults it; drop the library "
                    f"or serve with numerics='interp'")
        elif library is None:
            # The library manifest replaces the hand-maintained warm-up kind
            # set: Explorer.compile() packs every table the interp numerics
            # can touch (activations hardcoded by MoE/SSM layers and the
            # vision-stub projector included), so a kind can't be forgotten
            # here again. To serve from a custom session (cache dir, worker
            # pool), install it with repro.api.set_default_explorer() before
            # constructing the engine — or pass a compiled/loaded library.
            # A plan engine compiles one library per distinct plan slot and
            # threads the dict as a pytree (each value replicates/donates
            # like the single-library case).
            if cfg.plan is not None:
                from repro.plan.numerics import compile_plan_libraries

                library = compile_plan_libraries(cfg.plan)
            else:
                library = default_explorer().compile()
        self.library = library
        self.numerics = get_numerics(
            cfg, library, fused=self.fused and _interp(cfg))
        self.caches = tf.init_cache(cfg, slots, cache_len)
        self.pos = np.zeros(slots, np.int32)  # next position per slot
        self.cur = np.full(slots, -1, np.int32)  # current token per slot
        self.req: list[Request | None] = [None] * slots
        self.queue: collections.deque[Request] = collections.deque()
        self.finished: list[Request] = []
        self.failed: list[Request] = []
        # plan engines attribute degradations per layer label ("0", "7",
        # "rest", or "engine" for whole-ladder rungs); plan-less engines
        # keep the historical scalar counter
        self.stats = {"dispatches": 0, "transfers": 0, "ticks": 0,
                      "decode_steps": 0, "rejected": 0, "expired": 0,
                      "watchdog_trips": 0,
                      "degradations": {} if cfg.plan is not None else 0,
                      "rom_verifies": 0, "rom_faults": 0, "slot_failures": 0,
                      "resumed": 0, "resume_skipped_done": 0,
                      "resume_replay_steps": 0,
                      "aot_compiles": 0, "aot_hits": 0, "aot_misses": 0,
                      "aot_reshards": 0, "aot_fallbacks": 0,
                      "packed_admits": 0,
                      "packed_requests": 0, "admit_dispatches": 0,
                      "async_chunks": 0, "async_tokens": 0}
        self.faults: list[dict] = []  # structured fault/degradation log
        self._trips = 0  # watchdog trips since the last degradation
        self.journal = (journal if isinstance(journal, (ServeJournal,
                                                        type(None)))
                        else ServeJournal(journal))
        # device-resident slot state (fused path): current token, next
        # position, liveness — donated through the tick alongside the caches
        self._tok_dev = jnp.zeros((slots, 1), jnp.int32)
        self._pos_dev = jnp.zeros((slots,), jnp.int32)
        self._live_dev = jnp.zeros((slots,), jnp.bool_)
        # ISSUE 10: sharded / AOT-warmed / async serving tier (DESIGN.md §17)
        self.mesh = mesh
        self._mesh_key = aot_mod.mesh_key(mesh)
        # per-slot emitted-token counts owned by the MAIN thread: retirement
        # and chunk sizing cannot read len(Request.out) once the async
        # pipeline extends it from the worker
        self._emitted = np.zeros(slots, np.int64)
        # bucketed (padded) prefill packing is only sound for pure
        # attention-cache decoders: SSM state is cumulative, windowed caches
        # wrap, encoder/frontend extras carry no per-row length
        self._packable = (
            cfg.sliding_window is None and cfg.encoder is None
            and cfg.frontend is None
            and not any(k.mixer == "ssm" for seg in tf.layer_plan(cfg)
                        for k in seg.pattern))
        if aot_buckets is None:
            self.aot_buckets = None
        elif aot_buckets is True:
            self.aot_buckets = aot_mod.BucketTable.for_cache(cache_len)
        elif isinstance(aot_buckets, aot_mod.BucketTable):
            self.aot_buckets = aot_mod.BucketTable.for_cache(
                cache_len, aot_buckets.buckets)
        else:
            self.aot_buckets = aot_mod.BucketTable.for_cache(
                cache_len, aot_buckets)
        self._pack_sizes = aot_mod.pack_sizes(max_pack, slots)
        if async_host and not self.fused:
            raise ValueError(
                "async_host=True requires the fused engine: the serial "
                "per-op path is the synchronous oracle/baseline")
        self.pipeline = (HostPipeline(journal=self.journal,
                                      depth=pipeline_depth)
                         if async_host else None)
        if mesh is not None:
            self._shard_state()
        self._build_programs()
        self._warm_aot()
        # serve-time ROM integrity: the load-time checksum catches a corrupt
        # artifact; this catches the resident copy going bad afterwards
        self.verify_library()

    def _shard_state(self) -> None:
        """Place params/caches/slot-state/library on the serve mesh: KV pool
        batch-sharded over ``data`` and KV-head-sharded over ``tp``, weights
        per ``SERVE_PARAM_RULES`` (TP over ``tp``, replicated over ``data``),
        the library ROM(s) and the tiny slot-state vectors replicated.
        Everything downstream — jit traces, AOT lowerings, donation — then
        carries these shardings."""
        mesh = self.mesh

        def sds(tree):
            return jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(jnp.shape(x),
                                               jnp.asarray(x).dtype), tree)

        pspecs = shlib.param_specs(sds(self.params), mesh,
                                   rules=shlib.SERVE_PARAM_RULES)
        self.params = jax.device_put(self.params, pspecs)
        cspecs = shlib.cache_specs_sharding(sds(self.caches), self.cfg, mesh)
        self.caches = jax.device_put(self.caches, cspecs)
        rep = shlib.replicated(mesh)
        # slot-state vectors go batch-over-data, matching the constraint the
        # tick/admit programs put on their outputs — warming with the same
        # placement makes steady state a sharding fixed point (zero
        # per-tick reshards in stats["aot_reshards"])
        slot_s = shlib.named_sharding(("batch",), (self.slots,), mesh)
        tok_s = shlib.named_sharding(("batch", None), (self.slots, 1), mesh)
        self._tok_dev = jax.device_put(self._tok_dev, tok_s)
        self._pos_dev = jax.device_put(self._pos_dev, slot_s)
        self._live_dev = jax.device_put(self._live_dev, slot_s)
        if self.library is not None:
            # one ROM replica per device: the fused kernels gather locally,
            # and verify_resident() checksums the (replicated) leaves as-is
            self.library = jax.device_put(self.library, rep)
            from repro.kernels.interp.ops import assert_rom_replicated
            assert_rom_replicated(*jax.tree.leaves(self.library))

    def _ctx(self):
        """Logical-axis rule context for every trace/lower on this engine:
        ``constrain`` reads the thread-local rules at *trace* time, so all
        dispatch sites wrap themselves in this (a no-op without a mesh)."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return shlib.axis_rules(self.mesh)

    def _aot_key(self, kind: str, *extra) -> tuple:
        """Executable-cache key: the frozen (cfg [incl. plan], geometry,
        mesh) tuple plus the program-specific extras."""
        return (kind, self.cfg, self.cache_len, self.slots, *extra,
                self._mesh_key)

    def _warm_aot(self) -> None:
        """AOT warm-up (DESIGN.md §17): compile every steady-state program —
        the fused tick at each power-of-two chunk size up to ``horizon``,
        plus a packed bucketed-admission program per (bucket, pack-size)
        pair — at construction, so no request ever pays a compile.
        ``stats["aot_compiles"]`` counts fresh compiles (reconstructed
        engines hit the shared executable cache and count nothing)."""
        if self.aot_buckets is None:
            return
        if not self.fused:
            raise ValueError("aot_buckets requires the fused engine")
        rep = (shlib.replicated(self.mesh) if self.mesh is not None
               else None)
        with self._ctx():
            for steps in aot_mod.tick_chunk_sizes(self.horizon):
                key = self._aot_key("tick", steps)
                if aot_mod.lookup(key) is None:
                    self.stats["aot_compiles"] += 1
                aot_mod.compile_cached(
                    key, self._tick_jit(steps),
                    (self.params, self._tok_dev, self._pos_dev,
                     self._live_dev, self.caches),
                    {"library": self.library})
            if not self._packable:
                return
            for bucket in self.aot_buckets.buckets:
                for pk in self._pack_sizes:
                    prompts = jnp.zeros((pk, bucket), jnp.int32)
                    lens = jnp.ones((pk,), jnp.int32)
                    slots0 = jnp.arange(pk, dtype=jnp.int32)
                    if rep is not None:
                        prompts, lens, slots0 = (
                            jax.device_put(x, rep)
                            for x in (prompts, lens, slots0))
                    key = self._aot_key("admit_packed", bucket, pk)
                    if aot_mod.lookup(key) is None:
                        self.stats["aot_compiles"] += 1
                    aot_mod.compile_cached(
                        key, self._packed_jit(pk),
                        (self.params, prompts, lens, slots0, self.caches,
                         self._tok_dev, self._pos_dev, self._live_dev),
                        {"library": self.library})

    # -- program construction (re-run on every degradation rung) ----------
    def _build_programs(self) -> None:
        # every key carries the mesh identity: a meshed engine's traces are
        # made inside its axis-rules context and must never be confused with
        # a single-host engine's traces for the same frozen cfg
        cfg, cache_len, mk = self.cfg, self.cache_len, self._mesh_key
        self._prefill1 = _cached_jit(("prefill", cfg, cache_len, mk),
                                     lambda: make_prefill(cfg, cache_len))
        self._decode = _cached_jit(("decode", cfg, mk),
                                   lambda: make_serve_step(cfg))
        # fused-numerics twins of prefill/decode for resume replay: the
        # teacher-forced rebuild must re-run the exact float path the fused
        # admission/tick ran pre-crash (DESIGN.md §14)
        self._prefill_fnum = _cached_jit(
            ("prefill-fnum", cfg, cache_len, mk),
            lambda: make_prefill(cfg, cache_len, fused=_interp(cfg)))
        self._decode_fnum = _cached_jit(
            ("decode-fnum", cfg, mk),
            lambda: make_serve_step(cfg, fused=_interp(cfg)))
        # serial-path argmax + watchdog sentinel in one program: same
        # dispatch/transfer budget as the bare argmax it replaces
        self._argmax_ok = _cached_jit(
            ("argmax_ok",),
            lambda: (lambda logits: (
                jnp.argmax(logits[:, 0], -1).astype(jnp.int32),
                jnp.all(jnp.isfinite(logits[:, 0]), axis=-1))))
        # admission splice: donate the pool so slot insertion is in place
        self._splice = _cached_jit(
            ("splice", cfg, mk),
            lambda: (lambda pool, one, slot:
                     tf.splice_cache(cfg, pool, one, slot)),
            donate_argnums=(0,))
        # fused admission: prefill + splice + first-token argmax + slot
        # state, one dispatch, pool and slot-state buffers donated
        self._admit_fused = _cached_jit(
            ("admit", cfg, cache_len, mk),
            lambda: make_engine_admit(cfg, cache_len),
            donate_argnums=(2, 4, 5, 6))
        # retire flips one slot's liveness (traced index: one trace total,
        # unlike the eager .at[].set which recompiles per concrete index)
        self._set_live = _cached_jit(
            ("set_live",),
            lambda: (lambda live, slot, val: live.at[slot].set(val)),
            donate_argnums=(0,))
        # resume replay: land one slot's (token, position, live) in place
        self._set_slot = _cached_jit(
            ("set_slot",),
            lambda: (lambda tok, pos, live, slot, t, p: (
                tok.at[slot, 0].set(t), pos.at[slot].set(p),
                live.at[slot].set(True))),
            donate_argnums=(0, 1, 2))

    def _tick_jit(self, steps: int) -> Callable:
        """The lazily-traced jitted tick (also what AOT warm-up lowers)."""
        return _cached_jit(("tick", self.cfg, steps, self._mesh_key),
                           lambda: make_engine_tick(self.cfg, steps),
                           donate_argnums=(1, 2, 4))

    def _packed_jit(self, pack: int) -> Callable:
        """Jitted packed bucketed admission for a static pack size (the
        bucket length is a shape, not a key — one jit object, one trace per
        bucket); pool + slot-state donated like the single admit."""
        return _cached_jit(
            ("admit_packed", self.cfg, self.cache_len, pack, self._mesh_key),
            lambda: make_engine_admit_packed(self.cfg, self.cache_len, pack),
            donate_argnums=(4, 5, 6, 7))

    def _tick_fn(self, steps: int) -> Callable:
        """Fused tick for a chunk of ``steps`` decode steps; caches and
        slot-state buffers (token/pos) are donated — decode updates the pool
        in place every tick instead of copying it. An AOT-warmed engine
        returns the precompiled executable (``stats["aot_hits"]``); a cache
        miss (post-degradation cfg, oversized chunk) falls back to the lazy
        jit and is counted."""
        jit_fn = self._tick_jit(steps)
        if self.aot_buckets is None:
            return jit_fn
        exe = aot_mod.lookup(self._aot_key("tick", steps))
        if exe is not None:
            self.stats["aot_hits"] += 1
            return self._exe_call(exe)
        self.stats["aot_misses"] += 1
        return jit_fn

    def _packed_fn(self, bucket: int, pack: int) -> Callable:
        jit_fn = self._packed_jit(pack)
        exe = aot_mod.lookup(self._aot_key("admit_packed", bucket, pack))
        if exe is not None:
            self.stats["aot_hits"] += 1
            return self._exe_call(exe)
        self.stats["aot_misses"] += 1
        return jit_fn

    def _exe_call(self, exe) -> Callable:
        """Wrap a compiled executable so mismatched input shardings get
        re-placed instead of raising (see :func:`repro.serve.aot
        .call_matched`); re-placements are counted in
        ``stats["aot_reshards"]``."""
        def call(*args, **kwargs):
            out, moved = aot_mod.call_matched(exe, args, kwargs)
            self.stats["aot_reshards"] += moved
            return out
        return call

    # -- fault handling: integrity, watchdog, degradation ladder ----------
    def _rung(self) -> str:
        """Current degradation-ladder rung. A fused engine always has a
        rung below it (the serial per-op path — the fused scan program
        itself may be the faulty component); below that, interp numerics
        can still drop to table-free exact, which is the bottom."""
        if self.fused:
            return "fused"
        return "serial" if _interp(self.cfg) else "exact"

    def _record_fault(self, reason: str, detail: str = "",
                      action: str = "", layers: tuple | None = None) -> None:
        entry = {"tick": self.stats["ticks"], "reason": reason,
                 "detail": detail, "action": action}
        if layers is not None:
            entry["layers"] = tuple(layers)
        self.faults.append(entry)

    def _count_degradation(self, label: str) -> None:
        d = self.stats["degradations"]
        if isinstance(d, dict):
            d[label] = d.get(label, 0) + 1
        else:
            self.stats["degradations"] = d + 1

    def verify_library(self) -> bool:
        """Re-checksum the resident ROM(s); on mismatch degrade — a plan
        engine checks every slot library and downgrades only the layers
        reading a corrupt one (:meth:`_degrade_slots`); a homogeneous
        engine jumps straight to exact (both interp rungs would gather the
        corrupt ROM)."""
        if self.library is None:
            return True
        self.stats["rom_verifies"] += 1
        if isinstance(self.library, dict):
            bad: list[tuple[str, str]] = []
            for key in sorted(self.library):
                try:
                    self.library[key].verify_resident()
                except LibraryIntegrityError as e:
                    bad.append((key, str(e)))
            if not bad:
                return True
            self.stats["rom_faults"] += len(bad)
            self._degrade_slots([k for k, _ in bad], "rom_integrity",
                                detail="; ".join(m for _, m in bad))
            return False
        try:
            self.library.verify_resident()
            return True
        except LibraryIntegrityError as e:
            self.stats["rom_faults"] += 1
            self._degrade("rom_integrity", to="exact", detail=str(e))
            return False

    def _degrade_slots(self, slot_keys: list, reason: str,
                       detail: str = "") -> None:
        """Per-layer degradation rung (plan engines, DESIGN.md §16): every
        site reading a poisoned slot library drops to exact — in the named
        layers only. The rest of the stack keeps its fused interp datapath;
        ``stats["degradations"]`` and the fault log name the layers."""
        plan = self.cfg.plan
        keys = sorted(set(slot_keys))
        layers: list = []
        for k in keys:
            for lab in plan.layers_using_slot(k):
                if lab not in layers:
                    layers.append(lab)
        layers.sort(key=str)
        self.cfg = self.cfg.replace(plan=plan.degrade_layers(layers, keys))
        self.library = {k: v for k, v in self.library.items()
                        if k not in set(keys)} or None
        for lab in layers:
            self._count_degradation(str(lab))
        self._record_fault(reason, detail=detail,
                           action=f"slots:{','.join(keys)}->exact",
                           layers=tuple(str(x) for x in layers))
        self._trips = 0
        self.numerics = get_numerics(
            self.cfg, self.library, fused=self.fused and _interp(self.cfg))
        self._build_programs()

    def _degrade(self, reason: str, to: str | None = None,
                 detail: str = "") -> None:
        """Walk one rung down the degradation ladder (or jump to ``to``).

        fused → serial flips the dispatch mode and, for interp engines,
        swaps in the domain-guarded numerics (a plan engine guards every
        interp site, :meth:`NumericsPlan.degrade_serial`); → exact drops
        the library (plan: every site to exact). The KV pool and host slot
        mirrors carry over — in-flight requests keep decoding, just on the
        safer datapath.
        """
        was = self._rung()
        if to is None:
            to = "serial" if was == "fused" else "exact"
        if to == was:
            # already at (or below) the requested rung: nothing safer to
            # fall to — log the fault and keep serving
            self._record_fault(reason, detail=detail, action=f"hold:{was}")
            self._trips = 0
            return
        plan = self.cfg.plan
        if to == "serial":
            self.fused = False
            if self.pipeline is not None:
                # the async feeder only exists for the fused tick; the
                # serial rung is the synchronous oracle — drain and drop it
                self.close()
            if plan is not None:
                self.cfg = self.cfg.replace(plan=plan.degrade_serial())
            elif _interp(self.cfg) and self.cfg.numerics != "interp-guarded":
                self.cfg = self.cfg.replace(numerics="interp-guarded")
        elif to == "exact":
            if plan is not None:
                self.cfg = self.cfg.replace(plan=plan.degrade_exact())
            elif self.cfg.numerics != "exact":
                self.cfg = self.cfg.replace(numerics="exact")
            self.library = None
        else:
            raise ValueError(f"unknown degradation rung {to!r}")
        self._count_degradation("engine")
        self._record_fault(reason, detail=detail, action=f"{was}->{to}")
        self._trips = 0
        self.numerics = get_numerics(
            self.cfg, self.library, fused=self.fused and _interp(self.cfg))
        self._build_programs()

    def _watchdog_trip(self, reason: str, detail: str = "") -> None:
        self.stats["watchdog_trips"] += 1
        self._trips += 1
        self._record_fault(reason, detail=detail, action="trip")
        # a trip is also the moment to re-check the ROM: silent corruption
        # often *presents* as a poisoned datapath
        still_ok = self.verify_library()
        if still_ok and self._trips >= self.watchdog_limit:
            self._degrade(f"repeated_{reason}")

    def _journal(self, method: str, *args, crash: str | None = None) -> None:
        """One journal write. Async engines route it through the pipeline's
        FIFO so it lands *after* every already-queued token emit (the
        single-writer ordering :meth:`resume` depends on); sync engines
        write-and-fsync inline and hit the named crashpoint."""
        if self.journal is None:
            return
        if self.pipeline is not None:
            self.pipeline.journal_call(method, *args)
            return
        getattr(self.journal, method)(*args)
        if crash is not None:
            crashpoint(crash)

    def _fail_slot(self, s: int, error: str) -> None:
        """Retire a poisoned/expired slot with a structured error."""
        r = self.req[s]
        if r is None:
            return
        r.error = error
        self.failed.append(r)
        self.stats["slot_failures"] += 1
        self.req[s] = None
        self.cur[s] = -1
        self.pos[s] = 0
        self._emitted[s] = 0
        if self.fused:
            self._live_dev = self._set_live(self._live_dev, s, False)
        self._journal("fail", r.rid, error, crash="serve.fail.journaled")

    # -- admission control -------------------------------------------------
    def submit(self, req: Request):
        """Enqueue a request; rejects work the engine cannot serve safely.

        Typed rejections (:class:`Rejected`, a ``ValueError``):

        * cache overflow — without a sliding window, decode writes KV rows
          at absolute positions ``len(prompt) .. len(prompt)+max_new-2``;
          anything past ``cache_len - 1`` would be silently clamped by the
          dynamic-slice update (overwriting the last row again and again),
          so it is an error here rather than corruption later. Sliding-
          window engines wrap their (full-window, checked at construction)
          cache: prompts beyond the window prefill position-aligned to the
          wrap slots, and decode length is unbounded.
        * ``queue_full`` — bounded backpressure: an unbounded queue under
          sustained over-admission grows without limit while every queued
          request's deadline quietly expires.
        * ``bad_prompt`` — out-of-vocabulary token ids would clamp through
          the embedding gather and decode plausible-looking garbage.
        * ``deadline`` — already expired at submit time.
        """
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            self.stats["rejected"] += 1
            raise Rejected(
                "queue_full",
                f"request {req.rid}: queue full ({len(self.queue)} >= "
                f"max_queue {self.max_queue})")
        if len(req.prompt) == 0:
            self.stats["rejected"] += 1
            raise Rejected("bad_prompt", f"request {req.rid}: empty prompt")
        pmin, pmax = int(np.min(req.prompt)), int(np.max(req.prompt))
        if pmin < 0 or pmax >= self.cfg.vocab_size:
            self.stats["rejected"] += 1
            raise Rejected(
                "bad_prompt",
                f"request {req.rid}: token id {pmin if pmin < 0 else pmax} "
                f"outside vocab [0, {self.cfg.vocab_size})")
        if self.cfg.sliding_window is None:
            if len(req.prompt) > self.cache_len:
                self.stats["rejected"] += 1
                raise Rejected(
                    "prompt_overflow",
                    f"request {req.rid}: prompt length {len(req.prompt)} "
                    f"exceeds cache_len {self.cache_len}")
            if len(req.prompt) + req.max_new - 1 > self.cache_len:
                self.stats["rejected"] += 1
                raise Rejected(
                    "decode_overflow",
                    f"request {req.rid}: prompt ({len(req.prompt)}) + "
                    f"max_new ({req.max_new}) overflows cache_len "
                    f"{self.cache_len}")
        if req.deadline is None and self.deadline_s is not None:
            req.deadline = self.clock() + self.deadline_s
        if req.deadline is not None and self.clock() > req.deadline:
            self.stats["rejected"] += 1
            raise Rejected("deadline",
                           f"request {req.rid}: already past its deadline")
        self._journal("submit", req.rid, req.prompt, req.max_new,
                      req.deadline, crash="serve.submit.journaled")
        self.queue.append(req)

    def _expired(self, r: Request) -> bool:
        return r.deadline is not None and self.clock() > r.deadline

    def _admit(self):
        if (self.aot_buckets is not None and self.fused
                and self._packable):
            self._admit_bucketed()
        else:
            self._admit_legacy()

    def _fail_expired_queued(self, r: Request) -> None:
        """Expired while queued: fail without burning a prefill."""
        r.error = "deadline_exceeded"
        self.failed.append(r)
        self.stats["expired"] += 1
        self._journal("fail", r.rid, r.error)

    def _admit_legacy(self):
        for s in range(self.slots):
            while self.req[s] is None and self.queue:
                r = self.queue.popleft()
                if self._expired(r):
                    # keep draining into this slot
                    self._fail_expired_queued(r)
                    continue
                if r.out:  # resumed mid-stream: rebuild, emit nothing
                    self._admit_replay(r, s)
                    break
                self._admit_one(r, s)
                break

    def _admit_one(self, r: Request, s: int):
        """Exact-length admission of one request into slot ``s`` (the PR-5
        path; also the bucketed path's fallback for prompts longer than
        every bucket)."""
        self.stats["admit_dispatches"] += 1
        if self.fused:
            # one dispatch: prefill + in-place pool splice + greedy
            # first token + slot-state update (donated buffers)
            with self._ctx():
                (first, self.caches, self._tok_dev, self._pos_dev,
                 self._live_dev) = self._admit_fused(
                    self.params, r.prompt[None, :], self.caches, s,
                    self._tok_dev, self._pos_dev, self._live_dev,
                    library=self.library)
            self.req[s] = r
            self.pos[s] = len(r.prompt)
            self._emitted[s] = 1
            if self.pipeline is not None:
                # first-token download + journal emit happen on the worker,
                # in order with every other journal write
                self.pipeline.emit_admit(((0, r),), first)
                return
            tok = int(first)
        else:
            with self._ctx():
                logits, cache1, _ = self._prefill1(
                    self.params, r.prompt[None, :], library=self.library)
                # splice this request's cache rows into slot s of the
                # pool (batch axis differs per segment: tf.splice_cache
                # knows the stacked-layer layout); the pool buffer is
                # donated — the insertion is in place, not a pool copy
                self.caches = self._splice(self.caches, cache1, s)
                tok = int(jnp.argmax(logits[0, -1]))
            self.req[s] = r
            self.pos[s] = len(r.prompt)
            self._emitted[s] = 1
        r.out.append(tok)
        self.cur[s] = tok
        if self.journal is not None:
            self.journal.emit(r.rid, [tok])
            crashpoint("serve.admit.emitted")

    def _admit_bucketed(self):
        """Bucketed admission (DESIGN.md §17): drain the queue front into
        free slots in ascending order exactly like the legacy loop — the
        (request, slot) mapping is fixed *before* grouping, so packing never
        reorders admissions — then group same-bucket admissions and dispatch
        each group as one padded packed prefill."""
        free = [s for s in range(self.slots) if self.req[s] is None]
        packed: list[tuple[Request, int, int]] = []
        while free and self.queue:
            r = self.queue.popleft()
            if self._expired(r):
                self._fail_expired_queued(r)
                continue
            s = free.pop(0)
            if r.out:  # resumed mid-stream: rebuild, emit nothing
                self._admit_replay(r, s)
                continue
            b = self.aot_buckets.bucket_for(len(r.prompt))
            if b is None:
                # longer than every bucket: exact-length compile, counted
                self.stats["aot_fallbacks"] += 1
                self._admit_one(r, s)
                continue
            packed.append((r, s, b))
        by_bucket: dict[int, list] = {}
        for r, s, b in packed:
            by_bucket.setdefault(b, []).append((r, s))
        for b in sorted(by_bucket):
            group = by_bucket[b]
            while group:
                pk = 1
                for cand in self._pack_sizes:
                    if cand <= len(group):
                        pk = cand
                sub, group = group[:pk], group[pk:]
                self._admit_packed(sub, b)

    def _admit_packed(self, sub: list, bucket: int) -> None:
        """One padded prefill dispatch admitting ``len(sub)`` requests."""
        pk = len(sub)
        prompts = np.zeros((pk, bucket), np.int32)
        lens = np.zeros(pk, np.int32)
        slot_ix = np.zeros(pk, np.int32)
        for i, (r, s) in enumerate(sub):
            n = len(r.prompt)
            prompts[i, :n] = r.prompt
            lens[i] = n
            slot_ix[i] = s
        fn = self._packed_fn(bucket, pk)
        args = (jnp.asarray(prompts), jnp.asarray(lens),
                jnp.asarray(slot_ix))
        if self.mesh is not None:
            # AOT executables pin input shardings: host-built admission
            # arrays must arrive committed-replicated like the lowering saw
            rep = shlib.replicated(self.mesh)
            args = tuple(jax.device_put(a, rep) for a in args)
        with self._ctx():
            (firsts, self.caches, self._tok_dev, self._pos_dev,
             self._live_dev) = fn(
                self.params, *args, self.caches, self._tok_dev,
                self._pos_dev, self._live_dev, library=self.library)
        self.stats["admit_dispatches"] += 1
        self.stats["packed_admits"] += 1
        self.stats["packed_requests"] += pk
        for i, (r, s) in enumerate(sub):
            self.req[s] = r
            self.pos[s] = len(r.prompt)
            self._emitted[s] = 1
        if self.pipeline is not None:
            self.pipeline.emit_admit(
                tuple((i, r) for i, (r, _s) in enumerate(sub)), firsts)
            return
        vals = np.asarray(jax.device_get(firsts)).reshape(-1)
        for i, (r, s) in enumerate(sub):
            tok = int(vals[i])
            r.out.append(tok)
            self.cur[s] = tok
            if self.journal is not None:
                self.journal.emit(r.rid, [tok])
        if self.journal is not None:
            crashpoint("serve.admit.emitted")

    def _admit_replay(self, r: Request, s: int):
        """Re-admit a journal-recovered in-flight request at its recorded
        position: prefill the prompt, then *teacher-force* the already-
        emitted tokens through the decode step to rebuild the slot's cache
        bit-identically (greedy decode is deterministic, so replaying the
        recorded tokens reproduces exactly the pre-crash state — and the
        per-slot independence the solo-oracle tests pin makes the B=1
        rebuild equal to the original pooled decode). Nothing is re-emitted
        and nothing is re-journaled."""
        prefill = self._prefill_fnum if self.fused else self._prefill1
        decode = self._decode_fnum if self.fused else self._decode
        with self._ctx():
            _logits, cache1, _ = prefill(self.params, r.prompt[None, :],
                                         library=self.library)
            start = len(r.prompt)
            for i, t in enumerate(r.out[:-1]):
                tok1 = jnp.asarray([[t]], jnp.int32)
                pos1 = jnp.asarray([start + i], jnp.int32)
                _logits, cache1 = decode(self.params, tok1, pos1, cache1,
                                         library=self.library)
                self.stats["resume_replay_steps"] += 1
            self.caches = self._splice(self.caches, cache1, s)
        self.req[s] = r
        self.pos[s] = start + len(r.out) - 1
        self.cur[s] = r.out[-1]
        self._emitted[s] = len(r.out)
        if self.fused:
            (self._tok_dev, self._pos_dev, self._live_dev) = self._set_slot(
                self._tok_dev, self._pos_dev, self._live_dev, s,
                int(r.out[-1]), int(self.pos[s]))
        self.stats["resumed"] += 1

    def _retire(self):
        for s, r in enumerate(self.req):
            if r is None:
                continue
            # the main-thread emitted count, NOT len(r.out): the async
            # pipeline extends r.out from the worker thread
            if self._emitted[s] >= r.max_new:
                r.done = True
                self.finished.append(r)
                self.req[s] = None
                self.cur[s] = -1
                self.pos[s] = 0
                self._emitted[s] = 0
                if self.fused:
                    self._live_dev = self._set_live(self._live_dev, s, False)
                self._journal("done", r.rid, crash="serve.retire.journaled")
            elif self._expired(r):
                self.stats["expired"] += 1
                self._fail_slot(s, "deadline_exceeded")

    def step(self, max_steps: int = 1):
        """One engine tick: admit, batch-decode every live slot, retire.

        Each slot decodes at its *own* next position (``self.pos`` is passed
        as a per-slot vector): a freshly admitted short-prompt request keeps
        writing KV/state rows contiguously after its prefill instead of at
        the batch-wide max position. Empty slots decode garbage at position 0
        that is ignored and overwritten on admission (standard slot padding).

        A fused engine batches up to ``max_steps`` decode steps into the
        tick (``run`` passes ``self.horizon``) — bounded by the smallest
        remaining budget among live slots, so no in-flight request
        overshoots its ``max_new`` mid-chunk and the freed slot admits at
        the next tick (after ``_admit`` drains the queue into free slots, a
        chunk never delays an admission that could have happened). The one
        historical edge is shared with the serial path: a request whose
        admission token already fills its budget (``max_new <= 1``) still
        decodes once before retiring. The default ``step()`` performs
        exactly one decode step either way.
        """
        if self.pipeline is not None:
            self.pipeline.check()
        if (self.verify_rom_every
                and self.stats["ticks"] % self.verify_rom_every == 0):
            self.verify_library()
        self._admit()
        if all(r is None for r in self.req):
            if self.pipeline is not None:
                # idle: everything queued behind us is the backlog — drain
                # so callers observing Request.out see the final state
                self._drain_pipeline()
            return False
        if not self.fused:
            return self._step_serial()
        remaining = min(r.max_new - int(self._emitted[s])
                        for s, r in enumerate(self.req) if r is not None)
        steps = max(1, min(max_steps, remaining))
        # quantize to the largest power of two <= steps: retirement tails
        # then reuse log2(horizon)+1 compiled tick programs (1, 2, 4, ...)
        # instead of jitting one decode-scan per distinct tail length
        steps = 1 << (steps.bit_length() - 1)
        t0 = self.clock()
        with self._ctx():
            (toks, self._tok_dev, self._pos_dev, ok_dev,
             self.caches) = self._tick_fn(steps)(
                self.params, self._tok_dev, self._pos_dev, self._live_dev,
                self.caches, library=self.library)
        self.stats["dispatches"] += 1  # the tick program
        if self.pipeline is not None:
            # async host path: only the (B,) watchdog sentinel comes down
            # synchronously (poison detection timing unchanged); the token
            # block download + detokenize + journal emits ride the worker
            ok = np.asarray(jax.device_get(ok_dev))
            self.stats["transfers"] += 1
            self.stats["ticks"] += 1
            self.stats["decode_steps"] += steps
            tick_s = self.clock() - t0
            poisoned = [s for s, r in enumerate(self.req)
                        if r is not None and not bool(ok[s])]
            alive = tuple((s, r) for s, r in enumerate(self.req)
                          if r is not None and s not in poisoned)
            if alive:
                self.pipeline.emit_chunk(alive, toks)
            for s, _r in alive:
                self._emitted[s] += steps
                self.pos[s] += steps
        else:
            # ONE device->host round-trip: the (steps, B) token block and
            # the (B,) watchdog sentinel come down together
            out, ok = jax.device_get((toks, ok_dev))
            self.stats["transfers"] += 1
            self.stats["ticks"] += 1
            self.stats["decode_steps"] += steps
            tick_s = self.clock() - t0
            poisoned = [s for s, r in enumerate(self.req)
                        if r is not None and not bool(ok[s])]
            for s, r in enumerate(self.req):
                if r is not None and s not in poisoned:
                    fresh = [int(t) for t in out[:, s]]
                    r.out.extend(fresh)
                    self.cur[s] = int(out[-1, s])
                    self.pos[s] += steps
                    self._emitted[s] += steps
                    if self.journal is not None:
                        self.journal.emit(r.rid, fresh)
            if self.journal is not None:
                crashpoint("serve.tick.emitted")
        for s in poisoned:
            # a poisoned slot is retired with a structured error — its
            # chunk of garbage tokens is never streamed or journaled
            self._fail_slot(s, "non_finite_output")
        if poisoned:
            self._watchdog_trip("non_finite_output",
                                detail=f"slots {poisoned}")
        if self.max_tick_s is not None and tick_s > self.max_tick_s:
            self._watchdog_trip("stalled_tick",
                                detail=f"{tick_s:.3f}s > {self.max_tick_s}s")
        self._retire()
        return True

    def _step_serial(self):
        """The ISSUE-3/4 per-op tick: token upload, one decode dispatch, a
        host argmax round-trip — kept as the fused path's oracle/baseline.
        The watchdog sentinel rides the argmax program: same dispatch and
        transfer budget as the bare argmax it replaced."""
        toks = jnp.asarray(np.maximum(self.cur, 0)[:, None], jnp.int32)
        pos = jnp.asarray(self.pos, jnp.int32)
        self.stats["transfers"] += 2  # token + position upload
        t0 = self.clock()
        with self._ctx():
            logits, self.caches = self._decode(
                self.params, toks, pos, self.caches, library=self.library)
        self.stats["dispatches"] += 1  # decode program
        nxt_dev, ok_dev = self._argmax_ok(logits)
        self.stats["dispatches"] += 1  # argmax+sentinel program
        nxt, ok = jax.device_get((nxt_dev, ok_dev))
        self.stats["transfers"] += 1  # next-token (+ sentinel) download
        self.stats["ticks"] += 1
        self.stats["decode_steps"] += 1
        tick_s = self.clock() - t0
        poisoned = [s for s, r in enumerate(self.req)
                    if r is not None and not bool(ok[s])]
        for s, r in enumerate(self.req):
            if r is not None and s not in poisoned:
                r.out.append(int(nxt[s]))
                self.cur[s] = int(nxt[s])
                self.pos[s] += 1
                self._emitted[s] += 1
                if self.journal is not None:
                    self.journal.emit(r.rid, [int(nxt[s])])
        if self.journal is not None:
            crashpoint("serve.tick.emitted")
        for s in poisoned:
            self._fail_slot(s, "non_finite_output")
        if poisoned:
            self._watchdog_trip("non_finite_output",
                                detail=f"slots {poisoned}")
        if self.max_tick_s is not None and tick_s > self.max_tick_s:
            self._watchdog_trip("stalled_tick",
                                detail=f"{tick_s:.3f}s > {self.max_tick_s}s")
        self._retire()
        return True

    def run(self, max_ticks: int = 10_000) -> list[Request]:
        t = 0
        while (self.queue or any(r is not None for r in self.req)) and t < max_ticks:
            self.step(self.horizon)
            t += 1
        self._drain_pipeline()
        return self.finished

    # -- async host pipeline lifecycle -------------------------------------
    def _drain_pipeline(self) -> None:
        """Block until the background worker has processed everything queued
        so far, fold its counters into ``self.stats``, and surface any
        worker exception. After this, every finished request's ``out`` holds
        its full token stream."""
        if self.pipeline is None:
            return
        self.pipeline.flush()
        got = self.pipeline.drain_stats()
        self.stats["transfers"] += got.get("transfers", 0)
        self.stats["async_chunks"] += got.get("chunks", 0)
        self.stats["async_tokens"] += got.get("tokens", 0)

    def close(self) -> None:
        """Clean shutdown of the async host pipeline (sync engines: no-op).
        The engine stays usable afterwards — it falls back to synchronous
        host bookkeeping."""
        if self.pipeline is None:
            return
        self._drain_pipeline()
        self.pipeline.close()
        self.pipeline = None

    # -- crash recovery ----------------------------------------------------
    @classmethod
    def resume(cls, journal: str, cfg, params, *, slots: int, cache_len: int,
               **kw) -> "ServeEngine":
        """Reconstruct an engine from its admission/token journal.

        Completed (``done``/``fail``) requests are *never* replayed
        (``stats["resume_skipped_done"]`` counts them; their records are
        available via :func:`repro.serve.journal.load_requests`). In-flight
        requests are re-queued with their durable token prefix and
        re-admitted through the teacher-forced rebuild
        (:meth:`_admit_replay`): nothing already journaled is re-emitted,
        and the continued greedy decode produces bitwise the token suffix
        an uninterrupted run would have (the chaos suite's recovery
        contract). The journal stays attached — the resumed engine keeps
        appending to it.
        """
        states = load_requests(journal)
        eng = cls(cfg, params, slots=slots, cache_len=cache_len,
                  journal=journal, **kw)
        for st in states.values():
            if not st.in_flight:
                eng.stats["resume_skipped_done"] += 1
                continue
            if len(st.out) >= st.max_new:
                # crashed between the last emit and the done record: the
                # request is complete — journal the terminal event now,
                # replay nothing
                req = Request(st.rid, st.prompt, st.max_new,
                              out=list(st.out), done=True,
                              deadline=st.deadline)
                eng.finished.append(req)
                eng.stats["resume_skipped_done"] += 1
                if eng.journal is not None:
                    eng.journal.done(st.rid)
                continue
            eng.queue.append(Request(st.rid, st.prompt, st.max_new,
                                     out=list(st.out),
                                     deadline=st.deadline))
        return eng
