"""Serving: jit'd prefill/decode steps + a continuous-batching engine.

``make_serve_step`` builds the decode function the dry-run lowers for the
``decode_32k`` / ``long_500k`` cells: one new token against a seq_len-deep
KV cache (or SSM state), exactly as the shape table specifies.

``ServeEngine`` is a minimal continuous-batching driver: a fixed pool of B
slots, each slot holding one request's cache rows; finished requests free
their slot and a queued request is prefilled into it. Slot state lives in
the batched cache pytree — insertion is a per-slot dynamic_update on the
batch axis.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import DEFAULTS, default_explorer
from repro.models import transformer as tf
from repro.numerics.ops import get_numerics


def make_serve_step(cfg) -> Callable:
    """decode_step(params, token (B,1), pos (), caches) -> (logits, caches)."""
    numerics = get_numerics(cfg.numerics)

    def step(params, token, pos, caches, cross=None):
        return tf.decode_step(params, token, pos, caches, cfg, numerics, cross=cross)

    return step


def make_prefill(cfg, cache_len: int) -> Callable:
    numerics = get_numerics(cfg.numerics)

    def pf(params, tokens, frontend_emb=None, enc_frames=None):
        return tf.prefill(params, tokens, cfg, numerics, cache_len,
                          frontend_emb=frontend_emb, enc_frames=enc_frames)

    return pf


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Continuous batching over a fixed slot pool (greedy decoding)."""

    def __init__(self, cfg, params, slots: int, cache_len: int):
        self.cfg, self.params = cfg, params
        self.slots, self.cache_len = slots, cache_len
        numerics = get_numerics(cfg.numerics)
        self.numerics = numerics
        if cfg.numerics == "interp":
            # Warm every table the decode path can touch, so generation (if
            # not disk-cached yet) happens at engine construction rather than
            # inside the first jitted step. The jitted numerics resolve
            # tables through the process default session, so warm-up must use
            # the same one; to serve from a custom session (cache dir, worker
            # pool), install it with repro.api.set_default_explorer() before
            # constructing the engine.
            ex = default_explorer()
            # silu/gelu/softplus are hardcoded by MoE/SSM layers and the
            # vision-stub projector regardless of cfg.act, so always warm
            # them too (softplus: the SSM dt activation in decode).
            kinds = {"exp2neg", "recip", "rsqrt", "silu", "gelu", "softplus"}
            if getattr(cfg, "act", None) in DEFAULTS:
                kinds.add(cfg.act)
            for kind in sorted(kinds):
                ex.get_table(kind)
        self.caches = tf.init_cache(cfg, slots, cache_len)
        self.pos = np.zeros(slots, np.int32)  # next position per slot
        self.cur = np.full(slots, -1, np.int32)  # current token per slot
        self.req: list[Request | None] = [None] * slots
        self.queue: list[Request] = []
        self.finished: list[Request] = []

        self._prefill1 = jax.jit(make_prefill(cfg, cache_len))
        self._decode = jax.jit(make_serve_step(cfg))

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self.req[s] is None and self.queue:
                r = self.queue.pop(0)
                logits, cache1, _ = self._prefill1(self.params, r.prompt[None, :])
                # splice this request's cache rows into slot s of the pool
                self.caches = jax.tree.map(
                    lambda pool, one: jax.lax.dynamic_update_slice_in_dim(
                        pool, one.astype(pool.dtype), s, axis=0),
                    self.caches, cache1)
                tok = int(jnp.argmax(logits[0, -1]))
                r.out.append(tok)
                self.req[s] = r
                self.pos[s] = len(r.prompt)
                self.cur[s] = tok

    def _retire(self):
        for s, r in enumerate(self.req):
            if r is not None and (len(r.out) >= r.max_new):
                r.done = True
                self.finished.append(r)
                self.req[s] = None
                self.cur[s] = -1

    def step(self):
        """One engine tick: admit, batch-decode every live slot, retire."""
        self._admit()
        if all(r is None for r in self.req):
            return False
        # uniform-position decode per tick: all live slots share max(pos);
        # empty slots decode garbage that is ignored (standard slot padding)
        pos = int(self.pos.max())
        toks = jnp.asarray(np.maximum(self.cur, 0)[:, None], jnp.int32)
        logits, self.caches = self._decode(self.params, toks,
                                           jnp.asarray(pos, jnp.int32), self.caches)
        nxt = np.asarray(jnp.argmax(logits[:, 0], -1), np.int32)
        for s, r in enumerate(self.req):
            if r is not None:
                r.out.append(int(nxt[s]))
                self.cur[s] = int(nxt[s])
                self.pos[s] = pos + 1
        self._retire()
        return True

    def run(self, max_ticks: int = 10_000) -> list[Request]:
        t = 0
        while (self.queue or any(r is not None for r in self.req)) and t < max_ticks:
            self.step()
            t += 1
        return self.finished
