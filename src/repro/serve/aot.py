"""AOT warm-up for the serving tier: bucket table + compiled-executable cache.

Lazy ``jax.jit`` pays its compile on the first *request* — the worst place:
TTFT for the unlucky prompt length includes a full XLA compile, and every
distinct prompt length is its own unlucky prompt. The AOT tier moves all of
that to engine construction:

  * :class:`BucketTable` — a small ascending set of prefill lengths. A
    prompt admits at the smallest bucket that holds it (right-padded;
    ``models.transformer.prefill_padded`` keeps the padded rows bit-exact
    and masks the pad tail dead), so the engine serves *any* prompt length
    from a handful of compiled programs. Prompts longer than the largest
    bucket fall back to an exact-length compile, counted in
    ``stats["aot_fallbacks"]``.
  * :func:`compile_cached` — ``jax.jit(...).lower(...).compile()`` keyed by
    the frozen ``(kind, cfg[, plan], shapes, mesh)`` tuple in a module-level
    cache, mirroring the engine's ``_JIT_CACHE``: reconstructing a
    ``ServeEngine`` (same config, same mesh) reuses every executable.
    Compiled executables pin their input shardings, so the mesh is part of
    the key via :func:`mesh_key`.

The engine warms the decode tick at every power-of-two chunk size up to its
horizon plus a packed admission program per (bucket, pack) pair, then serves
with ``stats["aot_hits"]`` / ``stats["aot_misses"]`` counters — a warmed
engine's steady state shows zero misses, the property BENCH_10 asserts.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

DEFAULT_BUCKETS = (16, 32, 64, 128, 256, 512)


@dataclasses.dataclass(frozen=True)
class BucketTable:
    """Ascending, de-duplicated prefill length buckets."""

    buckets: tuple[int, ...]

    def __post_init__(self):
        bs = tuple(int(b) for b in self.buckets)
        if not bs:
            raise ValueError("BucketTable needs at least one bucket")
        if any(b < 1 for b in bs):
            raise ValueError(f"bucket lengths must be positive: {bs}")
        if list(bs) != sorted(set(bs)):
            raise ValueError(f"buckets must be ascending and unique: {bs}")
        object.__setattr__(self, "buckets", bs)

    @classmethod
    def for_cache(cls, cache_len: int,
                  buckets=DEFAULT_BUCKETS) -> "BucketTable":
        """Clip a candidate set to the slot cache: buckets longer than
        ``cache_len`` can never admit (submit rejects those prompts), and an
        empty survivor set degenerates to one full-cache bucket."""
        bs = sorted({int(b) for b in buckets if 0 < int(b) <= int(cache_len)})
        return cls(tuple(bs) if bs else (int(cache_len),))

    def bucket_for(self, n: int) -> Optional[int]:
        """Smallest bucket holding an ``n``-token prompt; an exact-boundary
        prompt (``n == bucket``) uses that bucket, not the next one. ``None``
        = longer than every bucket (exact-length fallback)."""
        for b in self.buckets:
            if n <= b:
                return b
        return None


def mesh_key(mesh) -> Optional[tuple]:
    """Hashable identity of a mesh for executable cache keys (``None`` for
    single-host engines). Device ids are included: executables pin input
    shardings to concrete devices."""
    if mesh is None:
        return None
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat))


# Compiled executables shared across engines, keyed by the frozen
# (kind, cfg[, plan], static shapes, mesh) tuple — the AOT analogue of
# engine._JIT_CACHE. An entry is a jax Compiled object: calling it never
# retraces or recompiles.
_EXEC_CACHE: dict = {}


def lookup(key: tuple):
    return _EXEC_CACHE.get(key)


def compile_cached(key: tuple, jit_fn, args: tuple, kwargs: dict):
    """AOT-compile ``jit_fn`` for the concrete ``args``/``kwargs`` (their
    shapes, dtypes *and shardings* are what gets pinned) unless an
    executable is already cached under ``key``. Lowering only traces — the
    donated buffers among ``args`` are not consumed."""
    exe = _EXEC_CACHE.get(key)
    if exe is None:
        exe = jit_fn.lower(*args, **kwargs).compile()
        _EXEC_CACHE[key] = exe
    return exe


def clear_cache() -> None:
    """Drop every cached executable (tests; never needed in serving)."""
    _EXEC_CACHE.clear()


def call_matched(exe, args: tuple, kwargs: dict):
    """Call a compiled executable, re-placing any input whose sharding no
    longer matches what the executable was compiled with (a Compiled object
    rejects mismatched inputs instead of resharding them the way ``jit``
    would). Steady state is a fixed point — the engine warms with the same
    shardings the programs emit — so the device_put is a no-op almost
    always; the count of actual re-placements comes back for
    ``stats["aot_reshards"]``."""
    import jax

    leaves, treedef = jax.tree.flatten((args, kwargs))
    want = jax.tree.leaves(exe.input_shardings)
    moved = 0
    if len(want) == len(leaves):
        out = []
        for x, s in zip(leaves, want):
            if isinstance(x, jax.Array) and not x.sharding.is_equivalent_to(
                    s, x.ndim):
                x = jax.device_put(x, s)
                moved += 1
            out.append(x)
        args, kwargs = jax.tree.unflatten(treedef, out)
    return exe(*args, **kwargs), moved


def pack_sizes(max_pack: int, slots: int) -> tuple[int, ...]:
    """Powers of two up to ``min(max_pack, slots)`` — the packed-admission
    group sizes the engine compiles (a group of e.g. 5 admits as 4 + 1)."""
    cap = max(1, min(int(max_pack), int(slots)))
    out = [1]
    while out[-1] * 2 <= cap:
        out.append(out[-1] * 2)
    return tuple(out)


def compile_count(table: "BucketTable", max_pack: int, slots: int,
                  horizon: int) -> int:
    """How many programs a full warm-up compiles (bucket x pack grid plus
    the power-of-two tick chunks) — surfaced by the CLI so operators can
    see what construction will pay before it happens."""
    ticks = len([s for s in _pow2_upto(horizon)])
    return len(table.buckets) * len(pack_sizes(max_pack, slots)) + ticks


def _pow2_upto(n: int):
    s = 1
    while s <= max(1, int(n)):
        yield s
        s *= 2


def tick_chunk_sizes(horizon: int) -> tuple[int, ...]:
    """The engine quantizes tick chunks to powers of two <= horizon."""
    return tuple(_pow2_upto(horizon))
