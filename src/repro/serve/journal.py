"""Crash-recoverable serve state: the engine's admission/token journal.

A journaled :class:`repro.serve.ServeEngine` appends one fsync'd jsonl
event per durability transition, through the shared
:mod:`repro.util.journal` machinery (same discipline as the DSE study
store — DESIGN.md §13/§14):

    {"ev": "submit", "rid": 3, "prompt": [...], "max_new": 12,
     "deadline": null}
    {"ev": "emit",   "rid": 3, "toks": [17, 4, ...]}   # per tick, per req
    {"ev": "done",   "rid": 3}
    {"ev": "fail",   "rid": 3, "error": "deadline_exceeded"}

The journal is the engine's recovery contract: after a kill at any
instant, :meth:`ServeEngine.resume` folds the journal into per-request
replay states (:func:`load_requests`) and reconstructs exactly the
in-flight work — completed requests are never replayed, already-emitted
tokens are never re-emitted, and greedy decoding being deterministic, the
resumed engine's token suffix is bitwise the suffix an uninterrupted run
would have produced.

A torn final line (the append that died mid-crash) is dropped on load —
its tokens were never durable, and the resumed engine regenerates them
identically. Mid-file corruption raises :class:`ServeJournalCorrupt`.
"""
from __future__ import annotations

import dataclasses
import pathlib

import numpy as np

from repro.util.journal import JournalCorrupt, JournalWriter, read_journal

SERVE_JOURNAL_SCHEMA = 1


class ServeJournalCorrupt(JournalCorrupt):
    """The serve journal is damaged beyond a torn tail."""


class ServeJournal:
    """Append-side schema over a :class:`repro.util.journal.JournalWriter`."""

    def __init__(self, path: str | pathlib.Path):
        self.path = pathlib.Path(path)
        self._writer = JournalWriter(self.path)

    def close(self) -> None:
        self._writer.close()

    # -- events ------------------------------------------------------------
    def submit(self, rid: int, prompt, max_new: int,
               deadline: float | None) -> None:
        self._writer.append({
            "schema": SERVE_JOURNAL_SCHEMA, "ev": "submit", "rid": int(rid),
            "prompt": [int(t) for t in prompt], "max_new": int(max_new),
            "deadline": None if deadline is None else float(deadline)})

    def emit(self, rid: int, toks) -> None:
        if len(toks):
            self._writer.append({"ev": "emit", "rid": int(rid),
                                 "toks": [int(t) for t in toks]})

    def done(self, rid: int) -> None:
        self._writer.append({"ev": "done", "rid": int(rid)})

    def fail(self, rid: int, error: str) -> None:
        self._writer.append({"ev": "fail", "rid": int(rid),
                             "error": str(error)})


@dataclasses.dataclass
class ReplayState:
    """One request's durable state folded out of the journal."""

    rid: int
    prompt: np.ndarray
    max_new: int
    deadline: float | None = None
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    error: str | None = None

    @property
    def in_flight(self) -> bool:
        return not self.done and self.error is None


def load_requests(path: str | pathlib.Path) -> dict[int, ReplayState]:
    """Fold a serve journal into per-request replay states (rid-keyed,
    journal order preserved — dicts iterate in insertion order)."""
    events, _dropped = read_journal(path, corrupt=ServeJournalCorrupt)
    out: dict[int, ReplayState] = {}
    for e in events:
        ev, rid = e.get("ev"), e.get("rid")
        if ev == "submit":
            out[rid] = ReplayState(
                rid=rid, prompt=np.asarray(e["prompt"], np.int32),
                max_new=e["max_new"], deadline=e.get("deadline"))
        elif ev == "emit" and rid in out:
            out[rid].out.extend(int(t) for t in e["toks"])
        elif ev == "done" and rid in out:
            out[rid].done = True
        elif ev == "fail" and rid in out:
            out[rid].error = e.get("error", "unknown")
    return out
