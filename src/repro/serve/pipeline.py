"""Async host pipeline: detokenize/journal bookkeeping off the tick path.

The synchronous engine interleaves device work with host work every tick:
dispatch the fused tick, download the (steps, B) token block, extend each
request's output list, journal (fsync) the fresh tokens — the device idles
through all of that Python. :class:`HostPipeline` moves everything after
the dispatch onto one background worker thread fed through a *bounded*
queue:

  * the main thread keeps only the (B,) watchdog-sentinel download per tick
    (poison detection timing is unchanged from DESIGN.md §14) and hands the
    device-resident token block to the worker;
  * the worker downloads the block, extends ``Request.out``, and performs
    **all** journal writes — admission records, token emits, done/fail
    marks — in queue order. One writer thread means the journal's
    append-then-fsync ordering is exactly the synchronous engine's, so
    :meth:`ServeEngine.resume` replays an async engine's journal
    unchanged;
  * the bounded queue is backpressure: if the host falls behind, the main
    thread blocks on ``put`` instead of buffering unboundedly;
  * worker exceptions are captured and re-raised on the main thread at the
    next ``check()``/``flush()`` — a failed fsync fails the engine, not a
    daemon thread's stderr.

Shutdown: ``flush()`` drains (blocks until every queued item is processed),
``close()`` drains then joins the thread. Stats are accumulated worker-side
and folded into the engine's counters at ``drain_stats()`` — no cross-
thread mutation of shared dicts.
"""
from __future__ import annotations

import queue
import threading

import jax
import numpy as np


class HostPipeline:
    """One background worker consuming (chunk | admit | journal) items."""

    def __init__(self, journal=None, depth: int = 4):
        self.journal = journal
        self._q: queue.Queue = queue.Queue(maxsize=max(1, int(depth)))
        self._lock = threading.Lock()
        self._stats = {"transfers": 0, "chunks": 0, "tokens": 0}
        self._exc: BaseException | None = None
        self._closed = False
        self._thread = threading.Thread(
            target=self._worker, name="serve-host-pipeline", daemon=True)
        self._thread.start()

    # -- producer side (engine main thread) --------------------------------
    def emit_chunk(self, items, toks) -> None:
        """``items``: ((slot, Request), ...) for the healthy slots of one
        tick; ``toks``: the device-resident (steps, B) token block. The
        worker downloads, detokenizes into each request and journals."""
        self._put(("chunk", tuple(items), toks))

    def emit_admit(self, items, firsts) -> None:
        """``items``: ((row, Request), ...) of one admission dispatch;
        ``firsts``: device-resident first-token vector (or scalar)."""
        self._put(("admit", tuple(items), firsts))

    def journal_call(self, method: str, *args) -> None:
        """Route a journal write (submit/done/fail) through the worker so it
        lands *after* every token emit already queued."""
        if self.journal is not None:
            self._put(("journal", method, args))

    def flush(self) -> None:
        """Block until the queue is fully processed; surface worker errors."""
        self._q.join()
        self.check()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._q.put(("stop",))
        self._thread.join(timeout=60.0)
        self.check()

    def check(self) -> None:
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc

    def drain_stats(self) -> dict:
        """Return-and-zero the worker-side counters (fold into engine
        stats on the main thread)."""
        with self._lock:
            out, self._stats = self._stats, {k: 0 for k in self._stats}
        return out

    @property
    def depth(self) -> int:
        return self._q.qsize()

    def _put(self, item) -> None:
        self.check()
        if self._closed:
            raise RuntimeError("HostPipeline is closed")
        self._q.put(item)  # blocks when full: bounded backpressure

    # -- worker side --------------------------------------------------------
    def _bump(self, **deltas) -> None:
        with self._lock:
            for k, v in deltas.items():
                self._stats[k] += v

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            try:
                kind = item[0]
                if kind == "stop":
                    return
                if self._exc is not None:
                    continue  # poisoned: drain without side effects
                if kind == "chunk":
                    _, items, toks = item
                    block = np.asarray(jax.device_get(toks))
                    self._bump(transfers=1, chunks=1,
                               tokens=block.shape[0] * len(items))
                    for slot, req in items:
                        fresh = [int(t) for t in block[:, slot]]
                        req.out.extend(fresh)
                        if self.journal is not None:
                            self.journal.emit(req.rid, fresh)
                elif kind == "admit":
                    _, items, firsts = item
                    vals = np.asarray(jax.device_get(firsts)).reshape(-1)
                    self._bump(transfers=1, tokens=len(items))
                    for row, req in items:
                        tok = int(vals[row])
                        req.out.append(tok)
                        if self.journal is not None:
                            self.journal.emit(req.rid, [tok])
                elif kind == "journal":
                    _, method, args = item
                    if self.journal is not None:
                        getattr(self.journal, method)(*args)
            except BaseException as e:  # noqa: BLE001 — surfaced to main
                self._exc = e
            finally:
                self._q.task_done()
