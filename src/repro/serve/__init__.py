from repro.serve.aot import BucketTable  # noqa: F401
from repro.serve.engine import (Rejected, Request, ServeEngine,  # noqa: F401
                                make_serve_step)
from repro.serve.journal import (ReplayState, ServeJournal,  # noqa: F401
                                 ServeJournalCorrupt, load_requests)
from repro.serve.pipeline import HostPipeline  # noqa: F401
