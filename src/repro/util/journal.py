"""Shared torn-write-safe persistence primitives.

This is the one implementation of the durability discipline every
persistent artifact in the repo follows (library saves, DSE studies,
checkpoints, serve-state journals — DESIGN.md §10/§13/§14):

  * ``atomic_write_text``/``atomic_write_bytes``: tmp file → flush →
    ``fsync`` → atomic rename. A crash at any instant leaves either the
    old complete file or the new complete file, never a torn mix.
  * ``JournalWriter``: an append-only jsonl journal where a record is
    durable only once its ``\\n``-terminated line has been flushed and
    ``fsync``'d. Opening for append first repairs the tail: a complete
    final record missing only its newline is terminated; a torn fragment
    (the append that wrote it died before fsync returned, so it was never
    durable) is truncated away.
  * ``read_journal``: parses a journal, dropping a torn *final* line
    (recoverable tail damage) but raising :class:`JournalCorrupt` for an
    undecodable line mid-file — that is real corruption, and silently
    dropping committed records behind it would be data loss.

``repro.dse.store.StudyStore`` and ``repro.serve.journal.ServeJournal``
are thin schemas over these primitives; ``repro.checkpoint`` routes its
manifest/pointer writes through the atomic helpers.
"""
from __future__ import annotations

import json
import os
import pathlib
from typing import Any, Callable


class JournalCorrupt(RuntimeError):
    """A journal is damaged beyond a torn tail (mid-file corruption)."""


def atomic_write_bytes(path: str | pathlib.Path, data: bytes,
                       tmp_suffix: str = ".tmp") -> pathlib.Path:
    """Durably replace ``path`` with ``data``: tmp + flush + fsync + rename."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_name(path.name + tmp_suffix)
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    tmp.replace(path)
    return path


def atomic_write_text(path: str | pathlib.Path, text: str,
                      tmp_suffix: str = ".tmp") -> pathlib.Path:
    return atomic_write_bytes(path, text.encode("utf-8"), tmp_suffix)


def trim_torn_tail(path: str | pathlib.Path) -> None:
    """Repair an unterminated journal tail in place (see module docstring)."""
    path = pathlib.Path(path)
    if not path.exists():
        return
    with open(path, "rb+") as f:
        data = f.read()
        if not data or data.endswith(b"\n"):
            return
        cut = data.rfind(b"\n") + 1
        try:
            json.loads(data[cut:].decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError):
            f.truncate(cut)
        else:
            f.write(b"\n")


def read_journal(path: str | pathlib.Path,
                 corrupt: Callable[[str], Exception] = JournalCorrupt
                 ) -> tuple[list[dict[str, Any]], int]:
    """All durable records of a jsonl journal, plus the count of torn
    final lines dropped. ``corrupt`` builds the exception raised on
    mid-file damage (lets callers surface their own error type)."""
    path = pathlib.Path(path)
    if not path.exists():
        return [], 0
    raw = path.read_text(encoding="utf-8")
    if not raw:
        return [], 0
    lines = raw.split("\n")
    if lines[-1] == "":
        lines.pop()  # the usual case: journal ends with a newline
    out: list[dict[str, Any]] = []
    dropped = 0
    last = len(lines) - 1
    for i, line in enumerate(lines):
        if line == "":
            continue
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError as e:
            if i == last:
                # the final line only: a torn append (with or without its
                # newline) is recoverable tail damage
                dropped += 1
                continue
            raise corrupt(
                f"{path}: undecodable journal line {i + 1} (not the tail — "
                f"refusing to drop committed records)") from e
    return out, dropped


class JournalWriter:
    """Append-only fsync'd jsonl journal (lazily opened, tail-repairing)."""

    def __init__(self, path: str | pathlib.Path):
        self.path = pathlib.Path(path)
        self._fh = None

    def __enter__(self) -> "JournalWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def append(self, record: dict[str, Any]) -> None:
        """Durably journal one record: write line, flush, fsync."""
        if self._fh is None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            trim_torn_tail(self.path)
            self._fh = open(self.path, "a", encoding="utf-8")
        line = json.dumps(record, sort_keys=True, separators=(",", ":"))
        self._fh.write(line + "\n")
        self._fh.flush()
        os.fsync(self._fh.fileno())
