from repro.util.journal import (JournalCorrupt, JournalWriter,  # noqa: F401
                                atomic_write_bytes, atomic_write_text,
                                read_journal)
