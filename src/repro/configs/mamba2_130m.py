"""Mamba2-130M [arXiv:2405.21060]: 24L, d=768, attention-free SSD,
ssm_state=128, vocab 50280 (padded to 50288 for lane alignment in the HF
release; we keep the published 50280)."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2_130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1),
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, vocab_size=256,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1,
                      chunk=32),
        param_dtype="float32",
    )
