"""Mixtral 8x22B [arXiv:2401.04088; hf]: 56L, d=6144, 48H GQA(kv=8),
d_ff=16384 per expert, vocab 32768, MoE 8 experts top-2, sliding-window
attention. SWA makes the long_500k decode cell runnable (rolling cache)."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral_8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    sliding_window=4096,
    rope_theta=1e6,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=16384),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, sliding_window=32,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=128),
        param_dtype="float32",
    )
