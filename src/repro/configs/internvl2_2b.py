"""InternVL2-2B [arXiv:2404.16821; hf]: InternLM2-1.8B language backbone
(24L, d=2048, 16H GQA kv=8, d_ff=8192, vocab 92553) + InternViT stub: the
vision tower is a STUB per the assignment; input_specs() provides 256
precomputed patch embeddings at 1024 dims, mapped by an MLP projector."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2_2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    frontend="vision_stub",
    frontend_dim=1024,
    frontend_len=256,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, frontend_dim=32, frontend_len=16,
        param_dtype="float32",
    )
