"""Assigned-architecture configs (one module per arch) + shape table."""
from repro.configs.base import (ARCH_IDS, SHAPES, EncoderConfig, MLAConfig,  # noqa: F401
                                ModelConfig, MoEConfig, ShapeConfig, SSMConfig,
                                cell_is_runnable, get_config, get_smoke_config)
