"""Architecture configuration system.

One frozen dataclass describes every assigned architecture; per-arch modules
(`repro.configs.<id>`) export ``CONFIG`` with the exact published figures and
``smoke_config()`` with a reduced same-family variant for CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.plan.schema import NumericsPlan


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int  # per-expert FFN hidden size
    n_shared: int = 0  # always-on shared experts (DeepSeekMoE)
    capacity_factor: float = 1.25
    every: int = 1  # MoE layer period (Jamba: 2); dense MLP otherwise
    router_numerics: bool = True  # route through the numerics backend softmax


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256  # SSD chunk length


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    n_layers: int
    source_len: int  # frozen source length (whisper: 1500 frames)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention
    attn_bias: bool = False  # qwen-style QKV bias
    sliding_window: Optional[int] = None  # mixtral SWA
    mla: Optional[MLAConfig] = None
    rope_theta: float = 1e4
    # mixture of experts
    moe: Optional[MoEConfig] = None
    first_dense_ff: Optional[int] = None  # DeepSeekMoE: dense layer 0 with own d_ff
    # ssm / hybrid
    ssm: Optional[SSMConfig] = None
    attn_period: int = 0  # hybrid: 1 attention layer per this many (Jamba: 8)
    # encoder-decoder / modality frontends
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[str] = None  # audio_stub | vision_stub
    frontend_dim: int = 0  # stub embedding dim (projector input)
    frontend_len: int = 0  # number of prepended frontend tokens
    # misc
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu | gelu | relu2
    learned_pos: bool = False  # whisper: learned positions instead of RoPE
    max_pos: int = 32768  # learned-position table height (learned_pos only)
    tie_embeddings: bool = False
    numerics: str = "exact"  # exact | interp  (the paper's technique switch)
    # per-layer heterogeneous numerics (DESIGN.md §16). When set, the plan
    # overrides ``numerics``: each layer x op site carries its own backend
    # and library slot. Frozen/hashable so configs still key jit caches.
    plan: Optional[NumericsPlan] = None
    # runtime policy
    param_dtype: str = "bfloat16"
    remat: str = "block"  # none | block | full

    @property
    def head_size(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 500k-token contexts? (SSM/hybrid state or SWA)"""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "mixtral_8x22b", "deepseek_moe_16b", "qwen1_5_110b", "minicpm3_4b",
    "minitron_8b", "yi_6b", "mamba2_130m", "jamba_v0_1_52b", "whisper_tiny",
    "internvl2_2b",
]


def get_config(arch: str) -> ModelConfig:
    import importlib

    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    import importlib

    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")
    return mod.smoke_config()


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Which (arch x shape) cells run; the rest are recorded as skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full quadratic attention at 512k KV is the marked-skip case"
    return True, ""
