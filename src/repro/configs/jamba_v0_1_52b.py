"""Jamba-v0.1 52B [arXiv:2403.19887; hf]: 32L hybrid, d=4096, 32H GQA(kv=8)
in the attention layers (1 per 8), Mamba elsewhere (d_state=16), d_ff=14336,
MoE 16 experts top-2 on every other layer, vocab 65536."""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba_v0_1_52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    attn_period=8,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1),
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=14336, every=2),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=256, attn_period=4,
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=16, n_groups=1,
                      chunk=32),
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=96, every=2),
        param_dtype="float32",
    )
