"""Minitron-8B [arXiv:2407.14679; hf]: pruned Nemotron-4: 32L, d=4096,
32H GQA(kv=8), d_ff=16384, vocab 256000, squared-ReLU MLP."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron_8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    act="relu2",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=512, param_dtype="float32",
    )
