"""Whisper-tiny [arXiv:2212.04356]: enc-dec, 4+4L, d=384, 6H, d_ff=1536,
vocab 51865, GELU, LayerNorm, learned positions. The conv audio frontend is
a STUB per the assignment: input_specs() provides precomputed frame
embeddings (B, 1500, 384)."""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper_tiny",
    family="encdec",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    encoder=EncoderConfig(n_layers=4, source_len=1500),
    frontend="audio_stub",
    frontend_dim=384,
    norm="layernorm",
    act="gelu",
    learned_pos=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256,
        encoder=EncoderConfig(n_layers=2, source_len=64),
        frontend_dim=64, param_dtype="float32",
    )
