"""Qwen1.5-110B [hf:Qwen/Qwen1.5-110B family]: 80L, d=8192, 64H GQA(kv=8),
d_ff=49152, vocab 152064, QKV bias."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1_5_110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=49152,
    vocab_size=152064,
    attn_bias=True,
    rope_theta=1e6,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=256, param_dtype="float32",
    )
