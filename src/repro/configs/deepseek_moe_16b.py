"""DeepSeekMoE 16B [arXiv:2401.06066; hf]: 28L, d=2048, 16H (kv=16),
fine-grained MoE with 64 routed experts (d_expert=1408) top-6 plus 2 shared
experts; layer 0 is a dense MLP (d_ff=10944) per the released config."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek_moe_16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    first_dense_ff=10944,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=48, vocab_size=256, first_dense_ff=96,
        moe=MoEConfig(n_experts=8, top_k=3, d_expert=48, n_shared=1),
        param_dtype="float32",
    )
