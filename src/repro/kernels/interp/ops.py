"""Jitted public wrappers: evaluate one TableDesign — or a whole compiled
InterpLibrary — on arbitrary-shape codes."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.table import TableDesign
from repro.kernels.interp.kernel import (BLOCK_ROWS, LANES, interp_eval_2d,
                                         library_eval_2d, library_walk_2d)
from repro.kernels.interp.ref import (interp_eval_ref, interp_eval_wide,
                                      library_eval_ref, library_walk_ref)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def assert_rom_replicated(*operands: jax.Array) -> None:
    """SPMD contract of every kernel in this module: the ROM-side operands
    (coeffs / meta / walk / dp) must be **replicated** on a mesh. The fused
    kernels gather table rows by local index — a partitioned ROM would turn
    each gather into a cross-device lookup XLA resolves with collectives (or
    worse, wrong rows under ``shard_map``). Sharded serving therefore places
    the library with ``NamedSharding(mesh, P())`` per leaf and calls this
    once at placement time; it is a no-op for tracers, committed single-
    device arrays, and non-array leaves.
    """
    from jax.sharding import NamedSharding

    for x in operands:
        if not isinstance(x, jax.Array) or isinstance(x, jax.core.Tracer):
            continue
        s = x.sharding
        if isinstance(s, NamedSharding) and any(
                p is not None for p in s.spec):
            raise ValueError(
                f"interp ROM operand {x.shape} is partitioned "
                f"({s.spec}); the fused kernels require a replicated ROM "
                f"— place the library with a fully-replicated sharding")


@partial(jax.jit, static_argnames=("eval_bits", "k", "sq_trunc", "lin_trunc",
                                   "degree", "interpret"))
def _eval_padded(codes, coeffs, *, eval_bits, k, sq_trunc, lin_trunc, degree,
                 interpret):
    n = codes.size
    tile = BLOCK_ROWS * LANES
    pad = (-n) % tile
    flat = jnp.pad(codes.reshape(-1), (0, pad)).reshape(-1, LANES)
    out = interp_eval_2d(flat, coeffs, eval_bits=eval_bits, k=k,
                         sq_trunc=sq_trunc, lin_trunc=lin_trunc,
                         degree=degree, interpret=interpret)
    return out.reshape(-1)[:n].reshape(codes.shape)


def table_eval(codes: jax.Array, design: TableDesign,
               use_kernel: bool = True, interpret: bool | None = None) -> jax.Array:
    """Evaluate ``design`` on int32 codes; Pallas kernel or jnp-ref path.

    Designs whose coefficients exceed int32 (wide-output reciprocals) take
    the emulated-int64 jnp path regardless of ``use_kernel`` — the int32
    ROM cannot hold them, and the historical fallback silently wrapped them
    through ``device_coeffs()`` (ROADMAP regression, DESIGN.md §7.5).
    """
    codes = codes.astype(jnp.int32)
    if not design.fits_int32:
        return interp_eval_wide(codes, design.device_coeffs_wide(),
                                eval_bits=design.eval_bits, k=design.k,
                                sq_trunc=design.sq_trunc,
                                lin_trunc=design.lin_trunc,
                                degree=design.degree)
    if not use_kernel:
        return interp_eval_ref(codes, design.device_coeffs(),
                               eval_bits=design.eval_bits,
                               k=design.k, sq_trunc=design.sq_trunc,
                               lin_trunc=design.lin_trunc, degree=design.degree)
    coeffs = design.device_coeffs(checked=True)
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _eval_padded(codes, coeffs, eval_bits=design.eval_bits, k=design.k,
                        sq_trunc=design.sq_trunc, lin_trunc=design.lin_trunc,
                        degree=design.degree, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def _library_eval_padded(codes, fids, coeffs, meta, *, interpret):
    n = codes.size
    tile = BLOCK_ROWS * LANES
    pad = (-n) % tile
    flat = jnp.pad(codes.reshape(-1), (0, pad)).reshape(-1, LANES)
    flat_f = jnp.pad(fids.reshape(-1), (0, pad)).reshape(-1, LANES)
    out = library_eval_2d(flat, flat_f, coeffs, meta, interpret=interpret)
    return out.reshape(-1)[:n].reshape(codes.shape)


def library_eval(codes: jax.Array, fids: jax.Array, coeffs: jax.Array,
                 meta: jax.Array, use_kernel: bool = True,
                 interpret: bool | None = None) -> jax.Array:
    """Fused multi-function evaluation: element i reads function
    ``fids[i]``'s table row. One kernel program serves the entire library —
    every call site lowers the same (shapes, F, R_max) executable, instead
    of one Pallas specialization per table.

    codes/fids: int32, any (matching) shape; coeffs: (F, R_max, 3) int32
    padded ROM; meta: (F, 5) int32 datapath rows.
    """
    codes = codes.astype(jnp.int32)
    fids = jnp.broadcast_to(jnp.asarray(fids, jnp.int32), codes.shape)
    if not use_kernel:
        return library_eval_ref(codes, fids, coeffs, meta)
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _library_eval_padded(codes, fids, coeffs, meta, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def _library_walk_padded(codes, fids, coeffs, walk, dp, *, interpret):
    n = codes.size
    tile = BLOCK_ROWS * LANES
    pad = (-n) % tile
    flat = jnp.pad(codes.reshape(-1), (0, pad)).reshape(-1, LANES)
    flat_f = jnp.pad(fids.reshape(-1), (0, pad)).reshape(-1, LANES)
    out = library_walk_2d(flat, flat_f, coeffs, walk, dp, interpret=interpret)
    return out.reshape(-1)[:n].reshape(codes.shape)


def library_walk(codes: jax.Array, fids: jax.Array, coeffs: jax.Array,
                 walk: jax.Array, dp: jax.Array, use_kernel: bool = True,
                 interpret: bool | None = None) -> jax.Array:
    """Generalized fused evaluation over a mixed uniform/segmented library:
    element i walks function ``fids[i]``'s slot whatever its layout. This
    is ``library_eval`` minus its all-uniform restriction — the per-slot
    address decode (region index vs segment-index table) rides per-function
    ``walk`` rows and per-leaf ``dp`` datapath rows instead of one (F, 5)
    meta operand.

    codes/fids: int32, any (matching) shape; coeffs: (F, R_max, 3) int32
    padded ROM; walk: (F, 5) int32; dp: (L, 5) int32.
    """
    codes = codes.astype(jnp.int32)
    fids = jnp.broadcast_to(jnp.asarray(fids, jnp.int32), codes.shape)
    if not use_kernel:
        return library_walk_ref(codes, fids, coeffs, walk, dp)
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _library_walk_padded(codes, fids, coeffs, walk, dp,
                                interpret=interpret)
