"""Jitted public wrapper: evaluate a TableDesign on arbitrary-shape codes."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.table import TableDesign
from repro.kernels.interp.kernel import BLOCK_ROWS, LANES, interp_eval_2d
from repro.kernels.interp.ref import interp_eval_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("eval_bits", "k", "sq_trunc", "lin_trunc",
                                   "degree", "interpret"))
def _eval_padded(codes, coeffs, *, eval_bits, k, sq_trunc, lin_trunc, degree,
                 interpret):
    n = codes.size
    tile = BLOCK_ROWS * LANES
    pad = (-n) % tile
    flat = jnp.pad(codes.reshape(-1), (0, pad)).reshape(-1, LANES)
    out = interp_eval_2d(flat, coeffs, eval_bits=eval_bits, k=k,
                         sq_trunc=sq_trunc, lin_trunc=lin_trunc,
                         degree=degree, interpret=interpret)
    return out.reshape(-1)[:n].reshape(codes.shape)


def table_eval(codes: jax.Array, design: TableDesign,
               use_kernel: bool = True, interpret: bool | None = None) -> jax.Array:
    """Evaluate ``design`` on int32 codes; Pallas kernel or jnp-ref path."""
    codes = codes.astype(jnp.int32)
    if not use_kernel:
        coeffs64 = jnp.asarray(np.stack([design.a, design.b, design.c], 1))
        return interp_eval_ref(codes, coeffs64, eval_bits=design.eval_bits,
                               k=design.k, sq_trunc=design.sq_trunc,
                               lin_trunc=design.lin_trunc, degree=design.degree)
    coeffs = jnp.asarray(design.packed_coeffs())
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _eval_padded(codes, coeffs, eval_bits=design.eval_bits, k=design.k,
                        sq_trunc=design.sq_trunc, lin_trunc=design.lin_trunc,
                        degree=design.degree, interpret=interpret)
