"""Pure-jnp oracle for the interp kernel (gather semantics, exact ints)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def library_eval_ref(codes: jax.Array, fids: jax.Array, coeffs: jax.Array,
                     meta: jax.Array) -> jax.Array:
    """Gather-semantics oracle for the fused multi-function kernel.

    coeffs: (F, R_max, 3) int32; meta: (F, 5) int32 rows of
    (eval_bits, k, sq_trunc, lin_trunc, degree). Bit-identical to running
    each element through ``interp_eval_ref`` with its own table.
    """
    m = meta[fids]  # (..., 5)
    eb, k, sq, lin, deg = (m[..., i] for i in range(5))
    one = jnp.int32(1)
    r = jax.lax.shift_right_logical(codes, eb)
    x = jnp.bitwise_and(codes, jax.lax.shift_left(one, eb) - 1)
    sel = coeffs[fids, r]  # (..., 3)
    xs = jax.lax.shift_left(jax.lax.shift_right_logical(x, sq), sq)
    xl = jax.lax.shift_left(jax.lax.shift_right_logical(x, lin), lin)
    xs = jnp.where(deg == 2, xs, 0)
    acc = sel[..., 0] * xs * xs + sel[..., 1] * xl + sel[..., 2]
    return jax.lax.shift_right_arithmetic(acc, k)


def library_walk_ref(codes: jax.Array, fids: jax.Array, coeffs: jax.Array,
                     walk: jax.Array, dp: jax.Array) -> jax.Array:
    """Gather-semantics oracle for the generalized multi-function ROM walk
    (uniform v1 + segmented v2 slots in one call).

    coeffs: (F, R_max, 3) int32; walk: (F, 5) int32 rows of (in_bits,
    depth, seg_flag, leaf_base, n_leaves); dp: (L, 5) int32 per-leaf
    (eval_bits, k, sq_trunc, lin_trunc, degree) rows — one per uniform
    function, one per segmented leaf. Bit-identical per slot to
    ``library_eval_ref`` (uniform) and ``interp_eval_seg_ref``
    (segmented).
    """
    codes = codes.astype(jnp.int32)
    f, r_max, _ = coeffs.shape
    rom = coeffs.reshape(f * r_max, 3)
    w = walk[fids]  # (..., 5)
    in_b, depth, segf, lbase, nlv = (w[..., i] for i in range(5))
    cell = jax.lax.shift_right_logical(codes, in_b - depth)
    # the packed segment-index table's entries are row-major in the
    # flattened ROM: entry index = (fid*r_max + n_leaves)*3 + cell.
    # Uniform elements read garbage here (clamped in bounds) and mask it.
    entries = rom.reshape(-1)
    eidx = (fids * r_max + nlv) * 3 + cell
    leaf_seg = entries[jnp.clip(eidx, 0, entries.shape[0] - 1)]
    leaf = jnp.where(segf == 1, leaf_seg, cell)
    sel = rom[fids * r_max + leaf]  # (..., 3)
    m = dp[lbase + jnp.where(segf == 1, leaf, 0)]  # (..., 5)
    eb, k, sq, lin, deg = (m[..., i] for i in range(5))
    one = jnp.int32(1)
    x = jnp.bitwise_and(codes, jax.lax.shift_left(one, eb) - 1)
    xs = jax.lax.shift_left(jax.lax.shift_right_logical(x, sq), sq)
    xl = jax.lax.shift_left(jax.lax.shift_right_logical(x, lin), lin)
    xs = jnp.where(deg == 2, xs, 0)
    acc = sel[..., 0] * xs * xs + sel[..., 1] * xl + sel[..., 2]
    return jax.lax.shift_right_arithmetic(acc, k)


def interp_eval_seg_ref(codes: jax.Array, rows: jax.Array, *,
                        seg: tuple) -> jax.Array:
    """Gather-semantics oracle for the non-uniform (ROM v2) slot datapath.

    ``rows`` is one function's slot: ``[0, S)`` per-leaf coefficient
    triples, then the segment-index table packed 3 int32 per row. ``seg``
    is the static ``FuncMeta.seg_spec()`` tuple ``(in_bits, depth,
    n_leaves, leaf_meta)``. Bit-identical to the in-kernel ``_lut_seg``
    one-hot path (tests/kernels) and to ``SegmentedDesign.eval_int``.
    """
    in_bits, depth, n_leaves, leaf_meta = seg
    n_cells = 1 << depth
    n_table_rows = (n_cells + 2) // 3
    seg_tab = rows[n_leaves:n_leaves + n_table_rows].reshape(-1)[:n_cells]
    codes = codes.astype(jnp.int32)
    cell = jax.lax.shift_right_logical(codes, in_bits - depth)
    leaf = seg_tab[cell]
    m = jnp.asarray(leaf_meta, jnp.int32)[leaf]  # (..., 5)
    eb, k, sq, lin, deg = (m[..., i] for i in range(5))
    one = jnp.int32(1)
    x = jnp.bitwise_and(codes, jax.lax.shift_left(one, eb) - 1)
    sel = rows[:n_leaves][leaf]  # (..., 3)
    xs = jax.lax.shift_left(jax.lax.shift_right_logical(x, sq), sq)
    xl = jax.lax.shift_left(jax.lax.shift_right_logical(x, lin), lin)
    xs = jnp.where(deg == 2, xs, 0)
    acc = sel[..., 0] * xs * xs + sel[..., 1] * xl + sel[..., 2]
    return jax.lax.shift_right_arithmetic(acc, k)


def interp_eval_ref(codes: jax.Array, coeffs: jax.Array, *, eval_bits: int,
                    k: int, sq_trunc: int, lin_trunc: int, degree: int) -> jax.Array:
    r = jax.lax.shift_right_logical(codes, eval_bits)
    x = jnp.bitwise_and(codes, (1 << eval_bits) - 1)
    sel = coeffs[r]
    xs = jax.lax.shift_left(jax.lax.shift_right_logical(x, sq_trunc), sq_trunc)
    xl = jax.lax.shift_left(jax.lax.shift_right_logical(x, lin_trunc), lin_trunc)
    acc = sel[..., 1] * xl + sel[..., 2]
    if degree == 2:
        acc = acc + sel[..., 0] * xs * xs
    return jax.lax.shift_right_arithmetic(acc, k)


# ---------------------------------------------------------------------------
# Emulated-int64 ("wide") exact evaluation — DESIGN.md §7.5's fallback for
# designs whose coefficients exceed int32 (e.g. wide-output reciprocals).
# jax runs with x64 disabled, so a literal jnp.int64 path would silently
# downcast; instead every 64-bit value is a (hi, lo) pair of 32-bit words
# and all arithmetic is exact modulo 2^64 — which equals the true signed
# result because ``TableDesign.eval_int`` (the numpy oracle) already
# guarantees the accumulator fits int64.
# ---------------------------------------------------------------------------


def _u32(x: jax.Array) -> jax.Array:
    """Reinterpret an int32 bit pattern as uint32 (no value conversion)."""
    return jax.lax.bitcast_convert_type(x.astype(jnp.int32), jnp.uint32)


def _i32(x: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(x, jnp.int32)


def _umul32(a: jax.Array, b: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Full 64-bit product of two uint32 arrays -> (hi, lo) uint32 words."""
    mask = jnp.uint32(0xFFFF)
    a0, a1 = a & mask, a >> 16
    b0, b1 = b & mask, b >> 16
    p00, p11 = a0 * b0, a1 * b1
    mid = a0 * b1 + a1 * b0  # may wrap: reconstruct the carry below
    carry_mid = (mid < a0 * b1).astype(jnp.uint32)
    lo = p00 + (mid << 16)
    carry_lo = (lo < p00).astype(jnp.uint32)
    hi = p11 + (mid >> 16) + (carry_mid << 16) + carry_lo
    return hi, lo


def _add64(ah, al, bh, bl) -> tuple[jax.Array, jax.Array]:
    lo = al + bl
    hi = ah + bh + (lo < al).astype(jnp.uint32)
    return hi, lo


def _mul64_64(ah, al, bh, bl) -> tuple[jax.Array, jax.Array]:
    """Low 64 bits of a 64x64-bit product (exact when the true signed
    product fits int64; two's-complement multiplication mod 2^64 equals the
    signed product mod 2^64, so no sign correction is needed)."""
    hi, lo = _umul32(al, bl)
    hi = hi + al * bh + ah * bl  # cross terms: only their low words survive
    return hi, lo


def _shra64(h: jax.Array, l: jax.Array, k: int) -> jax.Array:
    """Arithmetic >> k (static, 0 <= k <= 63) of (hi, lo); returns the low
    word of the result as int32 — the design contract keeps post-shift
    outputs within out_bits < 32."""
    if k == 0:
        return _i32(l)
    hs = _i32(h)
    if k < 32:
        return _i32((l >> k) | (h << (32 - k)))
    return jax.lax.shift_right_arithmetic(hs, min(k - 32, 31))


def interp_eval_wide(codes: jax.Array, coeffs_wide: jax.Array, *,
                     eval_bits: int, k: int, sq_trunc: int, lin_trunc: int,
                     degree: int) -> jax.Array:
    """Exact table evaluation with 64-bit coefficients, x64-off safe.

    ``coeffs_wide``: (2^R, 3, 2) int32 — ``[..., 0]`` the high and
    ``[..., 1]`` the low word of each int64 coefficient (two's complement,
    ``TableDesign.device_coeffs_wide``). Bit-identical to the numpy
    ``TableDesign.eval_int`` for any design whose accumulator fits int64,
    which the exhaustive ``verify`` sweep already presumes.
    """
    codes = codes.astype(jnp.int32)
    r = jax.lax.shift_right_logical(codes, eval_bits)
    x = jnp.bitwise_and(codes, (1 << eval_bits) - 1)
    xs = jax.lax.shift_left(jax.lax.shift_right_logical(x, sq_trunc), sq_trunc)
    xl = jax.lax.shift_left(jax.lax.shift_right_logical(x, lin_trunc), lin_trunc)
    sel = coeffs_wide[r]  # (..., 3, 2)
    zero = jnp.zeros_like(_u32(x))
    # b * lin(x): 64 x 32 (x >= 0, so its high word is zero)
    acc = _mul64_64(_u32(sel[..., 1, 0]), _u32(sel[..., 1, 1]), zero, _u32(xl))
    acc = _add64(*acc, _u32(sel[..., 2, 0]), _u32(sel[..., 2, 1]))
    if degree == 2:
        sq = _umul32(_u32(xs), _u32(xs))  # sq(x)^2 may itself exceed int32
        acc = _add64(*acc, *_mul64_64(_u32(sel[..., 0, 0]),
                                      _u32(sel[..., 0, 1]), *sq))
    return _shra64(*acc, k)
