"""Pure-jnp oracle for the interp kernel (gather semantics, exact ints)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def library_eval_ref(codes: jax.Array, fids: jax.Array, coeffs: jax.Array,
                     meta: jax.Array) -> jax.Array:
    """Gather-semantics oracle for the fused multi-function kernel.

    coeffs: (F, R_max, 3) int32; meta: (F, 5) int32 rows of
    (eval_bits, k, sq_trunc, lin_trunc, degree). Bit-identical to running
    each element through ``interp_eval_ref`` with its own table.
    """
    m = meta[fids]  # (..., 5)
    eb, k, sq, lin, deg = (m[..., i] for i in range(5))
    one = jnp.int32(1)
    r = jax.lax.shift_right_logical(codes, eb)
    x = jnp.bitwise_and(codes, jax.lax.shift_left(one, eb) - 1)
    sel = coeffs[fids, r]  # (..., 3)
    xs = jax.lax.shift_left(jax.lax.shift_right_logical(x, sq), sq)
    xl = jax.lax.shift_left(jax.lax.shift_right_logical(x, lin), lin)
    xs = jnp.where(deg == 2, xs, 0)
    acc = sel[..., 0] * xs * xs + sel[..., 1] * xl + sel[..., 2]
    return jax.lax.shift_right_arithmetic(acc, k)


def interp_eval_ref(codes: jax.Array, coeffs: jax.Array, *, eval_bits: int,
                    k: int, sq_trunc: int, lin_trunc: int, degree: int) -> jax.Array:
    r = jax.lax.shift_right_logical(codes, eval_bits)
    x = jnp.bitwise_and(codes, (1 << eval_bits) - 1)
    sel = coeffs[r]
    xs = jax.lax.shift_left(jax.lax.shift_right_logical(x, sq_trunc), sq_trunc)
    xl = jax.lax.shift_left(jax.lax.shift_right_logical(x, lin_trunc), lin_trunc)
    acc = sel[..., 1] * xl + sel[..., 2]
    if degree == 2:
        acc = acc + sel[..., 0] * xs * xs
    return jax.lax.shift_right_arithmetic(acc, k)
