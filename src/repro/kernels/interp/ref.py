"""Pure-jnp oracle for the interp kernel (gather semantics, exact ints)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def interp_eval_ref(codes: jax.Array, coeffs: jax.Array, *, eval_bits: int,
                    k: int, sq_trunc: int, lin_trunc: int, degree: int) -> jax.Array:
    r = jax.lax.shift_right_logical(codes, eval_bits)
    x = jnp.bitwise_and(codes, (1 << eval_bits) - 1)
    sel = coeffs[r]
    xs = jax.lax.shift_left(jax.lax.shift_right_logical(x, sq_trunc), sq_trunc)
    xl = jax.lax.shift_left(jax.lax.shift_right_logical(x, lin_trunc), lin_trunc)
    acc = sel[..., 1] * xl + sel[..., 2]
    if degree == 2:
        acc = acc + sel[..., 0] * xs * xs
    return jax.lax.shift_right_arithmetic(acc, k)
