"""Pallas TPU kernel: batched piecewise-polynomial table evaluation.

This is the TPU rendering of the paper's Figure-1 datapath:

  * the coefficient ROM lives in VMEM (2^R x 3 int32 — at most a few KiB);
  * the LUT read is a one-hot contraction (a ROM mux tree maps naturally onto
    the MXU: ``onehot(r) @ coeffs``), not a serial gather;
  * the squarer operates on the truncated ``x[W-1:i]`` exactly like the RTL;
  * evaluation is int32 throughout, final arithmetic shift by k.

Tiling: input codes are reshaped to (rows, 128) lanes; the grid walks row
blocks of 8, so each program touches an (8, 128) VREG-aligned tile while the
full table stays resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 8
LANES = 128


def _interp_kernel(codes_ref, coeffs_ref, out_ref, *, eval_bits: int, k: int,
                   sq_trunc: int, lin_trunc: int, n_regions: int, degree: int):
    codes = codes_ref[...]  # (BLOCK_ROWS, LANES) int32
    coeffs = coeffs_ref[...]  # (n_regions, 3) int32
    r = jax.lax.shift_right_logical(codes, eval_bits)
    x = jnp.bitwise_and(codes, (1 << eval_bits) - 1)
    # one-hot LUT read: (8*128, n_regions) @ (n_regions, 3) on the MXU
    flat_r = r.reshape(-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, (flat_r.shape[0], n_regions), 1)
    onehot = (flat_r[:, None] == iota).astype(jnp.int32)
    sel = jax.lax.dot_general(
        onehot, coeffs, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).reshape(codes.shape + (3,))
    xs = jax.lax.shift_left(jax.lax.shift_right_logical(x, sq_trunc), sq_trunc)
    xl = jax.lax.shift_left(jax.lax.shift_right_logical(x, lin_trunc), lin_trunc)
    acc = sel[..., 1] * xl + sel[..., 2]
    if degree == 2:
        acc = acc + sel[..., 0] * xs * xs
    out_ref[...] = jax.lax.shift_right_arithmetic(acc, k)


def interp_eval_2d(codes: jax.Array, coeffs: jax.Array, *, eval_bits: int,
                   k: int, sq_trunc: int, lin_trunc: int, degree: int,
                   interpret: bool = True) -> jax.Array:
    """codes: (rows, 128) int32, rows % 8 == 0; coeffs: (2^R, 3) int32."""
    rows, lanes = codes.shape
    assert lanes == LANES and rows % BLOCK_ROWS == 0, codes.shape
    n_regions = coeffs.shape[0]
    kernel = functools.partial(
        _interp_kernel, eval_bits=eval_bits, k=k, sq_trunc=sq_trunc,
        lin_trunc=lin_trunc, n_regions=n_regions, degree=degree)
    return pl.pallas_call(
        kernel,
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((n_regions, 3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        interpret=interpret,
    )(codes, coeffs)
