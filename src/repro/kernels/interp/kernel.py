"""Pallas TPU kernel: batched piecewise-polynomial table evaluation.

This is the TPU rendering of the paper's Figure-1 datapath:

  * the coefficient ROM lives in VMEM (2^R x 3 int32 — at most a few KiB);
  * the LUT read is a one-hot contraction (a ROM mux tree maps naturally onto
    the MXU: ``onehot(r) @ coeffs``), not a serial gather;
  * the squarer operates on the truncated ``x[W-1:i]`` exactly like the RTL;
  * evaluation is int32 throughout, final arithmetic shift by k.

Tiling: input codes are reshaped to (rows, 128) lanes; the grid walks row
blocks of 8, so each program touches an (8, 128) VREG-aligned tile while the
full table stays resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 8
LANES = 128


def _interp_kernel(codes_ref, coeffs_ref, out_ref, *, eval_bits: int, k: int,
                   sq_trunc: int, lin_trunc: int, n_regions: int, degree: int):
    codes = codes_ref[...]  # (BLOCK_ROWS, LANES) int32
    coeffs = coeffs_ref[...]  # (n_regions, 3) int32
    r = jax.lax.shift_right_logical(codes, eval_bits)
    x = jnp.bitwise_and(codes, (1 << eval_bits) - 1)
    # one-hot LUT read: (8*128, n_regions) @ (n_regions, 3) on the MXU
    flat_r = r.reshape(-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, (flat_r.shape[0], n_regions), 1)
    onehot = (flat_r[:, None] == iota).astype(jnp.int32)
    sel = jax.lax.dot_general(
        onehot, coeffs, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).reshape(codes.shape + (3,))
    out_ref[...] = poly_tail(sel, x, k=k, sq_trunc=sq_trunc,
                             lin_trunc=lin_trunc, degree=degree)


def poly_tail(sel: jax.Array, x: jax.Array, *, k: int, sq_trunc: int,
              lin_trunc: int, degree: int) -> jax.Array:
    """The Figure-1 fixed-point tail shared by every in-kernel table read:
    truncated square/linear terms, int32 Horner accumulate, arithmetic
    shift by k. One copy — the per-table (`_lut`) and library-ROM
    (`_lut_rom`) gathers feed the same datapath and cannot drift."""
    xs = jax.lax.shift_left(jax.lax.shift_right_logical(x, sq_trunc), sq_trunc)
    xl = jax.lax.shift_left(jax.lax.shift_right_logical(x, lin_trunc), lin_trunc)
    acc = sel[..., 1] * xl + sel[..., 2]
    if degree == 2:
        acc = acc + sel[..., 0] * xs * xs
    return jax.lax.shift_right_arithmetic(acc, k)


def _lut(codes: jax.Array, coeffs: jax.Array, *, eval_bits: int, k: int,
         sq_trunc: int, lin_trunc: int, degree: int) -> jax.Array:
    """One-hot table evaluation on int32 codes (any 2-D shape): region
    index from the code's top bits, a one-hot MXU contraction over the
    coefficient rows, then the shared fixed-point tail."""
    n_regions = coeffs.shape[0]
    r = jax.lax.shift_right_logical(codes, eval_bits)
    x = jnp.bitwise_and(codes, (1 << eval_bits) - 1)
    flat_r = r.reshape(-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, (flat_r.shape[0], n_regions), 1)
    onehot = (flat_r[:, None] == iota).astype(jnp.int32)
    sel = jax.lax.dot_general(onehot, coeffs, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32
                              ).reshape(codes.shape + (3,))
    return poly_tail(sel, x, k=k, sq_trunc=sq_trunc, lin_trunc=lin_trunc,
                     degree=degree)


def _lut_seg(codes: jax.Array, rows: jax.Array, *, seg: tuple) -> jax.Array:
    """Non-uniform (ROM v2) slot evaluation: segment-index gather, then the
    per-leaf fixed-point tail.

    ``rows`` is one function's slot of a v2 library ROM: rows ``[0, S)``
    hold the S per-leaf coefficient triples and rows ``[S, S + ceil(2^D/3))``
    the segment-index table packed 3 int32 entries per row. ``seg`` is the
    static ``FuncMeta.seg_spec()`` tuple ``(in_bits, depth, n_leaves,
    leaf_meta)`` with one ``(eval_bits, k, sq_trunc, lin_trunc, degree)``
    row per leaf — this is the address decoder the paper's uniform layout
    avoids: the top D input bits index a 2^D table that names the leaf, and
    the leaf supplies both the coefficient row and the datapath constants.
    Both gathers are one-hot MXU contractions like the uniform kernels; the
    shifts take per-element amounts (vector shifts), exactly as in
    ``_library_kernel``. Degenerate segmentations (every leaf at depth R)
    reproduce the uniform ``_lut`` bitwise: the cell index equals the
    region index, every leaf row carries the uniform datapath constants,
    and the int32 accumulate is order-insensitive (wrapping adds commute).
    """
    in_bits, depth, n_leaves, leaf_meta = seg
    n_cells = 1 << depth
    n_table_rows = (n_cells + 2) // 3
    # unpack the segment-index table: (T, 3) rows -> flat 2^D leaf ids
    table = jax.lax.slice_in_dim(rows, n_leaves, n_leaves + n_table_rows)
    seg_tab = jax.lax.slice_in_dim(table.reshape(-1), 0, n_cells)
    flat_cell = jax.lax.shift_right_logical(
        codes, in_bits - depth).reshape(-1)
    n = flat_cell.shape[0]
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (n, n_cells), 1)
    onehot_c = (flat_cell[:, None] == iota_c).astype(jnp.int32)
    leaf = jax.lax.dot_general(
        onehot_c, seg_tab[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)[:, 0]
    # per-leaf datapath constants: unrolled scalar-literal selection off the
    # leaf one-hot. A materialized (S, 5) meta matrix would be a captured
    # constant — which Pallas rejects — while scalar literals fold into the
    # jaxpr; S is static and small, so the unroll is a handful of vector
    # multiply-adds.
    iota_l = jax.lax.broadcasted_iota(jnp.int32, (n, n_leaves), 1)
    onehot_l = (leaf[:, None] == iota_l).astype(jnp.int32)

    def pick(j: int) -> jax.Array:
        acc = onehot_l[:, 0] * leaf_meta[0][j]
        for i in range(1, n_leaves):
            acc = acc + onehot_l[:, i] * leaf_meta[i][j]
        return acc.reshape(codes.shape)

    eb, k, sq, lin, deg = (pick(j) for j in range(5))
    one = jnp.int32(1)
    x = jnp.bitwise_and(codes, jax.lax.shift_left(one, eb) - 1)
    sel = jax.lax.dot_general(
        onehot_l, jax.lax.slice_in_dim(rows, 0, n_leaves),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32).reshape(codes.shape + (3,))
    xs = jax.lax.shift_left(jax.lax.shift_right_logical(x, sq), sq)
    xl = jax.lax.shift_left(jax.lax.shift_right_logical(x, lin), lin)
    xs = jnp.where(deg == 2, xs, 0)
    acc = sel[..., 0] * xs * xs + sel[..., 1] * xl + sel[..., 2]
    return jax.lax.shift_right_arithmetic(acc, k)


def _lut_rom(codes: jax.Array, rom: jax.Array, *, fid: int, r_max: int,
             eval_bits: int, k: int, sq_trunc: int, lin_trunc: int,
             degree: int, seg: tuple | None = None) -> jax.Array:
    """Table evaluation against a library ROM (static function id).

    ``rom`` is an :class:`repro.api.InterpLibrary` coefficient ROM flattened
    to ``(F * r_max, 3)`` int32; rows ``[fid * r_max, fid * r_max + 2^R)``
    hold the function's ``packed_coeffs`` and the padding rows are zero.
    ``fid``/``r_max`` are static, so the function's rows are a *static
    slice* of the ROM operand and the read is exactly ``_lut`` on them —
    bit-identical to the per-table kernels, and the one-hot contraction
    pays r_max columns, not F·r_max. The consuming fused kernels (softmax /
    rmsnorm / flashattn) thread the whole library ROM as ONE operand and
    evaluate each transcendental in-registers instead of launching a
    standalone table kernel between ops.

    ``seg`` (a static ``FuncMeta.seg_spec()`` tuple) switches the slot to
    the non-uniform ROM-v2 datapath: the per-call eval_bits/k/truncation
    scalars are ignored (each leaf carries its own) and the rows decode
    through :func:`_lut_seg` instead of :func:`_lut`.
    """
    rows = jax.lax.slice_in_dim(rom, fid * r_max, (fid + 1) * r_max)
    if seg is not None:
        return _lut_seg(codes, rows, seg=seg)
    return _lut(codes, rows, eval_bits=eval_bits, k=k, sq_trunc=sq_trunc,
                lin_trunc=lin_trunc, degree=degree)


def _rom_kernel(codes_ref, rom_ref, out_ref, *, fid: int, r_max: int,
                eval_bits: int, k: int, sq_trunc: int, lin_trunc: int,
                degree: int, seg: tuple | None = None):
    out_ref[...] = _lut_rom(codes_ref[...], rom_ref[...], fid=fid,
                            r_max=r_max, eval_bits=eval_bits, k=k,
                            sq_trunc=sq_trunc, lin_trunc=lin_trunc,
                            degree=degree, seg=seg)


def rom_eval_2d(codes: jax.Array, rom: jax.Array, *, fid: int, r_max: int,
                eval_bits: int, k: int, sq_trunc: int, lin_trunc: int,
                degree: int, seg: tuple | None = None,
                interpret: bool = True) -> jax.Array:
    """Golden-test harness for ``_lut_rom``: evaluate one function of a
    flattened ``(F * r_max, 3)`` ROM on (rows, 128) codes through the same
    in-kernel datapath the fused consumers use."""
    rows, lanes = codes.shape
    assert lanes == LANES and rows % BLOCK_ROWS == 0, codes.shape
    n_rows = rom.shape[0]
    kernel = functools.partial(_rom_kernel, fid=fid, r_max=r_max,
                               eval_bits=eval_bits, k=k, sq_trunc=sq_trunc,
                               lin_trunc=lin_trunc, degree=degree, seg=seg)
    return pl.pallas_call(
        kernel,
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((n_rows, 3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        interpret=interpret,
    )(codes, rom)


def _library_kernel(codes_ref, fids_ref, coeffs_ref, meta_ref, out_ref, *,
                    n_funcs: int, r_max: int):
    """Fused multi-function table evaluation: gather by (func_id, region).

    ``coeffs_ref`` is the library's padded ROM flattened to
    ``(n_funcs * r_max, 3)``; ``meta_ref`` is the per-function static
    datapath ``(n_funcs, 5)`` int32: eval_bits, k, sq_trunc, lin_trunc,
    degree. Both LUT reads are one-hot MXU contractions like the
    single-table kernel; the shifts take per-element amounts, which Mosaic
    lowers as vector shifts.
    """
    codes = codes_ref[...]  # (BLOCK_ROWS, LANES) int32
    fids = fids_ref[...]
    n = codes.size
    # per-element datapath params: onehot(fid) @ meta
    flat_f = fids.reshape(-1)
    iota_f = jax.lax.broadcasted_iota(jnp.int32, (n, n_funcs), 1)
    onehot_f = (flat_f[:, None] == iota_f).astype(jnp.int32)
    m = jax.lax.dot_general(
        onehot_f, meta_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    eb, k, sq, lin, deg = (m[:, i].reshape(codes.shape) for i in range(5))
    one = jnp.int32(1)
    r = jax.lax.shift_right_logical(codes, eb)
    x = jnp.bitwise_and(codes, jax.lax.shift_left(one, eb) - 1)
    # fused ROM read: row index = func_id * r_max + region
    row = (fids * r_max + r).reshape(-1)
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (n, n_funcs * r_max), 1)
    onehot_r = (row[:, None] == iota_r).astype(jnp.int32)
    sel = jax.lax.dot_general(
        onehot_r, coeffs_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).reshape(codes.shape + (3,))
    xs = jax.lax.shift_left(jax.lax.shift_right_logical(x, sq), sq)
    xl = jax.lax.shift_left(jax.lax.shift_right_logical(x, lin), lin)
    xs = jnp.where(deg == 2, xs, 0)  # degree-1 rows skip the squarer
    acc = sel[..., 0] * xs * xs + sel[..., 1] * xl + sel[..., 2]
    out_ref[...] = jax.lax.shift_right_arithmetic(acc, k)


def library_eval_2d(codes: jax.Array, fids: jax.Array, coeffs: jax.Array,
                    meta: jax.Array, *, interpret: bool = True) -> jax.Array:
    """codes/fids: (rows, 128) int32, rows % 8 == 0; coeffs: (F, R_max, 3);
    meta: (F, 5) int32 rows of (eval_bits, k, sq_trunc, lin_trunc, degree)."""
    rows, lanes = codes.shape
    assert lanes == LANES and rows % BLOCK_ROWS == 0, codes.shape
    assert fids.shape == codes.shape, (fids.shape, codes.shape)
    n_funcs, r_max, _ = coeffs.shape
    flat = coeffs.reshape(n_funcs * r_max, 3)
    kernel = functools.partial(_library_kernel, n_funcs=n_funcs, r_max=r_max)
    return pl.pallas_call(
        kernel,
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((n_funcs * r_max, 3), lambda i: (0, 0)),
            pl.BlockSpec((n_funcs, 5), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        interpret=interpret,
    )(codes, fids, flat, meta)


def _library_walk_kernel(codes_ref, fids_ref, rom_ref, walk_ref, dp_ref,
                         out_ref, *, n_funcs: int, r_max: int, n_dp: int):
    """Generalized multi-function ROM walk: uniform (v1) and segmented
    (v2) slots in one program.

    Per function, ``walk_ref`` carries ``(in_bits, depth, seg_flag,
    leaf_base, n_leaves)``: depth is R for a uniform slot and the
    segment-index depth D for a segmented one, so ``cell = code >>
    (in_bits - depth)`` is the region index (uniform) or the prefix-tree
    cell (segmented). A segmented element resolves the cell to a leaf id
    through the packed segment-index table — whose entries are row-major
    in the flattened ROM, so entry index ``(fid*r_max + n_leaves)*3 +
    cell`` needs no integer division by the 3-per-row packing — while a
    uniform element's leaf IS its cell. The coefficient row is then
    ``fid*r_max + leaf`` for both layouts, and the per-element datapath
    constants gather from ``dp_ref`` at ``leaf_base (+ leaf)``: one row
    per uniform function, one per segmented leaf. Every gather is a
    one-hot MXU contraction and the fixed-point tail is the same
    vector-shift datapath as ``_library_kernel``/``_lut_seg``, so each
    slot evaluates bit-identically to its specialized path.

    Unlike ``_lut_seg`` (whose leaf meta must fold into the jaxpr as
    scalar literals), the walk and datapath tables here are real kernel
    operands — the per-function layout varies, so it must be data.
    """
    codes = codes_ref[...]  # (BLOCK_ROWS, LANES) int32
    fids = fids_ref[...]
    rom = rom_ref[...]  # (n_funcs * r_max, 3) int32
    n = codes.size
    shape = codes.shape
    one = jnp.int32(1)
    flat_f = fids.reshape(-1)
    iota_f = jax.lax.broadcasted_iota(jnp.int32, (n, n_funcs), 1)
    onehot_f = (flat_f[:, None] == iota_f).astype(jnp.int32)
    w = jax.lax.dot_general(
        onehot_f, walk_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    in_b, depth, segf, lbase, nlv = (w[:, i].reshape(shape) for i in range(5))
    cell = jax.lax.shift_right_logical(codes, in_b - depth)
    # segment-index read (garbage for uniform elements, masked below)
    eidx = ((fids * r_max + nlv) * 3 + cell).reshape(-1)
    iota_e = jax.lax.broadcasted_iota(jnp.int32, (n, n_funcs * r_max * 3), 1)
    onehot_e = (eidx[:, None] == iota_e).astype(jnp.int32)
    leaf_seg = jax.lax.dot_general(
        onehot_e, rom.reshape(-1, 1), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)[:, 0].reshape(shape)
    leaf = jnp.where(segf == 1, leaf_seg, cell)
    # coefficient read: row = fid * r_max + leaf for both layouts
    row = (fids * r_max + leaf).reshape(-1)
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (n, n_funcs * r_max), 1)
    onehot_r = (row[:, None] == iota_r).astype(jnp.int32)
    sel = jax.lax.dot_general(
        onehot_r, rom, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32).reshape(shape + (3,))
    # per-element datapath constants
    drow = (lbase + jnp.where(segf == 1, leaf, 0)).reshape(-1)
    iota_d = jax.lax.broadcasted_iota(jnp.int32, (n, n_dp), 1)
    onehot_d = (drow[:, None] == iota_d).astype(jnp.int32)
    dp = jax.lax.dot_general(
        onehot_d, dp_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    eb, k, sq, lin, deg = (dp[:, i].reshape(shape) for i in range(5))
    x = jnp.bitwise_and(codes, jax.lax.shift_left(one, eb) - 1)
    xs = jax.lax.shift_left(jax.lax.shift_right_logical(x, sq), sq)
    xl = jax.lax.shift_left(jax.lax.shift_right_logical(x, lin), lin)
    xs = jnp.where(deg == 2, xs, 0)
    acc = sel[..., 0] * xs * xs + sel[..., 1] * xl + sel[..., 2]
    out_ref[...] = jax.lax.shift_right_arithmetic(acc, k)


def library_walk_2d(codes: jax.Array, fids: jax.Array, coeffs: jax.Array,
                    walk: jax.Array, dp: jax.Array, *,
                    interpret: bool = True) -> jax.Array:
    """codes/fids: (rows, 128) int32, rows % 8 == 0; coeffs: (F, R_max, 3);
    walk: (F, 5) int32 rows of (in_bits, depth, seg_flag, leaf_base,
    n_leaves); dp: (L, 5) int32 per-leaf datapath rows."""
    rows, lanes = codes.shape
    assert lanes == LANES and rows % BLOCK_ROWS == 0, codes.shape
    assert fids.shape == codes.shape, (fids.shape, codes.shape)
    n_funcs, r_max, _ = coeffs.shape
    n_dp = dp.shape[0]
    flat = coeffs.reshape(n_funcs * r_max, 3)
    kernel = functools.partial(_library_walk_kernel, n_funcs=n_funcs,
                               r_max=r_max, n_dp=n_dp)
    return pl.pallas_call(
        kernel,
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((n_funcs * r_max, 3), lambda i: (0, 0)),
            pl.BlockSpec((n_funcs, 5), lambda i: (0, 0)),
            pl.BlockSpec((n_dp, 5), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        interpret=interpret,
    )(codes, fids, flat, walk, dp)


def interp_eval_2d(codes: jax.Array, coeffs: jax.Array, *, eval_bits: int,
                   k: int, sq_trunc: int, lin_trunc: int, degree: int,
                   interpret: bool = True) -> jax.Array:
    """codes: (rows, 128) int32, rows % 8 == 0; coeffs: (2^R, 3) int32."""
    rows, lanes = codes.shape
    assert lanes == LANES and rows % BLOCK_ROWS == 0, codes.shape
    n_regions = coeffs.shape[0]
    kernel = functools.partial(
        _interp_kernel, eval_bits=eval_bits, k=k, sq_trunc=sq_trunc,
        lin_trunc=lin_trunc, n_regions=n_regions, degree=degree)
    return pl.pallas_call(
        kernel,
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((n_regions, 3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
        interpret=interpret,
    )(codes, coeffs)
