"""Jitted wrappers for the fused approx-softmax kernels (per-table design
operands, or one library ROM operand for the whole datapath)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.table import TableDesign
from repro.kernels.softmax.kernel import (BLOCK_ROWS, fused_softmax,
                                          fused_softmax_lib)
from repro.kernels.softmax.ref import fused_softmax_lib_ref, fused_softmax_ref
from repro.api import get_table


def _meta(design: TableDesign) -> dict:
    return {
        "in_bits": design.in_bits,
        "out_bits": design.out_bits,
        "eval": {
            "eval_bits": design.eval_bits,
            "k": design.k,
            "sq_trunc": design.sq_trunc,
            "lin_trunc": design.lin_trunc,
            "degree": design.degree,
        },
    }


def lib_meta(library, kind: str) -> dict:
    """The kernel meta dict of one library slot: the per-table ``_meta``
    fields plus the function's static ROM row offset (``fid``).

    A non-uniform (ROM v2) slot additionally carries its static
    ``seg_spec()`` tuple under ``eval["seg"]`` — the in-kernel ``_lut_rom``
    read and the jnp oracles route through the segment-index datapath when
    the key is present, so every fused consumer (softmax / rmsnorm /
    flashattn) decodes segmented slots with zero extra dispatches. Uniform
    slots omit the key entirely, keeping their meta dicts unchanged.
    """
    m = library.meta(kind)
    ev = {
        "eval_bits": m.eval_bits,
        "k": m.k,
        "sq_trunc": m.sq_trunc,
        "lin_trunc": m.lin_trunc,
        "degree": m.degree,
    }
    if m.segmented:
        ev["seg"] = m.seg_spec()
    return {
        "in_bits": m.in_bits,
        "out_bits": m.out_bits,
        "fid": library.func_id(kind),
        "eval": ev,
    }


def approx_softmax_library(x: jax.Array, library, use_kernel: bool | None = None,
                           interpret: bool | None = None) -> jax.Array:
    """Library-bound fused softmax over the last axis.

    One ROM operand (the compiled :class:`repro.api.InterpLibrary` pytree
    leaf) feeds both in-kernel table reads — exp at its static func id,
    recip at its own — so a softmax is ONE kernel launch instead of a
    gather→eval→elementwise chain per transcendental. ``use_kernel=None``
    picks the Pallas kernel on TPU (128-lane aligned features) and the
    bit-identical jnp ROM-gather oracle elsewhere."""
    em, rm = lib_meta(library, "exp2neg"), lib_meta(library, "recip")
    shape = x.shape
    d = shape[-1]
    rows = x.size // d
    xf = x.reshape(rows, d)
    r_max = library.coeffs.shape[1]
    rom = library.coeffs.reshape(-1, 3)
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu" and d % 128 == 0
    if not use_kernel:
        return fused_softmax_lib_ref(xf, library.coeffs, em, rm).reshape(shape)
    pad = (-rows) % BLOCK_ROWS
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    interpret = (jax.default_backend() != "tpu") if interpret is None else interpret
    out = fused_softmax_lib(xf, rom, em, rm, r_max=r_max, interpret=interpret)
    return out[:rows].reshape(shape)


def approx_softmax_fused(x: jax.Array,
                         exp_design: TableDesign | None = None,
                         recip_design: TableDesign | None = None,
                         use_kernel: bool = True,
                         interpret: bool | None = None) -> jax.Array:
    """Fused softmax over the last axis; leading axes are flattened to rows.

    Rows are padded to the 8-row block; the feature dim must be a multiple
    of 128 (the serving attention shapes used by the examples all are).
    """
    exp_design = exp_design or get_table("exp2neg")
    recip_design = recip_design or get_table("recip")
    ec = exp_design.device_coeffs(checked=True)
    rc = recip_design.device_coeffs(checked=True)
    em, rm = _meta(exp_design), _meta(recip_design)
    shape = x.shape
    d = shape[-1]
    rows = x.size // d
    xf = x.reshape(rows, d)
    if not use_kernel:
        return fused_softmax_ref(xf, ec, rc, em, rm).reshape(shape)
    pad = (-rows) % BLOCK_ROWS
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    interpret = (jax.default_backend() != "tpu") if interpret is None else interpret
    out = fused_softmax(xf, ec, rc, em, rm, interpret=interpret)
    return out[:rows].reshape(shape)
