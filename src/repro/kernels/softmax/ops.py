"""Jitted wrapper for the fused approx-softmax kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.table import TableDesign
from repro.kernels.softmax.kernel import BLOCK_ROWS, fused_softmax
from repro.kernels.softmax.ref import fused_softmax_ref
from repro.api import get_table


def _meta(design: TableDesign) -> dict:
    return {
        "in_bits": design.in_bits,
        "out_bits": design.out_bits,
        "eval": {
            "eval_bits": design.eval_bits,
            "k": design.k,
            "sq_trunc": design.sq_trunc,
            "lin_trunc": design.lin_trunc,
            "degree": design.degree,
        },
    }


def approx_softmax_fused(x: jax.Array,
                         exp_design: TableDesign | None = None,
                         recip_design: TableDesign | None = None,
                         use_kernel: bool = True,
                         interpret: bool | None = None) -> jax.Array:
    """Fused softmax over the last axis; leading axes are flattened to rows.

    Rows are padded to the 8-row block; the feature dim must be a multiple
    of 128 (the serving attention shapes used by the examples all are).
    """
    exp_design = exp_design or get_table("exp2neg")
    recip_design = recip_design or get_table("recip")
    ec = exp_design.device_coeffs(checked=True)
    rc = recip_design.device_coeffs(checked=True)
    em, rm = _meta(exp_design), _meta(recip_design)
    shape = x.shape
    d = shape[-1]
    rows = x.size // d
    xf = x.reshape(rows, d)
    if not use_kernel:
        return fused_softmax_ref(xf, ec, rc, em, rm).reshape(shape)
    pad = (-rows) % BLOCK_ROWS
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    interpret = (jax.default_backend() != "tpu") if interpret is None else interpret
    out = fused_softmax(xf, ec, rc, em, rm, interpret=interpret)
    return out[:rows].reshape(shape)
