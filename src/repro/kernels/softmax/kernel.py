"""Pallas TPU kernel: fused softmax with table-backed exp + reciprocal.

The paper's generated "hardware" evaluated inside one fused pass:

  1. row max (VPU reduction), t = (max - x) * log2(e) >= 0
  2. exponential: 2^-t = 2^-n * table_exp(frac(t))   — LUT + poly datapath
  3. row sum, then 1/sum via IEEE-754 exponent/mantissa split feeding the
     reciprocal table over [1, 2)                     — second LUT datapath
  4. scale.

The mantissa split uses integer bit twiddles (bitcast) exactly like the RTL
front-end the paper's reciprocal assumes (input already normalized to 1.x).
Table reads are one-hot MXU contractions; see kernels/interp for rationale.
Tiling: (BLOCK_ROWS, D) blocks, the whole feature dim resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 8
LOG2E = 1.4426950408889634


def _lut(codes: jax.Array, coeffs: jax.Array, *, eval_bits: int, k: int,
         sq_trunc: int, lin_trunc: int, degree: int) -> jax.Array:
    """One-hot table evaluation on int32 codes (any 2-D shape)."""
    n_regions = coeffs.shape[0]
    r = jax.lax.shift_right_logical(codes, eval_bits)
    x = jnp.bitwise_and(codes, (1 << eval_bits) - 1)
    flat_r = r.reshape(-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, (flat_r.shape[0], n_regions), 1)
    onehot = (flat_r[:, None] == iota).astype(jnp.int32)
    sel = jax.lax.dot_general(onehot, coeffs, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32
                              ).reshape(codes.shape + (3,))
    xs = jax.lax.shift_left(jax.lax.shift_right_logical(x, sq_trunc), sq_trunc)
    xl = jax.lax.shift_left(jax.lax.shift_right_logical(x, lin_trunc), lin_trunc)
    acc = sel[..., 1] * xl + sel[..., 2]
    if degree == 2:
        acc = acc + sel[..., 0] * xs * xs
    return jax.lax.shift_right_arithmetic(acc, k)


def _softmax_kernel(x_ref, ecoef_ref, rcoef_ref, out_ref, *, exp_meta: dict,
                    recip_meta: dict):
    x = x_ref[...].astype(jnp.float32)  # (BLOCK_ROWS, D)
    m = jnp.max(x, axis=-1, keepdims=True)
    t = jnp.minimum((m - x) * LOG2E, 126.0)
    n = jnp.floor(t)
    frac = t - n
    eb = exp_meta["in_bits"]
    codes = jnp.clip(jnp.round(frac * (1 << eb)).astype(jnp.int32), 0, (1 << eb) - 1)
    tab = _lut(codes, ecoef_ref[...], **exp_meta["eval"]).astype(jnp.float32)
    e = tab * (2.0 ** -exp_meta["out_bits"]) * jnp.exp2(-n)
    s = jnp.sum(e, axis=-1, keepdims=True)  # > 0
    # IEEE-754 split: s = 1.mant * 2^(E-127); reciprocal table wants 1.x codes
    bits = jax.lax.bitcast_convert_type(s, jnp.int32)
    expo = jnp.bitwise_and(jax.lax.shift_right_logical(bits, 23), 255) - 127
    mant = jnp.bitwise_and(bits, (1 << 23) - 1)
    rb = recip_meta["in_bits"]
    half = 1 << (23 - rb - 1)
    rcodes = jnp.clip(jax.lax.shift_right_logical(mant + half, 23 - rb),
                      0, (1 << rb) - 1)
    rtab = _lut(rcodes, rcoef_ref[...], **recip_meta["eval"]).astype(jnp.float32)
    recip = rtab * (2.0 ** -(rb + 1)) * jnp.exp2(-expo.astype(jnp.float32))
    out_ref[...] = (e * recip).astype(out_ref.dtype)


def fused_softmax(x: jax.Array, exp_coeffs: jax.Array, recip_coeffs: jax.Array,
                  exp_meta: dict, recip_meta: dict,
                  interpret: bool = True) -> jax.Array:
    """x: (rows, D) with rows % BLOCK_ROWS == 0, D % 128 == 0."""
    rows, d = x.shape
    assert rows % BLOCK_ROWS == 0 and d % 128 == 0, x.shape
    kernel = functools.partial(_softmax_kernel, exp_meta=exp_meta,
                               recip_meta=recip_meta)
    ne, nr = exp_coeffs.shape[0], recip_coeffs.shape[0]
    return pl.pallas_call(
        kernel,
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, d), lambda i: (i, 0)),
            pl.BlockSpec((ne, 3), lambda i: (0, 0)),
            pl.BlockSpec((nr, 3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, exp_coeffs, recip_coeffs)
