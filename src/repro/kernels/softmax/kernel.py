"""Pallas TPU kernel: fused softmax with table-backed exp + reciprocal.

The paper's generated "hardware" evaluated inside one fused pass:

  1. row max (VPU reduction), t = (max - x) * log2(e) >= 0
  2. exponential: 2^-t = 2^-n * table_exp(frac(t))   — LUT + poly datapath
  3. row sum, then 1/sum via IEEE-754 exponent/mantissa split feeding the
     reciprocal table over [1, 2)                     — second LUT datapath
  4. scale.

The mantissa split uses integer bit twiddles (bitcast) exactly like the RTL
front-end the paper's reciprocal assumes (input already normalized to 1.x).
Table reads are one-hot MXU contractions; see kernels/interp for rationale.
Tiling: (BLOCK_ROWS, D) blocks, the whole feature dim resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# the one-hot table read lives next to the ROM variant; re-exported here
# for the historical import path (rmsnorm/flashattn kernels, tests)
from repro.kernels.interp.kernel import _lut  # noqa: F401

BLOCK_ROWS = 8
LOG2E = 1.4426950408889634


def _softmax_body(x, lut_exp, lut_recip, exp_meta: dict, recip_meta: dict,
                  out_dtype):
    """Fused softmax math, parameterized over the two in-kernel table reads.

    ``lut_exp`` / ``lut_recip`` map int32 codes to the table's integer
    output — either a per-table ``_lut`` or a library-ROM ``_lut_rom``
    closure. Exactly one implementation of the float glue exists, so the
    per-table and library-bound kernels cannot drift."""
    x = x.astype(jnp.float32)  # (BLOCK_ROWS, D)
    m = jnp.max(x, axis=-1, keepdims=True)
    t = jnp.minimum((m - x) * LOG2E, 126.0)
    n = jnp.floor(t)
    frac = t - n
    eb = exp_meta["in_bits"]
    codes = jnp.clip(jnp.round(frac * (1 << eb)).astype(jnp.int32), 0, (1 << eb) - 1)
    tab = lut_exp(codes).astype(jnp.float32)
    e = tab * (2.0 ** -exp_meta["out_bits"]) * jnp.exp2(-n)
    s = jnp.sum(e, axis=-1, keepdims=True)  # > 0
    # IEEE-754 split: s = 1.mant * 2^(E-127); reciprocal table wants 1.x codes
    bits = jax.lax.bitcast_convert_type(s, jnp.int32)
    expo = jnp.bitwise_and(jax.lax.shift_right_logical(bits, 23), 255) - 127
    mant = jnp.bitwise_and(bits, (1 << 23) - 1)
    rb = recip_meta["in_bits"]
    half = 1 << (23 - rb - 1)
    rcodes = jnp.clip(jax.lax.shift_right_logical(mant + half, 23 - rb),
                      0, (1 << rb) - 1)
    rtab = lut_recip(rcodes).astype(jnp.float32)
    recip = rtab * (2.0 ** -(rb + 1)) * jnp.exp2(-expo.astype(jnp.float32))
    return (e * recip).astype(out_dtype)


def _softmax_kernel(x_ref, ecoef_ref, rcoef_ref, out_ref, *, exp_meta: dict,
                    recip_meta: dict):
    out_ref[...] = _softmax_body(
        x_ref[...],
        lambda c: _lut(c, ecoef_ref[...], **exp_meta["eval"]),
        lambda c: _lut(c, rcoef_ref[...], **recip_meta["eval"]),
        exp_meta, recip_meta, out_ref.dtype)


def _softmax_lib_kernel(x_ref, rom_ref, out_ref, *, r_max: int,
                        exp_meta: dict, recip_meta: dict):
    """Library-bound fused softmax: ONE ROM operand for both tables; the
    exp and recip reads are `_lut_rom` gathers at their static func ids —
    the whole softmax (including both transcendentals) is a single kernel
    with no intermediate HBM round-trip."""
    from repro.kernels.interp.kernel import _lut_rom

    rom = rom_ref[...]
    out_ref[...] = _softmax_body(
        x_ref[...],
        lambda c: _lut_rom(c, rom, fid=exp_meta["fid"], r_max=r_max,
                           **exp_meta["eval"]),
        lambda c: _lut_rom(c, rom, fid=recip_meta["fid"], r_max=r_max,
                           **recip_meta["eval"]),
        exp_meta, recip_meta, out_ref.dtype)


def fused_softmax_lib(x: jax.Array, rom: jax.Array, exp_meta: dict,
                      recip_meta: dict, *, r_max: int,
                      interpret: bool = True) -> jax.Array:
    """x: (rows, D) with rows % BLOCK_ROWS == 0, D % 128 == 0; rom: the
    library coefficient ROM flattened to (F * r_max, 3) int32."""
    rows, d = x.shape
    assert rows % BLOCK_ROWS == 0 and d % 128 == 0, x.shape
    kernel = functools.partial(_softmax_lib_kernel, r_max=r_max,
                               exp_meta=exp_meta, recip_meta=recip_meta)
    n_rows = rom.shape[0]
    return pl.pallas_call(
        kernel,
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, d), lambda i: (i, 0)),
            pl.BlockSpec((n_rows, 3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, rom)


def fused_softmax(x: jax.Array, exp_coeffs: jax.Array, recip_coeffs: jax.Array,
                  exp_meta: dict, recip_meta: dict,
                  interpret: bool = True) -> jax.Array:
    """x: (rows, D) with rows % BLOCK_ROWS == 0, D % 128 == 0."""
    rows, d = x.shape
    assert rows % BLOCK_ROWS == 0 and d % 128 == 0, x.shape
    kernel = functools.partial(_softmax_kernel, exp_meta=exp_meta,
                               recip_meta=recip_meta)
    ne, nr = exp_coeffs.shape[0], recip_coeffs.shape[0]
    return pl.pallas_call(
        kernel,
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, d), lambda i: (i, 0)),
            pl.BlockSpec((ne, 3), lambda i: (0, 0)),
            pl.BlockSpec((nr, 3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, exp_coeffs, recip_coeffs)
