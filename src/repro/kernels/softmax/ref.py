"""Oracle for the fused softmax kernel: identical math in plain jnp."""
from __future__ import annotations

import jax
import jax.numpy as jnp

LOG2E = 1.4426950408889634


def _rom_rows(coeffs, meta: dict):
    """Slice one function's live rows out of a padded (F, R_max, 3) ROM."""
    seg = meta["eval"].get("seg")
    if seg is not None:  # ROM v2 slot: per-leaf coeffs + packed seg table
        _, depth, n_leaves, _ = seg
        n_rows = n_leaves + ((1 << depth) + 2) // 3
    else:
        n_rows = 1 << (meta["in_bits"] - meta["eval"]["eval_bits"])
    return coeffs[meta["fid"], :n_rows]


def fused_softmax_lib_ref(x, coeffs, exp_meta, recip_meta):
    """jnp oracle of the library-bound fused softmax kernel: gather the two
    functions' rows from the padded ROM, then the identical glue — bit-
    identical to the per-table oracle because the padded ROM holds exactly
    ``packed_coeffs`` in rows [0, 2^R)."""
    return fused_softmax_ref(x, _rom_rows(coeffs, exp_meta),
                             _rom_rows(coeffs, recip_meta), exp_meta,
                             recip_meta)


def fused_softmax_ref(x, exp_coeffs, recip_coeffs, exp_meta, recip_meta):
    def lut(codes, coeffs, eval_bits, k, sq_trunc, lin_trunc, degree,
            seg=None):
        if seg is not None:
            from repro.kernels.interp.ref import interp_eval_seg_ref

            return interp_eval_seg_ref(codes, coeffs, seg=seg)
        r = jax.lax.shift_right_logical(codes, eval_bits)
        xi = jnp.bitwise_and(codes, (1 << eval_bits) - 1)
        sel = coeffs[r]
        xs = jax.lax.shift_left(jax.lax.shift_right_logical(xi, sq_trunc), sq_trunc)
        xl = jax.lax.shift_left(jax.lax.shift_right_logical(xi, lin_trunc), lin_trunc)
        acc = sel[..., 1] * xl + sel[..., 2]
        if degree == 2:
            acc = acc + sel[..., 0] * xs * xs
        return jax.lax.shift_right_arithmetic(acc, k)

    xf = x.astype(jnp.float32)
    m = jnp.max(xf, axis=-1, keepdims=True)
    t = jnp.minimum((m - xf) * LOG2E, 126.0)
    n = jnp.floor(t)
    frac = t - n
    eb = exp_meta["in_bits"]
    codes = jnp.clip(jnp.round(frac * (1 << eb)).astype(jnp.int32), 0, (1 << eb) - 1)
    tab = lut(codes, exp_coeffs, **exp_meta["eval"]).astype(jnp.float32)
    e = tab * (2.0 ** -exp_meta["out_bits"]) * jnp.exp2(-n)
    s = jnp.sum(e, axis=-1, keepdims=True)
    bits = jax.lax.bitcast_convert_type(s, jnp.int32)
    expo = jnp.bitwise_and(jax.lax.shift_right_logical(bits, 23), 255) - 127
    mant = jnp.bitwise_and(bits, (1 << 23) - 1)
    rb = recip_meta["in_bits"]
    half = 1 << (23 - rb - 1)
    rcodes = jnp.clip(jax.lax.shift_right_logical(mant + half, 23 - rb),
                      0, (1 << rb) - 1)
    rtab = lut(rcodes, recip_coeffs, **recip_meta["eval"]).astype(jnp.float32)
    recip = rtab * (2.0 ** -(rb + 1)) * jnp.exp2(-expo.astype(jnp.float32))
    return (e * recip).astype(x.dtype)
