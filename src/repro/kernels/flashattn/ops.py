"""Jitted public wrappers for the table-numerics flash-attention kernels
(per-table designs, or the whole-library ROM with explicit positions)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.table import TableDesign
from repro.kernels.flashattn.kernel import flash_attention, flash_attention_lib
from repro.kernels.flashattn.ref import (flash_attention_lib_ref,
                                         flash_attention_ref)
from repro.kernels.softmax.ops import _meta, lib_meta
from repro.api import get_table


def _block(n: int) -> int:
    """Largest power-of-two tile in [8, 128] dividing n (n % 8 == 0)."""
    for b in (128, 64, 32, 16):
        if n % b == 0:
            return b
    return 8


def attention_fused_library(q: jax.Array, k: jax.Array, v: jax.Array,
                            library, *, causal: bool = True,
                            scale: float | None = None,
                            window: int | None = None,
                            q_pos: jax.Array | None = None,
                            kv_pos: jax.Array | None = None,
                            use_kernel: bool | None = None,
                            interpret: bool | None = None) -> jax.Array:
    """(B, Sq, H, D) attention through the library-bound fused kernel.

    The library ROM is the single table operand (exp + recip read at their
    static func ids in-kernel). ``q_pos`` / ``kv_pos``: (B, S*) absolute
    positions (-1 = dead KV slot), the decode-against-cache contract of
    ``models.attention.attention_core``; ``None`` means the training layout
    (``arange``). GQA passes k/v with their own (fewer) heads — the kernel
    maps each query-head program onto its kv stripe by index (never
    materializing the expansion); Dk may differ from Dv (MLA).
    ``use_kernel=None`` picks the Pallas kernel on TPU and the unchunked
    jnp oracle elsewhere; the kernel path pads Sq/Sk to tile multiples
    with masked (-1) positions.
    """
    b, sq, h, d = q.shape
    sk, kvh, dv = k.shape[1], k.shape[2], v.shape[-1]
    assert h % kvh == 0, (h, kvh)
    g = h // kvh
    em, rm = lib_meta(library, "exp2neg"), lib_meta(library, "recip")
    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(sq, dtype=jnp.int32), (b, sq))
    if kv_pos is None:
        kv_pos = jnp.broadcast_to(jnp.arange(sk, dtype=jnp.int32), (b, sk))
    qn = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kn = k.transpose(0, 2, 1, 3).reshape(b * kvh, sk, k.shape[-1])
    vn = v.transpose(0, 2, 1, 3).reshape(b * kvh, sk, dv)
    qp = jnp.repeat(q_pos.astype(jnp.int32), h, axis=0)  # (B*H, Sq)
    kp = jnp.repeat(kv_pos.astype(jnp.int32), kvh, axis=0)
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if not use_kernel:
        # the unchunked oracle takes one kv stripe per query row
        if g > 1:
            kn = jnp.repeat(kn.reshape(b, kvh, sk, -1), g, axis=1
                            ).reshape(b * h, sk, -1)
            vn = jnp.repeat(vn.reshape(b, kvh, sk, -1), g, axis=1
                            ).reshape(b * h, sk, -1)
            kp = jnp.repeat(kp, g, axis=0)
        o = flash_attention_lib_ref(qn, kn, vn, qp, kp, library.coeffs, em,
                                    rm, causal=causal, window=window,
                                    scale=scale)
        return o.reshape(b, h, sq, dv).transpose(0, 2, 1, 3)
    pad_q, pad_k = (-sq) % 8, (-sk) % 8
    if pad_q:
        qn = jnp.pad(qn, ((0, 0), (0, pad_q), (0, 0)))
        qp = jnp.pad(qp, ((0, 0), (0, pad_q)), constant_values=-1)
    if pad_k:
        kn = jnp.pad(kn, ((0, 0), (0, pad_k), (0, 0)))
        vn = jnp.pad(vn, ((0, 0), (0, pad_k), (0, 0)))
        kp = jnp.pad(kp, ((0, 0), (0, pad_k)), constant_values=-1)
    interpret = (jax.default_backend() != "tpu") if interpret is None else interpret
    o = flash_attention_lib(
        qn, kn, vn, qp, kp, library.coeffs.reshape(-1, 3), em, rm,
        r_max=library.coeffs.shape[1], causal=causal, window=window,
        scale=scale, kv_group=g, block_q=_block(sq + pad_q),
        block_k=_block(sk + pad_k), interpret=interpret)
    return o[:, :sq].reshape(b, h, sq, dv).transpose(0, 2, 1, 3)


def attention_fused(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    exp_design: TableDesign | None = None,
                    recip_design: TableDesign | None = None,
                    use_kernel: bool = True,
                    interpret: bool | None = None) -> jax.Array:
    """(B, S, H, D) multi-head attention through the fused kernel.

    GQA callers expand kv heads first (kernel contract: one kv stripe per
    query head)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    assert k.shape[2] == h, "expand GQA kv heads before calling"
    exp_design = exp_design or get_table("exp2neg")
    recip_design = recip_design or get_table("recip")
    qn = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kn = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vn = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    if not use_kernel:
        o = flash_attention_ref(qn, kn, vn, exp_design, recip_design,
                                causal=causal, scale=scale)
    else:
        interpret = (jax.default_backend() != "tpu") if interpret is None else interpret
        ec = exp_design.device_coeffs(checked=True)
        rc = recip_design.device_coeffs(checked=True)
        o = flash_attention(qn, kn, vn, ec, rc, _meta(exp_design),
                            _meta(recip_design), causal=causal, scale=scale,
                            interpret=interpret)
    return o.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
