"""Jitted public wrapper for the table-numerics flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.table import TableDesign
from repro.kernels.flashattn.kernel import flash_attention
from repro.kernels.flashattn.ref import flash_attention_ref
from repro.kernels.softmax.ops import _meta
from repro.api import get_table


def attention_fused(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    exp_design: TableDesign | None = None,
                    recip_design: TableDesign | None = None,
                    use_kernel: bool = True,
                    interpret: bool | None = None) -> jax.Array:
    """(B, S, H, D) multi-head attention through the fused kernel.

    GQA callers expand kv heads first (kernel contract: one kv stripe per
    query head)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    assert k.shape[2] == h, "expand GQA kv heads before calling"
    exp_design = exp_design or get_table("exp2neg")
    recip_design = recip_design or get_table("recip")
    qn = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kn = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vn = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    if not use_kernel:
        o = flash_attention_ref(qn, kn, vn, exp_design, recip_design,
                                causal=causal, scale=scale)
    else:
        interpret = (jax.default_backend() != "tpu") if interpret is None else interpret
        ec = exp_design.device_coeffs(checked=True)
        rc = recip_design.device_coeffs(checked=True)
        o = flash_attention(qn, kn, vn, ec, rc, _meta(exp_design),
                            _meta(recip_design), causal=causal, scale=scale,
                            interpret=interpret)
    return o.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
