"""Pure-jnp oracle for the flash-attention kernel: unchunked attention with
the same table-backed exponential / reciprocal semantics."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.numerics.ops import approx_exp_neg, approx_recip_pos

NEG = -1e30
M_FLOOR = -1e20


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        exp_design, recip_design, *, causal: bool = True,
                        scale: float | None = None) -> jax.Array:
    """q: (N, Sq, D); k, v: (N, Sk, D)."""
    n, sq, d = q.shape
    sk = k.shape[1]
    scale = (d ** -0.5) if scale is None else scale
    s = jnp.einsum("nqd,nkd->nqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        qp = jnp.arange(sq)[:, None]
        kp = jnp.arange(sk)[None, :]
        s = jnp.where(qp >= kp, s, NEG)
    m = jnp.maximum(jnp.max(s, -1, keepdims=True), M_FLOOR)
    p = approx_exp_neg(s - m, exp_design)
    l = jnp.sum(p, -1, keepdims=True)
    o = jnp.einsum("nqk,nkd->nqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return (o * approx_recip_pos(jnp.maximum(l, 1e-30), recip_design)
            ).astype(v.dtype)
