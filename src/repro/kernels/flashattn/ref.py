"""Pure-jnp oracle for the flash-attention kernel: unchunked attention with
the same table-backed exponential / reciprocal semantics."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.numerics.ops import approx_exp_neg, approx_recip_pos

NEG = -1e30
M_FLOOR = -1e20
LOG2E = 1.4426950408889634


def flash_attention_lib_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                            q_pos: jax.Array, kv_pos: jax.Array,
                            coeffs: jax.Array, exp_meta: dict,
                            recip_meta: dict, *, causal: bool = True,
                            window: int | None = None,
                            scale: float | None = None) -> jax.Array:
    """Unchunked oracle of the library-bound flash kernel.

    Same in-kernel glue (`_table_exp_neg` / `_table_recip`) over the padded
    (F, R_max, 3) ROM — the integer table reads are bit-identical to the
    kernel's `_lut_rom`; only the chunked renormalization order differs.
    q: (N, Sq, D); k: (N, Sk, Dk); v: (N, Sk, Dv); positions as in the
    kernel (-1 = dead/padded row)."""
    from repro.kernels.flashattn.kernel import _table_exp_neg, _table_recip
    from repro.kernels.interp.ref import interp_eval_ref
    from repro.kernels.softmax.ref import _rom_rows

    def rom_lut(meta):
        rows = _rom_rows(coeffs, meta)
        seg = meta["eval"].get("seg")
        if seg is not None:  # ROM v2 slot: segment-index datapath
            from repro.kernels.interp.ref import interp_eval_seg_ref

            return lambda c: interp_eval_seg_ref(c, rows, seg=seg)
        return lambda c: interp_eval_ref(c, rows, **meta["eval"])

    n, sq, d = q.shape
    scale = (d ** -0.5) if scale is None else scale
    s = jnp.einsum("nqd,nkd->nqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    ok = (kv_pos >= 0)[:, None, :]
    if causal:
        ok = jnp.logical_and(ok, q_pos[:, :, None] >= kv_pos[:, None, :])
    if window is not None:
        ok = jnp.logical_and(ok, q_pos[:, :, None] - kv_pos[:, None, :] < window)
    s = jnp.where(ok, s, NEG)
    m = jnp.maximum(jnp.max(s, -1, keepdims=True), M_FLOOR)
    p = _table_exp_neg((m - s) * LOG2E, rom_lut(exp_meta), exp_meta)
    l = jnp.sum(p, -1, keepdims=True)
    o = jnp.einsum("nqk,nkd->nqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    recip = _table_recip(jnp.maximum(l, 1e-30), rom_lut(recip_meta), recip_meta)
    return (o * recip).astype(v.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        exp_design, recip_design, *, causal: bool = True,
                        scale: float | None = None) -> jax.Array:
    """q: (N, Sq, D); k, v: (N, Sk, D)."""
    n, sq, d = q.shape
    sk = k.shape[1]
    scale = (d ** -0.5) if scale is None else scale
    s = jnp.einsum("nqd,nkd->nqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        qp = jnp.arange(sq)[:, None]
        kp = jnp.arange(sk)[None, :]
        s = jnp.where(qp >= kp, s, NEG)
    m = jnp.maximum(jnp.max(s, -1, keepdims=True), M_FLOOR)
    p = approx_exp_neg(s - m, exp_design)
    l = jnp.sum(p, -1, keepdims=True)
    o = jnp.einsum("nqk,nkd->nqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return (o * approx_recip_pos(jnp.maximum(l, 1e-30), recip_design)
            ).astype(v.dtype)
