"""Pallas TPU kernel: fused flash attention with table-backed exp/recip.

The structural answer to the §Perf Cell-B memory term: the score block,
mask, exponential, running renormalization and PV product live entirely in
VMEM — HBM sees only Q/K/V reads and one output write per tile. Both
transcendentals come from the paper's certified tables (the same `_lut`
one-hot-MXU datapath as kernels/softmax), so the fused kernel *is* the
generated hardware of Fig. 1 dropped into the attention hot loop.

Tiling: grid (N heads-batch, Sq/BLOCK_Q); per step the q tile (BLOCK_Q, D)
and the full K/V stripe (Sk, D) for that head are VMEM-resident (bf16
Sk=4k, D=128 -> 2 MB; longer Sk moves kv onto the grid axis — documented
bound). The kv loop runs in BLOCK_K chunks with `pl.when`-guarded compute:
causally-dead chunks are skipped (perf iteration B1 inside the kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.interp.kernel import _lut_rom
from repro.kernels.softmax.kernel import _lut

BLOCK_Q = 128
BLOCK_K = 128
LOG2E = 1.4426950408889634
NEG = -1e30
M_FLOOR = -1e20


def _table_exp_neg(t, lut, meta):
    """2^(-t) for t >= 0 via the exp2neg table (exact power-of-2 scaling).
    ``lut``: int32 codes -> integer table output (per-table or library-ROM
    closure — one copy of the glue for both kernel variants)."""
    t = jnp.minimum(t, 126.0)
    n = jnp.floor(t)
    frac = t - n
    eb = meta["in_bits"]
    codes = jnp.clip(jnp.round(frac * (1 << eb)).astype(jnp.int32),
                     0, (1 << eb) - 1)
    tab = lut(codes).astype(jnp.float32)
    return tab * (2.0 ** -meta["out_bits"]) * jnp.exp2(-n)


def _table_recip(s, lut, meta):
    """1/s for s > 0 via IEEE-754 mantissa split + reciprocal table."""
    bits = jax.lax.bitcast_convert_type(s, jnp.int32)
    expo = jnp.bitwise_and(jax.lax.shift_right_logical(bits, 23), 255) - 127
    mant = jnp.bitwise_and(bits, (1 << 23) - 1)
    rb = meta["in_bits"]
    half = 1 << (23 - rb - 1)
    rcodes = jnp.clip(jax.lax.shift_right_logical(mant + half, 23 - rb),
                      0, (1 << rb) - 1)
    rtab = lut(rcodes).astype(jnp.float32)
    return rtab * (2.0 ** -(rb + 1)) * jnp.exp2(-expo.astype(jnp.float32))


def _flash_loop(q, k_ref, v_ref, out_ref, lut_exp, lut_recip, exp_meta: dict,
                recip_meta: dict, block_k: int, mask_chunk, chunk_live):
    """The online-softmax flash recurrence shared by the per-table and
    library-bound kernels: kv-chunked score/renormalize/PV loop with
    `pl.when`-style liveness skipping, then the reciprocal epilogue.

    ``mask_chunk(j, s)`` masks one (BQ, BK) score chunk (or returns it
    untouched); ``chunk_live(j)`` returns a traced liveness bool for the
    ``lax.cond`` skip, or None to always run the chunk. One copy of the
    m/l/acc update — the two kernel variants differ only in masking and
    table-read closures and cannot drift."""
    sk = k_ref.shape[1]
    nk = sk // block_k
    bq = q.shape[0]

    def body(j, carry):
        m_i, l_i, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(k_ref[0], j * block_k, block_k
                                          ).astype(jnp.float32)  # (BK, D)
        vb = jax.lax.dynamic_slice_in_dim(v_ref[0], j * block_k, block_k)
        s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (BQ, BK)
        s = mask_chunk(j, s)
        m_new = jnp.maximum(jnp.maximum(m_i, jnp.max(s, -1, keepdims=True)),
                            M_FLOOR)
        p = _table_exp_neg((m_new - s) * LOG2E, lut_exp, exp_meta)
        corr = _table_exp_neg((m_new - m_i) * LOG2E, lut_exp, exp_meta)
        l_new = l_i * corr + jnp.sum(p, -1, keepdims=True)
        pv = jax.lax.dot_general(p.astype(vb.dtype), vb,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        return m_new, l_new, acc * corr + pv

    def guarded(j, carry):
        live = chunk_live(j)
        if live is None:
            return body(j, carry)
        return jax.lax.cond(live, lambda c: body(j, c), lambda c: c, carry)

    init = (jnp.full((bq, 1), M_FLOOR, jnp.float32),
            jnp.zeros((bq, 1), jnp.float32),
            jnp.zeros((bq, v_ref.shape[-1]), jnp.float32))
    m_i, l_i, acc = jax.lax.fori_loop(0, nk, guarded, init)
    recip = _table_recip(jnp.maximum(l_i, 1e-30), lut_recip, recip_meta)
    out_ref[0] = (acc * recip).astype(out_ref.dtype)


def _flash_kernel(q_ref, k_ref, v_ref, ecoef_ref, rcoef_ref, out_ref, *,
                  causal: bool, scale: float, exp_meta: dict,
                  recip_meta: dict, block_k: int):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale  # (BQ, D)
    bq = q.shape[0]
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    def mask_chunk(j, s):
        if not causal:
            return s
        k_pos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        return jnp.where(q_pos >= k_pos, s, NEG)

    def chunk_live(j):
        if not causal:
            return None
        # B1 inside the kernel: skip chunks strictly above the diagonal
        return (j * block_k) <= (qi * bq + bq - 1)

    _flash_loop(q, k_ref, v_ref, out_ref,
                lambda c: _lut(c, ecoef_ref[...], **exp_meta["eval"]),
                lambda c: _lut(c, rcoef_ref[...], **recip_meta["eval"]),
                exp_meta, recip_meta, block_k, mask_chunk, chunk_live)


def _flash_lib_kernel(q_ref, k_ref, v_ref, qpos_ref, kpos_ref, rom_ref,
                      out_ref, *, causal: bool, window: int | None,
                      scale: float, r_max: int, exp_meta: dict,
                      recip_meta: dict, block_k: int):
    """Library-bound flash attention with explicit position operands.

    Both transcendentals read the whole-library ROM (`_lut_rom` at their
    static func ids) — the approximation datapath is inlined into the
    attention kernel, not a lookup service between ops. ``qpos_ref`` /
    ``kpos_ref`` carry *absolute* positions per row: decode against a
    partially-filled KV cache masks dead slots (pos < 0), applies causality
    by position (not buffer index), and honors a sliding window — the same
    contract as ``models.attention._mask``.
    """
    q = q_ref[0].astype(jnp.float32) * scale  # (BQ, D)
    qp = qpos_ref[0]  # (BQ,) int32, -1 = padded query row
    rom = rom_ref[...]
    imax = jnp.iinfo(jnp.int32).max

    def kpos(j):
        return jax.lax.dynamic_slice_in_dim(kpos_ref[0], j * block_k, block_k)

    def mask_chunk(j, s):
        kpb = kpos(j)
        ok = (kpb >= 0)[None, :]
        if causal:
            ok = jnp.logical_and(ok, qp[:, None] >= kpb[None, :])
        if window is not None:
            ok = jnp.logical_and(ok, qp[:, None] - kpb[None, :] < window)
        return jnp.where(ok, s, NEG)

    def chunk_live(j):
        # chunk liveness from the position operands (the per-table kernel's
        # B1 by grid index can't see cache occupancy): dead if every slot is
        # empty, entirely in the causal future, or outside the window
        kpb = kpos(j)
        need = jnp.any(kpb >= 0)
        if causal:
            need = jnp.logical_and(
                need, jnp.min(jnp.where(kpb < 0, imax, kpb)) <= jnp.max(qp))
        if window is not None:
            qmin = jnp.min(jnp.where(qp < 0, imax, qp))
            need = jnp.logical_and(need, jnp.max(kpb) > qmin - window)
        return need

    _flash_loop(q, k_ref, v_ref, out_ref,
                lambda c: _lut_rom(c, rom, fid=exp_meta["fid"], r_max=r_max,
                                   **exp_meta["eval"]),
                lambda c: _lut_rom(c, rom, fid=recip_meta["fid"],
                                   r_max=r_max, **recip_meta["eval"]),
                exp_meta, recip_meta, block_k, mask_chunk, chunk_live)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    exp_coeffs: jax.Array, recip_coeffs: jax.Array,
                    exp_meta: dict, recip_meta: dict, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = BLOCK_Q, block_k: int = BLOCK_K,
                    interpret: bool = True) -> jax.Array:
    """q: (N, Sq, D); k, v: (N, Sk, D). N = batch x heads (GQA expansion is
    the caller's contract). Sq % block_q == 0, Sk % block_k == 0."""
    n, sq, d = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk)
    scale = (d ** -0.5) if scale is None else scale
    kernel = functools.partial(_flash_kernel, causal=causal, scale=scale,
                               exp_meta=exp_meta, recip_meta=recip_meta,
                               block_k=block_k)
    ne, nr = exp_coeffs.shape[0], recip_coeffs.shape[0]
    return pl.pallas_call(
        kernel,
        grid=(n, sq // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, sk, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, sk, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((ne, 3), lambda i, j: (0, 0)),
            pl.BlockSpec((nr, 3), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, sq, d), v.dtype),
        interpret=interpret,
    )(q, k, v, exp_coeffs, recip_coeffs)


def flash_attention_lib(q: jax.Array, k: jax.Array, v: jax.Array,
                        q_pos: jax.Array, kv_pos: jax.Array, rom: jax.Array,
                        exp_meta: dict, recip_meta: dict, *, r_max: int,
                        causal: bool = True, window: int | None = None,
                        scale: float | None = None, kv_group: int = 1,
                        block_q: int = BLOCK_Q, block_k: int = BLOCK_K,
                        interpret: bool = True) -> jax.Array:
    """q: (N, Sq, D); k: (N // kv_group, Sk, Dk); v: (N // kv_group, Sk,
    Dv); q_pos: (N, Sq) int32 (-1 = padded row); kv_pos: (N // kv_group,
    Sk) int32 (-1 = dead cache slot); rom: the library ROM flattened to
    (F * r_max, 3). N = batch x query heads; GQA is expressed through
    ``kv_group`` = heads per kv head — query program i reads kv stripe
    ``i // kv_group`` via the BlockSpec index map, so grouped K/V are
    never materialized per query head. Sq % block_q == 0, Sk % block_k == 0.
    """
    n, sq, d = q.shape
    sk, dv = k.shape[1], v.shape[-1]
    g = kv_group
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk)
    assert n % g == 0 and k.shape[0] == n // g, (n, g, k.shape)
    assert q_pos.shape == (n, sq) and kv_pos.shape == (n // g, sk), \
        (q_pos.shape, kv_pos.shape)
    scale = (d ** -0.5) if scale is None else scale
    kernel = functools.partial(_flash_lib_kernel, causal=causal,
                               window=window, scale=scale, r_max=r_max,
                               exp_meta=exp_meta, recip_meta=recip_meta,
                               block_k=block_k)
    n_rows = rom.shape[0]
    return pl.pallas_call(
        kernel,
        grid=(n, sq // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, sk, k.shape[-1]), lambda i, j: (i // g, 0, 0)),
            pl.BlockSpec((1, sk, dv), lambda i, j: (i // g, 0, 0)),
            pl.BlockSpec((1, block_q), lambda i, j: (i, j)),
            pl.BlockSpec((1, sk), lambda i, j: (i // g, 0)),
            pl.BlockSpec((n_rows, 3), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, sq, dv), v.dtype),
        interpret=interpret,
    )(q, k, v, q_pos.astype(jnp.int32), kv_pos.astype(jnp.int32), rom)
