"""Pallas TPU kernel: fused flash attention with table-backed exp/recip.

The structural answer to the §Perf Cell-B memory term: the score block,
mask, exponential, running renormalization and PV product live entirely in
VMEM — HBM sees only Q/K/V reads and one output write per tile. Both
transcendentals come from the paper's certified tables (the same `_lut`
one-hot-MXU datapath as kernels/softmax), so the fused kernel *is* the
generated hardware of Fig. 1 dropped into the attention hot loop.

Tiling: grid (N heads-batch, Sq/BLOCK_Q); per step the q tile (BLOCK_Q, D)
and the full K/V stripe (Sk, D) for that head are VMEM-resident (bf16
Sk=4k, D=128 -> 2 MB; longer Sk moves kv onto the grid axis — documented
bound). The kv loop runs in BLOCK_K chunks with `pl.when`-guarded compute:
causally-dead chunks are skipped (perf iteration B1 inside the kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.softmax.kernel import _lut

BLOCK_Q = 128
BLOCK_K = 128
LOG2E = 1.4426950408889634
NEG = -1e30
M_FLOOR = -1e20


def _table_exp_neg(t, coeffs, meta):
    """2^(-t) for t >= 0 via the exp2neg table (exact power-of-2 scaling)."""
    t = jnp.minimum(t, 126.0)
    n = jnp.floor(t)
    frac = t - n
    eb = meta["in_bits"]
    codes = jnp.clip(jnp.round(frac * (1 << eb)).astype(jnp.int32),
                     0, (1 << eb) - 1)
    tab = _lut(codes, coeffs, **meta["eval"]).astype(jnp.float32)
    return tab * (2.0 ** -meta["out_bits"]) * jnp.exp2(-n)


def _table_recip(s, coeffs, meta):
    """1/s for s > 0 via IEEE-754 mantissa split + reciprocal table."""
    bits = jax.lax.bitcast_convert_type(s, jnp.int32)
    expo = jnp.bitwise_and(jax.lax.shift_right_logical(bits, 23), 255) - 127
    mant = jnp.bitwise_and(bits, (1 << 23) - 1)
    rb = meta["in_bits"]
    half = 1 << (23 - rb - 1)
    rcodes = jnp.clip(jax.lax.shift_right_logical(mant + half, 23 - rb),
                      0, (1 << rb) - 1)
    rtab = _lut(rcodes, coeffs, **meta["eval"]).astype(jnp.float32)
    return rtab * (2.0 ** -(rb + 1)) * jnp.exp2(-expo.astype(jnp.float32))


def _flash_kernel(q_ref, k_ref, v_ref, ecoef_ref, rcoef_ref, out_ref, *,
                  causal: bool, scale: float, exp_meta: dict,
                  recip_meta: dict, block_k: int):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale  # (BQ, D)
    sk = k_ref.shape[1]
    nk = sk // block_k
    bq = q.shape[0]
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    def body(j, carry):
        m_i, l_i, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(k_ref[0], j * block_k, block_k
                                          ).astype(jnp.float32)  # (BK, D)
        vb = jax.lax.dynamic_slice_in_dim(v_ref[0], j * block_k, block_k)
        s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (BQ, BK)
        if causal:
            k_pos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (1, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG)
        m_new = jnp.maximum(jnp.maximum(m_i, jnp.max(s, -1, keepdims=True)),
                            M_FLOOR)
        p = _table_exp_neg((m_new - s) * LOG2E, ecoef_ref[...], exp_meta)
        corr = _table_exp_neg((m_new - m_i) * LOG2E, ecoef_ref[...], exp_meta)
        l_new = l_i * corr + jnp.sum(p, -1, keepdims=True)
        pv = jax.lax.dot_general(p.astype(vb.dtype), vb,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        return m_new, l_new, acc * corr + pv

    def guarded(j, carry):
        if not causal:
            return body(j, carry)
        # B1 inside the kernel: skip chunks strictly above the diagonal
        live = (j * block_k) <= (qi * bq + bq - 1)
        return jax.lax.cond(live, lambda c: body(j, c), lambda c: c, carry)

    init = (jnp.full((bq, 1), M_FLOOR, jnp.float32),
            jnp.zeros((bq, 1), jnp.float32),
            jnp.zeros((bq, v_ref.shape[-1]), jnp.float32))
    m_i, l_i, acc = jax.lax.fori_loop(0, nk, guarded, init)
    recip = _table_recip(jnp.maximum(l_i, 1e-30), rcoef_ref[...], recip_meta)
    out_ref[0] = (acc * recip).astype(out_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    exp_coeffs: jax.Array, recip_coeffs: jax.Array,
                    exp_meta: dict, recip_meta: dict, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = BLOCK_Q, block_k: int = BLOCK_K,
                    interpret: bool = True) -> jax.Array:
    """q: (N, Sq, D); k, v: (N, Sk, D). N = batch x heads (GQA expansion is
    the caller's contract). Sq % block_q == 0, Sk % block_k == 0."""
    n, sq, d = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk)
    scale = (d ** -0.5) if scale is None else scale
    kernel = functools.partial(_flash_kernel, causal=causal, scale=scale,
                               exp_meta=exp_meta, recip_meta=recip_meta,
                               block_k=block_k)
    ne, nr = exp_coeffs.shape[0], recip_coeffs.shape[0]
    return pl.pallas_call(
        kernel,
        grid=(n, sq // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, sk, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, sk, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((ne, 3), lambda i, j: (0, 0)),
            pl.BlockSpec((nr, 3), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, sq, d), v.dtype),
        interpret=interpret,
    )(q, k, v, exp_coeffs, recip_coeffs)
