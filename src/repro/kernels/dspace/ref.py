"""Dense jnp oracle for the envelope kernel: straight from the definition."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

BIG = 3.4e38


def envelopes_parity_ref(l_arr, u_arr):
    """O(N^2)-memory masked reduction; returns (m_even, m_odd, M_even, M_odd)."""
    n = l_arr.shape[-1]
    lf = jnp.asarray(l_arr, jnp.float32)
    uf = jnp.asarray(u_arr, jnp.float32)
    x = jnp.arange(n)[:, None]
    y = jnp.arange(n)[None, :]
    with np.errstate(divide="ignore", invalid="ignore"):
        d_up = jnp.where(y > x, (uf[None, :] + 1.0 - lf[:, None]) / jnp.maximum(y - x, 1), BIG)
        d_lo = jnp.where(y > x, (lf[None, :] - uf[:, None] - 1.0) / jnp.maximum(y - x, 1), -BIG)
    m_even = jnp.full(n, BIG)
    m_odd = jnp.full(n, BIG)
    b_even = jnp.full(n, -BIG)
    b_odd = jnp.full(n, -BIG)
    tsum = x + y
    for j in range(n):
        even_mask = (tsum == 2 * j) & (y > x)
        odd_mask = (tsum == 2 * j + 1) & (y > x)
        m_even = m_even.at[j].set(jnp.where(even_mask, d_up, BIG).min())
        b_even = b_even.at[j].set(jnp.where(even_mask, d_lo, -BIG).max())
        m_odd = m_odd.at[j].set(jnp.where(odd_mask, d_up, BIG).min())
        b_odd = b_odd.at[j].set(jnp.where(odd_mask, d_lo, -BIG).max())
    return m_even, m_odd, b_even, b_odd


def envelopes_parity_ref_batched(l_rows, u_rows):
    """Region-batched oracle for ``kernel.envelopes_parity_batched``:
    the dense reference mapped over the leading (region) axis."""
    outs = [envelopes_parity_ref(l_rows[r], u_rows[r])
            for r in range(l_rows.shape[0])]
    return tuple(jnp.stack([o[i] for o in outs]) for i in range(4))
