"""Public wrapper: Pallas-accelerated envelope computation for generation.

``envelopes_pallas`` returns M(t), m(t) in the exact layout the core numpy
path (`repro.core.designspace.envelopes`) produces, so the generator can swap
implementations freely (``impl="pallas"`` in benchmarks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.dspace.kernel import TILE, envelopes_parity
from repro.kernels.dspace.ref import envelopes_parity_ref


def _interleave(me, mo, be, bo, n: int):
    """Parity arrays -> (M, m) indexed by t in [0, 2n-2); index 0 is padding."""
    m = np.empty(2 * n - 2, dtype=np.float64)
    big_m = np.empty(2 * n - 2, dtype=np.float64)
    m[0::2] = np.asarray(me)[: n - 1]
    m[1::2] = np.asarray(mo)[: n - 1]
    big_m[0::2] = np.asarray(be)[: n - 1]
    big_m[1::2] = np.asarray(bo)[: n - 1]
    m[0], big_m[0] = np.inf, -np.inf
    m[m >= 3.0e38] = np.inf
    big_m[big_m <= -3.0e38] = -np.inf
    return big_m, m


def envelopes_pallas(L: np.ndarray, U: np.ndarray, interpret: bool = True
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Drop-in replacement for core.designspace.envelopes via the kernel.

    Pads N up to a TILE multiple; pad lanes only ever appear as the *right*
    (y) operand of a kept-lane pair, so L[pad] = -2^30 / U[pad] = +2^30 make
    every pad-touching divided difference lose its min/max reduction.
    """
    n = len(L)
    if n < 2:
        return np.full(1, -np.inf), np.full(1, np.inf)
    n_pad = max(((n + TILE - 1) // TILE) * TILE, TILE)
    lp = np.zeros(n_pad, np.float64)
    up = np.zeros(n_pad, np.float64)
    lp[:n], up[:n] = L, U
    if n_pad > n:
        lp[n:] = -(2.0**30)  # d_lo = (L[y]-U[x]-1)/.. -> -huge, loses max
        up[n:] = 2.0**30  # d_up = (U[y]+1-L[x])/.. -> +huge, loses min
    me, mo, be, bo = envelopes_parity(jnp.asarray(lp), jnp.asarray(up), interpret)
    big_m, m = _interleave(me, mo, be, bo, n_pad)
    return big_m[: 2 * n - 2], m[: 2 * n - 2]


def envelopes_ref_jnp(L: np.ndarray, U: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    n = len(L)
    if n < 2:
        return np.full(1, -np.inf), np.full(1, np.inf)
    me, mo, be, bo = envelopes_parity_ref(jnp.asarray(L), jnp.asarray(U))
    return _interleave(me, mo, be, bo, n)
